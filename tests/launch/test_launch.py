"""Launch-layer tests: dry-run cells on a tiny debug mesh (subprocess —
jax locks the virtual device count at first init), elastic resharding,
HLO parser, and shape applicability."""
import json
import os
import subprocess
import sys

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "..", "src")


def run_dryrun(arch, shape, *flags, timeout=420):
    env = dict(os.environ, PYTHONPATH=SRC)
    cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
           "--shape", shape, "--debug-mesh", *flags]
    proc = subprocess.run(cmd, capture_output=True, text=True,
                          timeout=timeout, env=env)
    assert proc.returncode == 0, proc.stderr[-3000:]
    return json.loads(proc.stdout[proc.stdout.index("{"):])


@pytest.mark.slow
def test_dryrun_decode_cell_exact():
    r = run_dryrun("h2o_danube3_4b", "decode_32k", "--exact")
    assert r["status"] == "ok"
    assert r["roofline"]["flops_global"] > 0
    assert r["cost"]["collective_bytes_per_device"] > 0
    assert r["memory"]["argument_bytes_per_device"] > 0


@pytest.mark.slow
def test_dryrun_multipod_mesh():
    r = run_dryrun("h2o_danube3_4b", "decode_32k", "--multi-pod")
    assert r["status"] == "ok"   # proves the pod axis shards


@pytest.mark.slow
def test_dryrun_long_context_ssm():
    r = run_dryrun("mamba2_370m", "long_500k")
    assert r["status"] == "ok"


def test_dryrun_long_skip_for_full_attention():
    from repro.configs import get_config
    from repro.launch.shapes import SHAPES, is_applicable
    ok, reason = is_applicable(get_config("llama3_405b"),
                               SHAPES["long_500k"])
    assert not ok and "quadratic" in reason
    ok, _ = is_applicable(get_config("zamba2_1p2b"), SHAPES["long_500k"])
    assert ok
    ok, _ = is_applicable(get_config("h2o_danube3_4b"), SHAPES["long_500k"])
    assert ok   # SWA bounds the cache


def test_all_cells_have_input_specs():
    """Every (arch × shape) cell must produce well-formed specs."""
    from repro.configs import assigned_architectures, get_config
    from repro.launch.shapes import SHAPES, input_specs, is_applicable
    count = 0
    for arch in assigned_architectures():
        cfg = get_config(arch)
        for shape in SHAPES.values():
            specs = input_specs(cfg, shape)
            assert specs, (arch, shape.name)
            for leaf in specs.values():
                assert all(d > 0 for d in leaf.shape)
            count += 1
    assert count == 40   # the full assignment grid


def test_mesh_factories_no_device_requirement():
    """Importing mesh.py must not touch jax device state."""
    import repro.launch.mesh  # noqa: F401  (import side-effect check)


def test_hlo_collective_parser_units():
    from repro.roofline.hlo import collective_bytes, roofline_terms
    hlo = """
HloModule test
  %ag = bf16[4,128]{1,0} all-gather(%p0), replica_groups={{0,1}}
  %p0 = bf16[2,128]{1,0} parameter(0)
  %ar.1 = f32[64]{0} all-reduce(%conv), to_apply=%sum
  %conv = f32[64]{0} convert(%ag)
  %rs = (f32[32]{0}, f32[32]{0}) reduce-scatter(%a, %b)
  %a = f32[64]{0} constant(0)
  %b = f32[64]{0} constant(0)
"""
    out = collective_bytes(hlo)
    assert out["all-gather"]["count"] == 1
    assert out["all-gather"]["bytes"] == 2 * 128 * 2
    assert out["all-reduce"]["bytes"] == 64 * 4
    assert out["reduce-scatter"]["bytes"] == 2 * 64 * 4
    terms = roofline_terms(197e12, 819e9, 50e9, chips=256)
    assert terms["compute_s"] == pytest.approx(1.0)
    assert terms["memory_s"] == pytest.approx(1.0)
    assert terms["collective_s"] == pytest.approx(1.0)


@pytest.mark.slow
def test_elastic_reshard_across_meshes():
    """Params sharded on a 2×2×2 mesh survive a pod failure: reshard onto
    1×2×2 with identical values (subprocess: needs 8 virtual devices)."""
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.runtime.elastic import build_mesh, shrink_after_failure, reshard_state
from repro.sharding import specs_to_shardings

devs = jax.devices()
mesh = build_mesh(devs, (2, 2, 2), ("pod", "data", "model"))
specs = {"w": ("fsdp", "tp"), "b": (None,)}
shardings = specs_to_shardings(specs, mesh)
state = {"w": jnp.arange(64.0).reshape(8, 8), "b": jnp.ones((4,))}
state = jax.tree_util.tree_map(jax.device_put, state, shardings)

failed = [devs[1]]   # device in pod 0 → pod 0 evicted
new_mesh, new_shape = shrink_after_failure(devs, (2, 2, 2),
                                           ("pod", "data", "model"), failed)
assert new_shape == (1, 2, 2), new_shape
restored = reshard_state(state, specs, new_mesh)
np.testing.assert_array_equal(np.asarray(restored["w"]),
                              np.arange(64.0).reshape(8, 8))
np.testing.assert_array_equal(np.asarray(restored["b"]), np.ones(4))
# also expansion: reshard back onto the full 8-device mesh
big = reshard_state(restored, specs, mesh)
np.testing.assert_array_equal(np.asarray(big["w"]),
                              np.arange(64.0).reshape(8, 8))
print("ELASTIC_OK")
"""
    env = dict(os.environ, PYTHONPATH=SRC)
    proc = subprocess.run([sys.executable, "-c", code], capture_output=True,
                          text=True, timeout=300, env=env)
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "ELASTIC_OK" in proc.stdout
