"""Substrate integration tests: prefetch pipeline, async checkpoint
(crash-safe commit + restore), heartbeat failure detection, offload."""
import os
import shutil
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.async_ckpt import AsyncCheckpointer
from repro.configs import get_config
from repro.core import Engine, Transport
from repro.data.pipeline import PrefetchPipeline, SyntheticTokenSource
from repro.runtime.heartbeat import HeartbeatMonitor, HeartbeatSender
from repro.runtime.offload import (ContinuationBackend, OffloadManager,
                                   TestsomeBackend)


@pytest.fixture
def engine():
    eng = Engine()
    yield eng
    eng.shutdown()


def test_prefetch_pipeline_produces_all_batches(engine):
    cfg = get_config("paper_demo", reduced=True)
    src = SyntheticTokenSource(cfg, global_batch=2, seq_len=16,
                               fill_latency_s=0.002)
    pipe = PrefetchPipeline(src, engine, depth=3, max_batches=10)
    seen = [b["tokens"].copy() for b in pipe]
    assert len(seen) == 10
    # determinism: batch i depends only on i
    src2 = SyntheticTokenSource(cfg, global_batch=2, seq_len=16)
    np.testing.assert_array_equal(seen[3], src2.make_batch(3)["tokens"])
    pipe.close()


def test_prefetch_overlaps_compute(engine):
    """With prefetch depth 2 and fill latency L, consuming N batches with
    compute ≥ L per step should take ≈ N·compute, not N·(compute+L)."""
    cfg = get_config("paper_demo", reduced=True)
    L = 0.02
    src = SyntheticTokenSource(cfg, 2, 16, fill_latency_s=L)
    pipe = PrefetchPipeline(src, engine, depth=2, max_batches=8)
    t0 = time.monotonic()
    for _ in range(8):
        b = pipe.get_next()
        time.sleep(L)          # simulated compute
    elapsed = time.monotonic() - t0
    assert elapsed < 8 * 2 * L * 0.95, f"no overlap: {elapsed:.3f}s"
    pipe.close()


def test_checkpoint_save_restore_roundtrip(tmp_path, engine):
    state = {"params": {"w": jnp.arange(12.0).reshape(3, 4),
                        "b": jnp.ones((4,))},
             "step": jnp.int32(7)}
    ckpt = AsyncCheckpointer(str(tmp_path), engine, keep=2)
    handle = ckpt.save_async(7, state)
    assert handle.wait(timeout=30)
    assert ckpt.latest_step() == 7
    restored = ckpt.restore(7, state)
    for a, b in zip(jax.tree_util.tree_leaves(state),
                    jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    ckpt.close()


def test_checkpoint_uncommitted_invisible(tmp_path, engine):
    """A crash mid-save (no manifest) must not be restorable."""
    ckpt = AsyncCheckpointer(str(tmp_path), engine)
    state = {"w": jnp.ones((4,))}
    h = ckpt.save_async(3, state)
    assert h.wait(timeout=30)
    # simulate a torn save at a later step: dir exists, no MANIFEST
    os.makedirs(str(tmp_path / "step-00000009"))
    np.save(str(tmp_path / "step-00000009" / "w.npy"), np.zeros(4))
    assert ckpt.latest_step() == 3     # torn step invisible
    ckpt.close()


def test_checkpoint_gc_keeps_recent(tmp_path, engine):
    ckpt = AsyncCheckpointer(str(tmp_path), engine, keep=2)
    state = {"w": jnp.ones((2,))}
    for s in [1, 2, 3, 4]:
        assert ckpt.save_async(s, state).wait(timeout=30)
    assert ckpt.all_steps() == [3, 4]
    ckpt.close()


def test_train_crash_restart_resumes_bit_exact(tmp_path, engine):
    """Save at step k, keep training, 'crash', restore, re-train: states
    must match bit-exactly (fault-tolerance requirement)."""
    from repro.optim import OptConfig
    from repro.train.train_step import init_train_state, make_train_step
    cfg = get_config("paper_demo", reduced=True, dtype=jnp.float32,
                     param_dtype=jnp.float32)
    opt = OptConfig(lr=1e-3)
    step = jax.jit(make_train_step(cfg, opt))
    src = SyntheticTokenSource(cfg, 2, 16)
    state = init_train_state(jax.random.PRNGKey(0), cfg, opt)
    ckpt = AsyncCheckpointer(str(tmp_path), engine)
    for i in range(3):
        state, _ = step(state, src.make_batch(i))
    handle = ckpt.save_async(3, state)
    cont = [state]
    for i in range(3, 5):                      # training continues async
        cont[0], _ = step(cont[0], src.make_batch(i))
    assert handle.wait(timeout=30)
    # crash + restart from checkpoint
    restored = ckpt.restore(3, state)
    for i in range(3, 5):
        restored, _ = step(restored, src.make_batch(i))
    for a, b in zip(jax.tree_util.tree_leaves(cont[0]),
                    jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    ckpt.close()


def test_heartbeat_detects_failure(engine):
    tr = Transport(3, engine=engine)
    failures = []
    mon = HeartbeatMonitor(tr, engine, rank=0, watched=[1, 2],
                           timeout_s=0.15, sweep_interval_s=0.03,
                           on_failure=failures.append)
    stop = threading.Event()

    def rank1():     # healthy
        hb = HeartbeatSender(tr, 1, 0, interval_s=0.02)
        while not stop.is_set():
            hb.beat()
            time.sleep(0.01)

    def rank2():     # dies after 0.1s
        hb = HeartbeatSender(tr, 2, 0, interval_s=0.02)
        t0 = time.monotonic()
        while time.monotonic() - t0 < 0.1:
            hb.beat()
            time.sleep(0.01)

    t1 = threading.Thread(target=rank1)
    t2 = threading.Thread(target=rank2)
    t1.start(); t2.start()
    deadline = time.monotonic() + 3.0
    while not failures and time.monotonic() < deadline:
        mon.progress()
        time.sleep(0.01)
    stop.set()
    t1.join(); t2.join()
    mon.stop()
    assert failures == [2], failures


@pytest.mark.parametrize("backend_kind", ["continuations", "testsome"])
def test_offload_roundtrip(engine, backend_kind):
    """A task offloaded from rank 0 to rank 1 returns the computed result
    through the 2-out/3-back message group."""
    tr = Transport(2, engine=engine)
    if backend_kind == "continuations":
        b0, b1 = ContinuationBackend(engine), ContinuationBackend(engine)
    else:
        b0, b1 = TestsomeBackend(8), TestsomeBackend(8)
    m0 = OffloadManager(0, 2, tr, b0)
    m1 = OffloadManager(1, 2, tr, b1)
    task = m0.new_task(cost_s=0.001)
    m0.offload(task, target=1)
    deadline = time.monotonic() + 5.0
    while not task.done.is_set() and time.monotonic() < deadline:
        b0.progress(); b1.progress()
        time.sleep(1e-4)
    assert task.done.is_set()
    np.testing.assert_allclose(task.result, task.payload * 2 + 1)
    assert m1.stats["executed_remote"] == 1
    assert m0.stats["returned"] == 1
    m0.stop(); m1.stop()


def test_offload_quota_dynamics(engine):
    tr = Transport(2, engine=engine)
    m0 = OffloadManager(0, 2, tr, ContinuationBackend(engine))
    q0 = m0.quota[1]
    m0.end_iteration({1: False})
    assert m0.quota[1] == q0 + 1
    m0.end_iteration({1: True})       # emergency
    assert m0.quota[1] == max(1, (q0 + 1) // 2)
    assert m0.suspended[1] == 3
    assert m0.pick_target({1: 0.0}) is None   # suspended
    for _ in range(3):
        m0.end_iteration({})
    assert m0.pick_target({1: 0.0}) == 1


def test_heartbeat_sweep_error_surfaces_in_progress(engine):
    """Regression (review): a raising on_failure callback must surface
    from monitor.progress(), not silently kill the sweep chain."""
    import pytest as _pytest
    import time as _time
    from repro.core import Transport
    from repro.runtime.heartbeat import HeartbeatMonitor
    tr = Transport(2, engine=engine)

    def bad_on_failure(rank):
        raise RuntimeError("elastic controller exploded")

    mon = HeartbeatMonitor(tr, engine, rank=0, watched=[1],
                           timeout_s=0.01, sweep_interval_s=0.01,
                           on_failure=bad_on_failure)
    _time.sleep(0.05)                  # rank 1 never beats -> stale
    with _pytest.raises(RuntimeError, match="elastic controller"):
        deadline = _time.monotonic() + 5.0
        while _time.monotonic() < deadline:
            mon.progress()
            _time.sleep(0.005)
    mon.stop()


def test_checkpoint_commit_stage_error_surfaces(tmp_path, engine):
    """Regression (review): an exception in the commit stage itself
    (manifest write / rename) must surface from handle.wait(), not be
    swallowed into the promise chain."""
    import pytest as _pytest
    from repro.checkpoint.async_ckpt import AsyncCheckpointer
    ckpt = AsyncCheckpointer(str(tmp_path), engine)
    state = {"w": jnp.ones((2,))}
    boom = RuntimeError("disk full")
    orig_rename = os.rename

    def bad_rename(src, dst):
        raise boom

    os.rename = bad_rename
    try:
        h = ckpt.save_async(5, state)
        with _pytest.raises(RuntimeError, match="disk full"):
            h.wait(timeout=30)
    finally:
        os.rename = orig_rename
        ckpt.close()
    assert ckpt.latest_step() is None      # nothing committed


def test_heartbeat_dead_on_arrival(engine):
    """Regression: ``last_seen`` used to be seeded at construction time,
    vouching for ranks the monitor had never heard from. A rank that
    never beats ONCE must still be flagged one timeout after watch-start,
    and ``last_seen`` must never contain a fabricated entry for it."""
    tr = Transport(2, engine=engine)
    failures = []
    mon = HeartbeatMonitor(tr, engine, rank=0, watched=[1],
                           timeout_s=0.05, sweep_interval_s=0.01,
                           on_failure=failures.append)
    deadline = time.monotonic() + 3.0
    while not failures and time.monotonic() < deadline:
        mon.progress()
        time.sleep(0.005)
    mon.stop()
    assert failures == [1]
    assert 1 not in mon.last_seen          # never fabricated a beat


def test_heartbeat_watch_unwatch(engine):
    """Elastic shrink: an unwatched rank's silence never fires
    on_failure; re-watching restarts its silence clock from now."""
    tr = Transport(3, engine=engine)
    failures = []
    mon = HeartbeatMonitor(tr, engine, rank=0, watched=[1, 2],
                           timeout_s=0.05, sweep_interval_s=0.01,
                           on_failure=failures.append)
    assert mon.watched == [1, 2]
    mon.unwatch(2)
    assert mon.watched == [1]
    hb = HeartbeatSender(tr, 1, 0, interval_s=0.005)
    deadline = time.monotonic() + 0.3
    while time.monotonic() < deadline:
        hb.beat()
        mon.progress()
        time.sleep(0.005)
    assert failures == []                  # 2 silent but unwatched
    # re-watch 2: silence restarts now, flagged one timeout later
    mon.watch(2)
    deadline = time.monotonic() + 3.0
    while not failures and time.monotonic() < deadline:
        hb.beat()
        mon.progress()
        time.sleep(0.005)
    mon.stop()
    assert failures == [2]


def test_heartbeat_stall_guard(engine):
    """With ``stall_guard_s`` set, a long gap between sweeps (the driver
    thread stalled — e.g. jit compiling) restarts silence clocks instead
    of flagging ranks whose beats could not be observed."""
    tr = Transport(2, engine=engine)
    failures = []
    mon = HeartbeatMonitor(tr, engine, rank=0, watched=[1],
                           timeout_s=0.05, sweep_interval_s=0.01,
                           on_failure=failures.append,
                           stall_guard_s=0.05)
    hb = HeartbeatSender(tr, 1, 0, interval_s=0.005)
    hb.beat()
    mon.progress()
    time.sleep(0.2)                        # driver stalls >> timeout
    hb.beat()
    mon.progress()                         # stalled sweep: resets clocks
    assert failures == []
    # rank 1 now goes genuinely silent; regular sweeps flag it
    deadline = time.monotonic() + 3.0
    while not failures and time.monotonic() < deadline:
        mon.progress()
        time.sleep(0.005)
    mon.stop()
    assert failures == [1]
