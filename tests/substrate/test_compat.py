"""Targeted tests for ``repro.compat`` — the JAX version-drift shims.

Each shim gets its own test so that the day a new JAX release moves an
API again, CI reports a named compat failure instead of collateral
damage across the whole suite. Both branches of every shim are covered:
the live branch runs against the installed JAX, the other is driven
through monkeypatched stand-ins."""
import jax
import jax.numpy as jnp
import pytest

from repro import compat


# ------------------------------------------------------------- make_mesh
def test_make_mesh_real_call():
    mesh = compat.make_mesh((1,), ("x",))
    assert mesh.axis_names == ("x",)
    assert mesh.devices.size == 1


def test_make_mesh_passes_auto_axis_types_when_supported(monkeypatch):
    calls = {}

    def fake_make_mesh(shape, axes, **kwargs):
        calls.update(kwargs)
        return "mesh"

    monkeypatch.setattr(jax, "make_mesh", fake_make_mesh)
    if not hasattr(jax.sharding, "AxisType"):
        class FakeAxisType:
            Auto = "auto"
        monkeypatch.setattr(jax.sharding, "AxisType", FakeAxisType,
                            raising=False)
    assert compat.make_mesh((1, 1), ("a", "b")) == "mesh"
    assert calls["axis_types"] == (jax.sharding.AxisType.Auto,) * 2


def test_make_mesh_omits_axis_types_on_old_jax(monkeypatch):
    """Pre-AxisType builds reject the kwarg entirely — the shim must not
    send it."""
    calls = {}

    def fake_make_mesh(shape, axes, **kwargs):
        if "axis_types" in kwargs:
            raise TypeError("unexpected keyword argument 'axis_types'")
        calls["ok"] = True
        return "mesh"

    monkeypatch.setattr(jax, "make_mesh", fake_make_mesh)
    monkeypatch.delattr(jax.sharding, "AxisType", raising=False)
    assert compat.make_mesh((1,), ("x",)) == "mesh"
    assert calls["ok"]


def test_make_mesh_caller_override_wins(monkeypatch):
    calls = {}
    monkeypatch.setattr(jax, "make_mesh",
                        lambda shape, axes, **kw: calls.update(kw) or "m")
    if not hasattr(jax.sharding, "AxisType"):
        class FakeAxisType:
            Auto, Explicit = "auto", "explicit"
        monkeypatch.setattr(jax.sharding, "AxisType", FakeAxisType,
                            raising=False)
    compat.make_mesh((1,), ("x",), axis_types=("explicit",))
    assert calls["axis_types"] == ("explicit",)


# ------------------------------------------------------------- shard_map
def test_shard_map_import_resolved():
    """The shim found an implementation wherever this JAX keeps it
    (top-level export on new builds, jax.experimental on the 0.4.x
    line)."""
    assert callable(compat._shard_map_impl)
    assert compat._SHARD_MAP_PARAMS & {"check_vma", "check_rep"}


def test_shard_map_behavioral():
    from jax.sharding import PartitionSpec as P
    mesh = compat.make_mesh((1,), ("x",))
    f = compat.shard_map(lambda a: a * 2, mesh=mesh, in_specs=P("x"),
                         out_specs=P("x"), check_vma=False)
    out = f(jnp.arange(4, dtype=jnp.float32))
    assert out.tolist() == [0.0, 2.0, 4.0, 6.0]


@pytest.mark.parametrize("params,expected_kwarg", [
    (frozenset({"f", "mesh", "in_specs", "out_specs", "check_vma"}),
     "check_vma"),
    (frozenset({"f", "mesh", "in_specs", "out_specs", "check_rep"}),
     "check_rep"),
])
def test_shard_map_flag_renamed_per_signature(monkeypatch, params,
                                              expected_kwarg):
    """``check_vma`` must land as whichever spelling the installed build
    accepts (check_rep on 0.4.x, check_vma after the rename)."""
    seen = {}

    def fake_impl(f, mesh=None, in_specs=None, out_specs=None, **kwargs):
        seen.update(kwargs)
        return "wrapped"

    monkeypatch.setattr(compat, "_shard_map_impl", fake_impl)
    monkeypatch.setattr(compat, "_SHARD_MAP_PARAMS", params)
    assert compat.shard_map(lambda x: x, mesh="m", in_specs=(),
                            out_specs=(), check_vma=False) == "wrapped"
    assert seen == {expected_kwarg: False}


def test_shard_map_flag_dropped_when_signature_has_neither(monkeypatch):
    seen = {}

    def fake_impl(f, mesh=None, in_specs=None, out_specs=None, **kwargs):
        seen.update(kwargs)
        return "wrapped"

    monkeypatch.setattr(compat, "_shard_map_impl", fake_impl)
    monkeypatch.setattr(compat, "_SHARD_MAP_PARAMS",
                        frozenset({"f", "mesh", "in_specs", "out_specs"}))
    compat.shard_map(lambda x: x, mesh="m", in_specs=(), out_specs=())
    assert seen == {}


# --------------------------------------------------------- cost_analysis
class _FakeCompiled:
    def __init__(self, ca):
        self._ca = ca

    def cost_analysis(self):
        return self._ca


def test_cost_analysis_list_vs_dict():
    flat = {"flops": 8.0, "bytes accessed": 64.0}
    assert compat.cost_analysis(_FakeCompiled([flat])) == flat   # 0.4.x
    assert compat.cost_analysis(_FakeCompiled(flat)) == flat     # new
    assert compat.cost_analysis(_FakeCompiled(None)) == {}
    assert compat.cost_analysis(_FakeCompiled([])) == {}
    assert compat.cost_analysis(_FakeCompiled(({"a": 1.0},))) == {"a": 1.0}


def test_cost_analysis_real_compiled():
    compiled = jax.jit(lambda x: x * 2 + 1).lower(
        jnp.arange(8, dtype=jnp.float32)).compile()
    ca = compat.cost_analysis(compiled)
    assert isinstance(ca, dict)       # flat on every build, never a list
