"""Paper-analogue application tests: zones solver and dataflow Cholesky
must be exactly correct through the full distributed protocols."""
import numpy as np
import pytest

from repro.core import Engine
from repro.dataflow.cholesky import (assemble_result, build_cholesky_graph,
                                     make_spd_matrix)
from repro.dataflow.runtime import (ContinuationBackend, TestsomeBackend,
                                    run_dataflow)
from repro.zones.solver import distributed_solve, make_zones, reference_solve


@pytest.mark.parametrize("variant", ["fork_join", "continuations"])
@pytest.mark.parametrize("n_ranks", [1, 2, 4])
def test_zones_match_reference(variant, n_ranks):
    zones = make_zones(n_zones=6, ny=16, base_nx=8, seed=1)
    want = reference_solve(zones, timesteps=5)
    got, _ = distributed_solve(zones, n_ranks=n_ranks, timesteps=5,
                               variant=variant)
    for w, g in zip(want, got):
        np.testing.assert_allclose(w, g, atol=1e-12), variant


@pytest.mark.parametrize("backend", ["continuations", "testsome"])
@pytest.mark.parametrize("n_ranks,nb,tile", [(2, 4, 8), (4, 5, 8)])
def test_dataflow_cholesky_correct(backend, n_ranks, nb, tile):
    A = make_spd_matrix(nb * tile, seed=2)
    graph, meta = build_cholesky_graph(A, nb, tile, n_ranks)
    factory = (lambda eng: ContinuationBackend(eng)) \
        if backend == "continuations" else (lambda eng: TestsomeBackend(8))
    tiles, stats = run_dataflow(graph, factory, timeout=60)
    L = assemble_result(tiles, meta)
    np.testing.assert_allclose(L, np.linalg.cholesky(A), atol=1e-8)
    n_tasks = len(graph.tasks)
    assert stats["executed"] == n_tasks


def test_dataflow_single_rank():
    A = make_spd_matrix(24, seed=3)
    graph, meta = build_cholesky_graph(A, 3, 8, 1)
    tiles, _ = run_dataflow(graph, lambda eng: ContinuationBackend(eng))
    np.testing.assert_allclose(assemble_result(tiles, meta),
                               np.linalg.cholesky(A), atol=1e-8)
