"""Hypothesis property tests on the continuation engine's invariants.

Invariants (paper Fig. 1 + §2.2/§3):
  I1  Every registered continuation executes exactly once — never lost,
      never duplicated — for any interleaving of registration, completion
      order, cancellation, and progress calls.
  I2  Immediate completion (flag=True) ⇒ the callback is NEVER invoked by
      the engine; flag=False ⇒ invoked exactly once.
  I3  ``continue_all`` fires only after ALL its ops completed, regardless of
      completion order; statuses are populated before the callback runs.
  I4  CR.test() returns True ⟺ the active set is empty; the CR state is
      COMPLETE afterwards, and can always be reactivated by registration.
  I5  max_poll is respected: a test() executes at most max_poll callbacks
      of that CR.
  I6  With poll_only, callbacks run only during test()/wait() of that CR.
"""
import threading

import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="optional dev dependency (pip install hypothesis)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import CRState, Engine, Status
from repro.core.completable import Completable


class ScriptOp(Completable):
    def __init__(self, push: bool):
        super().__init__()
        self._push = push
        self._flag = False

    @property
    def supports_push(self):
        return self._push

    def fire(self):
        if self._push:
            self._complete(Status())
        else:
            self._flag = True

    def _poll(self):
        return self._flag


# Script step encodings:
#   ("reg", group_size, push?)  register continue_all over fresh ops
#   ("fire",)                   complete the oldest unfired op
#   ("cancel",)                 cancel the oldest unfired op
#   ("tick",)                   generic engine progress
#   ("test",)                   cr.test()
step_strategy = st.one_of(
    st.tuples(st.just("reg"), st.integers(1, 3), st.booleans()),
    st.tuples(st.just("fire")),
    st.tuples(st.just("cancel")),
    st.tuples(st.just("tick")),
    st.tuples(st.just("test")),
)


def run_script(script, info=None):
    eng = Engine()
    cr = eng.continue_init(info or {})
    runs = {}        # cont id -> run count
    lock = threading.Lock()
    unfired = []     # ops not yet fired/cancelled
    expected = 0     # registered (flag=False) continuations
    immediate = 0
    test_calls = []

    def make_cb(cid):
        def cb(statuses, data):
            with lock:
                runs[cid] = runs.get(cid, 0) + 1
                if statuses is not None:
                    assert all(s_ is not None for s_ in statuses), \
                        "status not populated before callback (I3)"
        return cb

    cid = 0
    for stp in script:
        kind = stp[0]
        if kind == "reg":
            _, size, push = stp
            ops = [ScriptOp(push) for _ in range(size)]
            statuses = [None] * size
            flag = eng.continue_all(ops, make_cb(cid), None,
                                    statuses=statuses, cr=cr)
            if flag:
                assert all(s_ is not None for s_ in statuses)
            else:
                expected += 1
                unfired.extend(ops)
            cid += 1
        elif kind == "fire":
            if unfired:
                unfired.pop(0).fire()
        elif kind == "cancel":
            if unfired:
                unfired.pop(0).cancel()
        elif kind == "tick":
            eng.tick()
        elif kind == "test":
            test_calls.append(cr.test())
    # drain everything
    for op in unfired:
        op.fire()
    assert cr.wait(timeout=10.0), "wait() did not drain the CR (I4)"
    assert cr.test() is True
    eng.shutdown()
    return runs, expected, immediate


@settings(max_examples=120, deadline=None)
@given(st.lists(step_strategy, max_size=30))
def test_exactly_once_any_interleaving(script):
    """I1 + I2: every registered continuation runs exactly once."""
    runs, expected, _ = run_script(script)
    assert sum(runs.values()) == expected
    assert all(v == 1 for v in runs.values())


@settings(max_examples=60, deadline=None)
@given(st.lists(step_strategy, max_size=25))
def test_exactly_once_poll_only(script):
    """I1 under poll_only: still exactly-once, just deferred to test()."""
    runs, expected, _ = run_script(script,
                                   info={"mpi_continue_poll_only": True})
    assert sum(runs.values()) == expected
    assert all(v == 1 for v in runs.values())


@settings(max_examples=60, deadline=None)
@given(st.lists(step_strategy, max_size=25))
def test_exactly_once_enqueue_complete(script):
    """I1 under enqueue_complete: nothing is immediate, all run once."""
    runs, expected, _ = run_script(
        script, info={"mpi_continue_enqueue_complete": True})
    assert sum(runs.values()) == expected
    assert all(v == 1 for v in runs.values())


@settings(max_examples=80, deadline=None)
@given(order=st.permutations(list(range(5))))
def test_continue_all_order_independent(order):
    """I3: continue_all fires after the LAST completion, any order."""
    eng = Engine()
    cr = eng.continue_init()
    ops = [ScriptOp(push=True) for _ in range(5)]
    fired_at = []
    eng.continue_all(ops, lambda st_, d: fired_at.append(len(done)), None,
                     statuses=[None] * 5, cr=cr)
    done = []
    for idx in order:
        done.append(idx)
        ops[idx].fire()
    assert cr.wait(timeout=5.0)
    assert fired_at == [5]
    eng.shutdown()


@settings(max_examples=50, deadline=None)
@given(n=st.integers(1, 12), max_poll=st.integers(1, 5))
def test_max_poll_bound(n, max_poll):
    """I5: each test() runs at most max_poll callbacks of the CR."""
    eng = Engine()
    cr = eng.continue_init({"mpi_continue_poll_only": True,
                            "mpi_continue_max_poll": max_poll})
    count = {"n": 0}
    for _ in range(n):
        op = ScriptOp(push=True)
        eng.continue_all([op], lambda st_, d: count.__setitem__("n", count["n"] + 1),
                         None, cr=cr)
        op.fire()
    executed_per_test = []
    while not cr.test():
        executed_per_test.append(count["n"] - sum(executed_per_test))
    executed_per_test.append(count["n"] - sum(executed_per_test))
    assert count["n"] == n
    assert all(e <= max_poll for e in executed_per_test)
    eng.shutdown()


@settings(max_examples=40, deadline=None)
@given(sizes=st.lists(st.integers(1, 4), min_size=1, max_size=6))
def test_cr_reactivation_cycles(sizes):
    """I4: INACTIVE→ACTIVE→IDLE→COMPLETE→ACTIVE… cycles are always legal."""
    eng = Engine()
    cr = eng.continue_init()
    for size in sizes:
        ops = [ScriptOp(push=True) for _ in range(size)]
        flag = eng.continue_all(ops, lambda st_, d: None, None, cr=cr)
        assert flag is False
        assert cr.cr_state is CRState.ACTIVE_REFERENCED
        for op in ops:
            op.fire()
        assert cr.test() is True
        assert cr.cr_state is CRState.COMPLETE
    eng.shutdown()


@settings(max_examples=30, deadline=None)
@given(n_threads=st.integers(2, 4), per_thread=st.integers(5, 20))
def test_concurrent_registration_property(n_threads, per_thread):
    """I1 under true concurrency: racing register/fire threads."""
    eng = Engine()
    cr = eng.continue_init()
    lock = threading.Lock()
    ran = []

    def worker(tid):
        for i in range(per_thread):
            op = ScriptOp(push=True)
            eng.continue_all([op], lambda st_, d: (lock.acquire(),
                                                   ran.append(d),
                                                   lock.release()),
                             (tid, i), cr=cr)
            op.fire()

    threads = [threading.Thread(target=worker, args=(t,))
               for t in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert cr.wait(timeout=10.0)
    assert len(ran) == n_threads * per_thread
    assert len(set(ran)) == len(ran)
    eng.shutdown()
