"""when_all/when_any/when_some combinators, continue_any/continue_some,
and the TestsomeManager first-k analogues — including hypothesis
properties under concurrent completion."""
import threading

import pytest

from repro.core import (CombinedOp, Engine, Status, TestsomeManager,
                        when_all, when_any, when_some)
from repro.core.completable import Completable
from repro.core.status import OpState


class ManualOp(Completable):
    def __init__(self, push: bool = True):
        super().__init__()
        self._push = push
        self.flag = False

    @property
    def supports_push(self):
        return self._push

    def trigger(self, status: Status = None):
        if self._push:
            self._complete(status or Status())
        else:
            self.flag = True

    def _poll(self):
        return self.flag


@pytest.fixture
def engine():
    eng = Engine()
    yield eng
    eng.shutdown()


# ------------------------------------------------------------------- units
def test_when_any_winner_and_loser_release():
    ops = [ManualOp() for _ in range(3)]
    comb = when_any(ops)
    assert all(op._attached for op in ops)       # construction consumes
    ops[1].trigger(Status(payload="won"))
    assert comb.state is OpState.COMPLETE
    assert comb.status.payload == "won"
    assert comb.indices == [1]
    assert ops[1]._attached                      # winner stays consumed
    assert not ops[0]._attached and not ops[2]._attached   # losers released
    # late loser completions are ignored — no state change, no refire
    ops[0].trigger(Status(payload="late"))
    assert comb.status.payload == "won" and comb.indices == [1]


def test_when_some_payload_pairs_and_order():
    ops = [ManualOp() for _ in range(4)]
    comb = when_some(ops, 2)
    ops[3].trigger(Status(payload="d"))
    assert comb.state is OpState.PENDING
    ops[0].trigger(Status(payload="a"))
    assert comb.state is OpState.COMPLETE
    assert comb.indices == [3, 0]                # completion order
    assert comb.status.payload == [(3, "d"), (0, "a")]
    assert comb.op_statuses[1] is None and comb.op_statuses[2] is None


def test_when_all_payload_list_in_op_order():
    ops = [ManualOp() for _ in range(3)]
    comb = when_all(ops)
    for i in (2, 0, 1):
        ops[i].trigger(Status(payload=i * 10))
    assert comb.status.payload == [0, 10, 20]    # op order, not completion
    # single-op when_all still yields a (1-element) list
    solo = ManualOp()
    comb1 = when_all([solo])
    solo.trigger(Status(payload=7))
    assert comb1.status.payload == [7]


def test_when_any_cancel_losers():
    ops = [ManualOp() for _ in range(3)]
    when_any(ops, cancel_losers=True)
    ops[0].trigger()
    assert ops[1].state is OpState.CANCELLED
    assert ops[2].state is OpState.CANCELLED


def test_when_all_error_propagates():
    ops = [ManualOp(), ManualOp()]
    comb = when_all(ops)
    ops[0].trigger(Status(payload=1))
    err = RuntimeError("shard write failed")
    ops[1].trigger(Status(error=err))
    assert comb.state is OpState.FAILED
    assert comb.status.error is err


def test_combined_cancel_cancels_pending_children():
    ops = [ManualOp() for _ in range(2)]
    comb = when_all(ops)
    ops[0].trigger()
    assert comb.cancel() is True
    assert comb.state is OpState.CANCELLED
    assert ops[1].state is OpState.CANCELLED
    assert comb.cancel() is False                # already settled


def test_combined_poll_mode_children(engine):
    """Poll-mode children are driven through the composite by progress
    scans — the composite is the only op the engine watches."""
    cr = engine.continue_init()
    ops = [ManualOp(push=False) for _ in range(2)]
    seen = []
    engine.continue_when(when_all(ops), lambda st, d: seen.append("all"),
                         cr=cr)
    engine.tick()
    assert seen == []
    for op in ops:
        op.trigger()                             # flips the poll flag only
    engine.tick()
    assert seen == ["all"]


def test_combined_validation():
    with pytest.raises(ValueError):
        CombinedOp([ManualOp()], 2)
    with pytest.raises(ValueError):
        CombinedOp([ManualOp()], 0)


# -------------------------------------------------------- engine front-ends
def test_continue_any_reports_indices_and_statuses(engine):
    cr = engine.continue_init()
    ops = [ManualOp() for _ in range(3)]
    statuses = [None] * 3
    indices = []
    fired = []
    flag = engine.continue_any(ops, lambda st, d: fired.append(list(indices)),
                               statuses=statuses, indices=indices, cr=cr)
    assert flag is False
    ops[2].trigger(Status(payload="w"))
    assert fired == [[2]]                        # reported before the cb ran
    assert indices == [2]
    assert statuses[2].payload == "w"
    assert statuses[0] is None and statuses[1] is None
    ops[0].trigger()                             # loser: cb never re-fires
    engine.tick()
    assert fired == [[2]]


def test_continue_some_immediate_path(engine):
    cr = engine.continue_init()
    ops = [ManualOp() for _ in range(3)]
    ops[0].trigger(Status(payload="a"))
    ops[1].trigger(Status(payload="b"))
    indices = []
    statuses = [None] * 3
    seen = []
    flag = engine.continue_some(ops, 2, lambda st, d: seen.append(d),
                                statuses=statuses, indices=indices, cr=cr)
    assert flag is True and seen == []           # immediate: cb not invoked
    assert sorted(indices) == [0, 1]
    assert statuses[0].payload == "a" and statuses[1].payload == "b"
    assert not ops[2]._attached                  # loser released


def test_continue_some_losers_attachment_released(engine):
    cr = engine.continue_init()
    ops = [ManualOp() for _ in range(4)]
    engine.continue_some(ops, 2, lambda st, d: None, cr=cr)
    ops[1].trigger()
    ops[3].trigger()
    assert cr.test() is True
    for i, op in enumerate(ops):
        assert op._attached == (i in (1, 3))
    # released losers are re-registrable
    done = []
    engine.continue_when(ops[0], lambda st, d: done.append(1), cr=cr)
    ops[0].trigger()
    assert done == [1]


# -------------------------------------------- TestsomeManager first-k analogue
def test_testsome_submit_any_drops_losers():
    mgr = TestsomeManager(window=8)
    ops = [ManualOp(push=False) for _ in range(4)]
    fired = []
    idx = []
    mgr.submit_any(ops, lambda st, d: fired.append(d), "grp",
                   indices_out=idx)
    ops[2].flag = True
    mgr.testsome()
    assert fired == ["grp"]
    assert idx == [2]                            # winner reported
    assert mgr.outstanding == 0
    # losers no longer tracked: completing them fires nothing
    for op in ops:
        op.flag = True
    mgr.testsome()
    assert fired == ["grp"]
    mgr.drain()                                  # converges immediately


def test_testsome_submit_some_statuses():
    mgr = TestsomeManager(window=8)
    ops = [ManualOp(push=False) for _ in range(3)]
    got = []
    idx = []
    mgr.submit_some(ops, 2, lambda st, d: got.append(st), want_statuses=True,
                    indices_out=idx)
    ops[0].flag = True
    ops[2].flag = True
    mgr.testsome()
    assert len(got) == 1
    assert sorted(idx) == [0, 2]
    mgr.drain()


def test_testsome_need_validation():
    mgr = TestsomeManager()
    with pytest.raises(ValueError):
        mgr.submit([ManualOp()], lambda st, d: None, need=2)


# ---------------------------------------- seeded property sweeps (always run)
# The hypothesis variants live in test_combinator_properties.py (optional
# dependency); these seeded sweeps keep the same invariants exercised in
# environments without it.
def test_some_sequential_interleavings_sweep():
    import random
    rng = random.Random(1234)
    for trial in range(60):
        n = rng.randint(2, 6)
        k = rng.randint(1, n)
        eng = Engine()
        try:
            cr = eng.continue_init()
            ops = [ManualOp() for _ in range(n)]
            fired = []
            statuses = [None] * n
            indices = []
            eng.continue_some(ops, k,
                              lambda st, d: fired.append(list(indices)),
                              statuses=statuses, indices=indices, cr=cr)
            perm = list(range(n))
            rng.shuffle(perm)
            for step, i in enumerate(perm):
                ops[i].trigger(Status(payload=i))
                eng.tick()
                assert len(fired) == (0 if step + 1 < k else 1)
            assert indices == perm[:k]
            for i in range(n):
                if i in perm[:k]:
                    assert statuses[i].payload == i
                else:
                    assert statuses[i] is None
                    assert not ops[i]._attached
            assert cr.test() is True
        finally:
            eng.shutdown()


def test_some_concurrent_completion_sweep():
    import random
    rng = random.Random(99)
    for trial in range(20):
        n = rng.randint(2, 8)
        k = rng.randint(1, n)
        eng = Engine()
        try:
            cr = eng.continue_init()
            ops = [ManualOp() for _ in range(n)]
            fired = []
            lock = threading.Lock()
            indices = []

            def cb(st_, d):
                with lock:
                    fired.append(list(indices))

            eng.continue_some(ops, k, cb, indices=indices, cr=cr)
            barrier = threading.Barrier(n)
            shuffled = list(ops)
            rng.shuffle(shuffled)

            def completer(op):
                barrier.wait()
                op.trigger()

            threads = [threading.Thread(target=completer, args=(op,))
                       for op in shuffled]
            for t in threads:
                t.start()
            for t in threads:
                t.join()
            assert cr.wait(timeout=10)
            assert len(fired) == 1
            assert len(set(fired[0])) == len(fired[0]) == k
            assert sum(1 for op in ops if op._attached) == k
        finally:
            eng.shutdown()


def test_combinator_ctor_rollback_on_consumed_child():
    """Regression (review): CombinedOp construction failing partway must
    release the already-marked prefix, like Engine.continue_all."""
    good = [ManualOp(), ManualOp()]
    used = ManualOp()
    used.mark_attached()
    with pytest.raises(RuntimeError, match="already has a continuation"):
        when_all(good + [used])
    assert not good[0]._attached and not good[1]._attached
    comb = when_all(good)                        # prefix usable again
    for op in good:
        op.trigger()
    assert comb.state is OpState.COMPLETE


def test_continue_some_rollback_releases_children(engine):
    """Regression (review): a failed continue_some registration (freed
    CR) must hand the children back, not just the composite."""
    cr = engine.continue_init()
    cr.free()
    ops = [ManualOp() for _ in range(3)]
    with pytest.raises(RuntimeError, match="freed"):
        engine.continue_some(ops, 2, lambda st, d: None, cr=cr)
    assert all(not op._attached for op in ops)
    # children usable on a live CR afterwards
    cr2 = engine.continue_init()
    seen = []
    engine.continue_some(ops, 2, lambda st, d: seen.append(1), cr=cr2)
    ops[0].trigger()
    ops[1].trigger()
    assert seen == [1]


def test_when_all_empty_completes_vacuously(engine):
    """Regression (review): when_all([]) must mirror continue_all([],...)'s
    immediate completion, not raise — e.g. checkpointing a leafless state."""
    comb = when_all([])
    assert comb.state is OpState.COMPLETE
    assert comb.status.payload == []
    # and through the promise front-end
    assert engine.wrap(when_all([])).result(timeout=5) == []
    with pytest.raises(ValueError):
        when_any([])                     # racing zero candidates: loud error


def test_when_any_single_element_payload_shape():
    """Regression (review): when_any([op]) yields the bare winner payload,
    same shape as any larger group."""
    op = ManualOp()
    comb = when_any([op])
    op.trigger(Status(payload="solo"))
    assert comb.status.payload == "solo"         # not ["solo"]


def test_when_some_payload_always_pairs():
    ops = [ManualOp(), ManualOp()]
    comb = when_some(ops, 2)                     # k == n, still pairs
    ops[1].trigger(Status(payload="b"))
    ops[0].trigger(Status(payload="a"))
    assert comb.status.payload == [(1, "b"), (0, "a")]


def test_rollback_composite_is_neutralized(engine):
    """Regression (review): after a failed continue_some registration the
    orphaned composite must not release/cancel the children when they
    later complete under a new registration."""
    cr = engine.continue_init()
    cr.free()
    ops = [ManualOp() for _ in range(3)]
    with pytest.raises(RuntimeError, match="freed"):
        engine.continue_some(ops, 2, lambda st, d: None, cr=cr,
                             cancel_losers=True)
    cr2 = engine.continue_init()
    seen = []
    engine.continue_some(ops, 2, lambda st, d: seen.append(1), cr=cr2)
    ops[0].trigger()
    ops[1].trigger()                 # zombie would release/cancel ops[2]
    assert seen == [1]
    assert ops[2].state is OpState.PENDING       # not spuriously cancelled
    assert not ops[2]._attached                  # released by the LIVE comb
