"""Transport edge cases: wildcard matching order, cancel-vs-match races,
and rendezvous completion (satellite coverage for the messaging layer)."""
import threading

import pytest

from repro.core import ANY_SOURCE, ANY_TAG, OpState, Transport


# ------------------------------------------------------ wildcard ordering
def test_wildcard_recv_matches_posted_order():
    """A send must match the FIRST posted recv it satisfies, even when a
    wildcard recv was posted ahead of a more specific one."""
    tr = Transport(2)
    r_any = tr.irecv(1, source=ANY_SOURCE, tag=ANY_TAG)
    r_spec = tr.irecv(1, source=0, tag=4)
    tr.isend(0, 1, 4, b"m1")
    assert r_any.done()               # posted first, wins the match
    assert not r_spec.done()
    assert r_any.status.tag == 4
    tr.isend(0, 1, 4, b"m2")
    assert r_spec.done()
    assert r_spec.status.payload == b"m2"


def test_wildcard_source_only_and_tag_only():
    tr = Transport(3)
    r_src = tr.irecv(2, source=ANY_SOURCE, tag=9)      # any source, tag 9
    r_tag = tr.irecv(2, source=1, tag=ANY_TAG)         # source 1, any tag
    tr.isend(1, 2, 5, b"tagged5")     # only r_tag matches (tag 9 required)
    assert r_tag.done() and not r_src.done()
    assert r_tag.status.source == 1 and r_tag.status.tag == 5
    tr.isend(0, 2, 9, b"tagged9")
    assert r_src.done()
    assert r_src.status.source == 0


def test_wildcard_recv_drains_unexpected_in_arrival_order():
    """ANY/ANY receives must consume unexpected messages FIFO (MPI
    non-overtaking per (src,dst,tag) — and our single mailbox keeps total
    arrival order)."""
    tr = Transport(2)
    for i in range(4):
        tr.isend(0, 1, 10 + i, i)
    got = [tr.irecv(1, source=ANY_SOURCE, tag=ANY_TAG).status.payload
           for i in range(4)]
    assert got == [0, 1, 2, 3]


def test_specific_recv_skips_nonmatching_unexpected():
    tr = Transport(2)
    tr.isend(0, 1, 1, b"first")
    tr.isend(0, 1, 2, b"second")
    r = tr.irecv(1, source=0, tag=2)       # must skip the tag-1 message
    assert r.done() and r.status.payload == b"second"
    r1 = tr.irecv(1)
    assert r1.done() and r1.status.payload == b"first"


# ------------------------------------------------------- cancel-vs-match
def test_cancel_vs_match_race_exactly_one_outcome():
    """Racing cancel() against a matching isend: exactly one of them wins,
    and the message is never lost — if the cancel wins, the payload stays
    available for a later receive."""
    n_iters = 200
    for i in range(n_iters):
        tr = Transport(2)
        recv = tr.irecv(1, source=0, tag=7)
        start = threading.Barrier(2)
        cancel_result = [None]

        def canceller():
            start.wait()
            cancel_result[0] = recv.cancel()

        def sender():
            start.wait()
            tr.isend(0, 1, 7, i)

        ts = [threading.Thread(target=canceller),
              threading.Thread(target=sender)]
        for t in ts:
            t.start()
        for t in ts:
            t.join()
        if cancel_result[0]:
            assert recv.state is OpState.CANCELLED
            assert recv.status.test_cancelled()
            late = tr.irecv(1, source=0, tag=7)   # message not lost
            assert late.done() and late.status.payload == i
        else:
            assert recv.done()
            assert recv.status.payload == i
            assert recv.state is not OpState.CANCELLED


def test_cancel_after_unexpected_match_is_noop():
    tr = Transport(2)
    tr.isend(0, 1, 3, b"early")           # lands unexpected
    recv = tr.irecv(1, source=0, tag=3)   # matches immediately on post
    assert recv.done()
    assert recv.cancel() is False
    assert recv.status.payload == b"early"


def test_double_cancel_is_idempotent():
    tr = Transport(2)
    recv = tr.irecv(1, source=0, tag=3)
    assert recv.cancel() is True
    assert recv.cancel() is False          # already removed + completed
    assert recv.state is OpState.CANCELLED


# ----------------------------------------------------------- rendezvous
def test_rendezvous_completes_only_on_matching_recv():
    tr = Transport(2, eager_threshold=8)
    send = tr.isend(0, 1, 5, b"x" * 64)          # rendezvous-sized
    assert not send.done()
    tr.irecv(1, source=0, tag=6)                 # wrong tag: no match
    assert not send.done()
    tr.irecv(1, source=ANY_SOURCE, tag=5)        # matches
    assert send.done()
    assert send.status.count == 64


def test_rendezvous_ignores_cancelled_recv():
    tr = Transport(2, eager_threshold=8)
    recv = tr.irecv(1, source=0, tag=5)
    assert recv.cancel() is True
    send = tr.isend(0, 1, 5, b"y" * 64)
    assert not send.done()                 # cancelled recv must not match
    r2 = tr.irecv(1, source=0, tag=5)
    assert send.done() and r2.done()
    assert r2.status.payload == b"y" * 64


def test_eager_vs_rendezvous_threshold_boundary():
    tr = Transport(2, eager_threshold=16)
    eager = tr.isend(0, 1, 1, b"e" * 16)         # == threshold: eager
    assert eager.done()
    rendez = tr.isend(0, 1, 1, b"r" * 17)        # > threshold: rendezvous
    assert not rendez.done()
    got = [tr.irecv(1, tag=1).status.payload for _ in range(2)]
    assert got == [b"e" * 16, b"r" * 17]         # FIFO preserved
    assert rendez.done()


# ------------------------------------- cancel vs in-flight _finish_pair
def test_cancel_waits_out_inflight_finish_pair():
    """Regression: the matcher pops a posted recv under the mailbox lock
    but completes it AFTER releasing the lock. A cancel() landing in that
    window used to return False while the op still read PENDING — the
    caller observed a receive that was neither matched nor cancelled.
    cancel() must block until the in-flight completion publishes."""
    tr = Transport(2)
    recv = tr.irecv(1, source=0, tag=11)

    in_window = threading.Event()     # matcher popped recv, not completed
    resume = threading.Event()        # let the matcher finish
    orig_finish = Transport._finish_pair

    def stalled_finish(self, send, r):
        in_window.set()
        assert resume.wait(5.0)
        orig_finish(self, send, r)

    tr._finish_pair = stalled_finish.__get__(tr, Transport)
    sender = threading.Thread(target=tr.isend,
                              args=(0, 1, 11, b"payload"))
    sender.start()
    assert in_window.wait(5.0)

    observed = {}

    def do_cancel():
        observed["result"] = recv.cancel()
        observed["state"] = recv.state

    canceller = threading.Thread(target=do_cancel)
    canceller.start()
    # cancel() must be stuck: the op is out of the posted list but its
    # completion has not published yet
    canceller.join(timeout=0.2)
    assert canceller.is_alive(), "cancel() returned inside the race window"
    resume.set()
    canceller.join(timeout=5.0)
    sender.join(timeout=5.0)
    assert not canceller.is_alive()
    assert observed["result"] is False          # matcher won the race
    assert observed["state"] is OpState.COMPLETE
    assert recv.status.payload == b"payload"


# ------------------------------------------------------- per-tag stats
def test_stats_per_tag_counters():
    tr = Transport(2, eager_threshold=8)
    tr.isend(0, 1, 3, b"abcd")                   # eager, 4 bytes
    tr.isend(0, 1, 3, b"efgh")
    big = tr.isend(0, 1, 5, b"z" * 32)           # rendezvous, unmatched
    s = tr.stats()
    assert s["sends"] == 3 and s["recvs"] == 0
    assert s["per_tag"][3] == {"sent_msgs": 2, "sent_bytes": 8,
                               "recvd_msgs": 0, "recvd_bytes": 0}
    # sent counters tick at post time even before a match
    assert s["per_tag"][5]["sent_msgs"] == 1
    assert s["per_tag"][5]["sent_bytes"] == 32
    assert s["per_tag"][5]["recvd_msgs"] == 0
    assert s["sent_bytes"] == 40 and s["recvd_bytes"] == 0

    tr.irecv(1, source=0, tag=3)
    tr.irecv(1, source=0, tag=5)
    s = tr.stats()
    assert big.done()
    assert s["matches"] == 2
    assert s["per_tag"][3]["recvd_msgs"] == 1    # one of two matched
    assert s["per_tag"][3]["recvd_bytes"] == 4
    assert s["per_tag"][5]["recvd_msgs"] == 1
    assert s["recvd_bytes"] == 36


def test_stats_payload_accounting_containers():
    """Container payloads are accounted at their real element sizes (plus
    framing), not the flat control-message default — typed messages with
    an nbytes property report it directly."""
    import numpy as np
    tr = Transport(2, eager_threshold=64)
    arr = np.zeros(100, np.int32)                # 400 bytes
    s1 = tr.isend(0, 1, 1, (7, arr))             # tuple: framed sum
    assert not s1.done()                         # > threshold: rendezvous
    assert s1.nbytes >= 400

    class Msg:
        nbytes = 123
    s2 = tr.isend(0, 1, 2, Msg())
    assert s2.nbytes == 123
    d = tr.isend(0, 1, 3, {"k": arr, "v": arr})
    assert d.nbytes >= 800
