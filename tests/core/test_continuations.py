"""Unit tests for the continuation engine (paper §2–3 semantics)."""
import threading
import time

import pytest

from repro.core import (ArrayOp, CallbackError, ConcurrentCompletionError,
                        CRState, Engine, HostTaskOp, PredicateOp, Status,
                        TimerOp, make_info)
from repro.core.completable import Completable
from repro.core.status import OpState


class ManualOp(Completable):
    """Test op completed explicitly (push) or via an external flag (poll)."""

    def __init__(self, push: bool = True):
        super().__init__()
        self._push = push
        self.flag = False

    @property
    def supports_push(self):
        return self._push

    def trigger(self, status: Status = None):
        if self._push:
            self._complete(status or Status())
        else:
            self.flag = True

    def _poll(self):
        return self.flag


@pytest.fixture
def engine():
    eng = Engine()
    yield eng
    eng.shutdown()


# ---------------------------------------------------------------- basics
def test_callback_runs_on_completion(engine):
    cr = engine.continue_init()
    op = ManualOp()
    seen = []
    flag = engine.continue_when(op, lambda st, d: seen.append(d), "ctx", cr=cr)
    assert flag is False
    assert not seen
    op.trigger()          # push discovery → inline execution
    assert seen == ["ctx"]
    assert cr.test() is True


def test_immediate_completion_flag_no_callback(engine):
    """Paper §2.2: already-complete op → flag=1, callback NOT invoked."""
    cr = engine.continue_init()
    op = ManualOp()
    op.trigger()
    seen = []
    statuses = [None]
    flag = engine.continue_when(op, lambda st, d: seen.append(d), "x",
                                status=statuses, cr=cr)
    assert flag is True
    assert seen == []                      # caller handles immediate case
    assert isinstance(statuses[0], Status)  # status set before return
    assert cr.test() is True               # nothing was registered


def test_enqueue_complete_defers_immediate(engine):
    """Paper §3.5: enqueue_complete forces flag=0 even when already done."""
    cr = engine.continue_init({"mpi_continue_enqueue_complete": True})
    op = ManualOp()
    op.trigger()
    seen = []
    flag = engine.continue_when(op, lambda st, d: seen.append(d), "x", cr=cr)
    assert flag is False
    assert cr.active_count == 1
    cr.test()
    assert seen == ["x"]


def test_continue_all_fires_once_after_last(engine):
    cr = engine.continue_init()
    ops = [ManualOp() for _ in range(5)]
    seen = []
    statuses = [None] * 5
    flag = engine.continue_all(ops, lambda st, d: seen.append(list(st)), None,
                               statuses=statuses, cr=cr)
    assert flag is False
    for op in ops[:-1]:
        op.trigger()
        assert seen == []
    ops[-1].trigger()
    assert len(seen) == 1
    assert all(isinstance(s, Status) for s in seen[0])
    assert cr.test()


def test_statuses_written_before_callback(engine):
    cr = engine.continue_init()
    op = ManualOp()
    captured = {}
    statuses = [None]

    def cb(st, d):
        captured["status"] = st[0]

    engine.continue_when(op, cb, None, status=statuses, cr=cr)
    op.trigger(Status(source=3, tag=7, count=128))
    assert captured["status"].source == 3
    assert captured["status"].tag == 7


def test_poll_mode_op_discovered_on_test(engine):
    cr = engine.continue_init()
    op = ManualOp(push=False)
    seen = []
    engine.continue_when(op, lambda st, d: seen.append(1), cr=cr)
    op.trigger()                 # sets the poll flag only
    assert seen == []            # nobody called into the engine yet
    assert cr.test() is True     # test discovers + executes
    assert seen == [1]


def test_op_handle_consumed_on_attach(engine):
    """Paper §2.2: only one continuation may be attached per op."""
    cr = engine.continue_init()
    op = ManualOp()
    engine.continue_when(op, lambda st, d: None, cr=cr)
    with pytest.raises(RuntimeError, match="already has a continuation"):
        engine.continue_when(op, lambda st, d: None, cr=cr)


# ------------------------------------------------------------ state machine
def test_cr_state_transitions(engine):
    cr = engine.continue_init()
    assert cr.cr_state is CRState.INACTIVE
    op = ManualOp()
    engine.continue_when(op, lambda st, d: None, cr=cr)
    assert cr.cr_state is CRState.ACTIVE_REFERENCED
    op.trigger()
    assert cr.cr_state is CRState.ACTIVE_IDLE     # executed + deregistered
    assert cr.test() is True
    assert cr.cr_state is CRState.COMPLETE
    # Complete → Active Referenced on new registration (Fig. 1)
    op2 = ManualOp()
    engine.continue_when(op2, lambda st, d: None, cr=cr)
    assert cr.cr_state is CRState.ACTIVE_REFERENCED
    op2.trigger()
    assert cr.test() is True


def test_free_active_cr_drains(engine):
    cr = engine.continue_init()
    op = ManualOp()
    engine.continue_when(op, lambda st, d: None, cr=cr)
    cr.free()
    with pytest.raises(RuntimeError, match="freed"):
        engine.continue_when(ManualOp(), lambda st, d: None, cr=cr)
    op.trigger()                 # previously registered continuation still runs
    assert cr.active_count == 0


def test_cr_chaining(engine):
    """Paper §3.2: a continuation attached to a CR, registered with another."""
    cr1 = engine.continue_init()
    cr2 = engine.continue_init()
    order = []
    ops = [ManualOp() for _ in range(3)]
    for i, op in enumerate(ops):
        engine.continue_when(op, lambda st, d: order.append(d), i, cr=cr1)
    flag = engine.continue_when(cr1, lambda st, d: order.append("chain"),
                                cr=cr2)
    assert flag is False
    for op in ops:
        op.trigger()
    assert order[-1] == "chain"
    assert set(order[:-1]) == {0, 1, 2}
    assert cr2.test()


# ----------------------------------------------------------------- info keys
def test_poll_only_runs_only_in_test(engine):
    cr = engine.continue_init({"mpi_continue_poll_only": True})
    op = ManualOp()
    seen = []
    engine.continue_when(op, lambda st, d: seen.append(1), cr=cr)
    op.trigger()
    assert seen == []            # push discovery, but poll_only defers
    engine.tick()
    assert seen == []            # generic progress must not run it either
    cr.test()
    assert seen == [1]


def test_max_poll_bounds_executions(engine):
    cr = engine.continue_init({"mpi_continue_poll_only": True,
                               "mpi_continue_max_poll": 2})
    ops = [ManualOp() for _ in range(5)]
    seen = []
    for op in ops:
        engine.continue_when(op, lambda st, d: seen.append(1), cr=cr)
        op.trigger()
    assert cr.test() is False
    assert len(seen) == 2
    assert cr.test() is False
    assert len(seen) == 4
    assert cr.test() is True
    assert len(seen) == 5


def test_poll_only_max_poll_zero_is_erroneous():
    with pytest.raises(ValueError, match="erroneous"):
        make_info({"mpi_continue_poll_only": True, "mpi_continue_max_poll": 0})


def test_unknown_info_key_rejected():
    with pytest.raises(KeyError):
        make_info({"mpi_continue_bogus": 1})


def test_thread_any_allows_internal_execution():
    eng = Engine(progress_thread=True, progress_interval=1e-4)
    try:
        cr = eng.continue_init({"mpi_continue_thread": "any"})
        op = ManualOp(push=False)
        seen = threading.Event()
        eng.continue_when(op, lambda st, d: seen.set(), cr=cr)
        op.trigger()
        # no application thread calls into the engine; the internal progress
        # thread must discover AND execute.
        assert seen.wait(timeout=2.0)
    finally:
        eng.shutdown()


def test_thread_application_blocks_internal_execution():
    eng = Engine(progress_thread=True, progress_interval=1e-4)
    try:
        cr = eng.continue_init()  # default thread=application
        op = ManualOp(push=False)
        seen = []
        eng.continue_when(op, lambda st, d: seen.append(1), cr=cr)
        op.trigger()
        time.sleep(0.05)          # progress thread discovers, must not execute
        assert seen == []
        cr.test()                 # application thread executes
        assert seen == [1]
    finally:
        eng.shutdown()


# -------------------------------------------------------------- cancellation
def test_cancelled_op_status_observed(engine):
    """Paper Listing 4: callbacks must see cancellation via the status."""
    cr = engine.continue_init()
    op = ManualOp()
    seen = {}
    statuses = [None]
    engine.continue_when(op, lambda st, d: seen.update(c=st[0].test_cancelled()),
                         status=statuses, cr=cr)
    op.cancel()
    assert seen == {"c": True}
    assert cr.test()


# ------------------------------------------------------------- thread safety
def test_concurrent_registration_many_threads(engine):
    cr = engine.continue_init()
    n_threads, per_thread = 8, 50
    done = []
    lock = threading.Lock()

    def cb(st, d):
        with lock:
            done.append(d)

    def worker(base):
        for i in range(per_thread):
            op = ManualOp()
            engine.continue_when(op, cb, base + i, cr=cr)
            op.trigger()

    threads = [threading.Thread(target=worker, args=(t * per_thread,))
               for t in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert cr.wait(timeout=5.0)
    assert sorted(done) == list(range(n_threads * per_thread))


def test_single_tester_enforced(engine):
    cr = engine.continue_init()
    op = ManualOp(push=False)     # poll path: callback runs inside cr.test()
    release = threading.Event()
    entered = threading.Event()

    def slow_cb(st, d):
        entered.set()
        release.wait(timeout=5.0)

    engine.continue_when(op, slow_cb, cr=cr)
    op.trigger()                  # sets the poll flag only
    t1 = threading.Thread(target=cr.test)
    t1.start()
    assert entered.wait(timeout=5.0)
    with pytest.raises(ConcurrentCompletionError):
        cr.test()                 # second tester while t1 is inside test()
    release.set()
    t1.join()


def test_no_nested_callback_execution(engine):
    """Paper §3.1: callbacks triggered inside a callback are deferred."""
    cr = engine.continue_init()
    order = []
    op2 = ManualOp()

    def inner(st, d):
        order.append("inner")

    def outer(st, d):
        order.append("outer-begin")
        op2.trigger()      # completes op2 while inside a callback
        order.append("outer-end")   # inner must NOT have run in between

    op1 = ManualOp()
    engine.continue_when(op1, outer, cr=cr)
    engine.continue_when(op2, inner, cr=cr)
    op1.trigger()
    assert order[:2] == ["outer-begin", "outer-end"]
    cr.wait(timeout=2.0)
    assert order == ["outer-begin", "outer-end", "inner"]


# ----------------------------------------------------------------- op types
def test_host_task_op(engine):
    from concurrent.futures import ThreadPoolExecutor
    cr = engine.continue_init()
    seen = []
    gate = threading.Event()

    def work():
        gate.wait(timeout=5.0)
        return 42

    with ThreadPoolExecutor(1) as pool:
        op = HostTaskOp(pool.submit(work))
        flag = engine.continue_when(op, lambda st, d: seen.append(st[0].payload),
                                    status=[None], cr=cr)
        assert flag is False
        gate.set()
        assert cr.wait(timeout=5.0)
    assert seen == [42]


def test_host_task_op_error_surfaces(engine):
    from concurrent.futures import ThreadPoolExecutor

    def boom():
        raise ValueError("io failed")

    cr = engine.continue_init()
    seen = []
    statuses = [None]
    with ThreadPoolExecutor(1) as pool:
        op = HostTaskOp(pool.submit(boom))
        flag = engine.continue_when(op, lambda st, d: seen.append(st[0].error),
                                    status=statuses, cr=cr)
        assert cr.wait(timeout=5.0)
    if flag:   # completed before registration: status written at return
        assert isinstance(statuses[0].error, ValueError)
    else:
        assert isinstance(seen[0], ValueError)


def test_array_op(engine):
    import jax.numpy as jnp
    cr = engine.continue_init()
    x = jnp.ones((8, 8)) * 2
    seen = []
    flag = engine.continue_when(ArrayOp(x), lambda st, d: seen.append(1), cr=cr)
    assert cr.wait(timeout=5.0)
    # tiny dispatch usually completes before registration → immediate flag
    assert seen == ([] if flag else [1])


def test_array_op_enqueue_complete_always_runs(engine):
    """enqueue_complete removes the immediate-completion race entirely."""
    import jax.numpy as jnp
    cr = engine.continue_init({"mpi_continue_enqueue_complete": True})
    x = jnp.ones((16, 16)) @ jnp.ones((16, 16))
    seen = []
    flag = engine.continue_when(ArrayOp(x), lambda st, d: seen.append(1), cr=cr)
    assert flag is False
    assert cr.wait(timeout=5.0)
    assert seen == [1]


def test_timer_and_predicate_ops(engine):
    cr = engine.continue_init()
    seen = []
    engine.continue_when(TimerOp(0.01), lambda st, d: seen.append("t"), cr=cr)
    box = {"v": False}
    engine.continue_when(PredicateOp(lambda: box["v"]),
                         lambda st, d: seen.append("p"), cr=cr)
    time.sleep(0.02)
    box["v"] = True
    assert cr.wait(timeout=2.0)
    assert sorted(seen) == ["p", "t"]


def test_callback_error_raises_from_test(engine):
    cr = engine.continue_init()
    op = ManualOp()

    def bad(st, d):
        raise RuntimeError("callback exploded")

    engine.continue_when(op, bad, cr=cr)
    op.trigger()
    with pytest.raises(CallbackError):
        cr.test()
    assert cr.test() is True   # errors cleared after raise


def test_callback_error_collect_mode(engine):
    cr = engine.continue_init({"on_error": "collect"})
    op = ManualOp()
    engine.continue_when(op, lambda st, d: 1 / 0, cr=cr)
    op.trigger()
    assert cr.test() is True
    assert len(cr.errors) == 1
