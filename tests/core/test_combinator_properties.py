"""Hypothesis properties for continue_any/continue_some under
sequential interleavings and concurrent completion (ISSUE-4 satellite).

Mirrors the always-running seeded sweeps in ``test_combinators.py``; this
module explores the same invariants with hypothesis-driven shrinking when
the optional dependency is installed.
"""
import threading

import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="optional dev dependency (pip install hypothesis)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import Engine, Status  # noqa: E402
from repro.core.completable import Completable  # noqa: E402


class ManualOp(Completable):
    @property
    def supports_push(self):
        return True

    def trigger(self, status: Status = None):
        self._complete(status or Status())


@settings(max_examples=40, deadline=None)
@given(n=st.integers(2, 6), k_frac=st.floats(0.0, 1.0),
       order=st.randoms(use_true_random=False))
def test_some_sequential_interleavings(n, k_frac, order):
    """Any completion order: fires exactly once at the k-th completion,
    winners' statuses/indices consistent, losers released and silent."""
    k = max(1, min(n, int(k_frac * n) + 1))
    eng = Engine()
    try:
        cr = eng.continue_init()
        ops = [ManualOp() for _ in range(n)]
        fired = []
        statuses = [None] * n
        indices = []
        eng.continue_some(ops, k, lambda st, d: fired.append(list(indices)),
                          statuses=statuses, indices=indices, cr=cr)
        perm = list(range(n))
        order.shuffle(perm)
        for step, i in enumerate(perm):
            ops[i].trigger(Status(payload=i))
            eng.tick()
            if step + 1 < k:
                assert fired == []
            else:
                assert len(fired) == 1           # never a double-fire
        assert sorted(fired[0]) == sorted(perm[:k])
        assert indices == perm[:k]               # completion order
        for i in range(n):
            if i in perm[:k]:
                assert statuses[i].payload == i
            else:
                assert statuses[i] is None
                assert not ops[i]._attached      # no attachment leak
        assert cr.test() is True
    finally:
        eng.shutdown()


@settings(max_examples=15, deadline=None)
@given(n=st.integers(2, 8), k_frac=st.floats(0.0, 1.0),
       seed=st.integers(0, 2**16))
def test_some_concurrent_completion(n, k_frac, seed):
    """All n ops complete simultaneously from n threads: the callback
    still fires exactly once with exactly k winners; losers never run a
    callback and end up released."""
    import random
    k = max(1, min(n, int(k_frac * n) + 1))
    eng = Engine()
    try:
        cr = eng.continue_init()
        ops = [ManualOp() for _ in range(n)]
        fired = []
        fired_lock = threading.Lock()
        indices = []

        def cb(st_, d):
            with fired_lock:
                fired.append(list(indices))

        eng.continue_some(ops, k, cb, indices=indices, cr=cr)
        barrier = threading.Barrier(n)
        rng = random.Random(seed)
        shuffled = list(ops)
        rng.shuffle(shuffled)

        def completer(op):
            barrier.wait()
            op.trigger()

        threads = [threading.Thread(target=completer, args=(op,))
                   for op in shuffled]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert cr.wait(timeout=10)
        assert len(fired) == 1                   # exactly once
        assert len(fired[0]) == k
        assert len(set(fired[0])) == k
        attached = sum(1 for op in ops if op._attached)
        assert attached == k                     # losers all released
    finally:
        eng.shutdown()
