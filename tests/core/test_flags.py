"""Per-registration ContinueFlags: overrides of the CR info defaults,
plus the registration-failure rollback and free()-on-idle regressions."""
import threading
import time

import pytest

from repro.core import (ContinueFlags, CRState, Engine, Status, make_flags,
                        make_info)
from repro.core.completable import Completable
from repro.core.flags import merge_flags, resolve


class ManualOp(Completable):
    def __init__(self, push: bool = True):
        super().__init__()
        self._push = push
        self.flag = False

    @property
    def supports_push(self):
        return self._push

    def trigger(self, status: Status = None):
        if self._push:
            self._complete(status or Status())
        else:
            self.flag = True

    def _poll(self):
        return self.flag


@pytest.fixture
def engine():
    eng = Engine()
    yield eng
    eng.shutdown()


# ------------------------------------------------------------- resolution
def test_flags_override_cr_defaults():
    info = make_info(enqueue_complete=True, poll_only=True,
                     on_error="collect")
    # no flags: inherit everything
    p = resolve(info, None)
    assert (p.enqueue_complete, p.poll_only, p.on_error) == \
        (True, True, "collect")
    # partial flags: only the named fields flip
    p = resolve(info, ContinueFlags(enqueue_complete=False))
    assert p.enqueue_complete is False
    assert p.poll_only is True                   # untouched default
    p = resolve(info, ContinueFlags(poll_only=False, on_error="raise"))
    assert (p.poll_only, p.on_error) == (False, "raise")
    assert p.enqueue_complete is True


def test_make_flags_accepts_mpi_style_keys_and_kwargs():
    f = make_flags({"mpi_continue_enqueue_complete": "true",
                    "mpi_continue_defer_complete": 1})
    assert f.enqueue_complete is True and f.defer_complete is True
    f = make_flags(poll_only=True)
    assert f.poll_only is True and f.enqueue_complete is None
    with pytest.raises(KeyError):
        make_flags({"mpi_continue_bogus": True})
    assert make_flags(None) is None


def test_flags_validation():
    with pytest.raises(ValueError):
        ContinueFlags(immediate=True, defer_complete=True)
    with pytest.raises(ValueError):
        ContinueFlags(thread="bogus")
    with pytest.raises(ValueError):
        ContinueFlags(on_error="explode")


def test_merge_flags_override_wins():
    base = ContinueFlags(enqueue_complete=True, immediate=True)
    over = ContinueFlags(thread="any", immediate=False)
    m = merge_flags(base, over)
    assert (m.enqueue_complete, m.immediate, m.thread) == (True, False, "any")
    assert merge_flags(None, over) is over
    assert merge_flags(base, None) is base


# ------------------------------------------------- per-registration behavior
def test_enqueue_complete_flag_overrides_cr_default(engine):
    cr = engine.continue_init()                  # CR default: fast path on
    op = ManualOp()
    op.trigger()
    seen = []
    flag = engine.continue_when(op, lambda st, d: seen.append(d), "x", cr=cr,
                                flags=ContinueFlags(enqueue_complete=True))
    assert flag is False and seen == []          # forced through the queue
    engine.tick()
    assert seen == ["x"]

    # and the reverse: CR says enqueue, registration opts back into the
    # fast path — previously this required a second CR
    cr2 = engine.continue_init(enqueue_complete=True)
    op2 = ManualOp()
    op2.trigger()
    flag = engine.continue_when(op2, lambda st, d: seen.append(d), "y",
                                cr=cr2,
                                flags=ContinueFlags(enqueue_complete=False))
    assert flag is True and seen == ["x"]        # callback not invoked


def test_poll_only_flag_on_plain_cr(engine):
    """One CR, mixed routing: a poll_only registration runs only inside
    cr.test(); a default registration still runs inline."""
    cr = engine.continue_init()
    seen = []
    op_poll = ManualOp()
    engine.continue_when(op_poll, lambda st, d: seen.append("poll"), cr=cr,
                         flags=ContinueFlags(poll_only=True))
    op_inline = ManualOp()
    engine.continue_when(op_inline, lambda st, d: seen.append("inline"),
                         cr=cr)
    op_inline.trigger()
    assert seen == ["inline"]
    op_poll.trigger()
    assert seen == ["inline"]                    # parked on the CR queue
    engine.tick()
    assert seen == ["inline"]                    # tick must NOT run it
    cr.test()
    assert seen == ["inline", "poll"]


def test_defer_complete_never_inline_at_discovery(engine):
    cr = engine.continue_init()
    seen = []
    op = ManualOp()
    engine.continue_when(op, lambda st, d: seen.append(d), "d", cr=cr,
                         flags=ContinueFlags(defer_complete=True))
    op.trigger()                                 # discovery thread = us
    assert seen == []                            # not run inline
    engine.tick()
    assert seen == ["d"]


def test_immediate_runs_inside_registration(engine):
    """immediate=True opts out of the §3.1 registration guard: an
    already-complete op registered with enqueue_complete runs its callback
    before continue_when returns."""
    cr = engine.continue_init()
    op = ManualOp()
    op.trigger()
    seen = []
    flag = engine.continue_when(
        op, lambda st, d: seen.append(d), "now", cr=cr,
        flags=ContinueFlags(enqueue_complete=True, immediate=True))
    assert flag is False
    assert seen == ["now"]                       # ran during registration


def test_volatile_statuses_snapshot(engine):
    cr = engine.continue_init()
    op = ManualOp()
    mine = [None]
    got = []
    engine.continue_when(op, lambda st, d: got.append(st), status=mine,
                         cr=cr, flags=ContinueFlags(volatile_statuses=True))
    mine[0] = "caller reused this slot"          # legal under volatile
    op.trigger()
    assert isinstance(got[0][0], Status)         # engine-owned snapshot
    assert mine[0] == "caller reused this slot"  # caller list untouched


def test_on_error_callable_handler(engine):
    cr = engine.continue_init()                  # CR default on_error=raise
    caught = []
    op = ManualOp()
    engine.continue_when(op, lambda st, d: 1 / 0, cr=cr,
                         flags=ContinueFlags(on_error=caught.append))
    op.trigger()
    assert len(caught) == 1 and isinstance(caught[0], ZeroDivisionError)
    assert cr.test() is True                     # nothing pending to raise
    assert cr.errors == []


def test_on_error_flag_overrides_cr(engine):
    from repro.core import CallbackError
    # collect-by-default CR, raise-flagged registration
    cr = engine.continue_init(on_error="collect")
    op = ManualOp()
    engine.continue_when(op, lambda st, d: 1 / 0, cr=cr,
                         flags=ContinueFlags(on_error="raise"))
    op.trigger()
    with pytest.raises(CallbackError):
        cr.test()
    # raise-by-default CR, collect-flagged registration
    cr2 = engine.continue_init()
    op2 = ManualOp()
    engine.continue_when(op2, lambda st, d: 1 / 0, cr=cr2,
                         flags=ContinueFlags(on_error="collect"))
    op2.trigger()
    assert cr2.test() is True
    assert len(cr2.errors) == 1


def test_thread_any_flag_runs_on_internal_thread():
    eng = Engine(progress_thread=True, progress_interval=1e-4)
    try:
        cr = eng.continue_init()                 # default thread=application
        ran_on = []
        op = ManualOp(push=False)
        eng.continue_when(op, lambda st, d: ran_on.append(
            threading.get_ident()), cr=cr,
            flags=ContinueFlags(thread="any"))
        op.trigger()                             # poll-mode: flag only
        deadline = time.monotonic() + 5.0
        while not ran_on and time.monotonic() < deadline:
            time.sleep(1e-3)                     # never calls into engine
        assert ran_on and ran_on[0] != threading.get_ident()
    finally:
        eng.shutdown()


# --------------------------------------------------------------- satellites
def test_mark_attached_rollback_on_registration_failure(engine):
    """Regression: a failing continue_all must release the already-marked
    prefix — previously those ops stayed consumed."""
    cr = engine.continue_init()
    good = [ManualOp() for _ in range(2)]
    used = ManualOp()
    used.mark_attached()                         # will fail mid-loop
    with pytest.raises(RuntimeError, match="already has a continuation"):
        engine.continue_all(good + [used], lambda st, d: None, cr=cr)
    # the prefix is usable again
    seen = []
    assert engine.continue_all(
        good, lambda st, d: seen.append("ok"), cr=cr) is False
    for op in good:
        op.trigger()
    assert seen == ["ok"]


def test_free_on_idle_cr_releases_immediately(engine):
    """Regression: free() on a CR with an empty active set used to leave
    it waiting for a drain that would never happen."""
    cr = engine.continue_init()
    assert cr.released is False
    cr.free()
    assert cr.released is True                   # released right away
    assert cr.cr_state is CRState.FREED

    # active CR: released only once the set drains
    cr2 = engine.continue_init()
    op = ManualOp()
    engine.continue_when(op, lambda st, d: None, cr=cr2)
    cr2.free()
    assert cr2.released is False
    op.trigger()
    assert cr2.released is True


def test_register_on_freed_cr_releases_ops(engine):
    """Regression (review): registration failing at cr._register (freed
    CR) must not leave the ops consumed."""
    cr = engine.continue_init()
    cr.free()
    op = ManualOp()
    with pytest.raises(RuntimeError, match="freed"):
        engine.continue_when(op, lambda st, d: None, cr=cr)
    assert not op._attached
    cr2 = engine.continue_init()
    seen = []
    engine.continue_when(op, lambda st, d: seen.append(1), cr=cr2)
    op.trigger()
    assert seen == [1]


def test_max_poll_cap_does_not_starve_other_crs(engine):
    """Regression (review): hitting the tested CR's max_poll cap must not
    skip other CRs' ready continuations queued behind it."""
    capped = engine.continue_init(poll_only=True, max_poll=1)
    other = engine.continue_init(
        enqueue_complete=True, poll_only=False)
    seen = []
    # two poll_only continuations on the capped CR (private queue)...
    for i in range(2):
        op = ManualOp()
        engine.continue_when(op, lambda st, d, i=i: seen.append(("cap", i)),
                             cr=capped)
        op.trigger()
    # ...and one from another CR parked on the scheduler queue
    op2 = ManualOp()
    engine.continue_when(op2, lambda st, d: seen.append("other"), cr=other,
                         flags=ContinueFlags(defer_complete=True))
    op2.trigger()
    capped.test()     # budget 1: one capped callback AND the other CR's
    assert ("cap", 0) in seen and "other" in seen
    assert ("cap", 1) not in seen
    capped.test()
    assert ("cap", 1) in seen


# --------------------------------------------------------------- priority
def test_priority_flag_resolution_and_validation():
    info = make_info()
    assert resolve(info, None).priority == 0
    assert resolve(info, ContinueFlags(priority=3)).priority == 3
    assert make_flags({"mpi_continue_priority": 2}).priority == 2
    with pytest.raises(ValueError, match="priority"):
        ContinueFlags(priority="high")


def test_priority_jumps_scheduler_ready_queue(engine):
    """A priority>0 registration drains ahead of normal-priority work
    already sitting in the ready queue (defer_complete parks both)."""
    cr = engine.continue_init()
    seen = []
    defer = ContinueFlags(defer_complete=True)
    for i in range(2):
        op = ManualOp()
        engine.continue_when(op, lambda st, d, i=i: seen.append(("lo", i)),
                             cr=cr, flags=defer)
        op.trigger()
    hi = ManualOp()
    engine.continue_when(
        hi, lambda st, d: seen.append("hi"), cr=cr,
        flags=ContinueFlags(defer_complete=True, priority=1))
    hi.trigger()
    engine.tick()
    assert seen[0] == "hi"
    assert ("lo", 0) in seen and ("lo", 1) in seen


def test_priority_jumps_poll_only_private_queue(engine):
    cr = engine.continue_init(poll_only=True)
    seen = []
    lo = ManualOp()
    engine.continue_when(lo, lambda st, d: seen.append("lo"), cr=cr)
    lo.trigger()
    hi = ManualOp()
    engine.continue_when(hi, lambda st, d: seen.append("hi"), cr=cr,
                         flags=ContinueFlags(priority=1))
    hi.trigger()
    cr.test()
    assert seen == ["hi", "lo"]


def test_priority_class_stays_fifo(engine):
    """Priority jumps the queue but must NOT reorder continuations within
    the priority class (an appendleft would run same-source completions
    LIFO — e.g. a serve request's consecutive step continuations)."""
    cr = engine.continue_init()
    seen = []
    flags = ContinueFlags(defer_complete=True, priority=1)
    for i in range(3):
        op = ManualOp()
        engine.continue_when(op, lambda st, d, i=i: seen.append(i),
                             cr=cr, flags=flags)
        op.trigger()
    engine.tick()
    assert seen == [0, 1, 2]
