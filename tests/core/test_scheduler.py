"""Scheduler/Progress split tests: both schedulers must preserve the
paper's execution semantics; affinity-specific behavior is covered on top.
"""
import threading
import time

import pytest

from repro.core import (AffinityScheduler, Engine, FifoScheduler, Scheduler,
                        Status, make_scheduler)
from repro.core.completable import Completable


class ManualOp(Completable):
    def __init__(self, push: bool = True):
        super().__init__()
        self._push = push
        self.flag = False

    @property
    def supports_push(self):
        return self._push

    def trigger(self, status: Status = None):
        if self._push:
            self._complete(status or Status())
        else:
            self.flag = True

    def _poll(self):
        return self.flag


@pytest.fixture(params=["fifo", "affinity"])
def engine(request):
    eng = Engine(scheduler=request.param)
    yield eng
    eng.shutdown()


# --------------------------------------------------------------- factory
def test_make_scheduler_resolution():
    assert isinstance(make_scheduler("fifo"), FifoScheduler)
    assert isinstance(make_scheduler("affinity"), AffinityScheduler)
    inst = FifoScheduler(inline_limit=3)
    assert make_scheduler(inst) is inst
    assert isinstance(make_scheduler(AffinityScheduler), AffinityScheduler)
    with pytest.raises(ValueError, match="unknown scheduler"):
        make_scheduler("bogus")


def test_engine_scheduler_kwarg_and_inline_limit():
    eng = Engine(scheduler="affinity", inline_limit=7)
    try:
        assert isinstance(eng.scheduler, AffinityScheduler)
        assert eng.inline_limit == 7
        eng.inline_limit = 3
        assert eng.scheduler.inline_limit == 3
    finally:
        eng.shutdown()


def test_engine_stats_merged_keys():
    eng = Engine()
    try:
        stats = eng.stats
        for key in ("progress_calls", "inline_runs", "queued_runs",
                    "poll_scans"):
            assert key in stats
    finally:
        eng.shutdown()


# ------------------------------------------------- semantics, both impls
def test_push_completion_runs_inline(engine):
    cr = engine.continue_init()
    op = ManualOp()
    seen = []
    engine.continue_when(op, lambda st, d: seen.append(d), "x", cr=cr)
    op.trigger()
    assert seen == ["x"]
    assert cr.test() is True


def test_poll_only_defers_to_test(engine):
    cr = engine.continue_init({"mpi_continue_poll_only": True})
    op = ManualOp()
    seen = []
    engine.continue_when(op, lambda st, d: seen.append(1), cr=cr)
    op.trigger()
    engine.tick()
    assert seen == []
    cr.test()
    assert seen == [1]


def test_no_nested_execution(engine):
    cr = engine.continue_init()
    order = []
    op2 = ManualOp()

    def outer(st, d):
        order.append("outer-begin")
        op2.trigger()
        order.append("outer-end")

    op1 = ManualOp()
    engine.continue_when(op1, outer, cr=cr)
    engine.continue_when(op2, lambda st, d: order.append("inner"), cr=cr)
    op1.trigger()
    assert order[:2] == ["outer-begin", "outer-end"]
    assert cr.wait(timeout=2.0)
    assert order == ["outer-begin", "outer-end", "inner"]


def test_concurrent_exactly_once(engine):
    cr = engine.continue_init()
    n_threads, per_thread = 6, 60
    done = []
    lock = threading.Lock()

    def worker(base):
        for i in range(per_thread):
            op = ManualOp()
            engine.continue_when(
                op, lambda st, d: (lock.acquire(), done.append(d),
                                   lock.release()), base + i, cr=cr)
            op.trigger()

    threads = [threading.Thread(target=worker, args=(t * per_thread,))
               for t in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert cr.wait(timeout=10.0)
    assert sorted(done) == list(range(n_threads * per_thread))


@pytest.mark.parametrize("sched", ["fifo", "affinity"])
def test_thread_any_runs_on_progress_thread(sched):
    eng = Engine(scheduler=sched, progress_thread=True,
                 progress_interval=1e-4)
    try:
        cr = eng.continue_init({"mpi_continue_thread": "any"})
        op = ManualOp(push=False)
        seen = threading.Event()
        eng.continue_when(op, lambda st, d: seen.set(), cr=cr)
        op.trigger()
        assert seen.wait(timeout=2.0)
    finally:
        eng.shutdown()


@pytest.mark.parametrize("sched", ["fifo", "affinity"])
def test_thread_application_not_run_internally(sched):
    eng = Engine(scheduler=sched, progress_thread=True,
                 progress_interval=1e-4)
    try:
        cr = eng.continue_init()
        op = ManualOp(push=False)
        seen = []
        eng.continue_when(op, lambda st, d: seen.append(1), cr=cr)
        op.trigger()
        time.sleep(0.05)
        assert seen == []     # internal thread discovered but must not run
        cr.test()
        assert seen == [1]
    finally:
        eng.shutdown()


# ----------------------------------------------------- affinity-specific
def test_affinity_cross_thread_stealing():
    """Work left on one thread's local queue must be runnable from another
    thread's engine entry (no stranding)."""
    eng = Engine(scheduler="affinity")
    try:
        cr = eng.continue_init({"mpi_continue_poll_only": False})
        seen = []
        gate = threading.Event()

        def producer():
            # Complete an op *inside registration* of another continuation:
            # the ready continuation is parked (no inline execution) on this
            # thread's local queue, and this thread never re-enters.
            op1 = ManualOp()
            op2 = ManualOp()
            eng.continue_when(op1, lambda st, d: seen.append("one"), cr=cr)
            op1.trigger()          # runs inline here
            op2.trigger()
            # registering an already-complete op with enqueue_complete path:
            # hook fires during registration -> parked, not executed
            cr2 = eng.continue_init({"mpi_continue_enqueue_complete": True})
            eng.continue_when(op2, lambda st, d: seen.append("two"), cr=cr2)
            gate.set()

        t = threading.Thread(target=producer)
        t.start()
        t.join(timeout=5.0)
        assert gate.is_set()
        assert "two" not in seen        # still parked on the dead thread
        eng.tick()                       # main thread steals + runs
        assert "two" in seen
        assert eng.scheduler.stats["steals"] >= 1
    finally:
        eng.shutdown()


def test_affinity_local_push_fast_path():
    eng = Engine(scheduler="affinity")
    try:
        cr = eng.continue_init()
        for _ in range(5):
            op = ManualOp()
            eng.continue_when(op, lambda st, d: None, cr=cr)
            op.trigger()
        assert cr.test() is True
        assert eng.scheduler.stats["local_pushes"] >= 5
        assert eng.scheduler.pending == 0
    finally:
        eng.shutdown()


# --------------------------------------------------- facade back-compat
def test_engine_backcompat_delegates():
    eng = Engine()
    try:
        cr = eng.continue_init()
        op = ManualOp(push=False)
        seen = []
        eng.continue_when(op, lambda st, d: seen.append(1), cr=cr)
        op.trigger()
        eng._scan_polls()      # discovery via legacy entry point
        eng._drain_ready()     # execution via legacy entry point
        assert seen == [1]
        assert cr.test() is True
    finally:
        eng.shutdown()


def test_scheduler_pending_introspection():
    for name in ("fifo", "affinity"):
        sched = make_scheduler(name)
        assert isinstance(sched, Scheduler)
        assert sched.pending == 0
