"""Promise front-end: chaining, cancellation, sync wait, asyncio bridge."""
import asyncio
import threading
import time

import pytest

from repro.core import (Engine, Promise, PromiseCancelled, Status, TimerOp,
                        when_all)
from repro.core.completable import Completable
from repro.core.status import OpState


class ManualOp(Completable):
    def __init__(self, push: bool = True):
        super().__init__()
        self._push = push
        self.flag = False

    @property
    def supports_push(self):
        return self._push

    def trigger(self, status: Status = None):
        if self._push:
            self._complete(status or Status())
        else:
            self.flag = True

    def _poll(self):
        return self.flag


@pytest.fixture
def engine():
    eng = Engine()
    yield eng
    eng.shutdown()


# ----------------------------------------------------------------- basics
def test_wrap_resolves_with_payload(engine):
    op = ManualOp()
    p = engine.wrap(op)
    assert p.state == "pending" and not p.done()
    op.trigger(Status(payload={"tok": [1, 2]}))
    assert p.done()
    assert p.result(timeout=5) == {"tok": [1, 2]}


def test_wrap_already_complete_op(engine):
    op = ManualOp()
    op.trigger(Status(payload="early"))
    p = engine.wrap(op)
    assert p.result(timeout=5) == "early"


def test_wrap_failed_op_rejects(engine):
    op = ManualOp()
    p = engine.wrap(op)
    op._complete(Status(error=ValueError("boom")), OpState.FAILED)
    with pytest.raises(ValueError, match="boom"):
        p.result(timeout=5)


def test_result_timeout(engine):
    p = engine.wrap(ManualOp())
    with pytest.raises(TimeoutError):
        p.result(timeout=0.05)


# --------------------------------------------------------------- chaining
def test_then_chain_values(engine):
    op = ManualOp()
    out = engine.wrap(op).then(lambda v: v + 1).then(lambda v: v * 10)
    op.trigger(Status(payload=4))
    assert out.result(timeout=5) == 50


def test_then_handler_raise_rejects_child(engine):
    op = ManualOp()
    child = engine.wrap(op).then(lambda v: 1 / 0)
    op.trigger(Status(payload=1))
    with pytest.raises(ZeroDivisionError):
        child.result(timeout=5)


def test_catch_recovers(engine):
    op = ManualOp()
    out = (engine.wrap(op)
           .then(lambda v: (_ for _ in ()).throw(RuntimeError("bad")))
           .catch(lambda exc: "recovered")
           .then(lambda v: v + "!"))
    op.trigger()
    assert out.result(timeout=5) == "recovered!"


def test_catch_skipped_on_fulfilment(engine):
    op = ManualOp()
    seen = []
    out = engine.wrap(op).catch(lambda exc: seen.append(exc)).then(
        lambda v: "through")
    op.trigger(Status(payload="v"))
    assert out.result(timeout=5) == "through" and seen == []


def test_then_adopts_completable(engine):
    """A handler returning an op chains the promise onto it."""
    first, second = ManualOp(), ManualOp()
    out = engine.wrap(first).then(lambda v: second)
    first.trigger(Status(payload="a"))
    assert not out.done()
    second.trigger(Status(payload="b"))
    assert out.result(timeout=5) == "b"


def test_then_adopts_promise(engine):
    inner = Promise.deferred(engine)
    op = ManualOp()
    out = engine.wrap(op).then(lambda v: inner)
    op.trigger()
    assert not out.done()
    inner.resolve(123)
    assert out.result(timeout=5) == 123


def test_then_on_settled_promise_runs_immediately(engine):
    op = ManualOp()
    op.trigger(Status(payload=2))
    p = engine.wrap(op)
    p.result(timeout=5)
    assert p.then(lambda v: v * 3).result(timeout=5) == 6


def test_all_of_any_of(engine):
    ops = [ManualOp() for _ in range(3)]
    pall = Promise.all_of(engine, ops)
    for i, op in enumerate(ops):
        op.trigger(Status(payload=i))
    assert pall.result(timeout=5) == [0, 1, 2]

    ops2 = [ManualOp() for _ in range(3)]
    pany = Promise.any_of(engine, ops2)
    ops2[1].trigger(Status(payload="winner"))
    assert pany.result(timeout=5) == "winner"


# ------------------------------------------------------------ cancellation
def test_cancel_propagates_to_op(engine):
    op = ManualOp()
    p = engine.wrap(op)
    assert p.cancel() is True
    assert op.state is OpState.CANCELLED
    with pytest.raises(PromiseCancelled):
        p.result(timeout=5)


def test_cancel_through_then_chain(engine):
    """Cancelling a chained child reaches the source operation."""
    op = ManualOp()
    child = engine.wrap(op).then(lambda v: v)
    assert child.cancel() is True
    assert op.state is OpState.CANCELLED
    with pytest.raises(PromiseCancelled):
        child.result(timeout=5)


def test_deferred_resolve_reject(engine):
    p = Promise.deferred(engine)
    assert p.resolve("v") is True
    assert p.resolve("again") is False           # settle-once
    assert p.result(timeout=5) == "v"
    q = Promise.deferred(engine)
    q.reject(RuntimeError("nope"))
    with pytest.raises(RuntimeError):
        q.result(timeout=5)
    d = Promise.deferred(engine)
    assert d.cancel() is True                    # no op: direct rejection
    with pytest.raises(PromiseCancelled):
        d.result(timeout=5)


# ---------------------------------------------------------- asyncio bridge
def test_await_cross_thread_resolution(engine):
    async def main():
        op = ManualOp()
        p = engine.wrap(op)
        threading.Timer(
            0.05, lambda: op.trigger(Status(payload="from-thread"))).start()
        return await p

    assert asyncio.run(main()) == "from-thread"


def test_await_already_settled(engine):
    async def main():
        op = ManualOp()
        op.trigger(Status(payload=7))
        return await engine.wrap(op)

    assert asyncio.run(main()) == 7


def test_await_poll_mode_op_loop_driven(engine):
    """A poll-mode op (TimerOp) awaited with NO external ticker: the
    bridge keeps the engine progressing from the event loop."""
    async def main():
        t0 = time.monotonic()
        await engine.wrap(TimerOp(0.05))
        return time.monotonic() - t0

    assert asyncio.run(main()) >= 0.05


def test_await_rejection_raises(engine):
    async def main():
        op = ManualOp()
        p = engine.wrap(op)
        threading.Timer(0.02, op.cancel).start()
        with pytest.raises(PromiseCancelled):
            await p
        return "ok"

    assert asyncio.run(main()) == "ok"


def test_await_gather_many(engine):
    """Batch awaiting — the serving-style pattern the bench gates."""
    async def main():
        ops = [ManualOp() for _ in range(32)]
        proms = [engine.wrap(op) for op in ops]

        def fire():
            for i, op in enumerate(ops):
                op.trigger(Status(payload=i))

        threading.Timer(0.02, fire).start()
        return await asyncio.gather(*proms)

    assert asyncio.run(main()) == list(range(32))


def test_await_when_all_composite(engine):
    async def main():
        ops = [ManualOp(push=False) for _ in range(3)]
        comb = when_all(ops)
        for op in ops:
            op.trigger()                         # poll flags only
        return await engine.wrap(comb)

    assert asyncio.run(main()) == [None, None, None]


def test_settle_callback_isolation(engine):
    """Regression (review): one broken settle consumer must not starve
    the others (e.g. an awaiter whose event loop already closed)."""
    op = ManualOp()
    p = engine.wrap(op)
    seen = []

    def broken(state, value):
        raise RuntimeError("consumer exploded")

    p._on_settle(broken)
    p._on_settle(lambda s, v: seen.append(v))
    op.trigger(Status(payload="v"))              # must not raise
    assert seen == ["v"]


def test_shared_progress_driver_single_chain(engine):
    """Regression (review): N concurrent awaits share one engine tick
    chain instead of N redundant per-interval scans."""
    async def main():
        import asyncio
        from repro.core import promise as pr
        ops = [ManualOp(push=False) for _ in range(8)]
        proms = [engine.wrap(op) for op in ops]

        async def one(p):
            return await p

        tasks = [asyncio.ensure_future(one(p)) for p in proms]
        await asyncio.sleep(0.01)                # let every __await__ run
        drivers = getattr(pr._BRIDGE_TLS, "drivers", {})
        assert len(drivers) == 1                 # one chain for the engine
        (_loop, watch), = drivers.values()
        assert len(watch) == 8
        for op in ops:
            op.trigger()                         # poll flags
        await asyncio.gather(*tasks)
        await asyncio.sleep(0.01)                # chain retires itself
        assert id(engine) not in getattr(pr._BRIDGE_TLS, "drivers", {})
        return True

    import asyncio
    assert asyncio.run(main())


# ---------------------------------------------------- Signal (multi-shot)
def test_signal_arm_then_set_then_rearm():
    from repro.core import Signal
    sig = Signal()
    p1 = sig.wait()
    assert p1.state == "pending"
    sig.set("a")
    assert p1.result(timeout=1) == "a"
    p2 = sig.wait()
    assert p2 is not p1 and p2.state == "pending"   # re-armed
    sig.set("b")
    assert p2.result(timeout=1) == "b"
    assert sig.fired == 2


def test_signal_set_between_arm_and_await_not_lost():
    """The arm→check→await pattern: a set() racing in after wait() still
    settles the armed promise, so the consumer cannot sleep through it."""
    from repro.core import Signal
    sig = Signal()
    armed = sig.wait()
    sig.set("raced")           # producer fires before the consumer waits
    assert armed.result(timeout=1) == "raced"
    # ...but a wait() AFTER the set observes only future generations
    assert sig.wait().state == "pending"


def test_signal_stream_consumer_threaded():
    """Multi-shot delivery: one producer thread, one consumer using the
    arm→check→await pattern over a shared buffer (the TokenStream
    shape), every item observed exactly once, in order."""
    from repro.core import Signal
    sig = Signal()
    buf, closed = [], []
    lock = threading.Lock()

    def producer():
        for i in range(200):
            with lock:
                buf.append(i)
            sig.set()
        with lock:
            closed.append(True)
        sig.set()

    got = []
    t = threading.Thread(target=producer)
    t.start()
    taken = 0
    while True:
        p = sig.wait()                    # arm first
        with lock:
            if taken < len(buf):
                got.append(buf[taken])
                taken += 1
                continue
            if closed:
                break
        p.result(timeout=5)               # blocking "await"
    t.join()
    assert got == list(range(200))


def test_signal_asyncio_await():
    from repro.core import Signal
    sig = Signal()

    async def main():
        out = []

        async def consumer():
            for _ in range(3):
                p = sig.wait()
                out.append(await p)
            return out

        task = asyncio.ensure_future(consumer())
        for v in ("x", "y", "z"):
            await asyncio.sleep(0.005)
            sig.set(v)
        return await task

    assert asyncio.run(main()) == ["x", "y", "z"]
