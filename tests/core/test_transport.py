"""Transport semantics + continuation integration tests."""
import threading
import time

import pytest

from repro.core import (ANY_SOURCE, ANY_TAG, Engine, OpState, Status,
                        Transport)


@pytest.fixture
def engine():
    eng = Engine()
    yield eng
    eng.shutdown()


def test_send_recv_matching():
    tr = Transport(2)
    recv = tr.irecv(1, source=0, tag=5)
    send = tr.isend(0, 1, 5, b"hello")
    assert recv.done() and send.done()
    assert recv.status.payload == b"hello"
    assert recv.status.source == 0 and recv.status.tag == 5


def test_unexpected_message_then_recv():
    tr = Transport(2)
    send = tr.isend(0, 1, 9, b"x" * 10)   # eager: completes buffered
    assert send.done()
    recv = tr.irecv(1, source=ANY_SOURCE, tag=ANY_TAG)
    assert recv.done()
    assert recv.status.tag == 9


def test_rendezvous_send_waits_for_recv():
    tr = Transport(2, eager_threshold=4)
    send = tr.isend(0, 1, 1, b"x" * 100)   # > threshold: rendezvous
    assert not send.done()
    tr.irecv(1)
    assert send.done()


def test_tag_and_source_selectivity():
    tr = Transport(3)
    r_tag2 = tr.irecv(2, source=ANY_SOURCE, tag=2)
    tr.isend(0, 2, 1, b"one")
    assert not r_tag2.done()
    tr.isend(1, 2, 2, b"two")
    assert r_tag2.done()
    assert r_tag2.status.source == 1
    r_any = tr.irecv(2)
    assert r_any.done() and r_any.status.payload == b"one"


def test_fifo_ordering_same_tag():
    tr = Transport(2)
    for i in range(5):
        tr.isend(0, 1, 7, i)
    got = [tr.irecv(1, tag=7).status.payload for _ in range(5)]
    assert got == list(range(5))


def test_recv_cancellation():
    tr = Transport(2)
    recv = tr.irecv(1, source=0, tag=3)
    assert recv.cancel() is True
    assert recv.state is OpState.CANCELLED
    assert recv.status.test_cancelled()
    # a matching send now goes to the unexpected queue, not the cancelled recv
    tr.isend(0, 1, 3, b"late")
    r2 = tr.irecv(1, tag=3)
    assert r2.done() and r2.status.payload == b"late"


def test_cancel_after_match_fails():
    tr = Transport(2)
    recv = tr.irecv(1)
    tr.isend(0, 1, 0, b"m")
    assert recv.cancel() is False
    assert recv.status.payload == b"m"


def test_continuation_on_recv(engine):
    """The paper's central flow: callback fires when the message lands,
    on the thread that made the completing transport call."""
    tr = Transport(2, engine=engine)
    cr = engine.continue_init()
    seen = []
    recv = tr.irecv(1, source=0, tag=1)
    engine.continue_when(recv, lambda st, d: seen.append(st[0].payload),
                         status=[None], cr=cr)
    assert seen == []
    tr.isend(0, 1, 1, b"payload")   # completes recv → continuation inline
    assert seen == [b"payload"]
    assert cr.test()


def test_continuation_repost_from_callback(engine):
    """Paper §2: a continuation body may start new operations (re-post).

    Callbacks run nested-free: the re-posted recv's own continuation fires
    later, not recursively.
    """
    tr = Transport(2, engine=engine)
    cr = engine.continue_init()
    got = []

    def on_msg(st, d):
        got.append(st[0].payload)
        if len(got) < 3:
            nxt = tr.irecv(1, source=0, tag=1)
            engine.continue_when(nxt, on_msg, status=[None], cr=cr)

    first = tr.irecv(1, source=0, tag=1)
    engine.continue_when(first, on_msg, status=[None], cr=cr)
    for i in range(3):
        tr.isend(0, 1, 1, i)
        engine.tick()
    assert cr.wait(timeout=2.0)
    assert got == [0, 1, 2]


def test_latency_delivery(engine):
    tr = Transport(2, engine=engine, latency_s=0.02)
    try:
        cr = engine.continue_init()
        seen = threading.Event()
        recv = tr.irecv(1, source=0, tag=1)
        engine.continue_when(recv, lambda st, d: seen.set(), cr=cr)
        t0 = time.monotonic()
        tr.isend(0, 1, 1, b"delayed")
        assert not seen.is_set()
        assert cr.wait(timeout=2.0)
        assert seen.is_set()
        assert time.monotonic() - t0 >= 0.015
    finally:
        tr.shutdown()


def test_multithreaded_ranks_pingpong(engine):
    """Two 'ranks' on two threads ping-pong via continuations."""
    tr = Transport(2, engine=engine)
    n_rounds = 20
    done = threading.Event()
    log = []

    def rank(rid, peer):
        # enqueue_complete: recv completed before registration still fires the
        # callback via the queue — no immediate-flag handling needed (§3.5).
        cr = engine.continue_init({"mpi_continue_enqueue_complete": True})
        count = {"n": 0}

        def on_msg(st, d):
            log.append((rid, st[0].payload))
            count["n"] += 1
            if st[0].payload < n_rounds:
                tr.isend(rid, peer, 0, st[0].payload + 1)
            nxt = tr.irecv(rid, source=peer, tag=0)
            engine.continue_when(nxt, on_msg, status=[None], cr=cr)

        first = tr.irecv(rid, source=peer, tag=0)
        engine.continue_when(first, on_msg, status=[None], cr=cr)
        if rid == 0:
            tr.isend(0, peer, 0, 0)
        deadline = time.monotonic() + 10
        while count["n"] < n_rounds // 2 and time.monotonic() < deadline:
            engine.tick()
            time.sleep(1e-4)
        done.set()

    t0 = threading.Thread(target=rank, args=(0, 1))
    t1 = threading.Thread(target=rank, args=(1, 0))
    t0.start(); t1.start()
    t0.join(timeout=15); t1.join(timeout=15)
    assert done.is_set()
    payloads = sorted(p for _, p in log)
    assert payloads[0] == 0 and payloads[-1] >= n_rounds - 1
