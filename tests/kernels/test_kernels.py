"""Pallas kernel sweeps: shapes × dtypes, interpret-mode vs pure-jnp oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="optional dev dependency (pip install hypothesis)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.kernels.flash_attention import kernel as fa_kernel, ref as fa_ref
from repro.kernels.rmsnorm import kernel as rn_kernel, ref as rn_ref
from repro.kernels.ssd_scan import kernel as ssd_kernel, ref as ssd_ref

TOL = {jnp.float32: dict(atol=2e-5, rtol=2e-5),
       jnp.bfloat16: dict(atol=2e-2, rtol=2e-2)}


def _mk_qkv(key, B, Sq, Sk, H, KV, D, dtype):
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, Sq, H, D)).astype(dtype)
    k = jax.random.normal(ks[1], (B, Sk, KV, D)).astype(dtype)
    v = jax.random.normal(ks[2], (B, Sk, KV, D)).astype(dtype)
    return q, k, v


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,S,H,KV,D", [
    (1, 64, 1, 1, 16),      # minimal
    (2, 128, 4, 2, 32),     # GQA
    (1, 96, 8, 1, 64),      # MQA, non-pow2 seq
    (2, 256, 4, 4, 64),     # MHA
])
@pytest.mark.parametrize("causal,window", [(True, 0), (True, 33), (False, 0)])
def test_flash_attention_sweep(dtype, B, S, H, KV, D, causal, window):
    q, k, v = _mk_qkv(jax.random.PRNGKey(0), B, S, S, H, KV, D, dtype)
    o_ref = fa_ref.attention(q, k, v, causal=causal, window=window)
    o_pal = fa_kernel.flash_attention(q, k, v, causal=causal, window=window,
                                      block_q=32, block_k=32, interpret=True)
    np.testing.assert_allclose(np.asarray(o_ref, np.float32),
                               np.asarray(o_pal, np.float32), **TOL[dtype])


@pytest.mark.parametrize("cache,off", [(40, 39), (64, 10), (96, 95)])
def test_flash_attention_decode_offsets(cache, off):
    """q_len=1 decode against a cache, various absolute positions."""
    q, k, v = _mk_qkv(jax.random.PRNGKey(1), 2, 1, cache, 4, 2, 32,
                      jnp.float32)
    o_ref = fa_ref.attention(q, k, v, causal=True, q_offset=off)
    o_pal = fa_kernel.flash_attention(q, k, v, causal=True, q_offset=off,
                                      block_q=1, block_k=32, interpret=True)
    np.testing.assert_allclose(o_ref, o_pal, atol=2e-5, rtol=2e-5)


def test_flash_attention_kv_padding():
    """KV length not divisible by block size exercises the pad/mask path."""
    q, k, v = _mk_qkv(jax.random.PRNGKey(2), 1, 64, 100, 2, 2, 32,
                      jnp.float32)
    o_ref = fa_ref.attention(q, k, v, causal=False)
    o_pal = fa_kernel.flash_attention(q, k, v, causal=False, block_q=32,
                                      block_k=32, interpret=True)
    np.testing.assert_allclose(o_ref, o_pal, atol=2e-5, rtol=2e-5)


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 3), st.integers(1, 4), st.integers(0, 1),
       st.sampled_from([16, 32]), st.sampled_from([48, 64, 128]))
def test_flash_attention_property(b, kv, causal_i, d, s):
    """Property sweep: random (B, KV, D, S) with H = 2·KV."""
    q, k, v = _mk_qkv(jax.random.PRNGKey(b * 100 + kv), b, s, s, 2 * kv, kv,
                      d, jnp.float32)
    causal = bool(causal_i)
    o_ref = fa_ref.attention(q, k, v, causal=causal)
    o_pal = fa_kernel.flash_attention(q, k, v, causal=causal, block_q=16,
                                      block_k=16, interpret=True)
    np.testing.assert_allclose(o_ref, o_pal, atol=3e-5, rtol=3e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", [(8, 128), (3, 5, 256), (2, 7, 9, 512),
                                   (16, 1024)])
def test_rmsnorm_sweep(dtype, shape):
    key = jax.random.PRNGKey(3)
    x = jax.random.normal(key, shape).astype(dtype)
    s = jax.random.normal(jax.random.fold_in(key, 1), (shape[-1],))
    o_ref = rn_ref.rmsnorm(x, s)
    o_pal = rn_kernel.rmsnorm(x, s, interpret=True)
    np.testing.assert_allclose(np.asarray(o_ref, np.float32),
                               np.asarray(o_pal, np.float32), **TOL[dtype])


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,T,H,P,G,N,chunk", [
    (1, 64, 2, 16, 1, 16, 16),
    (2, 128, 4, 16, 2, 32, 32),
    (1, 256, 8, 32, 1, 64, 64),
    (2, 96, 4, 16, 4, 16, 32),   # T not a chunk multiple of 64; G=H/1
])
def test_ssd_scan_sweep(dtype, B, T, H, P, G, N, chunk):
    key = jax.random.PRNGKey(4)
    ks = jax.random.split(key, 6)
    x = jax.random.normal(ks[0], (B, T, H, P)).astype(dtype)
    dt = (jax.nn.softplus(jax.random.normal(ks[1], (B, T, H))) * 0.1)
    A = -jnp.exp(jax.random.normal(ks[2], (H,))) * 0.5
    Bm = jax.random.normal(ks[3], (B, T, G, N))
    Cm = jax.random.normal(ks[4], (B, T, G, N))
    D = jax.random.normal(ks[5], (H,)) * 0.1
    if T % chunk:
        chunk = 16
    y_ref, _ = ssd_ref.ssd_sequential(x, dt, A, Bm, Cm, D)
    y_pal = ssd_kernel.ssd_scan(x, dt, A, Bm, Cm, D, chunk=chunk,
                                interpret=True)
    tol = dict(atol=2e-4, rtol=2e-3) if dtype == jnp.float32 else \
        dict(atol=5e-2, rtol=5e-2)
    np.testing.assert_allclose(np.asarray(y_ref, np.float32),
                               np.asarray(y_pal, np.float32), **tol)


def test_ssd_chunked_equals_sequential_long():
    """The xla production path (chunked einsum) against the oracle."""
    key = jax.random.PRNGKey(5)
    ks = jax.random.split(key, 6)
    B, T, H, P, G, N = 1, 512, 2, 16, 1, 32
    x = jax.random.normal(ks[0], (B, T, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, T, H))) * 0.1
    A = -jnp.exp(jax.random.normal(ks[2], (H,))) * 0.5
    Bm = jax.random.normal(ks[3], (B, T, G, N))
    Cm = jax.random.normal(ks[4], (B, T, G, N))
    D = jax.random.normal(ks[5], (H,)) * 0.1
    y0, s0 = ssd_ref.ssd_sequential(x, dt, A, Bm, Cm, D)
    y1, s1 = ssd_ref.ssd_chunked(x, dt, A, Bm, Cm, D, chunk=128)
    np.testing.assert_allclose(y0, y1, atol=5e-4, rtol=5e-4)
    np.testing.assert_allclose(s0, s1, atol=5e-4, rtol=5e-4)


def test_kernel_impl_dispatch():
    """ops wrappers honor the impl override context."""
    from repro.kernels import impl as impl_mod
    from repro.kernels.rmsnorm import ops as rn_ops
    x = jnp.ones((4, 64))
    s = jnp.ones((64,))
    with impl_mod.use_impl("xla"):
        a = rn_ops.rmsnorm(x, s)
    with impl_mod.use_impl("pallas_interpret"):
        b = rn_ops.rmsnorm(x, s)
    np.testing.assert_allclose(a, b, atol=1e-6)
    with pytest.raises(ValueError):
        impl_mod.resolve("cuda")
