"""Fused paged-attention parity: interpret-mode kernel vs pure-jnp ref,
and the ref vs an independent dense oracle.

Covers the shapes the serve engine actually dispatches — W=1 decode,
W=1+K verify windows (K = 0..n_draft), page-padded suffix prefill —
plus the write-side contract: accept-masked rows land in real pages,
rejected/padded rows only ever touch the scratch page, untouched pages
round-trip bit-exactly, and idle slots (n_valid=0) write nothing and
output zeros.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.paged_attention import kernel as pa_kernel, ref as pa_ref

TOL = dict(atol=2e-5, rtol=2e-5)


def _scenario(seed, S, W, H, KV, hd, ps, T, n_real, positions, n_valid,
              tables=None):
    """Random pool + per-slot tables. ``tables=None`` builds disjoint
    footprints covering each slot's positions, null-padded past them."""
    rng = np.random.default_rng(seed)
    null = n_real
    if tables is None:
        tables = np.full((S, T), null, np.int32)
        nxt = 0
        for s in range(S):
            need = min(T, -(-(positions[s] + W) // ps))
            for e in range(need):
                tables[s, e] = nxt % n_real
                nxt += 1
    args = dict(
        q=jnp.asarray(rng.normal(size=(S, W, H, hd)), jnp.float32),
        k_new=jnp.asarray(rng.normal(size=(S, W, KV, hd)), jnp.float32),
        v_new=jnp.asarray(rng.normal(size=(S, W, KV, hd)), jnp.float32),
        k_pages=jnp.asarray(rng.normal(size=(n_real + 1, ps, KV, hd)),
                            jnp.float32),
        v_pages=jnp.asarray(rng.normal(size=(n_real + 1, ps, KV, hd)),
                            jnp.float32),
        tables=jnp.asarray(tables),
        positions=jnp.asarray(positions, jnp.int32),
        n_valid=jnp.asarray(n_valid, jnp.int32),
    )
    return args, np.asarray(tables), null


def _compare(args, null):
    o_r, k_r, v_r = pa_ref.paged_attention(**args, page_size=args["k_pages"].shape[1])
    o_k, k_k, v_k = pa_kernel.paged_attention(
        **args, page_size=args["k_pages"].shape[1], interpret=True)
    np.testing.assert_allclose(np.asarray(o_r), np.asarray(o_k), **TOL)
    # pools bit-exact on every REAL page (the scratch page is garbage by
    # contract: the ref parks rejected rows there, the kernel does not)
    np.testing.assert_array_equal(np.asarray(k_r)[:null], np.asarray(k_k)[:null])
    np.testing.assert_array_equal(np.asarray(v_r)[:null], np.asarray(v_k)[:null])
    return o_k, k_k, v_k


@pytest.mark.parametrize("ps", [4, 8])
@pytest.mark.parametrize("K", [0, 1, 2, 3])
def test_verify_window_parity(ps, K):
    """1+K verify windows at ragged per-slot positions, incl. an idle
    slot and a slot with a rejected tail (n_valid < W)."""
    W = 1 + 3  # engine compiles one W for every slot; n_valid masks K
    positions = [ps + 1, 3 * ps - 1, 0, 2 * ps]
    n_valid = [1 + K, max(1, K), 0, 1 + K]
    args, tables, null = _scenario(
        0, 4, W, 4, 2, 16, ps, 6, 12, positions, n_valid)
    _compare(args, null)


@pytest.mark.parametrize("ps", [4, 8])
def test_decode_parity(ps):
    """W=1 plain decode, positions straddling page boundaries."""
    positions = [0, ps - 1, ps, 2 * ps + 1]
    args, tables, null = _scenario(
        1, 4, 1, 4, 2, 16, ps, 4, 10, positions, [1, 1, 1, 1])
    _compare(args, null)


def test_suffix_prefill_parity():
    """S=1 page-padded suffix window (n_valid = real tail < W)."""
    ps, tail = 8, 13
    W = 16  # padded to a page multiple
    args, tables, null = _scenario(2, 1, W, 4, 2, 16, ps, 6, 5, [8], [tail])
    _compare(args, null)


def test_idle_slot_writes_nothing_outputs_zero():
    ps = 8
    args, tables, null = _scenario(3, 2, 2, 4, 2, 16, ps, 3, 4,
                                   [5, 9], [0, 0])
    o, k_k, v_k = _compare(args, null)
    assert np.all(np.asarray(o) == 0)
    np.testing.assert_array_equal(np.asarray(k_k)[:null],
                                  np.asarray(args["k_pages"])[:null])


def test_accept_masked_rows_only_touch_scratch():
    """Rows j >= n_valid must not modify any REAL page; rows j < n_valid
    land exactly at (pos+j) in the slot's footprint."""
    ps, W, nv = 4, 4, 2
    pos = 3  # rows at positions 3,4,5,6 span a page boundary
    args, tables, null = _scenario(4, 1, W, 4, 2, 16, ps, 4, 6,
                                   [pos], [nv])
    _, k_k, _ = _compare(args, null)
    k_k = np.asarray(k_k)
    kp = np.asarray(args["k_pages"])
    kn = np.asarray(args["k_new"])
    for j in range(W):
        p = tables[0, (pos + j) // ps]
        row = (pos + j) % ps
        if j < nv:
            np.testing.assert_array_equal(k_k[p, row], kn[0, j])
        else:
            np.testing.assert_array_equal(k_k[p, row], kp[p, row])


def test_shared_page_read_only():
    """Two slots gathering one shared prefix page leave it bit-exact."""
    ps, W = 4, 2
    tables = np.array([[0, 1, 3, 3], [0, 2, 3, 3]], np.int32)
    args, tables, null = _scenario(5, 2, W, 4, 2, 16, ps, 4, 3,
                                   [ps + 1, ps], [2, 2], tables=tables)
    _, k_k, _ = _compare(args, null)
    np.testing.assert_array_equal(np.asarray(k_k)[0],
                                  np.asarray(args["k_pages"])[0])


def test_ref_matches_dense_oracle():
    """Triangulate: the ref (and therefore the kernel) reproduces plain
    dense causal attention computed on the contiguous history."""
    rng = np.random.default_rng(6)
    S, W, H, KV, hd, ps, T = 2, 3, 4, 2, 16, 4, 4
    G = H // KV
    hist_len = [6, 9]  # positions already in the pool, then W new rows
    n_real, null = 6, 6
    tables = np.full((S, T), null, np.int32)
    tables[0, :3] = [0, 1, 2]
    tables[1, :3] = [3, 4, 5]
    kp = np.zeros((n_real + 1, ps, KV, hd), np.float32)
    vp = np.zeros((n_real + 1, ps, KV, hd), np.float32)
    hist_k = [rng.normal(size=(hl, KV, hd)).astype(np.float32)
              for hl in hist_len]
    hist_v = [rng.normal(size=(hl, KV, hd)).astype(np.float32)
              for hl in hist_len]
    for s in range(S):
        for t in range(hist_len[s]):
            kp[tables[s, t // ps], t % ps] = hist_k[s][t]
            vp[tables[s, t // ps], t % ps] = hist_v[s][t]
    q = rng.normal(size=(S, W, H, hd)).astype(np.float32)
    kn = rng.normal(size=(S, W, KV, hd)).astype(np.float32)
    vn = rng.normal(size=(S, W, KV, hd)).astype(np.float32)
    o, _, _ = pa_ref.paged_attention(
        jnp.asarray(q), jnp.asarray(kn), jnp.asarray(vn),
        jnp.asarray(kp), jnp.asarray(vp), jnp.asarray(tables),
        jnp.asarray(hist_len, jnp.int32), jnp.asarray([W, W], jnp.int32),
        page_size=ps)
    for s in range(S):
        full_k = np.concatenate([hist_k[s], kn[s]])   # (L+W, KV, hd)
        full_v = np.concatenate([hist_v[s], vn[s]])
        for j in range(W):
            L = hist_len[s] + j + 1                   # causal horizon
            for h in range(H):
                kv = h // G
                sc = (q[s, j, h] @ full_k[:L, kv].T) * hd ** -0.5
                p = np.exp(sc - sc.max()); p /= p.sum()
                np.testing.assert_allclose(
                    np.asarray(o)[s, j, h], p @ full_v[:L, kv], **TOL)


def test_kernel_jits_and_is_deterministic():
    ps = 4
    args, tables, null = _scenario(7, 2, 2, 4, 2, 16, ps, 3, 5,
                                   [2, 5], [2, 1])
    f = jax.jit(lambda **kw: pa_kernel.paged_attention(
        **kw, page_size=ps, interpret=True))
    o1, k1, v1 = f(**args)
    o2, k2, v2 = f(**args)
    np.testing.assert_array_equal(np.asarray(o1), np.asarray(o2))
    np.testing.assert_array_equal(np.asarray(k1), np.asarray(k2))
