"""Serve-layer trace invariants on real engines: correlated per-request
timelines on the colocated tier, KV ship-before-import ordering across
the disaggregated role boundary, router shadow linking, recorder cause
attribution, and page-leak freedom while traced."""
import jax
import pytest

from repro import obs
from repro.configs import get_config
from repro.models import lm
from repro.obs import events as E
from repro.obs import tracer as tracer_mod
from repro.serve import Request, RequestState, Router, serve_requests
from repro.serve.disagg import DisaggServer


@pytest.fixture(scope="module")
def small_model():
    cfg = get_config("paper_demo", reduced=True)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


@pytest.fixture(autouse=True)
def _tracing_off():
    yield
    tracer_mod.stop()


PROMPTS = [
    list(range(1, 12)),              # 11 tokens -> 3 pages @ page_size=4
    [2, 3, 4, 5, 6],                 # 5 tokens
]
KW = dict(max_batch=2, max_cache_len=64, page_size=4, max_seq_len=48)


def _by_rid(events):
    out = {}
    for ev in events:
        if ev.kind.startswith("req.") and ev.rid >= 0:
            out.setdefault(ev.rid, []).append(ev)
    return out


# --------------------------------------------------------- colocated tier
def test_colocated_timeline_complete_and_ordered(small_model, tmp_path):
    cfg, params = small_model
    rec = obs.Recorder()
    with rec:
        reqs = serve_requests(cfg, params, [Request(p, 6) for p in PROMPTS],
                              timeout=300, paged=True, **KW)
    assert all(r.req_state is RequestState.FINISHED for r in reqs)
    timelines = _by_rid(rec.events)
    needed = {E.REQ_SUBMIT, E.REQ_ADMIT, E.REQ_PREFILL, E.REQ_PAGES_ALLOC,
              E.REQ_SEAT, E.REQ_STEP, E.REQ_DELIVER, E.REQ_PAGES_RELEASE,
              E.REQ_FINISH}
    for r in reqs:
        tl = timelines[r.req_id]
        kinds = {ev.kind for ev in tl}
        # the acceptance timeline: admission -> prefill -> every decode
        # step -> delivery, all on one correlated request id
        assert needed <= kinds
        # the admission span opens at arrival (before submit() ran) and
        # closes at placement: submission lands inside it
        admit = next(ev for ev in tl if ev.kind == E.REQ_ADMIT)
        submit = next(ev for ev in tl if ev.kind == E.REQ_SUBMIT)
        assert admit.ts <= submit.ts <= admit.ts + admit.dur
        assert tl[-1].kind in (E.REQ_FINISH, E.REQ_PAGES_RELEASE)
        steps = [ev for ev in tl if ev.kind == E.REQ_STEP]
        delivers = [ev for ev in tl if ev.kind == E.REQ_DELIVER]
        assert len(steps) >= len(r.tokens) - 1   # one span per decode step
        assert sum(ev.meta for ev in delivers) == len(r.tokens)
        prefill = next(ev for ev in tl if ev.kind == E.REQ_PREFILL)
        assert prefill.dur > 0.0
        assert all(prefill.ts <= ev.ts for ev in steps)

    # the runtime's own edges rode along: all four lifecycle histograms
    assert ({edge for edge, _ in rec.histograms}
            == set(E.LIFECYCLE_EDGES))
    cause = rec.cause_summary()
    assert cause["requests"] == len(reqs)
    assert cause["compute_ms_mean"] > 0.0
    assert cause["notify_latency_us_mean"] > 0.0
    assert cause["dropped"] == 0

    # chrome export: one process per request, spans render as "X"
    path = rec.write(str(tmp_path / "trace.json"))
    doc = rec.chrome_trace()
    pids = {r_["pid"] for r_ in doc["traceEvents"] if r_["ph"] != "M"}
    assert {r.req_id + 1 for r in reqs} <= pids
    assert any(r_["ph"] == "X" for r_ in doc["traceEvents"])
    assert path.endswith("trace.json")


# ------------------------------------------------------------ disagg tier
def test_disagg_ship_before_import_across_roles(small_model):
    cfg, params = small_model
    reqs = [Request(p, 6) for p in PROMPTS]
    obs.start()
    srv = DisaggServer(cfg, params, chunk_pages=1, **KW)
    try:
        for r in reqs:
            srv.submit(r)
        srv.close_intake()
        srv.run(timeout=300)
        assert all(r.req_state is RequestState.FINISHED for r in reqs)
        assert srv.decode.pool.pages_in_use == 0
        assert srv.prefill.pool.pages_in_use == 0
    finally:
        srv.shutdown()
        tr = tracer_mod.stop()

    timelines = _by_rid(tr.drain())
    for r in reqs:
        tl = timelines[r.req_id]
        ships = {ev.meta: ev.ts for ev in tl if ev.kind == E.REQ_KV_SHIP}
        imports = {ev.meta: ev.ts for ev in tl
                   if ev.kind == E.REQ_KV_IMPORT}
        # every shipped block is imported, and never before it shipped:
        # the request timeline stays monotone across the role boundary
        assert ships and set(ships) == set(imports)
        for block, t_ship in ships.items():
            assert imports[block] >= t_ship
        # prefill-role work precedes decode-role work on the same track
        prefill_ts = [ev.ts for ev in tl
                      if ev.src == "prefill" and ev.kind == E.REQ_PREFILL]
        step_ts = [ev.ts for ev in tl if ev.kind == E.REQ_STEP]
        assert prefill_ts and step_ts
        assert min(prefill_ts) <= min(step_ts)
        srcs = {ev.src for ev in tl}
        assert {"prefill", "decode"} <= srcs


# ------------------------------------------------------------ router tier
def test_router_links_shadows_to_originals(small_model):
    cfg, params = small_model
    obs.start()
    r = Router(cfg, params, n_replicas=2, paged=True, **KW)
    try:
        reqs = [r.submit(Request(p, 6)) for p in PROMPTS]
        r.close_intake()
        r.run(timeout=300)
        assert all(q.req_state is RequestState.FINISHED for q in reqs)
        for w in r.workers:
            if w.pool is not None:
                assert w.pool.pages_in_use == 0
        m = r.metrics()
        assert m["transport_sent_msgs"] > 0   # typed transport fields
    finally:
        r.shutdown()
        tr = tracer_mod.stop()

    events = tr.drain()
    roots = E.link_roots(events)
    originals = {q.req_id for q in reqs}
    assert roots                              # every dispatch is a shadow
    assert set(roots.values()) <= originals
    # the exporter collapses shadow events onto the originals' tracks
    doc = obs.chrome_trace(events)
    req_pids = {rec["pid"] for rec in doc["traceEvents"]
                if rec["ph"] != "M" and rec["name"].startswith("req.")}
    assert req_pids == {rid + 1 for rid in originals}
