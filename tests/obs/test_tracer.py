"""Observability core: ring-buffer drop-not-block semantics, the
default-off fast path, deterministic sampling, continuation lifecycle
ordering (op-complete never after callback-ran) with all four edge
histograms, and the Chrome/Prometheus exporters."""
import pytest

from repro import obs
from repro.obs import events as E
from repro.obs import tracer as tracer_mod
from repro.obs.buffer import TraceBuffer
from repro.obs.hist import Histogram


@pytest.fixture(autouse=True)
def _tracing_off():
    # never leak an armed global tracer into other tests
    yield
    tracer_mod.stop()


def _drive_engine(n=8):
    """Register n continuations on pushable ops, trigger, wait."""
    from repro.core import Engine, Status
    from repro.core.completable import Completable

    class Op(Completable):
        @property
        def supports_push(self):
            return True

        def trigger(self):
            self._complete(Status())

    eng = Engine()
    cr = eng.continue_init()
    done = []
    try:
        ops = [Op() for _ in range(n)]
        for op in ops:
            eng.continue_when(op, lambda st, d: done.append(d), cr=cr)
        for op in ops:
            op.trigger()
        assert cr.wait(timeout=10)
    finally:
        eng.shutdown()
    assert len(done) == n
    return done


# ---------------------------------------------------------------- basics
def test_tracing_is_off_by_default():
    assert tracer_mod.TRACE is None
    assert not obs.is_enabled()
    assert obs.active() is None


def test_start_stop_arm_and_disarm():
    tr = obs.start(sample=0.5, capacity=32)
    assert obs.is_enabled() and obs.active() is tr
    assert obs.stop() is tr
    assert not obs.is_enabled()
    assert obs.stop() is None          # idempotent


# ------------------------------------------------------------- overflow
def test_ring_overflow_drops_not_blocks():
    buf = TraceBuffer(4)
    for i in range(10):
        buf.record((float(i), 0.0, "k", i, "t", None))
    assert len(buf) == 4               # oldest records kept, never grows
    assert buf.dropped == 6
    snap = buf.snapshot()
    assert [ev.rid for ev in snap] == [0, 1, 2, 3]
    assert all(ev.tid == buf.tid for ev in snap)


def test_tracer_surfaces_drop_counter():
    tr = obs.start(capacity=8)
    for i in range(20):
        tr.evt(E.REQ_STEP, i, "test")
    assert tr.dropped == 12
    events = tr.drain()
    assert len(events) == 8
    doc = obs.chrome_trace(events, dropped=tr.dropped)
    assert doc["otherData"]["dropped_events"] == 12
    assert doc["otherData"]["event_count"] == 8


# ------------------------------------------------------------- sampling
def test_sampling_deterministic_by_id():
    a = obs.Tracer(sample=0.5)
    b = obs.Tracer(sample=0.5)
    picked = [i for i in range(1000) if a.want(i)]
    # same subset on every component/tracer; genuinely partial
    assert picked == [i for i in range(1000) if b.want(i)]
    assert 0 < len(picked) < 1000
    assert all(obs.Tracer(sample=1.0).want(i) for i in range(100))
    assert not any(obs.Tracer(sample=0.0).want(i) for i in range(100))


def test_sample_zero_records_nothing_from_core():
    obs.start(sample=0.0)
    _drive_engine()
    tr = tracer_mod.stop()
    assert tr.drain() == []
    assert tr.histograms() == {}


# --------------------------------------------------- lifecycle ordering
def test_lifecycle_edges_ordered_and_histogrammed():
    obs.start()
    _drive_engine()
    tr = tracer_mod.stop()
    by_cont = {}
    for ev in tr.drain():
        if ev.kind.startswith("cont."):
            by_cont.setdefault(ev.rid, {})[ev.kind] = ev
    assert by_cont
    full = {E.CONT_POSTED, E.CONT_READY, E.CONT_ENQUEUED, E.CONT_RAN}
    for kinds in by_cont.values():
        # sampled-at-registration => traced end-to-end, in causal order;
        # in particular op-complete (READY) never lands after the
        # callback-ran timestamp
        assert set(kinds) == full
        assert (kinds[E.CONT_POSTED].ts <= kinds[E.CONT_READY].ts
                <= kinds[E.CONT_ENQUEUED].ts <= kinds[E.CONT_RAN].ts)
        assert kinds[E.CONT_RAN].dur >= 0.0
    hist = tr.histograms()
    assert {edge for edge, _ in hist} == set(E.LIFECYCLE_EDGES)
    for h in hist.values():
        assert h.count > 0
        assert h.total >= 0.0


# ------------------------------------------------------------- exporters
def test_chrome_trace_tracks_and_phases():
    events = [
        E.Event(1.0, 0.5, E.REQ_ADMIT, 7, "engine", None, 1),
        E.Event(1.6, 0.0, E.REQ_DELIVER, 7, "serve", 3, 1),
        E.Event(1.7, 0.0, E.CONT_READY, 42, "core", None, 9),
    ]
    doc = obs.chrome_trace(events)
    recs = [r for r in doc["traceEvents"] if r["ph"] != "M"]
    admit, deliver, ready = recs
    assert admit["ph"] == "X"                      # span
    assert admit["dur"] == pytest.approx(0.5e6)    # us
    assert deliver["ph"] == "i"                    # instant
    assert admit["pid"] == deliver["pid"] == 8     # request 7's process
    assert ready["pid"] == 0                       # runtime process
    assert ready["tid"] == 9                       # real thread id


def test_chrome_trace_collapses_shadow_chains():
    events = [
        E.Event(1.0, 0.0, E.REQ_SUBMIT, 1, "router", None, 1),
        E.Event(1.1, 0.0, E.REQ_LINK, 5, "router", 1, 1),
        E.Event(1.2, 0.0, E.REQ_LINK, 9, "router", 5, 1),   # re-shadowed
        E.Event(1.3, 0.0, E.REQ_STEP, 9, "engine", None, 1),
    ]
    assert obs.link_roots(events) == {5: 1, 9: 1}   # transitive
    doc = obs.chrome_trace(events)
    pids = {r["pid"] for r in doc["traceEvents"] if r["ph"] != "M"}
    assert pids == {2}                 # everything on request 1's track


def test_prometheus_text_shapes():
    h = Histogram()
    for v in (0.5, 3.0, 100.0):
        h.observe(v)
    text = obs.prometheus_text(
        {"finished": 3, "ttft_mean": 0.25},
        histograms={("complete_to_run", "sched"): h},
        dropped=2,
        transport={"sent_bytes": 11, "per_tag": {7: {"sent_msgs": 4}}})
    assert "repro_trace_dropped_events 2" in text
    assert "repro_serve_finished 3" in text
    assert "repro_transport_sent_bytes 11" in text
    assert 'repro_transport_sent_msgs{tag="7"} 4' in text
    assert 'le="+Inf"' in text         # cumulative buckets close at +Inf
    assert ('repro_lifecycle_latency_us_count'
            '{edge="complete_to_run",policy="sched"} 3') in text
