"""Fused-vs-unfused serve engine: token identity and page hygiene.

The fused paged steps (one ``lm_paged_decode`` call through the
paged-attention kernel) must produce EXACTLY the token streams of the
unfused gather/scatter steps and of dense-cache serving, on randomized
workloads mixing ragged prompts, prefix-cache hits, budgets that retire
slots mid-batch, and speculative verify windows — with every page back
in the pool afterwards.
"""
import numpy as np
import pytest

import jax

from repro.configs import get_config
from repro.kernels import impl as impl_mod
from repro.models import lm
from repro.serve import Request, ServeEngine
from repro.serve.steps import greedy_generate


@pytest.fixture(scope="module")
def small_model():
    cfg = get_config("paper_demo", reduced=True)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _serve(cfg, params, prompts, news, *, impl=None, **kw):
    eng = ServeEngine(cfg, params, max_batch=3, max_cache_len=64,
                      page_size=8, total_pages=24, max_seq_len=48, **kw)
    try:
        reqs = [Request(p, n) for p, n in zip(prompts, news)]
        for r in reqs:
            eng.submit(r)
        eng.close_intake()
        if impl:
            with impl_mod.use_impl(impl):
                eng.run(timeout=600)
        else:
            eng.run(timeout=600)
        if eng.pool is not None:
            assert eng.pool.pages_in_use == 0, "leaked pages"
        return [r.tokens for r in reqs], eng.metrics()
    finally:
        eng.shutdown()


def _workload(seed, n_req=5):
    rng = np.random.default_rng(seed)
    prompts = [rng.integers(1, 500, size=int(rng.integers(4, 28))).tolist()
               for _ in range(n_req)]
    news = [int(rng.integers(2, 14)) for _ in range(n_req)]
    return prompts, news


@pytest.mark.parametrize("seed", [0, 1])
def test_fused_matches_unfused_and_dense(small_model, seed):
    cfg, params = small_model
    prompts, news = _workload(seed)
    fused, mf = _serve(cfg, params, prompts, news, paged=True, fused=True)
    unfused, mu = _serve(cfg, params, prompts, news, paged=True, fused=False)
    dense, _ = _serve(cfg, params, prompts, news, paged=False)
    assert fused == unfused == dense
    assert mf["fused"] and not mu["fused"]


def test_fused_matches_greedy_oracle(small_model):
    cfg, params = small_model
    prompts, news = _workload(2, n_req=3)
    fused, _ = _serve(cfg, params, prompts, news, paged=True, fused=True)
    for p, n, got in zip(prompts, news, fused):
        want = list(map(int, greedy_generate(
            cfg, params, np.asarray(p, np.int32)[None, :], n,
            max_cache_len=64)[0]))
        assert got == want


def test_fused_prefix_cache_hit_identical(small_model):
    """Shared page-aligned prefixes route through the fused suffix step."""
    cfg, params = small_model
    base = list(range(1, 17))  # 16 tokens = 2 full pages at ps=8
    prompts = [base + [100, 101], base + [200], base + [300, 301, 302]]
    news = [6, 5, 4]
    fused, mf = _serve(cfg, params, prompts, news, paged=True, fused=True)
    unfused, _ = _serve(cfg, params, prompts, news, paged=True, fused=False)
    assert fused == unfused
    assert mf["suffix_steps"] > 0  # the fused suffix path actually ran


def test_fused_speculative_identical(small_model):
    cfg, params = small_model
    rng = np.random.default_rng(3)
    base = rng.integers(1, 40, size=6).tolist()
    prompts = [base * 3, (base * 2)[:10], base * 4]
    news = [10, 7, 12]
    kw = dict(max_batch=3, max_cache_len=96, page_size=8,
              total_pages=40, max_seq_len=90)

    def run(**extra):
        eng = ServeEngine(cfg, params, **kw, **extra)
        try:
            reqs = [Request(p, n) for p, n in zip(prompts, news)]
            for r in reqs:
                eng.submit(r)
            eng.close_intake()
            eng.run(timeout=600)
            assert eng.pool.pages_in_use == 0
            return [r.tokens for r in reqs], eng.metrics()
        finally:
            eng.shutdown()

    plain, _ = run(paged=True, fused=True)
    spec_f, mf = run(paged=True, fused=True, speculate=3)
    spec_u, mu = run(paged=True, fused=False, speculate=3)
    assert spec_f == plain == spec_u
    # same schedule too: the fused verify accepts exactly what unfused did
    assert mf["draft_accepted"] == mu["draft_accepted"]
    assert mf["draft_proposed"] == mu["draft_proposed"]


def test_fused_interpret_kernel_identical(small_model):
    """The Pallas kernel body (interpret mode) drives the engine to the
    same tokens as the jnp reference path — the CPU-side proof the TPU
    lowering computes the serve semantics."""
    cfg, params = small_model
    prompts, news = _workload(4, n_req=2)
    ref, _ = _serve(cfg, params, prompts, news, paged=True, fused=True)
    interp, _ = _serve(cfg, params, prompts, news, paged=True, fused=True,
                       impl="pallas_interpret")
    assert interp == ref


def test_fused_requires_paged(small_model):
    cfg, params = small_model
    with pytest.raises(ValueError, match="fused"):
        ServeEngine(cfg, params, paged=False, fused=True)


def test_device_table_cache_incremental(small_model):
    """Placements/evictions refresh only dirty rows of the device-resident
    table mirror; the mirror always equals the host tables at dispatch."""
    cfg, params = small_model
    eng = ServeEngine(cfg, params, max_batch=3, max_cache_len=64,
                      page_size=8, total_pages=24, max_seq_len=48,
                      paged=True, fused=True)
    try:
        reqs = [Request(list(range(1, 10)), 3) for _ in range(3)]
        for r in reqs:
            eng.submit(r)
        eng.close_intake()
        while not (eng.batcher.closed and eng.idle):
            eng.step()
            if eng._tables_dev is not None and not eng._tables_dirty:
                np.testing.assert_array_equal(
                    np.asarray(eng._tables_dev), eng._tables)
        assert eng.pool.pages_in_use == 0
        # post-run: evictions marked their rows dirty; a final refresh
        # converges the mirror to the all-null host state
        np.testing.assert_array_equal(
            np.asarray(eng._device_tables()), eng._tables)
        assert not eng._tables_dirty
    finally:
        eng.shutdown()
