"""Multi-replica front door: prefix-affinity routing (hit rate on a
shared-prefix trace, saturation fallback), weighted per-tenant fairness
(DRR share convergence, quota refusal with retry-after), and
heartbeat-driven failover (kill a replica mid-decode: zero requests
lost, token-identical greedy replay) — with page-leak checks on every
replica's pool.

Routing-policy edges run against a stub tier satisfying ``EngineLike``
(no jit); token-identity and failover acceptance run against real
``ServeEngine`` replicas.
"""
import time

import jax
import pytest

from repro.configs import get_config
from repro.core import Engine
from repro.models import lm
from repro.serve import (EngineLike, FairBatcher, GenerationConfig,
                         QuotaExceeded, Request, RequestState, Router,
                         ServeMetrics, serve_requests)
from repro.serve.kv_cache import prefix_keys


@pytest.fixture(scope="module")
def small_model():
    cfg = get_config("paper_demo", reduced=True)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


KW = dict(max_batch=2, max_cache_len=64, paged=True, page_size=4,
          max_seq_len=48)

PROMPTS = [
    list(range(1, 12)),
    list(range(5, 14)),
    [2, 3, 4, 5, 6],
    list(range(7, 20)),
]


def _baseline(cfg, params, prompts, n=8):
    out = serve_requests(cfg, params, [Request(p, n) for p in prompts],
                         timeout=300, **KW)
    return {tuple(p): list(r.tokens) for p, r in zip(prompts, out)}


def _assert_no_leaks(router):
    for w in router.workers:
        if w.pool is not None:
            assert w.pool.pages_in_use == 0, \
                f"replica {w.rank} leaked {w.pool.pages_in_use} pages"


# ------------------------------------------------------------- stub tier
class _StubPool:
    """Just enough PagePool surface for the router's gossip/affinity."""

    total_pages = 256
    pages_in_use = 0
    page_size = 4

    def __init__(self):
        self.digests = set()

    def prefix_digests(self):
        return frozenset(self.digests)


class _StubTier:
    """An ``EngineLike`` tier with instant deterministic 'generation':
    token i for a prompt is ``sum(prompt) + i`` — same on every replica,
    so failover replay identity is checkable without a model."""

    paged = True
    page_size = 4
    max_seq_len = 10_000
    max_batch = 4

    def __init__(self, engine, tokens_per_step=2):
        self.engine = engine
        self.pool = _StubPool()
        self.active = []
        self.retired = []
        self._tps = tokens_per_step
        # the router only reads .batcher on ITSELF; tiers expose theirs
        # for the protocol, a stub object is enough
        self.batcher = type("B", (), {"closed": True, "drained": True})()

    def submit(self, request):
        request.on_admitted()
        self.active.append(request)
        base = sum(int(t) for t in request.prompt)
        for k in prefix_keys(request.prompt, self.page_size):
            self.pool.digests.add(k)
        request._stub_base = base
        return request

    def close_intake(self):
        pass

    def step(self):
        progressed = False
        for req in list(self.active):
            if req.is_terminal:
                self.active.remove(req)
                continue
            req.on_first_token()
            done = req.delivered
            n = min(self._tps, req.max_new_tokens - done)
            if n > 0:
                req.deliver([req._stub_base + done + i for i in range(n)])
                progressed = True
            if req.delivered >= req.max_new_tokens:
                req.retire()
                self.active.remove(req)
                self.retired.append(req)
        return progressed

    def run(self, timeout=None, idle_sleep=5e-5, until=None):
        while self.active:
            self.step()
        return self.retired

    def metrics(self):
        return ServeMetrics.from_flat({"finished": len(self.retired)})

    @property
    def idle(self):
        return not self.active

    def shutdown(self):
        pass


def _stub_router(n=2, **kw):
    engine = Engine()
    replicas = [_StubTier(engine) for _ in range(n)]
    return Router(replicas=replicas, engine=engine, **kw)


def _expected_stub_tokens(prompt, n):
    base = sum(prompt)
    return [base + i for i in range(n)]


# ------------------------------------------------------ policy (stub) tests
def test_stub_tier_satisfies_protocol():
    assert isinstance(_StubTier(Engine()), EngineLike)


def test_router_basic_stub_roundtrip():
    r = _stub_router(2)
    reqs = [r.submit(Request([i, i + 1], 4)) for i in range(4)]
    r.close_intake()
    done = r.run(timeout=30)
    assert len(done) == 4
    for req in reqs:
        assert req.tokens == _expected_stub_tokens(req.prompt, 4)
    m = r.metrics()
    assert m["routed"] == 4
    assert m["replicas_live"] == 2
    r.shutdown()


def test_affinity_prefers_digest_holder():
    r = _stub_router(2)
    prompt = list(range(1, 10))
    # replica 2 already holds this prompt's pages; 1 holds nothing
    r._digests[2] = set(prefix_keys(prompt, 4))
    req = r.submit(Request(prompt, 2))
    r.run(timeout=30, until=lambda: req.is_terminal and r.idle)
    assert r.metrics()["affinity_hits"] == 1
    assert r._rank_inflight[2] == 0 and r.stats["routed"] == 1
    # the dispatch went to rank 2 (its digest set absorbed the insert;
    # rank 1's is untouched)
    assert not r._digests[1]
    r.shutdown()


def test_affinity_falls_back_when_affine_replica_saturated():
    r = _stub_router(2, saturation=1)
    prompt = list(range(1, 10))
    r._digests[2] = set(prefix_keys(prompt, 4))
    # freeze dispatch-side capacity at rank 2
    r._rank_inflight[2] = 1
    req = r.submit(Request(prompt, 2))
    r.run(timeout=30, until=lambda: req.is_terminal and r.idle)
    m = r.metrics()
    assert m["affinity_misses"] == 1 and m["affinity_hits"] == 0
    # work landed on the unsaturated replica
    assert r.workers[0].tier.retired and not r.workers[1].tier.retired
    r._rank_inflight[2] = 0
    r.shutdown()


def test_quota_refusal_and_release():
    r = _stub_router(2, quota={"acme": 2})
    a = r.submit(Request([1, 2, 3], 4, ))
    acme = GenerationConfig(max_tokens=4, tenant="acme")
    b = r.submit(Request([1, 2, 4], acme))
    c = r.submit(Request([1, 2, 5], acme))
    with pytest.raises(QuotaExceeded) as ei:
        r.submit(Request([1, 2, 6], acme))
    assert ei.value.tenant == "acme"
    assert ei.value.retry_after_s >= 0.0
    assert r.metrics()["quota_refused"] == 1
    # default tenant is unlimited here
    r.submit(Request([9, 9], 4))
    # once acme's outstanding work completes, the quota slot frees up
    r.run(timeout=30, until=lambda: r.idle)
    d = r.submit(Request([1, 2, 7], acme))
    r.close_intake()
    done = r.run(timeout=30)
    assert d in done and len(done) == 5
    r.shutdown()


def test_weighted_share_convergence_fairbatcher():
    """DRR: with weights 3:1 and identical costs, admitted token budget
    converges to the weight ratio (checked over a prefix of the pops)."""
    engine = Engine()
    fb = FairBatcher(engine, weights={"gold": 3.0, "bronze": 1.0},
                     quantum=8.0)
    for i in range(40):
        fb.submit(Request([i], GenerationConfig(max_tokens=8,
                                                tenant="gold")))
        fb.submit(Request([i], GenerationConfig(max_tokens=8,
                                                tenant="bronze")))
    popped = fb.admit(40)
    assert len(popped) == 40
    gold = sum(1 for r in popped if r.tenant == "gold")
    bronze = len(popped) - gold
    assert bronze > 0
    assert 2.0 <= gold / bronze <= 4.0, (gold, bronze)
    # strict priority classes still dominate fairness
    hi = fb.submit(Request([99], GenerationConfig(max_tokens=8,
                                                  tenant="bronze",
                                                  priority=5)))
    assert fb.admit(1) == [hi]
    engine.shutdown()


def test_requeue_on_death_token_identity_stub():
    """Kill a stub replica mid-generation: every request finishes with
    the exact token sequence an uninterrupted run produces."""
    r = _stub_router(2, heartbeat_timeout_s=0.05, sweep_interval_s=0.01)
    reqs = [r.submit(Request([10 + i, 20 + i], 16)) for i in range(6)]
    r.close_intake()
    # step until some replica is mid-generation, then kill it
    deadline = time.monotonic() + 10
    victim = None
    while victim is None and time.monotonic() < deadline:
        r.step()
        for t in r._tracked.values():
            if t.rank is not None and 0 < t.original.delivered < 16:
                victim = t.rank
                break
    assert victim is not None
    r.kill_replica(victim)
    done = r.run(timeout=30)
    assert len(done) == 6          # zero requests lost
    for req in reqs:
        assert req.req_state is RequestState.FINISHED
        assert req.tokens == _expected_stub_tokens(req.prompt, 16)
    m = r.metrics()
    assert m["failovers"] == 1
    assert m["replicas_live"] == 1
    assert m["requeued"] >= 1
    r.shutdown()


def test_metrics_shape():
    r = _stub_router(2)
    req = r.submit(Request([1, 2], 4))
    r.close_intake()
    r.run(timeout=30)
    m = r.metrics()
    assert isinstance(m, ServeMetrics)
    assert m.finished == 1
    assert set(m["per_replica"]) == {1, 2}
    assert m["transport"]["sends"] >= 1
    assert 0.0 <= m["affinity_hit_rate"] <= 1.0
    assert req.tokens
    r.shutdown()


# ------------------------------------------------------- real-model tests
def test_router_matches_single_engine_greedy(small_model):
    cfg, params = small_model
    base = _baseline(cfg, params, PROMPTS)
    r = Router(cfg, params, n_replicas=2, **KW)
    reqs = [r.submit(Request(p, 8)) for p in PROMPTS]
    r.close_intake()
    done = r.run(timeout=300)
    assert len(done) == len(PROMPTS)
    for p, req in zip(PROMPTS, reqs):
        assert req.tokens == base[tuple(p)], p
    _assert_no_leaks(r)
    r.shutdown()


def test_affinity_hit_rate_on_shared_prefix_trace(small_model):
    cfg, params = small_model
    r = Router(cfg, params, n_replicas=2, **KW)
    shared = list(range(1, 9))             # two full pages @ page_size=4
    reqs = [r.submit(Request(shared + [30 + i], 6)) for i in range(12)]
    r.close_intake()
    done = r.run(timeout=300)
    assert len(done) == len(reqs)
    m = r.metrics()
    assert m["affinity_hit_rate"] > 0.8, m["affinity_hit_rate"]
    # affinity concentrated the prefix on one replica: its pool reused it
    reused = sum(w.pool.stats["prefix_tokens_reused"] for w in r.workers)
    assert reused > 0
    _assert_no_leaks(r)
    r.shutdown()


def test_kill_replica_mid_decode_zero_loss(small_model):
    """The acceptance gate: killing a replica mid-decode loses zero
    requests, and every token stream is identical to the single-engine
    greedy run."""
    cfg, params = small_model
    base = _baseline(cfg, params, PROMPTS)
    r = Router(cfg, params, n_replicas=2, heartbeat_timeout_s=0.1,
               sweep_interval_s=0.01, **KW)
    reqs = [r.submit(Request(p, 8)) for p in PROMPTS]
    r.close_intake()
    deadline = time.monotonic() + 240
    victim = None
    while victim is None:
        assert time.monotonic() < deadline, "no decode progress"
        r.step()
        for t in r._tracked.values():
            if t.rank is not None and t.original.delivered >= 2:
                victim = t.rank
                break
    r.kill_replica(victim)
    done = r.run(timeout=300)
    assert len(done) == len(PROMPTS)       # zero requests lost
    for p, req in zip(PROMPTS, reqs):
        assert req.tokens == base[tuple(p)], p
    m = r.metrics()
    assert m["failovers"] >= 1
    _assert_no_leaks(r)                    # including the dead replica
    r.shutdown()
