"""Disaggregated prefill/decode serving: token identity against the
colocated engine (greedy, speculative, prefix-hit traffic), per-block
KV-shipping pipelining, cancel teardown mid-prefill and mid-shipping,
page-leak checks on BOTH role pools, transport per-tag accounting, and
the streaming front-end running over the role-split server unchanged."""
import time

import jax
import pytest

from repro.configs import get_config
from repro.models import lm
from repro.serve import (GenerationConfig, Request, RequestState,
                         ServeClient, pages_for, serve_requests)
from repro.serve.disagg import (CTRL_TAG, DisaggServer, block_tag,
                                serve_requests_disagg)


@pytest.fixture(scope="module")
def small_model():
    cfg = get_config("paper_demo", reduced=True)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


PROMPTS = [
    list(range(1, 12)),          # 11 tokens -> 3 pages @ page_size=4
    list(range(5, 14)),          # 9 tokens
    [2, 3, 4, 5, 6],             # 5 tokens
    list(range(7, 20)),          # 13 tokens -> 4 pages
]


def _colocated(cfg, params, reqs, **kw):
    kw.setdefault("max_batch", 2)
    kw.setdefault("max_cache_len", 64)
    kw.setdefault("paged", True)
    kw.setdefault("page_size", 4)
    kw.setdefault("max_seq_len", 48)
    return serve_requests(cfg, params, reqs, timeout=300, **kw)


def _disagg(cfg, params, reqs, **kw):
    kw.setdefault("max_batch", 2)
    kw.setdefault("max_cache_len", 64)
    kw.setdefault("page_size", 4)
    kw.setdefault("max_seq_len", 48)
    return serve_requests_disagg(cfg, params, reqs, timeout=300, **kw)


def _drain(srv, timeout=60.0):
    t0 = time.monotonic()
    while not srv.idle:
        assert time.monotonic() - t0 < timeout, "disagg server stuck"
        if not srv.step():
            time.sleep(1e-4)


def _assert_no_leaks(srv):
    assert srv.decode.pool.pages_in_use == 0
    assert srv.prefill.pool.pages_in_use == 0


# ------------------------------------------------------- token identity
def test_disagg_matches_colocated_greedy(small_model):
    cfg, params = small_model
    colo = _colocated(cfg, params, [Request(p, 8) for p in PROMPTS])
    reqs = [Request(p, 8) for p in PROMPTS]
    srv = DisaggServer(cfg, params, max_batch=2, max_cache_len=64,
                       page_size=4, max_seq_len=48, chunk_pages=1)
    try:
        for r in reqs:
            srv.submit(r)
        srv.close_intake()
        srv.run(timeout=300)
        assert [r.tokens for r in reqs] == [r.tokens for r in colo]
        assert all(r.req_state is RequestState.FINISHED for r in reqs)
        _assert_no_leaks(srv)
        m = srv.metrics()
        assert m["finished"] == len(PROMPTS)
        assert m["blocks_shipped"] == sum(pages_for(len(p), 4)
                                          for p in PROMPTS)
        assert m["bytes_shipped_per_request"] > 0
    finally:
        srv.shutdown()


def test_disagg_matches_colocated_speculative(small_model):
    """The decode role runs the same verify steps as the colocated
    engine; speculation changes the schedule, never the tokens."""
    cfg, params = small_model
    colo = _colocated(cfg, params, [Request(p, 8) for p in PROMPTS],
                      speculate=3)
    reqs = [Request(p, 8) for p in PROMPTS]
    srv = DisaggServer(cfg, params, max_batch=2, max_cache_len=64,
                       page_size=4, max_seq_len=48, speculate=3)
    try:
        for r in reqs:
            srv.submit(r)
        srv.close_intake()
        srv.run(timeout=300)
        assert [r.tokens for r in reqs] == [r.tokens for r in colo]
        assert srv.decode.stats["verify_steps"] > 0
        _assert_no_leaks(srv)
    finally:
        srv.shutdown()


def test_disagg_matches_colocated_on_prefix_hit_traffic(small_model):
    """Traffic where the colocated engine takes the prefix-cache suffix
    path (second request reuses the first's prompt pages): the prefill
    role recomputes instead of sharing — tokens must still be identical."""
    cfg, params = small_model
    base = list(range(30, 42))              # 12 tokens = 3 full pages
    prompts = [base + [50], base + [60, 61, 62]]
    colo_reqs = [Request(p, 6) for p in prompts]
    from repro.serve import ServeEngine
    eng = ServeEngine(cfg, params, max_batch=2, max_cache_len=64,
                      paged=True, page_size=4, max_seq_len=48)
    try:
        # both in flight at once: the second request's prefill sees the
        # first's resident prompt pages and takes the suffix path
        for r in colo_reqs:
            eng.submit(r)
        eng.close_intake()
        eng.run(timeout=300)
        assert eng.pool.stats["prefix_hits"] > 0     # hit path exercised
    finally:
        eng.shutdown()
    reqs = [Request(p, 6) for p in prompts]
    srv = DisaggServer(cfg, params, max_batch=2, max_cache_len=64,
                       page_size=4, max_seq_len=48)
    try:
        for r in reqs:
            srv.submit(r)
        srv.close_intake()
        srv.run(timeout=300)
        assert [r.tokens for r in reqs] == [r.tokens for r in colo_reqs]
        _assert_no_leaks(srv)
    finally:
        srv.shutdown()


def test_single_token_request_answered_at_prefill_role(small_model):
    """max_tokens=1 is answered entirely by the prefill role: no header,
    no KV shipped, no decode involvement."""
    cfg, params = small_model
    colo = _colocated(cfg, params, [Request([3, 4, 5, 6], 1)])
    reqs = [Request([3, 4, 5, 6], 1)]
    srv = DisaggServer(cfg, params, max_batch=2, max_cache_len=64,
                       page_size=4, max_seq_len=48)
    try:
        srv.submit(reqs[0])
        srv.close_intake()
        srv.run(timeout=300)
        assert reqs[0].tokens == colo[0].tokens
        assert len(reqs[0].tokens) == 1
        assert reqs[0] in srv.prefill.retired
        assert srv.metrics()["blocks_shipped"] == 0
        assert srv.decode.ingest_stats["headers"] == 0
        _assert_no_leaks(srv)
    finally:
        srv.shutdown()


# -------------------------------------------------- per-block pipelining
def test_blocks_ship_before_prefill_finishes(small_model):
    """The disaggregation claim itself: with chunked prefill, the decode
    role installs the FIRST KV block before the prefill role finishes the
    last chunk — per-block pipelining, not a barrier at end-of-prompt."""
    cfg, params = small_model
    reqs = [Request(p, 6) for p in PROMPTS if len(p) > 8]
    srv = DisaggServer(cfg, params, max_batch=2, max_cache_len=64,
                       page_size=4, max_seq_len=48, chunk_pages=1)
    try:
        for r in reqs:
            srv.submit(r)
        srv.close_intake()
        srv.run(timeout=300)
        ev = srv.events
        for r in reqs:
            first_install = ev.index(("install", r.req_id, 0))
            prefill_done = ev.index(("prefill_done", r.req_id))
            assert first_install < prefill_done, (
                f"req {r.req_id}: first block landed only after prefill "
                f"finished — no pipelining ({ev})")
        _assert_no_leaks(srv)
    finally:
        srv.shutdown()


def test_transport_per_tag_accounting(small_model):
    """KV bandwidth is observable per channel: each request's block tag
    carries exactly its prompt pages at page_nbytes each; control traffic
    stays on CTRL_TAG."""
    cfg, params = small_model
    reqs = [Request(PROMPTS[0], 6), Request(PROMPTS[3], 6)]
    srv = DisaggServer(cfg, params, max_batch=2, max_cache_len=64,
                       page_size=4, max_seq_len=48)
    try:
        for r in reqs:
            srv.submit(r)
        srv.close_intake()
        srv.run(timeout=300)
        stats = srv.transport.stats()
        page_nbytes = srv.prefill.pool.page_nbytes
        for r, prompt in zip(reqs, (PROMPTS[0], PROMPTS[3])):
            t = stats["per_tag"][block_tag(r.req_id)]
            n = pages_for(len(prompt), 4)
            assert t["sent_msgs"] == t["recvd_msgs"] == n
            assert t["sent_bytes"] == t["recvd_bytes"] == n * page_nbytes
        ctrl = stats["per_tag"][CTRL_TAG]
        # header + done per request, all matched by the standing recv
        assert ctrl["sent_msgs"] == ctrl["recvd_msgs"] == 2 * len(reqs)
        assert stats["sent_bytes"] >= srv.prefill.bytes_shipped
        _assert_no_leaks(srv)
    finally:
        srv.shutdown()


# --------------------------------------------------------- cancel paths
def test_cancel_mid_prefill_releases_both_pools(small_model):
    """Cancel while chunks are still running: the prefill role aborts,
    the decode role cancels its outstanding block receives, and neither
    pool leaks a page."""
    cfg, params = small_model
    srv = DisaggServer(cfg, params, max_batch=2, max_cache_len=64,
                       page_size=4, max_seq_len=48, chunk_pages=1)
    try:
        req = Request(list(range(1, 14)), 8)      # 4 pages of prompt
        srv.submit(req)
        # step until the header went out but prefill hasn't finished
        t0 = time.monotonic()
        while ("header", req.req_id) not in srv.events:
            assert time.monotonic() - t0 < 60
            srv.step()
        assert ("prefill_done", req.req_id) not in srv.events
        req.cancel()
        srv.close_intake()
        _drain(srv)
        assert req.req_state is RequestState.CANCELLED
        assert req.tokens == []
        assert ("abort", req.req_id) in srv.events
        _assert_no_leaks(srv)
        assert not srv.decode._landings and not srv.prefill._jobs
    finally:
        srv.shutdown()


def test_cancel_mid_shipping_discards_remaining_blocks(small_model):
    """Cancel after at least one block landed but before seating: already
    installed blocks are discarded with the landing, in-flight receives
    cancel atomically, and both pools drain to zero."""
    cfg, params = small_model
    srv = DisaggServer(cfg, params, max_batch=2, max_cache_len=64,
                       page_size=4, max_seq_len=48, chunk_pages=1)
    try:
        req = Request(list(range(1, 14)), 8)
        srv.submit(req)
        t0 = time.monotonic()
        while ("install", req.req_id, 0) not in srv.events:
            assert time.monotonic() - t0 < 60
            srv.step()
        assert ("seat", req.req_id) not in srv.events
        req.cancel()
        srv.close_intake()
        _drain(srv)
        assert req.req_state is RequestState.CANCELLED
        ingest = srv.decode.ingest_stats
        assert ingest["blocks_installed"] >= 1
        _assert_no_leaks(srv)
        assert not srv.decode._landings and not srv.prefill._jobs
        # no receive left dangling on the ingest CR
        assert srv.decode.cr_ingest.active_count == 0
    finally:
        srv.shutdown()


def test_cancel_while_queued_at_router(small_model):
    """A request cancelled before the prefill role ever activates it is
    dropped cleanly (the zero-shipped abort clears the decode role's
    expectation) and everything drains."""
    cfg, params = small_model
    srv = DisaggServer(cfg, params, max_batch=2, max_cache_len=64,
                       page_size=4, max_seq_len=48, prefill_jobs=1)
    try:
        live = Request(PROMPTS[0], 6)
        queued = Request(PROMPTS[1], 6)
        srv.submit(live)
        srv.submit(queued)
        queued.cancel()                   # before any step routes it
        srv.close_intake()
        srv.run(timeout=300)
        assert live.req_state is RequestState.FINISHED
        assert queued.req_state is RequestState.CANCELLED
        assert not srv.decode._expected
        _assert_no_leaks(srv)
    finally:
        srv.shutdown()


# ---------------------------------------------------------- backpressure
def test_decode_pool_backpressure_defers_landing(small_model):
    """A decode pool too small for two footprints at once: the second
    landing defers until the first retires, then completes — no deadlock,
    no leak, identical tokens."""
    cfg, params = small_model
    prompts = [PROMPTS[0], PROMPTS[1]]
    colo = _colocated(cfg, params, [Request(p, 6) for p in prompts])
    reqs = [Request(p, 6) for p in prompts]
    # each request needs pages_for(plen + 6, 4) <= 5 pages; give the
    # decode pool room for one footprint plus a page, not two
    srv = DisaggServer(cfg, params, max_batch=2, max_cache_len=64,
                       page_size=4, max_seq_len=48, total_pages=6)
    try:
        for r in reqs:
            srv.submit(r)
        srv.close_intake()
        srv.run(timeout=300)
        assert [r.tokens for r in reqs] == [r.tokens for r in colo]
        assert srv.decode.ingest_stats["landings_deferred"] >= 1
        _assert_no_leaks(srv)
    finally:
        srv.shutdown()


# ------------------------------------------------------- streaming front
def test_stream_client_over_disagg_server(small_model):
    """The ServeClient streaming front-end drives a DisaggServer through
    the same duck-typed surface as a colocated engine — per-token streams
    land identically."""
    cfg, params = small_model
    colo = _colocated(cfg, params, [Request(p, 8) for p in PROMPTS])
    baseline = [r.tokens for r in colo]
    srv = DisaggServer(cfg, params, max_batch=2, max_cache_len=64,
                       page_size=4, max_seq_len=48)
    with ServeClient(engine=srv) as client:
        session = client.session(max_tokens=8)
        streams = [session.generate(p) for p in PROMPTS]
        assert [list(s) for s in streams] == baseline
        for s in streams:
            assert s.reason == "finished"
        m = client.metrics()
        assert m["disaggregated"] is True
    _assert_no_leaks(srv)


def test_disagg_respects_request_deadline(small_model):
    """A request whose deadline already passed at routing expires without
    prefill compute or page allocation at either role."""
    cfg, params = small_model
    srv = DisaggServer(cfg, params, max_batch=2, max_cache_len=64,
                       page_size=4, max_seq_len=48)
    try:
        doomed = Request(PROMPTS[0],
                         GenerationConfig(max_tokens=6, deadline_s=1e-6),
                         arrival_time=time.monotonic() - 1.0)
        live = Request(PROMPTS[2], 6)
        srv.submit(doomed)
        srv.submit(live)
        srv.close_intake()
        srv.run(timeout=300)
        assert doomed.req_state is RequestState.EXPIRED
        assert live.req_state is RequestState.FINISHED
        assert srv.prefill.stats["jobs"] == 1       # doomed never started
        _assert_no_leaks(srv)
    finally:
        srv.shutdown()
