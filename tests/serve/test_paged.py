"""Paged KV cache: pool bookkeeping, paged-vs-dense token identity,
prefix reuse, oversubscription, and engine cancellation paths (no page
leaks on any exit path)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import lm
from repro.serve import (PagePool, Request, RequestState, ServeEngine,
                         greedy_generate, pages_for, serve_requests)


@pytest.fixture(scope="module")
def small_model():
    cfg = get_config("paper_demo", reduced=True)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _greedy(cfg, params, prompt, n, cache_len=64):
    return list(map(int, greedy_generate(cfg, params, prompt[None, :], n,
                                         max_cache_len=cache_len)[0]))


# ---------------------------------------------------------------- PagePool
def test_pool_alloc_release_refcount(small_model):
    cfg, _ = small_model
    pool = PagePool(cfg, total_pages=4, page_size=8)
    assert pool.pages_in_use == 0
    a = pool.alloc(3)
    assert len(a) == 3 and pool.pages_in_use == 3
    assert pool.alloc(2) is None           # only 1 left: all-or-nothing
    pool.retain(a[0])
    pool.release(a)                        # a[0] survives (ref 2 -> 1)
    assert pool.pages_in_use == 1
    pool.release([a[0]])
    assert pool.pages_in_use == 0
    assert pool.stats["peak_in_use"] == 3


def test_pool_prefix_match_caps_at_last_token(small_model):
    cfg, _ = small_model
    pool = PagePool(cfg, total_pages=8, page_size=4)
    prompt = list(range(100, 112))               # 12 tokens = 3 full pages
    table = pool.alloc(pages_for(12 + 4, 4))
    pool.register_prefix(prompt, table)
    # identical prompt: only 2 pages may match — the page holding the last
    # prompt token must be re-run to produce the first generated token
    assert pool.match_prefix(prompt) == table[:2]
    # longer prompt sharing the 12-token prefix matches all 3 full pages
    assert pool.match_prefix(prompt + [7]) == table[:3]
    # diverging second page matches only the first
    assert pool.match_prefix(prompt[:4] + [9] * 8) == table[:1]
    pool.release(table)
    assert pool.pages_in_use == 0
    assert pool.match_prefix(prompt) == []       # freed pages fell out


def test_pool_rejects_unsupported_family():
    cfg = get_config("mamba2_370m", reduced=True)
    with pytest.raises(ValueError, match="unsupported"):
        PagePool(cfg, total_pages=4, page_size=8)


# ------------------------------------------------- paged vs dense identity
def test_paged_matches_dense_multipage(small_model):
    """Cold-path paged decode is token-identical to the dense engine and
    the synchronous greedy loop across page boundaries."""
    cfg, params = small_model
    prompts = jax.random.randint(jax.random.PRNGKey(3), (3, 10), 0,
                                 cfg.vocab_size)
    lengths = [9, 14, 23]        # crosses several 8-token page boundaries
    base = [_greedy(cfg, params, prompts[i], lengths[i]) for i in range(3)]

    dense = serve_requests(cfg, params,
                           [Request(prompts[i], lengths[i]) for i in range(3)],
                           max_batch=2, max_cache_len=64, paged=False)
    paged = serve_requests(cfg, params,
                           [Request(prompts[i], lengths[i]) for i in range(3)],
                           max_batch=2, max_cache_len=64, paged=True,
                           page_size=8)
    assert [r.tokens for r in dense] == base
    assert [r.tokens for r in paged] == base


def test_paged_prefix_reuse_hits_and_matches_dense(small_model):
    """Requests sharing a page-aligned prompt prefix reuse resident pages
    (prefix_hits > 0) and still produce the dense-path tokens."""
    cfg, params = small_model
    common = jax.random.randint(jax.random.PRNGKey(5), (12,), 0,
                                cfg.vocab_size)
    tails = jax.random.randint(jax.random.PRNGKey(6), (3, 4), 0,
                               cfg.vocab_size)
    prompts = [jnp.concatenate([common, tails[i]]) for i in range(3)]
    base = [_greedy(cfg, params, p, 6) for p in prompts]

    eng = ServeEngine(cfg, params, max_batch=3, max_cache_len=64,
                      paged=True, page_size=8)
    try:
        reqs = [Request(p, 6) for p in prompts]
        for r in reqs:
            eng.submit(r)
        eng.close_intake()
        eng.run(timeout=300)
        assert [r.tokens for r in reqs] == base
        m = eng.metrics()
        # 16-token prompts, 8-token pages: page 0 is a full shared page
        assert m["prefix_hits"] == 2
        assert m["prefix_tokens_reused"] == 16
        assert reqs[0].shared_prefix_tokens == 0
        assert {r.shared_prefix_tokens for r in reqs[1:]} == {8}
        assert m["pages_in_use"] == 0          # everything released
    finally:
        eng.shutdown()


def test_paged_prefix_hit_with_unaligned_tail_matches_dense(small_model):
    """A prompt whose tail past the shared pages is not a page multiple
    exercises the padded suffix-prefill path and stays token-exact."""
    cfg, params = small_model
    common = jax.random.randint(jax.random.PRNGKey(8), (12,), 0,
                                cfg.vocab_size)
    tails = jax.random.randint(jax.random.PRNGKey(9), (2, 2), 0,
                               cfg.vocab_size)
    prompts = [jnp.concatenate([common, tails[i]]) for i in range(2)]  # 14
    base = [_greedy(cfg, params, p, 5) for p in prompts]
    eng = ServeEngine(cfg, params, max_batch=2, max_cache_len=64,
                      paged=True, page_size=8)
    try:
        reqs = [Request(p, 5) for p in prompts]
        for r in reqs:
            eng.submit(r)
        eng.close_intake()
        eng.run(timeout=300)
        assert [r.tokens for r in reqs] == base
        m = eng.metrics()
        assert m["prefix_hits"] == 1 and m["suffix_tokens"] == 6  # 14 - 8
    finally:
        eng.shutdown()


def test_requeue_does_not_resurrect_cancelled():
    """cancel() racing a capacity-deferred requeue must stay terminal."""
    req = Request([1, 2, 3], 4)
    req.on_admitted()
    assert req.cancel() is True
    req.on_requeued()                        # engine returning it to queue
    assert req.req_state is RequestState.CANCELLED


def test_paged_oversubscription_defers_and_completes(small_model):
    """A pool smaller than the worst case of the queue forces deferrals;
    every request still completes and no page leaks."""
    cfg, params = small_model
    prompts = jax.random.randint(jax.random.PRNGKey(7), (6, 6), 0,
                                 cfg.vocab_size)
    # 6 requests x 2 pages each = 12 pages worst case, pool holds 5
    eng = ServeEngine(cfg, params, max_batch=4, max_cache_len=64,
                      paged=True, page_size=8, max_seq_len=16,
                      total_pages=5)
    try:
        reqs = [Request(prompts[i], 8) for i in range(6)]
        for r in reqs:
            eng.submit(r)
        eng.close_intake()
        eng.run(timeout=300)
        assert all(len(r.tokens) == 8 for r in reqs)
        m = eng.metrics()
        assert m["deferred"] > 0
        assert m["pages_in_use"] == 0
        assert m["peak_in_use"] <= 5
    finally:
        eng.shutdown()


def test_paged_submit_validates_footprint(small_model):
    cfg, params = small_model
    eng = ServeEngine(cfg, params, max_batch=2, max_cache_len=16,
                      paged=True, page_size=8)
    try:
        with pytest.raises(ValueError, match="max_seq_len"):
            eng.submit(Request(list(range(10)), 10))   # 20 > 16
    finally:
        eng.shutdown()


# ------------------------------------------------------ cancellation paths
def test_cancel_while_queued_drops_without_pages(small_model):
    cfg, params = small_model
    eng = ServeEngine(cfg, params, max_batch=2, max_cache_len=32,
                      paged=True, page_size=8)
    try:
        keep = Request(jnp.arange(4), 3)
        gone = Request(jnp.arange(4) + 1, 3)
        eng.submit(keep)
        eng.submit(gone)
        gone.cancel()
        eng.close_intake()
        eng.run(timeout=300)
        assert keep.req_state is RequestState.FINISHED
        assert gone.req_state is RequestState.CANCELLED
        assert eng.batcher.stats["dropped_cancelled"] == 1
        assert eng.metrics()["pages_in_use"] == 0
    finally:
        eng.shutdown()


def test_cancel_while_decoding_frees_slot_and_pages(small_model):
    cfg, params = small_model
    eng = ServeEngine(cfg, params, max_batch=2, max_cache_len=32,
                      paged=True, page_size=8)
    try:
        victim = Request(jnp.arange(4), 20)
        other = Request(jnp.arange(4) + 2, 6)
        eng.submit(victim)
        eng.submit(other)
        eng.close_intake()
        eng.run(until=lambda: victim.generated >= 2, timeout=300)
        assert victim.req_state is RequestState.DECODING
        assert victim.page_ids                   # holding pages mid-decode
        victim.cancel()
        eng.run(timeout=300)                     # drains the rest
        assert other.req_state is RequestState.FINISHED
        assert len(other.tokens) == 6
        assert eng.stats["cancelled"] >= 1
        assert victim.page_ids == []
        assert eng.metrics()["pages_in_use"] == 0
    finally:
        eng.shutdown()


def test_cancel_while_draining_still_releases_pages(small_model):
    """Cancel in the window between the final dispatched step and its
    completion continuation (white-box): the retirement continuation must
    still return the pages even though the request never retires."""
    cfg, params = small_model
    eng = ServeEngine(cfg, params, max_batch=1, max_cache_len=32,
                      paged=True, page_size=8)
    try:
        req = Request(jnp.arange(4), 2)
        eng.submit(req)
        eng.close_intake()
        eng._admit()
        assert eng._dispatch_step()              # generates the 2nd (last)
        assert eng._draining                     # budget met, step in flight
        assert req.cancel() is True
        eng.run(timeout=300)                     # fires _on_step_done
        assert req.req_state is RequestState.CANCELLED
        assert eng.stats["retired"] == 0
        assert req.page_ids == []
        assert eng.metrics()["pages_in_use"] == 0
    finally:
        eng.shutdown()


def test_submit_after_close_is_refused_and_counted(small_model):
    cfg, params = small_model
    eng = ServeEngine(cfg, params, max_batch=1, max_cache_len=32)
    try:
        eng.close_intake()
        with pytest.raises(RuntimeError, match="closed"):
            eng.submit(Request(jnp.arange(4), 2))
        assert eng.batcher.stats["refused_closed"] == 1
    finally:
        eng.shutdown()
