"""The formal engine protocol and the typed metrics mapping:
``EngineLike`` isinstance over all three tiers (colocated, disagg,
router), ``ServeClient`` binding to each, ``ServeMetrics`` typed fields,
Mapping semantics, and deprecated legacy-alias resolution."""
import warnings

import jax
import pytest

from repro.configs import get_config
from repro.core import Engine
from repro.models import lm
from repro.serve import (DisaggServer, EngineLike, GenerationConfig,
                         Router, ServeClient, ServeEngine, ServeMetrics)


@pytest.fixture(scope="module")
def small_model():
    cfg = get_config("paper_demo", reduced=True)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


KW = dict(max_batch=2, max_cache_len=64, page_size=4, max_seq_len=48)


# ------------------------------------------------------------- protocol
def test_all_three_tiers_satisfy_enginelike(small_model):
    cfg, params = small_model
    tiers = [ServeEngine(cfg, params, paged=True, **KW),
             DisaggServer(cfg, params, **KW),
             Router(cfg, params, n_replicas=2, paged=True, **KW)]
    for tier in tiers:
        assert isinstance(tier, EngineLike), type(tier).__name__
        tier.shutdown()


def test_non_engines_fail_the_protocol():
    class Half:
        def submit(self, request):
            return request

    assert not isinstance(object(), EngineLike)
    assert not isinstance(Half(), EngineLike)


def test_serve_client_rejects_non_engine():
    with pytest.raises(TypeError, match="EngineLike"):
        ServeClient(engine=object())


def test_client_binds_to_every_tier(small_model):
    """One ServeClient, three backends — the streaming front-end runs
    over each tier unchanged and yields identical greedy tokens."""
    cfg, params = small_model
    prompt = list(range(1, 10))
    results = {}
    for name, make in [
            ("colocated", lambda: ServeEngine(cfg, params, paged=True,
                                              **KW)),
            ("disagg", lambda: DisaggServer(cfg, params, **KW)),
            ("router", lambda: Router(cfg, params, n_replicas=2,
                                      paged=True, **KW))]:
        with ServeClient(engine=make()) as client:
            stream = client.generate(prompt,
                                     GenerationConfig(max_tokens=6))
            results[name] = list(stream)
    assert results["colocated"] == results["disagg"] == results["router"]
    assert len(results["colocated"]) == 6


# -------------------------------------------------------------- metrics
def test_serve_metrics_typed_fields_and_mapping():
    m = ServeMetrics.from_flat({"finished": 3, "total_tokens": 24,
                                "pages_in_use": 0, "total_pages": 16,
                                "custom_counter": 7})
    assert m.finished == 3 and m["finished"] == 3
    assert m["custom_counter"] == 7          # untyped keys ride `extra`
    assert "custom_counter" in m and "nope" not in m
    d = m.as_dict()
    assert d["total_tokens"] == 24 and d["custom_counter"] == 7
    assert len(m) == len(d)
    assert dict(m) == d                      # Mapping protocol


def test_serve_metrics_legacy_aliases_warn():
    m = ServeMetrics.from_flat({"pages_in_use": 2, "total_pages": 8,
                                "page_size": 4})
    with pytest.deprecated_call():
        assert m["pool_pages_in_use"] == 2
    with pytest.deprecated_call():
        assert m["pool_total_pages"] == 8
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        assert m["pages_in_use"] == 2        # canonical key: no warning


def test_every_tier_returns_serve_metrics(small_model):
    cfg, params = small_model
    engine = Engine()
    tiers = [ServeEngine(cfg, params, paged=True, engine=engine, **KW),
             DisaggServer(cfg, params, **KW)]
    for tier in tiers:
        m = tier.metrics()
        assert isinstance(m, ServeMetrics)
        assert m["finished"] == 0
        tier.shutdown()


def test_metrics_reject_unknown_key():
    m = ServeMetrics.from_flat({"finished": 1})
    with pytest.raises(KeyError):
        m["no_such_metric"]


def test_tenant_config_validation():
    cfg = GenerationConfig(max_tokens=4, tenant="acme")
    assert cfg.tenant == "acme"
    assert GenerationConfig(max_tokens=4).tenant == "default"
    with pytest.raises(ValueError):
        GenerationConfig(max_tokens=4, tenant="")
    with pytest.raises(ValueError):
        GenerationConfig(max_tokens=4, tenant=123)
