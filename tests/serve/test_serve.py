"""Serving subsystem tests: request lifecycle, batcher admission CR
semantics, and the continuous-batching engine end-to-end vs the
synchronous ``greedy_generate`` baseline (token-exact)."""
import threading
import time

import jax
import pytest

from repro.core import Engine
from repro.serve import (Batcher, Request, RequestState, ServeEngine,
                         greedy_generate, serve_requests, summarize)


# ------------------------------------------------------------- request
def test_request_lifecycle_and_timing():
    req = Request([1, 2, 3], 4)
    assert req.req_state is RequestState.QUEUED
    assert req.remaining == 4
    req.on_admitted()
    assert req.req_state is RequestState.PREFILLING
    req.push_device_token(7)
    req.on_first_token()
    assert req.req_state is RequestState.DECODING
    assert req.ttft is not None and req.ttft >= 0
    for t in (8, 9, 10):
        req.push_device_token(t)
    assert req.remaining == 0
    req.retire()
    assert req.req_state is RequestState.FINISHED
    assert req.tokens == [7, 8, 9, 10]
    assert req.wait(timeout=0.1)
    assert req.latency is not None


def test_request_is_completable():
    """A Request is an op: continuations attach to its completion."""
    eng = Engine()
    try:
        cr = eng.continue_init()
        req = Request([1], 1)
        seen = []
        flag = eng.continue_when(req, lambda st, d: seen.append(st[0].payload),
                                 status=[None], cr=cr)
        assert flag is False
        req.push_device_token(5)
        req.retire()
        assert seen == [[5]]
        assert cr.test() is True
    finally:
        eng.shutdown()


def test_request_cancel():
    req = Request([1], 3)
    assert req.cancel() is True
    assert req.req_state is RequestState.CANCELLED
    assert req.cancel() is False
    done = Request([1], 1)
    done.push_device_token(1)
    done.retire()
    assert done.cancel() is False
    assert done.req_state is RequestState.FINISHED


def test_request_validates_budget():
    with pytest.raises(ValueError):
        Request([1], 0)


# ------------------------------------------------------------- batcher
def test_batcher_defers_admission_to_loop():
    """Submissions must not run callbacks on the submitting thread — they
    queue on the poll_only CR until admit() (the paper's burst pattern)."""
    eng = Engine()
    try:
        b = Batcher(eng)
        reqs = [b.submit(Request([i], 2)) for i in range(3)]
        assert b.queued == 0             # nothing transferred yet
        assert b.cr.active_count == 3    # parked on the CR
        eng.tick()                       # generic progress must NOT admit
        assert b.queued == 0
        got = b.admit(2)
        assert [r.req_id for r in got] == [reqs[0].req_id, reqs[1].req_id]
        assert all(r.req_state is RequestState.PREFILLING for r in got)
        assert b.queued == 1             # third transferred, not admitted
        assert b.admit(5) == [reqs[2]]
    finally:
        eng.shutdown()


def test_batcher_submit_from_other_threads():
    eng = Engine()
    try:
        b = Batcher(eng)
        n = 40
        threads = [threading.Thread(
            target=lambda i=i: b.submit(Request([i], 1)))
            for i in range(n)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        got = b.admit(n)
        assert len(got) == n
        assert b.drained is False        # not closed yet
        b.close()
        assert b.drained is True
        with pytest.raises(RuntimeError, match="closed"):
            b.submit(Request([0], 1))
    finally:
        eng.shutdown()


def test_batcher_drops_cancelled_before_admit():
    eng = Engine()
    try:
        b = Batcher(eng)
        r1, r2 = Request([1], 2), Request([2], 2)
        b.submit(r1)
        b.submit(r2)
        r1.cancel()
        got = b.admit(5)
        assert got == [r2]
        assert b.stats["dropped_cancelled"] == 1
    finally:
        eng.shutdown()


# ------------------------------------------------- engine (end-to-end)
@pytest.fixture(scope="module")
def small_model():
    from repro.configs import get_config
    from repro.models import lm
    cfg = get_config("paper_demo", reduced=True)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    prompts = jax.random.randint(jax.random.PRNGKey(1), (4, 6), 0,
                                 cfg.vocab_size)
    return cfg, params, prompts


def test_serve_matches_greedy_baseline(small_model):
    cfg, params, prompts = small_model
    base = [list(map(int, greedy_generate(cfg, params, prompts[i:i + 1], 5,
                                          max_cache_len=16)[0]))
            for i in range(3)]
    reqs = serve_requests(cfg, params,
                          [Request(prompts[i], 5) for i in range(3)],
                          max_batch=2, max_cache_len=16, timeout=300)
    assert all(r.req_state is RequestState.FINISHED for r in reqs)
    assert [r.tokens for r in reqs] == base


def test_serve_heterogeneous_lengths_and_slot_reuse(small_model):
    cfg, params, prompts = small_model
    lengths = [1, 7, 3, 5]
    reqs = [Request(prompts[i], lengths[i]) for i in range(4)]
    eng = ServeEngine(cfg, params, max_batch=2, max_cache_len=16)
    try:
        for r in reqs:
            eng.submit(r)
        eng.close_intake()
        eng.run(timeout=300)
        assert [len(r.tokens) for r in reqs] == lengths
        assert eng.stats["retired"] == 4
        # 4 requests through 2 slots => slots were reused
        assert eng.stats["prefills"] == 4
        m = summarize(reqs)
        assert m["finished"] == 4
        assert m["total_tokens"] == sum(lengths)
        assert m["ttft_p99"] >= m["ttft_p50"] >= 0
    finally:
        eng.shutdown()


def test_serve_overlapped_submission_thread(small_model):
    """Requests arriving mid-decode are admitted without restarting the
    loop (prefill overlaps in-flight decode)."""
    cfg, params, prompts = small_model
    eng = ServeEngine(cfg, params, max_batch=2, max_cache_len=16,
                      scheduler="affinity")
    try:
        first = Request(prompts[0], 6)
        late = [Request(prompts[i], 3) for i in (1, 2)]
        eng.submit(first)

        def straggler():
            time.sleep(0.02)
            for r in late:
                eng.submit(r)
            eng.close_intake()

        t = threading.Thread(target=straggler)
        t.start()
        eng.run(timeout=300)
        t.join()
        assert len(first.tokens) == 6
        assert all(len(r.tokens) == 3 for r in late)
    finally:
        eng.shutdown()


def test_serve_single_token_requests_skip_slots(small_model):
    """max_new_tokens=1 is answered by prefill alone."""
    cfg, params, prompts = small_model
    base = list(map(int, greedy_generate(cfg, params, prompts[:1], 1,
                                         max_cache_len=16)[0]))
    reqs = serve_requests(cfg, params, [Request(prompts[0], 1)],
                          max_batch=2, max_cache_len=16, timeout=300)
    assert reqs[0].tokens == base


def test_submit_async_awaitable(small_model):
    """The promise front-end over serving: submit_async returns an
    awaitable that resolves with the token list at retirement, while the
    decode loop runs on its own thread."""
    import asyncio
    cfg, params, prompts = small_model
    eng = ServeEngine(cfg, params, max_batch=2, max_cache_len=32,
                      paged=False)
    try:
        async def main():
            reqs = [Request(prompts[i], 3 + i) for i in range(2)]
            proms = [eng.submit_async(r) for r in reqs]
            eng.close_intake()
            loop = threading.Thread(target=lambda: eng.run(timeout=300))
            loop.start()
            toks = await asyncio.gather(*proms)
            loop.join()
            return reqs, toks

        reqs, toks = asyncio.run(main())
        for i, (r, t) in enumerate(zip(reqs, toks)):
            assert t == r.tokens
            assert len(t) == 3 + i
    finally:
        eng.shutdown()


def test_submit_async_cancel_rejects(small_model):
    """promise.cancel() cancels the underlying request; the awaitable
    rejects with PromiseCancelled."""
    import asyncio
    from repro.core import PromiseCancelled
    cfg, params, prompts = small_model
    eng = ServeEngine(cfg, params, max_batch=2, max_cache_len=32,
                      paged=False)
    try:
        async def main():
            req = Request(prompts[0], 50)
            prom = eng.submit_async(req)
            prom.cancel()
            eng.close_intake()
            loop = threading.Thread(target=lambda: eng.run(timeout=300))
            loop.start()
            with pytest.raises(PromiseCancelled):
                await prom
            loop.join()
            return req

        req = asyncio.run(main())
        assert req.req_state is RequestState.CANCELLED
    finally:
        eng.shutdown()
