"""Speculative decoding: drafter units, write-table rollback mapping,
token identity with non-speculative greedy decode (random and
repetition-friendly workloads, mixed per-request accept lengths in one
batch, capacity-deferral/eviction, cancellation mid-verify), and
kv-page leak checks on every exit path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import lm
from repro.serve import (GenerationConfig, NgramDrafter, PagePool,
                         RepeatDrafter, Request, RequestState, ServeEngine,
                         greedy_generate, serve_requests)
from repro.serve.steps import make_decode_step, make_prefill_step


@pytest.fixture(scope="module")
def small_model():
    cfg = get_config("paper_demo", reduced=True)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


@pytest.fixture(scope="module")
def greedy_ref(small_model):
    """Greedy reference decoder with jits built once for the module
    (``greedy_generate`` re-jits per call, which dominates test time)."""
    cfg, params = small_model
    prefill = jax.jit(make_prefill_step(cfg, 64))
    decode = jax.jit(make_decode_step(cfg), donate_argnums=(1,))

    def ref(prompt, n):
        prompt = jnp.asarray(prompt, jnp.int32)[None, :]
        logits, cache = prefill(params, {"tokens": prompt})
        pos = prompt.shape[1]
        out = [jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)]
        for i in range(n - 1):
            logits, cache = decode(params, cache, out[-1][:, None],
                                   jnp.int32(pos + i))
            out.append(jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32))
        return [int(t[0]) for t in out]
    return ref


def _repetitive_prompts(n, plen=16):
    motif = np.array([5, 11, 3, 7])
    return [np.tile(np.roll(motif, i % 4), plen // 4) for i in range(n)]


# ----------------------------------------------------------------- drafter
def test_ngram_drafter_prefers_long_and_recent_matches():
    d = NgramDrafter(max_ngram=3)
    # trailing (8, 9) occurred twice; the most recent occurrence (idx 5)
    # is followed by 1, 2 — not the older continuation 7
    ctx = [8, 9, 7, 0, 4, 8, 9, 1, 2, 8, 9]
    assert d.draft(ctx, 2) == [1, 2]
    # a longer n-gram match beats a shorter one: trailing (4, 8, 9)
    # matched at idx 4 → continuation differs from the bigram match
    ctx3 = [4, 8, 9, 6, 4, 8, 9, 5, 1, 4, 8, 9]
    assert d.draft(ctx3, 2) == [5, 1]
    assert d.draft(ctx3, 5) == [5, 1, 4, 8, 9]   # truncated at k/available


def test_ngram_drafter_no_match_and_edge_cases():
    d = NgramDrafter(max_ngram=3)
    assert d.draft([1, 2, 3, 4], 3) == []        # no repeats anywhere
    assert d.draft([], 3) == []
    assert d.draft([7], 3) == []
    assert d.draft([1, 2, 1, 2], 0) == []
    assert d.draft([3, 3, 3, 3], 2) == [3, 3]    # constant run
    with pytest.raises(ValueError):
        NgramDrafter(max_ngram=0)


def test_repeat_drafter_protocol():
    d = RepeatDrafter()
    assert d.draft([4, 9], 3) == [9, 9, 9]
    assert d.draft([], 3) == []


# ------------------------------------------------------- write-table unit
def test_pool_write_table_maps_owned_and_rolls_back(small_model):
    cfg, _ = small_model
    pool = PagePool(cfg, total_pages=8, page_size=4)
    pages = pool.alloc(3)
    # write window starting at pos 5 spans pages 1.. of the table
    wt = pool.write_table(pages, pos=5, width=3)
    assert list(wt) == [pages[1], pages[2], pool.null_page]
    # near the end of the footprint: out-of-footprint entries are nulled
    # (the rollback half: past-budget speculative writes hit scratch)
    wt = pool.write_table(pages, pos=11, width=3)
    assert list(wt) == [pages[2], pool.null_page, pool.null_page]
    pool.release(pages)
    assert pool.pages_in_use == 0


# ------------------------------------------------------- engine validation
def test_speculate_requires_paged(small_model):
    cfg, params = small_model
    with pytest.raises(ValueError, match="paged"):
        ServeEngine(cfg, params, paged=False, speculate=4)
    with pytest.raises(ValueError):
        Request([1, 2], GenerationConfig(max_tokens=4, speculate=-1))


# -------------------------------------------------------- token identity
@pytest.fixture(scope="module")
def spec_engine(small_model):
    cfg, params = small_model
    eng = ServeEngine(cfg, params, max_batch=3, max_cache_len=64,
                      paged=True, page_size=8, max_seq_len=64, speculate=3)
    yield eng
    eng.shutdown()


def _serve(eng, reqs):
    done = eng.stats["retired"] + eng.stats["cancelled"]
    target = done + len(reqs)
    for r in reqs:
        eng.submit(r)
    eng.run(until=lambda: (eng.stats["retired"]
                           + eng.stats["cancelled"]) >= target,
            timeout=300)
    return reqs


def test_spec_matches_greedy_on_random_prompts(spec_engine, greedy_ref,
                                               small_model):
    """Random prompts barely accept — identity must hold regardless."""
    cfg, _ = small_model
    prompts = jax.random.randint(jax.random.PRNGKey(3), (3, 12), 0,
                                 cfg.vocab_size)
    lengths = [9, 14, 23]                     # crosses page boundaries
    base = [greedy_ref(prompts[i], lengths[i]) for i in range(3)]
    reqs = _serve(spec_engine,
                  [Request(prompts[i], lengths[i]) for i in range(3)])
    assert [r.tokens for r in reqs] == base
    assert spec_engine.metrics()["pages_in_use"] == 0


def test_spec_repetitive_accepts_and_matches(spec_engine, greedy_ref):
    """Repetition-friendly workload: drafts accept (>0) and the emitted
    stream is still exactly the greedy one."""
    prompts = _repetitive_prompts(3)
    base = [greedy_ref(p, 30) for p in prompts]
    reqs = _serve(spec_engine, [Request(p, 30) for p in prompts])
    assert [r.tokens for r in reqs] == base
    m = spec_engine.metrics()
    assert m["draft_accepted"] > 0
    assert m["verify_steps"] > 0
    assert any(r.accept_rate and r.accept_rate > 0 for r in reqs)
    assert m["pages_in_use"] == 0


def test_spec_mixed_accept_lengths_in_one_batch(spec_engine, greedy_ref,
                                                small_model):
    """One batch mixing speculate=0 (never proposes), speculate=1
    (capped), and engine-default requests, with different lengths —
    slots advance by different amounts per verify step and every stream
    stays token-exact."""
    cfg, _ = small_model
    rep = _repetitive_prompts(2)
    rand = np.asarray(jax.random.randint(jax.random.PRNGKey(9), (12,), 0,
                                         cfg.vocab_size))
    specs = [0, 1, None]
    prompts = [rep[0], rep[1], rand]
    lengths = [18, 25, 11]
    base = [greedy_ref(p, n) for p, n in zip(prompts, lengths)]
    reqs = _serve(spec_engine,
                  [Request(p, GenerationConfig(max_tokens=n, speculate=s))
                   for p, n, s in zip(prompts, lengths, specs)])
    assert [r.tokens for r in reqs] == base
    assert reqs[0].draft_tokens_proposed == 0    # opted out
    assert spec_engine.batcher.stats["submitted_speculative"] >= 1
    assert spec_engine.metrics()["pages_in_use"] == 0


def test_spec_slot_reuse_more_requests_than_slots(spec_engine, greedy_ref):
    """6 requests through 3 slots: retirement mid-verify frees slots for
    queued requests; identity holds across the reuse boundary."""
    prompts = _repetitive_prompts(6)
    lengths = [7, 12, 19, 4, 26, 9]
    base = [greedy_ref(p, n) for p, n in zip(prompts, lengths)]
    reqs = _serve(spec_engine,
                  [Request(p, n) for p, n in zip(prompts, lengths)])
    assert [r.tokens for r in reqs] == base
    assert spec_engine.metrics()["pages_in_use"] == 0


# ------------------------------------------- cancellation / deferral paths
def test_spec_cancel_mid_verify_releases_pages(small_model):
    """Cancel in the window between verify dispatch and its continuation
    (white-box): the continuation must evict without emitting, and the
    pages must come back."""
    cfg, params = small_model
    eng = ServeEngine(cfg, params, max_batch=1, max_cache_len=32,
                      paged=True, page_size=8, speculate=2)
    try:
        req = Request(np.arange(4), 10)
        eng.submit(req)
        eng.close_intake()
        eng._admit()
        assert eng._dispatch_step()             # one verify in flight
        assert eng._verifying == {0}
        n_before = req.generated
        assert req.cancel() is True
        eng.run(timeout=300)                    # fires _on_verify_done
        assert req.req_state is RequestState.CANCELLED
        assert req.generated == n_before        # nothing emitted post-cancel
        assert eng.stats["retired"] == 0
        assert eng.stats["cancelled"] >= 1
        assert req.page_ids == []
        assert eng.metrics()["pages_in_use"] == 0
    finally:
        eng.shutdown()


def test_spec_cancel_while_decoding(small_model):
    cfg, params = small_model
    eng = ServeEngine(cfg, params, max_batch=2, max_cache_len=32,
                      paged=True, page_size=8, speculate=2)
    try:
        victim = Request(_repetitive_prompts(1, plen=8)[0], 20)
        other = Request(np.arange(8) + 40, 6)
        eng.submit(victim)
        eng.submit(other)
        eng.close_intake()
        eng.run(until=lambda: victim.generated >= 2, timeout=300)
        victim.cancel()
        eng.run(timeout=300)
        assert other.req_state is RequestState.FINISHED
        assert len(other.tokens) == 6
        assert victim.page_ids == []
        assert eng.metrics()["pages_in_use"] == 0
    finally:
        eng.shutdown()


def test_spec_oversubscription_defers_and_stays_exact(small_model,
                                                      greedy_ref):
    """Pool smaller than the queue's worst case: capacity deferrals evict
    admissions back to the queue; all requests complete token-exact with
    no page leak even though verify steps write past-budget lanes into
    the scratch page."""
    cfg, params = small_model
    prompts = _repetitive_prompts(5, plen=8)
    base = [greedy_ref(p, 8) for p in prompts]
    eng = ServeEngine(cfg, params, max_batch=3, max_cache_len=64,
                      paged=True, page_size=8, max_seq_len=16,
                      total_pages=4, speculate=3)
    try:
        reqs = [Request(p, 8) for p in prompts]
        for r in reqs:
            eng.submit(r)
        eng.close_intake()
        eng.run(timeout=300)
        assert [r.tokens for r in reqs] == base
        m = eng.metrics()
        assert m["deferred"] > 0
        assert m["pages_in_use"] == 0
        assert m["peak_in_use"] <= 4
    finally:
        eng.shutdown()


def test_spec_near_budget_padding_writes_hit_scratch(small_model,
                                                     greedy_ref):
    """max_new smaller than K: every verify step runs with a clamped (or
    zero) draft window and the K+1-token write lane spills past the
    request footprint into the scratch page — identity and no leak."""
    cfg, params = small_model
    prompt = _repetitive_prompts(1, plen=8)[0]
    base = greedy_ref(prompt, 2)
    eng = ServeEngine(cfg, params, max_batch=1, max_cache_len=32,
                      paged=True, page_size=8, max_seq_len=16,
                      speculate=3)
    try:
        req = Request(prompt, 2)
        eng.submit(req)
        eng.close_intake()
        eng.run(timeout=300)
        assert req.tokens == base
        assert req.draft_tokens_proposed == 0   # k capped at remaining-1
        assert eng.metrics()["pages_in_use"] == 0
    finally:
        eng.shutdown()


# ------------------------------------------------------------ property test
@pytest.mark.parametrize("seed", range(4))
def test_spec_identity_property(spec_engine, greedy_ref, small_model, seed):
    """Randomized identity sweep: random prompts/lengths/knobs per seed,
    batched through the shared engine — every stream must equal greedy
    and the pool must drain. (Deterministic seeds rather than hypothesis:
    each example costs a model run, and shrinking re-runs are wasted
    here — any failure is already minimal: one prompt, one knob.)"""
    cfg, _ = small_model
    rng = np.random.RandomState(seed)
    n = int(rng.randint(1, 4))
    prompts = []
    for _ in range(n):
        if rng.rand() < 0.5:        # repetition-friendly half the time
            motif = rng.randint(0, cfg.vocab_size, size=rng.randint(1, 5))
            p = np.tile(motif, -(-12 // len(motif)))[:12]
        else:
            p = rng.randint(0, cfg.vocab_size, size=12)
        prompts.append(p.astype(np.int32))
    lengths = [int(rng.randint(2, 28)) for _ in range(n)]
    knobs = [rng.choice([0, 1, 2, 3, None]) for _ in range(n)]
    base = [greedy_ref(p, ln) for p, ln in zip(prompts, lengths)]
    reqs = _serve(spec_engine,
                  [Request(p, GenerationConfig(
                      max_tokens=ln,
                      speculate=None if k is None else int(k)))
                   for p, ln, k in zip(prompts, lengths, knobs)])
    assert [r.tokens for r in reqs] == base
    assert spec_engine.metrics()["pages_in_use"] == 0
