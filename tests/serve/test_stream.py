"""Streaming session API tests.

Covers the ISSUE-5 surface end to end: ``GenerationConfig`` validation
and the deprecated ``Request`` kwarg shims, sync + asyncio token
streams that are token-identical to retirement delivery (greedy and
speculative), first-token-before-retirement, slow-consumer backpressure
that never blocks the decode loop, stop-sequence truncation identity,
deadline expiry releasing pages in the completion continuation,
priority ordering under oversubscription, and cancel-mid-stream (incl.
mid-speculative-verify) with page-leak checks.
"""
import asyncio
import threading
import time

import jax
import pytest

from repro.core import Engine, PromiseCancelled
from repro.serve import (Batcher, DeadlineExceeded, GenerationConfig,
                         Request, RequestState, ServeClient, ServeEngine,
                         TokenStream, serve_requests)


# ------------------------------------------------------ GenerationConfig
def test_generation_config_validation():
    cfg = GenerationConfig(max_tokens=4, stop=[[1, 2]], priority=3,
                           deadline_s=1.5, stream_buffer=8)
    assert cfg.stop == ((1, 2),)
    with pytest.raises(ValueError, match="max_tokens"):
        GenerationConfig(max_tokens=0)
    with pytest.raises(ValueError, match="speculate"):
        GenerationConfig(max_tokens=1, speculate=-1)
    with pytest.raises(ValueError, match="greedy"):
        GenerationConfig(max_tokens=1, temperature=0.7)
    with pytest.raises(ValueError, match="stop"):
        GenerationConfig(max_tokens=1, stop=[[]])
    with pytest.raises(ValueError, match="stop"):
        GenerationConfig(max_tokens=1, stop=7)
    with pytest.raises(ValueError, match="deadline_s"):
        GenerationConfig(max_tokens=1, deadline_s=0.0)
    with pytest.raises(ValueError, match="stream_buffer"):
        GenerationConfig(max_tokens=1, stream_buffer=0)


def test_generation_config_merged_revalidates():
    cfg = GenerationConfig(max_tokens=4)
    assert cfg.merged(priority=2).priority == 2
    assert cfg.merged(priority=2).max_tokens == 4  # original preserved
    assert cfg.priority == 0                       # frozen: no mutation
    with pytest.raises(ValueError):
        cfg.merged(max_tokens=-1)


# ------------------------------------------------- deprecated kwarg shims
def test_request_deprecated_kwargs_still_work():
    with pytest.warns(DeprecationWarning, match="max_new_tokens"):
        old = Request([1, 2], max_new_tokens=5)
    assert old.config.max_tokens == 5 and old.max_new_tokens == 5
    with pytest.warns(DeprecationWarning, match="speculate"):
        old2 = Request([1, 2], 5, speculate=2)
    assert old2.config.speculate == 2 and old2.speculate == 2
    with pytest.warns(DeprecationWarning):
        with pytest.raises(ValueError):
            Request([1, 2], 4, speculate=-1)   # shimmed but still validated
    # canonical forms emit no warning
    import warnings as _w
    with _w.catch_warnings():
        _w.simplefilter("error")
        assert Request([1], 3).config.max_tokens == 3
        assert Request([1], GenerationConfig(max_tokens=3,
                                             speculate=1)).speculate == 1
    with pytest.raises(ValueError, match="not both"):
        Request([1], 3, max_new_tokens=4)
    with pytest.raises(ValueError):
        Request([1])                           # no budget at all


# ----------------------------------------------------- batcher QoS order
def test_batcher_priority_order_and_arrival_within_class():
    eng = Engine()
    try:
        b = Batcher(eng)
        reqs = [b.submit(Request([i], GenerationConfig(max_tokens=2,
                                                       priority=p)))
                for i, p in enumerate([0, 5, 1, 5])]
        got = b.admit(10)
        # strict priority, arrival order within a class
        assert got == [reqs[1], reqs[3], reqs[2], reqs[0]]
    finally:
        eng.shutdown()


def test_batcher_requeue_heads_priority_class():
    eng = Engine()
    try:
        b = Batcher(eng)
        r_hi = b.submit(Request([0], GenerationConfig(max_tokens=2,
                                                      priority=1)))
        r_a = b.submit(Request([1], 2))
        r_b = b.submit(Request([2], 2))
        got = b.admit(10)
        assert got == [r_hi, r_a, r_b]
        b.requeue(r_b)
        b.requeue(r_a)       # engine requeues in reverse, head-first
        assert r_a.req_state is RequestState.QUEUED
        assert b.admit(10) == [r_a, r_b]
    finally:
        eng.shutdown()


def test_batcher_refuses_past_deadline_queued():
    eng = Engine()
    try:
        b = Batcher(eng)
        doomed = b.submit(Request([1], GenerationConfig(max_tokens=2,
                                                        deadline_s=0.01)))
        ok = b.submit(Request([2], 2))
        time.sleep(0.03)
        assert b.admit(10) == [ok]
        assert b.stats["expired_queued"] == 1
        assert doomed.req_state is RequestState.EXPIRED
        assert doomed.wait(timeout=1.0)
        with pytest.raises(DeadlineExceeded):
            doomed.status.raise_for_error()
    finally:
        eng.shutdown()


# ---------------------------------------------------- streaming end-to-end
@pytest.fixture(scope="module")
def small_model():
    from repro.configs import get_config
    from repro.models import lm
    cfg = get_config("paper_demo", reduced=True)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    prompts = jax.random.randint(jax.random.PRNGKey(1), (4, 6), 0,
                                 cfg.vocab_size)
    return cfg, params, prompts


@pytest.fixture(scope="module")
def baseline(small_model):
    """Retirement-delivery tokens for the shared prompts (the identity
    reference for every streaming test)."""
    cfg, params, prompts = small_model
    reqs = serve_requests(cfg, params,
                          [Request(prompts[i], 8) for i in range(4)],
                          max_batch=2, max_cache_len=16, timeout=300)
    return [r.tokens for r in reqs]


def test_stream_tokens_identical_and_first_token_before_retirement(
        small_model, baseline):
    cfg, params, prompts = small_model
    with ServeClient(cfg, params, max_batch=2, max_cache_len=16) as client:
        session = client.session(max_tokens=8)
        streams = [session.generate(prompts[i]) for i in range(4)]
        out = [list(s) for s in streams]
        assert out == baseline
        for s in streams:
            assert s.reason == "finished"
            assert s.request.req_state is RequestState.FINISHED
            # TTFT claim: the first token was published strictly before
            # the request finished (multi-token request => earlier step)
            assert s.first_token_time < s.request.finish_time
        assert client.metrics()["pages_in_use"] == 0


def test_stream_async_consumers_and_text(small_model, baseline):
    cfg, params, prompts = small_model
    with ServeClient(cfg, params, max_batch=2, max_cache_len=16) as client:
        session = client.session(max_tokens=8)

        async def consume(i):
            return [t async for t in session.generate(prompts[i])]

        async def main():
            toks = await asyncio.gather(*(consume(i) for i in range(3)))
            text = await session.generate(prompts[3]).text()
            return toks, text

        toks, text = asyncio.run(main())
        assert toks == baseline[:3]
        assert text == " ".join(str(t) for t in baseline[3])


def test_stream_speculative_identity(small_model, baseline):
    """Streaming through the verify path (accept runs deliver in bursts)
    is token-identical to plain greedy retirement delivery."""
    cfg, params, prompts = small_model
    eng = ServeEngine(cfg, params, max_batch=2, max_cache_len=32,
                      paged=True, page_size=8, max_seq_len=16, speculate=2)
    with ServeClient(engine=eng) as client:
        session = client.session(max_tokens=8)
        streams = [session.generate(prompts[i]) for i in range(4)]
        assert [list(s) for s in streams] == baseline
        m = client.metrics()
        assert m["verify_steps"] > 0
        assert m["pages_in_use"] == 0


def test_slow_consumer_marks_lagging_never_blocks_loop(small_model,
                                                       baseline):
    cfg, params, prompts = small_model
    with ServeClient(cfg, params, max_batch=2, max_cache_len=16) as client:
        stream = client.generate(prompts[0], max_tokens=8, stream_buffer=2)
        # don't consume at all: the decode loop must finish regardless
        assert stream.request.wait(timeout=120)
        assert stream.request.req_state is RequestState.FINISHED
        assert stream.lagging is True
        assert stream.pending == 8        # everything still readable
        assert list(stream) == baseline[0]
        # a keeping-up consumer never lags
        fast = client.generate(prompts[1], max_tokens=8, stream_buffer=64)
        assert list(fast) == baseline[1]
        assert fast.lagging is False


def _apply_stop(tokens, stop_seqs):
    """Independent oracle for stop-truncation semantics: scan token by
    token, finish at the first completed stop sequence, exclude it."""
    out = []
    for t in tokens:
        out.append(t)
        for s in stop_seqs:
            if len(out) >= len(s) and tuple(out[-len(s):]) == tuple(s):
                return out[:len(out) - len(s)], True
    return out, False


def test_stop_sequence_stream_vs_retirement_identity(small_model, baseline):
    cfg, params, prompts = small_model
    stop = tuple(baseline[0][3:5])          # spans two decode steps
    expected, hit = _apply_stop(baseline[0], [stop])
    assert hit and len(expected) < len(baseline[0])
    gen = GenerationConfig(max_tokens=8, stop=[stop])
    # retirement path
    req = serve_requests(cfg, params, [Request(prompts[0], gen)],
                         max_batch=2, max_cache_len=16, timeout=300)[0]
    assert req.tokens == expected           # stop excluded from output
    # streaming path delivers exactly the same, and never leaks a token
    # of the stop sequence (holdback)
    with ServeClient(cfg, params, max_batch=2, max_cache_len=16) as client:
        st = client.generate(prompts[0], gen)
        assert list(st) == expected
        assert st.request.tokens == expected
        assert st.reason == "finished"
        assert client.metrics()["stopped"] == 1
        assert client.metrics()["pages_in_use"] == 0


def test_stop_on_first_token(small_model, baseline):
    cfg, params, prompts = small_model
    with ServeClient(cfg, params, max_batch=2, max_cache_len=16) as client:
        st = client.generate(prompts[0], max_tokens=8,
                             stop=[[baseline[0][0]]])
        assert list(st) == []
        assert st.request.tokens == []
        assert st.request.req_state is RequestState.FINISHED
        assert client.metrics()["pages_in_use"] == 0


def test_deadline_expiry_releases_pages_mid_decode(small_model):
    cfg, params, prompts = small_model
    with ServeClient(cfg, params, max_batch=2, max_cache_len=256,
                     max_seq_len=256) as client:
        client.generate(prompts[0], max_tokens=2).result(timeout=300)  # warm
        st = client.generate(prompts[1], max_tokens=200, deadline_s=0.3)
        with pytest.raises(DeadlineExceeded) as exc:
            st.tokens().result(timeout=60)
        assert st.request.req_state is RequestState.EXPIRED
        assert st.reason == "expired"
        # partial tokens survive on the request and ride the exception
        assert 0 < len(st.request.tokens) < 200
        assert exc.value.tokens == st.request.tokens
        m = client.metrics()
        assert m["expired"] == 1
        assert m["pages_in_use"] == 0     # released by the continuation


def test_priority_admission_under_oversubscription(small_model):
    """One slot, four queued requests: admission must seat strictly by
    priority (arrival order within a class), not submission order."""
    cfg, params, prompts = small_model
    eng = ServeEngine(cfg, params, max_batch=1, max_cache_len=16)
    try:
        reqs = [Request(prompts[i % 4],
                        GenerationConfig(max_tokens=3, priority=p))
                for i, p in enumerate([0, 0, 7, 3])]
        for r in reqs:
            eng.submit(r)
        eng.close_intake()
        eng.run(timeout=300)
        order = sorted(reqs, key=lambda r: r.admit_time)
        assert [r.priority for r in order] == [7, 3, 0, 0]
        assert order[2] is reqs[0]        # arrival order within class
        assert all(r.req_state is RequestState.FINISHED for r in reqs)
    finally:
        eng.shutdown()


# --------------------------------------------------- cancel-mid-stream
def _drive_until(eng, pred, timeout=120.0):
    deadline = time.monotonic() + timeout
    while not pred():
        eng.step()
        if time.monotonic() > deadline:
            raise TimeoutError("condition never became true")


def test_cancel_mid_stream_no_delivery_after_cancel(small_model):
    """Tokens produced in the same step a request is cancelled must not
    be delivered after cancel() returns — driven deterministically on
    this thread so a step is guaranteed in flight at cancel time."""
    cfg, params, prompts = small_model
    eng = ServeEngine(cfg, params, max_batch=2, max_cache_len=64,
                      max_seq_len=64)
    try:
        req = Request(prompts[0], GenerationConfig(max_tokens=40))
        stream = TokenStream(req)
        eng.submit(req)
        _drive_until(eng, lambda: stream.received >= 2)
        eng._dispatch_step()              # a step is now in flight…
        assert eng._inflight > 0
        assert req.cancel() is True       # …and cancel returns before it
        n_at_cancel = stream.received
        for _ in range(30):               # run its continuation + sweeps
            eng.step()
        assert stream.received == n_at_cancel
        assert list(stream)[:n_at_cancel] == stream._toks
        assert stream.reason == "cancelled"
        assert req.req_state is RequestState.CANCELLED
        with pytest.raises(PromiseCancelled):
            stream.tokens().result(timeout=5)
        assert eng.metrics()["pages_in_use"] == 0
    finally:
        eng.shutdown()


def test_cancel_mid_speculative_verify_no_delivery_no_leaks(small_model):
    cfg, params, prompts = small_model
    eng = ServeEngine(cfg, params, max_batch=2, max_cache_len=64,
                      paged=True, page_size=8, max_seq_len=64, speculate=3)
    try:
        req = Request(prompts[0], GenerationConfig(max_tokens=40))
        stream = TokenStream(req)
        eng.submit(req)
        _drive_until(eng, lambda: stream.received >= 2)
        # force a verify step in flight, then cancel before its
        # continuation runs: the whole accepted run must be dropped
        _drive_until(eng, lambda: eng._dispatch_step() or eng._verifying)
        assert req.cancel() is True
        n_at_cancel = stream.received
        for _ in range(30):
            eng.step()
        assert stream.received == n_at_cancel
        assert stream.reason == "cancelled"
        assert not eng._verifying
        assert eng.metrics()["pages_in_use"] == 0
        assert eng.stats["cancelled"] >= 1
    finally:
        eng.shutdown()


def test_cancel_from_consumer_thread_closes_stream(small_model):
    """stream.cancel() from a real consumer thread while the client loop
    decodes: iteration ends, nothing arrives after cancel returns."""
    cfg, params, prompts = small_model
    with ServeClient(cfg, params, max_batch=2, max_cache_len=64,
                     max_seq_len=64) as client:
        stream = client.generate(prompts[0], max_tokens=50)
        got = []
        post_cancel = []
        for tok in stream:
            got.append(tok)
            if len(got) == 3:
                stream.cancel()
                post_cancel.append(stream.received)
        time.sleep(0.2)                   # loop keeps running
        assert stream.received == post_cancel[0]
        assert stream.reason == "cancelled"
        assert client.metrics()["pages_in_use"] == 0


def test_completed_budget_outranks_lapsed_deadline(small_model):
    """A request whose final budgeted step is already in flight when the
    deadline lapses still FINISHES — the engine returns the output it
    already paid for instead of expiring it."""
    cfg, params, prompts = small_model
    eng = ServeEngine(cfg, params, max_batch=2, max_cache_len=16)
    try:
        warm = Request(prompts[1], 2)       # compile before the deadline
        eng.submit(warm)
        eng.run(until=lambda: warm.req_state is RequestState.FINISHED,
                timeout=300)
        req = Request(prompts[0],
                      GenerationConfig(max_tokens=2, deadline_s=0.2))
        eng.submit(req)
        eng._admit()              # seat + prefill (continuation pending)
        eng.engine.tick()         # first token delivers before deadline
        eng._dispatch_step()      # final budgeted token now in flight
        assert eng._draining
        time.sleep(0.3)           # deadline lapses mid-flight
        for _ in range(30):
            eng.step()
        assert req.req_state is RequestState.FINISHED
        assert len(req.tokens) == 2
        assert eng.stats["expired"] == 0
        assert eng.metrics()["pages_in_use"] == 0
    finally:
        eng.shutdown()


def test_client_loop_death_cancels_streams_and_reraises(small_model):
    """A decode-loop crash must not strand stream consumers: live
    requests are cancelled (closing their streams) and close()
    re-raises the loop error."""
    cfg, params, prompts = small_model
    client = ServeClient(cfg, params, max_batch=2, max_cache_len=64,
                         max_seq_len=64)
    stream = client.generate(prompts[0], max_tokens=50)

    def boom():
        raise RuntimeError("loop-test-crash")

    client.serve.step = boom          # next loop iteration raises
    list(stream)                      # must terminate, not hang
    assert stream.reason == "cancelled"
    with pytest.raises(PromiseCancelled):
        stream.tokens().result(timeout=5)
    # a failed client refuses new work instead of silently restarting
    with pytest.raises(RuntimeError, match="crashed"):
        client.generate(prompts[1], max_tokens=2)
    with pytest.raises(RuntimeError, match="loop-test-crash"):
        client.close()
