"""Train-step integration: loss decreases, microbatching is equivalent,
optimizer/clipping behave."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.optim import OptConfig, warmup_cosine
from repro.train.train_step import (init_train_state, make_train_step,
                                    train_state_specs)


def _batch(cfg, key, G=4, S=32):
    return {"tokens": jax.random.randint(key, (G, S), 0, cfg.vocab_size)}


def test_loss_decreases_over_steps():
    cfg = get_config("paper_demo", reduced=True)
    opt = OptConfig(lr=5e-3, grad_clip=1.0)
    state = init_train_state(jax.random.PRNGKey(0), cfg, opt)
    step = jax.jit(make_train_step(cfg, opt), donate_argnums=0)
    key = jax.random.PRNGKey(1)
    batch = _batch(cfg, key)
    losses = []
    for _ in range(8):
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] * 0.9, losses
    assert int(state["step"]) == 8


def test_microbatching_matches_single_batch():
    """grad accumulation over 4 microbatches == one big batch (same update)."""
    cfg = get_config("paper_demo", reduced=True, dtype=jnp.float32,
                     param_dtype=jnp.float32)
    opt = OptConfig(lr=1e-3, grad_clip=1e9)
    state1 = init_train_state(jax.random.PRNGKey(0), cfg, opt)
    state2 = jax.tree_util.tree_map(lambda x: x.copy(), state1)
    batch = _batch(cfg, jax.random.PRNGKey(2), G=8)
    s1 = jax.jit(make_train_step(cfg, opt, num_microbatches=1))
    s4 = jax.jit(make_train_step(cfg, opt, num_microbatches=4))
    state1, m1 = s1(state1, batch)
    state2, m2 = s4(state2, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=1e-5)
    # Accumulation is already fp32 (grads_of upcasts before the scan sum);
    # the residual difference is reduction-order only: one 8-row matmul
    # backward vs four 2-row ones, amplified by AdamW's 1/sqrt(v)
    # normalization where v is tiny after a single step. Observed max
    # |diff| ~2e-5 on this seed, so 5e-5 is equivalence, not slack.
    for a, b in zip(jax.tree_util.tree_leaves(state1["params"]),
                    jax.tree_util.tree_leaves(state2["params"])):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   atol=5e-5, rtol=1e-4)


def test_grad_clip_caps_norm():
    cfg = get_config("paper_demo", reduced=True)
    opt = OptConfig(lr=1e-3, grad_clip=1e-4)
    state = init_train_state(jax.random.PRNGKey(0), cfg, opt)
    step = jax.jit(make_train_step(cfg, opt))
    _, metrics = step(state, _batch(cfg, jax.random.PRNGKey(3)))
    assert float(metrics["grad_norm"]) > 1e-4  # raw norm reported


def test_lr_schedule_shapes():
    sched = warmup_cosine(1e-3, warmup_steps=10, total_steps=100)
    lrs = [float(sched(jnp.int32(s))) for s in [0, 5, 10, 50, 100]]
    assert lrs[0] == 0.0
    assert lrs[1] == pytest.approx(5e-4)
    assert lrs[2] == pytest.approx(1e-3)
    assert lrs[3] < 1e-3
    assert lrs[4] == pytest.approx(1e-4, rel=1e-2)


def test_state_specs_structure_matches():
    cfg = get_config("paper_demo", reduced=True)
    opt = OptConfig()
    state = init_train_state(jax.random.PRNGKey(0), cfg, opt)
    specs = train_state_specs(cfg)
    assert jax.tree_util.tree_structure(state) == \
        jax.tree_util.tree_structure(
            specs, is_leaf=lambda v: isinstance(v, tuple))


def test_compressed_psum_matches_mean():
    """int8 EF compression ≈ true mean; error feedback shrinks bias."""
    from functools import partial
    from jax.sharding import Mesh, PartitionSpec as P
    from repro.compat import shard_map
    from repro.optim import compressed_psum_mean, init_compression_state
    devs = jax.devices()
    mesh = Mesh(np.array(devs[:min(2, len(devs))]), ("data",))
    n = mesh.shape["data"]
    g = jax.random.normal(jax.random.PRNGKey(0), (n, 64, 32))
    err = init_compression_state({"w": g[0]})

    @partial(shard_map, mesh=mesh,
             in_specs=(P("data"), {"w": P()}),
             out_specs=({"w": P()}, {"w": P()}), check_vma=False)
    def sync(gs, e):
        mean, new_e = compressed_psum_mean({"w": gs[0]}, e, "data")
        return mean, new_e

    mean, new_err = sync(g, err)
    true_mean = g.mean(axis=0)
    err0 = float(jnp.max(jnp.abs(mean["w"] - true_mean)))
    scale = float(jnp.max(jnp.abs(g))) / 127
    assert err0 <= 2.1 * scale, (err0, scale)   # within quantization error
    # error feedback: transmitted mass + residual reconstructs the signal
    recon = mean["w"] + new_err["w"] / n
    assert float(jnp.max(jnp.abs(recon - true_mean))) <= 1e-5
