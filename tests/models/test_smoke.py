"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, asserting output shapes and finiteness (assignment requirement)."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import assigned_architectures, get_config
from repro.models import encdec, lm
from repro.models.common import AUDIO, VLM

ARCHS = assigned_architectures()


def make_batch(cfg, key, batch=2, seq=32):
    ks = jax.random.split(key, 3)
    if cfg.family == AUDIO:
        return {
            "audio_embed": jax.random.normal(ks[0], (batch, seq, cfg.frontend_dim)),
            "dec_tokens": jax.random.randint(ks[1], (batch, 16), 0, cfg.vocab_size),
        }
    b = {"tokens": jax.random.randint(ks[0], (batch, seq), 0, cfg.vocab_size)}
    if cfg.family == VLM:
        b["patches"] = jax.random.normal(ks[1], (batch, cfg.n_patches,
                                                 cfg.frontend_dim))
    return b


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_and_loss(arch):
    cfg = get_config(arch, reduced=True)
    key = jax.random.PRNGKey(0)
    batch = make_batch(cfg, jax.random.fold_in(key, 1))
    if cfg.family == AUDIO:
        params = encdec.init_params(key, cfg)
        loss = jax.jit(lambda p, b: encdec.encdec_loss(p, b, cfg))(params, batch)
    else:
        params = lm.init_params(key, cfg)
        logits = jax.jit(lambda p, b: lm.lm_forward(p, b, cfg))(params, batch)
        S = batch["tokens"].shape[1] + (cfg.n_patches if cfg.family == VLM else 0)
        assert logits.shape == (2, S, cfg.vocab_size)
        assert jnp.isfinite(logits.astype(jnp.float32)).all()
        loss = jax.jit(lambda p, b: lm.lm_loss(p, b, cfg))(params, batch)
    assert loss.shape == ()
    assert jnp.isfinite(loss), f"{arch}: loss not finite"
    assert float(loss) > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_one_train_step(arch):
    """One SGD step on the reduced config: grads finite, loss decreases
    (or at least changes) and params update."""
    cfg = get_config(arch, reduced=True)
    key = jax.random.PRNGKey(1)
    batch = make_batch(cfg, jax.random.fold_in(key, 2))
    loss_fn = (lambda p, b: encdec.encdec_loss(p, b, cfg)) \
        if cfg.family == AUDIO else (lambda p, b: lm.lm_loss(p, b, cfg))
    init_fn = encdec.init_params if cfg.family == AUDIO else lm.init_params
    params = init_fn(key, cfg)

    @jax.jit
    def step(p, b):
        loss, grads = jax.value_and_grad(loss_fn)(p, b)
        new_p = jax.tree_util.tree_map(
            lambda w, g: w - 0.05 * g.astype(w.dtype), p, grads)
        return loss, new_p, grads

    loss0, params1, grads = step(params, batch)
    gnorms = [float(jnp.max(jnp.abs(g.astype(jnp.float32))))
              for g in jax.tree_util.tree_leaves(grads)]
    assert all(jnp.isfinite(g) for g in gnorms), f"{arch}: non-finite grads"
    assert max(gnorms) > 0, f"{arch}: all-zero grads"
    loss1, _, _ = step(params1, batch)
    assert jnp.isfinite(loss1)
    assert float(loss1) < float(loss0), f"{arch}: loss did not decrease"


@pytest.mark.parametrize("arch", ["h2o_danube3_4b", "mamba2_370m",
                                  "zamba2_1p2b", "qwen3_moe_235b_a22b",
                                  "internvl2_26b"])
def test_param_specs_match_structure(arch):
    """Sharding-spec trees must mirror the param trees exactly."""
    cfg = get_config(arch, reduced=True)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    specs = lm.param_specs(cfg)
    pstruct = jax.tree_util.tree_structure(params)
    sstruct = jax.tree_util.tree_structure(
        specs, is_leaf=lambda v: isinstance(v, tuple))
    assert pstruct == sstruct
    # every spec tuple must match its tensor's rank
    flat_p = jax.tree_util.tree_leaves(params)
    flat_s = jax.tree_util.tree_leaves(
        specs, is_leaf=lambda v: isinstance(v, tuple))
    for p, s in zip(flat_p, flat_s):
        assert len(s) == p.ndim, f"{arch}: spec {s} vs shape {p.shape}"


def test_encdec_specs_match_structure():
    cfg = get_config("whisper_large_v3", reduced=True)
    params = encdec.init_params(jax.random.PRNGKey(0), cfg)
    specs = encdec.param_specs(cfg)
    assert jax.tree_util.tree_structure(params) == \
        jax.tree_util.tree_structure(specs,
                                     is_leaf=lambda v: isinstance(v, tuple))
    for p, s in zip(jax.tree_util.tree_leaves(params),
                    jax.tree_util.tree_leaves(
                        specs, is_leaf=lambda v: isinstance(v, tuple))):
        assert len(s) == p.ndim


@pytest.mark.parametrize("arch", ["paper_demo", "h2o_danube3_4b",
                                  "mamba2_370m", "zamba2_1p2b"])
def test_decode_matches_forward(arch):
    """prefill + decode_step must agree with the full forward pass."""
    cfg = get_config(arch, reduced=True, dtype=jnp.float32,
                     param_dtype=jnp.float32)
    key = jax.random.PRNGKey(3)
    B, S = 2, 24
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    params = lm.init_params(jax.random.PRNGKey(4), cfg)
    full = lm.lm_forward(params, {"tokens": tokens}, cfg)

    prompt_len = S - 4
    cache = lm.init_cache(cfg, B, max_len=S)
    logits_p, cache = jax.jit(
        lambda p, b, c: lm.lm_prefill(p, b, cfg, c)
    )(params, {"tokens": tokens[:, :prompt_len]}, cache)
    assert jnp.allclose(logits_p[:, 0], full[:, prompt_len - 1], atol=2e-3), \
        f"{arch}: prefill logits mismatch"
    dstep = jax.jit(lambda p, t, c, pos: lm.lm_decode_step(p, t, cfg, c, pos))
    for t in range(prompt_len, S):
        logits_d, cache = dstep(params, tokens[:, t:t + 1],
                                cache, jnp.int32(t))
        assert jnp.allclose(logits_d[:, 0], full[:, t], atol=2e-3), \
            f"{arch}: decode mismatch at pos {t}"


def test_encdec_decode_matches_train_logits():
    cfg = get_config("whisper_large_v3", reduced=True, dtype=jnp.float32,
                     param_dtype=jnp.float32)
    key = jax.random.PRNGKey(5)
    B, T_enc, T_dec = 2, 16, 8
    audio = jax.random.normal(key, (B, T_enc, cfg.frontend_dim))
    toks = jax.random.randint(jax.random.fold_in(key, 1), (B, T_dec), 0,
                              cfg.vocab_size)
    params = encdec.init_params(jax.random.PRNGKey(6), cfg)
    # full teacher-forced decoder logits
    enc_out = encdec.encode(params, audio, cfg)
    x = encdec._embed_dec(params, toks, cfg)
    for i in range(cfg.n_dec_layers):
        lp = jax.tree_util.tree_map(lambda a: a[i], params["decoder"])
        x = encdec._dec_block_train(lp, x, enc_out, cfg)
    from repro.models.layers import lm_logits, rmsnorm
    full = lm_logits(params["embed"],
                     rmsnorm(params["dec_norm"], x, cfg.norm_eps), cfg)
    # step-by-step decode
    state = encdec.init_decode_state(params, audio, cfg, max_len=T_dec)
    for t in range(T_dec):
        logits, state = encdec.encdec_decode_step(
            params, toks[:, t:t + 1], cfg, state, jnp.int32(t))
        assert jnp.allclose(logits[:, 0], full[:, t], atol=2e-3), f"pos {t}"


def test_moe_scatter_matches_einsum():
    """Both dispatch implementations must compute the same function
    (same capacity/drop policy)."""
    cfg = get_config("qwen3_moe_235b_a22b", reduced=True, dtype=jnp.float32,
                     param_dtype=jnp.float32)
    cfg_s = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, dispatch="scatter"))
    from repro.models import moe as moe_mod
    key = jax.random.PRNGKey(7)
    params = moe_mod.init_moe(key, cfg)
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, 16, cfg.d_model))
    y_e = moe_mod.moe_block(params, x, cfg)
    y_s = moe_mod.moe_block(params, x, cfg_s)
    assert jnp.allclose(y_e, y_s, atol=1e-4), \
        float(jnp.max(jnp.abs(y_e - y_s)))


def test_param_counts_sane():
    """Analytic param counts should match actual init sizes (<2% error)."""
    for arch in ["h2o_danube3_4b", "mamba2_370m", "qwen3_moe_235b_a22b",
                 "zamba2_1p2b", "whisper_large_v3", "internvl2_26b"]:
        cfg = get_config(arch, reduced=True)
        init_fn = encdec.init_params if cfg.family == AUDIO else lm.init_params
        params = init_fn(jax.random.PRNGKey(0), cfg)
        actual = sum(p.size for p in jax.tree_util.tree_leaves(params))
        predicted = cfg.param_count()
        assert abs(actual - predicted) / actual < 0.02, \
            f"{arch}: predicted {predicted} vs actual {actual}"


def test_head_padding_preserves_function():
    """TP head padding (llama4-style 40→48 w/ 8×(5+1) groups) must compute
    exactly the unpadded attention when real weights are embedded."""
    import numpy as np
    from repro.models import attention as attn_mod
    from repro.models.common import ModelConfig

    base = dict(n_layers=1, d_model=64, n_heads=10, n_kv_heads=2,
                head_dim=16, vocab_size=64, dtype=jnp.float32,
                param_dtype=jnp.float32, rope_theta=100.0)
    cfg_np = ModelConfig(head_pad_to=1, **base)    # unpadded: 10 heads
    cfg_p = ModelConfig(head_pad_to=4, **base)     # padded: 12, groups 2×6
    assert cfg_p.padded_heads == 12
    assert cfg_p.padded_kv_heads == 2
    assert cfg_p.padded_kv_groups == 6
    key = jax.random.PRNGKey(0)
    p_np = attn_mod.init_attention(key, cfg_np)

    # embed the real weights into the padded layout per head_mask
    mask = attn_mod.head_mask(cfg_p)
    wq = jnp.zeros((64, 12, 16))
    wo = jnp.zeros((12, 16, 64))
    wq = wq.at[:, np.where(mask)[0], :].set(p_np["wq"])
    wo = wo.at[np.where(mask)[0], :, :].set(p_np["wo"])
    p_p = {"wq": wq, "wk": p_np["wk"], "wv": p_np["wv"], "wo": wo}

    x = jax.random.normal(jax.random.fold_in(key, 1), (2, 24, 64))
    out_np = attn_mod.attention_block(p_np, x, cfg_np, causal=True)
    out_p = attn_mod.attention_block(p_p, x, cfg_p, causal=True)
    np.testing.assert_allclose(np.asarray(out_np), np.asarray(out_p),
                               atol=1e-5, rtol=1e-5)

    # decode path too
    cache_np = attn_mod.init_kv_cache(cfg_np, 2, 8)
    cache_p = attn_mod.init_kv_cache(cfg_p, 2, 8)
    o_np, _ = attn_mod.decode_attention(p_np, x[:, :1], cfg_np, cache_np,
                                        jnp.int32(0))
    o_p, _ = attn_mod.decode_attention(p_p, x[:, :1], cfg_p, cache_p,
                                       jnp.int32(0))
    np.testing.assert_allclose(np.asarray(o_np), np.asarray(o_p),
                               atol=1e-5, rtol=1e-5)
