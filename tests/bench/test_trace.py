"""Trace determinism and workload-model sanity: byte-identical
serialization per seed, arrival/length distribution shape, shared-prefix
mixtures, tenant/priority mixes, rescaling, and format versioning."""
import json
import random
import statistics

import pytest

from repro.bench import (Trace, TraceRequest, bounded_pareto, micro_trace,
                         onoff_arrivals, poisson_arrivals, rescale_qps,
                         synthetic_trace)
from repro.bench.trace import TRACE_FORMAT_VERSION


# ------------------------------------------------------------ determinism
def test_same_seed_is_byte_identical():
    kw = dict(seed=42, arrival="onoff", rate_qps=30.0, n_prefix_groups=3,
              shared_len=6, prompt_len=(8, 20), output_len=(2, 12),
              tenants={"a": 2.0, "b": 1.0}, priorities={0: 1.0, 1: 1.0},
              deadline_s=5.0)
    a = synthetic_trace(24, **kw).to_json()
    b = synthetic_trace(24, **kw).to_json()
    assert a == b                        # byte-identical, not just equal
    assert a.encode() == b.encode()


def test_different_seed_differs():
    a = synthetic_trace(12, seed=1).to_json()
    b = synthetic_trace(12, seed=2).to_json()
    assert a != b


def test_json_roundtrip_preserves_everything():
    t = synthetic_trace(10, seed=7, n_prefix_groups=2, shared_len=4,
                        prompt_len=(6, 12), deadline_s=2.5,
                        tenants={"x": 1.0, "y": 3.0})
    back = Trace.from_json(t.to_json())
    # arrival/deadline floats are canonically rounded to 6 decimals in
    # the serialized form, so compare through it (a second roundtrip is
    # the fixed point), plus exact fields directly
    assert back.to_json() == t.to_json()
    assert dict(back.meta) == dict(t.meta)
    for a, b in zip(back.requests, t.requests):
        assert (a.prompt, a.max_tokens, a.tenant, a.priority,
                a.prefix_group) == (b.prompt, b.max_tokens, b.tenant,
                                    b.priority, b.prefix_group)
        assert a.arrival_s == pytest.approx(b.arrival_s, abs=1e-6)


def test_canonical_json_is_sorted_and_compact():
    doc = synthetic_trace(3, seed=0).to_json()
    parsed = json.loads(doc)
    assert doc == json.dumps(parsed, sort_keys=True,
                             separators=(",", ":"))


def test_format_version_guard():
    doc = json.loads(synthetic_trace(2, seed=0).to_json())
    doc["format_version"] = TRACE_FORMAT_VERSION + 1
    with pytest.raises(ValueError, match="format_version"):
        Trace.from_json(json.dumps(doc))


def test_save_load_roundtrip(tmp_path):
    t = micro_trace(seed=3, n_requests=5)
    p = tmp_path / "t.json"
    t.save(str(p))
    assert Trace.load(str(p)).to_json() == t.to_json()


# -------------------------------------------------------- arrival models
def test_poisson_arrivals_shape():
    rng = random.Random(0)
    arr = poisson_arrivals(rng, 500, rate_qps=100.0)
    assert arr[0] == 0.0
    assert all(b >= a for a, b in zip(arr, arr[1:]))
    gaps = [b - a for a, b in zip(arr, arr[1:])]
    # mean gap ~ 1/rate (generous band: seeded, so this never flakes)
    assert 0.005 < statistics.mean(gaps) < 0.02


def test_onoff_arrivals_are_bursty():
    rng = random.Random(1)
    arr = onoff_arrivals(rng, 300, burst_rate_qps=200.0,
                         mean_burst=5.0, mean_off_s=0.5)
    gaps = sorted(b - a for a, b in zip(arr, arr[1:]))
    # bimodal: in-burst gaps ~5ms, off gaps ~500ms
    assert gaps[len(gaps) // 2] < 0.05      # median is an in-burst gap
    assert gaps[-1] > 0.1                   # tail is a quiet gap


def test_arrival_validation():
    rng = random.Random(0)
    with pytest.raises(ValueError):
        poisson_arrivals(rng, 3, rate_qps=0.0)
    with pytest.raises(ValueError):
        onoff_arrivals(rng, 3, burst_rate_qps=10.0, mean_burst=0.5)


# --------------------------------------------------------- length models
def test_bounded_pareto_respects_bounds():
    rng = random.Random(2)
    vals = [bounded_pareto(rng, alpha=1.2, lo=4, hi=64)
            for _ in range(2000)]
    assert min(vals) >= 4 and max(vals) <= 64
    # heavy tail: most draws are short, but the long tail is reached
    assert statistics.median(vals) < 12
    assert max(vals) > 32


def test_bounded_pareto_degenerate_and_validation():
    rng = random.Random(0)
    assert bounded_pareto(rng, alpha=1.0, lo=7, hi=7) == 7
    with pytest.raises(ValueError):
        bounded_pareto(rng, alpha=0.0, lo=1, hi=2)
    with pytest.raises(ValueError):
        bounded_pareto(rng, alpha=1.0, lo=5, hi=4)


# ------------------------------------------------------- prefix mixtures
def test_shared_prefix_groups():
    t = synthetic_trace(40, seed=9, n_prefix_groups=3, shared_len=6,
                        prompt_len=(8, 16))
    by_group = {}
    for r in t.requests:
        assert r.prefix_group in (0, 1, 2)
        by_group.setdefault(r.prefix_group, []).append(r.prompt[:6])
    assert len(by_group) == 3            # all groups actually drawn
    for group, prefixes in by_group.items():
        assert len(set(prefixes)) == 1   # one common prefix per group
    # distinct groups have distinct prefixes
    assert len({p[0] for p in by_group.values()}) == 3


def test_shared_prefix_validation():
    with pytest.raises(ValueError, match="shared_len"):
        synthetic_trace(4, seed=0, n_prefix_groups=2, shared_len=10,
                        prompt_len=(8, 16))


# --------------------------------------------------- tenant/priority mix
def test_tenant_and_priority_mix():
    t = synthetic_trace(60, seed=11, tenants={"gold": 3.0, "free": 1.0},
                        priorities={0: 1.0, 2: 1.0})
    tenants = {r.tenant for r in t.requests}
    prios = {r.priority for r in t.requests}
    assert tenants == {"gold", "free"}
    assert prios == {0, 2}
    n_gold = sum(1 for r in t.requests if r.tenant == "gold")
    assert n_gold > len(t) // 2          # 3:1 weighting dominates


# ----------------------------------------------------------- closed loop
def test_closed_loop_trace():
    t = synthetic_trace(8, seed=0, closed_loop=3)
    assert t.closed_loop == 3
    assert all(r.arrival_s == 0.0 for r in t.requests)
    assert t.offered_qps is None
    assert t.meta["arrival"] == "closed"


def test_closed_loop_validation():
    with pytest.raises(ValueError, match="closed_loop"):
        synthetic_trace(4, seed=0, arrival="closed")


# ------------------------------------------------------------- rescaling
def test_rescale_qps_changes_only_the_clock():
    t = synthetic_trace(30, seed=5, rate_qps=50.0)
    fast = rescale_qps(t, 200.0)
    assert fast.offered_qps == pytest.approx(200.0, rel=1e-6)
    assert [r.prompt for r in fast.requests] == \
        [r.prompt for r in t.requests]
    assert [r.max_tokens for r in fast.requests] == \
        [r.max_tokens for r in t.requests]
    assert fast.meta["rate_qps"] == 200.0
    assert fast.meta["rescaled_from_qps"] == pytest.approx(
        t.offered_qps)


def test_rescale_validation():
    t = synthetic_trace(6, seed=0, closed_loop=2)
    with pytest.raises(ValueError, match="open-loop"):
        rescale_qps(t, 10.0)
    with pytest.raises(ValueError):
        rescale_qps(synthetic_trace(6, seed=0), 0.0)


# ------------------------------------------------------------ misc shape
def test_micro_trace_is_small_and_deterministic():
    a, b = micro_trace(seed=4), micro_trace(seed=4)
    assert a.to_json() == b.to_json()
    assert len(a) == 4
    assert all(len(r.prompt) == 8 and r.max_tokens == 4 for r in a)


def test_trace_properties():
    t = synthetic_trace(5, seed=0, output_len=(3, 3))
    assert len(t) == 5
    assert t.total_output_tokens == 15
    assert list(iter(t))[0] is t.requests[0]
    with pytest.raises(ValueError):
        synthetic_trace(0, seed=0)
    with pytest.raises(ValueError, match="arrival"):
        synthetic_trace(2, seed=0, arrival="uniform")


def test_request_dict_roundtrip():
    r = TraceRequest(arrival_s=1.25, prompt=(1, 2, 3), max_tokens=4,
                     tenant="t", priority=2, deadline_s=9.0,
                     prefix_group=1)
    assert TraceRequest.from_dict(r.to_dict()) == r
    bare = TraceRequest(arrival_s=0.0, prompt=(1,), max_tokens=1)
    assert TraceRequest.from_dict(bare.to_dict()) == bare
