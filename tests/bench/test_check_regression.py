"""The regression gate's decision machinery, driven through fabricated
BENCH_serve.json documents: extraction, conditional exemption,
variance-aware unstable demotion (committed cv decides, exempt wins),
baseline migration via --update semantics, and the summary artifact.

``benchmarks/`` is not a package — the gate is loaded from its file path
exactly the way CI runs it (no PYTHONPATH=src, no repro import).
"""
import importlib.util
import json
import pathlib

import pytest

_PATH = (pathlib.Path(__file__).resolve().parents[2]
         / "benchmarks" / "check_regression.py")
_spec = importlib.util.spec_from_file_location("check_regression", _PATH)
cr = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(cr)


def make_doc(fused=False):
    """A complete bench document with healthy values and per-block
    variance fields (cv 0.02 everywhere except the marked-noisy spec
    speedup at 0.4)."""
    v = lambda cv: {"mean": 1.0, "cv": cv, "ci95": 0.01, "values": [1.0]}
    return {
        "speedup_tokens_per_s": 2.0,
        "continuous": {"tokens_per_s": 100.0, "ttft_p99_s": 0.01},
        "static_greedy": {"ttft_p99_s": 0.04},
        "variance": {"speedup_tokens_per_s": v(0.02),
                     "ttft_p99_ratio": v(0.02)},
        "paged": {"effective_batch_ratio": 2.0,
                  "speedup_tokens_per_s": 1.0,
                  "paged": {"tokens_per_s": 100.0},
                  "variance": {"effective_batch_ratio": v(0.0),
                               "speedup_tokens_per_s": v(0.02)}},
        "spec": {"speedup_tokens_per_s": 1.1,
                 "speculative": {"accept_rate": 0.3,
                                 "tokens_per_s": 110.0},
                 "variance": {"speedup_tokens_per_s": v(0.4)}},
        "stream": {"ttft_speedup": 5.0, "tokens_per_s_ratio": 1.1,
                   "streaming": {"tokens_per_s": 100.0,
                                 "ttft_mean_s": 0.01,
                                 "inter_token_p99_s": 0.005},
                   "variance": {"ttft_speedup": v(0.02),
                                "tokens_per_s_ratio": v(0.02)}},
        "api": {"raw_vs_await_ratio": 0.9, "raw_callback_us": 7.0,
                "await_bridge_us": 8.0, "flags_overhead_ratio": 1.05,
                "variance": {"raw_vs_await_ratio": v(0.02)}},
        "router": {"affinity_hit_rate": 0.83, "tokens_per_s_ratio": 0.9,
                   "failover": {"requeued": 12}},
        "disagg": {"tokens_per_s_ratio": 0.9,
                   "bytes_shipped_per_request": 6144},
        "obs": {"trace_overhead_tokens_per_s": 0.99,
                "cause": {"events": 1400,
                          "notify_latency_us_mean": 280.0},
                "variance": {"trace_overhead_tokens_per_s": v(0.01)}},
        "kernel": {"fused_kernel_active": fused},
    }


def baselines_for(doc, **overrides):
    """Baselines matching ``doc`` exactly (floor < current everywhere),
    with per-metric entry overrides layered on."""
    metrics = {}
    for name, (fn, tol) in cr.GATED.items():
        metrics[name] = {"value": float(fn(doc)), "tolerance": tol}
    cvs = cr.extract_cv(doc)
    for name, cv in cvs.items():
        metrics[name]["cv"] = cv
    for name, entry in overrides.items():
        metrics[name] = {**metrics[name], **entry}
    return {"metrics": metrics}


# ------------------------------------------------------------- extraction
def test_extract_covers_every_gated_metric():
    got = cr.extract(make_doc())
    assert set(got) == set(cr.GATED)
    assert got["continuous_vs_static_ttft_p99"] == pytest.approx(4.0)
    assert got["router_affinity_hit_rate"] == 0.83


def test_extract_tolerates_partial_documents():
    got = cr.extract({"paged": {"effective_batch_ratio": 2.0,
                                "speedup_tokens_per_s": 1.0}})
    assert got == {"paged_vs_dense_effective_batch": 2.0,
                   "paged_vs_dense_tokens_per_s": 1.0}


def test_extract_cv_reads_variance_fields():
    cvs = cr.extract_cv(make_doc())
    assert cvs["spec_vs_paged_tokens_per_s"] == pytest.approx(0.4)
    assert cvs["paged_vs_dense_effective_batch"] == 0.0
    # deterministic metrics are not in the CV map at all
    assert "router_affinity_hit_rate" not in cvs
    assert "spec_accept_rate" not in cvs
    # single-sample documents (no variance blocks) degrade to empty
    assert cr.extract_cv({"paged": {}}) == {}


# ------------------------------------------------------------ gate: happy
def test_gate_passes_on_matching_doc(capsys):
    doc = make_doc()
    assert cr.check(doc, baselines_for(doc)) == 0
    assert "regression gate passed" in capsys.readouterr().out


def test_gate_fails_on_regression(capsys):
    doc = make_doc()
    base = baselines_for(doc)
    doc["paged"]["effective_batch_ratio"] = 0.5   # collapse one ratio
    assert cr.check(doc, base) == 1
    out = capsys.readouterr().out
    assert "REGRESSED" in out
    assert "paged_vs_dense_effective_batch" in out


def test_gate_fails_on_missing_baseline_entry():
    doc = make_doc()
    base = baselines_for(doc)
    del base["metrics"]["spec_accept_rate"]
    assert cr.check(doc, base) == 1


def test_gate_fails_on_unextractable_metric():
    doc = make_doc()
    base = baselines_for(doc)
    del doc["router"]                              # block missing
    assert cr.check(doc, base) == 1


# --------------------------------------------------------- gate: unstable
def test_unstable_metric_is_recorded_only(capsys):
    """A committed cv over the threshold demotes the metric: even a
    value far below the floor must not fail the gate."""
    doc = make_doc()
    base = baselines_for(
        doc, spec_vs_paged_tokens_per_s={"value": 1.1, "tolerance": 0.25,
                                         "cv": cr.UNSTABLE_CV + 0.1})
    doc["spec"]["speedup_tokens_per_s"] = 0.01     # way below floor
    assert cr.check(doc, base) == 0
    out = capsys.readouterr().out
    assert "unstable" in out
    assert "recorded-only" in out


def test_current_cv_never_decides(capsys):
    """Only the COMMITTED cv demotes — a noisy current run with a stable
    committed baseline still gates (CI verdicts stay deterministic)."""
    doc = make_doc()
    base = baselines_for(doc,
                         spec_vs_paged_tokens_per_s={"cv": 0.02})
    doc["spec"]["variance"]["speedup_tokens_per_s"]["cv"] = 0.9
    doc["spec"]["speedup_tokens_per_s"] = 0.01
    assert cr.check(doc, base) == 1               # still enforced
    assert "REGRESSED" in capsys.readouterr().out


def test_legacy_baseline_without_cv_keeps_gating():
    doc = make_doc()
    base = baselines_for(doc)
    base["metrics"]["stream_vs_batch_ttft"].pop("cv", None)
    doc["stream"]["ttft_speedup"] = 0.01
    assert cr.check(doc, base) == 1


# ----------------------------------------------------------- gate: exempt
def test_conditional_exemption_and_precedence(capsys):
    """fused_kernel_active=False exempts the paged tokens/s floor; exempt
    wins over unstable (one status per row, exemption is the stronger
    statement)."""
    doc = make_doc(fused=False)
    base = baselines_for(
        doc, paged_vs_dense_tokens_per_s={"value": 1.0,
                                          "tolerance": 0.05, "cv": 0.9})
    doc["paged"]["speedup_tokens_per_s"] = 0.01
    assert cr.check(doc, base) == 0
    out = capsys.readouterr().out
    row = next(l for l in out.splitlines()
               if l.startswith("paged_vs_dense_tokens_per_s"))
    assert "exempt" in row and "unstable" not in row


def test_conditional_enforced_when_predicate_holds():
    doc = make_doc(fused=True)
    base = baselines_for(doc)
    doc["paged"]["speedup_tokens_per_s"] = 0.01
    assert cr.check(doc, base) == 1


# --------------------------------------------------------------- --update
def test_update_writes_value_tolerance_cv(tmp_path):
    path = tmp_path / "baselines.json"
    cr.update_baselines(make_doc(), path)
    saved = json.loads(path.read_text())
    entry = saved["metrics"]["continuous_vs_static_tokens_per_s"]
    assert entry["value"] == 2.0
    assert entry["tolerance"] == cr.GATED[
        "continuous_vs_static_tokens_per_s"][1]
    assert entry["cv"] == pytest.approx(0.02)
    # deterministic metric: no cv key rather than a fake zero
    assert "cv" not in saved["metrics"]["router_affinity_hit_rate"]
    assert set(saved["recorded"]) == set(cr.RECORDED)


def test_update_preserves_hand_tuned_tolerance(tmp_path):
    path = tmp_path / "baselines.json"
    path.write_text(json.dumps({"metrics": {
        "spec_accept_rate": {"value": 0.9, "tolerance": 0.07}}}))
    cr.update_baselines(make_doc(), path)
    saved = json.loads(path.read_text())
    assert saved["metrics"]["spec_accept_rate"]["tolerance"] == 0.07
    assert saved["metrics"]["spec_accept_rate"]["value"] == 0.3  # refreshed


def test_update_exempt_keeps_committed_value_and_cv(tmp_path):
    path = tmp_path / "baselines.json"
    path.write_text(json.dumps({"metrics": {
        "paged_vs_dense_tokens_per_s": {"value": 1.23, "tolerance": 0.05,
                                        "cv": 0.04}}}))
    cr.update_baselines(make_doc(fused=False), path)
    entry = json.loads(path.read_text())[
        "metrics"]["paged_vs_dense_tokens_per_s"]
    assert entry == {"value": 1.23, "tolerance": 0.05, "cv": 0.04}


def test_update_refuses_partial_document(tmp_path):
    with pytest.raises(SystemExit, match="not extractable"):
        cr.update_baselines({"paged": {}}, tmp_path / "b.json")


# ---------------------------------------------------------------- summary
def test_summary_markdown_has_cv_column_and_badges(tmp_path):
    doc = make_doc()
    base = baselines_for(
        doc, stream_vs_batch_ttft={"cv": 0.6})
    out = tmp_path / "summary.md"
    assert cr.check(doc, base, str(out)) == 0
    md = out.read_text()
    assert "| cv |" in md
    assert "🌀 unstable" in md
    assert "➖ exempt" in md                       # fused=False default
    assert "recorded-only" in md
