"""One seeded micro-trace through ALL THREE serving tiers — colocated
``ServeEngine``, disaggregated ``DisaggServer``, multi-replica ``Router``
— via the same ``Replayer``, producing comparable SLO reports. This is
the apples-to-apples contract the bench subsystem exists for."""
import jax
import pytest

from repro.bench import SLO, micro_trace, replay, slo_report, to_markdown
from repro.serve import DisaggServer, Router, ServeEngine

KW = dict(max_batch=2, max_cache_len=64, page_size=4, max_seq_len=48)

TRACE = micro_trace(seed=31, n_requests=4, prompt_len=12, max_tokens=3,
                    n_prefix_groups=2, shared_len=8, rate_qps=100.0,
                    deadline_s=60.0)

# loose bounds: this asserts plumbing (every tier measured the same way),
# not performance — perf floors live in benchmarks/, not unit tests
LOOSE = SLO(ttft_p99_s=60.0, min_finished_frac=1.0,
            min_deadline_met_frac=1.0)


def _tiers(small_model):
    cfg, params = small_model
    return [
        ("engine", lambda: ServeEngine(cfg, params, paged=True, **KW)),
        ("disagg", lambda: DisaggServer(cfg, params, **KW)),
        ("router", lambda: Router(cfg, params, n_replicas=2, **KW)),
    ]


@pytest.mark.parametrize("tier_name", ["engine", "disagg", "router"])
def test_each_tier_replays_the_same_trace(small_model, tier_name):
    factory = dict(_tiers(small_model))[tier_name]
    results = replay(factory, TRACE, samples=1, timeout=180.0,
                     name=tier_name)
    report = slo_report(results, LOOSE)
    assert report["tier"] == tier_name
    assert report["trace"] == "micro"
    assert report["requests"] == 4
    assert report["slo"]["ok"], report["slo"]["violations"]
    # the report carries dispersion fields for every headline metric
    for key in ("tokens_per_s", "goodput_tokens_per_s", "ttft_p99_s",
                "itl_p99_s", "finished_frac", "deadline_met_frac"):
        assert key in report["metrics"], key
    assert report["metrics"]["finished_frac"]["mean"] == 1.0

    md = to_markdown(report)
    assert tier_name in md and "SLO holds" in md


def test_slo_violation_is_reported(small_model):
    """An impossible bound must produce a structured violation, not a
    crash — the sweep relies on this verdict."""
    cfg, params = small_model
    results = replay(
        lambda: ServeEngine(cfg, params, paged=True, **KW),
        micro_trace(seed=32, n_requests=3, max_tokens=2),
        samples=1, timeout=180.0, name="engine")
    report = slo_report(results, SLO(ttft_p99_s=1e-9))
    assert not report["slo"]["ok"]
    (viol,) = report["slo"]["violations"]
    assert viol["metric"] == "ttft_p99_s"
    assert viol["worst"] > 1e-9
    assert "SLO violated" in to_markdown(report)
