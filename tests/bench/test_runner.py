"""Replayer semantics against a real (reduced) engine: complete replay
with per-request timing, shared-prefix traces actually hitting the
prefix cache THROUGH the measurement path, deadline accounting, closed
loops, and the metric aggregates fed to the SLO layer."""
import jax
import pytest

from repro.bench import Replayer, micro_trace, replay
from repro.bench.runner import RequestRecord, RunResult
from repro.serve import ServeEngine

KW = dict(max_batch=2, max_cache_len=64, page_size=4, max_seq_len=48)


@pytest.fixture(scope="module")
def warm_replayer(small_model):
    cfg, params = small_model
    with Replayer(ServeEngine(cfg, params, paged=True, **KW),
                  name="engine") as rp:
        yield rp


# ------------------------------------------------------------ basic replay
def test_open_loop_replay_records_everything(warm_replayer):
    trace = micro_trace(seed=21, n_requests=5, max_tokens=3)
    (res,) = warm_replayer.run(trace, samples=1, timeout=120.0)
    assert res.tier == "engine" and res.trace_name == "micro"
    assert len(res.records) == 5
    for rec in res.records:
        assert rec.status == "finished"
        assert rec.n_tokens == 3
        assert rec.ttft_s is not None and rec.ttft_s > 0
        assert rec.latency_s >= rec.ttft_s
        assert len(rec.itl_s) == 2              # gaps between 3 stamps
        assert all(g >= 0 for g in rec.itl_s)
    m = res.metrics()
    assert m["finished_frac"] == 1.0
    assert m["tokens_per_s"] > 0
    assert m["goodput_tokens_per_s"] == m["tokens_per_s"]  # no deadlines
    assert m["ttft_p99_s"] >= m["ttft_p50_s"] >= 0
    assert "deadline_met_frac" not in m
    assert res.engine_metrics                   # tier metrics snapshot


def test_multi_sample_reuses_warm_tier(warm_replayer):
    trace = micro_trace(seed=22, n_requests=4, max_tokens=2)
    results = warm_replayer.run(trace, samples=3, timeout=120.0)
    assert [r.sample for r in results] == [0, 1, 2]
    assert all(r.metrics()["finished_frac"] == 1.0 for r in results)


# ----------------------------------------------------- prefix-cache reality
def test_shared_prefix_trace_hits_prefix_cache(warm_replayer):
    """The runner measures the real serving path: a shared-prefix trace
    must land prefix-cache hits in the engine's page pool."""
    trace = micro_trace(seed=23, n_requests=8, prompt_len=12,
                        max_tokens=2, n_prefix_groups=2, shared_len=8)
    pool = warm_replayer.client.serve.pool
    before = pool.stats["prefix_hits"]
    (res,) = warm_replayer.run(trace, samples=1, timeout=120.0)
    assert all(r.status == "finished" for r in res.records)
    assert pool.stats["prefix_hits"] > before
    assert pool.stats["prefix_tokens_reused"] > 0


# ------------------------------------------------------ deadline accounting
def test_generous_deadlines_all_met(warm_replayer):
    trace = micro_trace(seed=24, n_requests=4, max_tokens=2,
                        deadline_s=60.0)
    (res,) = warm_replayer.run(trace, samples=1, timeout=120.0)
    assert all(r.deadline_met is True for r in res.records)
    assert res.metrics()["deadline_met_frac"] == 1.0


def test_missed_deadline_is_excluded_from_goodput():
    """Pure-record unit: a finished-but-late request counts toward
    throughput, never toward goodput."""
    ok = RequestRecord(index=0, tenant="t", priority=0, status="finished",
                       arrival_s=0.0, ttft_s=0.01, latency_s=0.1,
                       n_tokens=10, itl_s=[0.01] * 9, deadline_s=1.0,
                       deadline_met=True)
    late = RequestRecord(index=1, tenant="t", priority=0,
                         status="finished", arrival_s=0.0, ttft_s=0.5,
                         latency_s=2.0, n_tokens=10, itl_s=[0.1] * 9,
                         deadline_s=1.0, deadline_met=False)
    assert ok.good and not late.good
    res = RunResult(trace_name="x", tier="t", sample=0, duration_s=2.0,
                    records=[ok, late])
    m = res.metrics()
    assert m["tokens_per_s"] == pytest.approx(10.0)      # 20 tok / 2 s
    assert m["goodput_tokens_per_s"] == pytest.approx(5.0)
    assert m["deadline_met_frac"] == 0.5
    assert m["finished_frac"] == 1.0


def test_refused_and_expired_counting():
    refused = RequestRecord(index=0, tenant="t", priority=0,
                            status="refused", arrival_s=0.0)
    expired = RequestRecord(index=1, tenant="t", priority=0,
                            status="expired", arrival_s=0.0, n_tokens=2,
                            itl_s=[0.1])
    res = RunResult(trace_name="x", tier="t", sample=0, duration_s=1.0,
                    records=[refused, expired])
    m = res.metrics()
    assert m["refused"] == 1.0 and m["expired"] == 1.0
    assert m["finished_frac"] == 0.0
    assert m["goodput_tokens_per_s"] == 0.0
    assert not refused.good and not expired.good


# ------------------------------------------------------------- closed loop
def test_closed_loop_replay(warm_replayer):
    trace = micro_trace(seed=25, n_requests=6, max_tokens=2,
                        closed_loop=2)
    (res,) = warm_replayer.run(trace, samples=1, timeout=120.0)
    assert res.closed_loop == 2
    assert all(r.status == "finished" for r in res.records)
    assert res.metrics()["finished_frac"] == 1.0


# --------------------------------------------------------------- lifecycle
def test_replay_one_shot_owns_the_tier(small_model):
    cfg, params = small_model
    trace = micro_trace(seed=26, n_requests=3, max_tokens=2)
    results = replay(lambda: ServeEngine(cfg, params, paged=True, **KW),
                     trace, samples=1, timeout=120.0, name="oneshot")
    assert results[0].tier == "oneshot"
    assert results[0].metrics()["finished_frac"] == 1.0


def test_samples_validation(warm_replayer):
    with pytest.raises(ValueError):
        warm_replayer.run(micro_trace(seed=0), samples=0)
