"""Saturation-sweep contract over stubbed evaluate functions (no engine,
no clock): the three edge cases, bisection convergence, probe retries in
``sweep_tier``, and artifact shape."""
import dataclasses

import pytest

from repro.bench import (SLO, SweepResult, micro_trace, saturation_sweep,
                         sweep_tier)
from repro.bench.runner import RequestRecord, RunResult


def boundary_at(limit):
    """Evaluate stub: SLO holds iff qps <= limit."""
    return lambda qps: (qps <= limit, {"probed": qps})


# ---------------------------------------------------------- edge contract
def test_fail_at_lo_means_none():
    res = saturation_sweep(boundary_at(5.0), lo_qps=10.0, hi_qps=100.0)
    assert res.max_qps is None
    assert len(res.points) == 1                  # stopped after lo probe
    assert res.points[0].qps == 10.0 and not res.points[0].ok
    assert not res.saturated_range


def test_pass_at_hi_means_saturated_range():
    res = saturation_sweep(boundary_at(1e9), lo_qps=10.0, hi_qps=100.0)
    assert res.max_qps == 100.0
    assert res.saturated_range
    assert [p.qps for p in res.points] == [10.0, 100.0]


def test_bisection_converges_to_boundary():
    res = saturation_sweep(boundary_at(37.0), lo_qps=10.0, hi_qps=100.0,
                           iters=8)
    assert res.max_qps is not None
    assert res.max_qps <= 37.0                   # never overstates
    assert res.max_qps == pytest.approx(37.0, abs=(100 - 10) / 2 ** 8)
    assert not res.saturated_range
    # every probe answer is recorded, in probe order
    assert res.points[0].qps == 10.0 and res.points[1].qps == 100.0
    assert len(res.points) == 2 + 8


def test_zero_iters_returns_lo():
    res = saturation_sweep(boundary_at(50.0), lo_qps=10.0, hi_qps=100.0,
                           iters=0)
    assert res.max_qps == 10.0                   # lo is the only known-good


def test_validation():
    with pytest.raises(ValueError):
        saturation_sweep(boundary_at(1.0), lo_qps=10.0, hi_qps=10.0)
    with pytest.raises(ValueError):
        saturation_sweep(boundary_at(1.0), lo_qps=0.0, hi_qps=10.0)
    with pytest.raises(ValueError):
        saturation_sweep(boundary_at(1.0), lo_qps=1.0, hi_qps=10.0,
                         iters=-1)


def test_to_dict_keeps_violations():
    res = SweepResult(
        max_qps=None, lo_qps=1.0, hi_qps=2.0,
        points=(dataclasses.replace(  # build via SweepPoint for clarity
            saturation_sweep(boundary_at(0.0), lo_qps=1.0,
                             hi_qps=2.0).points[0],
            info={"slo": {"violations": [
                {"metric": "ttft_p99_s", "bound": 0.1, "worst": 0.4,
                 "kind": "ceiling"}]}}),))
    d = res.to_dict()
    assert d["points"][0]["violations"] == [
        {"metric": "ttft_p99_s", "bound": 0.1, "worst": 0.4}]
    import json
    json.dumps(d)                                # artifact is JSON-safe


# ------------------------------------------------------------- sweep_tier
class _StubReplayer:
    """Duck-typed Replayer: fabricates one RunResult per run() whose TTFT
    scales with the probe rate, optionally failing the first attempt at
    each rate (the ambient-straggler case retries exist for)."""

    def __init__(self, ttft_per_qps=0.001, flaky_rates=()):
        self.ttft_per_qps = ttft_per_qps
        self.flaky = set(flaky_rates)
        self.runs = []                           # (qps, ttft) per run()

    def run(self, trace, *, samples=1, timeout=300.0, warmup=2):
        qps = round(trace.offered_qps, 4)
        ttft = qps * self.ttft_per_qps
        if qps in self.flaky:                    # one-shot straggler
            self.flaky.discard(qps)
            ttft = 10.0
        self.runs.append((qps, ttft))
        rec = RequestRecord(index=0, tenant="default", priority=0,
                            status="finished", arrival_s=0.0,
                            ttft_s=ttft, latency_s=ttft + 0.01,
                            n_tokens=4, itl_s=[0.001] * 3)
        return [RunResult(trace_name=trace.name, tier="stub", sample=i,
                          duration_s=1.0, records=[rec])
                for i in range(samples)]


def _trace():
    return micro_trace(seed=0, n_requests=8, rate_qps=50.0)


def test_sweep_tier_finds_boundary_through_rescale():
    stub = _StubReplayer(ttft_per_qps=0.001)     # fails above 100 qps
    res = sweep_tier(stub, _trace(), SLO(ttft_p99_s=0.1),
                     lo_qps=10.0, hi_qps=400.0, iters=6, retries=0)
    assert res.max_qps is not None
    assert res.max_qps <= 100.0
    assert res.max_qps == pytest.approx(100.0, abs=(400 - 10) / 2 ** 6)
    # every probe replayed the rescaled trace at its own rate
    assert {q for q, _ in stub.runs} == {round(p.qps, 4)
                                         for p in res.points}


def test_sweep_tier_retries_confirm_failures():
    # the lo probe hits a one-shot straggler; without retries the sweep
    # would report None, with one retry it recovers the real boundary
    flaky = _StubReplayer(ttft_per_qps=0.001, flaky_rates=(10.0,))
    res = sweep_tier(flaky, _trace(), SLO(ttft_p99_s=0.1),
                     lo_qps=10.0, hi_qps=400.0, iters=2, retries=1)
    assert res.max_qps is not None               # straggler absorbed
    assert res.points[0].ok

    flaky2 = _StubReplayer(ttft_per_qps=0.001, flaky_rates=(10.0,))
    res2 = sweep_tier(flaky2, _trace(), SLO(ttft_p99_s=0.1),
                      lo_qps=10.0, hi_qps=400.0, iters=2, retries=0)
    assert res2.max_qps is None                  # sticky false-fail


def test_sweep_tier_genuine_failure_stays_failed():
    stub = _StubReplayer(ttft_per_qps=1.0)       # hopeless at any rate
    res = sweep_tier(stub, _trace(), SLO(ttft_p99_s=0.1),
                     lo_qps=10.0, hi_qps=400.0, iters=2, retries=2)
    assert res.max_qps is None
    assert len([q for q, _ in stub.runs if q == 10.0]) == 3  # 1 + 2 retries
