"""The variance layer: percentile interpolation, dispersion summaries,
per-metric aggregation across samples, and the instability predicate the
variance-aware regression gate stands on."""
import math

import pytest

from repro.bench import (UNSTABLE_CV, Summary, is_unstable, percentile,
                         summarize, summarize_metrics, variance_fields)


# ------------------------------------------------------------ percentile
def test_percentile_interpolation():
    vals = [10.0, 20.0, 30.0, 40.0]
    assert percentile(vals, 0.0) == 10.0
    assert percentile(vals, 1.0) == 40.0
    assert percentile(vals, 0.5) == 25.0
    assert percentile([7.0], 0.99) == 7.0
    assert percentile([], 0.5) == 0.0


def test_percentile_order_independent():
    assert percentile([3.0, 1.0, 2.0], 0.5) == 2.0


# ------------------------------------------------------------- summarize
def test_summarize_known_values():
    s = summarize([1.0, 2.0, 3.0])
    assert s.n == 3
    assert s.mean == pytest.approx(2.0)
    assert s.std == pytest.approx(1.0)          # sample std, ddof=1
    assert s.cv == pytest.approx(0.5)
    assert s.ci95 == pytest.approx(1.96 / math.sqrt(3))
    assert (s.lo, s.hi) == (1.0, 3.0)
    assert s.values == (1.0, 2.0, 3.0)


def test_summarize_single_sample():
    s = summarize([5.0])
    assert (s.std, s.cv, s.ci95) == (0.0, 0.0, 0.0)
    assert s.mean == 5.0
    assert not s.unstable


def test_summarize_zero_mean_and_empty():
    assert summarize([-1.0, 1.0]).cv == 0.0     # no div-by-zero
    with pytest.raises(ValueError):
        summarize([])


def test_unstable_property_tracks_threshold():
    stable = summarize([1.0, 1.01, 0.99])
    noisy = summarize([1.0, 2.0, 0.5])
    assert stable.cv < UNSTABLE_CV < noisy.cv
    assert not stable.unstable
    assert noisy.unstable


# ------------------------------------------------------- metric aggregation
def test_summarize_metrics_per_key():
    out = summarize_metrics([{"a": 1.0, "b": 10.0},
                             {"a": 3.0, "b": 10.0}])
    assert out["a"].mean == 2.0
    assert out["b"].std == 0.0


def test_summarize_metrics_skips_non_numeric_and_missing():
    out = summarize_metrics([{"a": 1.0, "flag": True, "name": "x"},
                             {"a": 2.0, "extra": 5.0}])
    assert set(out) == {"a", "extra"}           # bool/str skipped
    assert out["a"].n == 2
    assert out["extra"].n == 1                  # summarized where present


def test_variance_fields_shape():
    vf = variance_fields([{"m": 1.0}, {"m": 2.0}])
    assert set(vf["m"]) == {"mean", "cv", "ci95", "values"}
    assert vf["m"]["mean"] == 1.5
    assert vf["m"]["values"] == [1.0, 2.0]


# ------------------------------------------------------------- is_unstable
def test_is_unstable_predicate():
    assert not is_unstable(None)                # legacy: no cv keeps gating
    assert not is_unstable(UNSTABLE_CV)         # boundary is stable
    assert is_unstable(UNSTABLE_CV + 1e-6)
    assert is_unstable(0.05, threshold=0.01)    # custom threshold


def test_summary_to_dict_is_json_safe():
    import json
    json.dumps(summarize([1.0, 2.0]).to_dict())
