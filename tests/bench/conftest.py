"""Shared fixtures for the bench-subsystem tests: one reduced model per
module so the (slow) param init and XLA warmup are paid once."""
import jax
import pytest

from repro.configs import get_config
from repro.models import lm


@pytest.fixture(scope="package")
def small_model():
    cfg = get_config("paper_demo", reduced=True)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params
