"""End-to-end driver: train the ~100M paper-demo model.

The full continuation-driven trainer (prefetch pipeline, async checkpoint
commit barriers, non-blocking metric readback, crash-safe restart) on CPU.
A 250-step run's loss curve is recorded in EXPERIMENTS.md; this example
defaults to a quick 20-step demonstration.

Run:  PYTHONPATH=src python examples/train_small.py [--steps N]
"""
import argparse

from repro.launch.train import train

if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_small")
    args = ap.parse_args()
    result = train(arch="paper_demo", steps=args.steps, global_batch=2,
                   seq_len=128, ckpt_dir=args.ckpt_dir, ckpt_every=10,
                   log_every=5)
    print(f"loss: {result['first_loss']:.4f} → {result['final_loss']:.4f} "
          f"({result['elapsed_s']}s)")
