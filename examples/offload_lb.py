"""Straggler mitigation demo: diffusive task offloading (paper §5.4).

Four ranks with a 6× load imbalance; the critical rank offloads tasks via
the continuation-driven OffloadManager (metadata+payload out, 3-message
result groups back, quotas adapting diffusively). Underloaded ranks keep
*progressing* while waiting at the iteration barrier — that is where they
execute offloaded tasks (victim-side continuations).

Run:  PYTHONPATH=src python examples/offload_lb.py
"""
import threading
import time

import numpy as np

from repro.core import Engine, Transport
from repro.runtime.offload import ContinuationBackend, OffloadManager


def run(offloading: bool, n_ranks: int = 4, iters: int = 5,
        task_cost_s: float = 0.004, imbalance: int = 6):
    engine = Engine()
    tr = Transport(n_ranks, engine=engine)
    managers = [OffloadManager(r, n_ranks, tr, ContinuationBackend(engine))
                for r in range(n_ranks)]
    arrived = [0] * iters
    lock = threading.Lock()

    def progress_barrier(mgr, it):
        """Arrive at the barrier but keep serving while waiting."""
        with lock:
            arrived[it] += 1
        while True:
            with lock:
                if arrived[it] >= n_ranks:
                    return
            mgr.backend.progress()
            time.sleep(1e-4)

    def rank_loop(rank):
        mgr = managers[rank]
        n_tasks = imbalance * 8 if rank == 0 else 8
        for it in range(iters):
            tasks = [mgr.new_task(task_cost_s) for _ in range(n_tasks)]
            pending = []
            loads = {r: (imbalance if r == 0 else 1.0) for r in range(n_ranks)}
            budget = sum(mgr.quota.values()) if offloading else 0
            for t in tasks:
                target = mgr.pick_target(loads) if offloading else None
                if rank == 0 and target is not None and len(pending) < budget:
                    mgr.offload(t, target)
                    pending.append(t)
                    loads[target] += 1.0
                else:
                    t.result = t.payload * 2 + 1   # execute locally
                    time.sleep(task_cost_s)
                    t.done.set()
                mgr.backend.progress()
            deadline = time.monotonic() + 5.0
            missed = {}
            for t in pending:
                while not t.done.is_set() and time.monotonic() < deadline:
                    mgr.backend.progress()
                    time.sleep(1e-4)
                if not t.done.is_set():
                    missed[1] = True
            mgr.end_iteration(missed)
            progress_barrier(mgr, it)
        mgr.stop()

    threads = [threading.Thread(target=rank_loop, args=(r,))
               for r in range(n_ranks)]
    t0 = time.monotonic()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    total = time.monotonic() - t0
    offl = managers[0].stats["offloaded"]
    engine.shutdown()
    return total, offl


if __name__ == "__main__":
    base, _ = run(offloading=False)
    lb, offloaded = run(offloading=True)
    print(f"no offloading:   {base:.2f}s")
    print(f"with offloading: {lb:.2f}s  ({offloaded} tasks offloaded, "
          f"{base / lb:.2f}x speedup)")
