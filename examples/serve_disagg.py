"""Disaggregated prefill/decode serving example.

Two role engines share one process but are connected ONLY by the
in-process continuation transport: the prefill role runs chunked prompt
prefill and ships each finished KV page the moment its export completes,
the decode role's delivery continuations install the blocks into its own
page pool, and the request flips into a decode slot when the last block
lands — no barrier, per-block pipelining. The demo traces the handoff
lifecycle (header → ship/install interleaved with later prefill chunks →
prefill_done → landed → seat), verifies the token streams are identical
to a colocated engine on the same traffic, and prints the transport's
per-tag accounting (control vs KV-block bytes).

Run:  PYTHONPATH=src python examples/serve_disagg.py [--arch paper_demo]
(the architecture must support the paged KV cache: dense/MoE family,
scan_layers, no sliding window)
"""
import argparse
import time

import jax

from repro.configs import get_config
from repro.models import lm
from repro.serve import Request, serve_requests
from repro.serve.disagg import CTRL_TAG, DisaggServer, block_tag


def main(args):
    cfg = get_config(args.arch, reduced=True)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    prompts = [
        jax.random.randint(jax.random.PRNGKey(i), (args.prompt_len,),
                           0, cfg.vocab_size).tolist()
        for i in range(args.requests)
    ]
    geometry = dict(max_batch=args.slots,
                    max_cache_len=args.prompt_len + args.new_tokens,
                    page_size=4, max_seq_len=args.prompt_len + args.new_tokens)

    print("== colocated baseline ==")
    colo = serve_requests(cfg, params,
                          [Request(p, args.new_tokens) for p in prompts],
                          paged=True, timeout=600, **geometry)
    baseline = [r.tokens for r in colo]
    print(f"   {len(baseline)} requests, "
          f"{sum(len(t) for t in baseline)} tokens")

    print("== disaggregated (prefill role -> transport -> decode role) ==")
    reqs = [Request(p, args.new_tokens) for p in prompts]
    srv = DisaggServer(cfg, params, chunk_pages=1, **geometry)
    try:
        t0 = time.monotonic()
        for r in reqs:
            srv.submit(r)
        srv.close_intake()
        srv.run(timeout=600)
        dt = time.monotonic() - t0

        # the handoff lifecycle for the first request, in driver order —
        # note installs of early blocks landing BEFORE prefill_done
        rid = reqs[0].req_id
        trace = [e for e in srv.events if e[1] == rid]
        print(f"   request {rid} lifecycle:")
        for e in trace:
            print(f"     {e[0]:<16} {e[2:] if len(e) > 2 else ''}")
        first_install = srv.events.index(("install", rid, 0))
        done = srv.events.index(("prefill_done", rid))
        print(f"   first block installed at event #{first_install}, "
              f"prefill finished at #{done} -> "
              f"{'PIPELINED' if first_install < done else 'sequential'}")

        assert [r.tokens for r in reqs] == baseline, "token mismatch!"
        print(f"   token streams identical to colocated: OK ({dt:.2f}s)")

        m = srv.metrics()
        print(f"   shipped {m['blocks_shipped']} blocks, "
              f"{m['bytes_shipped_per_request']:.0f} B/request")
        stats = m["transport"]
        ctrl = stats["per_tag"][CTRL_TAG]
        blk = stats["per_tag"][block_tag(rid)]
        print(f"   per-tag: ctrl {ctrl['sent_msgs']} msgs "
              f"({ctrl['sent_bytes']} B), request-{rid} KV "
              f"{blk['sent_msgs']} blocks ({blk['sent_bytes']} B)")
        print(f"   leak check: decode pool {srv.decode.pool.pages_in_use} "
              f"pages in use, prefill pool "
              f"{srv.prefill.pool.pages_in_use} -> "
              f"{'OK' if srv.decode.pool.pages_in_use == 0 and srv.prefill.pool.pages_in_use == 0 else 'LEAK'}")
    finally:
        srv.shutdown()


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="paper_demo",
                    help="architecture (reduced config is used; must "
                    "support the paged KV cache)")
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--new-tokens", type=int, default=8)
    main(ap.parse_args())
