"""Quickstart: the MPI-Continuations-style engine in ~80 lines.

Shows the paper's core interface (DESIGN.md §1) on three kinds of
asynchronous work: a JAX computation, a host I/O task, and messages
between two "ranks" — with the immediate-completion flag, a
``continue_all`` group, and the Listing-2 polling pattern — then the
application-facing payoff: a token stream from the serving session API,
delivered per token by the same continuation machinery.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import threading
import time
from concurrent.futures import ThreadPoolExecutor

import jax
import jax.numpy as jnp

from repro.core import ArrayOp, Engine, HostTaskOp, Transport

engine = Engine()
cr = engine.continue_init({"mpi_continue_enqueue_complete": True})

# --- 1. continuation on a JAX async computation -------------------------
x = jnp.ones((256, 256)) @ jnp.ones((256, 256))
flag = engine.continue_when(
    ArrayOp(x), lambda st, d: print(f"  [cb] matmul ready: sum={d[0,0]:.0f}"),
    x, cr=cr)
print(f"registered matmul continuation (immediate={flag})")

# --- 2. continuation group over host I/O tasks (continue_all) -----------
pool = ThreadPoolExecutor(2)

def slow_io(n):
    time.sleep(0.05)
    return n * n

ops = [HostTaskOp(pool.submit(slow_io, n)) for n in (3, 4)]
statuses = [None, None]
engine.continue_all(
    ops, lambda st, d: print(f"  [cb] both I/O tasks done: "
                             f"{st[0].payload} + {st[1].payload} = "
                             f"{st[0].payload + st[1].payload}"),
    None, statuses=statuses, cr=cr)
print("registered continue_all over 2 I/O tasks")

# --- 3. message continuation between two ranks ---------------------------
tr = Transport(2, engine=engine)
recv = tr.irecv(1, source=0, tag=7)
engine.continue_when(
    recv, lambda st, d: print(f"  [cb] rank 1 got: {st[0].payload!r} "
                              f"(tag {st[0].tag})"),
    status=[None], cr=cr)
threading.Thread(target=lambda: tr.isend(0, 1, 7, b"hello from rank 0")).start()

# --- polling service (paper Listing 2): progress until drained -----------
while not cr.test():
    time.sleep(0.001)
print("all continuations completed; CR is idle")
pool.shutdown()
engine.shutdown()

# --- 4. the serving front-end: a continuation-fed token stream -----------
from repro.configs import get_config          # noqa: E402
from repro.models import lm                   # noqa: E402
from repro.serve import GenerationConfig, ServeClient  # noqa: E402

cfg = get_config("paper_demo", reduced=True)
params = lm.init_params(jax.random.PRNGKey(0), cfg)
prompt = jax.random.randint(jax.random.PRNGKey(1), (8,), 0, cfg.vocab_size)
with ServeClient(cfg, params, max_batch=2, max_cache_len=32) as client:
    stream = client.generate(prompt, GenerationConfig(max_tokens=6))
    # each token is delivered by its decode step's completion
    # continuation — the stream wakes per token, not at retirement
    print("  [stream]", *(f"tok={t}" for t in stream))
    print(f"stream done ({stream.reason}); "
          f"ttft={stream.request.ttft * 1e3:.0f}ms")
