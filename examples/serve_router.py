"""Multi-replica front door example: affinity, fairness, and failover.

A ``Router`` fronts two serving replicas behind one ``EngineLike``
surface. The demo drives three acts:

1. **Prefix affinity** — a burst of requests sharing a system-prompt
   prefix. The router content-hashes prompts with the same chained page
   digests the ``PagePool`` indexes resident pages under, replicas
   gossip their digest sets on a control tag, and the burst concentrates
   on one replica where the shared pages already live (watch the
   hit-rate and the pools' prefix-reuse counters).
2. **Tenant fairness** — two tenants with 3:1 weights flood the intake;
   the weighted deficit scheduler interleaves admissions at the weight
   ratio, and a third tenant hits its quota and is refused with a
   retry-after hint.
3. **Failover** — mid-decode, one replica is killed. Its heartbeats
   stop, the monitor's sweep continuation declares it dead, cancels its
   pending receives, requeues its in-flight requests at the head of
   their class, and greedy replay on the survivor finishes every stream
   token-identically — the client-side streams never notice beyond a
   latency blip.

Run:  PYTHONPATH=src python examples/serve_router.py [--arch paper_demo]
(the architecture must support the paged KV cache: dense/MoE family,
scan_layers, no sliding window)
"""
import argparse
import time

import jax

from repro.configs import get_config
from repro.models import lm
from repro.serve import (GenerationConfig, QuotaExceeded, Request, Router,
                         serve_requests)


def main(args):
    cfg = get_config(args.arch, reduced=True)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    geometry = dict(max_batch=2, max_cache_len=48, paged=True, page_size=4,
                    max_seq_len=48)
    system_prefix = list(range(1, 9))          # two full pages @ 4

    print("== single-engine baseline (for token-identity checks) ==")
    trace = [system_prefix + [100 + i] for i in range(8)]
    colo = serve_requests(cfg, params,
                          [Request(p, args.new_tokens) for p in trace],
                          timeout=600, **geometry)
    baseline = {tuple(p): list(r.tokens) for p, r in zip(trace, colo)}
    print(f"   {len(colo)} requests done")

    print("== act 1: prefix affinity over 2 replicas ==")
    router = Router(cfg, params, n_replicas=2,
                    weights={"gold": 3.0, "bronze": 1.0},
                    quota={"capped": 1},
                    heartbeat_timeout_s=0.15, sweep_interval_s=0.01,
                    **geometry)
    reqs = [router.submit(Request(p, args.new_tokens)) for p in trace]
    router.run(timeout=600, until=lambda: len(router.retired) == len(reqs))
    m = router.metrics()
    print(f"   affinity hit rate: {m['affinity_hit_rate']:.2f} "
          f"({m['affinity_hits']} hits / {m['routed']} routed)")
    for w in router.workers:
        s = w.pool.stats
        print(f"   replica {w.rank}: prefix_tokens_reused="
              f"{s['prefix_tokens_reused']}")
    assert all(r.tokens == baseline[tuple(p)]
               for p, r in zip(trace, reqs)), "token identity broken"

    print("== act 2: weighted tenant fairness + quota ==")
    gold = GenerationConfig(max_tokens=args.new_tokens, tenant="gold")
    bronze = GenerationConfig(max_tokens=args.new_tokens, tenant="bronze")
    fair = [router.submit(Request(trace[i % len(trace)],
                                  gold if i % 2 == 0 else bronze))
            for i in range(8)]
    capped = GenerationConfig(max_tokens=args.new_tokens, tenant="capped")
    router.submit(Request(trace[0], capped))
    try:
        router.submit(Request(trace[1], capped))
        print("   !! quota not enforced")
    except QuotaExceeded as e:
        print(f"   tenant {e.tenant!r} over quota, retry in "
              f"~{e.retry_after_s * 1e3:.0f}ms")
    router.run(timeout=600, until=lambda: router.idle)
    for tenant, s in sorted(router.batcher.tenant_stats.items()):
        print(f"   {tenant:>8}: admitted={s['admitted']} "
              f"tokens={s['admitted_tokens']}")
    del fair

    print("== act 3: kill a replica mid-decode ==")
    wave = [router.submit(Request(p, args.new_tokens)) for p in trace]
    victim, deadline = None, time.monotonic() + 300
    while victim is None and time.monotonic() < deadline:
        router.step()
        for t in router._tracked.values():
            if t.rank is not None and t.original.delivered >= 2:
                victim = t.rank
                break
    print(f"   killing replica {victim} "
          f"(requests in flight: {len(router._tracked)})")
    router.kill_replica(victim)
    router.close_intake()
    router.run(timeout=600)
    m = router.metrics()
    lost = sum(1 for r in wave if not r.tokens)
    identical = all(r.tokens == baseline[tuple(p)]
                    for p, r in zip(trace, wave))
    print(f"   failovers={m['failovers']} requeued={m['requeued']} "
          f"lost={lost} token_identical={identical}")
    print(f"   survivors: {[w.rank for w in router.live_workers]}, "
          f"pages leaked: "
          f"{sum(w.pool.pages_in_use for w in router.workers)}")
    router.shutdown()
    assert lost == 0 and identical
    print("OK — zero requests lost, all streams token-identical")


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="paper_demo")
    ap.add_argument("--new-tokens", type=int, default=10)
    main(ap.parse_args())
