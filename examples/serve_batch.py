"""Continuous-batching serving example (continuation-driven).

Serves a reduced-config model (CPU) through the streaming session API
(``repro.serve.ServeClient``): requests are admitted into decode slots as
they arrive (admission queues on a ``poll_only`` continuation request, so
bursts never preempt the decode loop), each vmapped decode step advances
every occupied slot by one token, and per-step ``ArrayOp`` continuations
deliver tokens into each request's ``TokenStream`` and retire finished
sequences — freeing their slots for waiting requests immediately instead
of padding along to the longest member of a static batch.

Run:  PYTHONPATH=src python examples/serve_batch.py [--arch h2o_danube3_4b]
"""
import argparse
import time

import jax

from repro.configs import get_config
from repro.models import lm
from repro.serve import ServeClient

if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="h2o_danube3_4b",
                    help="architecture (reduced config is used)")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=4,
                    help="decode slots (max concurrent sequences)")
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--new-tokens", type=int, default=16,
                    help="max new tokens; request i gets 4 + i*3 capped here")
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=True)
    key = jax.random.PRNGKey(0)
    params = lm.init_params(key, cfg)
    prompts = jax.random.randint(jax.random.fold_in(key, 1),
                                 (args.requests, args.prompt_len), 0,
                                 cfg.vocab_size)
    # heterogeneous output lengths — where continuous batching shines
    lengths = [min(args.new_tokens, 4 + 3 * i) for i in range(args.requests)]

    with ServeClient(cfg, params, max_batch=args.slots,
                     max_cache_len=args.prompt_len + args.new_tokens
                     ) as client:
        session = client.session()
        t0 = time.time()
        streams = [session.generate(prompts[i], max_tokens=lengths[i])
                   for i in range(args.requests)]
        tokens = [s.result(timeout=600) for s in streams]
        dt = time.time() - t0
        print(f"arch={cfg.name} requests={args.requests} slots={args.slots} "
              f"prompt={args.prompt_len}")
        for s, toks in zip(streams, tokens):
            r = s.request
            print(f"  req {r.req_id}: ttft={r.ttft * 1e3:7.1f}ms "
                  f"n={len(toks):2d} tokens={toks}")
        m = client.metrics()
        n_tok = m["total_tokens"]
        print(f"{n_tok} tokens in {dt:.2f}s ({n_tok / dt:.1f} tok/s incl. "
              f"compile); steps={m['steps']} slot_steps={m['slot_steps']} "
              f"padded={m['padded_steps']}")
