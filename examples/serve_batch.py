"""Batched serving example: prefill + KV-cache greedy decode.

Serves a reduced-config model (CPU): one prefill over the prompt batch,
then token-by-token decode with donated caches — the same
``prefill_step``/``serve_step`` programs the dry-run lowers at the
32k/500k shapes.

Run:  PYTHONPATH=src python examples/serve_batch.py [--arch h2o_danube3_4b]
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import lm
from repro.serve.steps import greedy_generate

if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="h2o_danube3_4b",
                    help="architecture (reduced config is used)")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--new-tokens", type=int, default=16)
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=True)
    key = jax.random.PRNGKey(0)
    params = lm.init_params(key, cfg)
    prompts = jax.random.randint(jax.random.fold_in(key, 1),
                                 (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size)
    t0 = time.time()
    out = greedy_generate(cfg, params, prompts, args.new_tokens,
                          max_cache_len=args.prompt_len + args.new_tokens)
    dt = time.time() - t0
    print(f"arch={cfg.name} batch={args.batch} "
          f"prompt={args.prompt_len} new={args.new_tokens}")
    for i in range(args.batch):
        print(f"  req {i}: {list(map(int, out[i]))}")
    n_tok = args.batch * args.new_tokens
    print(f"{n_tok} tokens in {dt:.2f}s ({n_tok / dt:.1f} tok/s incl. "
          f"compile)")
