"""End-to-end tracing example: one correlated request timeline.

Arms the observability runtime (``repro.obs``) around a serving run and
writes ``trace.json`` in Chrome trace_event format — load it in
https://ui.perfetto.dev (or ``chrome://tracing``) and each request reads
left-to-right on its own track: admission span, prefill span, per-block
KV ship/import instants (disaggregated tier), every decode-step span,
token deliveries, finish. Runtime-internal continuation lifecycle events
(posted → ready → enqueued → ran) land on a shared "runtime" process,
and the four lifecycle-edge latency histograms (the paper's notification
latency among them) are embedded in the JSON and printed per policy.

Run:  PYTHONPATH=src python examples/serve_trace.py [--tier engine|disagg]
      PYTHONPATH=src python examples/serve_trace.py --sample 0.5
"""
import argparse

import jax

from repro import obs
from repro.configs import get_config
from repro.models import lm
from repro.obs import events as E
from repro.serve import Request, RequestState, serve_requests
from repro.serve.disagg import DisaggServer


def main(args):
    cfg = get_config(args.arch, reduced=True)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    prompts = [
        jax.random.randint(jax.random.PRNGKey(i), (args.prompt_len,),
                           0, cfg.vocab_size).tolist()
        for i in range(args.requests)
    ]
    geometry = dict(max_batch=args.slots,
                    max_cache_len=args.prompt_len + args.new_tokens,
                    page_size=4,
                    max_seq_len=args.prompt_len + args.new_tokens)

    print(f"== traced {args.tier} run "
          f"(sample={args.sample:g}, {args.requests} requests) ==")
    reqs = [Request(p, args.new_tokens) for p in prompts]
    rec = obs.Recorder(sample=args.sample)
    with rec:
        if args.tier == "disagg":
            srv = DisaggServer(cfg, params, chunk_pages=1, **geometry)
            try:
                for r in reqs:
                    srv.submit(r)
                srv.close_intake()
                srv.run(timeout=600)
                metrics = srv.metrics()
            finally:
                srv.shutdown()
        else:
            reqs = serve_requests(cfg, params, reqs, paged=True,
                                  timeout=600, **geometry)
            metrics = None
    assert all(r.req_state is RequestState.FINISHED for r in reqs)

    # ------------------------------------------- one request's timeline
    rid = reqs[0].req_id
    tl = [ev for ev in rec.events if ev.rid == rid]
    print(f"   request {rid} timeline ({len(tl)} events):")
    t0 = tl[0].ts if tl else 0.0
    for ev in tl:
        span = f" +{ev.dur * 1e3:.2f}ms" if ev.dur else ""
        meta = "" if ev.meta is None else f"  {ev.meta}"
        print(f"     {(ev.ts - t0) * 1e3:9.2f}ms  {ev.kind:<18} "
              f"[{ev.src}]{span}{meta}")

    # ------------------------------------- lifecycle latency histograms
    print("   continuation lifecycle latencies (us), per edge x policy:")
    hists = rec.histograms
    for edge in E.LIFECYCLE_EDGES:
        for (e, pkey), h in sorted(hists.items()):
            if e == edge:
                d = h.to_dict()
                print(f"     {edge:<20} {pkey:<16} n={d['count']:<5} "
                      f"mean={d['mean_us']:<10g} p99={d['p99_us']:g}")
    missing = set(E.LIFECYCLE_EDGES) - {e for e, _ in hists}
    assert not missing, f"lifecycle edges never observed: {missing}"

    cause = rec.cause_summary()
    print(f"   where time went (means/request): "
          f"queue {cause['queue_delay_ms_mean']}ms, "
          f"compute {cause['compute_ms_mean']}ms, "
          f"shipping {cause['shipping_ms_mean']}ms, "
          f"notify {cause['notify_latency_us_mean']}us")
    print(f"   {cause['events']} events, {cause['dropped']} dropped")

    if metrics is not None:
        text = rec.prometheus(metrics, transport=metrics["transport"])
        print("   prometheus snapshot (first lines):")
        for line in text.splitlines()[:6]:
            print(f"     {line}")

    path = rec.write(args.out)
    print(f"   wrote {path} -> open https://ui.perfetto.dev and load it")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="paper_demo")
    ap.add_argument("--tier", choices=("engine", "disagg"),
                    default="disagg",
                    help="disagg adds KV ship/import events to the track")
    ap.add_argument("--sample", type=float, default=1.0,
                    help="request/continuation sampling rate (0..1]; "
                    "complete timelines are guaranteed at 1.0")
    ap.add_argument("--out", default="trace.json")
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--new-tokens", type=int, default=8)
    main(ap.parse_args())
