"""Streaming-session serving example: an SSE-style async gateway.

Several concurrent asyncio "connections" share one ``ServeClient``; each
calls ``session.generate(prompt, ...)`` and relays the resulting
``TokenStream`` as Server-Sent-Events-style lines (``data: <tok>``) the
moment each token's decode step completes — the continuation-driven
per-token path, no polling thread, first token long before retirement.
The demo also exercises the rest of the surface: a stop sequence, a
mid-stream ``cancel()``, a QoS deadline, and priority tiers.

Run:  PYTHONPATH=src python examples/serve_stream.py [--arch h2o_danube3_4b]
"""
import argparse
import asyncio
import time

import jax

from repro.configs import get_config
from repro.models import lm
from repro.serve import DeadlineExceeded, GenerationConfig, ServeClient


async def sse_connection(name, session, prompt, t0, **overrides):
    """One gateway connection: stream tokens out as SSE data lines."""
    stream = session.generate(prompt, **overrides)
    n = 0
    async for tok in stream:
        n += 1
        print(f"  [{name} +{time.time() - t0:5.2f}s] data: {tok}")
        if name == "cancelled" and n == 3:
            stream.cancel()          # client went away after 3 tokens
    # iteration always ends cleanly; the close reason says why, and the
    # promise surface (tokens()/text()) rejects on expiry/cancel
    if stream.reason == "expired":
        try:
            await stream.tokens()
        except DeadlineExceeded as exc:
            print(f"  [{name}] event: expired ({exc})")
    else:
        print(f"  [{name}] event: done ({stream.reason}, {n} tokens, "
              f"lagging={stream.lagging})")
    return name, n


async def main(args):
    cfg = get_config(args.arch, reduced=True)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    prompts = jax.random.randint(jax.random.PRNGKey(1), (5, args.prompt_len),
                                 0, cfg.vocab_size)

    with ServeClient(cfg, params, max_batch=args.slots,
                     max_cache_len=args.prompt_len + 48) as client:
        # warm the compile cache so the timed streams measure decode only
        client.generate(prompts[0], max_tokens=2).result(timeout=300)

        session = client.session(max_tokens=args.new_tokens)
        t0 = time.time()
        # pick a stop sequence from the warmed request's continuation so
        # the "stopped" connection demonstrably truncates early
        probe = client.generate(prompts[1], max_tokens=8).result(timeout=300)
        results = await asyncio.gather(
            sse_connection("plain", session, prompts[1], t0),
            sse_connection("stopped", session, prompts[1], t0,
                           stop=[probe[4:6]]),
            sse_connection("cancelled", session, prompts[2], t0),
            sse_connection("deadline", session, prompts[3], t0,
                           max_tokens=40, deadline_s=0.25),
            sse_connection("priority", session, prompts[4], t0,
                           priority=5),
        )
        # the structured-config path also supports plain awaits:
        text = await session.generate(
            prompts[0], GenerationConfig(max_tokens=6)).text()
        print(f"  [await ] text(): {text!r}")
        m = client.metrics()
        print(f"done: {dict(results)} | retired={m['retired']} "
              f"stopped={m['stopped']} cancelled={m['cancelled']} "
              f"expired={m['expired']}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="h2o_danube3_4b",
                    help="architecture (reduced config is used)")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--new-tokens", type=int, default=12)
    asyncio.run(main(ap.parse_args()))
