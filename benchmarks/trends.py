"""Metric trend table over ``benchmarks/history.jsonl``.

``run.py`` appends one compact record per invocation (git SHA,
timestamp, gated + recorded metric values); this script turns the tail
of that log into a trend table so drift is visible *across* commits,
not just against the single committed baseline the regression gate
checks. For every metric it shows the last N observed values (oldest
first), the delta of the newest run against the one before it, and the
coefficient of variation over the window — a metric that wanders
run-to-run shows a fat cv long before it trips the gate.

Like ``check_regression.py`` this runs without ``PYTHONPATH=src`` (CI
calls it with the system python); stdlib only. Plain table to stdout,
``--summary PATH`` appends the markdown version (CI passes
``$GITHUB_STEP_SUMMARY``).
"""
from __future__ import annotations

import argparse
import json
import math
import sys
from pathlib import Path
from typing import Dict, List, Optional, Tuple

# trend rows: (metric, [values oldest->newest], delta, cv)
Row = Tuple[str, List[float], Optional[float], Optional[float]]


def load_history(path: Path) -> List[dict]:
    """Parse the jsonl log, skipping unparseable lines (a killed run can
    leave a torn tail; history is best-effort by design)."""
    records = []
    try:
        text = path.read_text()
    except OSError:
        return records
    for line in text.splitlines():
        line = line.strip()
        if not line:
            continue
        try:
            rec = json.loads(line)
        except json.JSONDecodeError:
            continue
        if isinstance(rec, dict):
            records.append(rec)
    return records


def _series(records: List[dict]) -> Dict[str, List[float]]:
    """Per-metric value series, oldest record first. Gated metrics lead
    (they are what the gate protects), recorded ones follow; a run that
    didn't measure a metric (``--only`` subset) just leaves a gap."""
    order: List[str] = []
    series: Dict[str, List[float]] = {}
    for group in ("metrics", "recorded"):
        for rec in records:
            for name in rec.get(group, {}):
                if name not in series:
                    order.append(name)
                    series[name] = []
    for name in order:
        for rec in records:
            val = rec.get("metrics", {}).get(name)
            if val is None:
                val = rec.get("recorded", {}).get(name)
            if val is not None:
                try:
                    series[name].append(float(val))
                except (TypeError, ValueError):
                    pass
    return {name: series[name] for name in order if series[name]}


def trend_rows(records: List[dict], last_n: int) -> List[Row]:
    rows: List[Row] = []
    for name, values in _series(records).items():
        window = values[-last_n:]
        delta = window[-1] - window[-2] if len(window) >= 2 else None
        cv = None
        if len(window) >= 2:
            mean = sum(window) / len(window)
            if abs(mean) > 1e-12:
                var = sum((v - mean) ** 2 for v in window) / len(window)
                cv = math.sqrt(var) / abs(mean)
        rows.append((name, window, delta, cv))
    return rows


def _fmt(v: Optional[float], signed: bool = False) -> str:
    if v is None:
        return "-"
    return f"{v:+.3f}" if signed else f"{v:.3f}"


def render_text(rows: List[Row], n_runs: int) -> str:
    header = (f"{'metric':<38} {'runs':>4} {'latest':>9} "
              f"{'delta':>8} {'cv':>6}  history (oldest first)")
    lines = [f"metric trends over the last {n_runs} run(s) in "
             f"history.jsonl", header, "-" * len(header)]
    for name, window, delta, cv in rows:
        hist = " ".join(f"{v:.3f}" for v in window)
        lines.append(f"{name:<38} {len(window):>4} {window[-1]:>9.3f} "
                     f"{_fmt(delta, signed=True):>8} {_fmt(cv):>6}  "
                     f"{hist}")
    return "\n".join(lines)


def render_markdown(rows: List[Row], n_runs: int) -> str:
    md = [f"### benchmark metric trends (last {n_runs} runs)", "",
          "| metric | runs | latest | Δ vs prev | cv | history |",
          "| --- | ---: | ---: | ---: | ---: | --- |"]
    for name, window, delta, cv in rows:
        hist = " ".join(f"{v:.3f}" for v in window)
        md.append(f"| {name} | {len(window)} | {window[-1]:.3f} "
                  f"| {_fmt(delta, signed=True)} | {_fmt(cv)} "
                  f"| {hist} |")
    return "\n".join(md)


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--history", default="benchmarks/history.jsonl",
                    help="jsonl log written by benchmarks/run.py")
    ap.add_argument("--last", type=int, default=8, metavar="N",
                    help="window size: newest N runs per metric")
    ap.add_argument("--summary", default=None, metavar="PATH",
                    help="append the markdown table to PATH (CI passes "
                    "$GITHUB_STEP_SUMMARY)")
    args = ap.parse_args()

    records = load_history(Path(args.history))
    if not records:
        # nothing to trend yet (fresh clone, first CI run): not an error
        print(f"no history records in {args.history}; nothing to trend")
        return 0
    n_runs = min(args.last, len(records))
    rows = trend_rows(records, args.last)
    print(render_text(rows, n_runs))
    if args.summary:
        with open(args.summary, "a") as f:
            f.write(render_markdown(rows, n_runs) + "\n")
    return 0


if __name__ == "__main__":
    sys.exit(main())
