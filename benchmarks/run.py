"""Benchmark harness — one benchmark per paper table/figure.

Output: ``name,us_per_call,derived`` CSV rows (+ a context comment per
block). Mapping to the paper (DESIGN.md §7):

  notification.*   §5.1 PoC overhead — completion-notification latency and
                   throughput, continuations vs the application-space
                   Testsome-window manager (the paper's headline claim).
  zones.*          Fig. 2/3 — NPB BT-MZ analogue: fork-join vs
                   continuation-released zone tasks, uneven zones.
  dataflow.*       Fig. 6 — PaRSEC/DPLASMA analogue: tiled-Cholesky DAG
                   makespan + activation latency, per-class CRs vs Testsome.
  offload.*        Fig. 8/9 — ExaHyPE analogue: diffusive offloading
                   throughput and critical-path wait.
  loc.*            Table 3 — lines of code of the submit/progress paths.
  overlap.*        beyond-paper: continuation-driven trainer I/O overlap.
"""
from __future__ import annotations

import inspect
import threading
import time
from typing import Callable, List

import numpy as np

ROWS: List[str] = []


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    row = f"{name},{us_per_call:.3f},{derived}"
    ROWS.append(row)
    print(row, flush=True)


def _timeit(fn: Callable, n: int, warmup: int = 1) -> float:
    for _ in range(warmup):
        fn()
    t0 = time.perf_counter()
    for _ in range(n):
        fn()
    return (time.perf_counter() - t0) / n * 1e6


# ===================================================== §5.1 notification
def bench_notification() -> None:
    from repro.core import Engine, Status, TestsomeManager
    from repro.core.completable import Completable

    class Op(Completable):
        def __init__(self):
            super().__init__()
            self.flag = False

        def trigger(self):
            self._complete(Status())

        def _poll(self):
            return self.flag

    # -- registration overhead (us/registration, incl. handle bookkeeping)
    eng = Engine()
    cr = eng.continue_init()

    def reg_continuation():
        op = Op()
        eng.continue_when(op, lambda st, d: None, cr=cr)
        op.trigger()

    us = _timeit(reg_continuation, 3000)
    emit("notification.register.continuation", us, "incl_trigger+run")
    cr.wait(timeout=10)

    mgr = TestsomeManager(window=32)

    def reg_testsome():
        op = Op()
        mgr.submit([op], lambda st, d: None)
        op.flag = True
        mgr.testsome()

    us = _timeit(reg_testsome, 3000)
    emit("notification.register.testsome_w32", us, "incl_trigger+run")

    # -- notification latency. For testsome, K cold outstanding ops sit
    # ahead in the window (PaRSEC's promotion artifact): latency grows
    # with the backlog.
    def latency_continuation() -> float:
        eng2 = Engine()
        cr2 = eng2.continue_init()
        lat = []
        for _ in range(300):
            op = Op()
            t_done = [0.0]
            eng2.continue_when(
                op, lambda st, d: t_done.__setitem__(0, time.perf_counter()),
                cr=cr2)
            t0 = time.perf_counter()
            op.trigger()          # push: runs inline on this thread
            lat.append(t_done[0] - t0)
        eng2.shutdown()
        return float(np.mean(lat)) * 1e6

    emit("notification.latency.continuation", latency_continuation(),
         "push_inline")

    # a completed-but-recently-posted op is invisible until promoted into
    # the window; ``backlog`` older ops drain in bursts ahead of it
    # (the PaRSEC §5.3 completion-detection delay)
    for backlog in (0, 64, 256):
        lat = []
        for _ in range(60):
            mgr2 = TestsomeManager(window=32)
            cold = [Op() for _ in range(backlog)]
            for c in cold:
                mgr2.submit([c], lambda st, d: None)
            op = Op()
            t_done = [0.0]
            mgr2.submit([op],
                        lambda st, d: t_done.__setitem__(0, time.perf_counter()))
            t0 = time.perf_counter()
            op.flag = True
            ci = 0
            while t_done[0] == 0.0:
                # older ops complete a few at a time while we poll
                for _ in range(4):
                    if ci < len(cold):
                        cold[ci].flag = True
                        ci += 1
                mgr2.testsome()
            lat.append(t_done[0] - t0)
        emit(f"notification.latency.testsome_backlog{backlog}",
             float(np.mean(lat)) * 1e6, "poll+promotion")

    # -- throughput: completions/s with many concurrent ops
    n = 20000
    eng3 = Engine()
    cr3 = eng3.continue_init({"mpi_continue_enqueue_complete": True})
    count = [0]
    ops = [Op() for _ in range(n)]
    for op in ops:
        eng3.continue_when(op, lambda st, d: count.__setitem__(0, count[0] + 1),
                           cr=cr3)
    t0 = time.perf_counter()
    for op in ops:
        op.trigger()
    while not cr3.test():
        pass
    dt = time.perf_counter() - t0
    emit("notification.throughput.continuation", dt / n * 1e6,
         f"{n / dt:.0f}_cb_per_s")
    eng3.shutdown()

    mgr3 = TestsomeManager(window=32)
    count2 = [0]
    ops = [Op() for _ in range(n)]
    for op in ops:
        mgr3.submit([op], lambda st, d: count2.__setitem__(0, count2[0] + 1))
    t0 = time.perf_counter()
    for op in ops:
        op.flag = True
    mgr3.drain()
    dt = time.perf_counter() - t0
    emit("notification.throughput.testsome_w32", dt / n * 1e6,
         f"{n / dt:.0f}_cb_per_s")
    eng.shutdown()


# ========================================================= Fig 2/3 zones
def bench_zones() -> None:
    from repro.zones.solver import distributed_solve, make_zones
    zones = make_zones(n_zones=8, ny=96, base_nx=16, max_ratio=20.0, seed=3)
    steps = 30
    results = {}
    for variant in ("fork_join", "continuations"):
        best = None
        for _ in range(3):
            z = [a.copy() for a in zones]
            _, timing = distributed_solve(z, n_ranks=4, timesteps=steps,
                                          variant=variant, smooth_iters=2)
            best = min(best, timing["elapsed"]) if best else timing["elapsed"]
        results[variant] = best
        emit(f"zones.{variant}", best / steps * 1e6, f"{steps}_steps_4_ranks")
    emit("zones.speedup", 0.0,
         f"{results['fork_join'] / results['continuations']:.3f}x")


# ======================================================= Fig 6 dataflow
def bench_dataflow() -> None:
    from repro.dataflow.cholesky import build_cholesky_graph, make_spd_matrix
    from repro.dataflow.runtime import (ContinuationBackend, TestsomeBackend,
                                        run_dataflow)
    nb, tile, ranks = 6, 64, 4
    A = make_spd_matrix(nb * tile, seed=5)
    results = {}
    for name, factory in (
            ("continuations", lambda eng: ContinuationBackend(eng)),
            ("testsome_w4", lambda eng: TestsomeBackend(4))):
        best, lat = None, 0.0
        for _ in range(3):
            graph, meta = build_cholesky_graph(A, nb, tile, ranks)
            _, stats = run_dataflow(graph, factory, timeout=120)
            if best is None or stats["makespan"] < best:
                best = stats["makespan"]
                lat = stats["mean_activation_latency"]
        results[name] = best
        emit(f"dataflow.cholesky.{name}", best * 1e6,
             f"act_lat_{lat * 1e6:.0f}us")
    emit("dataflow.speedup", 0.0,
         f"{results['testsome_w4'] / results['continuations']:.3f}x")


# ======================================================= Fig 8/9 offload
def _run_offload_backend(backend: str, iters: int = 8):
    import threading as th
    from repro.core import Engine, Transport
    from repro.runtime.offload import (ContinuationBackend, OffloadManager,
                                       TestsomeBackend)
    n_ranks, task_cost, imbalance = 4, 0.003, 6
    engine = Engine()
    tr = Transport(n_ranks, engine=engine)
    mk = (lambda: ContinuationBackend(engine)) if backend == "continuations" \
        else (lambda: TestsomeBackend(8))
    managers = [OffloadManager(r, n_ranks, tr, mk()) for r in range(n_ranks)]
    arrived = [0] * iters
    lock = th.Lock()
    wait_critical = [0.0]

    def barrier(mgr, it):
        with lock:
            arrived[it] += 1
        while True:
            with lock:
                if arrived[it] >= n_ranks:
                    return
            mgr.backend.progress()
            time.sleep(1e-4)

    def loop(rank):
        mgr = managers[rank]
        n_tasks = imbalance * 8 if rank == 0 else 8
        for it in range(iters):
            tasks = [mgr.new_task(task_cost) for _ in range(n_tasks)]
            pending = []
            loads = {r: (imbalance if r == 0 else 1.0)
                     for r in range(n_ranks)}
            budget = sum(mgr.quota.values())
            for t in tasks:
                target = mgr.pick_target(loads)
                if rank == 0 and target is not None and len(pending) < budget:
                    mgr.offload(t, target)
                    pending.append(t)
                    loads[target] += 1.0
                else:
                    time.sleep(task_cost)
                    t.done.set()
                mgr.backend.progress()
            missed = {}
            t_wait = time.monotonic()
            deadline = time.monotonic() + 5.0
            for t in pending:
                while not t.done.is_set() and time.monotonic() < deadline:
                    mgr.backend.progress()
                    time.sleep(5e-5)
                if not t.done.is_set():
                    missed[1] = True
            if rank == 0:
                wait_critical[0] += time.monotonic() - t_wait
            mgr.end_iteration(missed)
            barrier(mgr, it)
        mgr.stop()

    threads = [th.Thread(target=loop, args=(r,)) for r in range(n_ranks)]
    t0 = time.monotonic()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    total = time.monotonic() - t0
    offl = managers[0].stats["offloaded"]
    engine.shutdown()
    return total, offl, wait_critical[0]


def bench_offload() -> None:
    import examples.offload_lb as lb
    base, _ = lb.run(offloading=False, iters=8)
    results = {}
    for backend in ("continuations", "testsome"):
        t, offl, wait = _run_offload_backend(backend, iters=8)
        results[backend] = (t, offl)
        emit(f"offload.{backend}", t * 1e6,
             f"{offl}_offloaded_wait{wait * 1e3:.0f}ms")
    emit("offload.no_offloading", base * 1e6, "baseline")
    emit("offload.speedup_vs_baseline", 0.0,
         f"{base / results['continuations'][0]:.3f}x")


# ========================================================== Table 3 LoC
def bench_loc() -> None:
    """Measured LoC of the submit + progress paths in this repo."""
    from repro.core import engine as eng_mod
    from repro.core import testsome as ts_mod
    from repro.core.continuation import ContinuationRequest

    def loc(fn) -> int:
        src = inspect.getsource(fn)
        return sum(1 for line in src.splitlines()
                   if line.strip() and not line.strip().startswith(("#", '"')))

    emit("loc.submit.continuations", 0.0,
         f"{loc(eng_mod.Engine.continue_all)}_lines")
    emit("loc.submit.testsome", 0.0,
         f"{loc(ts_mod.TestsomeManager.submit)}_lines")
    emit("loc.progress.continuations", 0.0,
         f"{loc(ContinuationRequest.test)}_lines")
    emit("loc.progress.testsome", 0.0,
         f"{loc(ts_mod.TestsomeManager.testsome)}_lines")
    # application-side: one continue_all per group vs 3 parallel dicts
    emit("loc.app_parallel_structures.continuations", 0.0, "0_dicts")
    emit("loc.app_parallel_structures.testsome", 0.0,
         "3_dicts(op_group,groups,index)")


# =============================================== beyond paper: overlap
def bench_train_overlap() -> None:
    """Continuation-driven async checkpoint+prefetch vs blocking I/O."""
    import os
    import shutil
    import jax
    from repro.checkpoint.async_ckpt import AsyncCheckpointer
    from repro.configs import get_config
    from repro.core import Engine
    from repro.data.pipeline import PrefetchPipeline, SyntheticTokenSource
    from repro.optim import OptConfig
    from repro.train.train_step import init_train_state, make_train_step

    cfg = get_config("paper_demo", reduced=True)
    opt = OptConfig(lr=1e-3)
    steps, fill_latency = 12, 0.02
    step_fn = jax.jit(make_train_step(cfg, opt))

    def run_async() -> float:
        eng = Engine()
        state = init_train_state(jax.random.PRNGKey(0), cfg, opt)
        src = SyntheticTokenSource(cfg, 4, 64, fill_latency_s=fill_latency)
        pipe = PrefetchPipeline(src, eng, depth=2)
        ck = AsyncCheckpointer("/tmp/bench_ck_a", eng)
        jax.block_until_ready(step_fn(state, pipe.get_next())[0]["params"])
        t0 = time.perf_counter()
        handles = []
        for i in range(steps):
            batch = pipe.get_next()
            state, m = step_fn(state, batch)
            if (i + 1) % 4 == 0:
                handles.append(ck.save_async(i, state))
        jax.block_until_ready(state["params"])
        dt = time.perf_counter() - t0
        for h in handles:
            h.wait(timeout=60)
        pipe.close(); ck.close(); eng.shutdown()
        shutil.rmtree("/tmp/bench_ck_a", ignore_errors=True)
        return dt

    def run_blocking() -> float:
        state = init_train_state(jax.random.PRNGKey(0), cfg, opt)
        src = SyntheticTokenSource(cfg, 4, 64, fill_latency_s=fill_latency)
        jax.block_until_ready(step_fn(state, src.make_batch(0))[0]["params"])
        os.makedirs("/tmp/bench_ck_b", exist_ok=True)
        t0 = time.perf_counter()
        for i in range(steps):
            batch = src.make_batch(i)          # synchronous fill
            state, m = step_fn(state, batch)
            if (i + 1) % 4 == 0:               # synchronous save
                for j, leaf in enumerate(jax.tree_util.tree_leaves(state)):
                    np.save(f"/tmp/bench_ck_b/{j}.npy", np.asarray(leaf))
        jax.block_until_ready(state["params"])
        dt = time.perf_counter() - t0
        shutil.rmtree("/tmp/bench_ck_b", ignore_errors=True)
        return dt

    asy = min(run_async() for _ in range(2))
    blk = min(run_blocking() for _ in range(2))
    emit("overlap.trainer.async_continuations", asy / steps * 1e6, "")
    emit("overlap.trainer.blocking_reference", blk / steps * 1e6, "")
    emit("overlap.trainer.speedup", 0.0, f"{blk / asy:.3f}x")


def main() -> None:
    print("# name,us_per_call,derived")
    for bench in (bench_notification, bench_zones, bench_dataflow,
                  bench_offload, bench_loc, bench_train_overlap):
        print(f"# --- {bench.__name__} ---", flush=True)
        bench()


if __name__ == "__main__":
    main()
