"""Benchmark harness — one benchmark per paper table/figure.

Output: ``name,us_per_call,derived`` CSV rows (+ a context comment per
block). Mapping to the paper (DESIGN.md §7):

  notification.*   §5.1 PoC overhead — completion-notification latency and
                   throughput, continuations vs the application-space
                   Testsome-window manager (the paper's headline claim).
  zones.*          Fig. 2/3 — NPB BT-MZ analogue: fork-join vs
                   continuation-released zone tasks, uneven zones.
  dataflow.*       Fig. 6 — PaRSEC/DPLASMA analogue: tiled-Cholesky DAG
                   makespan + activation latency, per-class CRs vs Testsome.
  offload.*        Fig. 8/9 — ExaHyPE analogue: diffusive offloading
                   throughput and critical-path wait.
  loc.*            Table 3 — lines of code of the submit/progress paths.
  overlap.*        beyond-paper: continuation-driven trainer I/O overlap.
  scheduler.*      beyond-paper: fifo vs affinity ready-queue schedulers
                   under a multi-threaded completion storm.
  core.api.*       beyond-paper: the redesigned registration API —
                   per-registration flag overhead and awaitable-bridge
                   (``engine.wrap`` + asyncio) notification latency vs
                   the raw callback surface. Gated in CI (api block of
                   BENCH_serve.json).
  serve.*          beyond-paper: continuation-driven continuous batching vs
                   the synchronous static-batch ``greedy_generate`` loop,
                   bursty multi-request workload — tokens/s and p99 TTFT.
                   ``serve.paged.*`` adds dense vs paged-pool at equal cache
                   memory; ``serve.spec.*`` adds speculative (draft/verify)
                   vs plain paged decode on a repetition-friendly trace;
                   ``serve.stream.*`` adds the streaming session API
                   (per-token continuation delivery: TTFT speedup over
                   retirement delivery, inter-token p99, tokens/s
                   overhead); ``serve.disagg.*`` adds disaggregated
                   prefill/decode (role engines over the continuation
                   transport, per-block KV shipping) vs colocated. All
                   emitted machine-readable to BENCH_serve.json.

``--quick`` runs a CI-smoke subset (notification + scheduler + loc +
serve) at reduced sizes; ``--only BLOCK`` runs a single block by name.
"""
from __future__ import annotations

import argparse
import inspect
import json
import threading
import time
from typing import Callable, List

import numpy as np

ROWS: List[str] = []
QUICK = False
SAMPLES = 3          # measured samples per serve scenario (--samples)


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    row = f"{name},{us_per_call:.3f},{derived}"
    ROWS.append(row)
    print(row, flush=True)


def _append_block(block: str, payload: dict) -> None:
    """Merge one block into BENCH_serve.json (bench_serve creates it)."""
    try:
        with open("BENCH_serve.json") as f:
            doc = json.load(f)
    except (OSError, json.JSONDecodeError):
        doc = {}
    doc[block] = payload
    with open("BENCH_serve.json", "w") as f:
        json.dump(doc, f, indent=2)
    print(f"# appended {block} block to BENCH_serve.json", flush=True)


def _variance(samples: List[dict]) -> dict:
    """Per-metric {mean, cv, ci95, values} over per-sample dicts — the
    fields the variance-aware regression gate reads."""
    from repro.bench.stats import variance_fields
    return variance_fields(samples)


def _timeit(fn: Callable, n: int, warmup: int = 1) -> float:
    for _ in range(warmup):
        fn()
    t0 = time.perf_counter()
    for _ in range(n):
        fn()
    return (time.perf_counter() - t0) / n * 1e6


# ===================================================== §5.1 notification
def bench_notification() -> None:
    from repro.core import Engine, Status, TestsomeManager
    from repro.core.completable import Completable

    class Op(Completable):
        def __init__(self):
            super().__init__()
            self.flag = False

        def trigger(self):
            self._complete(Status())

        def _poll(self):
            return self.flag

    # -- registration overhead (us/registration, incl. handle bookkeeping)
    eng = Engine()
    cr = eng.continue_init()

    def reg_continuation():
        op = Op()
        eng.continue_when(op, lambda st, d: None, cr=cr)
        op.trigger()

    n_reg = 600 if QUICK else 3000
    us = _timeit(reg_continuation, n_reg)
    emit("notification.register.continuation", us, "incl_trigger+run")
    cr.wait(timeout=10)

    mgr = TestsomeManager(window=32)

    def reg_testsome():
        op = Op()
        mgr.submit([op], lambda st, d: None)
        op.flag = True
        mgr.testsome()

    us = _timeit(reg_testsome, n_reg)
    emit("notification.register.testsome_w32", us, "incl_trigger+run")

    # -- notification latency. For testsome, K cold outstanding ops sit
    # ahead in the window (PaRSEC's promotion artifact): latency grows
    # with the backlog.
    def latency_continuation() -> float:
        eng2 = Engine()
        cr2 = eng2.continue_init()
        lat = []
        for _ in range(300):
            op = Op()
            t_done = [0.0]
            eng2.continue_when(
                op, lambda st, d: t_done.__setitem__(0, time.perf_counter()),
                cr=cr2)
            t0 = time.perf_counter()
            op.trigger()          # push: runs inline on this thread
            lat.append(t_done[0] - t0)
        eng2.shutdown()
        return float(np.mean(lat)) * 1e6

    emit("notification.latency.continuation", latency_continuation(),
         "push_inline")

    # a completed-but-recently-posted op is invisible until promoted into
    # the window; ``backlog`` older ops drain in bursts ahead of it
    # (the PaRSEC §5.3 completion-detection delay)
    for backlog in ((0, 64) if QUICK else (0, 64, 256)):
        lat = []
        for _ in range(15 if QUICK else 60):
            mgr2 = TestsomeManager(window=32)
            cold = [Op() for _ in range(backlog)]
            for c in cold:
                mgr2.submit([c], lambda st, d: None)
            op = Op()
            t_done = [0.0]
            mgr2.submit([op],
                        lambda st, d: t_done.__setitem__(0, time.perf_counter()))
            t0 = time.perf_counter()
            op.flag = True
            ci = 0
            while t_done[0] == 0.0:
                # older ops complete a few at a time while we poll
                for _ in range(4):
                    if ci < len(cold):
                        cold[ci].flag = True
                        ci += 1
                mgr2.testsome()
            lat.append(t_done[0] - t0)
        emit(f"notification.latency.testsome_backlog{backlog}",
             float(np.mean(lat)) * 1e6, "poll+promotion")

    # -- throughput: completions/s with many concurrent ops
    n = 4000 if QUICK else 20000
    eng3 = Engine()
    cr3 = eng3.continue_init({"mpi_continue_enqueue_complete": True})
    count = [0]
    ops = [Op() for _ in range(n)]
    for op in ops:
        eng3.continue_when(op, lambda st, d: count.__setitem__(0, count[0] + 1),
                           cr=cr3)
    t0 = time.perf_counter()
    for op in ops:
        op.trigger()
    while not cr3.test():
        pass
    dt = time.perf_counter() - t0
    emit("notification.throughput.continuation", dt / n * 1e6,
         f"{n / dt:.0f}_cb_per_s")
    eng3.shutdown()

    mgr3 = TestsomeManager(window=32)
    count2 = [0]
    ops = [Op() for _ in range(n)]
    for op in ops:
        mgr3.submit([op], lambda st, d: count2.__setitem__(0, count2[0] + 1))
    t0 = time.perf_counter()
    for op in ops:
        op.flag = True
    mgr3.drain()
    dt = time.perf_counter() - t0
    emit("notification.throughput.testsome_w32", dt / n * 1e6,
         f"{n / dt:.0f}_cb_per_s")
    eng.shutdown()


# ========================================================= Fig 2/3 zones
def bench_zones() -> None:
    from repro.zones.solver import distributed_solve, make_zones
    zones = make_zones(n_zones=8, ny=96, base_nx=16, max_ratio=20.0, seed=3)
    steps = 30
    results = {}
    for variant in ("fork_join", "continuations"):
        best = None
        for _ in range(3):
            z = [a.copy() for a in zones]
            _, timing = distributed_solve(z, n_ranks=4, timesteps=steps,
                                          variant=variant, smooth_iters=2)
            best = min(best, timing["elapsed"]) if best else timing["elapsed"]
        results[variant] = best
        emit(f"zones.{variant}", best / steps * 1e6, f"{steps}_steps_4_ranks")
    emit("zones.speedup", 0.0,
         f"{results['fork_join'] / results['continuations']:.3f}x")


# ======================================================= Fig 6 dataflow
def bench_dataflow() -> None:
    from repro.dataflow.cholesky import build_cholesky_graph, make_spd_matrix
    from repro.dataflow.runtime import (ContinuationBackend, TestsomeBackend,
                                        run_dataflow)
    nb, tile, ranks = 6, 64, 4
    A = make_spd_matrix(nb * tile, seed=5)
    results = {}
    for name, factory in (
            ("continuations", lambda eng: ContinuationBackend(eng)),
            ("testsome_w4", lambda eng: TestsomeBackend(4))):
        best, lat = None, 0.0
        for _ in range(3):
            graph, meta = build_cholesky_graph(A, nb, tile, ranks)
            _, stats = run_dataflow(graph, factory, timeout=120)
            if best is None or stats["makespan"] < best:
                best = stats["makespan"]
                lat = stats["mean_activation_latency"]
        results[name] = best
        emit(f"dataflow.cholesky.{name}", best * 1e6,
             f"act_lat_{lat * 1e6:.0f}us")
    emit("dataflow.speedup", 0.0,
         f"{results['testsome_w4'] / results['continuations']:.3f}x")


# ======================================================= Fig 8/9 offload
def _run_offload_backend(backend: str, iters: int = 8):
    import threading as th
    from repro.core import Engine, Transport
    from repro.runtime.offload import (ContinuationBackend, OffloadManager,
                                       TestsomeBackend)
    n_ranks, task_cost, imbalance = 4, 0.003, 6
    engine = Engine()
    tr = Transport(n_ranks, engine=engine)
    mk = (lambda: ContinuationBackend(engine)) if backend == "continuations" \
        else (lambda: TestsomeBackend(8))
    managers = [OffloadManager(r, n_ranks, tr, mk()) for r in range(n_ranks)]
    arrived = [0] * iters
    lock = th.Lock()
    wait_critical = [0.0]

    def barrier(mgr, it):
        with lock:
            arrived[it] += 1
        while True:
            with lock:
                if arrived[it] >= n_ranks:
                    return
            mgr.backend.progress()
            time.sleep(1e-4)

    def loop(rank):
        mgr = managers[rank]
        n_tasks = imbalance * 8 if rank == 0 else 8
        for it in range(iters):
            tasks = [mgr.new_task(task_cost) for _ in range(n_tasks)]
            pending = []
            loads = {r: (imbalance if r == 0 else 1.0)
                     for r in range(n_ranks)}
            budget = sum(mgr.quota.values())
            for t in tasks:
                target = mgr.pick_target(loads)
                if rank == 0 and target is not None and len(pending) < budget:
                    mgr.offload(t, target)
                    pending.append(t)
                    loads[target] += 1.0
                else:
                    time.sleep(task_cost)
                    t.done.set()
                mgr.backend.progress()
            missed = {}
            t_wait = time.monotonic()
            deadline = time.monotonic() + 5.0
            for t in pending:
                while not t.done.is_set() and time.monotonic() < deadline:
                    mgr.backend.progress()
                    time.sleep(5e-5)
                if not t.done.is_set():
                    missed[1] = True
            if rank == 0:
                wait_critical[0] += time.monotonic() - t_wait
            mgr.end_iteration(missed)
            barrier(mgr, it)
        mgr.stop()

    threads = [th.Thread(target=loop, args=(r,)) for r in range(n_ranks)]
    t0 = time.monotonic()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    total = time.monotonic() - t0
    offl = managers[0].stats["offloaded"]
    engine.shutdown()
    return total, offl, wait_critical[0]


def bench_offload() -> None:
    import examples.offload_lb as lb
    base, _ = lb.run(offloading=False, iters=8)
    results = {}
    for backend in ("continuations", "testsome"):
        t, offl, wait = _run_offload_backend(backend, iters=8)
        results[backend] = (t, offl)
        emit(f"offload.{backend}", t * 1e6,
             f"{offl}_offloaded_wait{wait * 1e3:.0f}ms")
    emit("offload.no_offloading", base * 1e6, "baseline")
    emit("offload.speedup_vs_baseline", 0.0,
         f"{base / results['continuations'][0]:.3f}x")


# ========================================================== Table 3 LoC
def bench_loc() -> None:
    """Measured LoC of the submit + progress paths in this repo."""
    from repro.core import engine as eng_mod
    from repro.core import testsome as ts_mod
    from repro.core.continuation import ContinuationRequest

    def loc(fn) -> int:
        src = inspect.getsource(fn)
        return sum(1 for line in src.splitlines()
                   if line.strip() and not line.strip().startswith(("#", '"')))

    emit("loc.submit.continuations", 0.0,
         f"{loc(eng_mod.Engine.continue_all)}_lines")
    emit("loc.submit.testsome", 0.0,
         f"{loc(ts_mod.TestsomeManager.submit)}_lines")
    emit("loc.progress.continuations", 0.0,
         f"{loc(ContinuationRequest.test)}_lines")
    emit("loc.progress.testsome", 0.0,
         f"{loc(ts_mod.TestsomeManager.testsome)}_lines")
    # application-side: one continue_all per group vs 3 parallel dicts
    emit("loc.app_parallel_structures.continuations", 0.0, "0_dicts")
    emit("loc.app_parallel_structures.testsome", 0.0,
         "3_dicts(op_group,groups,index)")


# =============================================== beyond paper: overlap
def bench_train_overlap() -> None:
    """Continuation-driven async checkpoint+prefetch vs blocking I/O."""
    import os
    import shutil
    import jax
    from repro.checkpoint.async_ckpt import AsyncCheckpointer
    from repro.configs import get_config
    from repro.core import Engine
    from repro.data.pipeline import PrefetchPipeline, SyntheticTokenSource
    from repro.optim import OptConfig
    from repro.train.train_step import init_train_state, make_train_step

    cfg = get_config("paper_demo", reduced=True)
    opt = OptConfig(lr=1e-3)
    steps, fill_latency = 12, 0.02
    step_fn = jax.jit(make_train_step(cfg, opt))

    def run_async() -> float:
        eng = Engine()
        state = init_train_state(jax.random.PRNGKey(0), cfg, opt)
        src = SyntheticTokenSource(cfg, 4, 64, fill_latency_s=fill_latency)
        pipe = PrefetchPipeline(src, eng, depth=2)
        ck = AsyncCheckpointer("/tmp/bench_ck_a", eng)
        jax.block_until_ready(step_fn(state, pipe.get_next())[0]["params"])
        t0 = time.perf_counter()
        handles = []
        for i in range(steps):
            batch = pipe.get_next()
            state, m = step_fn(state, batch)
            if (i + 1) % 4 == 0:
                handles.append(ck.save_async(i, state))
        jax.block_until_ready(state["params"])
        dt = time.perf_counter() - t0
        for h in handles:
            h.wait(timeout=60)
        pipe.close(); ck.close(); eng.shutdown()
        shutil.rmtree("/tmp/bench_ck_a", ignore_errors=True)
        return dt

    def run_blocking() -> float:
        state = init_train_state(jax.random.PRNGKey(0), cfg, opt)
        src = SyntheticTokenSource(cfg, 4, 64, fill_latency_s=fill_latency)
        jax.block_until_ready(step_fn(state, src.make_batch(0))[0]["params"])
        os.makedirs("/tmp/bench_ck_b", exist_ok=True)
        t0 = time.perf_counter()
        for i in range(steps):
            batch = src.make_batch(i)          # synchronous fill
            state, m = step_fn(state, batch)
            if (i + 1) % 4 == 0:               # synchronous save
                for j, leaf in enumerate(jax.tree_util.tree_leaves(state)):
                    np.save(f"/tmp/bench_ck_b/{j}.npy", np.asarray(leaf))
        jax.block_until_ready(state["params"])
        dt = time.perf_counter() - t0
        shutil.rmtree("/tmp/bench_ck_b", ignore_errors=True)
        return dt

    asy = min(run_async() for _ in range(2))
    blk = min(run_blocking() for _ in range(2))
    emit("overlap.trainer.async_continuations", asy / steps * 1e6, "")
    emit("overlap.trainer.blocking_reference", blk / steps * 1e6, "")
    emit("overlap.trainer.speedup", 0.0, f"{blk / asy:.3f}x")


# ==================================== scheduler: ready-queue contention
def bench_scheduler() -> None:
    """fifo (shared deque + one lock) vs affinity (per-thread queues with
    stealing) under a multi-threaded completion storm — the hot
    submit→inline-drain path the affinity scheduler optimizes."""
    from repro.core import Engine, Status
    from repro.core.completable import Completable

    class Op(Completable):
        @property
        def supports_push(self):
            return True

        def trigger(self):
            self._complete(Status())

    n_threads = 4
    per_thread = 2000 if QUICK else 10000
    results = {}
    for sched in ("fifo", "affinity"):
        eng = Engine(scheduler=sched)
        crs = [eng.continue_init() for _ in range(n_threads)]

        def worker(cr):
            for _ in range(per_thread):
                op = Op()
                eng.continue_when(op, lambda st, d: None, cr=cr)
                op.trigger()     # discover + execute on this thread

        threads = [threading.Thread(target=worker, args=(cr,))
                   for cr in crs]
        t0 = time.perf_counter()
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        for cr in crs:
            cr.wait(timeout=30)
        dt = time.perf_counter() - t0
        results[sched] = dt
        n_ops = n_threads * per_thread
        emit(f"scheduler.storm.{sched}", dt / n_ops * 1e6,
             f"{n_threads}_threads_{n_ops / dt:.0f}_cb_per_s")
        eng.shutdown()
    emit("scheduler.storm.affinity_speedup", 0.0,
         f"{results['fifo'] / results['affinity']:.3f}x")


# ====================================== beyond paper: continuous batching
def _serve_workload(n_requests: int, n_slots: int):
    """Bursty request trace: an initial burst of 2×slots, then stragglers.

    Output lengths vary ~4..28 tokens — the regime where continuous
    batching beats static batching (no padding to the longest member, no
    waiting for a batch to fill).
    """
    lengths = [(4 + 6 * (i % 5)) for i in range(n_requests)]       # 4..28
    burst = min(n_requests, 2 * n_slots)
    arrivals = [0.0] * burst + [0.03 * (i + 1)
                                for i in range(n_requests - burst)]
    return lengths, arrivals


def bench_serve() -> None:
    """Continuation-driven continuous batching vs synchronous static
    batching built on the same jitted prefill/decode steps (the
    ``greedy_generate`` loop, compile-warmed for fairness).

    The continuous side is driven through the ``repro.bench`` harness —
    the same bursty workload frozen into a seeded ``Trace`` and replayed
    ``SAMPLES`` times by a ``Replayer`` over the real ``ServeClient``
    streaming surface — so the headline ratios carry variance fields
    (mean/cv/ci95) instead of a single roll of the load dice.
    """
    import random as pyrandom

    import jax
    import jax.numpy as jnp
    from repro.bench import Replayer, Trace, TraceRequest
    from repro.configs import get_config
    from repro.models import lm
    from repro.serve import ServeEngine
    from repro.serve.request import _percentile
    from repro.serve.steps import make_decode_step, make_prefill_step

    cfg = get_config("paper_demo", reduced=True)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    n_slots, prompt_len, cache_len = 4, 8, 64
    n_requests = 8 if QUICK else 16
    lengths, arrivals = _serve_workload(n_requests, n_slots)
    seed = 1
    prng = pyrandom.Random(seed)
    trace = Trace(
        requests=tuple(TraceRequest(
            arrival_s=arrivals[i],
            prompt=tuple(prng.randrange(cfg.vocab_size)
                         for _ in range(prompt_len)),
            max_tokens=lengths[i]) for i in range(n_requests)),
        meta={"name": "serve_burst", "seed": seed,
              "vocab_size": cfg.vocab_size})
    prompts = jnp.asarray([list(r.prompt) for r in trace.requests],
                          dtype=jnp.int32)
    useful_tokens = sum(lengths)

    # ---- continuous batching (continuation-driven), via the harness ----
    # dense slots: this block isolates the scheduling win; the memory win
    # is measured separately by bench_serve_paged (dense vs paged pool)
    replayer = Replayer(ServeEngine(cfg, params, max_batch=n_slots,
                                    max_cache_len=cache_len, paged=False),
                        name="continuous")

    # ---- static batching (synchronous greedy_generate loop) ----
    prefill = jax.jit(make_prefill_step(cfg, cache_len))
    decode = jax.jit(make_decode_step(cfg), donate_argnums=(1,))

    def static_generate(batch_prompts, n_tokens):
        """The greedy_generate loop body, on pre-jitted (warm) steps."""
        logits, cache = prefill(params, {"tokens": batch_prompts})
        pos = batch_prompts.shape[1]
        out = [jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)]
        for i in range(n_tokens - 1):
            logits, cache = decode(params, cache, out[-1][:, None],
                                   jnp.int32(pos + i))
            out.append(jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32))
        return jnp.stack(out, axis=1)

    jax.block_until_ready(static_generate(prompts[:n_slots], 2))  # warm

    def static_trial():
        """One paced pass of the synchronous loop over the trace."""
        t0 = time.monotonic()
        static_ttft = []
        done = 0
        while done < n_requests:
            now = time.monotonic() - t0
            ready = [i for i in range(done, n_requests)
                     if arrivals[i] <= now]
            if not ready:
                time.sleep(1e-3)
                continue
            batch = ready[:n_slots]
            idx = list(batch) + [batch[-1]] * (n_slots - len(batch))  # pad
            n_steps = max(lengths[i] for i in batch)
            out = static_generate(prompts[jnp.asarray(idx)], n_steps)
            jax.block_until_ready(out)   # synchronous: block per batch
            t_end = time.monotonic() - t0
            # tokens observable only when the whole batch finishes
            static_ttft.extend(t_end - arrivals[i] for i in batch)
            done += len(batch)
        return time.monotonic() - t0, static_ttft

    def p99(vals):
        return _percentile(sorted(vals), 0.99)

    def p50(vals):
        return _percentile(sorted(vals), 0.50)

    static_trial()   # throwaway: full-trace Python-dispatch warm

    # interleave continuous/static samples (alternating order per sample)
    # so machine-load drift hits both variants alike; every sample of each
    # feeds the variance fields the regression gate reads
    cont_results, static_results = [], []
    for s in range(SAMPLES):
        pair = [lambda: cont_results.extend(replayer.run(trace, samples=1)),
                lambda: static_results.append(static_trial())]
        for f in (pair if s % 2 == 0 else reversed(pair)):
            f()
    replayer.close()

    per_sample = []
    for res, (s_mk, s_ttft) in zip(cont_results, static_results):
        m = res.metrics()
        s_tps = useful_tokens / s_mk
        per_sample.append({
            "continuous_tokens_per_s": m["tokens_per_s"],
            "continuous_makespan_s": m["makespan_s"],
            "continuous_ttft_p50_s": m["ttft_p50_s"],
            "continuous_ttft_p99_s": m["ttft_p99_s"],
            "static_tokens_per_s": s_tps,
            "static_makespan_s": s_mk,
            "static_ttft_p50_s": p50(s_ttft),
            "static_ttft_p99_s": p99(s_ttft),
            "speedup_tokens_per_s": m["tokens_per_s"] / s_tps,
            "ttft_p99_ratio": p99(s_ttft) / m["ttft_p99_s"],
        })
    var = _variance(per_sample)

    def mean(key):
        return var[key]["mean"]

    emit("serve.continuous_batching",
         mean("continuous_makespan_s") / useful_tokens * 1e6,
         f"{mean('continuous_tokens_per_s'):.0f}_tok_per_s_ttft_p99_"
         f"{mean('continuous_ttft_p99_s') * 1e3:.0f}ms")
    emit("serve.static_greedy",
         mean("static_makespan_s") / useful_tokens * 1e6,
         f"{mean('static_tokens_per_s'):.0f}_tok_per_s_ttft_p99_"
         f"{mean('static_ttft_p99_s') * 1e3:.0f}ms")
    emit("serve.speedup", 0.0,
         f"{mean('speedup_tokens_per_s'):.3f}x_cv_"
         f"{var['speedup_tokens_per_s']['cv']:.3f}")
    with open("BENCH_serve.json", "w") as f:
        json.dump({
            "workload": {"n_requests": n_requests, "n_slots": n_slots,
                         "prompt_len": prompt_len, "lengths": lengths,
                         "arrivals_s": arrivals, "trace_seed": seed,
                         "trace_name": trace.name},
            "samples": SAMPLES,
            "continuous": {
                "tokens_per_s": mean("continuous_tokens_per_s"),
                "makespan_s": mean("continuous_makespan_s"),
                "ttft_p50_s": mean("continuous_ttft_p50_s"),
                "ttft_p99_s": mean("continuous_ttft_p99_s")},
            "static_greedy": {
                "tokens_per_s": mean("static_tokens_per_s"),
                "makespan_s": mean("static_makespan_s"),
                "ttft_p50_s": mean("static_ttft_p50_s"),
                "ttft_p99_s": mean("static_ttft_p99_s")},
            "speedup_tokens_per_s": mean("speedup_tokens_per_s"),
            "variance": var,
        }, f, indent=2)
    print("# wrote BENCH_serve.json", flush=True)


# ============================= beyond paper: paged KV cache + prefix reuse
def bench_serve_paged() -> None:
    """Dense per-slot cache vs paged pool at EQUAL cache memory.

    Dense pre-allocates ``n_slots × cache_len`` tokens of KV; paged holds
    the same token budget as a shared page pool, so shorter-than-worst-case
    sequences and a shared prompt prefix translate into more concurrent
    slots (effective batch) and higher tokens/s on the same bursty trace.
    Appends a ``paged`` block to BENCH_serve.json.
    """
    import jax
    import jax.numpy as jnp
    from repro.configs import get_config
    from repro.models import lm
    from repro.serve import Request, ServeEngine
    from repro.serve.request import _percentile

    cfg = get_config("paper_demo", reduced=True)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)

    # workload: shared system prefix + unique 4-token tail per request
    # (the prefix-cache regime: every request after the first maps the
    # full shared page and runs only the tail through one chunked
    # suffix-prefill call). Bursty arrivals, varied output lengths. On
    # CPU decode compute scales linearly with batch, so tokens/s is
    # load-noisy around 1-2x — the stable structural win at equal cache
    # memory is the 2x effective batch (on accelerators, where batch
    # amortizes, the tokens/s follows it).
    n_requests = 8 if QUICK else 16
    page_size, prompt_len, shared_len = 8, 16, 12
    dense_slots, dense_cache_len = 4, 64              # 256 cached tokens
    paged_slots, total_pages, max_seq = 8, 31, 48     # 31+1 scratch = 256
    lengths = [(4 + 6 * (i % 5)) for i in range(n_requests)]      # 4..28
    burst = min(n_requests, 2 * dense_slots)
    arrivals = [0.0] * burst + [0.03 * (i + 1)
                                for i in range(n_requests - burst)]
    common = jax.random.randint(jax.random.PRNGKey(2), (shared_len,), 0,
                                cfg.vocab_size)
    tails = jax.random.randint(jax.random.PRNGKey(3),
                               (n_requests, prompt_len - shared_len), 0,
                               cfg.vocab_size)
    prompts = [jnp.concatenate([common, tails[i]]) for i in range(n_requests)]
    useful_tokens = sum(lengths)
    # warm prompts: same shapes, disjoint tokens (released pages drop out
    # of the prefix index, so the measured run still sees one cold miss)
    warm_prompts = jax.random.randint(jax.random.PRNGKey(4),
                                      (2, prompt_len), 0, cfg.vocab_size)

    def make_engine(**engine_kwargs):
        serve = ServeEngine(cfg, params, **engine_kwargs)
        warm = [Request(warm_prompts[0], 2),
                Request(jnp.concatenate([warm_prompts[0][:shared_len],
                                         warm_prompts[1][shared_len:]]), 2)]
        for r in warm:                      # warms prefill+decode+suffix
            serve.submit(r)
        serve._bench_done = len(warm)
        serve.run(until=lambda: len(serve.retired) == serve._bench_done,
                  timeout=120)
        return serve

    def measure(serve):
        # drop prior-phase counters (warmup, earlier samples) so the
        # reported metrics reflect only this sample's trace — released
        # pages fall out of the prefix index, so every sample sees the
        # same one-cold-miss-per-run structure
        serve.stats.update(max_active=0, deferred=0)
        if serve.paged:
            serve.pool.stats.update(prefix_hits=0, prefix_tokens_reused=0,
                                    peak_in_use=serve.pool.pages_in_use)

        reqs = [Request(prompts[i], lengths[i]) for i in range(n_requests)]
        t0 = time.monotonic()

        def submitter():
            for req, dt in zip(reqs, arrivals):
                now = time.monotonic() - t0
                if dt > now:
                    time.sleep(dt - now)
                req.arrival_time = time.monotonic()
                serve.submit(req)

        sub = threading.Thread(target=submitter)
        sub.start()
        serve._bench_done += n_requests
        serve.run(until=lambda: len(serve.retired) == serve._bench_done,
                  timeout=300)
        sub.join()
        makespan = max(r.finish_time for r in reqs) - t0
        out = {
            "tokens_per_s": useful_tokens / makespan,
            "makespan_s": makespan,
            "ttft_p50_s": _percentile(sorted(r.ttft for r in reqs), 0.50),
            "ttft_p99_s": _percentile(sorted(r.ttft for r in reqs), 0.99),
            "effective_batch": serve.stats["max_active"],
            "cached_tokens_budget": (dense_slots * dense_cache_len),
        }
        m = serve.metrics()
        if m.get("paged"):
            out.update({k: m[k] for k in ("prefix_hits",
                                          "prefix_tokens_reused",
                                          "peak_in_use", "total_pages",
                                          "page_size", "deferred")})
        return out

    # interleave dense/paged samples (alternating order) so load drift
    # hits both variants alike; headline dicts keep the best (min
    # makespan) sample, the variance fields carry all of them
    dense_eng = make_engine(max_batch=dense_slots,
                            max_cache_len=dense_cache_len, paged=False)
    paged_eng = make_engine(max_batch=paged_slots,
                            max_cache_len=dense_cache_len, paged=True,
                            page_size=page_size, max_seq_len=max_seq,
                            total_pages=total_pages)
    dense = paged = None
    per_sample = []
    for rep in range(SAMPLES):
        if rep % 2 == 0:
            d, p = measure(dense_eng), measure(paged_eng)
        else:
            p, d = measure(paged_eng), measure(dense_eng)
        per_sample.append({
            "dense_tokens_per_s": d["tokens_per_s"],
            "paged_tokens_per_s": p["tokens_per_s"],
            "speedup_tokens_per_s":
                p["tokens_per_s"] / d["tokens_per_s"],
            "effective_batch_ratio":
                p["effective_batch"] / d["effective_batch"],
        })
        if dense is None or d["makespan_s"] < dense["makespan_s"]:
            dense = d
        if paged is None or p["makespan_s"] < paged["makespan_s"]:
            paged = p
    dense_eng.shutdown()
    paged_eng.shutdown()
    var = _variance(per_sample)

    emit("serve.paged.dense_baseline",
         dense["makespan_s"] / useful_tokens * 1e6,
         f"{dense['tokens_per_s']:.0f}_tok_per_s_batch{dense['effective_batch']}")
    emit("serve.paged.paged_pool",
         paged["makespan_s"] / useful_tokens * 1e6,
         f"{paged['tokens_per_s']:.0f}_tok_per_s_batch{paged['effective_batch']}"
         f"_hits{paged['prefix_hits']}")
    emit("serve.paged.effective_batch", 0.0,
         f"{paged['effective_batch'] / dense['effective_batch']:.2f}x"
         f"_at_{dense_slots * dense_cache_len}_cached_tokens")
    emit("serve.paged.speedup", 0.0,
         f"{paged['tokens_per_s'] / dense['tokens_per_s']:.3f}x")

    _append_block("paged", {
        "workload": {"n_requests": n_requests, "prompt_len": prompt_len,
                     "shared_prefix_len": shared_len, "lengths": lengths,
                     "arrivals_s": arrivals,
                     "cached_tokens_budget": dense_slots * dense_cache_len},
        "samples": SAMPLES,
        "dense": dense, "paged": paged,
        "effective_batch_ratio":
            paged["effective_batch"] / dense["effective_batch"],
        "speedup_tokens_per_s":
            paged["tokens_per_s"] / dense["tokens_per_s"],
        "variance": var,
    })


# ==================== fused paged-attention kernel vs unfused steps
def bench_serve_kernel() -> None:
    """Fused paged-attention serving (ONE kernel: on-device page-table
    gather + flash-attend + accept-masked KV write) vs the unfused
    gather/scatter paged steps, at identical pool geometry and workload.

    Tokens/s ratio plus static ``cost_analysis`` (flops / bytes accessed)
    of the two compiled decode steps — the unfused step materializes a
    ``(S, max_pages*page_size, KV, hd)`` contiguous view per layer and
    scatters written pages back, traffic the fused kernel never emits.
    Also records ``fused_kernel_active``: whether this runner lowers the
    real Pallas kernel (TPU) or the jnp reference fallback (CPU CI) —
    the regression gate only enforces the fused >= dense floor when the
    real kernel ran. Appends a ``kernel`` block to BENCH_serve.json.
    """
    import jax
    import numpy as np_
    from repro.configs import get_config
    from repro.kernels import impl as impl_mod
    from repro.models import lm
    from repro.serve import Request, ServeEngine

    cfg = get_config("paper_demo", reduced=True)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)

    n_requests = 6 if QUICK else 12
    n_slots, page_size, prompt_len, max_seq = 4, 8, 16, 64
    length = 24
    repeats = max(3, SAMPLES)
    useful_tokens = n_requests * length

    def make_engine(fused):
        eng = ServeEngine(cfg, params, max_batch=n_slots,
                          max_cache_len=max_seq, paged=True, fused=fused,
                          page_size=page_size, max_seq_len=max_seq)
        wbase = np_.arange(prompt_len) + 300
        warm = [Request(wbase, 4),
                Request(np_.concatenate([wbase[:8], np_.arange(8) + 400]),
                        4)]
        for r in warm:                # warms prefill, suffix, decode
            eng.submit(r)
        eng._bench_done = len(warm)
        eng.run(until=lambda: len(eng.retired) == eng._bench_done,
                timeout=200)
        return eng

    def trial(eng, rep):
        prompts = [np_.arange(prompt_len) + 17 * rep + 31 * i
                   for i in range(n_requests)]
        reqs = [Request(p % (cfg.vocab_size - 1), length) for p in prompts]
        t0 = time.monotonic()
        for r in reqs:
            eng.submit(r)
        eng._bench_done += n_requests
        eng.run(until=lambda: len(eng.retired) == eng._bench_done,
                timeout=300)
        return time.monotonic() - t0

    def step_cost(eng):
        """Static compiled-cost of one decode step (flops/bytes)."""
        import jax.numpy as jnp_
        args = [eng.params, eng.pool.arrays,
                jnp_.zeros((n_slots, 1, 1), jnp_.int32),
                jnp_.zeros((n_slots,), jnp_.int32),
                jnp_.zeros((n_slots, eng._table_pages), jnp_.int32)]
        if eng.fused:
            args.append(jnp_.ones((n_slots,), jnp_.int32))
        try:
            ca = eng._decode_fn.lower(*args).compile().cost_analysis()
            if isinstance(ca, (list, tuple)):
                ca = ca[0] if ca else {}
            return {"flops": float(ca.get("flops", 0.0)),
                    "bytes_accessed": float(ca.get("bytes accessed", 0.0))}
        except Exception:                      # backend without analysis
            return {"flops": 0.0, "bytes_accessed": 0.0}

    fused_eng, unfused_eng = make_engine(True), make_engine(False)
    fused_best = unfused_best = None
    per_rep = []
    for rep in range(repeats):   # interleave so load drift hits both
        if rep % 2 == 0:
            f, u = trial(fused_eng, rep), trial(unfused_eng, rep)
        else:
            u, f = trial(unfused_eng, rep), trial(fused_eng, rep)
        per_rep.append({"fused_tokens_per_s": useful_tokens / f,
                        "unfused_tokens_per_s": useful_tokens / u,
                        "speedup_tokens_per_s": u / f})
        fused_best = f if fused_best is None else min(fused_best, f)
        unfused_best = u if unfused_best is None else min(unfused_best, u)
    var = _variance(per_rep)

    fused_cost = step_cost(fused_eng)
    unfused_cost = step_cost(unfused_eng)
    active = impl_mod.resolve_runnable() == "pallas"
    fused_eng.shutdown()
    unfused_eng.shutdown()

    fused_tps = useful_tokens / fused_best
    unfused_tps = useful_tokens / unfused_best
    emit("serve.kernel.fused", fused_best / useful_tokens * 1e6,
         f"{fused_tps:.0f}_tok_per_s_{'pallas' if active else 'xla_ref'}")
    emit("serve.kernel.unfused", unfused_best / useful_tokens * 1e6,
         f"{unfused_tps:.0f}_tok_per_s")
    emit("serve.kernel.speedup", 0.0,
         f"{fused_tps / unfused_tps:.3f}x_fused_vs_unfused")
    if unfused_cost["bytes_accessed"]:
        emit("serve.kernel.step_bytes_ratio", 0.0,
             f"{fused_cost['bytes_accessed'] / unfused_cost['bytes_accessed']:.3f}"
             "x_fused_vs_unfused")

    _append_block("kernel", {
        "workload": {"n_requests": n_requests, "n_slots": n_slots,
                     "prompt_len": prompt_len, "length": length,
                     "page_size": page_size, "max_seq_len": max_seq,
                     "repeats_best_of": repeats},
        "samples": repeats,
        "fused_kernel_active": active,
        "fused": {"tokens_per_s": fused_tps, "makespan_s": fused_best,
                  "step_cost": fused_cost},
        "unfused": {"tokens_per_s": unfused_tps,
                    "makespan_s": unfused_best,
                    "step_cost": unfused_cost},
        "speedup_tokens_per_s": fused_tps / unfused_tps,
        "variance": var,
    })


# ========================= beyond paper: self-speculative decoding
def bench_serve_spec() -> None:
    """Speculative (draft/verify) vs plain paged decode at EQUAL cache
    memory on a repetition-friendly workload (tiled-motif prompts whose
    greedy continuations settle into cycles — the regime prompt-lookup
    drafting targets). Tokens/s and accept rate; both engines share the
    same pool geometry, warmed through every shape (cold prefill, shared
    suffix, verify, retirement continuations) before timing. Appends a
    ``spec`` block to BENCH_serve.json.
    """
    import jax
    import numpy as np_
    from repro.configs import get_config
    from repro.models import lm
    from repro.serve import Request, ServeEngine

    cfg = get_config("paper_demo", reduced=True)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)

    n_requests = 6 if QUICK else 10
    n_slots, page_size, prompt_len, max_seq = 4, 8, 16, 64
    speculate, length = 4, 48
    repeats = max(3, SAMPLES)
    motif = np_.array([5, 11, 3, 7])
    useful_tokens = n_requests * length

    def make_engine(spec_k):
        eng = ServeEngine(cfg, params, max_batch=n_slots,
                          max_cache_len=max_seq, paged=True,
                          page_size=page_size, max_seq_len=max_seq,
                          speculate=spec_k)
        # warm every shape the trace hits: cold prefill, shared-prefix
        # suffix, decode/verify, retirement continuations. Token ids stay
        # inside the reduced vocab (512) and clear of the measured
        # prompts' range (< ~220), so warm pages can never alias them.
        wbase = np_.arange(prompt_len) + 300
        warm = [Request(wbase, 6),
                Request(np_.concatenate([wbase[:12], np_.arange(4) + 400]),
                        6),
                Request(np_.arange(prompt_len) + 450, 6)]
        for r in warm:
            eng.submit(r)
        eng._bench_done = len(warm)
        eng.run(until=lambda: len(eng.retired) == eng._bench_done,
                timeout=200)
        # drop warm-phase counters so the reported (and gated) accept
        # rate / step counts reflect only the measured trace
        eng.stats.update(steps=0, verify_steps=0, slot_steps=0,
                         padded_steps=0, spec_tokens=0, draft_proposed=0,
                         draft_accepted=0)
        return eng

    def trial(eng, rep):
        # shift token values per repeat: fresh pages, no stale
        # prefix-cache hits inflating later repeats
        prompts = [np_.tile(np_.roll(motif, i % 4), prompt_len // 4)
                   + 101 * rep + i // 4 for i in range(n_requests)]
        reqs = [Request(p, length) for p in prompts]
        t0 = time.monotonic()
        for r in reqs:
            eng.submit(r)
        eng._bench_done += n_requests
        eng.run(until=lambda: len(eng.retired) == eng._bench_done,
                timeout=300)
        return time.monotonic() - t0

    def summarize_variant(eng, best):
        m = eng.metrics()
        out = {
            "tokens_per_s": useful_tokens / best,
            "makespan_s": best,
            "verify_steps": m["verify_steps"],
            "steps": m["steps"],
            "accept_rate": m.get("accept_rate_engine", 0.0),
            "draft_proposed": m["draft_proposed"],
            "draft_accepted": m["draft_accepted"],
        }
        eng.shutdown()
        return out

    # interleave baseline/speculative trials (alternating order each
    # repeat) so machine-load drift hits both variants alike; report
    # each variant's best repeat
    base_eng, spec_eng = make_engine(0), make_engine(speculate)
    base_best = spec_best = None
    per_rep = []
    for rep in range(repeats):
        if rep % 2 == 0:
            b, s = trial(base_eng, rep), trial(spec_eng, rep)
        else:
            s, b = trial(spec_eng, rep), trial(base_eng, rep)
        per_rep.append({"baseline_tokens_per_s": useful_tokens / b,
                        "spec_tokens_per_s": useful_tokens / s,
                        "speedup_tokens_per_s": b / s})
        base_best = b if base_best is None else min(base_best, b)
        spec_best = s if spec_best is None else min(spec_best, s)
    var = _variance(per_rep)
    base = summarize_variant(base_eng, base_best)
    spec = summarize_variant(spec_eng, spec_best)

    emit("serve.spec.paged_baseline",
         base["makespan_s"] / useful_tokens * 1e6,
         f"{base['tokens_per_s']:.0f}_tok_per_s")
    emit("serve.spec.speculative",
         spec["makespan_s"] / useful_tokens * 1e6,
         f"{spec['tokens_per_s']:.0f}_tok_per_s"
         f"_accept{spec['accept_rate']:.2f}")
    emit("serve.spec.accept_rate", 0.0,
         f"{spec['accept_rate']:.3f}"
         f"_{spec['draft_accepted']}of{spec['draft_proposed']}")
    emit("serve.spec.speedup", 0.0,
         f"{spec['tokens_per_s'] / base['tokens_per_s']:.3f}x")

    _append_block("spec", {
        "workload": {"n_requests": n_requests, "n_slots": n_slots,
                     "prompt_len": prompt_len, "length": length,
                     "page_size": page_size, "max_seq_len": max_seq,
                     "speculate": speculate, "repeats_best_of": repeats},
        "samples": repeats,
        "paged_baseline": base,
        "speculative": spec,
        "speedup_tokens_per_s":
            spec["tokens_per_s"] / base["tokens_per_s"],
        "variance": var,
    })


# ===================== beyond paper: streaming session API (per-token)
def bench_serve_stream() -> None:
    """Streaming (per-token continuation delivery through ``TokenStream``)
    vs retirement delivery (``submit()``: tokens observable only when the
    request finishes) on the same engine geometry and workload.

    The streaming claims, measured as ratios so CI can gate them
    hardware-portably:

    * ``ttft_speedup`` — mean time to the first *observable* token:
      retirement-mode first-observable (= request latency) over streaming
      TTFT. First tokens must arrive well before retirement.
    * ``tokens_per_s_ratio`` — streaming tokens/s over retirement
      tokens/s: the inter-token overhead of per-token delivery. On CPU
      this reads ~0.8-1.0 — each token wakes a consumer thread, and the
      GIL handoff steals cycles from the Python-heavy dispatch path —
      while the decode loop itself never blocks on a consumer (the
      failure mode the gate exists for, which lands at 0.1-0.3x).

    Inter-token p99 gap is recorded (ms, informational). Appends a
    ``stream`` block to BENCH_serve.json.
    """
    import jax
    from repro.configs import get_config
    from repro.models import lm
    from repro.serve import GenerationConfig, Request, ServeClient, \
        ServeEngine
    from repro.serve.request import _percentile

    cfg = get_config("paper_demo", reduced=True)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    # one request per slot: no admission queueing, so the measured TTFT
    # gap is purely delivery timing (first step completion vs retirement)
    # — the serve.* block already measures batching under oversubscription
    n_requests = n_slots = 4
    prompt_len, length = 8, 32
    max_seq = prompt_len + length
    repeats = max(3 if QUICK else 5, SAMPLES)
    prompts = jax.random.randint(jax.random.PRNGKey(5),
                                 (n_requests, prompt_len), 0, cfg.vocab_size)
    useful_tokens = n_requests * length

    def make_engine():
        eng = ServeEngine(cfg, params, max_batch=n_slots,
                          max_cache_len=max_seq)
        warm = [Request(prompts[0], 2), Request(prompts[1], 2)]
        for r in warm:
            eng.submit(r)
        eng._bench_done = len(warm)
        eng.run(until=lambda: len(eng.retired) == eng._bench_done,
                timeout=200)
        return eng

    def batch_trial(eng):
        """Retirement delivery: tokens observable at finish only."""
        reqs = [Request(prompts[i], GenerationConfig(max_tokens=length))
                for i in range(n_requests)]
        t0 = time.monotonic()
        for r in reqs:
            r.arrival_time = time.monotonic()
            eng.submit(r)
        eng._bench_done += n_requests
        eng.run(until=lambda: len(eng.retired) == eng._bench_done,
                timeout=300)
        first_observable = [r.finish_time - r.arrival_time for r in reqs]
        return max(r.finish_time for r in reqs) - t0, first_observable

    def stream_trial(client):
        """Per-token delivery: consumers time every token's arrival."""
        session = client.session(max_tokens=length)
        times = [[] for _ in range(n_requests)]
        streams = [None] * n_requests

        def consume(i):
            for _ in streams[i]:
                times[i].append(time.monotonic())

        t0 = time.monotonic()
        threads = []
        for i in range(n_requests):
            streams[i] = session.generate(prompts[i])
            threads.append(threading.Thread(target=consume, args=(i,)))
            threads[-1].start()
        for t in threads:
            t.join()
        makespan = max(ts[-1] for ts in times) - t0
        ttfts = [ts[0] - s.request.arrival_time
                 for ts, s in zip(times, streams)]
        gaps = [b - a for ts in times for a, b in zip(ts, ts[1:])]
        return makespan, ttfts, gaps

    # interleave the two variants (alternating order per repeat) so
    # machine-load drift hits both alike; report each variant's best
    batch_eng = make_engine()
    stream_client = ServeClient(engine=make_engine())
    batch_best = stream_best = None
    batch_first, stream_ttfts, stream_gaps = [], [], []
    per_rep = []
    for rep in range(repeats):
        if rep % 2 == 0:
            b = batch_trial(batch_eng)
            s = stream_trial(stream_client)
        else:
            s = stream_trial(stream_client)
            b = batch_trial(batch_eng)
        per_rep.append({
            "ttft_speedup": (sum(b[1]) / len(b[1]))
            / (sum(s[1]) / len(s[1])),
            "tokens_per_s_ratio": b[0] / s[0],
        })
        if batch_best is None or b[0] < batch_best:
            batch_best, batch_first = b
        if stream_best is None or s[0] < stream_best:
            stream_best, stream_ttfts, stream_gaps = s
    var = _variance(per_rep)
    batch_eng.shutdown()
    stream_client.close()

    batch_tps = useful_tokens / batch_best
    stream_tps = useful_tokens / stream_best
    ttft_stream = sum(stream_ttfts) / len(stream_ttfts)
    ttft_batch = sum(batch_first) / len(batch_first)
    inter_p99 = _percentile(sorted(stream_gaps), 0.99)
    ttft_speedup = ttft_batch / ttft_stream
    tps_ratio = stream_tps / batch_tps

    emit("serve.stream.stream_delivery", stream_best / useful_tokens * 1e6,
         f"{stream_tps:.0f}_tok_per_s_ttft_{ttft_stream * 1e3:.0f}ms")
    emit("serve.stream.retirement_baseline",
         batch_best / useful_tokens * 1e6,
         f"{batch_tps:.0f}_tok_per_s_first_observable_"
         f"{ttft_batch * 1e3:.0f}ms")
    emit("serve.stream.ttft_speedup", 0.0, f"{ttft_speedup:.3f}x")
    emit("serve.stream.inter_token_p99", inter_p99 * 1e6, "per_gap")
    emit("serve.stream.tokens_per_s_ratio", 0.0,
         f"{tps_ratio:.3f}x_vs_retirement")

    _append_block("stream", {
        "workload": {"n_requests": n_requests, "n_slots": n_slots,
                     "prompt_len": prompt_len, "length": length,
                     "repeats_best_of": repeats},
        "samples": repeats,
        "streaming": {"tokens_per_s": stream_tps,
                      "makespan_s": stream_best,
                      "ttft_mean_s": ttft_stream,
                      "inter_token_p99_s": inter_p99},
        "retirement": {"tokens_per_s": batch_tps,
                       "makespan_s": batch_best,
                       "first_observable_mean_s": ttft_batch},
        "ttft_speedup": ttft_speedup,
        "tokens_per_s_ratio": tps_ratio,
        "variance": var,
    })


# ==================== beyond paper: disaggregated prefill/decode roles
def bench_serve_disagg() -> None:
    """Disaggregated prefill/decode (role engines connected by the
    continuation transport, KV pages shipped per-block as chunked prefill
    produces them) vs the colocated paged engine on the same workload and
    decode geometry.

    Reported as a ratio so CI stays hardware-portable:

    * ``tokens_per_s_ratio`` — disaggregated tokens/s over colocated.
      Recorded (not gated): in-process the transport hop is pure
      overhead — export slices, typed messages, per-block install — so
      the interesting signal is how CLOSE the role split stays to
      colocated (~0.7-1.0x on CPU), i.e. the price of an honest
      transport boundary before multi-host shipping makes it pay.
    * TTFT mean for both: the prefill role delivers the first token
      itself, so disaggregation must not regress time-to-first-token.
    * ``bytes_shipped_per_request`` — KV actually crossing the boundary
      (prompt pages × page_nbytes), from the transport's per-tag
      accounting.

    Appends a ``disagg`` block to BENCH_serve.json.
    """
    import jax
    from repro.configs import get_config
    from repro.models import lm
    from repro.serve import Request, serve_requests
    from repro.serve.disagg import DisaggServer

    cfg = get_config("paper_demo", reduced=True)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    n_requests = 6 if QUICK else 12
    prompt_len, length = 12, 24
    page_size = 4
    max_seq = prompt_len + length
    key = jax.random.PRNGKey(11)
    prompts = jax.random.randint(key, (n_requests, prompt_len), 0,
                                 cfg.vocab_size)
    useful_tokens = n_requests * length

    def mk_reqs():
        rs = [Request(prompts[i], length) for i in range(n_requests)]
        for r in rs:
            r.arrival_time = time.monotonic()
        return rs

    def colocated_trial():
        reqs = mk_reqs()
        t0 = time.monotonic()
        serve_requests(cfg, params, reqs, max_batch=4,
                       max_cache_len=max_seq, paged=True,
                       page_size=page_size, max_seq_len=max_seq,
                       timeout=600)
        dt = time.monotonic() - t0
        ttfts = [r.ttft for r in reqs if r.ttft is not None]
        return dt, sum(ttfts) / len(ttfts)

    def disagg_trial():
        reqs = mk_reqs()
        srv = DisaggServer(cfg, params, max_batch=4, max_cache_len=max_seq,
                           page_size=page_size, max_seq_len=max_seq,
                           chunk_pages=1)
        t0 = time.monotonic()
        try:
            for r in reqs:
                srv.submit(r)
            srv.close_intake()
            srv.run(timeout=600)
            dt = time.monotonic() - t0
            m = srv.metrics()
        finally:
            srv.shutdown()
        ttfts = [r.ttft for r in reqs if r.ttft is not None]
        return dt, sum(ttfts) / len(ttfts), m

    # warm both compile caches, then best-of-N with interleaved order
    colocated_trial()
    disagg_trial()
    repeats = max(2 if QUICK else 3, SAMPLES)
    colo_best = dis_best = None
    colo_ttft = dis_ttft = 0.0
    dis_metrics = {}
    per_rep = []
    for rep in range(repeats):
        trials = (colocated_trial, disagg_trial) if rep % 2 == 0 \
            else (disagg_trial, colocated_trial)
        rep_colo = rep_dis = None
        for t in trials:
            if t is colocated_trial:
                dt, ttft = t()
                rep_colo = dt
                if colo_best is None or dt < colo_best:
                    colo_best, colo_ttft = dt, ttft
            else:
                dt, ttft, m = t()
                rep_dis = dt
                if dis_best is None or dt < dis_best:
                    dis_best, dis_ttft, dis_metrics = dt, ttft, m
        per_rep.append({
            "colocated_tokens_per_s": useful_tokens / rep_colo,
            "disagg_tokens_per_s": useful_tokens / rep_dis,
            "tokens_per_s_ratio": rep_colo / rep_dis,
        })
    var = _variance(per_rep)

    colo_tps = useful_tokens / colo_best
    dis_tps = useful_tokens / dis_best
    tps_ratio = dis_tps / colo_tps
    bytes_per_req = dis_metrics["bytes_shipped_per_request"]

    emit("serve.disagg.disaggregated", dis_best / useful_tokens * 1e6,
         f"{dis_tps:.0f}_tok_per_s_ttft_{dis_ttft * 1e3:.0f}ms")
    emit("serve.disagg.colocated_baseline",
         colo_best / useful_tokens * 1e6,
         f"{colo_tps:.0f}_tok_per_s_ttft_{colo_ttft * 1e3:.0f}ms")
    emit("serve.disagg.tokens_per_s_ratio", 0.0,
         f"{tps_ratio:.3f}x_vs_colocated")
    emit("serve.disagg.bytes_shipped_per_request", 0.0,
         f"{bytes_per_req:.0f}B_{dis_metrics['blocks_shipped']}_blocks")

    _append_block("disagg", {
        "workload": {"n_requests": n_requests, "prompt_len": prompt_len,
                     "length": length, "page_size": page_size,
                     "chunk_pages": 1, "repeats_best_of": repeats},
        "samples": repeats,
        "disaggregated": {"tokens_per_s": dis_tps, "makespan_s": dis_best,
                          "ttft_mean_s": dis_ttft},
        "colocated": {"tokens_per_s": colo_tps, "makespan_s": colo_best,
                      "ttft_mean_s": colo_ttft},
        "tokens_per_s_ratio": tps_ratio,
        "bytes_shipped_per_request": bytes_per_req,
        "blocks_shipped": dis_metrics["blocks_shipped"],
        "variance": var,
    })


# ==================== beyond paper: multi-replica front door (router)
def bench_serve_router() -> None:
    """The multi-replica front door (prefix-affinity routing, tenant
    fairness, heartbeat failover) over 2 replicas vs one colocated engine
    on a shared-prefix trace, plus a failover drill.

    * ``affinity_hit_rate`` — fraction of dispatches routed by prefix
      affinity on the shared-prefix trace (4 prefix groups; each group's
      first request is an unavoidable miss, the rest must follow their
      prefix). GATED: deterministic by construction (optimistic digest
      insert at dispatch), so the floor sits just above the 0.8 design
      target.
    * ``tokens_per_s_ratio`` — router-over-2-replicas tokens/s over one
      colocated engine. Recorded only: in one process the replicas share
      the CPU, so this is the price of the routing/control plane
      (~0.7-1.0x), not a throughput win.
    * failover drill — a second wave on the same router; one replica is
      killed mid-decode and the heartbeat sweep must requeue its work
      with zero requests lost and token-identical greedy output
      (also enforced by tests/serve/test_router.py in CI).

    Appends a ``router`` block to BENCH_serve.json.
    """
    import jax
    from repro.configs import get_config
    from repro.models import lm
    from repro.serve import Request, Router, serve_requests

    cfg = get_config("paper_demo", reduced=True)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    per_group = 6 if QUICK else 10
    n_groups, shared_len, length = 4, 8, 12
    page_size = 4
    max_seq = shared_len + 1 + length
    prompts = [list(range(1 + 10 * g, 1 + 10 * g + shared_len)) + [200 + i]
               for g in range(n_groups) for i in range(per_group)]
    n_requests = len(prompts)
    useful_tokens = n_requests * length
    kw = dict(max_batch=4, max_cache_len=max_seq, paged=True,
              page_size=page_size, max_seq_len=max_seq)

    def mk_reqs():
        rs = [Request(p, length) for p in prompts]
        for r in rs:
            r.arrival_time = time.monotonic()
        return rs

    def colocated_trial():
        reqs = mk_reqs()
        t0 = time.monotonic()
        serve_requests(cfg, params, reqs, timeout=600, **kw)
        dt = time.monotonic() - t0
        ttfts = [r.ttft for r in reqs if r.ttft is not None]
        return dt, sum(ttfts) / len(ttfts), \
            {tuple(p): list(r.tokens) for p, r in zip(prompts, reqs)}

    colo_best, colo_ttft, expected = colocated_trial()
    dt, ttft, _ = colocated_trial()        # best-of-2, first warms compile
    if dt < colo_best:
        colo_best, colo_ttft = dt, ttft

    # saturation >= trace size: the bench measures AFFINITY, so the
    # fallback path (covered by tests) must not add timing-dependent
    # misses — exactly one miss per prefix group remains
    router = Router(cfg, params, n_replicas=2, saturation=n_requests,
                    heartbeat_timeout_s=0.1, sweep_interval_s=0.01, **kw)
    # untimed warmup: compile both replicas' step functions. Prompts are
    # disjoint from the trace prefixes so the affinity measurement keeps
    # its exactly-one-miss-per-group structure.
    warm = [Request(list(range(400 + 10 * i, 400 + 10 * i + shared_len)), 2)
            for i in range(4)]
    for r in warm:
        router.submit(r)
    router.run(timeout=600, until=lambda: len(router.retired) == len(warm))
    hits0, routed0 = (router.stats["affinity_hits"],
                      router.stats["routed"])
    reqs = mk_reqs()
    t0 = time.monotonic()
    for r in reqs:
        router.submit(r)
    router.run(timeout=600,
               until=lambda: len(router.retired) == len(warm) + n_requests)
    rout_best = time.monotonic() - t0
    hit_rate = (router.stats["affinity_hits"] - hits0) \
        / (router.stats["routed"] - routed0)
    ttfts = [r.ttft for r in reqs if r.ttft is not None]
    rout_ttft = sum(ttfts) / len(ttfts)

    # failover drill: same router (warm compile caches), second wave;
    # kill whichever replica is observed mid-decode first
    wave = mk_reqs()
    for r in wave:
        router.submit(r)
    victim, deadline = None, time.monotonic() + 300
    while victim is None and time.monotonic() < deadline:
        router.step()
        for t in router._tracked.values():
            if t.rank is not None and t.original.delivered >= 2:
                victim = t.rank
                break
    router.kill_replica(victim)
    router.close_intake()
    router.run(timeout=600)
    zero_loss = sum(1 for r in wave if r.req_state.value == "finished") \
        == n_requests
    identical = all(r.tokens == expected[tuple(p)]
                    for p, r in zip(prompts, wave))
    m2 = router.metrics()
    router.shutdown()

    colo_tps = useful_tokens / colo_best
    rout_tps = useful_tokens / rout_best
    tps_ratio = rout_tps / colo_tps

    emit("serve.router.routed", rout_best / useful_tokens * 1e6,
         f"{rout_tps:.0f}_tok_per_s_ttft_{rout_ttft * 1e3:.0f}ms")
    emit("serve.router.colocated_baseline",
         colo_best / useful_tokens * 1e6,
         f"{colo_tps:.0f}_tok_per_s_ttft_{colo_ttft * 1e3:.0f}ms")
    emit("serve.router.affinity_hit_rate", 0.0,
         f"{hit_rate:.3f}_over_{n_requests}_requests")
    emit("serve.router.failover", 0.0,
         f"zero_loss_{zero_loss}_identical_{identical}_requeued_"
         f"{m2['requeued']}")

    _append_block("router", {
        "workload": {"n_requests": n_requests, "prefix_groups": n_groups,
                     "shared_len": shared_len, "length": length,
                     "page_size": page_size, "n_replicas": 2},
        "affinity_hit_rate": hit_rate,
        "tokens_per_s_ratio": tps_ratio,
        "router": {"tokens_per_s": rout_tps, "makespan_s": rout_best,
                   "ttft_mean_s": rout_ttft},
        "colocated": {"tokens_per_s": colo_tps, "makespan_s": colo_best,
                      "ttft_mean_s": colo_ttft},
        "failover": {"zero_loss": zero_loss, "token_identical": identical,
                     "failovers": m2["failovers"],
                     "requeued": m2["requeued"]},
    })


# ================== beyond paper: trace-replay harness over every tier
def bench_serve_trace() -> None:
    """One seeded mixed workload — bursty on/off arrivals, heavy-tailed
    output lengths, shared-prefix groups, two tenants, two priorities,
    per-request deadlines — replayed through ALL three serving tiers
    (colocated ``ServeEngine``, disaggregated ``DisaggServer``,
    multi-replica ``Router``) by the ``repro.bench`` harness, ``SAMPLES``
    samples each, reported as SLO verdicts (goodput under deadline,
    p50/p99/p99.9 TTFT and inter-token latency, mean/cv/ci95 per metric).

    Then a saturation sweep on the colocated engine: binary-search the
    max offered QPS at which the SLO still holds, rescaling the SAME
    trace (same prompts, same ordering — only the arrival clock moves).
    Appends a ``trace`` block to BENCH_serve.json.
    """
    import jax
    from repro.bench import (Replayer, SLO, slo_report, sweep_tier,
                             synthetic_trace)
    from repro.configs import get_config
    from repro.models import lm
    from repro.serve import Router, ServeEngine
    from repro.serve.disagg import DisaggServer

    cfg = get_config("paper_demo", reduced=True)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    n_requests = 8 if QUICK else 16
    prompt_len, max_seq = 12, 64
    kw = dict(max_batch=4, max_cache_len=max_seq, page_size=8,
              max_seq_len=32)
    trace = synthetic_trace(
        n_requests, seed=1009, vocab_size=cfg.vocab_size,
        arrival="onoff", rate_qps=40.0, mean_burst=4.0, mean_off_s=0.15,
        prompt_len=(prompt_len, prompt_len), output_len=(4, 16),
        output_alpha=1.2, n_prefix_groups=2, shared_len=8,
        tenants={"alpha": 2.0, "beta": 1.0}, priorities={0: 3.0, 1: 1.0},
        deadline_s=30.0, name="serve_mix")
    # correctness-shaped SLO: everything must finish inside its deadline
    # and first tokens must land in single-digit seconds even on a
    # throttled CI runner — a hung tier or admission bug fails it
    slo = SLO(ttft_p99_s=10.0, min_finished_frac=1.0,
              min_deadline_met_frac=1.0)

    tiers = (
        ("engine", lambda: ServeEngine(cfg, params, paged=True, **kw)),
        ("disagg", lambda: DisaggServer(cfg, params, chunk_pages=1, **kw)),
        ("router", lambda: Router(cfg, params, n_replicas=2,
                                  saturation=2 * n_requests, paged=True,
                                  **kw)),
    )
    # the sweep needs enough offered work that overload visibly queues:
    # same shapes as the main trace (no fresh compiles on the warm
    # replayer) but more, longer requests and a TTFT bound that holds at
    # trickle rates and breaks once arrivals outrun decode capacity
    sweep_trace = synthetic_trace(
        32 if QUICK else 48, seed=1013, vocab_size=cfg.vocab_size,
        arrival="poisson", rate_qps=20.0, prompt_len=(prompt_len,
                                                      prompt_len),
        output_len=(12, 16), output_alpha=1.2, n_prefix_groups=2,
        shared_len=8, name="serve_sweep")
    sweep_slo = SLO(ttft_p99_s=0.15, min_finished_frac=1.0)
    reports = {}
    sweep_doc = None
    for name, factory in tiers:
        with Replayer(factory, name=name) as rp:
            results = rp.run(trace, samples=SAMPLES, timeout=600)
            rep = slo_report(results, slo)
            reports[name] = rep
            m = rep["metrics"]
            tok = max(1.0, m["tokens_per_s"]["mean"])
            emit(f"serve.trace.{name}", 1e6 / tok,
                 f"{m['tokens_per_s']['mean']:.0f}_tok_per_s_goodput_"
                 f"{m['goodput_tokens_per_s']['mean']:.0f}_ttft_p99_"
                 f"{m['ttft_p99_s']['mean'] * 1e3:.0f}ms_slo_"
                 f"{'ok' if rep['slo']['ok'] else 'VIOLATED'}")
            if name == "engine":
                # saturation sweep on the warm colocated engine
                sweep = sweep_tier(rp, sweep_trace, sweep_slo,
                                   lo_qps=8.0, hi_qps=150.0,
                                   iters=2 if QUICK else 3)
                sweep_doc = dict(sweep.to_dict(),
                                 slo=sweep_slo.to_dict(),
                                 trace=sweep_trace.meta)
                mq = sweep.max_qps
                emit("serve.trace.sweep_max_qps", 0.0,
                     f"{'none' if mq is None else f'{mq:.1f}'}_qps_"
                     f"{len(sweep.points)}_probes"
                     f"{'_range_saturated' if sweep.saturated_range else ''}")

    _append_block("trace", {
        "workload": dict(trace.meta, n_requests=n_requests),
        "samples": SAMPLES,
        "slo": slo.to_dict(),
        "tiers": reports,
        "sweep": sweep_doc,
    })


# ======================= beyond paper: observability overhead (obs)
def bench_serve_obs() -> None:
    """Tracing overhead on the colocated serving path: one seeded trace
    replayed on the same warm engine with tracing off vs sampled-on
    (``Recorder(sample=0.5)``), interleaved off/on per sample so
    machine-load drift hits both modes alike. The gated claim: tokens/s
    with tracing on stays >= 0.95x of tracing off.

    Side effects: writes ``trace.json`` (the Chrome/Perfetto export of
    the traced samples — the CI artifact next to BENCH_serve.json) and
    appends an ``obs`` block with the overhead ratio plus the recorder's
    SLO cause attribution (queue delay vs compute vs shipping vs
    notification latency).
    """
    import jax
    from repro.bench import Replayer, synthetic_trace
    from repro.configs import get_config
    from repro.models import lm
    from repro.obs import Recorder
    from repro.serve import ServeEngine

    cfg = get_config("paper_demo", reduced=True)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    n_requests = 8 if QUICK else 16
    trace = synthetic_trace(
        n_requests, seed=1021, vocab_size=cfg.vocab_size,
        arrival="poisson", rate_qps=50.0, prompt_len=(12, 12),
        output_len=(8, 16), output_alpha=1.2, n_prefix_groups=2,
        shared_len=8, name="serve_obs")
    rec = Recorder(sample=0.5)
    offs, ons, ratios = [], [], []
    with Replayer(lambda: ServeEngine(cfg, params, paged=True,
                                      max_batch=4, max_cache_len=64,
                                      page_size=8, max_seq_len=32),
                  name="engine") as rp:
        for _ in range(SAMPLES):
            off = rp.run(trace, samples=1, timeout=600)[0]
            rp.recorder = rec       # traced window: this sample only
            on = rp.run(trace, samples=1, timeout=600)[0]
            rp.recorder = None
            off_tok = off.metrics()["tokens_per_s"]
            on_tok = on.metrics()["tokens_per_s"]
            offs.append(off_tok)
            ons.append(on_tok)
            ratios.append({"trace_overhead_tokens_per_s":
                           on_tok / max(off_tok, 1e-9)})
    var = _variance(ratios)
    ratio = var["trace_overhead_tokens_per_s"]["mean"]
    rec.write("trace.json")
    cause = rec.cause_summary()
    emit("serve.obs.off", 0.0,
         f"{sum(offs) / len(offs):.0f}_tok_per_s_untraced")
    emit("serve.obs.on", 0.0,
         f"{sum(ons) / len(ons):.0f}_tok_per_s_sample_{rec.sample:g}")
    emit("serve.obs.overhead", 0.0,
         f"{ratio:.3f}x_on_vs_off_{cause['events']}_events_"
         f"{cause['dropped']}_dropped")
    _append_block("obs", {
        "workload": dict(trace.meta, n_requests=n_requests),
        "samples": SAMPLES,
        "sample_rate": rec.sample,
        "off_tokens_per_s": sum(offs) / len(offs),
        "on_tokens_per_s": sum(ons) / len(ons),
        "trace_overhead_tokens_per_s": ratio,
        "cause": cause,
        "trace_json": "trace.json",
        "variance": var,
    })


# ========================= beyond paper: API layer (flags + await bridge)
def bench_api() -> None:
    """Per-registration flag overhead and awaitable-bridge notification
    latency vs the raw ``cb(statuses, cb_data)`` surface.

    * ``core.api.flags.*`` — registration+trigger+run cost with and
      without a per-registration ``ContinueFlags`` override (the price of
      resolving flags at registration).
    * ``core.api.notify.*`` — time from completion to handler for a batch
      of K in-flight ops: raw inline callbacks vs ``await
      asyncio.gather(*map(engine.wrap, ops))``. The gated claim: the
      awaitable bridge costs <= 25% over raw callbacks (loop-thread
      resolutions set futures directly; no call_soon hop).

    Appends an ``api`` block to BENCH_serve.json for the regression gate.
    """
    import asyncio
    from repro.core import ContinueFlags, Engine, Status
    from repro.core.completable import Completable

    class Op(Completable):
        @property
        def supports_push(self):
            return True

        def trigger(self):
            self._complete(Status())

    eng = Engine()
    cr = eng.continue_init()
    n_reg = 600 if QUICK else 3000

    def reg_plain():
        op = Op()
        eng.continue_when(op, lambda st, d: None, cr=cr)
        op.trigger()

    flags = ContinueFlags(enqueue_complete=False, on_error="raise")

    def reg_flagged():
        op = Op()
        eng.continue_when(op, lambda st, d: None, cr=cr, flags=flags)
        op.trigger()

    us_plain = _timeit(reg_plain, n_reg)
    us_flagged = _timeit(reg_flagged, n_reg)
    cr.wait(timeout=10)
    flags_ratio = us_flagged / us_plain
    emit("core.api.flags.register_plain", us_plain, "incl_trigger+run")
    emit("core.api.flags.register_flagged", us_flagged,
         f"{flags_ratio:.3f}x_vs_plain")

    # -- notification latency at batch K: completion -> handler ran
    K = 128
    rounds = 40 if QUICK else 80

    def raw_batch() -> float:
        ops = [Op() for _ in range(K)]
        done = []
        for op in ops:
            eng.continue_when(op, lambda st, d: done.append(d), cr=cr)
        t0 = time.perf_counter()
        for op in ops:
            op.trigger()          # push discovery -> inline callback
        while len(done) < K:
            eng.tick()
        return (time.perf_counter() - t0) / K * 1e6

    async def await_batch() -> float:
        ops = [Op() for _ in range(K)]
        proms = [eng.wrap(op) for op in ops]
        t0 = time.perf_counter()
        for op in ops:
            op.trigger()          # resolution inline on the loop thread
        for p in proms:
            await p               # direct __await__: no per-promise Task
        return (time.perf_counter() - t0) / K * 1e6

    async def gather_batch() -> float:
        # informational: asyncio.gather wraps each awaitable in a Task —
        # fan-in machinery on top of the bridge, not the bridge itself
        ops = [Op() for _ in range(K)]
        proms = [eng.wrap(op) for op in ops]
        t0 = time.perf_counter()
        for op in ops:
            op.trigger()
        await asyncio.gather(*proms)
        return (time.perf_counter() - t0) / K * 1e6

    # interleave raw / direct-await / gather rounds so machine-load drift
    # hits all three alike; report each variant's best (min) round — the
    # ratio of minima is the load-independent cost comparison the CI gate
    # needs on shared runners
    async def interleaved():
        raws, directs, gathers = [], [], []
        for _ in range(rounds):
            raws.append(raw_batch())
            directs.append(await await_batch())
            gathers.append(await gather_batch())
        return raws, directs, gathers

    raws, directs, gathers = asyncio.run(interleaved())
    raw_us, await_us, gather_us = min(raws), min(directs), min(gathers)
    var = _variance([{"raw_vs_await_ratio": r / d}
                     for r, d in zip(raws, directs)])
    eng.shutdown()

    emit("core.api.notify.raw_callback", raw_us, "us_per_completion")
    emit("core.api.notify.await_bridge", await_us,
         f"{await_us / raw_us:.3f}x_vs_raw")
    emit("core.api.notify.await_overhead", 0.0,
         f"{(await_us / raw_us - 1.0) * 100:.1f}pct")
    emit("core.api.notify.gather_bridge", gather_us,
         f"{gather_us / raw_us:.3f}x_vs_raw_incl_task_wrap")

    _append_block("api", {
        "flags_register_plain_us": us_plain,
        "flags_register_flagged_us": us_flagged,
        "flags_overhead_ratio": flags_ratio,
        "notify_batch": K,
        "samples": rounds,
        "raw_callback_us": raw_us,
        "await_bridge_us": await_us,
        "gather_bridge_us": gather_us,
        "await_vs_raw_ratio": await_us / raw_us,
        # gated form: higher is better, floor 0.8 == "<= 25% overhead"
        "raw_vs_await_ratio": raw_us / await_us,
        "variance": var,
    })


# bench_api must run after bench_serve: bench_serve (re)creates
# BENCH_serve.json from scratch; api/paged/spec blocks append to it
ALL_BENCHES = (bench_notification, bench_scheduler, bench_zones,
               bench_dataflow, bench_offload, bench_loc,
               bench_train_overlap, bench_serve, bench_serve_paged,
               bench_serve_kernel, bench_serve_spec, bench_serve_stream,
               bench_serve_disagg, bench_serve_router,
               bench_serve_trace, bench_serve_obs, bench_api)
QUICK_BENCHES = (bench_notification, bench_scheduler, bench_loc,
                 bench_serve, bench_serve_paged, bench_serve_kernel,
                 bench_serve_spec, bench_serve_stream,
                 bench_serve_disagg, bench_serve_router,
                 bench_serve_trace, bench_serve_obs, bench_api)


def _append_history(args: argparse.Namespace) -> None:
    """One compact record per invocation into benchmarks/history.jsonl —
    git SHA, timestamp, gated/recorded metric values, sample count — so
    metric drift is greppable across commits without digging through CI
    artifacts. Best-effort: a partial run records what it measured."""
    import datetime
    import os
    import subprocess

    try:
        with open("BENCH_serve.json") as f:
            doc = json.load(f)
    except (OSError, json.JSONDecodeError):
        return                       # no serve blocks ran (e.g. --only zones)
    bench_dir = os.path.dirname(os.path.abspath(__file__))
    try:
        import check_regression      # benchmarks/ is sys.path[0]
    except ImportError:
        import importlib.util
        spec = importlib.util.spec_from_file_location(
            "check_regression",
            os.path.join(bench_dir, "check_regression.py"))
        check_regression = importlib.util.module_from_spec(spec)
        spec.loader.exec_module(check_regression)
    try:
        sha = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"], cwd=bench_dir,
            capture_output=True, text=True, timeout=10).stdout.strip()
    except (OSError, subprocess.SubprocessError):
        sha = ""
    recorded = {}
    for name, fn in check_regression.RECORDED.items():
        try:
            recorded[name] = round(float(fn(doc)), 4)
        except (KeyError, TypeError, ZeroDivisionError):
            pass
    record = {
        "ts": datetime.datetime.now(datetime.timezone.utc).isoformat(
            timespec="seconds"),
        "git_sha": sha,
        "quick": bool(args.quick),
        "only": args.only,
        "samples": SAMPLES,
        "metrics": {k: round(v, 4)
                    for k, v in check_regression.extract(doc).items()},
        "recorded": recorded,
    }
    path = os.path.join(bench_dir, "history.jsonl")
    with open(path, "a") as f:
        f.write(json.dumps(record, sort_keys=True) + "\n")
    print(f"# appended run record to {path}", flush=True)


def main() -> None:
    global QUICK, SAMPLES
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke subset at reduced sizes")
    ap.add_argument("--only", default=None, metavar="BLOCK",
                    help="run a single block (e.g. 'serve', 'dataflow')")
    ap.add_argument("--samples", type=int, default=3, metavar="N",
                    help="measured samples per serve scenario; feeds the "
                    "mean/cv/ci95 variance fields in BENCH_serve.json")
    args = ap.parse_args()
    QUICK = args.quick
    SAMPLES = max(1, args.samples)
    benches = QUICK_BENCHES if args.quick else ALL_BENCHES
    if args.only:
        benches = [b for b in ALL_BENCHES
                   if b.__name__ == f"bench_{args.only}"]
        if not benches:
            raise SystemExit(f"unknown block {args.only!r}")
    print("# name,us_per_call,derived")
    for bench in benches:
        print(f"# --- {bench.__name__} ---", flush=True)
        bench()
    _append_history(args)


if __name__ == "__main__":
    main()
