"""Benchmark-regression gate for the ``serve.*`` blocks.

Reads the machine-readable ``BENCH_serve.json`` that ``benchmarks/run.py
--quick`` (or the full sweep) just wrote, extracts the serving headline
metrics, and compares them against the committed
``benchmarks/baselines.json``. Any metric falling below
``baseline * (1 - tolerance)`` fails the job.

Gated metrics are **dimensionless ratios** (speedups, effective-batch
ratio, accept rate): absolute tokens/s and TTFT vary wildly across
runner hardware, but the *relative* wins — continuous over static
batching, paged over dense, speculative over plain paged — are the
claims this repo makes, are hardware-portable, and are exactly what a
bad change would erode. Absolute numbers are still recorded in the
baselines file (``recorded`` key) for eyeballing, but never gated.

Per-metric tolerances live in baselines.json so noisy metrics (CI
runners are shared and throttled) can carry wider bands than stable
ones. Refresh the file after an intentional perf change with::

    PYTHONPATH=src python benchmarks/run.py --quick
    python benchmarks/check_regression.py --update

and commit the result.

**Variance-aware gating**: ``run.py`` measures every serve scenario
``--samples`` times and embeds per-metric ``variance`` fields
(mean/cv/ci95) in BENCH_serve.json; ``--update`` snapshots each gated
metric's coefficient of variation next to its value. A metric whose
*committed* cv exceeds ``UNSTABLE_CV`` is flagged ``unstable`` and
recorded-only — enforcing a floor on a metric that swings more than
15% run-to-run produces alert fatigue, not protection. The decision
uses the committed cv (deterministic in CI), while the current run's
cv is displayed so drift toward instability is visible before it bites.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path
from typing import Dict, Optional

DEFAULT_TOLERANCE = 0.25
# mirror of repro.bench.stats.UNSTABLE_CV — this script must run without
# PYTHONPATH=src (CI calls it with the system python path)
UNSTABLE_CV = 0.15

# metric name -> (how to pull it out of BENCH_serve.json, tolerance).
# Tolerances: 0.25 absorbs CI-runner noise on stable ratios; the two
# wall-clock-sensitive serving speedups get 0.45 (CPU decode compute
# scales ~linearly with batch, so their tokens/s ratio is load-noisy —
# a real regression collapses them toward/below 1.0, far past the band).
GATED = {
    "continuous_vs_static_tokens_per_s": (
        lambda d: d["speedup_tokens_per_s"], 0.45),
    "continuous_vs_static_ttft_p99": (
        lambda d: d["static_greedy"]["ttft_p99_s"]
        / d["continuous"]["ttft_p99_s"], 0.45),
    "paged_vs_dense_effective_batch": (
        lambda d: d["paged"]["effective_batch_ratio"], 0.25),
    "spec_vs_paged_tokens_per_s": (
        lambda d: d["spec"]["speedup_tokens_per_s"], 0.25),
    # the fused-kernel claim: paged serving at LEAST matches dense
    # tokens/s (then wins on effective batch). Only enforceable where
    # the real Pallas kernel lowers — see CONDITIONAL below; CPU runs
    # (jnp reference fallback) record the ratio but are exempt.
    "paged_vs_dense_tokens_per_s": (
        lambda d: d["paged"]["speedup_tokens_per_s"], 0.05),
    "spec_accept_rate": (
        lambda d: d["spec"]["speculative"]["accept_rate"], 0.25),
    # streaming session API: first observable token must arrive well
    # before retirement, and per-token delivery must not erode tokens/s
    # (the inter-token overhead of stream publication + consumer
    # wakeups; ~1.0 on a quiet machine). Both are wall-clock-sensitive
    # — the stream variant runs N consumer threads against the decode
    # loop, so shared-runner contention hits it harder than the
    # retirement baseline — hence the 0.45 band the other serving
    # speedups use. The failure modes these gates exist for (decode
    # loop blocking on a slow consumer, per-token wakeup storms) land
    # at 0.1-0.3x, far past any band.
    "stream_vs_batch_ttft": (
        lambda d: d["stream"]["ttft_speedup"], 0.45),
    "stream_vs_batch_tokens_per_s": (
        lambda d: d["stream"]["tokens_per_s_ratio"], 0.45),
    # awaitable-bridge notification latency vs the raw callback surface
    # (core.api.* block), gated as raw/await so higher is better. The API
    # contract is "await costs <= 25% over raw callbacks" (ratio >= 0.8,
    # which quiet-machine runs meet at ~0.85-1.0); the extra band to the
    # 0.7 floor absorbs 2-core CI-runner contention, which hits the
    # event-loop path harder than the raw loop. A real bridge regression
    # (e.g. a per-await get_running_loop, ~20us on sandboxed kernels)
    # lands at 3-8x — far past any band.
    "await_vs_raw_notify_latency": (
        lambda d: d["api"]["raw_vs_await_ratio"], 0.3),
    # multi-replica front door: the prefix-affinity claim — on a
    # shared-prefix trace over 2 replicas, >0.8 of dispatches must route
    # by affinity. Deterministic by construction (optimistic digest
    # insert at dispatch; each prefix group's first request is the only
    # unavoidable miss), so the band is narrow: the quick trace (4
    # groups x 6) measures exactly 0.8333, the full trace (4 x 10) 0.9,
    # and the floor sits just above the 0.8 design target.
    "router_affinity_hit_rate": (
        lambda d: d["router"]["affinity_hit_rate"], 0.035),
    # observability overhead: tokens/s with sampled tracing on vs off,
    # interleaved on the same warm engine (obs block). The design claim
    # is "tracing costs <= 5%", so the floor sits at exactly 0.95; the
    # default-off fast path is one module-attr load + None check, and
    # measured overhead is ~0.5% (cv ~0.007), far inside the band.
    "trace_overhead_tokens_per_s": (
        lambda d: d["obs"]["trace_overhead_tokens_per_s"], 0.05),
}

# metric name -> where its coefficient of variation lives in the
# bench file's per-block ``variance`` fields (written by run.py when
# --samples > 1). Metrics absent here are deterministic by construction
# (router affinity is a counting argument; spec accept rate is a seeded
# token comparison) and never flagged unstable.
CV = {
    "continuous_vs_static_tokens_per_s":
        lambda d: d["variance"]["speedup_tokens_per_s"]["cv"],
    "continuous_vs_static_ttft_p99":
        lambda d: d["variance"]["ttft_p99_ratio"]["cv"],
    "paged_vs_dense_effective_batch":
        lambda d: d["paged"]["variance"]["effective_batch_ratio"]["cv"],
    "paged_vs_dense_tokens_per_s":
        lambda d: d["paged"]["variance"]["speedup_tokens_per_s"]["cv"],
    "spec_vs_paged_tokens_per_s":
        lambda d: d["spec"]["variance"]["speedup_tokens_per_s"]["cv"],
    "stream_vs_batch_ttft":
        lambda d: d["stream"]["variance"]["ttft_speedup"]["cv"],
    "stream_vs_batch_tokens_per_s":
        lambda d: d["stream"]["variance"]["tokens_per_s_ratio"]["cv"],
    "await_vs_raw_notify_latency":
        lambda d: d["api"]["variance"]["raw_vs_await_ratio"]["cv"],
    "trace_overhead_tokens_per_s":
        lambda d: d["obs"]["variance"]["trace_overhead_tokens_per_s"]["cv"],
}

# gates enforced only when their predicate holds for this run's
# BENCH_serve.json; otherwise the row reports "exempt" and --update
# preserves the committed baseline value (falling back to the declared
# default when none exists) instead of snapshotting a value measured
# under the exempt configuration
CONDITIONAL = {
    "paged_vs_dense_tokens_per_s": (
        lambda d: bool(d.get("kernel", {}).get("fused_kernel_active")),
        1.0),
}

# absolute numbers snapshotted alongside (informational only)
RECORDED = {
    "continuous_tokens_per_s": lambda d: d["continuous"]["tokens_per_s"],
    "paged_tokens_per_s": lambda d: d["paged"]["paged"]["tokens_per_s"],
    "spec_tokens_per_s": lambda d: d["spec"]["speculative"]["tokens_per_s"],
    "paged_vs_dense_tokens_per_s":
        lambda d: d["paged"]["speedup_tokens_per_s"],
    "api_raw_callback_us": lambda d: d["api"]["raw_callback_us"],
    "api_await_bridge_us": lambda d: d["api"]["await_bridge_us"],
    "api_flags_overhead_ratio": lambda d: d["api"]["flags_overhead_ratio"],
    "stream_tokens_per_s": lambda d: d["stream"]["streaming"]["tokens_per_s"],
    "stream_ttft_ms":
        lambda d: d["stream"]["streaming"]["ttft_mean_s"] * 1e3,
    "stream_inter_token_p99_ms":
        lambda d: d["stream"]["streaming"]["inter_token_p99_s"] * 1e3,
    # disaggregated prefill/decode vs colocated: recorded only — in a
    # single process the transport hop is pure overhead, so the ratio is
    # a cost-of-the-boundary observable (~0.7-1.0x on CPU), not a win to
    # gate; the correctness claims (token identity, leak-freedom,
    # pipelining) are enforced by tests/serve/test_disagg.py in CI
    "disagg_vs_colocated_tokens_per_s":
        lambda d: d["disagg"]["tokens_per_s_ratio"],
    "disagg_bytes_shipped_per_request":
        lambda d: d["disagg"]["bytes_shipped_per_request"],
    # router vs one colocated engine: recorded only — two replicas share
    # the process's CPU, so the ratio prices the routing control plane,
    # it is not a throughput win; failover correctness (zero loss,
    # token-identical replay) is enforced by tests/serve/test_router.py
    "router_vs_colocated_tokens_per_s":
        lambda d: d["router"]["tokens_per_s_ratio"],
    "router_failover_requeued":
        lambda d: d["router"]["failover"]["requeued"],
    # tracing cost context for the obs gate: how many events the traced
    # samples produced and what the runtime's own notification latency
    # (op-complete -> callback-ran) contributed
    "obs_events_traced": lambda d: d["obs"]["cause"]["events"],
    "obs_notify_latency_us_mean":
        lambda d: d["obs"]["cause"]["notify_latency_us_mean"],
}


def extract(doc: dict) -> Dict[str, float]:
    out = {}
    for name, (fn, _tol) in GATED.items():
        try:
            out[name] = float(fn(doc))
        except (KeyError, TypeError, ZeroDivisionError):
            # block missing (partial run.py crash, --only subset) — leave
            # the metric out so check() reports it as not extractable
            # instead of dying on a raw traceback
            pass
    return out


def extract_cv(doc: dict) -> Dict[str, float]:
    """Per-gated-metric coefficient of variation from this run, where
    the bench file carries variance fields (single-sample runs do not)."""
    out = {}
    for name, fn in CV.items():
        try:
            cv = fn(doc)
            if cv is not None:
                out[name] = float(cv)
        except (KeyError, TypeError):
            pass
    return out


def update_baselines(doc: dict, path: Path) -> None:
    old = {}
    if path.exists():
        old = json.loads(path.read_text())
    cvs = extract_cv(doc)
    metrics = {}
    for name, (fn, default_tol) in GATED.items():
        old_entry = old.get("metrics", {}).get(name, {})
        tol = old_entry.get("tolerance", default_tol)
        if name in CONDITIONAL and not CONDITIONAL[name][0](doc):
            # exempt on this runner: keep the committed baseline (set on
            # a runner where the condition held) rather than overwrite it
            # with a value the gate would never have checked
            value = old_entry.get("value", CONDITIONAL[name][1])
            entry = {"value": value, "tolerance": tol}
            if old_entry.get("cv") is not None:
                entry["cv"] = old_entry["cv"]
            metrics[name] = entry
            continue
        try:
            value = round(float(fn(doc)), 4)
        except (KeyError, TypeError, ZeroDivisionError):
            raise SystemExit(
                f"--update refuses a partial benchmark file: metric "
                f"{name!r} is not extractable (run the full --quick "
                f"sweep first)")
        entry = {"value": value, "tolerance": tol}
        if name in cvs:
            entry["cv"] = round(cvs[name], 4)
        metrics[name] = entry
    recorded = {name: round(float(fn(doc)), 2)
                for name, fn in RECORDED.items()}
    path.write_text(json.dumps({
        "comment": "serve.* regression baselines — gated metrics are "
                   "dimensionless ratios (hardware-portable); refresh "
                   "with check_regression.py --update after intentional "
                   "perf changes",
        "metrics": metrics,
        "recorded": recorded,
    }, indent=2) + "\n")
    print(f"wrote {path}")


def check(doc: dict, baselines: dict,
          summary_path: Optional[str] = None) -> int:
    current = extract(doc)
    current_cv = extract_cv(doc)
    rows = []  # (name, base, floor, got, cv_shown, status)
    failed = []
    # a metric gated in code but absent from the committed baselines
    # would otherwise silently not be compared at all
    for name in GATED:
        if name not in baselines["metrics"]:
            failed.append(f"{name}: gated in check_regression.py but "
                          "missing from baselines.json — run --update "
                          "and commit the refreshed file")
    for name, entry in baselines["metrics"].items():
        base, tol = entry["value"], entry.get("tolerance",
                                              DEFAULT_TOLERANCE)
        floor = base * (1.0 - tol)
        # display this run's cv when the bench file has one, else the
        # committed snapshot; the unstable *decision* below always uses
        # the committed cv so CI verdicts don't depend on run-to-run luck
        base_cv = entry.get("cv")
        cv_shown = current_cv.get(name, base_cv)
        exempt = (name in CONDITIONAL
                  and not CONDITIONAL[name][0](doc))
        if exempt:
            # condition not met on this runner (e.g. CPU fallback instead
            # of the real Pallas kernel): report the measured value when
            # available but never gate on it
            rows.append((name, base, floor,
                         current.get(name, float("nan")), cv_shown,
                         "exempt"))
            continue
        if base_cv is not None and base_cv > UNSTABLE_CV:
            # metric swings too much run-to-run on the baseline runner:
            # recorded-only until an --update on a quieter measurement
            # brings its cv back under the threshold
            rows.append((name, base, floor,
                         current.get(name, float("nan")), cv_shown,
                         "unstable"))
            continue
        if name not in current:
            failed.append(f"{name}: in baselines but not extractable "
                          "from BENCH_serve.json")
            continue
        got = current[name]
        ok = got >= floor
        rows.append((name, base, floor, got, cv_shown,
                     "ok" if ok else "REGRESSED"))
        if not ok:
            failed.append(f"{name}: {got:.3f} < floor {floor:.3f} "
                          f"(baseline {base:.3f}, tolerance {tol:.0%})")

    def _cv_txt(cv):
        return "-" if cv is None else f"{cv:.3f}"

    header = f"{'metric':<38} {'baseline':>9} {'floor':>8} " \
             f"{'current':>8} {'cv':>6}  status"
    lines = [header, "-" * len(header)]
    for name, base, floor, got, cv, status in rows:
        lines.append(f"{name:<38} {base:>9.3f} {floor:>8.3f} "
                     f"{got:>8.3f} {_cv_txt(cv):>6}  {status}")
    print("\n".join(lines))
    n_unstable = sum(1 for r in rows if r[5] == "unstable")
    if n_unstable:
        print(f"note: {n_unstable} metric(s) recorded-only (committed "
              f"cv > {UNSTABLE_CV:.2f}); re-measure and --update on a "
              "quiet runner to re-arm their gates")

    if summary_path:
        md = ["### serve benchmark regression gate", "",
              "| metric | baseline | floor | current | cv | status |",
              "| --- | ---: | ---: | ---: | ---: | --- |"]
        badge = {"exempt": "➖ exempt", "unstable": "🌀 unstable",
                 "ok": "✅", "REGRESSED": "❌ regressed"}
        for name, base, floor, got, cv, status in rows:
            md.append(f"| {name} | {base:.3f} | {floor:.3f} | {got:.3f} "
                      f"| {_cv_txt(cv)} | {badge[status]} |")
        if n_unstable:
            md += ["", f"🌀 = committed cv > {UNSTABLE_CV:.2f}: "
                   "recorded-only, not gated."]
        with open(summary_path, "a") as f:
            f.write("\n".join(md) + "\n")

    if failed:
        print("\nREGRESSION GATE FAILED:")
        for f_ in failed:
            print(f"  - {f_}")
        return 1
    print("\nregression gate passed")
    return 0


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--bench", default="BENCH_serve.json",
                    help="benchmark results to check")
    ap.add_argument("--baselines", default="benchmarks/baselines.json")
    ap.add_argument("--update", action="store_true",
                    help="refresh baselines.json from --bench (keeps "
                    "hand-tuned tolerances) instead of checking")
    ap.add_argument("--summary", default=None, metavar="PATH",
                    help="append a markdown result table to PATH (CI "
                    "passes $GITHUB_STEP_SUMMARY)")
    args = ap.parse_args()

    doc = json.loads(Path(args.bench).read_text())
    if args.update:
        update_baselines(doc, Path(args.baselines))
        return 0
    baselines = json.loads(Path(args.baselines).read_text())
    return check(doc, baselines, args.summary)


if __name__ == "__main__":
    sys.exit(main())
