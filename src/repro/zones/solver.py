"""Multi-zone stencil solver — the NPB BT-MZ analogue (paper §5.2).

A 1-D ring of 2-D zones with up-to-20× uneven widths (BT-MZ's static
load-imbalance characteristic), Jacobi-smoothed each timestep with halo
columns exchanged between neighboring zones across ranks. Two execution
variants, mirroring the paper's comparison:

* ``fork_join``     — every timestep: compute ALL local zones, then
  exchange ALL boundaries and drain them with a Testsome-style waitall
  (the OpenMP work-sharing reference).
* ``continuations`` — per-zone dataflow: a zone's update task is released
  by the *continuation* of ``continue_all`` over its two halo receives
  (the detached-tasks + MPIX_Continueall variant, paper Listing 2). Zones
  with early neighbors compute immediately; no global barrier.

Both variants are bit-identical to the single-rank reference (tested).
"""
from __future__ import annotations

import threading
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core import Engine, TestsomeManager, Transport

HALO_TAG_BASE = 5000


def make_zones(n_zones: int, ny: int, base_nx: int, max_ratio: float = 20.0,
               seed: int = 0) -> List[np.ndarray]:
    """Zone widths follow BT-MZ's uneven distribution (≈20× spread)."""
    rng = np.random.default_rng(seed)
    ratios = np.exp(np.linspace(0.0, np.log(max_ratio), n_zones))
    rng.shuffle(ratios)
    widths = np.maximum(4, (base_nx * ratios / ratios.mean()).astype(int))
    return [np.asarray(rng.standard_normal((w, ny)), np.float64)
            for w in widths]


def _smooth(zone: np.ndarray, left: np.ndarray, right: np.ndarray,
            iters: int = 1) -> np.ndarray:
    """Jacobi smoothing with halo columns; interior 5-point average."""
    for _ in range(iters):
        padded = np.concatenate([left[None, :], zone, right[None, :]], axis=0)
        up = np.roll(padded, 1, axis=1)
        down = np.roll(padded, -1, axis=1)
        zone = 0.25 * (padded[:-2] + padded[2:] + up[1:-1] + down[1:-1])
    return zone


def reference_solve(zones: List[np.ndarray], timesteps: int,
                    smooth_iters: int = 1) -> List[np.ndarray]:
    """Single-rank oracle: synchronous ring exchange every step."""
    zones = [z.copy() for z in zones]
    n = len(zones)
    for _ in range(timesteps):
        lefts = [zones[(i - 1) % n][-1, :].copy() for i in range(n)]
        rights = [zones[(i + 1) % n][0, :].copy() for i in range(n)]
        zones = [_smooth(zones[i], lefts[i], rights[i], smooth_iters)
                 for i in range(n)]
    return zones


class ZoneRank:
    """One rank of the distributed multi-zone solver."""

    def __init__(self, rank: int, n_ranks: int, all_sizes: List[int],
                 my_zones: Dict[int, np.ndarray], transport: Transport,
                 engine: Optional[Engine], variant: str,
                 timesteps: int, smooth_iters: int = 1) -> None:
        self.rank = rank
        self.n_ranks = n_ranks
        self.n_zones = len(all_sizes)
        self.zones = my_zones                     # zone_id -> array
        self.transport = transport
        self.engine = engine
        self.variant = variant
        self.timesteps = timesteps
        self.smooth_iters = smooth_iters
        self.owner = lambda z: z % n_ranks        # static round-robin
        self.wait_time = 0.0
        self.compute_time = 0.0

    # ---------------------------------------------------------------- common
    def _neighbors(self, z: int) -> Tuple[int, int]:
        return (z - 1) % self.n_zones, (z + 1) % self.n_zones

    def _tag(self, src_zone: int, dst_zone: int, step: int, side: int) -> int:
        return HALO_TAG_BASE + ((step % 2) * 2 + side) * self.n_zones ** 2 \
            + src_zone * self.n_zones + dst_zone

    def _send_boundaries(self, z: int, step: int) -> None:
        left_n, right_n = self._neighbors(z)
        zone = self.zones[z]
        # side 0: my left edge → left neighbor's "right" halo; side 1 vice versa
        self.transport.isend(self.rank, self.owner(left_n),
                             self._tag(z, left_n, step, 0), zone[0, :].copy())
        self.transport.isend(self.rank, self.owner(right_n),
                             self._tag(z, right_n, step, 1), zone[-1, :].copy())

    # -------------------------------------------------------------- fork-join
    def run_fork_join(self) -> None:
        mgr = TestsomeManager(window=16)
        for step in range(self.timesteps):
            for z in self.zones:
                self._send_boundaries(z, step)
            halos: Dict[int, List[Optional[np.ndarray]]] = \
                {z: [None, None] for z in self.zones}
            done = {"n": 0}
            for z in self.zones:
                left_n, right_n = self._neighbors(z)
                r_left = self.transport.irecv(
                    self.rank, source=self.owner(left_n),
                    tag=self._tag(left_n, z, step, 1))
                r_right = self.transport.irecv(
                    self.rank, source=self.owner(right_n),
                    tag=self._tag(right_n, z, step, 0))

                def on_done(statuses, zz, h=halos, d=done):
                    h[zz][0] = statuses[0].payload
                    h[zz][1] = statuses[1].payload
                    d["n"] += 1

                mgr.submit([r_left, r_right], on_done, z, want_statuses=True)
            t0 = time.monotonic()
            while done["n"] < len(self.zones):     # waitall barrier
                mgr.testsome()
            self.wait_time += time.monotonic() - t0
            t0 = time.monotonic()
            for z in self.zones:                    # then compute everything
                self.zones[z] = _smooth(self.zones[z], halos[z][0],
                                        halos[z][1], self.smooth_iters)
            self.compute_time += time.monotonic() - t0

    # ---------------------------------------------------------- continuations
    def run_continuations(self) -> None:
        """Zone tasks released by halo-completion continuations."""
        eng = self.engine
        cr = eng.continue_init({"mpi_continue_enqueue_complete": True})
        remaining = {"n": self.timesteps * len(self.zones)}
        # continuations may run on ANY rank's thread (paper §3) — the
        # counter decrement must be atomic across them
        rem_lock = threading.Lock()

        def post_zone(z: int, step: int) -> None:
            left_n, right_n = self._neighbors(z)
            r_left = self.transport.irecv(
                self.rank, source=self.owner(left_n),
                tag=self._tag(left_n, z, step, 1))
            r_right = self.transport.irecv(
                self.rank, source=self.owner(right_n),
                tag=self._tag(right_n, z, step, 0))
            statuses = [None, None]

            def on_halos(sts, zz):
                t0 = time.monotonic()
                self.zones[zz] = _smooth(self.zones[zz], sts[0].payload,
                                         sts[1].payload, self.smooth_iters)
                self.compute_time += time.monotonic() - t0
                with rem_lock:
                    remaining["n"] -= 1
                if step + 1 < self.timesteps:
                    # send my new boundaries, then wait for the next halos
                    self._send_boundaries(zz, step + 1)
                    post_zone(zz, step + 1)

            eng.continue_all([r_left, r_right], on_halos, z,
                             statuses=statuses, cr=cr)

        for z in self.zones:
            self._send_boundaries(z, 0)
        for z in self.zones:
            post_zone(z, 0)
        t0 = time.monotonic()
        while remaining["n"] > 0:
            cr.test()
        self.wait_time += max(0.0, time.monotonic() - t0 - self.compute_time)

    def run(self) -> None:
        if self.variant == "fork_join":
            self.run_fork_join()
        else:
            self.run_continuations()


def distributed_solve(zones: List[np.ndarray], n_ranks: int, timesteps: int,
                      variant: str, smooth_iters: int = 1
                      ) -> Tuple[List[np.ndarray], Dict[str, float]]:
    """Run the solver on ``n_ranks`` threads; returns (zones, timings)."""
    engine = Engine()
    transport = Transport(n_ranks, engine=engine)
    sizes = [z.shape[0] for z in zones]
    ranks = []
    for r in range(n_ranks):
        mine = {i: zones[i].copy() for i in range(len(zones))
                if i % n_ranks == r}
        ranks.append(ZoneRank(r, n_ranks, sizes, mine, transport, engine,
                              variant, timesteps, smooth_iters))
    threads = [threading.Thread(target=rk.run) for rk in ranks]
    t0 = time.monotonic()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    elapsed = time.monotonic() - t0
    out: List[Optional[np.ndarray]] = [None] * len(zones)
    for rk in ranks:
        for z, arr in rk.zones.items():
            out[z] = arr
    timings = {
        "elapsed": elapsed,
        "wait": sum(rk.wait_time for rk in ranks),
        "compute": sum(rk.compute_time for rk in ranks),
    }
    engine.shutdown()
    return out, timings
