"""Mamba-2 (SSD) block: projections, causal depthwise conv, SSD scan,
gated RMSNorm, plus the single-token decode recurrence.

Depthwise conv over the concatenated [x|B|C] streams is implemented as
*separate* per-stream depthwise convs (mathematically identical, since
depthwise = per-channel), which keeps TP sharding clean: the x-stream
channels shard over the model axis, the small B/C streams stay replicated.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig
from repro.models.layers import _init_dense, gathered
from repro.sharding import constrain


def init_ssm(key, cfg: ModelConfig) -> Dict[str, Any]:
    s = cfg.ssm
    d, di = cfg.d_model, cfg.ssm_inner
    H, P, N, G, W = cfg.ssm_heads, s.head_dim, s.state_dim, s.n_groups, s.conv_width
    ks = jax.random.split(key, 8)
    dt = jnp.exp(jax.random.uniform(ks[6], (H,))
                 * (jnp.log(s.dt_max) - jnp.log(s.dt_min)) + jnp.log(s.dt_min))
    dt_bias = dt + jnp.log(-jnp.expm1(-dt))   # inverse softplus
    return {
        "wz": _init_dense(ks[0], (d, di), cfg.param_dtype),
        "wx": _init_dense(ks[1], (d, di), cfg.param_dtype),
        "wB": _init_dense(ks[2], (d, G * N), cfg.param_dtype),
        "wC": _init_dense(ks[3], (d, G * N), cfg.param_dtype),
        "wdt": _init_dense(ks[4], (d, H), cfg.param_dtype),
        "conv_x": (jax.random.normal(ks[5], (W, di)) * 0.1).astype(cfg.param_dtype),
        "conv_B": (jax.random.normal(ks[7], (W, G * N)) * 0.1).astype(cfg.param_dtype),
        "conv_C": (jax.random.normal(jax.random.fold_in(key, 9), (W, G * N))
                   * 0.1).astype(cfg.param_dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H)).astype(jnp.float32),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": dt_bias.astype(jnp.float32),
        "norm_scale": jnp.ones((di,), jnp.float32),
        "wo": _init_dense(jax.random.fold_in(key, 10), (di, d), cfg.param_dtype),
    }


def ssm_specs() -> Dict[str, Any]:
    return {
        "wz": ("fsdp", "tp"), "wx": ("fsdp", "tp"),
        "wB": ("fsdp", None), "wC": ("fsdp", None), "wdt": ("fsdp", "tp"),
        "conv_x": (None, "tp"), "conv_B": (None, None), "conv_C": (None, None),
        "A_log": ("tp",), "D": ("tp",), "dt_bias": ("tp",),
        "norm_scale": ("tp",), "wo": ("tp", "fsdp"),
    }


def _causal_depthwise_conv(x: jax.Array, w: jax.Array) -> jax.Array:
    """x: (B, T, C); w: (W, C) — causal depthwise conv along T."""
    W = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    out = jax.lax.conv_general_dilated(
        xp, w[:, None, :],                  # (W, 1, C)
        window_strides=(1,), padding="VALID",
        dimension_numbers=("NWC", "WIO", "NWC"),
        feature_group_count=x.shape[-1])
    return out


def _gated_rmsnorm(y: jax.Array, z: jax.Array, scale: jax.Array,
                   eps: float) -> jax.Array:
    yf = y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(yf * yf, axis=-1, keepdims=True)
    return (yf * (var + eps) ** -0.5 * scale).astype(y.dtype)


def ssm_block(params, x: jax.Array, cfg: ModelConfig,
              return_state: bool = False):
    """Full-sequence SSD forward (train; prefill with ``return_state``)."""
    from repro.kernels.ssd_scan import ops as ssd_ops
    from repro.kernels.ssd_scan import ref as ssd_ref
    s = cfg.ssm
    B, T, _ = x.shape
    H, P, N, G = cfg.ssm_heads, s.head_dim, s.state_dim, s.n_groups
    gw = cfg.gather_weights
    z = jnp.einsum("btd,de->bte", x,
                   gathered(params["wz"], None, "tp", gather=gw).astype(cfg.dtype))
    xs_raw = jnp.einsum("btd,de->bte", x,
                        gathered(params["wx"], None, "tp", gather=gw).astype(cfg.dtype))
    Bs_raw = jnp.einsum("btd,de->bte", x,
                        gathered(params["wB"], None, None, gather=gw).astype(cfg.dtype))
    Cs_raw = jnp.einsum("btd,de->bte", x,
                        gathered(params["wC"], None, None, gather=gw).astype(cfg.dtype))
    dt = jnp.einsum("btd,dh->bth", x,
                    gathered(params["wdt"], None, "tp", gather=gw).astype(cfg.dtype))
    xs = jax.nn.silu(_causal_depthwise_conv(xs_raw, params["conv_x"].astype(cfg.dtype)))
    Bs = jax.nn.silu(_causal_depthwise_conv(Bs_raw, params["conv_B"].astype(cfg.dtype)))
    Cs = jax.nn.silu(_causal_depthwise_conv(Cs_raw, params["conv_C"].astype(cfg.dtype)))
    xs = constrain(xs, "batch", None, "tp")
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    A = -jnp.exp(params["A_log"])
    chunk = min(s.chunk_size, T)
    while T % chunk:
        chunk -= 1
    args = (xs.reshape(B, T, H, P), dt, A,
            Bs.reshape(B, T, G, N), Cs.reshape(B, T, G, N), params["D"])
    if return_state:
        y, final = ssd_ref.ssd_chunked(*args, chunk=chunk)
    else:
        y = ssd_ops.ssd_scan(*args, chunk=chunk, impl=cfg.attn_impl)
    y = _gated_rmsnorm(y.reshape(B, T, -1), z, params["norm_scale"], cfg.norm_eps)
    y = constrain(y, "batch", None, "tp")
    out = jnp.einsum("bte,ed->btd", y,
                     gathered(params["wo"], "tp", None,
                              gather=cfg.gather_weights).astype(cfg.dtype))
    if not return_state:
        return out
    W = s.conv_width
    state = {
        "ssm": final,
        "conv_x": xs_raw[:, T - (W - 1):, :],
        "conv_B": Bs_raw[:, T - (W - 1):, :],
        "conv_C": Cs_raw[:, T - (W - 1):, :],
    }
    return out, state


# --------------------------------------------------------------- decode path
def init_ssm_state(cfg: ModelConfig, batch: int,
                   n_layers: Optional[int] = None) -> Dict[str, Any]:
    s = cfg.ssm
    H, P, N, G, W = cfg.ssm_heads, s.head_dim, s.state_dim, s.n_groups, s.conv_width
    di = cfg.ssm_inner

    def shp(*dims):
        return ((n_layers,) if n_layers else ()) + tuple(dims)

    return {
        "ssm": jnp.zeros(shp(batch, H, P, N), jnp.float32),
        "conv_x": jnp.zeros(shp(batch, W - 1, di), cfg.dtype),
        "conv_B": jnp.zeros(shp(batch, W - 1, G * N), cfg.dtype),
        "conv_C": jnp.zeros(shp(batch, W - 1, G * N), cfg.dtype),
    }


def ssm_state_specs(layer_stacked: bool) -> Dict[str, Any]:
    lead = (None,) if layer_stacked else ()
    return {
        "ssm": lead + ("batch", "tp", None, None),
        "conv_x": lead + ("batch", None, "tp"),
        "conv_B": lead + ("batch", None, None),
        "conv_C": lead + ("batch", None, None),
    }


def _conv_step(state: jax.Array, xt: jax.Array, w: jax.Array
               ) -> Tuple[jax.Array, jax.Array]:
    """state (B, W-1, C), xt (B, C) → (conv output (B, C), new state)."""
    full = jnp.concatenate([state, xt[:, None, :]], axis=1)   # (B, W, C)
    out = jnp.einsum("bwc,wc->bc", full, w)
    return out, full[:, 1:, :]


def ssm_decode_step(params, x: jax.Array, cfg: ModelConfig,
                    state: Dict[str, Any]) -> Tuple[jax.Array, Dict[str, Any]]:
    """x: (B, 1, d) → (B, 1, d); constant-size state update (the long_500k
    decode path — no KV growth, the whole point of SSM serving)."""
    s = cfg.ssm
    B = x.shape[0]
    H, P, N, G = cfg.ssm_heads, s.head_dim, s.state_dim, s.n_groups
    xt = x[:, 0, :]
    z = xt @ params["wz"].astype(cfg.dtype)
    xs = xt @ params["wx"].astype(cfg.dtype)
    Bs = xt @ params["wB"].astype(cfg.dtype)
    Cs = xt @ params["wC"].astype(cfg.dtype)
    dt = xt @ params["wdt"].astype(cfg.dtype)
    xs, cx = _conv_step(state["conv_x"], xs, params["conv_x"].astype(cfg.dtype))
    Bs, cB = _conv_step(state["conv_B"], Bs, params["conv_B"].astype(cfg.dtype))
    Cs, cC = _conv_step(state["conv_C"], Cs, params["conv_C"].astype(cfg.dtype))
    xs, Bs, Cs = jax.nn.silu(xs), jax.nn.silu(Bs), jax.nn.silu(Cs)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])   # (B,H)
    A = -jnp.exp(params["A_log"])
    xh = xs.reshape(B, H, P).astype(jnp.float32)
    Bh = jnp.repeat(Bs.reshape(B, G, N), H // G, axis=1).astype(jnp.float32)
    Ch = jnp.repeat(Cs.reshape(B, G, N), H // G, axis=1).astype(jnp.float32)
    decay = jnp.exp(dt * A)                                            # (B,H)
    new_state = state["ssm"] * decay[..., None, None] \
        + jnp.einsum("bh,bhp,bhn->bhpn", dt, xh, Bh)
    y = jnp.einsum("bhn,bhpn->bhp", Ch, new_state) \
        + xh * params["D"][None, :, None]
    y = _gated_rmsnorm(y.reshape(B, -1).astype(cfg.dtype), z,
                       params["norm_scale"], cfg.norm_eps)
    out = (y @ params["wo"].astype(cfg.dtype))[:, None, :]
    return out, {"ssm": new_state, "conv_x": cx, "conv_B": cB, "conv_C": cC}
