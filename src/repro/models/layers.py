"""Shared layers: norms, rotary embeddings, gated MLP, embeddings.

Pure-functional: ``init_*`` builds param dicts, ``*_specs`` builds the
matching logical-axis trees (structure equality is unit-tested), forward
functions take (params, x).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig
from repro.sharding import constrain


def gathered(w: jax.Array, *tp_axes, gather: bool = False) -> jax.Array:
    """ZeRO-3 just-in-time weight gather (§Perf ``gather_weights``):
    constrain the weight to its tensor-parallel-only sharding, forcing the
    partitioner to all-gather the fsdp shards at the use site instead of
    reducing activation-sized partials after the matmul."""
    if not gather:
        return w
    return constrain(w, *tp_axes)


def _init_dense(key, shape, dtype, scale: Optional[float] = None):
    fan_in = shape[0]
    scale = (1.0 / fan_in) ** 0.5 if scale is None else scale
    return (jax.random.normal(key, shape) * scale).astype(dtype)


# --------------------------------------------------------------------- norm
def init_rmsnorm(width: int, dtype) -> Dict[str, Any]:
    return {"scale": jnp.ones((width,), dtype=jnp.float32)}


def rmsnorm_specs() -> Dict[str, Any]:
    return {"scale": (None,)}


def rmsnorm(params, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    from repro.kernels.rmsnorm import ops as rms_ops
    return rms_ops.rmsnorm(x, params["scale"], eps=eps)


# --------------------------------------------------------------------- rope
def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    exponent = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta ** exponent)         # (head_dim/2,)


def apply_rope(x: jax.Array, positions: jax.Array, theta: float,
               lean: bool = False) -> jax.Array:
    """x: (..., seq, heads, head_dim); positions: (..., seq) int32.

    ``lean`` (§Perf): angles/rotators computed fp32 on the small (S, hd/2)
    table, but applied to x in its own dtype — removes the (B,S,H,hd) fp32
    convert/multiply traffic of the baseline."""
    head_dim = x.shape[-1]
    freqs = rope_frequencies(head_dim, theta)
    angles = positions[..., None].astype(jnp.float32) * freqs   # (..., S, hd/2)
    if lean:
        cos = jnp.cos(angles)[..., None, :].astype(x.dtype)
        sin = jnp.sin(angles)[..., None, :].astype(x.dtype)
        x1, x2 = jnp.split(x, 2, axis=-1)
        return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin],
                               axis=-1)
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_pe(positions: jax.Array, width: int) -> jax.Array:
    """Fixed sinusoidal embedding for arbitrary (possibly traced) positions.

    positions: (S,) → (S, width). Works for one decode position as well as
    full sequences (no table materialization).
    """
    pos = positions.astype(jnp.float32)[:, None]
    dim = jnp.arange(0, width, 2, dtype=jnp.float32)[None, :]
    angle = pos / jnp.power(10000.0, dim / width)
    return jnp.concatenate([jnp.sin(angle), jnp.cos(angle)], axis=-1)


def sinusoidal_positions(seq_len: int, width: int) -> jax.Array:
    """Whisper-style fixed positional embedding (encoder)."""
    return sinusoidal_pe(jnp.arange(seq_len), width)


# ---------------------------------------------------------------------- mlp
def init_mlp(key, width: int, d_ff: int, dtype) -> Dict[str, Any]:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "wi": _init_dense(k1, (width, d_ff), dtype),
        "wg": _init_dense(k2, (width, d_ff), dtype),
        "wo": _init_dense(k3, (d_ff, width), dtype),
    }


def mlp_specs() -> Dict[str, Any]:
    return {"wi": ("fsdp", "tp"), "wg": ("fsdp", "tp"), "wo": ("tp", "fsdp")}


def mlp(params, x: jax.Array, gather: bool = False) -> jax.Array:
    """SwiGLU MLP with TP-sharded hidden dim."""
    wi = gathered(params["wi"], None, "tp", gather=gather)
    wg = gathered(params["wg"], None, "tp", gather=gather)
    wo = gathered(params["wo"], "tp", None, gather=gather)
    h = jnp.einsum("...d,df->...f", x, wi)
    g = jnp.einsum("...d,df->...f", x, wg)
    h = jax.nn.silu(g) * h
    h = constrain(h, "batch", None, "tp")
    return jnp.einsum("...f,fd->...d", h, wo)


# ---------------------------------------------------------------- embedding
def init_embedding(key, cfg: ModelConfig) -> Dict[str, Any]:
    p = {"tokens": (jax.random.normal(key, (cfg.padded_vocab, cfg.d_model))
                    * 0.02).astype(cfg.param_dtype)}
    if not cfg.tied_embeddings:
        p["head"] = _init_dense(jax.random.fold_in(key, 1),
                                (cfg.d_model, cfg.padded_vocab),
                                cfg.param_dtype, scale=cfg.d_model ** -0.5)
    return p


def embedding_specs(cfg: ModelConfig) -> Dict[str, Any]:
    p = {"tokens": ("fsdp", "tp")}
    if not cfg.tied_embeddings:
        # untied head: contract replicated d, produce vocab-sharded logits
        p["head"] = ("fsdp", "vocab")
    return p


def embed_tokens(params, tokens: jax.Array, cfg: ModelConfig) -> jax.Array:
    emb = gathered(params["tokens"], None, "tp",
                   gather=cfg.gather_weights)
    x = emb[tokens].astype(cfg.dtype)
    return constrain(x, "batch", None, None)


def lm_logits(params, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Vocab-sharded logits; padded vocab tail masked to -inf."""
    if cfg.tied_embeddings:
        emb = gathered(params["tokens"], "vocab", None,
                       gather=cfg.gather_weights)
        logits = jnp.einsum("...d,vd->...v", x, emb.astype(cfg.dtype))
    else:
        head = gathered(params["head"], None, "vocab",
                        gather=cfg.gather_weights)
        logits = jnp.einsum("...d,dv->...v", x, head.astype(cfg.dtype))
    if cfg.padded_vocab != cfg.vocab_size:
        vpos = jax.lax.broadcasted_iota(jnp.int32, logits.shape,
                                        logits.ndim - 1)
        logits = jnp.where(vpos < cfg.vocab_size, logits,
                           jnp.asarray(-1e30, logits.dtype))
    return constrain(logits, "batch", None, "vocab")


def cross_entropy(logits: jax.Array, labels: jax.Array,
                  mask: Optional[jax.Array] = None) -> jax.Array:
    """Mean CE over valid tokens; fp32; vocab axis may be sharded."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    label_logit = jnp.take_along_axis(
        logits, labels[..., None].astype(jnp.int32), axis=-1)[..., 0]
    nll = lse - label_logit
    if mask is not None:
        mask = mask.astype(jnp.float32)
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)
