"""GQA attention block: train/prefill/decode paths, RoPE, SWA, TP head
padding, cross-attention (enc-dec), and KV-cache management.

Head padding (DESIGN.md §5): head counts not divisible by the TP degree
(llama4 40, deepseek 56, whisper 20) are padded to ``cfg.padded_heads`` with
zero-initialized weights — the o-projection rows of padded heads are zero so
the function computed is exactly the unpadded architecture, while every
einsum shards cleanly over the 16-way model axis.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig
from repro.models.layers import _init_dense, apply_rope, gathered
from repro.sharding import constrain


def head_mask(cfg: ModelConfig):
    """Boolean (padded_heads,) mask of REAL q-head slots.

    Padded head slots sit at the tail of each kv group (head n belongs to
    kv group n // padded_kv_groups; slot j = n % padded_kv_groups is real
    iff j < kv_groups and the group is a real kv head). llama4 40→48 is
    8 groups of (5 real + 1 pad); deepseek 56→64 is 8×(7+1)."""
    import numpy as np
    Gp = cfg.padded_kv_groups
    n = np.arange(cfg.padded_heads)
    return ((n // Gp < cfg.n_kv_heads) & (n % Gp < cfg.kv_groups))


def init_attention(key, cfg: ModelConfig, width: int = 0) -> Dict[str, Any]:
    width = width or cfg.d_model
    hd = cfg.resolved_head_dim
    Hp, KVp = cfg.padded_heads, cfg.padded_kv_heads
    ks = jax.random.split(key, 4)
    wq = _init_dense(ks[0], (width, Hp, hd), cfg.param_dtype)
    wk = _init_dense(ks[1], (width, KVp, hd), cfg.param_dtype)
    wv = _init_dense(ks[2], (width, KVp, hd), cfg.param_dtype)
    wo = _init_dense(ks[3], (Hp, hd, width), cfg.param_dtype,
                     scale=(Hp * hd) ** -0.5)
    if Hp != cfg.n_heads or KVp != cfg.n_kv_heads:
        mask = jnp.asarray(head_mask(cfg))
        # zero q/o weights of padded slots: function preserved exactly
        wq = wq * mask[None, :, None].astype(wq.dtype)
        wo = wo * mask[:, None, None].astype(wo.dtype)
    if KVp != cfg.n_kv_heads:
        wk = wk.at[:, cfg.n_kv_heads:, :].set(0)
        wv = wv.at[:, cfg.n_kv_heads:, :].set(0)
    return {"wq": wq, "wk": wk, "wv": wv, "wo": wo}


def attention_specs(cfg: ModelConfig = None) -> Dict[str, Any]:
    # kv heads < TP degree → shard head_dim instead, IF it divides 16
    # (danube's head_dim=120 does not: its small kv weights replicate on tp)
    hd_ax = "tp" if cfg is None or cfg.resolved_head_dim % 16 == 0 else None
    return {
        "wq": ("fsdp", "heads", None),
        "wk": ("fsdp", None, hd_ax),
        "wv": ("fsdp", None, hd_ax),
        "wo": ("heads", None, "fsdp"),
    }


def init_kv_cache(cfg: ModelConfig, batch: int, max_len: int,
                  n_layers: Optional[int] = None) -> Dict[str, Any]:
    """Ring-buffer KV cache. SWA archs bound it at the window size."""
    hd = cfg.resolved_head_dim
    if cfg.window:
        max_len = min(max_len, cfg.window)
    shape = (batch, max_len, cfg.padded_kv_heads, hd)
    if n_layers:
        shape = (n_layers,) + shape
    return {
        "k": jnp.zeros(shape, cfg.dtype),
        "v": jnp.zeros(shape, cfg.dtype),
    }


def kv_cache_specs(layer_stacked: bool,
                   cfg: ModelConfig = None) -> Dict[str, Any]:
    hd_ax = "tp" if cfg is None or cfg.resolved_head_dim % 16 == 0 else None
    lead = (None,) if layer_stacked else ()
    return {"k": lead + ("batch", "kv_seq", None, hd_ax),
            "v": lead + ("batch", "kv_seq", None, hd_ax)}


def _project_qkv(params, x, cfg: ModelConfig, positions):
    g = cfg.gather_weights
    hd_ax = "tp" if cfg.resolved_head_dim % 16 == 0 else None
    wq = gathered(params["wq"], None, "heads", None, gather=g)
    wk = gathered(params["wk"], None, None, hd_ax, gather=g)
    wv = gathered(params["wv"], None, None, hd_ax, gather=g)
    q = jnp.einsum("bsd,dhk->bshk", x, wq.astype(cfg.dtype))
    k = jnp.einsum("bsd,dhk->bshk", x, wk.astype(cfg.dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, wv.astype(cfg.dtype))
    if cfg.rope_theta > 0:
        q = apply_rope(q, positions, cfg.rope_theta, lean=cfg.lean_attention)
        k = apply_rope(k, positions, cfg.rope_theta, lean=cfg.lean_attention)
    q = constrain(q, "batch", None, "act_heads", None)
    return q, k, v


def attention_block(params, x: jax.Array, cfg: ModelConfig, *,
                    causal: bool = True,
                    positions: Optional[jax.Array] = None) -> jax.Array:
    """Full-sequence attention (train / prefill), no cache output."""
    from repro.kernels.flash_attention import ops as attn_ops
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.arange(S, dtype=jnp.int32)[None, :]
    q, k, v = _project_qkv(params, x, cfg, positions)
    groups = cfg.padded_kv_groups
    if groups > 1 and cfg.attn_impl == "xla":
        # materialized repeat + constraint shards heads over TP cleanly
        k = constrain(jnp.repeat(k, groups, axis=2), "batch", None, "act_heads", None)
        v = constrain(jnp.repeat(v, groups, axis=2), "batch", None, "act_heads", None)
    o = attn_ops.attention(q, k, v, causal=causal, window=cfg.window,
                           impl=cfg.attn_impl, lean=cfg.lean_attention)
    o = constrain(o, "batch", None, "act_heads", None)
    wo = gathered(params["wo"], "heads", None, None,
                  gather=cfg.gather_weights)
    return jnp.einsum("bshk,hkd->bsd", o, wo.astype(cfg.dtype))


def prefill_attention(params, x, cfg: ModelConfig, cache: Dict[str, Any],
                      positions: Optional[jax.Array] = None
                      ) -> Tuple[jax.Array, Dict[str, Any]]:
    """Prefill: full-seq attention + populate the (possibly ring) cache."""
    from repro.kernels.flash_attention import ops as attn_ops
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.arange(S, dtype=jnp.int32)[None, :]
    q, k, v = _project_qkv(params, x, cfg, positions)
    groups = cfg.padded_kv_groups
    kr, vr = k, v
    if groups > 1 and cfg.attn_impl == "xla":
        kr = constrain(jnp.repeat(k, groups, axis=2), "batch", None, "act_heads", None)
        vr = constrain(jnp.repeat(v, groups, axis=2), "batch", None, "act_heads", None)
    o = attn_ops.attention(q, kr, vr, causal=True, window=cfg.window,
                           impl=cfg.attn_impl, lean=cfg.lean_attention)
    o = constrain(o, "batch", None, "act_heads", None)
    wo = gathered(params["wo"], "heads", None, None,
                  gather=cfg.gather_weights)
    out = jnp.einsum("bshk,hkd->bsd", o, wo.astype(cfg.dtype))
    L = cache["k"].shape[1]
    if S >= L:                     # keep last L positions (SWA ring)
        cache = {"k": k[:, S - L:], "v": v[:, S - L:]}
    else:
        cache = {"k": cache["k"].at[:, :S].set(k),
                 "v": cache["v"].at[:, :S].set(v)}
    return out, cache


def decode_attention(params, x, cfg: ModelConfig, cache: Dict[str, Any],
                     pos: jax.Array) -> Tuple[jax.Array, Dict[str, Any]]:
    """Decode ``S1`` new tokens against a cache of length L (usually
    S1 == 1; the paged suffix-prefill step passes a whole prompt tail —
    positions ``pos .. pos+S1-1`` — in one call, "chunked prefill").

    ``pos``: scalar int32, absolute position of the first new token. For
    SWA the cache is a ring buffer of size ``window`` indexed by
    ``pos % window`` (single-token only).

    GQA is computed with *grouped einsums* — the cache is never repeated to
    the query-head count (a 16× cache blowup at 32k otherwise). Sharding is
    flash-decoding style: the cache's sequence axis shards over the model
    axis ("kv_seq" rule), the softmax/value contractions over it become
    small per-layer all-reduces, and activation heads stay replicated
    ("act_heads" → None in decode rule tables).
    """
    B, S1, _ = x.shape
    if cfg.window and S1 > 1:
        raise NotImplementedError(
            "multi-token decode against a SWA ring buffer")
    offs = jnp.arange(S1, dtype=jnp.int32)
    positions = pos + offs[None, :]                     # (1, S1), broadcast
    q, k, v = _project_qkv(params, x, cfg, positions)
    q = constrain(q, "batch", None, "act_heads", None)
    L = cache["k"].shape[1]
    slot = (pos % L).astype(jnp.int32) if cfg.window else pos.astype(jnp.int32)
    ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, slot, axis=1)
    cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, slot, axis=1)
    ck = constrain(ck, "batch", "kv_seq", None, None)
    cv = constrain(cv, "batch", "kv_seq", None, None)
    KVp = cfg.padded_kv_heads
    G = cfg.padded_heads // KVp
    qg = q.reshape(B, S1, KVp, G, -1)
    # causal masking by absolute position held in each slot, per query row
    idx = jnp.arange(L, dtype=jnp.int32)
    if cfg.window:
        # slot i holds the latest absolute position ≤ pos congruent to i
        abs_pos = idx + ((pos - idx) // L) * L
        valid = ((abs_pos >= 0) & (abs_pos <= pos)
                 & (abs_pos > pos - cfg.window))[None, :]
    else:
        valid = idx[None, :] <= (pos + offs)[:, None]          # (S1, L)
    scores = jnp.einsum("bskgd,btkd->bkgst", qg, ck).astype(jnp.float32) \
        * cfg.resolved_head_dim ** -0.5
    scores = jnp.where(valid[None, None, None, :, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(cfg.dtype)
    o = jnp.einsum("bkgst,btkd->bskgd", probs, cv)
    o = o.reshape(B, S1, cfg.padded_heads, -1)
    o = constrain(o, "batch", None, "act_heads", None)
    wo = gathered(params["wo"], "heads", None, None,
                  gather=cfg.gather_weights)
    out = jnp.einsum("bshk,hkd->bsd", o, wo.astype(cfg.dtype))
    return out, {"k": ck, "v": cv}


def paged_decode_attention(params, x, cfg: ModelConfig,
                           k_pages: jax.Array, v_pages: jax.Array,
                           positions: jax.Array, tables: jax.Array,
                           n_valid: jax.Array, *, page_size: int
                           ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Fused paged decode over one layer's KV page pool.

    ``x``: (S, W, d_model) — S serve slots, each with a ``W``-token query
    window (W=1 plain decode, W=1+K speculative verify, W=padded tail for
    suffix prefill). ``positions (S,)`` is the absolute position of each
    slot's window row 0, ``tables (S, T)`` the per-slot page tables and
    ``n_valid (S,)`` the accept mask (rows written; 0 = idle slot).

    The gather → insert → attend → write-back all happens inside
    :func:`repro.kernels.paged_attention.ops.paged_attention`; this
    wrapper only does qkv projection (RoPE at per-slot absolute
    positions) and the output projection. Returns
    ``(out (S, W, d_model), new_k_pages, new_v_pages)`` — the pool
    arrays updated in place when the Pallas path runs (aliased outputs).
    """
    from repro.kernels.paged_attention import ops as paged_ops
    S, W, _ = x.shape
    offs = jnp.arange(W, dtype=jnp.int32)
    pos2d = positions[:, None] + offs[None, :]              # (S, W)
    q, k, v = _project_qkv(params, x, cfg, pos2d)
    q = constrain(q, "batch", None, "act_heads", None)
    o, new_k, new_v = paged_ops.paged_attention(
        q, k, v, k_pages, v_pages, tables, positions, n_valid,
        page_size=page_size, scale=cfg.resolved_head_dim ** -0.5)
    o = o.astype(cfg.dtype)
    o = constrain(o, "batch", None, "act_heads", None)
    wo = gathered(params["wo"], "heads", None, None,
                  gather=cfg.gather_weights)
    out = jnp.einsum("bshk,hkd->bsd", o, wo.astype(cfg.dtype))
    return out, new_k, new_v


# ------------------------------------------------------------ cross-attn
def init_cross_attention(key, cfg: ModelConfig) -> Dict[str, Any]:
    return init_attention(key, cfg)


def precompute_cross_kv(params, enc_out: jax.Array, cfg: ModelConfig
                        ) -> Dict[str, Any]:
    k = jnp.einsum("bsd,dhk->bshk", enc_out, params["wk"].astype(cfg.dtype))
    v = jnp.einsum("bsd,dhk->bshk", enc_out, params["wv"].astype(cfg.dtype))
    return {"k": k, "v": v}


def cross_attention(params, x: jax.Array, cross_kv: Dict[str, Any],
                    cfg: ModelConfig) -> jax.Array:
    from repro.kernels.flash_attention import ops as attn_ops
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(cfg.dtype))
    q = constrain(q, "batch", None, "heads", None)
    k, v = cross_kv["k"], cross_kv["v"]
    groups = cfg.padded_kv_groups
    if groups > 1 and cfg.attn_impl == "xla":
        k = jnp.repeat(k, groups, axis=2)
        v = jnp.repeat(v, groups, axis=2)
    o = attn_ops.attention(q, k, v, causal=False, window=0,
                           impl=cfg.attn_impl)
    o = constrain(o, "batch", None, "act_heads", None)
    wo = gathered(params["wo"], "heads", None, None,
                  gather=cfg.gather_weights)
    return jnp.einsum("bshk,hkd->bsd", o, wo.astype(cfg.dtype))
