"""Decoder-only language models: dense / MoE / SSM / hybrid / VLM.

Layer weights are *stacked* on a leading L axis regardless of application
style: ``cfg.scan_layers=True`` applies them via ``jax.lax.scan`` (small
HLO, fast compiles — production default), ``False`` unrolls a python loop
over indexed slices (exact per-layer HLO accounting for the roofline's
full-unroll mode). The hybrid (zamba2) family adds unstacked shared-block
weights and always unrolls (its pattern is heterogeneous).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn_mod
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.common import DENSE, HYBRID, MOE, SSM, VLM, ModelConfig
from repro.models.layers import (cross_entropy, embed_tokens, embedding_specs,
                                 init_embedding, init_mlp, init_rmsnorm,
                                 lm_logits, mlp, mlp_specs, rmsnorm,
                                 rmsnorm_specs, _init_dense)
from repro.sharding import constrain

# ============================================================ initialization

def _init_layer(key, cfg: ModelConfig) -> Dict[str, Any]:
    ks = jax.random.split(key, 4)
    if cfg.family in (SSM, HYBRID):
        return {"norm": init_rmsnorm(cfg.d_model, cfg.param_dtype),
                "ssm": ssm_mod.init_ssm(ks[0], cfg)}
    p = {"norm1": init_rmsnorm(cfg.d_model, cfg.param_dtype),
         "attn": attn_mod.init_attention(ks[0], cfg)}
    if not cfg.parallel_block:
        p["norm2"] = init_rmsnorm(cfg.d_model, cfg.param_dtype)
    if cfg.family == MOE:
        p["moe"] = moe_mod.init_moe(ks[1], cfg)
    else:
        p["mlp"] = init_mlp(ks[1], cfg.d_model, cfg.d_ff, cfg.param_dtype)
    return p


def _layer_specs(cfg: ModelConfig) -> Dict[str, Any]:
    if cfg.family in (SSM, HYBRID):
        return {"norm": rmsnorm_specs(), "ssm": ssm_mod.ssm_specs()}
    p = {"norm1": rmsnorm_specs(), "attn": attn_mod.attention_specs(cfg)}
    if not cfg.parallel_block:
        p["norm2"] = rmsnorm_specs()
    if cfg.family == MOE:
        p["moe"] = moe_mod.moe_specs(cfg)
    else:
        p["mlp"] = mlp_specs()
    return p


def _stack_leading(tree):
    return jax.tree_util.tree_map(
        lambda spec: (None,) + tuple(spec), tree,
        is_leaf=lambda v: isinstance(v, tuple))


def init_params(key, cfg: ModelConfig) -> Dict[str, Any]:
    k_emb, k_layers, k_extra = jax.random.split(key, 3)
    layer_keys = jax.random.split(k_layers, cfg.n_layers)
    params: Dict[str, Any] = {
        "embed": init_embedding(k_emb, cfg),
        "layers": jax.vmap(lambda k: _init_layer(k, cfg))(layer_keys),
        "final_norm": init_rmsnorm(cfg.d_model, cfg.param_dtype),
    }
    if cfg.family == HYBRID:
        w = 2 * cfg.d_model
        ks = jax.random.split(k_extra, 4)
        n_sites = max(1, cfg.n_layers // cfg.hybrid_attn_every)
        params["shared"] = {
            "norm1": init_rmsnorm(w, cfg.param_dtype),
            "attn": attn_mod.init_attention(ks[0], cfg, width=w),
            "norm2": init_rmsnorm(w, cfg.param_dtype),
            "mlp": init_mlp(ks[1], w, cfg.d_ff, cfg.param_dtype),
            # per-site output projectors 2d → d
            "proj": _init_dense(ks[2], (n_sites, w, cfg.d_model),
                                cfg.param_dtype),
        }
    if cfg.family == VLM or cfg.frontend_dim:
        params["frontend"] = {
            "proj": _init_dense(k_extra, (cfg.frontend_dim, cfg.d_model),
                                cfg.param_dtype)}
    return params


def param_specs(cfg: ModelConfig) -> Dict[str, Any]:
    specs: Dict[str, Any] = {
        "embed": embedding_specs(cfg),
        "layers": _stack_leading(_layer_specs(cfg)),
        "final_norm": rmsnorm_specs(),
    }
    if cfg.family == HYBRID:
        specs["shared"] = {
            "norm1": rmsnorm_specs(), "attn": attn_mod.attention_specs(cfg),
            "norm2": rmsnorm_specs(), "mlp": mlp_specs(),
            "proj": (None, "fsdp", "tp"),
        }
    if cfg.family == VLM or cfg.frontend_dim:
        specs["frontend"] = {"proj": ("fsdp", "tp")}
    return specs


# ================================================================== blocks

def _block_train(lp, x, cfg: ModelConfig):
    if cfg.family in (SSM, HYBRID):
        return x + ssm_mod.ssm_block(lp["ssm"], rmsnorm(lp["norm"], x, cfg.norm_eps), cfg)
    h = rmsnorm(lp["norm1"], x, cfg.norm_eps)
    a = attn_mod.attention_block(lp["attn"], h, cfg, causal=True)
    if cfg.parallel_block:     # command-r: attn + mlp share one pre-norm
        return x + a + mlp(lp["mlp"], h, cfg.gather_weights)
    x = x + a
    h2 = rmsnorm(lp["norm2"], x, cfg.norm_eps)
    if cfg.family == MOE:
        return x + moe_mod.moe_block(lp["moe"], h2, cfg)
    return x + mlp(lp["mlp"], h2, cfg.gather_weights)


def _block_decode(lp, x, cache, pos, cfg: ModelConfig):
    if cfg.family in (SSM, HYBRID):
        y, new_state = ssm_mod.ssm_decode_step(
            lp["ssm"], rmsnorm(lp["norm"], x, cfg.norm_eps), cfg, cache)
        return x + y, new_state
    h = rmsnorm(lp["norm1"], x, cfg.norm_eps)
    a, new_cache = attn_mod.decode_attention(lp["attn"], h, cfg, cache, pos)
    if cfg.parallel_block:
        return x + a + mlp(lp["mlp"], h, cfg.gather_weights), new_cache
    x = x + a
    h2 = rmsnorm(lp["norm2"], x, cfg.norm_eps)
    if cfg.family == MOE:
        return x + moe_mod.moe_block(lp["moe"], h2, cfg), new_cache
    return x + mlp(lp["mlp"], h2, cfg.gather_weights), new_cache


def _block_paged_decode(lp, x, k_pages, v_pages, cfg: ModelConfig,
                        positions, tables, n_valid, page_size: int):
    """`_block_decode` over the paged pool: attention is the fused paged
    kernel; the residual/mlp/moe structure is identical."""
    h = rmsnorm(lp["norm1"], x, cfg.norm_eps)
    a, nk, nv = attn_mod.paged_decode_attention(
        lp["attn"], h, cfg, k_pages, v_pages, positions, tables, n_valid,
        page_size=page_size)
    if cfg.parallel_block:
        return x + a + mlp(lp["mlp"], h, cfg.gather_weights), nk, nv
    x = x + a
    h2 = rmsnorm(lp["norm2"], x, cfg.norm_eps)
    if cfg.family == MOE:
        return x + moe_mod.moe_block(lp["moe"], h2, cfg), nk, nv
    return x + mlp(lp["mlp"], h2, cfg.gather_weights), nk, nv


def _block_prefill(lp, x, cache, cfg: ModelConfig):
    if cfg.family in (SSM, HYBRID):
        # chunked scan also yields the final SSD + conv state → decode cache
        y, state = ssm_mod.ssm_block(
            lp["ssm"], rmsnorm(lp["norm"], x, cfg.norm_eps), cfg,
            return_state=True)
        return x + y, state
    h = rmsnorm(lp["norm1"], x, cfg.norm_eps)
    a, new_cache = attn_mod.prefill_attention(lp["attn"], h, cfg, cache)
    if cfg.parallel_block:
        return x + a + mlp(lp["mlp"], h, cfg.gather_weights), new_cache
    x = x + a
    h2 = rmsnorm(lp["norm2"], x, cfg.norm_eps)
    if cfg.family == MOE:
        return x + moe_mod.moe_block(lp["moe"], h2, cfg), new_cache
    return x + mlp(lp["mlp"], h2, cfg.gather_weights), new_cache


def _maybe_remat(fn, cfg: ModelConfig):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(fn)


def _apply_layers(params, x, cfg: ModelConfig, mode: str = "train",
                  cache=None, pos=None):
    """Run the stacked layers; returns (x, new_cache)."""
    layers = params["layers"]
    if cfg.family == HYBRID:
        return _apply_hybrid(params, x, cfg, mode, cache, pos)
    if mode == "train":
        body = _maybe_remat(lambda h, lp: (_block_train(lp, h, cfg), None), cfg)
        if cfg.scan_layers:
            x, _ = jax.lax.scan(body, x, layers)
        else:
            for i in range(cfg.n_layers):
                lp = jax.tree_util.tree_map(lambda a: a[i], layers)
                x, _ = body(x, lp)
        return x, None
    if mode == "decode":
        def body(h, inp):
            lp, lc = inp
            h, nc = _block_decode(lp, h, lc, pos, cfg)
            return h, nc
    else:
        def body(h, inp):
            lp, lc = inp
            h, nc = _block_prefill(lp, h, lc, cfg)
            return h, nc
    if cfg.scan_layers:
        x, new_cache = jax.lax.scan(body, x, (layers, cache))
    else:
        # unrolled mode: the cache is a LIST of per-layer buffers — no
        # slice-of-stacked reads, and donated per-layer args update in
        # place (serving-system layout; also exact HLO accounting)
        ncs = []
        for i in range(cfg.n_layers):
            lp = jax.tree_util.tree_map(lambda a: a[i], layers)
            x, nc = body(x, (lp, cache[i]))
            ncs.append(nc)
        new_cache = ncs
    return x, new_cache


def _apply_hybrid(params, x, cfg: ModelConfig, mode, cache, pos):
    """zamba2: SSM backbone + shared attention block over concat(x, x0)
    every ``hybrid_attn_every`` layers (site-specific output projectors)."""
    sh = params["shared"]
    x0 = x
    site = 0
    new_cache: Dict[str, Any] = {"ssm": [], "kv": []} if cache is not None else None
    for i in range(cfg.n_layers):
        lp = jax.tree_util.tree_map(lambda a: a[i], params["layers"])
        if i % cfg.hybrid_attn_every == 0 and cfg.n_heads > 0:
            w_in = jnp.concatenate([x, x0], axis=-1)
            h = rmsnorm(sh["norm1"], w_in, cfg.norm_eps)
            if mode == "decode":
                a, nkv = attn_mod.decode_attention(sh["attn"], h, cfg,
                                                   cache["kv"][site], pos)
                new_cache["kv"].append(nkv)
            elif mode == "prefill":
                a, nkv = attn_mod.prefill_attention(sh["attn"], h, cfg,
                                                    cache["kv"][site])
                new_cache["kv"].append(nkv)
            else:
                a = attn_mod.attention_block(sh["attn"], h, cfg, causal=True)
            w_mid = w_in + a
            h2 = rmsnorm(sh["norm2"], w_mid, cfg.norm_eps)
            w_out = w_mid + mlp(sh["mlp"], h2, cfg.gather_weights)
            x = x + jnp.einsum("bsw,wd->bsd", w_out,
                               sh["proj"][site].astype(cfg.dtype))
            site += 1
        h = rmsnorm(lp["norm"], x, cfg.norm_eps)
        if mode == "decode":
            y, ns = ssm_mod.ssm_decode_step(lp["ssm"], h, cfg,
                                            cache["ssm"][i])
            new_cache["ssm"].append(ns)
            x = x + y
        elif mode == "prefill":
            y, ns = ssm_mod.ssm_block(lp["ssm"], h, cfg, return_state=True)
            new_cache["ssm"].append(ns)
            x = x + y
        else:
            x = x + ssm_mod.ssm_block(lp["ssm"], h, cfg)
    return x, new_cache


# ================================================================ embeddings

def _embed_inputs(params, batch: Dict[str, jax.Array], cfg: ModelConfig
                  ) -> Tuple[jax.Array, Optional[jax.Array]]:
    """Returns (x, loss_mask). VLM prepends projected patch embeddings."""
    x = embed_tokens(params["embed"], batch["tokens"], cfg)
    mask = None
    if cfg.family == VLM:
        patches = batch["patches"].astype(cfg.dtype)
        px = jnp.einsum("bpf,fd->bpd", patches,
                        params["frontend"]["proj"].astype(cfg.dtype))
        x = jnp.concatenate([px, x], axis=1)
        B, S = batch["tokens"].shape
        mask = jnp.concatenate(
            [jnp.zeros((B, cfg.n_patches)), jnp.ones((B, S))], axis=1)
    return constrain(x, "batch", None, None), mask


# ============================================================ public forward

def lm_loss(params, batch: Dict[str, jax.Array], cfg: ModelConfig) -> jax.Array:
    """Next-token CE loss over the token positions."""
    x, mask = _embed_inputs(params, batch, cfg)
    x, _ = _apply_layers(params, x, cfg, mode="train")
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = lm_logits(params["embed"], x, cfg)
    tokens = batch["tokens"]
    if cfg.family == VLM:
        labels = jnp.roll(tokens, -1, axis=1)
        token_logits = logits[:, cfg.n_patches:, :]
        valid = jnp.ones_like(tokens, jnp.float32).at[:, -1].set(0.0)
        return cross_entropy(token_logits, labels, valid)
    labels = jnp.roll(tokens, -1, axis=1)
    valid = jnp.ones_like(tokens, jnp.float32).at[:, -1].set(0.0)
    return cross_entropy(logits, labels, valid)


def lm_forward(params, batch: Dict[str, jax.Array], cfg: ModelConfig
               ) -> jax.Array:
    """Logits for the whole sequence (tests / generation without cache)."""
    x, _ = _embed_inputs(params, batch, cfg)
    x, _ = _apply_layers(params, x, cfg, mode="train")
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return lm_logits(params["embed"], x, cfg)


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> Any:
    stacked = cfg.scan_layers
    if cfg.family == SSM:
        if stacked:
            return ssm_mod.init_ssm_state(cfg, batch, n_layers=cfg.n_layers)
        return [ssm_mod.init_ssm_state(cfg, batch)
                for _ in range(cfg.n_layers)]
    if cfg.family == HYBRID:   # always unrolled → per-layer/site lists
        n_sites = max(1, -(-cfg.n_layers // cfg.hybrid_attn_every))
        return {
            "ssm": [ssm_mod.init_ssm_state(cfg, batch)
                    for _ in range(cfg.n_layers)],
            "kv": [attn_mod.init_kv_cache(cfg, batch, max_len)
                   for _ in range(n_sites)],
        }
    if stacked:
        return attn_mod.init_kv_cache(cfg, batch, max_len,
                                      n_layers=cfg.n_layers)
    return [attn_mod.init_kv_cache(cfg, batch, max_len)
            for _ in range(cfg.n_layers)]


def cache_specs(cfg: ModelConfig) -> Any:
    stacked = cfg.scan_layers
    if cfg.family == SSM:
        one = ssm_mod.ssm_state_specs(layer_stacked=stacked)
        return one if stacked else [one] * cfg.n_layers
    if cfg.family == HYBRID:
        n_sites = max(1, -(-cfg.n_layers // cfg.hybrid_attn_every))
        return {
            "ssm": [ssm_mod.ssm_state_specs(False)] * cfg.n_layers,
            "kv": [attn_mod.kv_cache_specs(False, cfg)] * n_sites,
        }
    one = attn_mod.kv_cache_specs(layer_stacked=stacked, cfg=cfg)
    return one if stacked else [one] * cfg.n_layers


def lm_prefill(params, batch: Dict[str, jax.Array], cfg: ModelConfig,
               cache: Any) -> Tuple[jax.Array, Any]:
    """Process the prompt; returns (last-position logits, primed cache)."""
    x, _ = _embed_inputs(params, batch, cfg)
    x, cache = _apply_layers(params, x, cfg, mode="prefill", cache=cache)
    x = rmsnorm(params["final_norm"], x[:, -1:, :], cfg.norm_eps)
    return lm_logits(params["embed"], x, cfg), cache


def lm_decode_step(params, token: jax.Array, cfg: ModelConfig, cache: Any,
                   pos: jax.Array) -> Tuple[jax.Array, Any]:
    """One-token decode. token: (B, 1) int32; pos: scalar int32."""
    x = embed_tokens(params["embed"], token, cfg)
    x, cache = _apply_layers(params, x, cfg, mode="decode", cache=cache,
                             pos=pos)
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return lm_logits(params["embed"], x, cfg), cache


def lm_paged_decode(params, tokens: jax.Array, cfg: ModelConfig,
                    pool: Dict[str, jax.Array], positions: jax.Array,
                    tables: jax.Array, n_valid: jax.Array, *,
                    page_size: int) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Fused paged decode over ALL serve slots in one call.

    ``tokens (S, W)`` int32 — each slot's query window (W=1 decode,
    W=1+K verify, W=padded tail for suffix prefill); ``pool`` is the
    layer-stacked page pool ``{"k","v": (L, P+1, ps, KVp, hd)}``;
    ``positions``/``n_valid``: (S,) int32; ``tables``: (S, T) int32.

    Returns ``(logits (S, W, vocab), new_pool)``. Layers run under
    ``lax.scan`` with per-layer pool leaves as scanned inputs/outputs,
    so a donated pool updates in place layer by layer.
    """
    if cfg.family in (SSM, HYBRID):
        raise NotImplementedError("paged decode requires KV attention")
    if not cfg.scan_layers:
        raise NotImplementedError("fused paged decode requires scan_layers")
    x = embed_tokens(params["embed"], tokens, cfg)

    def body(h, inp):
        lp, kp, vp = inp
        h, nk, nv = _block_paged_decode(lp, h, kp, vp, cfg, positions,
                                        tables, n_valid, page_size)
        return h, (nk, nv)

    x, (nk, nv) = jax.lax.scan(
        body, x, (params["layers"], pool["k"], pool["v"]))
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return lm_logits(params["embed"], x, cfg), {"k": nk, "v": nv}
