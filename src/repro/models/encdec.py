"""Encoder-decoder backbone (whisper-large-v3 assignment).

The conv audio frontend is a STUB per the assignment: ``input_specs()``
provides precomputed frame embeddings (B, T_enc, frontend_dim) which a
linear projector maps to d_model. Decoder positions use sinusoidal
embeddings (whisper's learned table is capped at 448; the decode_32k shape
demands 32k positions — documented deviation, DESIGN.md §4).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn_mod
from repro.models.common import ModelConfig
from repro.models.layers import (_init_dense, cross_entropy, init_embedding,
                                 init_mlp, init_rmsnorm, lm_logits, mlp,
                                 mlp_specs, rmsnorm, rmsnorm_specs,
                                 sinusoidal_pe, sinusoidal_positions,
                                 embedding_specs)
from repro.sharding import constrain


def _init_enc_layer(key, cfg: ModelConfig) -> Dict[str, Any]:
    ks = jax.random.split(key, 2)
    return {"norm1": init_rmsnorm(cfg.d_model, cfg.param_dtype),
            "attn": attn_mod.init_attention(ks[0], cfg),
            "norm2": init_rmsnorm(cfg.d_model, cfg.param_dtype),
            "mlp": init_mlp(ks[1], cfg.d_model, cfg.d_ff, cfg.param_dtype)}


def _init_dec_layer(key, cfg: ModelConfig) -> Dict[str, Any]:
    ks = jax.random.split(key, 3)
    return {"norm1": init_rmsnorm(cfg.d_model, cfg.param_dtype),
            "self_attn": attn_mod.init_attention(ks[0], cfg),
            "norm_x": init_rmsnorm(cfg.d_model, cfg.param_dtype),
            "cross_attn": attn_mod.init_cross_attention(ks[1], cfg),
            "norm2": init_rmsnorm(cfg.d_model, cfg.param_dtype),
            "mlp": init_mlp(ks[2], cfg.d_model, cfg.d_ff, cfg.param_dtype)}


def init_params(key, cfg: ModelConfig) -> Dict[str, Any]:
    k_emb, k_enc, k_dec, k_fe = jax.random.split(key, 4)
    enc_keys = jax.random.split(k_enc, cfg.n_enc_layers)
    dec_keys = jax.random.split(k_dec, cfg.n_dec_layers)
    return {
        "embed": init_embedding(k_emb, cfg),
        "frontend": {"proj": _init_dense(k_fe, (cfg.frontend_dim, cfg.d_model),
                                         cfg.param_dtype)},
        "encoder": jax.vmap(lambda k: _init_enc_layer(k, cfg))(enc_keys),
        "enc_norm": init_rmsnorm(cfg.d_model, cfg.param_dtype),
        "decoder": jax.vmap(lambda k: _init_dec_layer(k, cfg))(dec_keys),
        "dec_norm": init_rmsnorm(cfg.d_model, cfg.param_dtype),
    }


def param_specs(cfg: ModelConfig) -> Dict[str, Any]:
    def stack(tree):
        return jax.tree_util.tree_map(
            lambda s: (None,) + tuple(s), tree,
            is_leaf=lambda v: isinstance(v, tuple))
    enc = {"norm1": rmsnorm_specs(), "attn": attn_mod.attention_specs(cfg),
           "norm2": rmsnorm_specs(), "mlp": mlp_specs()}
    dec = {"norm1": rmsnorm_specs(),
           "self_attn": attn_mod.attention_specs(cfg),
           "norm_x": rmsnorm_specs(),
           "cross_attn": attn_mod.attention_specs(cfg),
           "norm2": rmsnorm_specs(), "mlp": mlp_specs()}
    return {
        "embed": embedding_specs(cfg),
        "frontend": {"proj": ("fsdp", "tp")},
        "encoder": stack(enc), "enc_norm": rmsnorm_specs(),
        "decoder": stack(dec), "dec_norm": rmsnorm_specs(),
    }


def _enc_block(lp, x, cfg: ModelConfig):
    h = rmsnorm(lp["norm1"], x, cfg.norm_eps)
    x = x + attn_mod.attention_block(lp["attn"], h, cfg, causal=False)
    h = rmsnorm(lp["norm2"], x, cfg.norm_eps)
    return x + mlp(lp["mlp"], h, cfg.gather_weights)


def encode(params, audio_embed: jax.Array, cfg: ModelConfig) -> jax.Array:
    """audio_embed: (B, T_enc, frontend_dim) — stub frontend output."""
    x = jnp.einsum("btf,fd->btd", audio_embed.astype(cfg.dtype),
                   params["frontend"]["proj"].astype(cfg.dtype))
    x = x + sinusoidal_positions(x.shape[1], cfg.d_model).astype(cfg.dtype)
    x = constrain(x, "batch", None, None)

    def body(h, lp):
        return _enc_block(lp, h, cfg), None

    if cfg.scan_layers:
        x, _ = jax.lax.scan(body, x, params["encoder"])
    else:
        for i in range(cfg.n_enc_layers):
            lp = jax.tree_util.tree_map(lambda a: a[i], params["encoder"])
            x, _ = body(x, lp)
    return rmsnorm(params["enc_norm"], x, cfg.norm_eps)


def _dec_block_train(lp, x, enc_out, cfg: ModelConfig):
    h = rmsnorm(lp["norm1"], x, cfg.norm_eps)
    x = x + attn_mod.attention_block(lp["self_attn"], h, cfg, causal=True)
    h = rmsnorm(lp["norm_x"], x, cfg.norm_eps)
    ckv = attn_mod.precompute_cross_kv(lp["cross_attn"], enc_out, cfg)
    x = x + attn_mod.cross_attention(lp["cross_attn"], h, ckv, cfg)
    h = rmsnorm(lp["norm2"], x, cfg.norm_eps)
    return x + mlp(lp["mlp"], h, cfg.gather_weights)


def _embed_dec(params, tokens: jax.Array, cfg: ModelConfig,
               pos0: int | jax.Array = 0) -> jax.Array:
    x = params["embed"]["tokens"][tokens].astype(cfg.dtype)
    S = tokens.shape[1]
    pe = sinusoidal_pe(jnp.arange(S) + pos0, cfg.d_model).astype(cfg.dtype)
    return constrain(x + pe, "batch", None, None)


def encdec_loss(params, batch: Dict[str, jax.Array], cfg: ModelConfig):
    """batch: audio_embed (B,T_enc,F), dec_tokens (B,T_dec)."""
    enc_out = encode(params, batch["audio_embed"], cfg)
    x = _embed_dec(params, batch["dec_tokens"], cfg)

    def body(h, lp):
        return _dec_block_train(lp, h, enc_out, cfg), None

    if cfg.scan_layers:
        x, _ = jax.lax.scan(body, x, params["decoder"])
    else:
        for i in range(cfg.n_dec_layers):
            lp = jax.tree_util.tree_map(lambda a: a[i], params["decoder"])
            x, _ = body(x, lp)
    x = rmsnorm(params["dec_norm"], x, cfg.norm_eps)
    logits = lm_logits(params["embed"], x, cfg)
    labels = jnp.roll(batch["dec_tokens"], -1, axis=1)
    valid = jnp.ones_like(batch["dec_tokens"], jnp.float32).at[:, -1].set(0.0)
    return cross_entropy(logits, labels, valid)


# ------------------------------------------------------------------ serving
def init_decode_state(params, audio_embed: jax.Array, cfg: ModelConfig,
                      max_len: int) -> Dict[str, Any]:
    """Encoder pass + per-layer cross-KV precompute + empty self-KV cache."""
    enc_out = encode(params, audio_embed, cfg)

    def per_layer(lp):
        return attn_mod.precompute_cross_kv(lp["cross_attn"], enc_out, cfg)

    B = audio_embed.shape[0]
    if cfg.scan_layers:
        cross = jax.vmap(per_layer)(params["decoder"])
        self_kv = attn_mod.init_kv_cache(cfg, B, max_len,
                                         n_layers=cfg.n_dec_layers)
    else:
        # unrolled: per-layer buffers (no slice-of-stacked; in-place updates)
        cross = [per_layer(jax.tree_util.tree_map(lambda a: a[i],
                                                  params["decoder"]))
                 for i in range(cfg.n_dec_layers)]
        self_kv = [attn_mod.init_kv_cache(cfg, B, max_len)
                   for _ in range(cfg.n_dec_layers)]
    return {"cross": cross, "self": self_kv}


def encdec_decode_step(params, token: jax.Array, cfg: ModelConfig,
                       state: Dict[str, Any], pos: jax.Array):
    """One decoder token against 32k self-KV + precomputed cross-KV."""
    x = _embed_dec(params, token, cfg, pos0=pos)

    def body(h, inp):
        lp, kvc, ckv = inp
        hh = rmsnorm(lp["norm1"], h, cfg.norm_eps)
        a, nkv = attn_mod.decode_attention(lp["self_attn"], hh, cfg, kvc, pos)
        h = h + a
        hh = rmsnorm(lp["norm_x"], h, cfg.norm_eps)
        h = h + attn_mod.cross_attention(lp["cross_attn"], hh, ckv, cfg)
        hh = rmsnorm(lp["norm2"], h, cfg.norm_eps)
        return h + mlp(lp["mlp"], hh, cfg.gather_weights), nkv

    if cfg.scan_layers:
        x, new_kv = jax.lax.scan(
            body, x, (params["decoder"], state["self"], state["cross"]))
    else:
        kvs = []
        for i in range(cfg.n_dec_layers):
            lp = jax.tree_util.tree_map(lambda a: a[i], params["decoder"])
            x, nkv = body(x, (lp, state["self"][i], state["cross"][i]))
            kvs.append(nkv)
        new_kv = kvs
    x = rmsnorm(params["dec_norm"], x, cfg.norm_eps)
    logits = lm_logits(params["embed"], x, cfg)
    return logits, {"cross": state["cross"], "self": new_kv}
