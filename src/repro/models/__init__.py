from repro.models.common import (AUDIO, DENSE, HYBRID, MOE, SSM, VLM,
                                 ModelConfig, MoEConfig, SSMConfig)
from repro.models import lm, encdec

__all__ = ["AUDIO", "DENSE", "HYBRID", "MOE", "SSM", "VLM", "ModelConfig",
           "MoEConfig", "SSMConfig", "lm", "encdec"]
