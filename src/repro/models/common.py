"""Model configuration and logical-axis vocabulary.

One ``ModelConfig`` covers every assigned family (dense / moe / ssm /
hybrid / audio enc-dec / vlm). Architecture files in ``repro/configs``
instantiate it with the exact published dimensions; ``reduced()`` derives
the CPU smoke-test configuration.

Logical axis names used on params/activations (resolved to mesh axes by
``repro.train.sharding``):

    "batch"   activation batch             → (pod, data)
    "fsdp"    weight shard dim (ZeRO-3)    → (pod, data)
    "tp"      tensor-parallel dim          → model
    "vocab"   embedding/vocab dim          → model
    "expert"  MoE expert dim               → model
    "seq"     sequence (SP for long decode)→ data (long_500k only)
    None      replicated
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Optional, Tuple

import jax.numpy as jnp

# ---------------------------------------------------------------- families
DENSE = "dense"
MOE = "moe"
SSM = "ssm"
HYBRID = "hybrid"
AUDIO = "audio"   # encoder-decoder, stub audio frontend
VLM = "vlm"       # decoder LM, stub vision frontend


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 0
    top_k: int = 1
    d_ff: int = 0               # per-expert hidden dim
    shared_d_ff: int = 0        # shared-expert hidden dim (0 = none)
    capacity_factor: float = 1.25
    router_dtype: Any = jnp.float32
    #: "einsum"  = GShard one-hot dispatch (baseline; sharding-friendly,
    #:            pays one-hot matmul FLOPs)
    #: "scatter" = sort/scatter dispatch (optimized; no dispatch FLOPs)
    dispatch: str = "einsum"
    #: tokens per routing group (GShard G×S grouping); 0 = one seq per group
    group_size: int = 0


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    state_dim: int = 128
    head_dim: int = 64
    expand: int = 2
    conv_width: int = 4
    n_groups: int = 1
    chunk_size: int = 256       # SSD chunked-scan block length
    dt_min: float = 0.001
    dt_max: float = 0.1


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    family: str = DENSE
    n_layers: int = 2
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 4
    head_dim: int = 0           # 0 → d_model // n_heads
    d_ff: int = 1024
    vocab_size: int = 1024
    norm_eps: float = 1e-5
    rope_theta: float = 10000.0
    #: sliding-window attention size; 0 = full attention
    window: int = 0
    #: command-r style parallel attn+MLP block sharing one pre-norm
    parallel_block: bool = False
    #: tie input embedding and LM head (true for small models)
    tied_embeddings: bool = True
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    # hybrid (zamba2): shared transformer block every k SSM layers,
    # operating on concat(x, x0) at width 2·d_model
    hybrid_attn_every: int = 6
    # encoder-decoder (whisper)
    n_enc_layers: int = 0
    n_dec_layers: int = 0
    max_target_len: int = 448
    #: frontend stub: inputs are precomputed embeddings of this dim
    frontend_dim: int = 0
    #: vlm: number of prepended patch-embedding positions
    n_patches: int = 0

    dtype: Any = jnp.bfloat16
    param_dtype: Any = jnp.bfloat16
    #: scan over stacked layers (compile-time/memory win) vs python loop
    scan_layers: bool = True
    #: remat policy for the layer body: "none" | "full" | "dots"
    remat: str = "full"
    #: attention implementation: "xla" | "pallas" | "pallas_interpret"
    attn_impl: str = "xla"
    #: §Perf optimization: bf16 score/softmax tensors with fp32 reductions
    #: + bf16-applied RoPE (flash-attention numerics). False = baseline.
    lean_attention: bool = False
    #: §Perf optimization: ZeRO-3-style just-in-time weight all-gather —
    #: un-shard the fsdp dim of each weight at its use site so matmuls
    #: contract replicated dims (weight gathers, small) instead of psum-ing
    #: activation-sized partial sums. False = baseline.
    gather_weights: bool = False
    #: pad attention heads up to a multiple of this for TP divisibility
    #: (DESIGN.md §5: llama4 40→48, deepseek 56→64, whisper 20→32)
    head_pad_to: int = 16
    #: pad the embedding table to a multiple of this (vocab must divide the
    #: TP degree; padded logits are masked to -inf — standard practice)
    vocab_pad_to: int = 128

    # ----------------------------------------------------------- derived
    @property
    def kv_groups(self) -> int:
        return self.n_heads // self.n_kv_heads

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.n_heads if self.n_heads else 0

    @property
    def padded_heads(self) -> int:
        if self.head_pad_to <= 1:
            return self.n_heads
        return math.ceil(self.n_heads / self.head_pad_to) * self.head_pad_to

    @property
    def padded_vocab(self) -> int:
        if self.vocab_pad_to <= 1:
            return self.vocab_size
        return math.ceil(self.vocab_size / self.vocab_pad_to) * self.vocab_pad_to

    @property
    def padded_kv_heads(self) -> int:
        """KV heads after TP padding.

        GQA (groups > 1): kv heads stay real — q-head padding is
        distributed *within* each kv group (llama4 40→48 = 8 groups of
        5 real + 1 pad; deepseek 56→64 = 8×(7+1)). MHA (groups == 1,
        whisper 20→32): kv pads alongside q. Padded slots carry zero
        q/o weights, so the computed function is exactly the unpadded
        architecture (unit-tested)."""
        if self.n_kv_heads and self.padded_heads % self.n_kv_heads == 0 \
                and self.kv_groups > 1:
            return self.n_kv_heads
        return max(1, self.padded_heads // max(self.kv_groups, 1))

    @property
    def padded_kv_groups(self) -> int:
        return self.padded_heads // self.padded_kv_heads if self.n_heads \
            else 1

    @property
    def is_encdec(self) -> bool:
        return self.family == AUDIO

    @property
    def attn_free(self) -> bool:
        return self.family == SSM

    @property
    def ssm_inner(self) -> int:
        assert self.ssm is not None
        return self.ssm.expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        assert self.ssm is not None
        return self.ssm_inner // self.ssm.head_dim

    def param_count(self) -> int:
        """Analytic parameter count (for 6·N·D roofline terms)."""
        d, v = self.d_model, self.vocab_size
        hd = self.resolved_head_dim
        emb = v * d * (1 if self.tied_embeddings else 2)

        def attn_params(width: int, heads: int, kv: int) -> int:
            return (width * heads * hd + 2 * width * kv * hd
                    + heads * hd * width)

        def mlp_params(width: int, ff: int) -> int:
            return 3 * width * ff  # gated (SwiGLU)

        if self.family == SSM:
            s = self.ssm
            di = self.ssm_inner
            per = (d * (2 * di + 2 * s.n_groups * s.state_dim + self.ssm_heads)
                   + s.conv_width * (di + 2 * s.n_groups * s.state_dim)
                   + 2 * self.ssm_heads + di   # A, D, dt_bias + gated-norm
                   + di * d)
            return emb + self.n_layers * (per + d)
        if self.family == HYBRID:
            s = self.ssm
            di = self.ssm_inner
            per = (d * (2 * di + 2 * s.n_groups * s.state_dim + self.ssm_heads)
                   + s.conv_width * (di + 2 * s.n_groups * s.state_dim)
                   + 2 * self.ssm_heads + di + di * d)
            w = 2 * d   # shared block width
            shared = (attn_params(w, self.n_heads, self.n_kv_heads)
                      + mlp_params(w, self.d_ff) + 2 * w
                      + (self.n_layers // self.hybrid_attn_every) * (w * d))
            return emb + self.n_layers * (per + d) + shared
        if self.family == AUDIO:
            per_enc = attn_params(d, self.n_heads, self.n_kv_heads) \
                + mlp_params(d, self.d_ff) + 2 * d
            per_dec = 2 * attn_params(d, self.n_heads, self.n_kv_heads) \
                + mlp_params(d, self.d_ff) + 3 * d
            return emb + self.n_enc_layers * per_enc \
                + self.n_dec_layers * per_dec + self.frontend_dim * d
        per = attn_params(d, self.n_heads, self.n_kv_heads) + 2 * d
        if self.moe is not None:
            m = self.moe
            per += d * m.n_experts                     # router
            per += m.n_experts * mlp_params(d, m.d_ff)
            if m.shared_d_ff:
                per += mlp_params(d, m.shared_d_ff)
        else:
            per += mlp_params(d, self.d_ff)
        n = emb + self.n_layers * per + d
        if self.family == VLM:
            n += self.frontend_dim * d                 # patch projector stub
        return n

    def active_param_count(self) -> int:
        """Active params per token (MoE: routed top-k + shared only)."""
        if self.moe is None:
            return self.param_count()
        m = self.moe
        dense_like = self.param_count() \
            - self.n_layers * m.n_experts * 3 * self.d_model * m.d_ff
        return dense_like + self.n_layers * m.top_k * 3 * self.d_model * m.d_ff

    # ------------------------------------------------------------- reduced
    def reduced(self) -> "ModelConfig":
        """Tiny same-family config for CPU smoke tests."""
        kw: dict = dict(
            name=self.name + "-reduced",
            n_layers=min(self.n_layers, 2 if self.family != HYBRID else 4),
            d_model=128,
            n_heads=4, n_kv_heads=max(1, min(self.n_kv_heads, 2)),
            head_dim=32,
            d_ff=256 if self.d_ff else 0,
            vocab_size=512,
            window=min(self.window, 64) if self.window else 0,
            scan_layers=self.scan_layers,
            remat="none",
            head_pad_to=1,
            vocab_pad_to=1,
            parallel_block=self.parallel_block,
            family=self.family,
            hybrid_attn_every=2,
            tied_embeddings=True,
        )
        if self.moe is not None:
            kw["moe"] = dataclasses.replace(
                self.moe, n_experts=4, top_k=min(self.moe.top_k, 2),
                d_ff=128, shared_d_ff=128 if self.moe.shared_d_ff else 0)
        if self.ssm is not None:
            kw["ssm"] = dataclasses.replace(
                self.ssm, state_dim=16, head_dim=16, chunk_size=32)
        if self.family == AUDIO:
            kw.update(n_enc_layers=2, n_dec_layers=2, max_target_len=32,
                      frontend_dim=64)
        if self.family == VLM:
            kw.update(frontend_dim=64, n_patches=16)
        return dataclasses.replace(self, **kw)
