"""Mixture-of-Experts block.

Two dispatch implementations (DESIGN.md §9 — the contrast is a planned
§Perf iteration):

* ``"einsum"``  — GShard-style capacity-based one-hot dispatch/combine
  einsums. Shards perfectly under GSPMD (experts over the model axis,
  groups over data → all-to-all emitted by the partitioner) but pays
  one-hot matmul FLOPs comparable to the expert compute itself.
* ``"scatter"`` — sort-free scatter/gather dispatch into the same
  (expert, capacity) buffer layout: no dispatch FLOPs, indexing only.

Both drop tokens beyond expert capacity (capacity_factor), matching the
published GShard/Switch training recipe.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig
from repro.models.layers import (_init_dense, gathered, init_mlp, mlp,
                                 mlp_specs)
from repro.sharding import constrain


def init_moe(key, cfg: ModelConfig) -> Dict[str, Any]:
    m = cfg.moe
    d = cfg.d_model
    ks = jax.random.split(key, 5)
    p = {
        "router": _init_dense(ks[0], (d, m.n_experts), jnp.float32),
        "wi": _init_dense(ks[1], (m.n_experts, d, m.d_ff), cfg.param_dtype),
        "wg": _init_dense(ks[2], (m.n_experts, d, m.d_ff), cfg.param_dtype),
        "wo": _init_dense(ks[3], (m.n_experts, m.d_ff, d), cfg.param_dtype,
                          scale=m.d_ff ** -0.5),
    }
    if m.shared_d_ff:
        p["shared"] = init_mlp(ks[4], d, m.shared_d_ff, cfg.param_dtype)
    return p


def moe_specs(cfg: ModelConfig) -> Dict[str, Any]:
    p = {
        "router": ("fsdp", None),
        "wi": ("expert", "fsdp", None),
        "wg": ("expert", "fsdp", None),
        "wo": ("expert", None, "fsdp"),
    }
    if cfg.moe.shared_d_ff:
        p["shared"] = mlp_specs()
    return p


def expert_capacity(cfg: ModelConfig, tokens_per_group: int) -> int:
    m = cfg.moe
    cap = int(tokens_per_group * m.top_k / m.n_experts * m.capacity_factor)
    cap = max(cap, 1)
    return cap + (-cap) % 4 if cap > 4 else cap


def _route(params, x: jax.Array, cfg: ModelConfig
           ) -> Tuple[jax.Array, jax.Array]:
    """Router: top-k gates (renormalized) + expert indices. x: (B,S,d)."""
    m = cfg.moe
    logits = jnp.einsum("bsd,de->bse", x.astype(m.router_dtype),
                        params["router"].astype(m.router_dtype))
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = jax.lax.top_k(probs, m.top_k)           # (B,S,k)
    gate = gate / jnp.maximum(jnp.sum(gate, axis=-1, keepdims=True), 1e-9)
    return gate, idx


def load_balance_loss(gate_probs_mean: jax.Array,
                      dispatch_frac: jax.Array) -> jax.Array:
    """Switch/GShard auxiliary loss term (used by the trainer)."""
    E = gate_probs_mean.shape[-1]
    return E * jnp.sum(gate_probs_mean * dispatch_frac)


def _positions_in_expert(idx: jax.Array, n_experts: int) -> jax.Array:
    """idx: (B, S, k) → position of each assignment within its expert,
    counted in (s, k) order per batch group. Returns (B, S, k) int32."""
    B, S, k = idx.shape
    onehot = jax.nn.one_hot(idx.reshape(B, S * k), n_experts, dtype=jnp.int32)
    pos = jnp.cumsum(onehot, axis=1) - onehot            # exclusive
    sel = jnp.take_along_axis(pos, idx.reshape(B, S * k, 1), axis=-1)
    return sel.reshape(B, S, k)


def _experts_apply(params, expert_in: jax.Array, cfg: ModelConfig
                   ) -> jax.Array:
    """expert_in: (E, B, C, d) → (E, B, C, d) through per-expert SwiGLU."""
    gw = cfg.gather_weights
    wi = gathered(params["wi"], "expert", None, None, gather=gw)
    wg = gathered(params["wg"], "expert", None, None, gather=gw)
    wo = gathered(params["wo"], "expert", None, None, gather=gw)
    h = jnp.einsum("ebcd,edf->ebcf", expert_in, wi.astype(cfg.dtype))
    g = jnp.einsum("ebcd,edf->ebcf", expert_in, wg.astype(cfg.dtype))
    h = jax.nn.silu(g) * h
    h = constrain(h, "expert", "batch", None, None)
    return jnp.einsum("ebcf,efd->ebcd", h, wo.astype(cfg.dtype))


def moe_block(params, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    m = cfg.moe
    B, S, d = x.shape
    gate, idx = _route(params, x, cfg)
    C = expert_capacity(cfg, S)
    pos = _positions_in_expert(idx, m.n_experts)         # (B,S,k)
    keep = (pos < C)
    gate = (gate * keep).astype(cfg.dtype)

    if m.dispatch == "einsum":
        onehot_e = jax.nn.one_hot(idx, m.n_experts, dtype=cfg.dtype)
        onehot_c = jax.nn.one_hot(pos, C, dtype=cfg.dtype) \
            * keep[..., None].astype(cfg.dtype)
        # (B,S,k,E) × (B,S,k,C) → dispatch (B,S,E,C); combine adds gates
        dispatch = jnp.einsum("bske,bskc->bsec", onehot_e, onehot_c)
        dispatch = constrain(dispatch, "batch", None, "expert", None)
        combine = jnp.einsum("bske,bskc,bsk->bsec", onehot_e, onehot_c, gate)
        combine = constrain(combine, "batch", None, "expert", None)
        expert_in = jnp.einsum("bsec,bsd->ebcd", dispatch, x)
        expert_in = constrain(expert_in, "expert", "batch", None, None)
        out = _experts_apply(params, expert_in, cfg)
        y = jnp.einsum("bsec,ebcd->bsd", combine, out)
    else:  # "scatter": same (E,C) buffer, built by indexing — no matmul FLOPs
        slot = idx * C + pos                              # (B,S,k)
        slot = jnp.where(keep, slot, m.n_experts * C)     # overflow → trash row
        buf = jnp.zeros((B, m.n_experts * C + 1, d), cfg.dtype)
        flat_slot = slot.reshape(B, S * m.top_k)
        src = jnp.repeat(x, m.top_k, axis=1)              # (B, S·k, d)
        buf = buf.at[jnp.arange(B)[:, None], flat_slot].set(src)
        expert_in = buf[:, :-1].reshape(B, m.n_experts, C, d)
        expert_in = constrain(expert_in.transpose(1, 0, 2, 3),
                              "expert", "batch", None, None)
        out = _experts_apply(params, expert_in, cfg)      # (E,B,C,d)
        out_flat = out.transpose(1, 0, 2, 3).reshape(B, m.n_experts * C, d)
        out_flat = jnp.concatenate(
            [out_flat, jnp.zeros((B, 1, d), cfg.dtype)], axis=1)
        picked = jnp.take_along_axis(
            out_flat, flat_slot[..., None], axis=1)       # (B, S·k, d)
        y = jnp.einsum("bskd,bsk->bsd",
                       picked.reshape(B, S, m.top_k, d), gate)
    y = constrain(y, "batch", None, None)
    if m.shared_d_ff:
        y = y + mlp(params["shared"], x, cfg.gather_weights)
    return y
