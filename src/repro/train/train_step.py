"""Train-step factory: microbatched gradient accumulation + AdamW.

The global batch is reshaped to (n_microbatches, mb, ...) and scanned;
fp32 gradient accumulators are sharded like the weights (FSDP), so the
per-microbatch reduce-scatters overlap the next microbatch's backward under
XLA's scheduler — the device-plane realization of the paper's
"no idle waiting on completion" objective (DESIGN.md §2b).
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.models import encdec, lm
from repro.models.common import AUDIO, ModelConfig
from repro.optim import (OptConfig, adamw_init, adamw_update,
                         clip_by_global_norm, opt_state_specs, warmup_cosine)
from repro.sharding import constrain


def default_loss_fn(cfg: ModelConfig) -> Callable:
    if cfg.family == AUDIO:
        return lambda p, b: encdec.encdec_loss(p, b, cfg)
    return lambda p, b: lm.lm_loss(p, b, cfg)


def init_train_state(key, cfg: ModelConfig, opt_cfg: OptConfig) -> Dict[str, Any]:
    init_fn = encdec.init_params if cfg.family == AUDIO else lm.init_params
    params = init_fn(key, cfg)
    return {"params": params, "opt": adamw_init(params, opt_cfg),
            "step": jnp.zeros((), jnp.int32)}


def train_state_specs(cfg: ModelConfig) -> Dict[str, Any]:
    specs_fn = encdec.param_specs if cfg.family == AUDIO else lm.param_specs
    pspecs = specs_fn(cfg)
    return {"params": pspecs, "opt": opt_state_specs(pspecs), "step": ()}


def make_train_step(cfg: ModelConfig, opt_cfg: OptConfig, *,
                    num_microbatches: int = 1,
                    lr_schedule: Optional[Callable] = None,
                    loss_fn: Optional[Callable] = None,
                    grad_spec_tree: Any = None) -> Callable:
    """``grad_spec_tree``: logical-axis tree (= param specs). When given,
    per-microbatch gradients are constrained to the weight sharding, which
    lets GSPMD lower the data-parallel sync as reduce-scatters fused into
    the backward instead of full all-reduces (§Perf optimization)."""
    loss_fn = loss_fn or default_loss_fn(cfg)
    lr_schedule = lr_schedule or (lambda step: jnp.float32(opt_cfg.lr))

    def _constrain_grads(grads):
        if grad_spec_tree is None:
            return grads
        from repro.sharding import constrain
        return jax.tree_util.tree_map(
            lambda axes, g: constrain(g, *axes),
            grad_spec_tree, grads,
            is_leaf=lambda v: isinstance(v, tuple))

    def grads_of(params, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        grads = jax.tree_util.tree_map(lambda g: g.astype(jnp.float32), grads)
        return loss, _constrain_grads(grads)

    def train_step(state, batch):
        params = state["params"]
        if num_microbatches == 1:
            loss, grads = grads_of(params, batch)
        else:
            def split_mb(x):
                x = x.reshape((num_microbatches, -1) + x.shape[1:])
                return constrain(x, None, "batch", *([None] * (x.ndim - 2)))

            mbs = jax.tree_util.tree_map(split_mb, batch)

            def body(acc, mb):
                loss_acc, grads_acc = acc
                loss, grads = grads_of(params, mb)
                grads_acc = jax.tree_util.tree_map(jnp.add, grads_acc, grads)
                return (loss_acc + loss, grads_acc), None

            zeros = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss, grads), _ = jax.lax.scan(
                body, (jnp.zeros((), jnp.float32), zeros), mbs)
            inv = 1.0 / num_microbatches
            loss = loss * inv
            grads = jax.tree_util.tree_map(lambda g: g * inv, grads)

        grads, gnorm = clip_by_global_norm(grads, opt_cfg.grad_clip)
        lr = lr_schedule(state["step"])
        new_params, new_opt = adamw_update(grads, state["opt"], params, lr,
                                           opt_cfg)
        new_state = {"params": new_params, "opt": new_opt,
                     "step": state["step"] + 1}
        metrics = {"loss": loss, "grad_norm": gnorm, "lr": lr}
        return new_state, metrics

    return train_step
