"""Per-shape sharding rule tables (DESIGN.md §5).

The logical-axis vocabulary is fixed (repro.sharding.DEFAULT_RULES); what
varies across the four assigned input shapes is how activations map to the
mesh:

* ``train`` / ``prefill``: batch + FSDP over (pod, data); weights TP over
  model; activation heads over model; KV-seq unsharded (prefill caches
  shard on head_dim via the weight "tp" rule).
* ``decode``: flash-decoding layout — KV cache sequence over *model*,
  activation heads replicated (the per-token tensors are tiny; the cache
  is the object being parallelized), batch over (pod, data).
* ``long`` (seq 500k, batch 1): batch unshardable → KV window/state
  sequence over (pod, data) (sequence parallelism), SSM state heads over
  model, activation heads replicated.
"""
from __future__ import annotations

from typing import Dict, Optional

from repro.sharding import DEFAULT_RULES

SHAPE_KINDS = ("train", "prefill", "decode", "long")


def rules_for(kind: str) -> Dict[str, object]:
    if kind not in SHAPE_KINDS:
        raise ValueError(f"unknown shape kind {kind!r}")
    rules = dict(DEFAULT_RULES)
    if kind == "decode":
        rules.update({"kv_seq": "model", "act_heads": None})
    elif kind == "long":
        rules.update({"batch": None, "kv_seq": ("pod", "data"),
                      "act_heads": None})
    return rules


def batch_logical_axes(batch_tree) -> dict:
    """Logical axes for input batches: leading batch dim, rest replicated."""
    import jax

    def leaf_axes(x):
        return ("batch",) + (None,) * (len(x.shape) - 1)

    return jax.tree_util.tree_map(leaf_axes, batch_tree)
