"""Inject generated tables into EXPERIMENTS.md between markers."""
from __future__ import annotations

import argparse
import json
import os
import re

from repro.roofline.report import (bottleneck_notes, dryrun_table,
                                   load_results, roofline_table)


def replace_between(text: str, start: str, end: str, payload: str) -> str:
    pattern = re.compile(re.escape(start) + r".*?" + re.escape(end),
                         re.DOTALL)
    return pattern.sub(start + "\n" + payload + "\n" + end, text)


def e2e_section(path: str) -> str:
    if not os.path.exists(path):
        return "(run in progress)"
    with open(path) as f:
        r = json.load(f)
    rows = r["rows"]
    pick = [row for row in rows if row["step"] % 25 == 0 or
            row["step"] == rows[-1]["step"]]
    lines = ["| step | loss | elapsed |", "|---|---|---|"]
    for row in pick:
        lines.append(f"| {row['step']} | {row['loss']:.4f} "
                     f"| {row['elapsed_s']:.0f}s |")
    lines.append("")
    lines.append(f"Loss {r['first_loss']:.3f} → {r['final_loss']:.3f} over "
                 f"{r['steps']} steps ({r['elapsed_s']}s wall on 1 CPU core; "
                 f"checkpoints committed asynchronously at every 50 steps — "
                 f"crash-restart resumes bit-exactly, tests/substrate).")
    return "\n".join(lines)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--results", default="results/dryrun")
    ap.add_argument("--experiments", default="EXPERIMENTS.md")
    ap.add_argument("--train-json", default="results/train_small.json")
    args = ap.parse_args()
    results = load_results(args.results)
    with open(args.experiments) as f:
        text = f.read()
    text = replace_between(text, "<!-- DRYRUN_TABLE_START -->",
                           "<!-- DRYRUN_TABLE_END -->", dryrun_table(results))
    text = replace_between(text, "<!-- ROOFLINE_TABLE_START -->",
                           "<!-- ROOFLINE_TABLE_END -->",
                           roofline_table(results))
    text = replace_between(text, "<!-- NOTES_START -->", "<!-- NOTES_END -->",
                           bottleneck_notes(results))
    text = replace_between(text, "<!-- E2E_START -->", "<!-- E2E_END -->",
                           e2e_section(args.train_json))
    with open(args.experiments, "w") as f:
        f.write(text)
    print(f"updated {args.experiments} from {len(results)} results")


if __name__ == "__main__":
    main()
