"""Generate EXPERIMENTS.md §Dry-run / §Roofline tables from sweep JSONs."""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List, Optional


def load_results(results_dir: str) -> List[Dict]:
    out = []
    for path in sorted(glob.glob(os.path.join(results_dir, "*.json"))):
        with open(path) as f:
            out.append(json.load(f))
    return out


def _fmt_s(v: Optional[float]) -> str:
    if v is None:
        return "—"
    if v >= 1.0:
        return f"{v:.2f}s"
    if v >= 1e-3:
        return f"{v * 1e3:.1f}ms"
    return f"{v * 1e6:.0f}µs"


def _fmt_n(v: Optional[float]) -> str:
    if v is None:
        return "—"
    for unit, scale in (("P", 1e15), ("T", 1e12), ("G", 1e9), ("M", 1e6)):
        if abs(v) >= scale:
            return f"{v / scale:.2f}{unit}"
    return f"{v:.0f}"


FIX_HINTS = {
    ("memory_s", "train"): "cut activation traffic: fused/chunked attention "
                           "(no (S,S) scores in HBM), bf16 master/opt state",
    ("memory_s", "decode"): "KV-cache layout (no transposes), quantized KV, "
                            "larger per-step batch",
    ("memory_s", "prefill"): "chunked attention + remat-free fwd",
    ("memory_s", "long"): "state layout; batch>1 to amortize weight reads",
    ("compute_s", "train"): "drop one-hot dispatch FLOPs (MoE) / reduce "
                            "remat recompute",
    ("compute_s", "prefill"): "flash attention kernel (MXU-shaped tiles)",
    ("collective_s", "train"): "reduce-scatter grad sync instead of "
                               "all-reduce; overlap via microbatch scan",
    ("collective_s", "decode"): "shrink per-layer all-gathers (act_heads "
                                "layout)",
    ("collective_s", "prefill"): "same",
    ("collective_s", "long"): "sequence-parallel state partitioning",
}


def roofline_table(results: List[Dict]) -> str:
    rows = [
        "| arch | shape | status | compute | memory | collective | dominant "
        "| roofline frac | MODEL/HLO flops | fits 16G |",
        "|---|---|---|---|---|---|---|---|---|---|".replace("|---|---|---|---"
                                                             "|---|---|---|---"
                                                             "|---|---|",
                                                             "|---|---|---|---"
                                                             "|---|---|---|---"
                                                             "|---|"),
    ]
    for r in results:
        if r.get("multi_pod") or not r.get("exact"):
            continue
        arch, shape = r["arch"], r["shape"]
        if r["status"] == "skipped":
            rows.append(f"| {arch} | {shape} | skip: {r['reason'][:40]}… "
                        f"| — | — | — | — | — | — | — |")
            continue
        if r["status"] != "ok":
            rows.append(f"| {arch} | {shape} | {r['status']} "
                        f"| — | — | — | — | — | — | — |")
            continue
        t = r["roofline"]
        mem = r["memory"]
        rows.append(
            f"| {arch} | {shape} | ok | {_fmt_s(t['compute_s'])} "
            f"| {_fmt_s(t['memory_s'])} | {_fmt_s(t['collective_s'])} "
            f"| {t['dominant'].replace('_s', '')} "
            f"| {t['roofline_fraction']:.3f} "
            f"| {t['useful_flops_ratio']:.2f} "
            f"| {'✓' if mem['fits_16g_hbm'] else '✗'} |")
    return "\n".join(rows)


def dryrun_table(results: List[Dict]) -> str:
    rows = [
        "| arch | shape | mesh | status | compile | args/dev | temp/dev "
        "| collectives (count) |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in results:
        mesh = "2×16×16" if r.get("multi_pod") else "16×16"
        arch, shape = r["arch"], r["shape"]
        if r["status"] == "skipped":
            rows.append(f"| {arch} | {shape} | {mesh} | skipped (documented) "
                        f"| — | — | — | — |")
            continue
        if r["status"] != "ok":
            rows.append(f"| {arch} | {shape} | {mesh} | {r['status']} "
                        f"| — | — | — | — |")
            continue
        mem = r["memory"]
        colls = r.get("collectives", {})
        cstr = " ".join(f"{k.split('-')[-1][:4]}:{int(v['count'])}"
                        for k, v in colls.items() if v["count"])
        rows.append(
            f"| {arch} | {shape} | {mesh} | ok | {r['compile_s']}s "
            f"| {_fmt_n(mem['argument_bytes_per_device'])}B "
            f"| {_fmt_n(mem['temp_bytes_per_device'])}B | {cstr} |")
    return "\n".join(rows)


def bottleneck_notes(results: List[Dict]) -> str:
    lines = []
    from repro.launch.shapes import SHAPES
    for r in results:
        if r.get("multi_pod") or not r.get("exact") or r["status"] != "ok":
            continue
        kind = SHAPES[r["shape"]].kind
        dom = r["roofline"]["dominant"]
        hint = FIX_HINTS.get((dom, kind), "—")
        lines.append(f"- **{r['arch']} × {r['shape']}** — bottleneck "
                     f"{dom.replace('_s', '')}: {hint}.")
    return "\n".join(lines)


def main() -> None:
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--results", default="results/dryrun")
    ap.add_argument("--section", choices=["roofline", "dryrun", "notes"],
                    default="roofline")
    args = ap.parse_args()
    results = load_results(args.results)
    if args.section == "roofline":
        print(roofline_table(results))
    elif args.section == "dryrun":
        print(dryrun_table(results))
    else:
        print(bottleneck_notes(results))


if __name__ == "__main__":
    main()
