"""HLO post-partitioning analysis: collective bytes + roofline terms.

``collective_bytes`` parses the compiled (per-device SPMD) module text and
sums *operand* bytes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute, via a name→shape symbol table built from
the instruction definitions (cost_analysis does not expose collectives).

Hardware model (assignment constants, TPU v5e):
    197 TFLOP/s bf16 · chip⁻¹ ;  819 GB/s HBM ;  ~50 GB/s/link ICI.

Terms are computed from per-device quantities of the partitioned module
(cost_analysis FLOPs/bytes are per-device; collective operand bytes are the
per-device payload — ring algorithms move ≈ (n−1)/n of it per link, which
this model rounds to 1).
"""
from __future__ import annotations

import re
from typing import Dict, Optional

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s
LINK_BW = 50e9               # bytes/s per ICI link

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")


def _shape_bytes(text: str) -> int:
    """Sum bytes over every dtype[dims] occurrence in a type string."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(text):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, Dict[str, float]]:
    """Per collective kind: op count + summed operand bytes (per device)."""
    # symbol table: instruction name -> bytes of its (tuple) result type
    sym: Dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if not m:
            continue
        name, rhs = m.groups()
        # result type = everything before the opcode token; cheapest robust
        # approach: bytes of all shapes appearing before the first '(' that
        # follows the opcode — instead take shapes in the segment before
        # the opcode word.
        sym[name] = _shape_bytes(rhs.split("(", 1)[0])
    out = {k: {"count": 0, "bytes": 0.0} for k in COLLECTIVES}
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if not m:
            continue
        _, rhs = m.groups()
        opcode_m = re.match(r"(?:\([^=]*\)|[a-z0-9]+\[[0-9,]*\]\S*)\s+"
                            r"([a-z0-9\-]+)", rhs)
        if not opcode_m:
            continue
        opcode = opcode_m.group(1)
        kind = next((k for k in COLLECTIVES
                     if opcode == k or opcode.startswith(k + ".")), None)
        if kind is None:
            continue
        # operand list: first (...) after the opcode
        tail = rhs.split(opcode, 1)[1]
        paren = tail.find("(")
        if paren < 0:
            continue
        depth, j = 0, paren
        for j in range(paren, len(tail)):
            if tail[j] == "(":
                depth += 1
            elif tail[j] == ")":
                depth -= 1
                if depth == 0:
                    break
        operands = tail[paren + 1:j]
        # Operand spelling differs across XLA text dumps: typed
        # ``f32[2,8]{1,0} %name`` (each operand carries its shape — sum the
        # shapes directly; a comma-split would break inside the dims) vs
        # untyped ``%name`` (resolve through the symbol table).
        total = _shape_bytes(operands)
        if total == 0:
            for opnd in operands.split(","):
                opnd = opnd.strip().lstrip("%")
                opnd = opnd.split(" ")[0]
                if opnd in sym:
                    total += sym[opnd]
                else:
                    total += _shape_bytes(opnd)
        out[kind]["count"] += 1
        out[kind]["bytes"] += float(total)
    return out


def opcode_bytes_histogram(hlo_text: str, top: int = 14) -> Dict[str, Dict]:
    """Output bytes + op counts per opcode — the dry-run 'profile' that
    drives §Perf hypotheses (no wall-clock exists on this container)."""
    hist: Dict[str, Dict[str, float]] = {}
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if not m:
            continue
        _, rhs = m.groups()
        opcode_m = re.match(r"(?:\([^=]*\)|[a-z0-9]+\[[0-9,]*\]\S*)\s+"
                            r"([a-z0-9\-]+)", rhs)
        if not opcode_m:
            continue
        opcode = opcode_m.group(1).split(".")[0]
        nbytes = _shape_bytes(rhs.split("(", 1)[0])
        rec = hist.setdefault(opcode, {"count": 0, "out_bytes": 0.0})
        rec["count"] += 1
        rec["out_bytes"] += nbytes
    ranked = sorted(hist.items(), key=lambda kv: -kv[1]["out_bytes"])
    return dict(ranked[:top])


def roofline_terms(flops_per_device: float, bytes_per_device: float,
                   coll_bytes_per_device: float, chips: int) -> Dict[str, float]:
    compute_s = flops_per_device / PEAK_FLOPS
    memory_s = bytes_per_device / HBM_BW
    collective_s = coll_bytes_per_device / LINK_BW
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s,
             "flops_global": flops_per_device * chips,
             "bytes_global": bytes_per_device * chips,
             "coll_bytes_global": coll_bytes_per_device * chips}
    dom = max(("compute_s", "memory_s", "collective_s"),
              key=lambda k: terms[k])
    terms["dominant"] = dom
    bound = max(terms["compute_s"], terms["memory_s"], terms["collective_s"])
    terms["roofline_fraction"] = (terms["compute_s"] / bound) if bound else 0.0
    return terms


def model_flops(cfg, shape_kind: str, tokens: int) -> float:
    """6·N·D (train) / 2·N_active·D (inference fwd) per assignment."""
    n_active = cfg.active_param_count()
    if shape_kind == "train":
        return 6.0 * n_active * tokens
    return 2.0 * n_active * tokens
