"""Load-generation and measurement subsystem for the serving stack.

The paper's claims are quantitative; this package is how the repo's own
serving claims earn the same trust — seeded replayable workloads,
multi-sample variance, SLO-style reporting, and saturation sweeps, all
speaking to any tier through ``serve.protocol.EngineLike``.

* ``bench.trace``  — workload models: arrival processes (open-loop
  Poisson, bursty on/off, closed-loop), heavy-tailed length
  distributions, shared-prefix mixtures, tenant/priority mixes — frozen
  into a serializable, byte-deterministic ``Trace``.
* ``bench.runner`` — ``Replayer``: replays a trace against
  ``ServeEngine`` / ``DisaggServer`` / ``Router`` through the
  ``ServeClient`` streaming surface, recording per-request TTFT,
  inter-token latencies, completion status and deadline outcomes.
* ``bench.stats``  — multi-sample summaries (mean / 95% CI /
  coefficient-of-variation) and the instability predicate the
  variance-aware regression gate uses.
* ``bench.report`` — ``SLO`` bounds + ``slo_report``: goodput under
  deadline, p50/p99/p99.9 TTFT and ITL, pass/fail verdicts, markdown.
* ``bench.sweep``  — binary-search the max sustainable QPS per config
  where the SLO still holds.
"""
from repro.bench.report import SLO, slo_report, to_markdown
from repro.bench.runner import Replayer, RequestRecord, RunResult, replay
from repro.bench.stats import (UNSTABLE_CV, Summary, is_unstable,
                               percentile, summarize, summarize_metrics,
                               variance_fields)
from repro.bench.sweep import (SweepPoint, SweepResult, saturation_sweep,
                               sweep_tier)
from repro.bench.trace import (Trace, TraceRequest, bounded_pareto,
                               micro_trace, onoff_arrivals,
                               poisson_arrivals, rescale_qps,
                               synthetic_trace)

__all__ = [
    "Trace", "TraceRequest", "synthetic_trace", "micro_trace",
    "rescale_qps", "poisson_arrivals", "onoff_arrivals", "bounded_pareto",
    "Replayer", "RequestRecord", "RunResult", "replay",
    "Summary", "summarize", "summarize_metrics", "variance_fields",
    "percentile", "is_unstable", "UNSTABLE_CV",
    "SLO", "slo_report", "to_markdown",
    "SweepPoint", "SweepResult", "saturation_sweep", "sweep_tier",
]
