"""Multi-sample statistics — the variance layer under every bench claim.

Single-shot timings on a shared 2-core box are the reason this repo's
regression gates carried 30-45% tolerances. The fix is not wider bands
but *measured dispersion*: run each scenario ``samples`` times, report
per-metric mean / confidence interval / coefficient of variation, and
let the gate distinguish "stable metric, tight tolerance" from
"unstable metric, record-only".

No scipy/numpy dependency: sample standard deviation and a normal-
approximation 95% CI are all the gate needs, and keeping this module
pure-Python means `benchmarks/check_regression.py` can import it from a
bare CI runner before JAX ever loads.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Mapping, Optional, Sequence

# coefficient-of-variation threshold above which a metric is treated as
# too noisy to gate (recorded-only, flagged "unstable" in summaries)
UNSTABLE_CV = 0.15


def percentile(values: Sequence[float], q: float) -> float:
    """Linear-interpolation percentile (q in [0, 1]) — stable for the
    small sample counts benches produce; 0.0 on empty input."""
    if not values:
        return 0.0
    s = sorted(values)
    if len(s) == 1:
        return float(s[0])
    pos = q * (len(s) - 1)
    lo = int(math.floor(pos))
    hi = min(lo + 1, len(s) - 1)
    frac = pos - lo
    return float(s[lo] * (1.0 - frac) + s[hi] * frac)


@dataclasses.dataclass(frozen=True)
class Summary:
    """Dispersion summary of one metric over n samples."""

    n: int
    mean: float
    std: float          # sample std (ddof=1); 0.0 when n < 2
    cv: float           # std / |mean|; 0.0 when mean == 0
    ci95: float         # 1.96 * std / sqrt(n) (normal approximation)
    lo: float
    hi: float
    values: tuple = ()

    @property
    def unstable(self) -> bool:
        """True when run-to-run dispersion is too high to gate on."""
        return self.cv > UNSTABLE_CV

    def to_dict(self) -> Dict[str, float]:
        return {"n": self.n, "mean": self.mean, "std": self.std,
                "cv": self.cv, "ci95": self.ci95,
                "lo": self.lo, "hi": self.hi,
                "values": list(self.values)}


def summarize(values: Sequence[float]) -> Summary:
    """Mean / std / cv / 95% CI over a sample list (>= 1 value)."""
    vals = [float(v) for v in values]
    if not vals:
        raise ValueError("summarize needs at least one sample")
    n = len(vals)
    mean = sum(vals) / n
    if n > 1:
        var = sum((v - mean) ** 2 for v in vals) / (n - 1)
        std = math.sqrt(var)
    else:
        std = 0.0
    cv = std / abs(mean) if mean else 0.0
    ci95 = 1.96 * std / math.sqrt(n) if n > 1 else 0.0
    return Summary(n=n, mean=mean, std=std, cv=cv, ci95=ci95,
                   lo=min(vals), hi=max(vals), values=tuple(vals))


def summarize_metrics(samples: Sequence[Mapping[str, float]]
                      ) -> Dict[str, Summary]:
    """Per-key summaries over a list of metric dicts (one per sample).
    Keys missing from some samples are summarized over the samples that
    have them; non-numeric values are skipped."""
    by_key: Dict[str, List[float]] = {}
    for sample in samples:
        for k, v in sample.items():
            if isinstance(v, bool) or not isinstance(v, (int, float)):
                continue
            by_key.setdefault(k, []).append(float(v))
    return {k: summarize(vs) for k, vs in by_key.items()}


def variance_fields(samples: Sequence[Mapping[str, float]]
                    ) -> Dict[str, Dict[str, float]]:
    """The compact ``{metric: {mean, cv, ci95, values}}`` mapping bench
    blocks embed in BENCH_serve.json so the regression gate (and the
    history log) can see measured dispersion, not just a point value."""
    return {k: {"mean": round(s.mean, 6), "cv": round(s.cv, 6),
                "ci95": round(s.ci95, 6),
                "values": [round(v, 6) for v in s.values]}
            for k, s in summarize_metrics(samples).items()}


def is_unstable(cv: Optional[float],
                threshold: float = UNSTABLE_CV) -> bool:
    """The gate's stability predicate: an unknown cv is treated as
    stable (legacy baselines without variance data keep gating)."""
    return cv is not None and float(cv) > threshold
