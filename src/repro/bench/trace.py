"""Workload models — seeded, replayable request traces for every tier.

The paper defends its completion-notification claims with message-rate
and latency microbenchmarks; this repo's serving claims need the same
discipline at the request level. A ``Trace`` is the unit of measurement:
a frozen, seeded sequence of (arrival time, prompt, generation config)
tuples that any serving tier can replay (``bench.runner``), so

* the SAME workload drives ``ServeEngine``, ``DisaggServer`` and
  ``Router`` — tier comparisons are apples-to-apples;
* reruns are deterministic at the trace level (same seed ⇒ byte-identical
  serialized trace), so run-to-run variance is *measurement* variance,
  never workload variance;
* a trace survives in a JSON artifact next to the numbers it produced.

Workload models (all driven by one ``random.Random(seed)`` — Python's
Mersenne Twister is stable across versions, so no numpy dependency in
the determinism contract):

* **arrival processes** — open-loop Poisson (exponential gaps at a
  target QPS), bursty on/off (geometric bursts at a high in-burst rate
  separated by exponential quiet gaps), and closed-loop (all arrivals at
  t=0; ``meta["closed_loop"]`` holds the concurrency the runner
  maintains).
* **length distributions** — heavy-tailed bounded Pareto for prompt and
  output lengths (the LLM-serving regime: many short, few very long).
* **shared-prefix mixtures** — N prefix groups, each with a common
  prompt prefix and per-request unique tails, so prefix caches and
  affinity routers see realistic hit structure.
* **multi-tenant / priority mixes** — weighted tenant and priority
  assignment per request (drives the router's fairness lanes and the
  strict priority classes).
"""
from __future__ import annotations

import dataclasses
import json
import random
from typing import Any, Dict, List, Mapping, Optional, Sequence, Tuple

TRACE_FORMAT_VERSION = 1


@dataclasses.dataclass(frozen=True)
class TraceRequest:
    """One request of a trace: when it arrives and what it asks for."""

    arrival_s: float                 # offset from trace start (0 = closed loop)
    prompt: Tuple[int, ...]          # token ids
    max_tokens: int
    tenant: str = "default"
    priority: int = 0
    deadline_s: Optional[float] = None
    prefix_group: Optional[int] = None   # which shared-prefix group (metadata)

    def to_dict(self) -> Dict[str, Any]:
        return {
            "arrival_s": round(float(self.arrival_s), 6),
            "prompt": list(self.prompt),
            "max_tokens": int(self.max_tokens),
            "tenant": self.tenant,
            "priority": int(self.priority),
            "deadline_s": (None if self.deadline_s is None
                           else round(float(self.deadline_s), 6)),
            "prefix_group": self.prefix_group,
        }

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "TraceRequest":
        return cls(arrival_s=float(d["arrival_s"]),
                   prompt=tuple(int(t) for t in d["prompt"]),
                   max_tokens=int(d["max_tokens"]),
                   tenant=d.get("tenant", "default"),
                   priority=int(d.get("priority", 0)),
                   deadline_s=(None if d.get("deadline_s") is None
                               else float(d["deadline_s"])),
                   prefix_group=d.get("prefix_group"))


@dataclasses.dataclass(frozen=True)
class Trace:
    """A frozen, replayable workload: requests plus generator metadata.

    ``meta`` records how the trace was made (generator name, seed,
    parameters) and the replay mode: ``meta["closed_loop"]`` is ``None``
    for open-loop traces (the runner paces arrivals) or an int
    concurrency for closed-loop traces (the runner keeps that many
    requests outstanding and ignores arrival times).
    """

    requests: Tuple[TraceRequest, ...]
    meta: Mapping[str, Any] = dataclasses.field(default_factory=dict)

    def __len__(self) -> int:
        return len(self.requests)

    def __iter__(self):
        return iter(self.requests)

    @property
    def name(self) -> str:
        return str(self.meta.get("name", "trace"))

    @property
    def closed_loop(self) -> Optional[int]:
        cl = self.meta.get("closed_loop")
        return None if cl is None else int(cl)

    @property
    def total_output_tokens(self) -> int:
        return sum(r.max_tokens for r in self.requests)

    @property
    def offered_qps(self) -> Optional[float]:
        """Offered arrival rate over the trace span (None: closed loop
        or a single-request trace, where a rate is meaningless)."""
        if self.closed_loop is not None or len(self.requests) < 2:
            return None
        span = self.requests[-1].arrival_s - self.requests[0].arrival_s
        if span <= 0.0:
            return None
        return (len(self.requests) - 1) / span

    # -------------------------------------------------------- serialization
    def to_json(self) -> str:
        """Canonical JSON — sorted keys, fixed separators, rounded floats
        — so equal traces serialize byte-identically (the determinism
        contract tests assert on)."""
        doc = {"format_version": TRACE_FORMAT_VERSION,
               "meta": dict(self.meta),
               "requests": [r.to_dict() for r in self.requests]}
        return json.dumps(doc, sort_keys=True, separators=(",", ":"))

    @classmethod
    def from_json(cls, text: str) -> "Trace":
        doc = json.loads(text)
        ver = doc.get("format_version")
        if ver != TRACE_FORMAT_VERSION:
            raise ValueError(f"unsupported trace format_version {ver!r} "
                             f"(this build reads {TRACE_FORMAT_VERSION})")
        return cls(requests=tuple(TraceRequest.from_dict(d)
                                  for d in doc["requests"]),
                   meta=doc.get("meta", {}))

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(self.to_json())

    @classmethod
    def load(cls, path: str) -> "Trace":
        with open(path) as f:
            return cls.from_json(f.read())


# ============================================================ arrival models
def poisson_arrivals(rng: random.Random, n: int,
                     rate_qps: float) -> List[float]:
    """Open-loop Poisson process: exponential inter-arrival gaps at
    ``rate_qps``; first arrival at t=0 so replay starts immediately."""
    if rate_qps <= 0:
        raise ValueError(f"rate_qps must be > 0, got {rate_qps}")
    t, out = 0.0, []
    for i in range(n):
        out.append(t)
        t += rng.expovariate(rate_qps)
    return out


def onoff_arrivals(rng: random.Random, n: int, *, burst_rate_qps: float,
                   mean_burst: float = 4.0,
                   mean_off_s: float = 0.2) -> List[float]:
    """Bursty on/off process: geometric-length bursts at
    ``burst_rate_qps`` separated by exponential quiet gaps of mean
    ``mean_off_s`` — the flash-crowd regime tail-latency SLOs exist for."""
    if burst_rate_qps <= 0 or mean_burst < 1.0 or mean_off_s <= 0:
        raise ValueError("onoff_arrivals needs burst_rate_qps > 0, "
                         "mean_burst >= 1, mean_off_s > 0")
    # geometric with mean ``mean_burst`` (support >= 1)
    p_stop = 1.0 / mean_burst
    t, out = 0.0, []
    while len(out) < n:
        out.append(t)
        if rng.random() < p_stop:        # burst ends: quiet gap
            t += rng.expovariate(1.0 / mean_off_s)
        else:                            # stay in burst: fast gap
            t += rng.expovariate(burst_rate_qps)
    return out


# ============================================================ length models
def bounded_pareto(rng: random.Random, *, alpha: float, lo: int,
                   hi: int) -> int:
    """Heavy-tailed integer draw in ``[lo, hi]`` — inverse-CDF sampling
    of a Pareto truncated at both ends. Small ``alpha`` (~1-1.5) gives
    the many-short/few-huge shape real prompt/output lengths follow."""
    if not (0 < lo <= hi):
        raise ValueError(f"need 0 < lo <= hi, got lo={lo} hi={hi}")
    if alpha <= 0:
        raise ValueError(f"alpha must be > 0, got {alpha}")
    if lo == hi:
        return lo
    u = rng.random()
    l_a, h_a = float(lo) ** -alpha, float(hi) ** -alpha
    x = (l_a - u * (l_a - h_a)) ** (-1.0 / alpha)
    return max(lo, min(hi, int(x)))


def _weighted_choice(rng: random.Random,
                     weights: Mapping[Any, float]) -> Any:
    keys = list(weights.keys())          # insertion order: deterministic
    total = float(sum(weights.values()))
    if total <= 0:
        raise ValueError("weights must sum to > 0")
    u = rng.random() * total
    acc = 0.0
    for k in keys:
        acc += float(weights[k])
        if u < acc:
            return k
    return keys[-1]


# ========================================================= trace generators
def synthetic_trace(n_requests: int, *, seed: int,
                    vocab_size: int = 512,
                    arrival: str = "poisson",
                    rate_qps: float = 50.0,
                    mean_burst: float = 4.0,
                    mean_off_s: float = 0.2,
                    closed_loop: Optional[int] = None,
                    prompt_len: Tuple[int, int] = (8, 24),
                    prompt_alpha: float = 1.5,
                    output_len: Tuple[int, int] = (4, 24),
                    output_alpha: float = 1.2,
                    n_prefix_groups: int = 0,
                    shared_len: int = 0,
                    tenants: Optional[Mapping[str, float]] = None,
                    priorities: Optional[Mapping[int, float]] = None,
                    deadline_s: Optional[float] = None,
                    name: str = "synthetic") -> Trace:
    """The one-stop seeded generator composing every workload model.

    * ``arrival``: ``"poisson"`` | ``"onoff"`` | ``"closed"`` (with
      ``closed_loop`` concurrency; also selected implicitly whenever
      ``closed_loop`` is given).
    * ``prompt_len`` / ``output_len``: inclusive ``(lo, hi)`` bounds of
      the bounded-Pareto length draws (``*_alpha`` sets tail weight).
    * ``n_prefix_groups`` + ``shared_len``: shared-prefix mixture — each
      request joins a uniformly drawn group whose first ``shared_len``
      prompt tokens are common; ``0`` disables (fully unique prompts).
    * ``tenants`` / ``priorities``: weighted mixes (default: single
      tenant ``"default"``, priority 0).
    * ``deadline_s``: per-request QoS deadline stamped on every request
      (``None``: no deadlines — goodput equals throughput).

    Same arguments + same seed ⇒ byte-identical ``Trace.to_json()``.
    """
    if n_requests < 1:
        raise ValueError(f"n_requests must be >= 1, got {n_requests}")
    if n_prefix_groups > 0 and not (0 < shared_len <= prompt_len[0]):
        raise ValueError(
            f"shared_len must be in (0, min prompt_len] when prefix "
            f"groups are on, got shared_len={shared_len} "
            f"prompt_len={prompt_len}")
    rng = random.Random(seed)
    if closed_loop is not None:
        arrival = "closed"
    if arrival == "poisson":
        arrivals = poisson_arrivals(rng, n_requests, rate_qps)
    elif arrival == "onoff":
        arrivals = onoff_arrivals(rng, n_requests,
                                  burst_rate_qps=rate_qps,
                                  mean_burst=mean_burst,
                                  mean_off_s=mean_off_s)
    elif arrival == "closed":
        if closed_loop is None or int(closed_loop) < 1:
            raise ValueError("closed-loop traces need closed_loop >= 1")
        arrivals = [0.0] * n_requests
    else:
        raise ValueError(f"unknown arrival model {arrival!r}")

    # shared-prefix groups: the group prefixes are drawn FIRST (before
    # per-request randomness) so trimming n_requests never changes them
    prefixes: List[Tuple[int, ...]] = []
    for _ in range(max(0, n_prefix_groups)):
        prefixes.append(tuple(rng.randrange(vocab_size)
                              for _ in range(shared_len)))

    tenants = tenants or {"default": 1.0}
    priorities = priorities or {0: 1.0}
    reqs: List[TraceRequest] = []
    for i in range(n_requests):
        plen = bounded_pareto(rng, alpha=prompt_alpha,
                              lo=prompt_len[0], hi=prompt_len[1])
        olen = bounded_pareto(rng, alpha=output_alpha,
                              lo=output_len[0], hi=output_len[1])
        group: Optional[int] = None
        if prefixes:
            group = rng.randrange(len(prefixes))
            tail = tuple(rng.randrange(vocab_size)
                         for _ in range(plen - shared_len))
            prompt = prefixes[group] + tail
        else:
            prompt = tuple(rng.randrange(vocab_size) for _ in range(plen))
        reqs.append(TraceRequest(
            arrival_s=arrivals[i], prompt=prompt, max_tokens=olen,
            tenant=str(_weighted_choice(rng, tenants)),
            priority=int(_weighted_choice(rng, priorities)),
            deadline_s=deadline_s, prefix_group=group))

    meta = {"name": name, "seed": seed, "generator": "synthetic_trace",
            "arrival": arrival, "rate_qps": rate_qps,
            "closed_loop": closed_loop, "vocab_size": vocab_size,
            "prompt_len": list(prompt_len), "output_len": list(output_len),
            "n_prefix_groups": n_prefix_groups, "shared_len": shared_len,
            "deadline_s": deadline_s}
    return Trace(requests=tuple(reqs), meta=meta)


def rescale_qps(trace: Trace, target_qps: float) -> Trace:
    """The same requests at a different offered rate: arrival offsets are
    scaled uniformly so the trace's offered QPS becomes ``target_qps``.
    Prompt content, ordering, lengths and configs are untouched — this is
    how the saturation sweep probes one workload across load levels
    without re-rolling its randomness."""
    if target_qps <= 0:
        raise ValueError(f"target_qps must be > 0, got {target_qps}")
    cur = trace.offered_qps
    if cur is None:
        raise ValueError("rescale_qps needs an open-loop trace with a "
                         "measurable rate (>= 2 spread-out arrivals)")
    scale = cur / target_qps
    reqs = tuple(dataclasses.replace(r, arrival_s=r.arrival_s * scale)
                 for r in trace.requests)
    meta = dict(trace.meta)
    meta["rate_qps"] = target_qps
    meta["rescaled_from_qps"] = cur
    return Trace(requests=reqs, meta=meta)


def micro_trace(seed: int = 0, *, n_requests: int = 4,
                vocab_size: int = 512, max_tokens: int = 4,
                prompt_len: int = 8, rate_qps: float = 200.0,
                **kwargs: Any) -> Trace:
    """A seconds-not-minutes trace for CI and unit tests: few requests,
    short prompts, tiny budgets, fast arrivals."""
    return synthetic_trace(
        n_requests, seed=seed, vocab_size=vocab_size, rate_qps=rate_qps,
        prompt_len=(prompt_len, prompt_len),
        output_len=(max_tokens, max_tokens),
        name=kwargs.pop("name", "micro"), **kwargs)
