"""Trace replay against any serving tier — the measurement loop.

``Replayer`` binds a ``Trace`` (``bench.trace``) to anything satisfying
``serve.protocol.EngineLike`` — the colocated ``ServeEngine``, the
disaggregated ``DisaggServer``, or the multi-replica ``Router`` —
through the ``ServeClient`` streaming surface, so the measured path is
the one applications actually use (admission, per-token continuation
delivery, stream publication), not a bench-only shortcut.

Per request it records:

* **TTFT** — arrival (the paced ``session.generate`` call) to first
  delivered token (``Request.ttft``).
* **inter-token latencies** — gaps between ``Request.token_times``
  entries, stamped in the engine's step-completion continuations at the
  instant each token batch is committed/stream-published. Tokens
  accepted together (one speculative verify step) share a stamp: their
  gap is honestly zero.
* **completion status** — finished / expired / cancelled / refused
  (``QuotaExceeded`` at admission), and whether the deadline was met.

Replay modes follow the trace: open-loop traces are paced by arrival
offset on the submitting thread (late submissions — the engine running
slower than the trace — are submitted immediately and the lag is the
measured queueing delay, exactly like an open-loop client); closed-loop
traces keep ``trace.closed_loop`` requests outstanding from worker
threads.

Multi-sample runs (``samples=``) replay the same trace repeatedly on the
same (warm) tier — run-to-run dispersion is then measurement noise, not
workload noise, and feeds ``bench.stats`` / ``bench.report``.
"""
from __future__ import annotations

import dataclasses
import random
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Union

from repro.bench.stats import percentile
from repro.bench.trace import Trace, TraceRequest
from repro.obs.recorder import Recorder
from repro.serve.api import ServeClient
from repro.serve.config import GenerationConfig, QuotaExceeded
from repro.serve.protocol import EngineLike
from repro.serve.request import Request


@dataclasses.dataclass
class RequestRecord:
    """Measured outcome of one replayed trace request."""

    index: int                        # position in the trace
    tenant: str
    priority: int
    status: str                       # finished|expired|cancelled|refused
    arrival_s: float                  # offset from sample start (actual)
    ttft_s: Optional[float] = None
    latency_s: Optional[float] = None
    n_tokens: int = 0
    itl_s: List[float] = dataclasses.field(default_factory=list)
    deadline_s: Optional[float] = None
    deadline_met: Optional[bool] = None   # None: no deadline configured

    @property
    def finished(self) -> bool:
        return self.status == "finished"

    @property
    def good(self) -> bool:
        """Counts toward goodput: finished AND met its deadline (a
        request without a deadline only needs to finish)."""
        return self.finished and self.deadline_met is not False


@dataclasses.dataclass
class RunResult:
    """One replay sample: per-request records plus derived SLO metrics."""

    trace_name: str
    tier: str
    sample: int
    duration_s: float
    records: List[RequestRecord]
    closed_loop: Optional[int] = None
    engine_metrics: Dict[str, Any] = dataclasses.field(default_factory=dict)

    # ------------------------------------------------------------ aggregates
    @property
    def ttfts(self) -> List[float]:
        return [r.ttft_s for r in self.records if r.ttft_s is not None]

    @property
    def itls(self) -> List[float]:
        return [g for r in self.records for g in r.itl_s]

    @property
    def tokens_delivered(self) -> int:
        return sum(r.n_tokens for r in self.records)

    def count(self, status: str) -> int:
        return sum(1 for r in self.records if r.status == status)

    def metrics(self) -> Dict[str, float]:
        """The flat headline-metric dict ``bench.report``/``bench.stats``
        summarize across samples."""
        n = len(self.records)
        dur = max(self.duration_s, 1e-9)
        good = [r for r in self.records if r.good]
        good_tokens = sum(r.n_tokens for r in good)
        ttfts, itls = self.ttfts, self.itls
        with_deadline = [r for r in self.records
                         if r.deadline_met is not None]
        out = {
            "makespan_s": self.duration_s,
            "tokens_per_s": self.tokens_delivered / dur,
            "goodput_tokens_per_s": good_tokens / dur,
            "goodput_requests_per_s": len(good) / dur,
            "finished_frac": self.count("finished") / n if n else 0.0,
            "expired": float(self.count("expired")),
            "refused": float(self.count("refused")),
            "ttft_mean_s": sum(ttfts) / len(ttfts) if ttfts else 0.0,
            "ttft_p50_s": percentile(ttfts, 0.50),
            "ttft_p99_s": percentile(ttfts, 0.99),
            "ttft_p999_s": percentile(ttfts, 0.999),
            "itl_p50_s": percentile(itls, 0.50),
            "itl_p99_s": percentile(itls, 0.99),
            "itl_p999_s": percentile(itls, 0.999),
        }
        if with_deadline:
            out["deadline_met_frac"] = (
                sum(1 for r in with_deadline if r.deadline_met)
                / len(with_deadline))
        return out


def _config_for(entry: TraceRequest) -> GenerationConfig:
    return GenerationConfig(max_tokens=entry.max_tokens,
                            tenant=entry.tenant,
                            priority=entry.priority,
                            deadline_s=entry.deadline_s)


def _record(index: int, entry: TraceRequest, req: Optional[Request],
            t0: float) -> RequestRecord:
    if req is None:                      # refused at admission (quota)
        return RequestRecord(index=index, tenant=entry.tenant,
                             priority=entry.priority, status="refused",
                             arrival_s=entry.arrival_s,
                             deadline_s=entry.deadline_s)
    times = list(req.token_times)
    rec = RequestRecord(
        index=index, tenant=entry.tenant, priority=entry.priority,
        status=req.req_state.value,
        arrival_s=req.arrival_time - t0,
        ttft_s=req.ttft,
        latency_s=req.latency,
        n_tokens=len(times),
        itl_s=[b - a for a, b in zip(times, times[1:])],
        deadline_s=entry.deadline_s)
    if entry.deadline_s is not None:
        rec.deadline_met = (req.req_state.value == "finished"
                            and req.finish_time is not None
                            and req.finish_time
                            <= req.arrival_time + entry.deadline_s)
    return rec


class Replayer:
    """Owns a ``ServeClient`` over one tier and replays traces at it.

    ``tier`` is an ``EngineLike`` instance or a zero-arg factory; either
    way the Replayer owns the resulting tier and ``close()`` shuts it
    down (``with Replayer(...) as rp:`` is the usual shape). One
    Replayer can run many traces/samples back-to-back on the same warm
    tier — that is the point: compile warmup happens once, and every
    sample after it measures the serving path, not XLA.
    """

    def __init__(self, tier: Union[EngineLike, Callable[[], EngineLike]],
                 *, name: Optional[str] = None,
                 recorder: Optional[Recorder] = None) -> None:
        engine = tier() if callable(tier) and not isinstance(
            tier, EngineLike) else tier
        self.client = ServeClient(engine=engine)
        self.tier_name = name or type(engine).__name__
        #: optional ``obs.Recorder``: measured samples run traced (warmup
        #: and the throwaway replay stay untraced), accumulating request
        #: timelines + lifecycle histograms for SLO cause attribution
        self.recorder = recorder
        self._warmed = False

    # ------------------------------------------------------------------ runs
    def run(self, trace: Trace, *, samples: int = 1,
            warmup: Optional[int] = 2,
            timeout: float = 300.0) -> List[RunResult]:
        """Replay ``trace`` ``samples`` times; one ``RunResult`` each.

        ``warmup``: how many untimed throwaway requests to run before the
        first sample (compile warming for prefill/decode/suffix shapes);
        ``None``/``0`` skips. Warm prompts are drawn from a seed-derived
        stream disjoint from the trace ordering, and their pages are
        released before measurement starts.
        """
        if samples < 1:
            raise ValueError(f"samples must be >= 1, got {samples}")
        if warmup and not self._warmed:
            self._run_warmup(trace, int(warmup), timeout)
            # one untimed throwaway replay: host-side eager ops whose
            # shapes depend on scheduling coincidence (e.g. page-table
            # scatters sized by how many requests admit in one tick)
            # compile on the pattern the trace actually produces, not
            # inside the first measured sample
            self._run_once(trace, -1, timeout)
            self._warmed = True
        if self.recorder is not None:
            # trace only the measured window: warmup and the throwaway
            # replay above ran with tracing off
            self.recorder.start()
        try:
            return [self._run_once(trace, i, timeout)
                    for i in range(samples)]
        finally:
            if self.recorder is not None:
                self.recorder.stop()

    def _run_warmup(self, trace: Trace, n: int, timeout: float) -> None:
        # cover every distinct prompt-length *shape* the trace will hit
        # (each length is a separate XLA compile), then pad to n with the
        # most common one, so measured samples time serving, not XLA
        vocab = int(trace.meta.get("vocab_size", 512))
        plens = sorted({len(r.prompt) for r in trace.requests}) or [8]
        rng = random.Random(int(trace.meta.get("seed", 0)) ^ 0x5EED)
        session = self.client.session()
        reqs = []
        # run warm requests as long as the longest trace request: paths
        # that only trigger deep into decode (e.g. allocating KV pages
        # past the prefill footprint) must compile now, not mid-sample
        warm_tokens = max([2] + [r.max_tokens for r in trace.requests])

        def warm(prompt: List[int]) -> None:
            reqs.append(session.generate(prompt, GenerationConfig(
                max_tokens=warm_tokens)).request)

        for i in range(max(n, len(plens))):
            plen = plens[i % len(plens)]
            warm([rng.randrange(vocab) for _ in range(plen)])
        # shared-prefix traces also hit the chunked suffix-prefill path
        # (a different compiled shape per (plen, shared_len)): warm it
        # with an adjacent pair sharing a prefix disjoint from the trace
        shared = int(trace.meta.get("shared_len") or 0)
        if shared > 0:
            for plen in plens:
                if plen <= shared:
                    continue
                base = [rng.randrange(vocab) for _ in range(plen)]
                tail = [rng.randrange(vocab)
                        for _ in range(plen - shared)]
                warm(base)
                warm(base[:shared] + tail)
        for r in reqs:
            r.wait(timeout=timeout)

    def _run_once(self, trace: Trace, sample: int,
                  timeout: float) -> RunResult:
        if trace.closed_loop is not None:
            return self._run_closed(trace, sample, timeout)
        return self._run_open(trace, sample, timeout)

    def _run_open(self, trace: Trace, sample: int,
                  timeout: float) -> RunResult:
        session = self.client.session()
        submitted: List[Optional[Request]] = [None] * len(trace.requests)
        t0 = time.monotonic()
        for i, entry in enumerate(trace.requests):
            lag = entry.arrival_s - (time.monotonic() - t0)
            if lag > 0:
                time.sleep(lag)
            try:
                stream = session.generate(list(entry.prompt),
                                          _config_for(entry))
                submitted[i] = stream.request
            except QuotaExceeded:
                submitted[i] = None
        return self._collect(trace, sample, submitted, t0, timeout)

    def _run_closed(self, trace: Trace, sample: int,
                    timeout: float) -> RunResult:
        session = self.client.session()
        submitted: List[Optional[Request]] = [None] * len(trace.requests)
        it = iter(range(len(trace.requests)))
        lock = threading.Lock()
        t0 = time.monotonic()

        def worker() -> None:
            while True:
                with lock:
                    i = next(it, None)
                if i is None:
                    return
                entry = trace.requests[i]
                try:
                    stream = session.generate(list(entry.prompt),
                                              _config_for(entry))
                    submitted[i] = stream.request
                except QuotaExceeded:
                    submitted[i] = None
                    continue
                # closed loop: hold this lane until the request retires
                submitted[i].wait(timeout=timeout)

        n_workers = min(trace.closed_loop or 1, len(trace.requests))
        threads = [threading.Thread(target=worker, daemon=True)
                   for _ in range(n_workers)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=timeout + 10.0)
        return self._collect(trace, sample, submitted, t0, timeout)

    def _collect(self, trace: Trace, sample: int,
                 submitted: Sequence[Optional[Request]], t0: float,
                 timeout: float) -> RunResult:
        deadline = time.monotonic() + timeout
        for req in submitted:
            if req is None:
                continue
            if not req.wait(timeout=max(0.0, deadline - time.monotonic())):
                req.cancel()             # sample overran: fail it visibly
        records = [_record(i, entry, req, t0)
                   for i, (entry, req)
                   in enumerate(zip(trace.requests, submitted))]
        finish = [req.finish_time for req in submitted
                  if req is not None and req.finish_time is not None]
        duration = (max(finish) - t0) if finish \
            else (time.monotonic() - t0)
        return RunResult(trace_name=trace.name, tier=self.tier_name,
                         sample=sample, duration_s=duration,
                         records=records, closed_loop=trace.closed_loop,
                         engine_metrics=self._metrics_snapshot())

    def _metrics_snapshot(self) -> Dict[str, Any]:
        """JSON-safe scalar slice of the tier's metrics() mapping."""
        out = {}
        for k, v in dict(self.client.metrics()).items():
            if isinstance(v, (bool, int, float, str)):
                out[k] = v
        return out

    # ------------------------------------------------------------- lifecycle
    def close(self) -> None:
        self.client.close()

    def __enter__(self) -> "Replayer":
        return self

    def __exit__(self, *exc: Any) -> None:
        self.close()


def replay(tier: Union[EngineLike, Callable[[], EngineLike]],
           trace: Trace, *, samples: int = 1, warmup: Optional[int] = 2,
           timeout: float = 300.0, name: Optional[str] = None,
           recorder: Optional[Recorder] = None) -> List[RunResult]:
    """One-shot convenience: build a ``Replayer`` over ``tier``, replay
    ``trace`` ``samples`` times, shut the tier down, return the results.
    Keep a ``Replayer`` instead when the tier should stay warm across
    traces (the saturation sweep does)."""
    with Replayer(tier, name=name, recorder=recorder) as rp:
        return rp.run(trace, samples=samples, warmup=warmup,
                      timeout=timeout)
