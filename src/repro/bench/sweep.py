"""Saturation sweep — the max sustainable QPS where an SLO still holds.

A throughput number without a latency bound is marketing; the defensible
form of "how fast is this config" is *the highest offered load at which
the SLO is still met*. ``saturation_sweep`` binary-searches that
boundary over any monotone-ish evaluate function; ``sweep_tier`` builds
the evaluate from the real pipeline — rescale one seeded trace to the
probe QPS (same prompts, same ordering: only the arrival clock changes),
replay it on a warm ``Replayer``, and ask ``bench.report`` whether the
SLO held.

The search contract:

* SLO fails at ``lo_qps``  → ``max_qps`` is ``None`` (the config cannot
  meet the SLO at any probed load; the lo point is in ``points``).
* SLO holds at ``hi_qps`` → ``max_qps == hi_qps`` (saturation is beyond
  the probed range — widen it).
* otherwise ``iters`` bisection steps between the known-good and
  known-bad loads; ``max_qps`` is the highest passing probe.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.bench.report import SLO, slo_report
from repro.bench.runner import Replayer
from repro.bench.trace import Trace, rescale_qps

# evaluate(qps) -> (slo_ok, info-dict)
Evaluate = Callable[[float], Tuple[bool, Dict[str, Any]]]


@dataclasses.dataclass(frozen=True)
class SweepPoint:
    qps: float
    ok: bool
    info: Dict[str, Any] = dataclasses.field(default_factory=dict)


@dataclasses.dataclass(frozen=True)
class SweepResult:
    max_qps: Optional[float]          # None: SLO unmet even at lo_qps
    lo_qps: float
    hi_qps: float
    points: Tuple[SweepPoint, ...]
    saturated_range: bool = False     # True: SLO held all the way to hi

    def to_dict(self) -> Dict[str, Any]:
        points = []
        for p in self.points:
            d: Dict[str, Any] = {"qps": round(p.qps, 4), "ok": p.ok}
            # keep enough of the probe report to see WHY it failed
            # (worst value per violated bound) without embedding the
            # full per-sample report in every artifact
            for v in p.info.get("slo", {}).get("violations", []):
                d.setdefault("violations", []).append(
                    {k: v[k] for k in ("metric", "bound", "worst")
                     if k in v})
            points.append(d)
        return {"max_sustainable_qps": self.max_qps,
                "lo_qps": self.lo_qps, "hi_qps": self.hi_qps,
                "saturated_range": self.saturated_range,
                "points": points}


def saturation_sweep(evaluate: Evaluate, *, lo_qps: float, hi_qps: float,
                     iters: int = 4) -> SweepResult:
    """Binary-search the pass/fail boundary of ``evaluate`` over
    ``[lo_qps, hi_qps]`` (see module docstring for the edge contract)."""
    if not (0 < lo_qps < hi_qps):
        raise ValueError(f"need 0 < lo_qps < hi_qps, got "
                         f"lo={lo_qps} hi={hi_qps}")
    if iters < 0:
        raise ValueError(f"iters must be >= 0, got {iters}")
    points: List[SweepPoint] = []

    def probe(qps: float) -> bool:
        ok, info = evaluate(qps)
        points.append(SweepPoint(qps=qps, ok=bool(ok), info=info))
        return bool(ok)

    if not probe(lo_qps):
        return SweepResult(max_qps=None, lo_qps=lo_qps, hi_qps=hi_qps,
                           points=tuple(points))
    if probe(hi_qps):
        return SweepResult(max_qps=hi_qps, lo_qps=lo_qps, hi_qps=hi_qps,
                           points=tuple(points), saturated_range=True)
    good, bad = lo_qps, hi_qps
    for _ in range(iters):
        mid = (good + bad) / 2.0
        if probe(mid):
            good = mid
        else:
            bad = mid
    return SweepResult(max_qps=good, lo_qps=lo_qps, hi_qps=hi_qps,
                       points=tuple(points))


def sweep_tier(replayer: Replayer, trace: Trace, slo: SLO, *,
               lo_qps: float, hi_qps: float, iters: int = 4,
               samples: int = 1, retries: int = 1,
               timeout: float = 300.0) -> SweepResult:
    """Find the max sustainable QPS of ``replayer``'s tier on ``trace``
    under ``slo``. The trace must be open-loop (rescaling a closed-loop
    trace is meaningless); each probe replays the SAME requests at the
    probe rate, so the boundary is a property of load, not workload.

    ``retries``: a FAILED probe is re-run up to this many times and
    passes if any attempt meets the SLO. A false "pass" costs one wasted
    bisection step; a false "fail" (one ambient-load straggler blowing a
    tail bound) is sticky — it permanently caps the reported boundary —
    so failures must be confirmed, not taken on first sight."""

    def evaluate(qps: float) -> Tuple[bool, Dict[str, Any]]:
        probe_trace = rescale_qps(trace, qps)
        report: Dict[str, Any] = {}
        for _attempt in range(1 + max(0, retries)):
            results = replayer.run(probe_trace, samples=samples,
                                   timeout=timeout)
            report = slo_report(results, slo)
            if report["slo"]["ok"]:
                break
        return report["slo"]["ok"], report

    return saturation_sweep(evaluate, lo_qps=lo_qps, hi_qps=hi_qps,
                            iters=iters)
