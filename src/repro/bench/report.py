"""SLO evaluation and reporting over replayed samples.

An ``SLO`` is the service contract a config is measured against —
tail-latency ceilings (p99/p99.9 TTFT and inter-token latency) and
goodput floors (tokens per second from requests that finished within
deadline). ``slo_report`` folds one or more ``RunResult`` samples into a
JSON-safe report: per-metric mean / CI / coefficient-of-variation via
``bench.stats``, plus a pass/fail verdict per SLO bound. The saturation
sweep (``bench.sweep``) asks exactly one question of this module —
"does the SLO hold at this load?" — and the markdown renderer feeds CI
job summaries.

Verdicts are evaluated on the per-sample **worst** value, not the mean:
an SLO is a ceiling, and a config that blows p99.9 every third run does
not meet it. (The mean/cv still appear in the report for trend-reading.)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Tuple

from repro.bench.runner import RunResult
from repro.bench.stats import variance_fields

# (slo_field, metric_key, kind): ceilings bound the metric from above,
# floors from below
_BOUNDS: Tuple[Tuple[str, str, str], ...] = (
    ("ttft_p50_s", "ttft_p50_s", "ceiling"),
    ("ttft_p99_s", "ttft_p99_s", "ceiling"),
    ("ttft_p999_s", "ttft_p999_s", "ceiling"),
    ("itl_p99_s", "itl_p99_s", "ceiling"),
    ("itl_p999_s", "itl_p999_s", "ceiling"),
    ("min_goodput_tokens_per_s", "goodput_tokens_per_s", "floor"),
    ("min_finished_frac", "finished_frac", "floor"),
    ("min_deadline_met_frac", "deadline_met_frac", "floor"),
)


@dataclasses.dataclass(frozen=True)
class SLO:
    """Service-level objective: unset fields are unchecked."""

    ttft_p50_s: Optional[float] = None
    ttft_p99_s: Optional[float] = None
    ttft_p999_s: Optional[float] = None
    itl_p99_s: Optional[float] = None
    itl_p999_s: Optional[float] = None
    min_goodput_tokens_per_s: Optional[float] = None
    min_finished_frac: Optional[float] = None
    min_deadline_met_frac: Optional[float] = None

    def to_dict(self) -> Dict[str, float]:
        return {f.name: getattr(self, f.name)
                for f in dataclasses.fields(self)
                if getattr(self, f.name) is not None}


def slo_report(results: Sequence[RunResult],
               slo: Optional[SLO] = None) -> Dict[str, Any]:
    """Fold replay samples into one report dict.

    ``metrics`` carries ``{name: {mean, cv, ci95, values}}`` over the
    samples; ``slo`` (when given) carries the verdict: ``ok`` plus a
    violation list of ``{metric, bound, kind, worst}``.
    """
    if not results:
        raise ValueError("slo_report needs at least one RunResult")
    samples = [r.metrics() for r in results]
    report: Dict[str, Any] = {
        "tier": results[0].tier,
        "trace": results[0].trace_name,
        "samples": len(results),
        "requests": len(results[0].records),
        "metrics": variance_fields(samples),
    }
    if slo is not None:
        violations: List[Dict[str, Any]] = []
        for field, key, kind in _BOUNDS:
            bound = getattr(slo, field)
            if bound is None:
                continue
            vals = [s[key] for s in samples if key in s]
            if not vals:
                violations.append({"metric": key, "bound": bound,
                                   "kind": kind, "worst": None,
                                   "reason": "metric not measured"})
                continue
            worst = max(vals) if kind == "ceiling" else min(vals)
            ok = worst <= bound if kind == "ceiling" else worst >= bound
            if not ok:
                violations.append({"metric": key, "bound": bound,
                                   "kind": kind,
                                   "worst": round(worst, 6)})
        report["slo"] = {"ok": not violations,
                         "checked": slo.to_dict(),
                         "violations": violations}
    return report


def to_markdown(report: Dict[str, Any]) -> str:
    """Render one report as a compact markdown table (CI job summaries)."""
    lines = [f"#### {report['tier']} · trace `{report['trace']}` · "
             f"{report['samples']} sample(s), {report['requests']} requests",
             "", "| metric | mean | cv | ci95 |", "| --- | ---: | ---: | ---: |"]
    for name, s in sorted(report["metrics"].items()):
        lines.append(f"| {name} | {s['mean']:.4g} | {s['cv']:.3f} "
                     f"| ±{s['ci95']:.4g} |")
    if "slo" in report:
        verdict = "✅ SLO holds" if report["slo"]["ok"] else "❌ SLO violated"
        lines += ["", verdict]
        for v in report["slo"]["violations"]:
            worst = "n/a" if v.get("worst") is None else f"{v['worst']:.4g}"
            op = "<=" if v["kind"] == "ceiling" else ">="
            lines.append(f"- `{v['metric']}` worst {worst} "
                         f"(needs {op} {v['bound']:.4g})")
    return "\n".join(lines)
