"""Mini distributed dataflow runtime — the PaRSEC analogue (paper §5.3).

Tasks form a DAG over *tiles* owned by ranks; completing a task activates
remote successors through the paper's Fig.-4 message pattern:

    owner ──AM activate──▶ successor rank
    successor ──AM get───▶ owner          (emulated one-sided get)
    owner ──tile data────▶ successor

Each rank runs a single loop interleaving task execution and communication
progress (the PaRSEC communication-thread role). Completion notification is
pluggable, mirroring §5.3.1:

* ``TestsomeBackend``      — reference: pending/active request window walked
  by ``MPI_Testsome`` (completion of fresh requests invisible until
  promoted; the delay artifact the paper eliminates).
* ``ContinuationBackend``  — per-message-class CRs: *activation AMs* on a
  ``poll_only + enqueue_complete`` CR (heavy callbacks deferred to the comm
  loop, bursts queued — exactly the info-key usage the paper describes),
  data sends/recvs eligible for immediate execution.
"""
from __future__ import annotations

import threading
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import (ANY_SOURCE, Engine, Status, TestsomeManager,
                        Transport)

AM_ACTIVATE = 6001
AM_GET = 6002
DATA_TAG = 6003


class DataflowTask:
    __slots__ = ("task_id", "fn", "inputs", "output", "owner", "successors",
                 "n_deps")

    def __init__(self, task_id: str, fn: Callable, inputs: Sequence[str],
                 output: str, owner: int) -> None:
        self.task_id = task_id
        self.fn = fn                  # (dict tile_name->array) -> array
        self.inputs = list(inputs)    # tile names (versioned)
        self.output = output          # tile name it produces
        self.owner = owner
        self.successors: List[str] = []
        self.n_deps = 0


class DataflowGraph:
    """DAG builder: tasks reading/writing versioned tiles."""

    def __init__(self, n_ranks: int) -> None:
        self.n_ranks = n_ranks
        self.tasks: Dict[str, DataflowTask] = {}
        self.producers: Dict[str, str] = {}     # tile -> producing task
        self.initial_tiles: Dict[str, np.ndarray] = {}
        self.tile_owner: Dict[str, int] = {}

    def add_tile(self, name: str, value: np.ndarray, owner: int) -> None:
        self.initial_tiles[name] = value
        self.tile_owner[name] = owner

    def add_task(self, task_id: str, fn: Callable, inputs: Sequence[str],
                 output: str, owner: int) -> None:
        t = DataflowTask(task_id, fn, inputs, output, owner)
        self.tasks[task_id] = t
        self.producers[output] = task_id
        self.tile_owner[output] = owner

    def finalize(self) -> None:
        for t in self.tasks.values():
            for tile in t.inputs:
                prod = self.producers.get(tile)
                if prod is not None:
                    self.tasks[prod].successors.append(t.task_id)
                    t.n_deps += 1


# ------------------------------------------------------------------ backends
class ContinuationBackend:
    """Per-class CRs with the paper's §5.3.1 info-key configuration."""

    name = "continuations"

    def __init__(self, engine: Engine) -> None:
        self.engine = engine
        # activation AMs: heavy callbacks → poll_only; bursts → enqueue
        self.cr_am = engine.continue_init({
            "mpi_continue_poll_only": True,
            "mpi_continue_enqueue_complete": True,
        })
        # data movement: short callbacks, immediate execution allowed
        self.cr_data = engine.continue_init(
            {"mpi_continue_enqueue_complete": True})

    def submit_am(self, op, cb, data=None):
        self.engine.continue_when(op, cb, data, status=[None], cr=self.cr_am)

    def submit_data(self, op, cb, data=None):
        self.engine.continue_when(op, cb, data, status=[None],
                                  cr=self.cr_data)

    def progress(self):
        self.cr_am.test()
        self.cr_data.test()


class TestsomeBackend:
    """Reference PaRSEC layout (Fig. 5): persistent AM receives are always
    part of the tested set; only *data* requests go through the bounded
    pending→active window (whose promotion delay is the measured artifact —
    an unbounded shared window would deadlock on never-completing AM posts,
    a bounded shared one starves; the split is what PaRSEC actually does)."""

    name = "testsome"
    __test__ = False     # keep pytest from collecting this backend class

    def __init__(self, window: int = 8) -> None:
        self.am_manager = TestsomeManager(window=1 << 30)
        self.data_manager = TestsomeManager(window=window)

    def submit_am(self, op, cb, data=None):
        self.am_manager.submit([op], cb, data, want_statuses=True)

    def submit_data(self, op, cb, data=None):
        self.data_manager.submit([op], cb, data, want_statuses=True)

    def progress(self):
        self.am_manager.testsome()
        self.data_manager.testsome()


class DataflowRank:
    """One rank: task queue + comm handling (Fig. 4/5 protocol)."""

    def __init__(self, rank: int, graph: DataflowGraph, transport: Transport,
                 backend, prepost_ams: int = 8) -> None:
        self.rank = rank
        self.graph = graph
        self.transport = transport
        self.backend = backend
        self.tiles: Dict[str, np.ndarray] = {
            k: v.copy() for k, v in graph.initial_tiles.items()
            if graph.tile_owner[k] == rank}
        self.deps_left: Dict[str, int] = {
            t.task_id: t.n_deps for t in graph.tasks.values()
            if t.owner == rank}
        self.ready: List[str] = [t for t, n in self.deps_left.items()
                                 if n == 0]
        self.requested: set = set()
        self.waiting: set = set()        # tasks blocked on in-flight tiles
        self.pending_gets: Dict[str, List[int]] = {}   # tile -> requesters
        self.done_tasks: set = set()
        self._lock = threading.Lock()
        self.stats = {"executed": 0, "am_sent": 0, "data_sent": 0,
                      "activation_latency": []}
        for _ in range(prepost_ams):
            self._post_am_recv()
            self._post_get_recv()

    # --------------------------------------------------------------- comms
    def _post_am_recv(self) -> None:
        op = self.transport.irecv(self.rank, source=ANY_SOURCE,
                                  tag=AM_ACTIVATE)
        self.backend.submit_am(op, self._on_activate)

    def _post_get_recv(self) -> None:
        op = self.transport.irecv(self.rank, source=ANY_SOURCE, tag=AM_GET)
        self.backend.submit_am(op, self._on_get)

    def _on_activate(self, statuses, _):
        st: Status = statuses[0]
        if st.test_cancelled():
            return
        succ_id, tile, t_sent = st.payload
        self.stats["activation_latency"].append(time.monotonic() - t_sent)
        self._post_am_recv()                       # re-arm
        self._ensure_tile(succ_id, tile, count_dep=True)

    def _ensure_tile(self, succ_id: str, tile: str, count_dep: bool) -> None:
        """Fetch a remote tile (idempotent per (succ, tile)). If it is
        already local and this call carries a dependency edge, satisfy it."""
        with self._lock:
            if tile in self.tiles:
                if count_dep:
                    self._dep_satisfied_locked(succ_id)
                return
            if (succ_id, tile) in self.requested:
                return                              # data already in flight
            self.requested.add((succ_id, tile))
        owner = self.graph.tile_owner[tile]
        recv = self.transport.irecv(self.rank, source=owner, tag=DATA_TAG)
        self.backend.submit_data(recv, self._on_tile_data,
                                 (succ_id, tile, count_dep))
        self.transport.isend(self.rank, owner, AM_GET, (tile, self.rank))

    def _on_get(self, statuses, _):
        st: Status = statuses[0]
        if st.test_cancelled():
            return
        tile, requester = st.payload
        self._post_get_recv()
        with self._lock:
            if tile not in self.tiles:
                # requested ahead of production (an early-ready consumer):
                # served from _complete_task when the producer finishes
                self.pending_gets.setdefault(tile, []).append(requester)
                return
            payload = self.tiles[tile]
        self.transport.isend(self.rank, requester, DATA_TAG, (tile, payload))
        self.stats["data_sent"] += 1

    def _on_tile_data(self, statuses, meta):
        succ_id, tile, count_dep = meta
        got_tile, payload = statuses[0].payload
        with self._lock:
            self.tiles[got_tile] = payload
            if count_dep:
                self._dep_satisfied_locked(succ_id)
            # any task parked on an in-flight tile gets re-examined
            if self.waiting:
                self.ready.extend(self.waiting)
                self.waiting.clear()

    def _dep_satisfied_locked(self, task_id: str) -> None:
        self.deps_left[task_id] -= 1
        if self.deps_left[task_id] == 0:
            self.ready.append(task_id)

    # ---------------------------------------------------------------- tasks
    def _complete_task(self, task: DataflowTask, result: np.ndarray) -> None:
        with self._lock:
            self.tiles[task.output] = result
            self.done_tasks.add(task.task_id)
            deferred = self.pending_gets.pop(task.output, [])
        for requester in deferred:       # serve GETs that raced production
            self.transport.isend(self.rank, requester, DATA_TAG,
                                 (task.output, result))
            self.stats["data_sent"] += 1
        for succ_id in task.successors:
            succ = self.graph.tasks[succ_id]
            if succ.owner == self.rank:
                with self._lock:
                    # local successor: check whether its inputs are present
                    self._dep_satisfied_locked(succ_id)
            else:
                self.transport.isend(
                    self.rank, succ.owner, AM_ACTIVATE,
                    (succ_id, task.output, time.monotonic()))
                self.stats["am_sent"] += 1

    def _inputs_present(self, task: DataflowTask) -> bool:
        with self._lock:
            return all(t in self.tiles for t in task.inputs)

    def step(self) -> bool:
        """One scheduler iteration; returns True if any work was done."""
        self.backend.progress()
        task_id = None
        with self._lock:
            while self.ready:
                cand = self.ready.pop(0)
                if cand not in self.done_tasks:    # dedupe re-queued entries
                    task_id = cand
                    break
        if task_id is None:
            return False
        task = self.graph.tasks[task_id]
        if not self._inputs_present(task):
            # an input tile is still in flight (remote *initial* tiles have
            # no producer edge, and crossed data messages resolve late):
            # request anything missing (idempotent) and park the task
            with self._lock:
                missing = [t for t in task.inputs if t not in self.tiles]
                self.waiting.add(task_id)
            for tile in missing:
                self._ensure_tile(task_id, tile, count_dep=False)
            return True
        with self._lock:
            inputs = {t: self.tiles[t] for t in task.inputs}
        result = task.fn(inputs)
        self.stats["executed"] += 1
        self._complete_task(task, result)
        return True

    @property
    def finished(self) -> bool:
        my_tasks = [t for t in self.graph.tasks.values()
                    if t.owner == self.rank]
        return len(self.done_tasks) == len(my_tasks)


def run_dataflow(graph: DataflowGraph, backend_factory,
                 engine: Optional[Engine] = None, timeout: float = 60.0,
                 scheduler: str = "fifo",
                 ) -> Tuple[Dict[str, np.ndarray], Dict[str, Any]]:
    """Execute the DAG on n_ranks threads; returns (all tiles, stats).

    ``scheduler`` selects the continuation scheduler for an internally
    created engine ("fifo" or "affinity" — the per-thread affinity queues
    cut ready-queue contention across the rank threads).
    """
    own_engine = engine is None
    engine = engine or Engine(scheduler=scheduler)
    transport = Transport(graph.n_ranks, engine=engine)
    graph.finalize()
    ranks = [DataflowRank(r, graph, transport, backend_factory(engine))
             for r in range(graph.n_ranks)]
    deadline = time.monotonic() + timeout
    errors: List[BaseException] = []

    def loop(rk: DataflowRank):
        # termination is GLOBAL: a rank done with its own tasks must keep
        # serving GETs/data for ranks still working (distributed-termination)
        try:
            idle_spins = 0
            while not all(r.finished for r in ranks):
                if time.monotonic() > deadline:
                    raise TimeoutError(f"rank {rk.rank} stalled; "
                                       f"done={len(rk.done_tasks)}")
                if rk.step():
                    idle_spins = 0
                else:
                    idle_spins += 1
                    if idle_spins > 50:
                        time.sleep(1e-5)
        except BaseException as e:   # surfaced to the caller
            errors.append(e)

    threads = [threading.Thread(target=loop, args=(rk,)) for rk in ranks]
    t0 = time.monotonic()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    makespan = time.monotonic() - t0
    if errors:
        raise errors[0]
    tiles: Dict[str, np.ndarray] = {}
    for rk in ranks:
        tiles.update(rk.tiles)
    lat = [l for rk in ranks for l in rk.stats["activation_latency"]]
    stats = {
        "makespan": makespan,
        "executed": sum(rk.stats["executed"] for rk in ranks),
        "ams": sum(rk.stats["am_sent"] for rk in ranks),
        "mean_activation_latency": float(np.mean(lat)) if lat else 0.0,
    }
    if own_engine:
        engine.shutdown()
    return tiles, stats
