"""Tiled Cholesky factorization over the dataflow runtime — the DPLASMA/QR
weak-scaling analogue of paper §5.3.2 (same DAG structure class: panel
factorization + trailing updates; Cholesky chosen for its compact task set).

Tiles are distributed 2-D block-cyclic. Tile names are versioned
("A[i,j]v{k}") so every task reads/writes unique dataflow objects.
"""
from __future__ import annotations

from typing import Dict, Tuple

import numpy as np

from repro.dataflow.runtime import DataflowGraph


def make_spd_matrix(n: int, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    a = rng.standard_normal((n, n))
    return a @ a.T + n * np.eye(n)


def _owner(i: int, j: int, n_ranks: int) -> int:
    return (i * 31 + j) % n_ranks          # 2-D cyclic-ish distribution


def build_cholesky_graph(A: np.ndarray, nb: int, tile: int,
                         n_ranks: int) -> Tuple[DataflowGraph, Dict]:
    """nb × nb tiles of size tile × tile; returns (graph, tile name map)."""
    g = DataflowGraph(n_ranks)
    name = lambda i, j, v: f"A[{i},{j}]v{v}"
    version = {}
    for i in range(nb):
        for j in range(i + 1):
            version[(i, j)] = 0
            g.add_tile(name(i, j, 0),
                       A[i * tile:(i + 1) * tile, j * tile:(j + 1) * tile],
                       _owner(i, j, n_ranks))

    def potrf(inputs):
        (a,) = inputs.values()
        return np.linalg.cholesky(a)

    def trsm(ins):
        return lambda inputs: np.linalg.solve(
            inputs[ins[1]], inputs[ins[0]].T).T   # A · L^{-T}

    def syrk(ins):
        def fn(inputs):
            return inputs[ins[0]] - inputs[ins[1]] @ inputs[ins[1]].T
        return fn

    def gemm(ins):
        def fn(inputs):
            return inputs[ins[0]] - inputs[ins[1]] @ inputs[ins[2]].T
        return fn

    for k in range(nb):
        vk = version[(k, k)]
        lkk = name(k, k, vk + 1)
        g.add_task(f"POTRF({k})", potrf, [name(k, k, vk)], lkk,
                   _owner(k, k, n_ranks))
        version[(k, k)] = vk + 1
        for i in range(k + 1, nb):
            vik = version[(i, k)]
            ins = [name(i, k, vik), lkk]
            g.add_task(f"TRSM({i},{k})", trsm(ins), ins,
                       name(i, k, vik + 1), _owner(i, k, n_ranks))
            version[(i, k)] = vik + 1
        for i in range(k + 1, nb):
            lik = name(i, k, version[(i, k)])
            for j in range(k + 1, i + 1):
                ljk = name(j, k, version[(j, k)])
                vij = version[(i, j)]
                if i == j:
                    ins = [name(i, i, vij), lik]
                    g.add_task(f"SYRK({i},{k})", syrk(ins), ins,
                               name(i, i, vij + 1), _owner(i, i, n_ranks))
                else:
                    ins = [name(i, j, vij), lik, ljk]
                    g.add_task(f"GEMM({i},{j},{k})", gemm(ins), ins,
                               name(i, j, vij + 1), _owner(i, j, n_ranks))
                version[(i, j)] = vij + 1
    return g, {"name": name, "version": version, "nb": nb, "tile": tile}


def assemble_result(tiles: Dict[str, np.ndarray], meta: Dict) -> np.ndarray:
    nb, tile = meta["nb"], meta["tile"]
    name, version = meta["name"], meta["version"]
    L = np.zeros((nb * tile, nb * tile))
    for i in range(nb):
        for j in range(i + 1):
            L[i * tile:(i + 1) * tile, j * tile:(j + 1) * tile] = \
                tiles[name(i, j, version[(i, j)])]
    return L
