"""zamba2-1.2b [hybrid] — Mamba2 backbone + shared attention blocks.

38L d_model=2048 32H (kv=32) d_ff=8192 vocab=32000 ssm_state=64
[arXiv:2411.15242; hf]. Shared transformer block at width 2·d_model applied
every 6 SSM layers with per-site projectors (Zamba2 design); LoRA-style
per-site adapters on the shared block are omitted (DESIGN.md §4).
"""
from repro.models.common import HYBRID, ModelConfig, SSMConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-1.2b", family=HYBRID,
        n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32, head_dim=128,
        d_ff=8192, vocab_size=32000, tied_embeddings=True,
        hybrid_attn_every=6, rope_theta=10000.0,
        ssm=SSMConfig(state_dim=64, head_dim=64, expand=2, conv_width=4,
                      n_groups=1, chunk_size=64),
        scan_layers=False,  # heterogeneous pattern: python-loop layers
    )
