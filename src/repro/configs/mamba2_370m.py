"""mamba2-370m [ssm] — attention-free SSD stack.

48L d_model=1024 vocab=50280 ssm_state=128, d_ff=0 (no MLP blocks)
[arXiv:2405.21060; unverified]. headdim=64 → 32 SSD heads. All four
shapes run, including long_500k (constant-size decode state).
"""
from repro.models.common import SSM, ModelConfig, SSMConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-370m", family=SSM,
        n_layers=48, d_model=1024, n_heads=0, n_kv_heads=1, d_ff=0,
        vocab_size=50280, tied_embeddings=True, rope_theta=0.0,
        ssm=SSMConfig(state_dim=128, head_dim=64, expand=2, conv_width=4,
                      n_groups=1, chunk_size=64),
    )
