"""h2o-danube-3-4b [dense] — llama+mistral mix with sliding-window attention.

24L d_model=3840 32H (GQA kv=8) d_ff=10240 vocab=32000
[arXiv:2401.16818; unverified]. Window = 4096 (danube SWA recipe;
documented assumption, DESIGN.md §4). SWA makes long_500k decode runnable:
the KV cache is a window-bounded ring buffer.
"""
from repro.models.common import DENSE, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="h2o-danube-3-4b", family=DENSE,
        n_layers=24, d_model=3840, n_heads=32, n_kv_heads=8,
        d_ff=10240, vocab_size=32000, window=4096,
        tied_embeddings=False, rope_theta=10000.0,
    )
