"""Architecture registry: one module per assigned architecture.

``get_config(name)`` returns the exact published configuration;
``get_config(name, reduced=True)`` the CPU smoke-test reduction.
"""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.models.common import ModelConfig

ARCHITECTURES: List[str] = [
    "zamba2_1p2b",
    "h2o_danube3_4b",
    "deepseek_coder_33b",
    "llama3_405b",
    "command_r_plus_104b",
    "mamba2_370m",
    "qwen3_moe_235b_a22b",
    "llama4_scout_17b_16e",
    "whisper_large_v3",
    "internvl2_26b",
    "paper_demo",
]

_ALIASES = {
    "zamba2-1.2b": "zamba2_1p2b",
    "h2o-danube-3-4b": "h2o_danube3_4b",
    "deepseek-coder-33b": "deepseek_coder_33b",
    "llama3-405b": "llama3_405b",
    "command-r-plus-104b": "command_r_plus_104b",
    "mamba2-370m": "mamba2_370m",
    "qwen3-moe-235b-a22b": "qwen3_moe_235b_a22b",
    "llama4-scout-17b-a16e": "llama4_scout_17b_16e",
    "whisper-large-v3": "whisper_large_v3",
    "internvl2-26b": "internvl2_26b",
    "paper-demo": "paper_demo",
}


def canonical(name: str) -> str:
    name = name.replace("-", "_").replace(".", "p")
    return _ALIASES.get(name, name)


def get_config(name: str, reduced: bool = False, **overrides) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{canonical(name)}")
    cfg: ModelConfig = mod.config()
    if reduced:
        cfg = cfg.reduced()
    if overrides:
        import dataclasses
        cfg = dataclasses.replace(cfg, **overrides)
    return cfg


def assigned_architectures() -> List[str]:
    """The ten pool architectures (excludes the paper-demo config)."""
    return [a for a in ARCHITECTURES if a != "paper_demo"]
