"""internvl2-26b [vlm] — InternLM2-20b decoder backbone; InternViT STUB.

48L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=92553
[arXiv:2404.16821; hf]. input_specs() provides precomputed patch
embeddings (n_patches=1024) prepended to the token sequence; loss over
token positions only. Full attention → long_500k skip.
"""
from repro.models.common import VLM, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="internvl2-26b", family=VLM,
        n_layers=48, d_model=6144, n_heads=48, n_kv_heads=8, head_dim=128,
        d_ff=16384, vocab_size=92553, tied_embeddings=False,
        rope_theta=1000000.0,
        frontend_dim=3200, n_patches=1024,
    )
