"""whisper-large-v3 [audio] — enc-dec backbone; conv frontend STUB.

32L (32 enc + 32 dec) d_model=1280 20H (kv=20, MHA) d_ff=5120 vocab=51866
[arXiv:2212.04356; unverified]. 20 heads pad to 32 for TP16.
input_specs() provides precomputed frame embeddings (the two conv+GELU
stem layers are the stub). Sinusoidal decoder positions (DESIGN.md §4).
"""
from repro.models.common import AUDIO, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-large-v3", family=AUDIO,
        n_layers=32, n_enc_layers=32, n_dec_layers=32,
        d_model=1280, n_heads=20, n_kv_heads=20, head_dim=64,
        d_ff=5120, vocab_size=51866, tied_embeddings=True,
        rope_theta=0.0,  # sinusoidal/learned positions, not RoPE
        frontend_dim=1280, max_target_len=448,
    )
