"""llama4-scout-17b-a16e [moe] — 16 experts top-1 + shared expert.

48L d_model=5120 40H (GQA kv=8) d_ff=8192 vocab=202048
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]. 40 heads pad to 48 for
TP16. iRoPE chunked attention not modeled (full attention) → long_500k
skip (DESIGN.md §4).
"""
from repro.models.common import MOE, ModelConfig, MoEConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="llama4-scout-17b-a16e", family=MOE,
        n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8, head_dim=128,
        d_ff=8192, vocab_size=202048, tied_embeddings=False,
        rope_theta=500000.0,
        moe=MoEConfig(n_experts=16, top_k=1, d_ff=8192, shared_d_ff=8192,
                      capacity_factor=1.25, dispatch="einsum"),
    )
