"""command-r-plus-104b [dense] — parallel attn+MLP blocks, no-bias, tied.

64L d_model=12288 96H (GQA kv=8) d_ff=33792 vocab=256000
[hf:CohereForAI/c4ai-command-r-v01; unverified].
"""
from repro.models.common import DENSE, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="command-r-plus-104b", family=DENSE,
        n_layers=64, d_model=12288, n_heads=96, n_kv_heads=8, head_dim=128,
        d_ff=33792, vocab_size=256000, tied_embeddings=True,
        parallel_block=True, rope_theta=75000000.0,
    )
