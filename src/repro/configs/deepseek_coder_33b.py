"""deepseek-coder-33b [dense] — llama-arch code model.

62L d_model=7168 56H (GQA kv=8) d_ff=19200 vocab=32256
[arXiv:2401.14196; hf]. 56 heads pad to 64 for 16-way TP (zero-init padded
heads; function preserved — DESIGN.md §5). Full attention → long_500k skip.
"""
from repro.models.common import DENSE, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-coder-33b", family=DENSE,
        n_layers=62, d_model=7168, n_heads=56, n_kv_heads=8, head_dim=128,
        d_ff=19200, vocab_size=32256, tied_embeddings=False,
        rope_theta=100000.0,
    )
