"""llama3-405b [dense] — GQA, 128k vocab.

126L d_model=16384 128H (GQA kv=8) d_ff=53248 vocab=128256
[arXiv:2407.21783; unverified]. Full attention → long_500k skip.
"""
from repro.models.common import DENSE, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="llama3-405b", family=DENSE,
        n_layers=126, d_model=16384, n_heads=128, n_kv_heads=8, head_dim=128,
        d_ff=53248, vocab_size=128256, tied_embeddings=False,
        rope_theta=500000.0,
    )
