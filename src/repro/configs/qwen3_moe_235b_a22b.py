"""qwen3-moe-235b-a22b [moe] — 128 experts, top-8.

94L d_model=4096 64H (GQA kv=4) head_dim=128 d_ff(expert)=1536
vocab=151936 [hf:Qwen/Qwen3-30B-A3B; hf]. Expert-parallel over the model
axis (8 experts/chip at TP16). Full attention → long_500k skip.
"""
from repro.models.common import MOE, ModelConfig, MoEConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-235b-a22b", family=MOE,
        n_layers=94, d_model=4096, n_heads=64, n_kv_heads=4, head_dim=128,
        d_ff=1536, vocab_size=151936, tied_embeddings=False,
        rope_theta=1000000.0,
        moe=MoEConfig(n_experts=128, top_k=8, d_ff=1536,
                      capacity_factor=1.25, dispatch="einsum"),
    )
