"""paper-demo — the ~100M-parameter model used by the end-to-end training
example (examples/train_small.py): small llama-style decoder whose trainer
exercises the full continuation-driven runtime (async checkpoint, prefetch,
metric pump) on CPU.
"""
from repro.models.common import DENSE, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="paper-demo", family=DENSE,
        n_layers=12, d_model=768, n_heads=12, n_kv_heads=4, head_dim=64,
        d_ff=2048, vocab_size=16384, tied_embeddings=True,
        rope_theta=10000.0, remat="none", head_pad_to=1, vocab_pad_to=1,
    )
