"""Asynchronous sharded checkpointing with continuation-based commit.

Fault-tolerance substrate (DESIGN.md §5):

* ``save_async`` snapshots the train state (device→host copies started
  asynchronously), writes one ``.npy`` per leaf on an I/O pool, and attaches
  a continuation to ``when_all(write ops)`` that atomically commits the
  checkpoint (writes ``MANIFEST.json`` + renames the step dir). The
  registration carries per-registration flags (``enqueue_complete`` — the
  commit always runs through the continuation path, even when every write
  finished before registration; ``thread=any`` — I/O threads may run it
  directly). The trainer keeps stepping; it may ``handle.cr.test()`` at
  step boundaries (Listing-2 polling-service pattern), ``await
  handle.promise`` from async code, or simply ignore the handle.
* A checkpoint without a committed manifest is invisible to
  ``latest_step``/``restore`` — crash-during-save is safe (restart resumes
  from the previous committed step).
* ``restore`` rebuilds the pytree (and re-shards it onto whatever mesh the
  restarted job has — elastic restart goes through the same path).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Any, Dict, List, Optional

import jax
import numpy as np

from repro.core import (THREAD_ANY, ContinueFlags, Engine, HostTaskOp,
                        Promise, when_all)

# commit-continuation registration flags (see module docstring)
_COMMIT_FLAGS = ContinueFlags(enqueue_complete=True, thread=THREAD_ANY)


def _flatten_with_paths(tree) -> List[tuple]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        name = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out.append((name, leaf))
    return out


class CheckpointHandle:
    """Handle on an in-flight save: pollable (``cr``), blockable
    (``wait``), and awaitable (``promise`` resolves with the committed
    directory once the manifest is in place, rejects on write errors)."""

    def __init__(self, step: int, directory: str, cr,
                 promise: Promise) -> None:
        self.step = step
        self.directory = directory
        self.cr = cr
        self.promise = promise
        self.committed = threading.Event()
        self.error: Optional[BaseException] = None

    def wait(self, timeout: float = 120.0) -> bool:
        self.cr.wait(timeout=timeout)
        ok = self.committed.wait(timeout=timeout)
        if self.error is not None:
            raise self.error
        return ok


class AsyncCheckpointer:
    def __init__(self, base_dir: str, engine: Engine, *,
                 io_workers: int = 4, keep: int = 3) -> None:
        self.base_dir = base_dir
        self.engine = engine
        self.keep = keep
        os.makedirs(base_dir, exist_ok=True)
        self._pool = ThreadPoolExecutor(max_workers=io_workers,
                                        thread_name_prefix="ckpt-io")
        self.stats = {"saves": 0, "commits": 0, "bytes": 0}

    # ------------------------------------------------------------------ save
    def save_async(self, step: int, state: Any) -> CheckpointHandle:
        tmp_dir = os.path.join(self.base_dir, f".tmp-step-{step:08d}")
        final_dir = os.path.join(self.base_dir, f"step-{step:08d}")
        os.makedirs(tmp_dir, exist_ok=True)
        leaves = _flatten_with_paths(state)
        cr = self.engine.continue_init()   # plain CR; flags ride the
        # registration (_COMMIT_FLAGS: thread=any, enqueue_complete)

        # start async device→host copies first (non-blocking snapshot)
        host_futs = []
        for name, leaf in leaves:
            if isinstance(leaf, jax.Array):
                try:
                    leaf.copy_to_host_async()
                except Exception:
                    pass
            host_futs.append((name, leaf))

        ops = []
        manifest = {"step": step, "leaves": {}}
        for name, leaf in host_futs:
            fname = name.replace("/", "__") + ".npy"
            manifest["leaves"][name] = fname

            def write(leaf=leaf, fname=fname):
                arr = np.asarray(leaf)
                path = os.path.join(tmp_dir, fname)
                with open(path, "wb") as f:
                    np.save(f, arr)
                return arr.nbytes

            ops.append(HostTaskOp(self._pool.submit(write)))

        # the new surface: one when_all composite, a Promise front-end, and
        # per-registration flags — enqueue_complete means the commit always
        # flows through the continuation path (no manual "everything was
        # already done" branch anymore), thread=any lets whatever I/O
        # thread finishes the last write run the commit directly.
        writes = Promise.of(self.engine, when_all(ops), cr=cr,
                            flags=_COMMIT_FLAGS)
        handle = CheckpointHandle(step, final_dir, cr, writes)

        def commit(nbytes: List[int]) -> str:
            self.stats["bytes"] += sum(n or 0 for n in nbytes)
            with open(os.path.join(tmp_dir, "MANIFEST.json"), "w") as f:
                json.dump(manifest, f)
            if os.path.exists(final_dir):
                shutil.rmtree(final_dir)
            os.rename(tmp_dir, final_dir)       # atomic commit
            self.stats["commits"] += 1
            handle.committed.set()
            self._gc()
            return final_dir

        def failed(exc: BaseException):
            handle.error = exc
            shutil.rmtree(tmp_dir, ignore_errors=True)
            handle.committed.set()
            raise exc                           # keep the promise rejected

        def commit_failed(exc: BaseException):
            # a failure in commit itself (manifest write, rename, gc) must
            # still surface through handle.wait(), not just the promise
            if handle.error is None:
                handle.error = exc
            handle.committed.set()
            raise exc

        handle.promise = writes.then(commit, failed).catch(commit_failed)
        self.stats["saves"] += 1
        return handle

    def _gc(self) -> None:
        steps = sorted(self.all_steps())
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.base_dir, f"step-{s:08d}"),
                          ignore_errors=True)

    # --------------------------------------------------------------- restore
    def all_steps(self) -> List[int]:
        out = []
        for d in os.listdir(self.base_dir):
            if d.startswith("step-") and os.path.exists(
                    os.path.join(self.base_dir, d, "MANIFEST.json")):
                out.append(int(d.split("-")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, like: Any, shardings: Any = None) -> Any:
        """Rebuild the pytree of ``like``'s structure from disk; optionally
        re-shard onto a (possibly different / shrunken) mesh."""
        d = os.path.join(self.base_dir, f"step-{step:08d}")
        with open(os.path.join(d, "MANIFEST.json")) as f:
            manifest = json.load(f)
        names = [n for n, _ in _flatten_with_paths(like)]
        arrays = []
        for name in names:
            arr = np.load(os.path.join(d, manifest["leaves"][name]))
            arrays.append(arr)
        treedef = jax.tree_util.tree_structure(like)
        restored = jax.tree_util.tree_unflatten(treedef, arrays)
        if shardings is not None:
            restored = jax.tree_util.tree_map(
                lambda a, s: jax.device_put(a, s) if s is not None
                else jax.device_put(a), restored, shardings)
        else:
            restored = jax.tree_util.tree_map(jax.device_put, restored)
        return restored

    def close(self) -> None:
        self._pool.shutdown(wait=True)
