"""Dry-run sweep: every (architecture × shape) cell, both meshes.

Each cell runs in a fresh subprocess (jax locks the virtual-device count at
first init). Two passes per cell:
  * single-pod (16×16), ``--exact``  → roofline numbers (§Roofline)
  * multi-pod (2×16×16), scanned     → proves the pod axis shards (§Dry-run)

Results accumulate as JSON under ``results/dryrun/`` so EXPERIMENTS.md can
be regenerated at any time. Cells ordered smallest-first.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

ORDER = [
    "mamba2_370m", "zamba2_1p2b", "h2o_danube3_4b", "whisper_large_v3",
    "llama4_scout_17b_16e", "internvl2_26b", "deepseek_coder_33b",
    "qwen3_moe_235b_a22b", "command_r_plus_104b", "llama3_405b",
]
SHAPES = ["decode_32k", "long_500k", "prefill_32k", "train_4k"]


def run_cell(arch: str, shape: str, out_dir: str, *, multi_pod: bool,
             exact: bool, timeout: int, force: bool = False,
             extra_env: dict | None = None) -> dict:
    tag = f"{arch}.{shape}.{'multi' if multi_pod else 'single'}" \
          f"{'.exact' if exact else ''}"
    out = os.path.join(out_dir, tag + ".json")
    if os.path.exists(out) and not force:
        with open(out) as f:
            return json.load(f)
    cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
           "--shape", shape, "--out", out]
    if multi_pod:
        cmd.append("--multi-pod")
    if exact:
        cmd.append("--exact")
    env = dict(os.environ)
    env["PYTHONPATH"] = env.get("PYTHONPATH", "src")
    if extra_env:
        env.update(extra_env)
    t0 = time.time()
    try:
        proc = subprocess.run(cmd, capture_output=True, text=True,
                              timeout=timeout, env=env)
    except subprocess.TimeoutExpired:
        rec = {"arch": arch, "shape": shape, "multi_pod": multi_pod,
               "exact": exact, "status": "timeout", "elapsed": timeout}
        with open(out, "w") as f:
            json.dump(rec, f)
        return rec
    if proc.returncode != 0:
        rec = {"arch": arch, "shape": shape, "multi_pod": multi_pod,
               "exact": exact, "status": "error",
               "stderr": proc.stderr[-4000:],
               "elapsed": round(time.time() - t0, 1)}
        with open(out, "w") as f:
            json.dump(rec, f, indent=2)
        return rec
    with open(out) as f:
        return json.load(f)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="results/dryrun")
    ap.add_argument("--timeout", type=int, default=1800)
    ap.add_argument("--only-arch", default=None)
    ap.add_argument("--only-shape", default=None)
    ap.add_argument("--skip-multi", action="store_true")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)
    archs = [args.only_arch] if args.only_arch else ORDER
    shapes = [args.only_shape] if args.only_shape else SHAPES
    t_start = time.time()
    for arch in archs:
        for shape in shapes:
            for multi_pod, exact in ((False, True), (True, False)):
                if multi_pod and args.skip_multi:
                    continue
                t0 = time.time()
                rec = run_cell(arch, shape, args.out_dir,
                               multi_pod=multi_pod, exact=exact,
                               timeout=args.timeout, force=args.force)
                status = rec.get("status")
                extra = ""
                if status == "ok":
                    r = rec.get("roofline", {})
                    extra = (f" dom={r.get('dominant')} "
                             f"frac={r.get('roofline_fraction', 0):.3f}")
                print(f"[{time.time() - t_start:7.0f}s] {arch:24s} "
                      f"{shape:12s} {'multi' if multi_pod else 'single':6s} "
                      f"{status:8s} ({time.time() - t0:5.1f}s){extra}",
                      flush=True)


if __name__ == "__main__":
    main()
