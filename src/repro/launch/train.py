"""Training driver: the continuation engine orchestrating a real run.

Every asynchronous subsystem of the trainer is a continuation client
(DESIGN.md §2a):

* input pipeline — depth-N prefetch, fills re-posted from continuations;
* metrics — a continuation on the step's loss ``ArrayOp`` logs when the
  device value materializes (the loop never blocks on readback);
* checkpointing — async sharded save whose *commit* is a ``continue_all``
  over the shard writes; the loop polls ``cr.test()`` at step boundaries
  (paper Listing-2 polling-service pattern);
* restart — on launch, the latest *committed* checkpoint is restored
  (crash-safety tested in tests/substrate).

Usage:  PYTHONPATH=src python -m repro.launch.train --arch paper_demo \
            --steps 300 --global-batch 4 --seq-len 256
"""
from __future__ import annotations

import argparse
import json
import os
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.async_ckpt import AsyncCheckpointer
from repro.configs import get_config
from repro.core import ArrayOp, Engine
from repro.data.pipeline import PrefetchPipeline, SyntheticTokenSource
from repro.optim import OptConfig, warmup_cosine
from repro.train.train_step import init_train_state, make_train_step


def train(arch: str = "paper_demo", steps: int = 100, global_batch: int = 4,
          seq_len: int = 256, lr: float = 3e-4, ckpt_dir: Optional[str] = None,
          ckpt_every: int = 50, log_every: int = 10, reduced: bool = False,
          num_microbatches: int = 1, log_path: Optional[str] = None,
          seed: int = 0) -> Dict[str, Any]:
    engine = Engine()
    cfg = get_config(arch, reduced=reduced, remat="none",
                     dtype=jnp.float32, param_dtype=jnp.float32)
    opt = OptConfig(lr=lr)
    sched = warmup_cosine(lr, warmup_steps=max(1, steps // 20),
                          total_steps=steps)
    step_fn = jax.jit(make_train_step(cfg, opt, lr_schedule=sched,
                                      num_microbatches=num_microbatches))

    ckpt = AsyncCheckpointer(ckpt_dir, engine) if ckpt_dir else None
    state = init_train_state(jax.random.PRNGKey(seed), cfg, opt)
    start_step = 0
    if ckpt is not None and ckpt.latest_step() is not None:
        start_step = ckpt.latest_step()
        state = ckpt.restore(start_step, state)
        print(f"[train] restored committed checkpoint at step {start_step}")

    source = SyntheticTokenSource(cfg, global_batch, seq_len, seed=seed)
    pipeline = PrefetchPipeline(source, engine, depth=2)
    # skip batches already consumed before the restart (deterministic resume)
    for _ in range(start_step):
        pipeline._next_deliver += 0  # indices are absolute; realign below
    pipeline._posted = start_step
    pipeline._next_deliver = start_step

    metrics_cr = engine.continue_init({"mpi_continue_enqueue_complete": True})
    log_rows = []
    t_start = time.time()

    def log_metrics(statuses, step_idx):
        loss = float(np.asarray(statuses[0].payload["loss"]))
        row = {"step": step_idx, "loss": loss,
               "elapsed_s": round(time.time() - t_start, 2)}
        log_rows.append(row)
        if step_idx % log_every == 0 or step_idx == steps - 1:
            print(f"[train] step {step_idx:5d} loss {loss:.4f} "
                  f"({row['elapsed_s']:.1f}s)", flush=True)

    handles = []
    for step_idx in range(start_step, steps):
        batch = pipeline.get_next()
        state, metrics = step_fn(state, batch)
        # completion-driven metric readback: callback runs when the loss
        # array is materialized; never blocks the step loop
        engine.continue_when(ArrayOp(metrics, payload=metrics), log_metrics,
                             step_idx, status=[None], cr=metrics_cr)
        if ckpt is not None and (step_idx + 1) % ckpt_every == 0:
            handles.append(ckpt.save_async(step_idx + 1, state))
        metrics_cr.test()        # Listing-2 polling service at step boundary

    metrics_cr.wait(timeout=60)
    if ckpt is not None:
        final = ckpt.save_async(steps, state)
        final.wait(timeout=300)
        for h in handles:
            h.wait(timeout=300)
        ckpt.close()
    pipeline.close()
    engine.shutdown()
    result = {"arch": cfg.name, "steps": steps,
              "first_loss": log_rows[0]["loss"] if log_rows else None,
              "final_loss": log_rows[-1]["loss"] if log_rows else None,
              "elapsed_s": round(time.time() - t_start, 1),
              "rows": log_rows}
    if log_path:
        os.makedirs(os.path.dirname(log_path) or ".", exist_ok=True)
        with open(log_path, "w") as f:
            json.dump(result, f, indent=2)
    return result


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="paper_demo")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--global-batch", type=int, default=4)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--log-path", default=None)
    args = ap.parse_args()
    result = train(arch=args.arch, steps=args.steps,
                   global_batch=args.global_batch, seq_len=args.seq_len,
                   lr=args.lr, ckpt_dir=args.ckpt_dir,
                   ckpt_every=args.ckpt_every, reduced=args.reduced,
                   num_microbatches=args.microbatches,
                   log_path=args.log_path)
    print(f"[train] done: loss {result['first_loss']:.4f} → "
          f"{result['final_loss']:.4f} in {result['elapsed_s']}s")


if __name__ == "__main__":
    main()
