import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture × shape × mesh).

The two lines above run before ANY other import — jax locks the device
count at first init, and only the dry-run may see 512 placeholder devices
(assignment requirement; tests/benches must see 1).

Per cell this driver:
  1. builds the production mesh (16×16 single-pod / 2×16×16 multi-pod),
  2. builds the step program (train_step / prefill_step / serve_step) with
     ShapeDtypeStruct inputs and NamedShardings from the logical rules,
  3. ``.lower().compile()`` — failures here are sharding bugs,
  4. dumps ``memory_analysis()`` / ``cost_analysis()`` / parsed collective
     bytes as JSON for EXPERIMENTS.md §Dry-run and §Roofline.

Accounting modes (DESIGN.md §6):
  * ``--exact``: layers unrolled (``scan_layers=False``) and, for train,
    a single-microbatch program — no ``while`` loops, so cost_analysis and
    the collective parse are exact; totals scale by the microbatch count.
  * default (scan): fast compile; used for the multi-pod validation pass
    and for full-program memory_analysis.
"""
import argparse
import dataclasses
import json
import sys
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.launch.mesh import make_debug_mesh, make_production_mesh
from repro.launch.shapes import (SHAPES, ShapeSpec, input_specs,
                                 is_applicable, microbatches_for)
from repro.models import encdec, lm
from repro.models.common import AUDIO, VLM, ModelConfig
from repro.optim import OptConfig
from repro.roofline.hlo import collective_bytes, model_flops, roofline_terms
from repro.serve.steps import make_decode_step
from repro.sharding import specs_to_shardings, use_sharding
from repro.train.sharding import batch_logical_axes, rules_for
from repro.train.train_step import (init_train_state, make_train_step,
                                    train_state_specs)


def _shape_structs(fn, *args) -> Any:
    return jax.eval_shape(fn, *args)


def serve_state_specs(cfg: ModelConfig) -> Any:
    if cfg.family == AUDIO:
        from repro.models.attention import kv_cache_specs
        if cfg.scan_layers:
            cross = {"k": (None, "batch", None, None, "tp"),
                     "v": (None, "batch", None, None, "tp")}
            return {"cross": cross,
                    "self": kv_cache_specs(True, cfg)}
        cross_one = {"k": ("batch", None, None, "tp"),
                     "v": ("batch", None, None, "tp")}
        return {"cross": [cross_one] * cfg.n_dec_layers,
                "self": [kv_cache_specs(False, cfg)] * cfg.n_dec_layers}
    return lm.cache_specs(cfg)


def build_program(cfg: ModelConfig, shape: ShapeSpec, mesh, *,
                  exact: bool, opts: Optional[Dict[str, Any]] = None
                  ) -> Dict[str, Any]:
    opts = opts or {}
    """Returns {lowered, n_repeat, tokens} for the cell."""
    rules = rules_for(shape.kind)
    key = jax.random.PRNGKey(0)
    batch_structs = input_specs(cfg, shape)

    with use_sharding(mesh, rules):
        batch_shardings = specs_to_shardings(
            batch_logical_axes(batch_structs), mesh, rules)

        if shape.kind == "train":
            state_dtype = jnp.bfloat16 if (
                opts.get("opt_dtype") == "bf16"
                or (opts.get("opt_dtype") is None
                    and cfg.param_count() > 2e11)) else jnp.float32
            opt = OptConfig(state_dtype=state_dtype)
            n_mb = opts.get("microbatches") or microbatches_for(cfg)
            state_structs = _shape_structs(
                lambda: init_train_state(key, cfg, opt))
            state_shardings = specs_to_shardings(
                train_state_specs(cfg), mesh, rules)
            if exact:
                # single-microbatch exact program; totals scale ×n_mb
                mb = shape.global_batch // n_mb
                sub = ShapeSpec(shape.name, "train", shape.seq_len, mb)
                batch_structs = input_specs(cfg, sub)
                batch_shardings = specs_to_shardings(
                    batch_logical_axes(batch_structs), mesh, rules)
                gst = train_state_specs(cfg)["params"] \
                    if opts.get("grad_rs") else None
                step = make_train_step(cfg, opt, num_microbatches=1,
                                       grad_spec_tree=gst)
                n_repeat = n_mb
            else:
                gst = train_state_specs(cfg)["params"] \
                    if opts.get("grad_rs") else None
                step = make_train_step(cfg, opt, num_microbatches=n_mb,
                                       grad_spec_tree=gst)
                n_repeat = 1
            jf = jax.jit(step, in_shardings=(state_shardings, batch_shardings),
                         donate_argnums=(0,))
            lowered = jf.lower(state_structs, batch_structs)
            tokens = shape.global_batch * shape.seq_len
            return {"lowered": lowered, "n_repeat": n_repeat,
                    "tokens": tokens}

        params_init = (encdec.init_params if cfg.family == AUDIO
                       else lm.init_params)
        params_structs = _shape_structs(lambda: params_init(key, cfg))
        pspecs = (encdec.param_specs if cfg.family == AUDIO
                  else lm.param_specs)(cfg)
        param_shardings = specs_to_shardings(pspecs, mesh, rules)

        if shape.kind == "prefill":
            from repro.serve.steps import make_prefill_step
            cache_len = shape.seq_len
            step = make_prefill_step(cfg, cache_len)
            jf = jax.jit(step, in_shardings=(param_shardings,
                                             batch_shardings))
            lowered = jf.lower(params_structs, batch_structs)
            return {"lowered": lowered, "n_repeat": 1,
                    "tokens": shape.global_batch * shape.seq_len}

        # decode / long: one token against a seq_len cache
        B = shape.global_batch
        if cfg.family == AUDIO:
            from repro.launch.shapes import WHISPER_CROSS_LEN
            audio_struct = jax.ShapeDtypeStruct(
                (B, WHISPER_CROSS_LEN, cfg.frontend_dim), jnp.bfloat16)
            cache_structs = _shape_structs(
                lambda p, a: encdec.init_decode_state(p, a, cfg,
                                                      shape.seq_len),
                params_structs, audio_struct)
        else:
            cache_structs = _shape_structs(
                lambda: lm.init_cache(cfg, B, shape.seq_len))
        cache_shardings = specs_to_shardings(serve_state_specs(cfg), mesh,
                                             rules)
        step = make_decode_step(cfg)
        tok_sharding = specs_to_shardings(
            {"t": ("batch", None)}, mesh, rules)["t"]
        jf = jax.jit(step,
                     in_shardings=(param_shardings, cache_shardings,
                                   tok_sharding, None),
                     donate_argnums=(1,))
        lowered = jf.lower(params_structs, cache_structs,
                           jax.ShapeDtypeStruct((B, 1), jnp.int32),
                           jax.ShapeDtypeStruct((), jnp.int32))
        return {"lowered": lowered, "n_repeat": 1, "tokens": B}


def run_cell(arch: str, shape_name: str, *, multi_pod: bool, exact: bool,
             debug_mesh: bool = False,
             opts: Optional[Dict[str, Any]] = None) -> Dict[str, Any]:
    opts = opts or {}
    shape = SHAPES[shape_name]
    overrides: Dict[str, Any] = {"attn_impl": "xla",
                                 "scan_layers": (not exact)}
    if opts.get("remat"):
        overrides["remat"] = opts["remat"]
    if opts.get("lean"):
        overrides["lean_attention"] = True
    if opts.get("gather_weights"):
        overrides["gather_weights"] = True
    if opts.get("n_layers"):
        overrides["n_layers"] = opts["n_layers"]
    cfg = get_config(arch, **overrides)
    if opts.get("dispatch") and cfg.moe is not None:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, dispatch=opts["dispatch"]))
    if opts.get("ssm_chunk") and cfg.ssm is not None:
        cfg = dataclasses.replace(
            cfg, ssm=dataclasses.replace(cfg.ssm,
                                         chunk_size=opts["ssm_chunk"]))
    if cfg.family == "hybrid":
        cfg = dataclasses.replace(cfg, scan_layers=False)
    ok, reason = is_applicable(cfg, shape)
    result: Dict[str, Any] = {
        "arch": cfg.name, "shape": shape_name, "multi_pod": multi_pod,
        "exact": exact, "applicable": ok, "reason": reason,
    }
    if not ok:
        result["status"] = "skipped"
        return result
    mesh = (make_debug_mesh(multi_pod=multi_pod) if debug_mesh
            else make_production_mesh(multi_pod=multi_pod))
    chips = mesh.size
    result["opts"] = {k: v for k, v in opts.items() if v}
    t0 = time.time()
    prog = build_program(cfg, shape, mesh, exact=exact, opts=opts)
    lowered = prog["lowered"]
    result["lower_s"] = round(time.time() - t0, 2)
    t0 = time.time()
    compiled = lowered.compile()
    result["compile_s"] = round(time.time() - t0, 2)

    ma = compiled.memory_analysis()
    result["memory"] = {
        "argument_bytes_per_device": int(ma.argument_size_in_bytes),
        "output_bytes_per_device": int(ma.output_size_in_bytes),
        "temp_bytes_per_device": int(ma.temp_size_in_bytes),
        "alias_bytes_per_device": int(ma.alias_size_in_bytes),
        "fits_16g_hbm": bool(
            ma.argument_size_in_bytes + ma.output_size_in_bytes
            + ma.temp_size_in_bytes - ma.alias_size_in_bytes < 16e9),
    }
    from repro.compat import cost_analysis
    ca = cost_analysis(compiled)
    n_rep = prog["n_repeat"]
    flops_dev = float(ca.get("flops", 0.0)) * n_rep
    bytes_dev = float(ca.get("bytes accessed", 0.0)) * n_rep
    txt = compiled.as_text()
    if opts.get("dump_hlo"):
        with open(opts["dump_hlo"], "w") as f:
            f.write(txt)
    from repro.roofline.hlo import opcode_bytes_histogram
    result["opcode_hist"] = opcode_bytes_histogram(txt)
    colls = collective_bytes(txt)
    for v in colls.values():
        v["bytes"] *= n_rep
        v["count"] *= n_rep
    coll_dev = sum(v["bytes"] for v in colls.values())
    result["collectives"] = colls
    result["cost"] = {"flops_per_device": flops_dev,
                      "bytes_per_device": bytes_dev,
                      "collective_bytes_per_device": coll_dev,
                      "n_repeat_scaling": n_rep}
    terms = roofline_terms(flops_dev, bytes_dev, coll_dev, chips)
    mf = model_flops(cfg, shape.kind, prog["tokens"])
    terms["model_flops"] = mf
    terms["useful_flops_ratio"] = mf / terms["flops_global"] \
        if terms["flops_global"] else 0.0
    result["roofline"] = terms
    result["status"] = "ok"
    result["chips"] = chips
    return result


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True, choices=list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--exact", action="store_true",
                    help="unrolled layers, single-microbatch (roofline mode)")
    ap.add_argument("--debug-mesh", action="store_true",
                    help="tiny 2x2 mesh (CI tests)")
    ap.add_argument("--out", default=None)
    # hillclimb levers (§Perf)
    ap.add_argument("--remat", choices=["none", "full", "dots"], default=None)
    ap.add_argument("--dispatch", choices=["einsum", "scatter"], default=None)
    ap.add_argument("--microbatches", type=int, default=None)
    ap.add_argument("--opt-dtype", choices=["f32", "bf16"], default=None)
    ap.add_argument("--ssm-chunk", type=int, default=None)
    ap.add_argument("--grad-rs", action="store_true",
                    help="constrain grads to weight sharding (reduce-scatter)")
    ap.add_argument("--dump-hlo", default=None,
                    help="write the compiled SPMD module text to this path")
    ap.add_argument("--lean", action="store_true",
                    help="memory-lean attention/rope (bf16 tensors, fp32 "
                         "reductions)")
    ap.add_argument("--gather-weights", action="store_true",
                    help="ZeRO-3 just-in-time weight all-gather (§Perf)")
    ap.add_argument("--n-layers", type=int, default=None,
                    help="layer-count override (two-point extrapolation "
                         "when the full unrolled compile exceeds host RAM)")
    args = ap.parse_args()
    opts = {"remat": args.remat, "dispatch": args.dispatch,
            "microbatches": args.microbatches, "opt_dtype": args.opt_dtype,
            "ssm_chunk": args.ssm_chunk, "grad_rs": args.grad_rs,
            "dump_hlo": args.dump_hlo, "lean": args.lean,
            "gather_weights": args.gather_weights,
            "n_layers": args.n_layers}
    result = run_cell(args.arch, args.shape, multi_pod=args.multi_pod,
                      exact=args.exact, debug_mesh=args.debug_mesh,
                      opts=opts)
    js = json.dumps(result, indent=2, default=str)
    if args.out:
        with open(args.out, "w") as f:
            f.write(js)
    print(js)
    if result["status"] not in ("ok", "skipped"):
        sys.exit(1)


if __name__ == "__main__":
    main()
