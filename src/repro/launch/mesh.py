"""Production mesh construction.

A FUNCTION, not a module-level constant: importing this module never
touches jax device state (assignment requirement). The single-pod mesh is
16×16 = 256 chips (data, model); the multi-pod mesh adds the scale-out
"pod" axis: 2×16×16 = 512 chips. The pod axis composes with data for
batch/FSDP sharding (logical rules in repro.sharding), so the multi-pod
dry-run proves cross-pod gradient reduction shards.
"""
from __future__ import annotations

from repro.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_debug_mesh(n_data: int = 2, n_model: int = 2, *,
                    multi_pod: bool = False):
    """Small mesh for CI-scale dry-run tests (8 virtual devices)."""
    shape = (2, n_data, n_model) if multi_pod else (n_data, n_model)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)
