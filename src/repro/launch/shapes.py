"""Assigned input shapes × architectures: specs, applicability, programs.

The four LM shapes (assignment):
    train_4k     seq 4096  × global_batch 256   → train_step
    prefill_32k  seq 32768 × global_batch 32    → prefill_step
    decode_32k   seq 32768 × global_batch 128   → serve_step (1 new token,
                                                  KV cache of seq_len)
    long_500k    seq 524288 × global_batch 1    → serve_step; sub-quadratic
                 archs only (SSM / hybrid / SWA) — full-attention archs skip
                 (DESIGN.md §4)

``input_specs`` returns weak-type-correct ShapeDtypeStructs for every model
input (no allocation); ``state_specs``/``cache_specs`` the same for carried
state. Per-arch microbatch counts keep train_4k activation memory bounded.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import AUDIO, HYBRID, SSM, VLM, ModelConfig

WHISPER_CROSS_LEN = 1500   # canonical whisper encoder output length


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str          # train | prefill | decode | long
    seq_len: int
    global_batch: int


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "long", 524288, 1),
}

# train_4k gradient-accumulation microbatches (global batch 256)
MICROBATCHES: Dict[str, int] = {
    "llama3-405b": 16,
    "command-r-plus-104b": 16,
    "deepseek-coder-33b": 8,
    "qwen3-moe-235b-a22b": 8,
    "internvl2-26b": 8,
    "llama4-scout-17b-a16e": 8,
    "h2o-danube-3-4b": 4,
    "zamba2-1.2b": 4,
    "mamba2-370m": 4,
    "whisper-large-v3": 4,
}


def microbatches_for(cfg: ModelConfig) -> int:
    return MICROBATCHES.get(cfg.name, 4)


def is_applicable(cfg: ModelConfig, shape: ShapeSpec) -> Tuple[bool, str]:
    if shape.kind == "long":
        if cfg.family in (SSM, HYBRID):
            return True, "state-space decode: O(1) state"
        if cfg.window:
            return True, f"SWA decode: window-bounded cache ({cfg.window})"
        return False, ("full attention: 500k-token stream is the quadratic "
                       "regime this shape excludes (DESIGN.md §4)")
    return True, ""


def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> Dict[str, Any]:
    """ShapeDtypeStructs for the step's *batch* inputs."""
    B, S = shape.global_batch, shape.seq_len
    tok = lambda b, s: jax.ShapeDtypeStruct((b, s), jnp.int32)
    if cfg.family == AUDIO:
        if shape.kind == "train":
            return {"audio_embed": jax.ShapeDtypeStruct(
                        (B, S, cfg.frontend_dim), jnp.bfloat16),
                    "dec_tokens": tok(B, min(cfg.max_target_len, 448))}
        if shape.kind == "prefill":
            return {"audio_embed": jax.ShapeDtypeStruct(
                        (B, S, cfg.frontend_dim), jnp.bfloat16)}
        return {"token": tok(B, 1)}                    # decode
    if cfg.family == VLM:
        if shape.kind == "train":
            return {"tokens": tok(B, S - cfg.n_patches),
                    "patches": jax.ShapeDtypeStruct(
                        (B, cfg.n_patches, cfg.frontend_dim), jnp.bfloat16)}
        if shape.kind == "prefill":
            return {"tokens": tok(B, S - cfg.n_patches),
                    "patches": jax.ShapeDtypeStruct(
                        (B, cfg.n_patches, cfg.frontend_dim), jnp.bfloat16)}
        return {"token": tok(B, 1)}
    if shape.kind in ("train", "prefill"):
        return {"tokens": tok(B, S)}
    return {"token": tok(B, 1)}                        # decode / long


def cache_shape_len(cfg: ModelConfig, shape: ShapeSpec) -> int:
    """KV cache length for serve shapes (SWA bounds it at the window)."""
    return shape.seq_len
