"""Logical-axis sharding: rules table + constraint helper.

Model code annotates activations with *logical* axis names; a rules table
maps them to mesh axes (MaxText-style). Outside a mesh context the helpers
are no-ops, so the same model code runs in CPU smoke tests and in the
256/512-chip dry-run unchanged.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Any, Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Logical = Union[str, None, Tuple[Union[str, None], ...]]

# Default rules: single-pod (data, model) and multi-pod (pod, data, model)
# meshes share one table — "replica" composes pod×data when present.
DEFAULT_RULES: Dict[str, Any] = {
    "batch": ("pod", "data"),
    "fsdp": ("pod", "data"),
    "tp": "model",
    "vocab": "model",
    "expert": "model",
    "heads": "model",
    "act_heads": "model",
    "seq": None,          # overridden to ("pod", "data") for long-context SP
    "kv_seq": None,
    "chunk": None,
    "state": None,
}

_CTX = threading.local()


def _current() -> Optional[Tuple[Mesh, Dict[str, Any]]]:
    return getattr(_CTX, "ctx", None)


@contextlib.contextmanager
def use_sharding(mesh: Optional[Mesh], rules: Optional[Dict[str, Any]] = None,
                 overrides: Optional[Dict[str, Any]] = None):
    """Activate a mesh + logical rules for model code in this thread."""
    if mesh is None:
        yield
        return
    table = dict(DEFAULT_RULES if rules is None else rules)
    if overrides:
        table.update(overrides)
    # drop mesh axes that don't exist (e.g. "pod" on the single-pod mesh)
    names = set(mesh.axis_names)

    def resolve(v):
        if v is None:
            return None
        if isinstance(v, tuple):
            kept = tuple(a for a in v if a in names)
            return kept if kept else None
        return v if v in names else None

    table = {k: resolve(v) for k, v in table.items()}
    prev = _current()
    _CTX.ctx = (mesh, table)
    try:
        yield
    finally:
        _CTX.ctx = prev


def logical_to_spec(axes: Sequence[Logical],
                    table: Optional[Dict[str, Any]] = None) -> P:
    """Map logical axis names to a PartitionSpec using the active rules."""
    if table is None:
        ctx = _current()
        if ctx is None:
            return P()
        table = ctx[1]
    spec = []
    used: set = set()

    def lookup(name):
        if name is None:
            return None
        v = table.get(name, None)
        return v

    for ax in axes:
        if isinstance(ax, tuple):
            parts = []
            for a in ax:
                v = lookup(a)
                if v is None:
                    continue
                parts.extend(v if isinstance(v, tuple) else (v,))
            parts = [p for p in parts if p not in used]
            used.update(parts)
            spec.append(tuple(parts) if parts else None)
        else:
            v = lookup(ax)
            if isinstance(v, tuple):
                v = tuple(p for p in v if p not in used)
                used.update(v)
                spec.append(v if v else None)
            else:
                if v in used:
                    v = None
                if v is not None:
                    used.add(v)
                spec.append(v)
    return P(*spec)


def constrain(x: jax.Array, *axes: Logical) -> jax.Array:
    """``with_sharding_constraint`` by logical names; no-op without a mesh."""
    ctx = _current()
    if ctx is None:
        return x
    mesh, table = ctx
    spec = logical_to_spec(axes, table)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def named_sharding(axes: Sequence[Logical]) -> Optional[NamedSharding]:
    ctx = _current()
    if ctx is None:
        return None
    mesh, table = ctx
    return NamedSharding(mesh, logical_to_spec(axes, table))


def specs_to_shardings(spec_tree: Any, mesh: Mesh,
                       rules: Optional[Dict[str, Any]] = None,
                       overrides: Optional[Dict[str, Any]] = None) -> Any:
    """Convert a pytree of logical-axis tuples into NamedShardings."""
    with use_sharding(mesh, rules, overrides):
        return jax.tree_util.tree_map(
            lambda axes: named_sharding(axes), spec_tree,
            is_leaf=lambda v: isinstance(v, tuple) or v is None)
