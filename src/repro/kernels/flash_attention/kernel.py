"""Pallas TPU flash attention (fwd) with causal / sliding-window / GQA.

TPU adaptation notes (DESIGN.md §2): tiles are shaped for the MXU
(block_q × block_k matmuls with head_dim as the lane axis, all multiples of
128 at production sizes) and the online-softmax state (m, l and the output
accumulator) lives in VMEM scratch that persists across the sequential TPU
grid — the kv-block axis is the innermost grid dimension, so each (batch,
head, q-block) revisits its accumulator while streaming KV tiles HBM→VMEM.

VMEM working set per step ≈ (block_q·D) q + 2·(block_k·D) kv +
(block_q·block_k) scores + (block_q·D) acc, all fp32 ≤ ~2 MB at
block_q = block_k = 512, D = 128 — comfortably inside 16 MB, leaving room
for double-buffered pipelining of the KV stream.

Fully-masked KV tiles are skipped via ``@pl.when`` on block-index
arithmetic: causal skips ki·bk > (qi+1)·bq; sliding-window additionally
skips tiles older than the window.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

# canary-sensitive imports route through compat: ``pl``/``pltpu`` are None
# on a pallas-less build and flash_attention() raises a targeted error at
# trace time (the ops wrapper never gets here — it downgrades to 'xla')
from repro.compat import pl, pltpu, require_pallas

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, causal: bool, window: int,
                  block_q: int, block_k: int, q_offset: int, kv_len: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    n_k = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * block_q + q_offset          # absolute pos of first query
    k_start = ki * block_k

    def _not_skipped():
        q = q_ref[0, 0].astype(jnp.float32)       # (bq, D)
        k = k_ref[0, 0].astype(jnp.float32)       # (bk, D)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ()))) * scale      # (bq, bk)
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = kpos < kv_len                   # tail padding
        if causal:
            mask &= kpos <= qpos
        if window > 0:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]                    # (bq, 1)
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        # guard fully-masked rows (exp(NEG_INF - NEG_INF) = 1 otherwise)
        p = jnp.where(m_new <= NEG_INF, 0.0, p)
        alpha = jnp.exp(m_prev - m_new)
        alpha = jnp.where(m_prev <= NEG_INF, 0.0, alpha)
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())))
        m_scr[...] = m_new

    if causal or window > 0:
        skip = jnp.array(False)
        if causal:  # tile entirely in the future
            skip |= k_start > q_start + block_q - 1
        if window > 0:  # tile entirely before every query's window
            skip |= (k_start + block_k - 1) <= (q_start - window)
        pl.when(~skip)(_not_skipped)
    else:
        _not_skipped()

    @pl.when(ki == n_k - 1)
    def _finish():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l).astype(o_ref.dtype)


def _pick_block(seq: int, want: int) -> int:
    b = min(seq, want)
    while seq % b:
        b -= 1
    return b


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "scale", "q_offset", "block_q",
                     "block_k", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0,
                    scale: float | None = None, q_offset: int = 0,
                    block_q: int = 512, block_k: int = 512,
                    interpret: bool = False) -> jax.Array:
    """q: (B, Sq, H, D);  k, v: (B, Sk, KV, D). Returns (B, Sq, H, D)."""
    require_pallas()
    B, Sq, H, D = q.shape
    _, Sk, KV, _ = k.shape
    groups = H // KV
    scale = D ** -0.5 if scale is None else scale
    bq = _pick_block(Sq, block_q)
    # pad kv length to a block multiple; padding masked via kv_len
    bk = min(block_k, max(Sk, 1))
    pad = (-Sk) % bk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    Skp = Sk + pad

    # (B, S, H, D) → (B, H, S, D) blocks; kv head index = h // groups
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    grid = (B, H, Sq // bq, Skp // bk)

    out = pl.pallas_call(
        functools.partial(
            _flash_kernel, scale=scale, causal=causal, window=window,
            block_q=bq, block_k=bk, q_offset=q_offset, kv_len=Sk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, bk, D),
                         lambda b, h, qi, ki, g=groups: (b, h // g, ki, 0)),
            pl.BlockSpec((1, 1, bk, D),
                         lambda b, h, qi, ki, g=groups: (b, h // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, D),
                               lambda b, h, qi, ki: (b, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, D), q.dtype),
        scratch_shapes=[
            # fp32 online-softmax state persisted across the kv grid axis
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, D), jnp.float32),
        ],
        interpret=interpret,
    )(qt, kt, vt)
    return out.transpose(0, 2, 1, 3)
