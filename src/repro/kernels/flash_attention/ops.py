"""Dispatching wrapper for attention: xla | pallas | pallas_interpret.

JAX-version-sensitive imports go through ``repro.compat``
(``impl_mod.resolve_runnable``): on a build where
``jax.experimental.pallas`` moved or broke — the canary CI leg — the
kernel module is never imported and the call degrades to the ``xla``
reference path with a one-time warning instead of an ImportError.
"""
from __future__ import annotations

from typing import Optional

import jax

from repro.kernels import impl as impl_mod
from repro.kernels.flash_attention import ref


def attention(q, k, v, *, causal: bool = True, window: int = 0,
              q_offset: int = 0, scale: Optional[float] = None,
              impl: str | None = None, lean: bool = False,
              block_q: int = 512, block_k: int = 512) -> jax.Array:
    impl = impl_mod.resolve_runnable(impl)
    if impl == "xla":
        return ref.attention(q, k, v, causal=causal, window=window,
                             q_offset=q_offset, scale=scale, lean=lean)
    from repro.kernels.flash_attention import kernel
    return kernel.flash_attention(
        q, k, v, causal=causal, window=window, q_offset=q_offset,
        scale=scale, block_q=block_q, block_k=block_k,
        interpret=(impl == "pallas_interpret"))
