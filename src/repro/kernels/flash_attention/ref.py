"""Pure-jnp oracle for (GQA, causal, sliding-window) attention.

Also the "xla" production path used by the dry-run/roofline compiles.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
              causal: bool = True, window: int = 0,
              q_offset: Optional[jax.Array] = None,
              scale: Optional[float] = None,
              lean: bool = False) -> jax.Array:
    """Multi-head attention with grouped KV heads.

    q: (B, Sq, H, D);  k, v: (B, Sk, KV, D) with H % KV == 0.
    ``q_offset``: absolute position of q[0] minus that of k[0] (decode uses
    q_offset = cache_len - Sq ≥ 0); default 0 (self-attention, aligned).
    ``window`` > 0 restricts each query to the last ``window`` keys
    (sliding-window attention). Softmax in fp32.
    """
    B, Sq, H, D = q.shape
    _, Sk, KV, _ = k.shape
    groups = H // KV
    scale = D ** -0.5 if scale is None else scale
    if groups > 1:
        k = jnp.repeat(k, groups, axis=2)
        v = jnp.repeat(v, groups, axis=2)
    qpos = jnp.arange(Sq)[:, None] + (0 if q_offset is None else q_offset)
    kpos = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), dtype=bool)
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= kpos > qpos - window
    if lean:
        # §Perf: (S,S) tensors stay bf16; only the max/sum reductions are
        # fp32 (flash-attention numerics) — halves attention HBM traffic
        s_ = jnp.einsum("bqhd,bkhd->bhqk", q, k) * jnp.asarray(scale, q.dtype)
        s_ = jnp.where(mask[None, None], s_, jnp.asarray(-3e38, s_.dtype))
        m = jax.lax.stop_gradient(
            jnp.max(s_.astype(jnp.float32), axis=-1, keepdims=True))
        p = jnp.exp(s_ - m.astype(s_.dtype))
        denom = jnp.sum(p.astype(jnp.float32), axis=-1, keepdims=True)
        probs = (p.astype(jnp.float32) / denom).astype(q.dtype)
        return jnp.einsum("bhqk,bkhd->bqhd", probs, v)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale
    scores = jnp.where(mask[None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, v)
