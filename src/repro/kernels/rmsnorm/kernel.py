"""Pallas TPU kernel: fused RMSNorm over the last axis.

Tiling: rows are flattened to (R, D); the grid walks row blocks. Each step
holds a (block_rows, D) tile of x plus the (D,) scale in VMEM, computes the
fp32 row-wise rsqrt(mean-square) and writes the scaled tile. D is kept whole
per tile (lane-dim multiple of 128 for the VPU); block_rows is chosen so the
working set stays ≪ VMEM (~16 MB on v5e):

    bytes ≈ block_rows · D · (2 in + 2 out) + 4·D  → block_rows = 256 at
    D = 16384 is ~16 MB? no: 256·16384·4 = 16 MB — we cap block_rows so the
    tile stays under ~4 MB and let the grid scale instead.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, scale_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)                     # (bR, D)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    o_ref[...] = (y * scale_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


def pick_block_rows(n_rows: int, d: int, budget_bytes: int = 4 << 20) -> int:
    per_row = d * 8  # fp32 in-tile + output
    block = max(1, min(n_rows, budget_bytes // per_row))
    # favor multiples of 8 (sublane) when possible
    if block >= 8:
        block -= block % 8
    while n_rows % block:
        block -= 1
    return max(block, 1)


@functools.partial(jax.jit, static_argnames=("eps", "interpret", "block_rows"))
def rmsnorm(x: jax.Array, scale: jax.Array, *, eps: float = 1e-5,
            block_rows: int = 0, interpret: bool = False) -> jax.Array:
    orig_shape = x.shape
    d = x.shape[-1]
    xf = x.reshape(-1, d)
    rows = xf.shape[0]
    br = block_rows or pick_block_rows(rows, d)
    grid = (rows // br,)
    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, d), x.dtype),
        interpret=interpret,
    )(xf, scale)
    return out.reshape(orig_shape)
