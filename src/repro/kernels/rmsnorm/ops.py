"""Dispatching wrapper for RMSNorm: xla | pallas | pallas_interpret."""
from __future__ import annotations

import jax

from repro.kernels import impl as impl_mod
from repro.kernels.rmsnorm import kernel, ref


def rmsnorm(x, scale, eps: float = 1e-5, impl: str | None = None):
    impl = impl_mod.resolve(impl)
    if impl == "xla":
        return ref.rmsnorm(x, scale, eps)
    return kernel.rmsnorm(x, scale, eps=eps,
                          interpret=(impl == "pallas_interpret"))
