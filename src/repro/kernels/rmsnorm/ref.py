"""Pure-jnp oracle for fused RMSNorm."""
import jax.numpy as jnp


def rmsnorm(x, scale, eps: float = 1e-5):
    """RMS-normalize the last axis and apply the learned scale.

    Computation in fp32, result cast back to x.dtype (LLaMA convention).
    """
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * (var + eps) ** -0.5
    return (y * scale.astype(jnp.float32)).astype(x.dtype)
