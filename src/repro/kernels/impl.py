"""Kernel implementation selection.

Models call kernel ``ops`` wrappers; the active implementation is resolved
per-call → per-context override → backend default:

* ``"pallas"``           — real TPU lowering (the deployment target),
* ``"pallas_interpret"`` — kernel body interpreted on CPU (tests),
* ``"xla"``              — the pure-jnp reference path (CPU smoke tests and
                           the dry-run/roofline compiles, which target the
                           CPU backend where TPU Pallas cannot lower).
"""
from __future__ import annotations

import contextlib
import threading
import warnings

import jax

_TLS = threading.local()
VALID = ("xla", "pallas", "pallas_interpret")
_WARNED_NO_PALLAS = False


def backend_default() -> str:
    return "pallas" if jax.default_backend() == "tpu" else "xla"


def resolve(impl: str | None = None) -> str:
    if impl is None:
        impl = getattr(_TLS, "impl", None) or backend_default()
    if impl not in VALID:
        raise ValueError(f"unknown kernel impl {impl!r}; expected {VALID}")
    return impl


def resolve_runnable(impl: str | None = None) -> str:
    """:func:`resolve`, then downgrade ``pallas*`` → ``xla`` (one visible
    warning) when the build lacks Pallas — the canary-safe entry point
    for ops wrappers, so a JAX that moved ``jax.experimental.pallas``
    degrades to the reference path instead of breaking imports."""
    from repro import compat
    impl = resolve(impl)
    if impl != "xla" and not compat.pallas_available():
        global _WARNED_NO_PALLAS
        if not _WARNED_NO_PALLAS:
            warnings.warn(
                "jax.experimental.pallas is unavailable on this JAX "
                "build; kernel ops fall back to the 'xla' reference "
                "implementation", RuntimeWarning, stacklevel=3)
            _WARNED_NO_PALLAS = True
        return "xla"
    return impl


@contextlib.contextmanager
def use_impl(impl: str):
    prev = getattr(_TLS, "impl", None)
    _TLS.impl = impl
    try:
        yield
    finally:
        _TLS.impl = prev
