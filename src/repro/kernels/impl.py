"""Kernel implementation selection.

Models call kernel ``ops`` wrappers; the active implementation is resolved
per-call → per-context override → backend default:

* ``"pallas"``           — real TPU lowering (the deployment target),
* ``"pallas_interpret"`` — kernel body interpreted on CPU (tests),
* ``"xla"``              — the pure-jnp reference path (CPU smoke tests and
                           the dry-run/roofline compiles, which target the
                           CPU backend where TPU Pallas cannot lower).
"""
from __future__ import annotations

import contextlib
import threading

import jax

_TLS = threading.local()
VALID = ("xla", "pallas", "pallas_interpret")


def backend_default() -> str:
    return "pallas" if jax.default_backend() == "tpu" else "xla"


def resolve(impl: str | None = None) -> str:
    if impl is None:
        impl = getattr(_TLS, "impl", None) or backend_default()
    if impl not in VALID:
        raise ValueError(f"unknown kernel impl {impl!r}; expected {VALID}")
    return impl


@contextlib.contextmanager
def use_impl(impl: str):
    prev = getattr(_TLS, "impl", None)
    _TLS.impl = impl
    try:
        yield
    finally:
        _TLS.impl = prev
