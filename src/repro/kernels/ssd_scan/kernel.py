"""Pallas TPU kernel for the Mamba-2 SSD chunked scan.

TPU adaptation (DESIGN.md §2): the GPU Mamba-2 kernel leans on warp-level
shuffles for the intra-chunk scan; here the chunk decomposition is recast as
MXU matmuls — the (Q×Q) intra-chunk decay-weighted score matrix, the (Q×N)
state projection and the (P×N) running state are all dense tiles. The
running state lives in fp32 VMEM scratch and is carried across the
*innermost, sequential* chunk axis of the grid, so state passing costs no
HBM traffic.

Grid: (B, H, T/Q) with chunk innermost. Per step the kernel holds
x (Q,P), dt (Q,1), B/C (Q,N), scores (Q,Q) and state (P,N) in VMEM —
≈ 1 MB fp32 at Q=256, P=64, N=128.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, d_ref, y_ref, state_scr,
                *, chunk: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    h = pl.program_id(1)
    x = x_ref[0, 0].astype(jnp.float32)        # (Q, P)
    dt = dt_ref[0, 0].astype(jnp.float32)      # (Q, 1)
    bm = b_ref[0, 0].astype(jnp.float32)       # (Q, N)
    cm = c_ref[0, 0].astype(jnp.float32)       # (Q, N)
    a = a_ref[0]                               # scalar (per head)
    d = d_ref[0]

    dA = dt[:, 0] * a                          # (Q,)
    cum = jnp.cumsum(dA)                       # inclusive
    total = cum[-1]

    # intra-chunk: scores[i,j] = C_i·B_j · exp(cum_i - cum_j) · dt_j, i ≥ j
    cb = jax.lax.dot_general(cm, bm, (((1,), (1,)), ((), ())))   # (Q, Q)
    ii = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    ldiff = cum[:, None] - cum[None, :]
    l_mat = jnp.where(ii >= jj, jnp.exp(ldiff), 0.0)
    scores = cb * l_mat * dt[:, 0][None, :]
    y = jax.lax.dot_general(scores, x, (((1,), (0,)), ((), ())))  # (Q, P)

    # inter-chunk: y += exp(cum_i) · C_i · state_inᵀ
    state_in = state_scr[...]                  # (P, N)
    y += jnp.exp(cum)[:, None] * jax.lax.dot_general(
        cm, state_in, (((1,), (1,)), ((), ())))

    # state update: state' = exp(total)·state + Σ_j dt_j e^{total-cum_j} x_jᵀB_j
    w = (dt[:, 0] * jnp.exp(total - cum))[:, None]               # (Q, 1)
    state_scr[...] = state_in * jnp.exp(total) + jax.lax.dot_general(
        x * w, bm, (((0,), (0,)), ((), ())))                     # (P, N)

    y_ref[0, 0] = (y + x * d).astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x, dt, A, Bm, Cm, D, *, chunk: int = 128,
             interpret: bool = False):
    """Shapes as in ``ref.py``; returns y (B, T, H, P)."""
    B_, T, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    assert T % chunk == 0
    hpg = H // G
    nC = T // chunk

    xt = x.transpose(0, 2, 1, 3)                       # (B, H, T, P)
    dtt = dt.transpose(0, 2, 1)[..., None]             # (B, H, T, 1)
    bt = Bm.transpose(0, 2, 1, 3)                      # (B, G, T, N)
    ct = Cm.transpose(0, 2, 1, 3)

    grid = (B_, H, nC)
    y = pl.pallas_call(
        functools.partial(_ssd_kernel, chunk=chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, chunk, P), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, chunk, 1), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1,), lambda b, h, c: (h,)),
            pl.BlockSpec((1, 1, chunk, N),
                         lambda b, h, c, g=hpg: (b, h // g, c, 0)),
            pl.BlockSpec((1, 1, chunk, N),
                         lambda b, h, c, g=hpg: (b, h // g, c, 0)),
            pl.BlockSpec((1,), lambda b, h, c: (h,)),
        ],
        out_specs=pl.BlockSpec((1, 1, chunk, P), lambda b, h, c: (b, h, c, 0)),
        out_shape=jax.ShapeDtypeStruct((B_, H, T, P), x.dtype),
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=interpret,
    )(xt, dtt, A.astype(jnp.float32), bt, ct, D.astype(jnp.float32))
    return y.transpose(0, 2, 1, 3)
