"""Oracles for the Mamba-2 SSD (state-space duality) scan.

``ssd_sequential``: the exact per-timestep recurrence — the correctness
oracle for both the chunked jnp path and the Pallas kernel.

``ssd_chunked``: the block-decomposed einsum formulation (Mamba-2 paper
§6) used as the "xla" production path: intra-chunk quadratic term +
inter-chunk state passing, all matmul-shaped — this is what the Pallas
kernel mirrors tile-by-tile.

Shapes:
    x  (B, T, H, P)   inputs per head (P = head_dim)
    dt (B, T, H)      positive step sizes (softplus+bias applied upstream)
    A  (H,)           negative per-head decay
    Bm (B, T, G, N)   input projections (G groups broadcast over heads)
    Cm (B, T, G, N)   output projections
    D  (H,)           skip gain
Returns y (B, T, H, P) and the final state (B, H, P, N).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _expand_groups(m: jax.Array, n_heads: int) -> jax.Array:
    """(B, T, G, N) → (B, T, H, N) by repeating groups over their heads."""
    g = m.shape[2]
    return jnp.repeat(m, n_heads // g, axis=2)


def ssd_sequential(x, dt, A, Bm, Cm, D):
    B_, T, H, P = x.shape
    N = Bm.shape[-1]
    Bh = _expand_groups(Bm.astype(jnp.float32), H)
    Ch = _expand_groups(Cm.astype(jnp.float32), H)
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    Af = A.astype(jnp.float32)

    def step(state, inputs):
        xt, dtt, bt, ct = inputs          # (B,H,P), (B,H), (B,H,N), (B,H,N)
        decay = jnp.exp(dtt * Af)         # (B,H)
        inc = jnp.einsum("bh,bhp,bhn->bhpn", dtt, xt, bt)
        state = state * decay[..., None, None] + inc
        yt = jnp.einsum("bhn,bhpn->bhp", ct, state)
        return state, yt

    state0 = jnp.zeros((B_, H, P, N), jnp.float32)
    xs = (xf.transpose(1, 0, 2, 3), dtf.transpose(1, 0, 2),
          Bh.transpose(1, 0, 2, 3), Ch.transpose(1, 0, 2, 3))
    state, ys = jax.lax.scan(step, state0, xs)
    y = ys.transpose(1, 0, 2, 3) + xf * D.astype(jnp.float32)[None, None, :, None]
    return y.astype(x.dtype), state


def ssd_chunked(x, dt, A, Bm, Cm, D, chunk: int = 64):
    B_, T, H, P = x.shape
    assert T % chunk == 0, "sequence length must be divisible by chunk"
    nC = T // chunk
    Bh = _expand_groups(Bm.astype(jnp.float32), H)
    Ch = _expand_groups(Cm.astype(jnp.float32), H)
    xf = x.astype(jnp.float32).reshape(B_, nC, chunk, H, P)
    dtf = dt.astype(jnp.float32).reshape(B_, nC, chunk, H)
    Bh = Bh.reshape(B_, nC, chunk, H, -1)
    Ch = Ch.reshape(B_, nC, chunk, H, -1)
    Af = A.astype(jnp.float32)

    dA = dtf * Af                                  # (B,C,Q,H) log-decay
    cum = jnp.cumsum(dA, axis=2)                   # inclusive within chunk
    total = cum[:, :, -1, :]                       # (B,C,H)

    # intra-chunk: L[i,j] = exp(cum_i - cum_j) for i ≥ j (decay j+1..i)
    Ldiff = cum[:, :, :, None, :] - cum[:, :, None, :, :]   # (B,C,Qi,Qj,H)
    tri = jnp.tril(jnp.ones((chunk, chunk), bool))
    L = jnp.where(tri[None, None, :, :, None], jnp.exp(Ldiff), 0.0)
    scores = jnp.einsum("bcihn,bcjhn->bcijh", Ch, Bh) * L \
        * dtf[:, :, None, :, :]                    # dt_j on the j axis
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", scores, xf)

    # chunk states: contributions decayed to the chunk end
    decay_to_end = jnp.exp(total[:, :, None, :] - cum)      # (B,C,Q,H)
    S = jnp.einsum("bcqh,bcqhp,bcqhn->bchpn", dtf * decay_to_end, xf, Bh)

    # inter-chunk scan: running state entering each chunk
    def chunk_step(state, inputs):
        s_c, tot_c = inputs
        new = state * jnp.exp(tot_c)[..., None, None] + s_c
        return new, state                          # emit state *entering* c

    state0 = jnp.zeros((B_, H, P, jnp.shape(Bh)[-1]), jnp.float32)
    final, entering = jax.lax.scan(
        chunk_step, state0,
        (S.transpose(1, 0, 2, 3, 4), total.transpose(1, 0, 2)))
    entering = entering.transpose(1, 0, 2, 3, 4)   # (B,C,H,P,N)

    y_inter = jnp.einsum("bcqhn,bchpn->bcqhp", Ch * jnp.exp(cum)[..., None],
                         entering)
    y = (y_intra + y_inter).reshape(B_, T, H, P) \
        + x.astype(jnp.float32) * D.astype(jnp.float32)[None, None, :, None]
    return y.astype(x.dtype), final
