"""Dispatching wrapper for the SSD scan: xla (chunked jnp) | pallas."""
from __future__ import annotations

from repro.kernels import impl as impl_mod
from repro.kernels.ssd_scan import kernel, ref


def ssd_scan(x, dt, A, Bm, Cm, D, *, chunk: int = 128,
             impl: str | None = None):
    """Returns y (B, T, H, P). Final-state output only on the xla path
    (training starts from zero state; decode uses the explicit recurrence)."""
    impl = impl_mod.resolve(impl)
    if impl == "xla":
        y, _ = ref.ssd_chunked(x, dt, A, Bm, Cm, D, chunk=chunk)
        return y
    return kernel.ssd_scan(x, dt, A, Bm, Cm, D, chunk=chunk,
                           interpret=(impl == "pallas_interpret"))
