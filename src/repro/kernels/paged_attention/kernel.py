"""Fused Pallas paged-attention decode kernel: gather + attend + write.

ONE kernel walks each slot's page table on device and does everything the
unfused serve path needed three stages for:

1. **gather** — the page table rides in as a *scalar-prefetch* operand
   (``pltpu.PrefetchScalarGridSpec``), so the k/v pool BlockSpec index
   maps read ``tables[s, p]`` directly and the pipeline streams exactly
   the slot's pages HBM→VMEM; no ``(S, T*ps, KV, hd)`` contiguous view is
   ever materialized.
2. **attend** — flash-style online softmax over the page axis (innermost
   grid dimension); the (m, l, acc) state lives in VMEM scratch that
   persists across pages of the same (slot, kv-head). Causal masking is
   positional (``kpos <= qpos``), so null-page garbage in table tails and
   stale speculative rows are never attended.
3. **accept-masked KV write** — the ``1 + K`` window's new KV rows are
   inserted into the loaded page in-register (rows ``j < n_valid`` whose
   position falls inside the page) and every gathered page is written
   back through an output aliased onto the pool (``input_output_aliases``
   → in-place update, donated by the serve steps). The *gather* table
   doubles as the write map: row ``j`` lands in entry ``(pos+j) //
   page_size``, which the slot owns inside its footprint and which is the
   scratch page past it — reproducing ``PagePool.write_table``'s
   rollback semantics with no host-built write tables at all.

The query window ``W`` generalizes the kernel over every serve step
shape: ``W=1`` is plain decode, ``W=1+K`` is the speculative verify
window (``n_valid = 1 + k_live`` accept-masks the live draft count), and
``W=page-padded tail`` with ``S=1`` is the chunked suffix prefill for a
prefix-cache hit.

On-device page-table memory layout (pinned contract, shared with
``ref.py`` and ``serve.kv_cache.PagePool``):

* pool (one layer): ``(total_pages + 1, page_size, KV, head_dim)`` —
  page index ``total_pages`` is the scratch ("null") page; table padding
  points at it so idle slots and table tails read/write garbage there.
* ``tables (S, T)`` int32 — entry ``p`` holds the pool page owning
  absolute token positions ``[p*page_size, (p+1)*page_size)``.
* ``positions (S,)`` int32 — absolute position of window row 0.
* ``n_valid (S,)`` int32 — rows actually written (0 = idle slot).

TPU shaping notes: blocks are one page × one kv head × head_dim, with
``W*G`` query rows per grid step; at production sizes pick page_size and
head_dim as multiples of the (8, 128) tile. Every visited page is
re-written (read-modify-write through the alias), trading one page of
write bandwidth per gathered page for the one-kernel structure; a
write-window-only output spec is the follow-up optimization.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.compat import pl, pltpu, require_pallas

NEG_INF = -1e30


def _paged_kernel(tab_ref, pos_ref, nv_ref,          # scalar prefetch
                  q_ref, kn_ref, vn_ref, kp_ref, vp_ref,
                  o_ref, ko_ref, vo_ref,
                  m_scr, l_scr, acc_scr, *,
                  scale: float, page_size: int, window: int, groups: int):
    s = pl.program_id(0)
    p = pl.program_id(2)
    n_p = pl.num_programs(2)
    pos = pos_ref[s]
    nv = nv_ref[s]
    W, ps, G = window, page_size, groups
    page_start = p * ps        # absolute position of the page's first row

    @pl.when(p == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    k = kp_ref[0, :, 0, :]                               # (ps, hd)
    v = vp_ref[0, :, 0, :]
    kn = kn_ref[0, 0]                                    # (W, hd)
    vn = vn_ref[0, 0]

    # accept-masked in-register KV insert: window row j (absolute position
    # pos + j) lands at page offset pos + j - page_start when that offset
    # is inside this page AND j < n_valid. One-hot contraction keeps the
    # select vectorized (TPU wants 2D iota).
    jj = jax.lax.broadcasted_iota(jnp.int32, (W, ps), 0)
    tt = jax.lax.broadcasted_iota(jnp.int32, (W, ps), 1)
    oh = ((pos + jj - page_start) == tt) & (jj < nv)     # (W, ps)
    hit = oh.any(axis=0)                                 # (ps,)
    ohf = oh.astype(kn.dtype)
    dot_tw = (((0,), (0,)), ((), ()))                    # contract j axis
    k = jnp.where(hit[:, None], jax.lax.dot_general(ohf, kn, dot_tw), k)
    v = jnp.where(hit[:, None], jax.lax.dot_general(ohf, vn, dot_tw), v)

    # unconditional writeback: the output block aliases the pool, so
    # untouched pages round-trip their own content and inserted rows land
    # in place (identical stores for pages shared across slots; the null
    # page collects garbage by contract)
    ko_ref[0, :, 0, :] = k
    vo_ref[0, :, 0, :] = v

    def _attend():
        qf = q_ref[0, 0].reshape(W * G, -1).astype(jnp.float32)
        kf = k.astype(jnp.float32)
        sc = jax.lax.dot_general(
            qf, kf, (((1,), (1,)), ((), ()))) * scale    # (W*G, ps)
        # row r is query window row r // G; causal by absolute position,
        # horizon clamped to the last written row (ref.py pins the same
        # clamp: padding rows never read unwritten/null-page positions)
        wrow = jax.lax.broadcasted_iota(jnp.int32, sc.shape, 0) // G
        qpos = pos + jnp.minimum(wrow, nv - 1)
        kpos = page_start + jax.lax.broadcasted_iota(jnp.int32, sc.shape, 1)
        sc = jnp.where(kpos <= qpos, sc, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(sc, axis=-1, keepdims=True))
        pe = jnp.exp(sc - m_new)
        pe = jnp.where(m_new <= NEG_INF, 0.0, pe)        # fully-masked rows
        alpha = jnp.exp(m_prev - m_new)
        alpha = jnp.where(m_prev <= NEG_INF, 0.0, alpha)
        l_scr[...] = l_scr[...] * alpha + jnp.sum(pe, axis=-1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            pe, v.astype(jnp.float32), (((1,), (0,)), ((), ())))
        m_scr[...] = m_new

    # idle slots (nv == 0) and pages entirely in the future (null-padded
    # table tails) contribute nothing: skip the matmul/softmax work (the
    # zero-initialized scratch yields a zero output), keep the writeback
    pl.when((nv > 0) & (page_start <= pos + W - 1))(_attend)

    @pl.when(p == n_p - 1)
    def _finish():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l).reshape(W, G, -1).astype(o_ref.dtype)


def paged_attention(q: jax.Array, k_new: jax.Array, v_new: jax.Array,
                    k_pages: jax.Array, v_pages: jax.Array,
                    tables: jax.Array, positions: jax.Array,
                    n_valid: jax.Array, *, page_size: int,
                    scale: float | None = None, interpret: bool = False
                    ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Shapes as in ``ref.paged_attention``; returns (out, new_k, new_v)
    with the new pool arrays aliased in place over the inputs."""
    require_pallas()
    S, W, H, hd = q.shape
    P1, ps, KV, _ = k_pages.shape
    assert ps == page_size, (ps, page_size)
    T = tables.shape[1]
    G = H // KV
    scale = hd ** -0.5 if scale is None else scale

    # head-major layouts so one (slot, kv-head) grid step owns one block
    qt = q.reshape(S, W, KV, G, hd).transpose(0, 2, 1, 3, 4)
    knt = k_new.transpose(0, 2, 1, 3)                    # (S, KV, W, hd)
    vnt = v_new.transpose(0, 2, 1, 3)

    def _page_map(s, h, p, tab, pos, nv):
        return (tab[s, p], 0, h, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(S, KV, T),
        in_specs=[
            pl.BlockSpec((1, 1, W, G, hd),
                         lambda s, h, p, tab, pos, nv: (s, h, 0, 0, 0)),
            pl.BlockSpec((1, 1, W, hd),
                         lambda s, h, p, tab, pos, nv: (s, h, 0, 0)),
            pl.BlockSpec((1, 1, W, hd),
                         lambda s, h, p, tab, pos, nv: (s, h, 0, 0)),
            pl.BlockSpec((1, ps, 1, hd), _page_map),
            pl.BlockSpec((1, ps, 1, hd), _page_map),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, W, G, hd),
                         lambda s, h, p, tab, pos, nv: (s, h, 0, 0, 0)),
            pl.BlockSpec((1, ps, 1, hd), _page_map),
            pl.BlockSpec((1, ps, 1, hd), _page_map),
        ],
        scratch_shapes=[
            pltpu.VMEM((W * G, 1), jnp.float32),
            pltpu.VMEM((W * G, 1), jnp.float32),
            pltpu.VMEM((W * G, hd), jnp.float32),
        ],
    )
    o, nk, nv_out = pl.pallas_call(
        functools.partial(_paged_kernel, scale=scale, page_size=ps,
                          window=W, groups=G),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((S, KV, W, G, hd), q.dtype),
            jax.ShapeDtypeStruct(k_pages.shape, k_pages.dtype),
            jax.ShapeDtypeStruct(v_pages.shape, v_pages.dtype),
        ],
        # pool arrays update in place (operand index counts the 3 scalar-
        # prefetch args: k_pages is operand 6, v_pages operand 7)
        input_output_aliases={6: 1, 7: 2},
        interpret=interpret,
    )(tables, positions, n_valid, qt, knt, vnt, k_pages, v_pages)
    return o.transpose(0, 2, 1, 3, 4).reshape(S, W, H, hd), nk, nv_out
