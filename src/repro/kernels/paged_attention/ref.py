"""Pure-jnp reference for fused paged attention (scatter → gather → attend).

Semantically identical to the Pallas kernel and numerically identical to
the unfused serve path (``steps._gather_pages`` + ``decode_attention``):
new KV rows are scattered into the pool first, the page tables gather a
contiguous per-slot view, and grouped-einsum GQA attention runs over it
with per-query-row causal masking by absolute position — fp32 scores,
``-1e30`` mask value, softmax in fp32 cast back to the compute dtype.

On-device page-table layout (the contract `kernel.py` pins too):

* pool (one layer): ``(total_pages + 1, page_size, KV, head_dim)``;
  index ``total_pages`` is the scratch ("null") page.
* ``tables (S, T)``: entry ``p`` of a slot's row is the pool page holding
  absolute positions ``[p*page_size, (p+1)*page_size)``; entries past the
  slot's footprint are the null page.
* ``positions (S,)``: absolute position of window row 0 per slot.
* ``n_valid (S,)``: window rows actually WRITTEN per slot — 0 for idle
  slots, 1 for plain decode, ``1 + k_live`` for a verify window, the real
  tail length for suffix prefill. Rows past ``n_valid`` land in the
  scratch page (accept-masked write / rollback).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def paged_attention(q: jax.Array, k_new: jax.Array, v_new: jax.Array,
                    k_pages: jax.Array, v_pages: jax.Array,
                    tables: jax.Array, positions: jax.Array,
                    n_valid: jax.Array, *, page_size: int,
                    scale: float | None = None
                    ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """q: (S, W, H, hd); k_new/v_new: (S, W, KV, hd);
    k_pages/v_pages: (P+1, ps, KV, hd); tables: (S, T) int32;
    positions/n_valid: (S,) int32.

    Returns ``(out (S, W, H, hd), new_k_pages, new_v_pages)``.
    """
    S, W, H, hd = q.shape
    P1, ps, KV, _ = k_pages.shape
    T = tables.shape[1]
    G = H // KV
    null = P1 - 1
    scale = hd ** -0.5 if scale is None else scale

    # ---- accept-masked scatter of the new KV rows into the pool.
    # Window row j of slot s holds absolute position pos_s + j; its write
    # target is the table entry owning that position. Rows past n_valid
    # are redirected to the scratch page (collisions there are garbage by
    # contract), so rejected/padded rows can never touch a real page.
    offs = jnp.arange(W, dtype=jnp.int32)
    pos_j = positions[:, None] + offs[None, :]              # (S, W)
    entry = jnp.clip(pos_j // ps, 0, T - 1)
    page = jnp.take_along_axis(tables, entry, axis=1)       # (S, W)
    valid = offs[None, :] < n_valid[:, None]
    page = jnp.where(valid, page, null)
    row = (page * ps + pos_j % ps).reshape(-1)              # flat pool row
    new_k = k_pages.reshape(P1 * ps, KV, hd).at[row].set(
        k_new.reshape(S * W, KV, hd)).reshape(P1, ps, KV, hd)
    new_v = v_pages.reshape(P1 * ps, KV, hd).at[row].set(
        v_new.reshape(S * W, KV, hd)).reshape(P1, ps, KV, hd)

    # ---- gather each slot's contiguous view and attend (grouped GQA,
    # exactly decode_attention's math on the gathered cache)
    gk = new_k[tables].reshape(S, T * ps, KV, hd)
    gv = new_v[tables].reshape(S, T * ps, KV, hd)
    qg = q.reshape(S, W, KV, G, hd)
    scores = jnp.einsum("swkgd,stkd->skgwt", qg, gk).astype(jnp.float32) \
        * scale
    idx = jnp.arange(T * ps, dtype=jnp.int32)
    # causal horizon clamped to the last WRITTEN position: rows past
    # n_valid attend as if they were row n_valid - 1, so no row ever
    # reads unwritten positions (which only null-page entries cover);
    # idle slots (n_valid == 0) are fully masked and output zeros —
    # the kernel pins the same clamps, making padding rows deterministic
    qpos = jnp.where(n_valid[:, None] > 0,
                     positions[:, None] + jnp.minimum(offs, n_valid[:, None] - 1),
                     -1)
    mask = idx[None, None, :] <= qpos[:, :, None]           # (S, W, T*ps)
    scores = jnp.where(mask[:, None, None, :, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    alive = mask.any(axis=-1)                               # (S, W)
    probs = jnp.where(alive[:, None, None, :, None], probs, 0.0)
    o = jnp.einsum("skgwt,stkd->swkgd", probs, gv)
    return o.reshape(S, W, H, hd), new_k, new_v
