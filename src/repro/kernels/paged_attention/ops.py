"""Dispatching wrapper for fused paged attention: xla | pallas | interpret.

Same canary-safe structure as ``flash_attention.ops``: the Pallas kernel
module is only imported after :func:`repro.kernels.impl.resolve_runnable`
confirms the build has ``jax.experimental.pallas``; otherwise the call
runs the pure-jnp reference (identical semantics, including the in-pool
scatter), with the one-time downgrade warning.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax

from repro.kernels import impl as impl_mod
from repro.kernels.paged_attention import ref


def paged_attention(q: jax.Array, k_new: jax.Array, v_new: jax.Array,
                    k_pages: jax.Array, v_pages: jax.Array,
                    tables: jax.Array, positions: jax.Array,
                    n_valid: jax.Array, *, page_size: int,
                    scale: Optional[float] = None,
                    impl: str | None = None
                    ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Fused gather→attend→write over the paged KV pool.

    Shapes: q ``(S, W, H, hd)``, k_new/v_new ``(S, W, KV, hd)``,
    k_pages/v_pages ``(P+1, ps, KV, hd)``, tables ``(S, T)`` int32,
    positions/n_valid ``(S,)`` int32. Returns
    ``(out (S, W, H, hd), new_k_pages, new_v_pages)``.
    """
    impl = impl_mod.resolve_runnable(impl)
    if impl == "xla":
        return ref.paged_attention(
            q, k_new, v_new, k_pages, v_pages, tables, positions, n_valid,
            page_size=page_size, scale=scale)
    from repro.kernels.paged_attention import kernel
    return kernel.paged_attention(
        q, k_new, v_new, k_pages, v_pages, tables, positions, n_valid,
        page_size=page_size, scale=scale,
        interpret=(impl == "pallas_interpret"))
