"""int8 error-feedback gradient compression for data-parallel sync.

Distributed-optimization trick (DESIGN.md §5): on bandwidth-constrained
cross-pod links, gradients are quantized to int8 with a per-tensor scale
before the data-parallel mean; quantization error is carried in a local
*error-feedback* buffer (Seide et al. 1-bit SGD / EF-SGD lineage) so the
bias vanishes over steps instead of accumulating.

Implemented with ``shard_map`` + explicit ``psum`` — the DDP-style trainer
(examples/train_small) uses it on the ``data`` axis; the FSDP pjit path
keeps XLA-fused reduce-scatters (compression there would break the fusion;
measured trade-off discussed in EXPERIMENTS.md §Perf).

Wire cost: 1 byte/grad element + 4 bytes/tensor scale vs 2–4 bytes/element
uncompressed → ≥2× cross-pod traffic reduction at bf16, 4× at fp32.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

CompressionState = Dict[str, Any]   # error-feedback buffers, like grads


def init_compression_state(grads_like) -> CompressionState:
    return jax.tree_util.tree_map(
        lambda g: jnp.zeros(g.shape, jnp.float32), grads_like)


def _quantize(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def compressed_psum_mean(grads, err_state: CompressionState, axis_name: str
                         ) -> Tuple[Any, CompressionState]:
    """Mean-reduce ``grads`` over ``axis_name`` with int8 + error feedback.

    Must run inside ``shard_map``/``pmap`` where ``axis_name`` is bound.
    Returns (mean gradients fp32, new error-feedback state).
    """
    n = jax.lax.psum(1, axis_name)

    def one(g, err):
        gf = g.astype(jnp.float32) + err
        q, scale = _quantize(gf)
        # int8 payload summed in int32 (no overflow below ~2^23 members);
        # per-shard scales averaged alongside (4 bytes per tensor).
        qsum = jax.lax.psum(q.astype(jnp.int32), axis_name)
        avg_scale = jax.lax.psum(scale, axis_name) / n
        mean = qsum.astype(jnp.float32) * avg_scale / n
        # residual vs the value effectively transmitted (avg scale), so the
        # feedback buffer also absorbs cross-shard scale mismatch
        new_err = gf - q.astype(jnp.float32) * avg_scale
        return mean, new_err

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_e = treedef.flatten_up_to(err_state)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    means = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    errs = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    return means, errs
