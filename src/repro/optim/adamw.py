"""AdamW with FSDP-sharded state (m, v stored like the weights).

``state_dtype`` trades optimizer-memory for fidelity: fp32 default; the
405B config drops to bf16 state so a single pod remains within reach
(memory accounting reported per-cell in EXPERIMENTS.md §Dry-run).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    state_dtype: Any = jnp.float32


def adamw_init(params, opt_cfg: OptConfig) -> Dict[str, Any]:
    zeros = lambda p: jnp.zeros(p.shape, opt_cfg.state_dtype)
    return {
        "m": jax.tree_util.tree_map(zeros, params),
        "v": jax.tree_util.tree_map(zeros, params),
        "count": jnp.zeros((), jnp.int32),
    }


def opt_state_specs(param_spec_tree) -> Dict[str, Any]:
    """m and v shard exactly like the weights; count is replicated."""
    return {"m": param_spec_tree, "v": param_spec_tree, "count": ()}


def adamw_update(grads, opt_state, params, lr, opt_cfg: OptConfig):
    count = opt_state["count"] + 1
    b1, b2 = opt_cfg.b1, opt_cfg.b2
    c1 = 1.0 - b1 ** count.astype(jnp.float32)
    c2 = 1.0 - b2 ** count.astype(jnp.float32)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32)
        mf = m.astype(jnp.float32) * b1 + (1 - b1) * g
        vf = v.astype(jnp.float32) * b2 + (1 - b2) * g * g
        step = (mf / c1) / (jnp.sqrt(vf / c2) + opt_cfg.eps)
        step = step + opt_cfg.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * step).astype(p.dtype)
        return new_p, mf.astype(m.dtype), vf.astype(v.dtype)

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(opt_state["m"])
    flat_v = treedef.flatten_up_to(opt_state["v"])
    out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_params = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree_util.tree_unflatten(treedef, [o[2] for o in out])
    return new_params, {"m": new_m, "v": new_v, "count": count}
