from repro.optim.adamw import (adamw_init, adamw_update, opt_state_specs,
                               OptConfig)
from repro.optim.schedules import warmup_cosine, constant
from repro.optim.clip import clip_by_global_norm, global_norm
from repro.optim.compress import (compressed_psum_mean, CompressionState,
                                  init_compression_state)

__all__ = ["adamw_init", "adamw_update", "opt_state_specs", "OptConfig",
           "warmup_cosine", "constant", "clip_by_global_norm", "global_norm",
           "compressed_psum_mean", "CompressionState",
           "init_compression_state"]
