"""Version-compat shims for JAX API drift.

The repo runs on whatever JAX build the image bakes in; these helpers
paper over the API moves between the 0.4.x line and newer releases so
the same source works on both:

* ``shard_map`` graduated from ``jax.experimental.shard_map`` to
  ``jax.shard_map``, and its replication-check kwarg was renamed
  ``check_rep`` → ``check_vma`` along the way.
* ``jax.sharding.AxisType`` (explicit mesh axis types) does not exist on
  older builds, where ``jax.make_mesh`` also rejects an ``axis_types``
  kwarg; meshes there are implicitly Auto on every axis, which is the
  behaviour we want anyway.
* Pallas: ``jax.experimental.pallas`` (and its TPU dialect) is the one
  import the canary CI leg can break silently — experimental namespaces
  move without deprecation cycles. Kernel modules import ``pl``/``pltpu``
  from here instead of from ``jax.experimental`` directly, and the ops
  wrappers consult :func:`pallas_available` so a pallas-less build
  degrades to the ``xla`` reference path with a visible warning instead
  of an ImportError at collection time.
"""
from __future__ import annotations

import inspect
from typing import Any

import jax

try:  # new-style top-level export
    from jax import shard_map as _shard_map_impl
except ImportError:  # pinned 0.4.x line
    from jax.experimental.shard_map import shard_map as _shard_map_impl

_SHARD_MAP_PARAMS = frozenset(
    inspect.signature(_shard_map_impl).parameters)


def shard_map(f, mesh=None, in_specs=None, out_specs=None, *,
              check_vma: bool = True, **kwargs: Any):
    """``shard_map`` accepting the ``check_vma`` spelling on every JAX."""
    if "check_vma" in _SHARD_MAP_PARAMS:
        kwargs["check_vma"] = check_vma
    elif "check_rep" in _SHARD_MAP_PARAMS:
        kwargs["check_rep"] = check_vma
    return _shard_map_impl(f, mesh=mesh, in_specs=in_specs,
                           out_specs=out_specs, **kwargs)


def cost_analysis(compiled) -> dict:
    """Flat cost-analysis dict on every JAX build.

    Older builds return a one-element list of per-program dicts from
    ``Compiled.cost_analysis()``; newer ones return the dict directly.
    """
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return ca


try:  # canary-sensitive: experimental namespaces move without notice
    from jax.experimental import pallas as pl
except ImportError:  # pragma: no cover - exercised only on broken canaries
    pl = None
try:
    from jax.experimental.pallas import tpu as pltpu
except ImportError:  # pragma: no cover
    pltpu = None


def pallas_available() -> bool:
    """True when both ``pallas`` and its TPU dialect import cleanly."""
    return pl is not None and pltpu is not None


def require_pallas():
    """Return ``(pl, pltpu)`` or raise a targeted ImportError.

    Kernel entry points call this at trace time so a pallas-less build
    fails with an actionable message (use the ``xla`` impl) instead of an
    AttributeError on a ``None`` module.
    """
    if not pallas_available():
        raise ImportError(
            "jax.experimental.pallas(.tpu) is unavailable on this JAX "
            "build; select the 'xla' kernel impl "
            "(repro.kernels.impl.use_impl) or pin a JAX with Pallas")
    return pl, pltpu


def make_mesh(shape, axes, **kwargs: Any):
    """``jax.make_mesh`` that passes Auto ``axis_types`` only where the
    build supports them (older builds are implicitly Auto)."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        kwargs.setdefault("axis_types", (axis_type.Auto,) * len(axes))
    return jax.make_mesh(shape, axes, **kwargs)
