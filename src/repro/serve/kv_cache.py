"""Paged KV-cache block pool with prefix reuse.

Dense continuous batching still pads memory: every slot pre-allocates a
full ``max_cache_len`` KV cache, so batch capacity is bounded by the
worst-case sequence, not the actual ones (the same padding waste the
paper's §5.3 burst handling removes from the *scheduling* side). The
``PagePool`` removes it from the *memory* side:

* **block pool** — one device allocation of ``total_pages`` fixed-size
  pages per layer (``k``/``v``: ``(n_layers, total_pages + 1, page_size,
  kv_heads, head_dim)``); a request only holds pages proportional to its
  sequence, so the pool oversubscribes slots the way rtp-llm's block
  cache manager does. Index ``total_pages`` is a scratch ("null") page:
  page-table padding points at it, so idle slots and table tails
  read/write garbage there instead of needing dynamic shapes.
* **free-list allocation** — host-side free list + per-page refcounts.
  Allocation is worst-case at admission (``ceil((prompt + max_new) /
  page_size)`` pages), so decode never allocates mid-flight and can
  never OOM; capacity-deferred requests are requeued at the head of the
  batcher queue.
* **prefix reuse** — full prompt pages are content-hashed (the page's
  token prefix, chained from position 0). A new request whose prompt
  starts with an already-resident prefix maps those pages read-only
  (refcount++) and skips re-prefilling them. Sharing is restricted to
  *full, immutable* pages — the partially-filled tail page is always
  private — so the copy-on-write policy degenerates to "never write a
  shared page": every write (suffix prefill and decode both append at
  positions past the shared prefix) lands in pages the request owns.

All mutation happens on the engine loop thread (single-consumer, like
the slot state it feeds); no locking is needed here.
"""
from __future__ import annotations

import hashlib
import warnings
from functools import partial
from typing import Any, Dict, FrozenSet, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.common import DENSE, MOE, ModelConfig


def prefix_keys(tokens: Any, page_size: int,
                n_pages: Optional[int] = None) -> List[bytes]:
    """Chained per-page prefix digests of a token sequence — the content
    identity the prefix cache (and the router's affinity map) keys on.

    Key ``i`` hashes the sequence's first ``(i+1) * page_size`` tokens via
    one running sha256 — O(tokens), not O(tokens^2), and
    content-equivalent to hashing each prefix from scratch. Pure
    computation: needs no pool (the multi-replica router hashes prompts
    with it to find which replica already holds the pages), and two
    callers with the same ``page_size`` always derive the same keys for
    the same tokens.

    ``n_pages`` defaults to every *full* page of the sequence
    (``len(tokens) // page_size``).
    """
    toks = np.asarray(tokens, np.int32).reshape(-1)
    if n_pages is None:
        n_pages = len(toks) // int(page_size)
    keys: List[bytes] = []
    h = hashlib.sha256()
    for i in range(n_pages):
        h.update(toks[i * page_size:(i + 1) * page_size].tobytes())
        keys.append(h.digest())
    return keys


@jax.jit
def _page_slice(arr: jax.Array, page: jax.Array) -> jax.Array:
    """One page's KV out of a pool array: (L, P+1, ps, KV, hd) →
    (L, ps, KV, hd). ``page`` is traced, so every page id shares one
    compilation."""
    return jax.lax.dynamic_index_in_dim(arr, page, axis=1, keepdims=False)


@partial(jax.jit, donate_argnums=(0,))
def _page_install(arr: jax.Array, page: jax.Array,
                  data: jax.Array) -> jax.Array:
    """Install one page of KV into a (donated) pool array at a traced
    page index — the ingestion half of remote page shipping."""
    return jax.lax.dynamic_update_slice_in_dim(arr, data[:, None], page,
                                               axis=1)


def paged_supported(cfg: ModelConfig) -> bool:
    """Paged caching targets stacked full-attention KV caches: dense/MoE
    decoders with ``scan_layers`` and no sliding window (a SWA ring
    buffer re-keys slots by ``pos % window``, which a page table does not
    model; SSM/hybrid state is O(1) per slot and gains nothing)."""
    return (cfg.family in (DENSE, MOE) and cfg.scan_layers
            and not cfg.window)


def pages_for(n_tokens: int, page_size: int) -> int:
    return -(-int(n_tokens) // int(page_size))


class PagePool:
    """Fixed-size KV page pool: free-list + refcounts + prefix index.

    The device arrays live in ``arrays`` (``{"k", "v"}``, page axis 1)
    and are created lazily so constructing an engine never touches the
    device; the jitted steps donate them back and forth. This object
    owns only the host-side bookkeeping.
    """

    def __init__(self, cfg: ModelConfig, total_pages: int,
                 page_size: int) -> None:
        if not paged_supported(cfg):
            raise ValueError(
                f"paged KV cache unsupported for family={cfg.family!r} "
                f"(scan_layers={cfg.scan_layers}, window={cfg.window})")
        self.cfg = cfg
        self.total_pages = int(total_pages)
        self.page_size = int(page_size)
        self.null_page = self.total_pages      # scratch page, never owned
        self._free: List[int] = list(range(self.total_pages))
        self._ref = np.zeros(self.total_pages, np.int32)
        # prefix index: hash of the prompt's first (i+1)*page_size tokens
        # -> resident page holding page i of that prefix
        self._prefix: Dict[bytes, int] = {}
        self._page_key: Dict[int, bytes] = {}
        self.arrays: Optional[Dict[str, Any]] = None
        self.stats = {"allocated": 0, "released": 0, "prefix_hits": 0,
                      "prefix_tokens_reused": 0, "peak_in_use": 0,
                      "pages_exported": 0, "pages_imported": 0}

    # ------------------------------------------------------------- arrays
    def ensure_arrays(self) -> None:
        if self.arrays is not None:
            return
        cfg = self.cfg
        shape = (cfg.n_layers, self.total_pages + 1, self.page_size,
                 cfg.padded_kv_heads, cfg.resolved_head_dim)
        self.arrays = {"k": jnp.zeros(shape, cfg.dtype),
                       "v": jnp.zeros(shape, cfg.dtype)}

    @property
    def page_nbytes(self) -> int:
        """Wire size of one exported page (all layers, k + v)."""
        cfg = self.cfg
        itemsize = jnp.dtype(cfg.dtype).itemsize
        return (2 * cfg.n_layers * self.page_size * cfg.padded_kv_heads
                * cfg.resolved_head_dim * itemsize)

    # ------------------------------------------------- remote page shipping
    def export_page(self, page: int) -> Dict[str, Any]:
        """Copy one resident page out of the pool: ``{"k", "v"}`` device
        arrays of shape ``(n_layers, page_size, kv_heads, head_dim)``.

        The slices are fresh buffers ordered after every write already
        dispatched against the pool (jax data dependency), so a prefill
        role can ship them over a transport — and later release the page
        — without synchronizing with in-flight device work."""
        self.ensure_arrays()
        self.stats["pages_exported"] += 1
        page_idx = jnp.int32(page)
        return {k: _page_slice(a, page_idx) for k, a in self.arrays.items()}

    def import_page(self, page: int, data: Dict[str, Any]) -> None:
        """Install shipped KV into an owned page (the ingestion side of
        ``export_page``). Dispatches asynchronously; any step reading the
        pool arrays afterwards is ordered behind the install by data
        dependency, so callers may seat the request immediately."""
        self.ensure_arrays()
        page_idx = jnp.int32(page)
        for k in self.arrays:
            self.arrays[k] = _page_install(self.arrays[k], page_idx,
                                           data[k])
        self.stats["pages_imported"] += 1

    # ---------------------------------------------------------- free list
    @property
    def pages_in_use(self) -> int:
        return self.total_pages - len(self._free)

    def alloc(self, n: int) -> Optional[List[int]]:
        """Pop ``n`` pages (refcount 1 each), or None if the pool can't
        cover them — the caller defers the request, never partial-allocs."""
        if n > len(self._free):
            return None
        pages = [self._free.pop() for _ in range(n)]
        for p in pages:
            self._ref[p] = 1
        self.stats["allocated"] += n
        self.stats["peak_in_use"] = max(self.stats["peak_in_use"],
                                        self.pages_in_use)
        return pages

    def retain(self, page: int) -> None:
        assert self._ref[page] > 0, "retain of a free page"
        self._ref[page] += 1

    def release(self, pages: Sequence[int]) -> None:
        """Drop one reference per page; refcount-0 pages return to the
        free list and fall out of the prefix index."""
        for p in pages:
            self._ref[p] -= 1
            if self._ref[p] == 0:
                key = self._page_key.pop(p, None)
                if key is not None:
                    self._prefix.pop(key, None)
                self._free.append(p)
                self.stats["released"] += 1

    # ------------------------------------------------- speculative writes
    def write_table(self, page_ids: Sequence[int], pos: int,
                    width: int) -> np.ndarray:
        """Scatter targets for a verify step whose write window starts at
        ``pos`` and spans ``width`` pages (``1 + ceil(K / page_size)``
        for a K-draft verify): entry ``j`` receives the page holding
        positions ``(pos // page_size + j) * page_size ...``; entries
        past the request's reserved footprint map to the scratch page.

        This is the rollback half of speculative page writes: a request
        reserves ``ceil((prompt + max_new) / page_size)`` pages at
        admission, but a verify step may write up to ``n_draft``
        positions past the token budget (padded draft slots of a batch
        member that is nearly finished). Nulling those entries sends the
        out-of-footprint KV to the scratch page, so rejected tails can
        never land in — or leak — a real page; rejected writes *inside*
        the footprint are rolled back positionally (the engine advances
        ``pos`` only by the accepted run, and the next verify step
        overwrites them before the causal mask can expose them).
        """
        out = np.full(width, self.null_page, np.int32)
        first = int(pos) // self.page_size
        for j in range(width):
            if first + j < len(page_ids):
                out[j] = page_ids[first + j]
        return out

    # -------------------------------------------------------- prefix reuse
    def prefix_keys(self, tokens: Any,
                    n_pages: Optional[int] = None) -> List[bytes]:
        """Chained per-page prefix digests at this pool's ``page_size``
        (see the module-level :func:`prefix_keys`). Pure hash
        computation — touches no pool state."""
        return prefix_keys(tokens, self.page_size, n_pages)

    def _prefix_keys(self, prompt: Any, n_pages: int) -> List[bytes]:
        warnings.warn(
            "PagePool._prefix_keys is deprecated; use the public "
            "PagePool.prefix_keys (or serve.kv_cache.prefix_keys)",
            DeprecationWarning, stacklevel=2)
        return self.prefix_keys(prompt, n_pages)

    def match_prefix(self, prompt: Any) -> List[int]:
        """Longest chain of resident pages covering a page-aligned prompt
        prefix. Capped at ``len(prompt) - 1`` tokens so at least the last
        prompt token is always re-run — its logits produce the first
        generated token. Does NOT retain; the caller retains only once
        the rest of the admission (owned-page alloc) succeeds."""
        n = (len(np.asarray(prompt).reshape(-1)) - 1) // self.page_size
        matched: List[int] = []
        for key in self.prefix_keys(prompt, n):
            page = self._prefix.get(key)
            if page is None:
                break
            matched.append(page)
        return matched

    def resident_prefix_len(self, tokens: Any) -> int:
        """How many leading tokens of ``tokens`` are covered by resident
        shared pages right now (page-aligned; capped one token short of
        the full sequence, like :meth:`match_prefix`)."""
        return len(self.match_prefix(tokens)) * self.page_size

    def prefix_digests(self) -> FrozenSet[bytes]:
        """Snapshot of every resident prefix digest — what a replica
        gossips to the router so shared-prefix traffic can be routed to
        the pool that already holds the pages."""
        return frozenset(self._prefix)

    def register_prefix(self, prompt: Any, table: Sequence[int]) -> None:
        """Index every full prompt page of ``table`` for future sharing
        (first-registration wins; shared pages re-register as no-ops)."""
        n = len(np.asarray(prompt).reshape(-1)) // self.page_size
        for i, key in enumerate(self.prefix_keys(prompt, n)):
            if key not in self._prefix:
                self._prefix[key] = table[i]
                self._page_key[table[i]] = key

    def metrics(self) -> Dict[str, Any]:
        out = dict(self.stats)
        out["pages_in_use"] = self.pages_in_use
        out["total_pages"] = self.total_pages
        out["page_size"] = self.page_size
        return out
