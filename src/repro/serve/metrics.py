"""Typed serving metrics — one shape for every engine tier.

``ServeEngine``, ``DisaggServer`` and the multi-replica ``Router`` all
report the same headline metrics, but the key names had drifted (the
prefill role prefixed its pool counters ``pool_*``; the facade nested
what the engine flattened). ``ServeMetrics`` is the unification: a typed
dataclass carrying the headline fields every tier shares, plus an
``extra`` mapping for tier-specific counters, exposed as a read-only
``Mapping`` so every existing ``metrics()["key"]`` consumer keeps
working unchanged.

Back-compat: ``as_dict()`` returns the old plain-dict shape, and legacy
key aliases (``pool_pages_in_use`` → ``pages_in_use``, …) still resolve
through ``__getitem__``/``get`` — with a ``DeprecationWarning`` so the
drifted spellings can eventually be dropped.
"""
from __future__ import annotations

import dataclasses
import warnings
from collections.abc import Mapping
from typing import Any, Dict, Iterator

# drifted spelling -> canonical key. The ``pool_*`` family (the prefill
# role used to prefix every PagePool counter) is handled structurally in
# ``_canonical`` so new pool counters don't need enumeration here.
LEGACY_ALIASES: Dict[str, str] = {
    "pool_pages_in_use": "pages_in_use",
    "pool_total_pages": "total_pages",
    "pool_page_size": "page_size",
}

# dataclass fields every tier reports (``extra`` carries the rest).
# The ``transport_*`` family is zero for tiers without a transport
# (colocated ``ServeEngine``); transport-connected tiers fill them via
# ``transport_fields`` from their ``Transport.stats()`` per-tag counters.
_TYPED_FIELDS = ("finished", "total_tokens", "ttft_mean", "ttft_p50",
                 "ttft_p99", "accept_rate", "retired", "pages_in_use",
                 "total_pages", "transport_sent_msgs",
                 "transport_recvd_msgs", "transport_sent_bytes",
                 "transport_recvd_bytes", "transport_ctrl_bytes",
                 "transport_data_bytes")

# data-plane tags (per-request KV-block channels) start here; everything
# below is control plane (headers, routing, gossip, heartbeats)
_DATA_TAG_BASE = 1 << 16


def transport_fields(stats: Dict[str, Any]) -> Dict[str, Any]:
    """Lift a ``Transport.stats()`` snapshot into the typed
    ``transport_*`` metric fields.

    Message counts sum delivered traffic per tag; the byte split
    classifies tags into control plane (< ``1 << 16``: headers, routing,
    gossip, heartbeats) vs data plane (per-request KV-block channels),
    so dashboards separate shipping bandwidth from control chatter
    without parsing the nested per-tag dict.
    """
    sent_msgs = recvd_msgs = 0
    ctrl = data = 0
    for tag, t in stats.get("per_tag", {}).items():
        sent_msgs += t.get("sent_msgs", 0)
        recvd_msgs += t.get("recvd_msgs", 0)
        b = t.get("sent_bytes", 0)
        if int(tag) >= _DATA_TAG_BASE:
            data += b
        else:
            ctrl += b
    return {
        "transport_sent_msgs": sent_msgs,
        "transport_recvd_msgs": recvd_msgs,
        "transport_sent_bytes": stats.get("sent_bytes", 0),
        "transport_recvd_bytes": stats.get("recvd_bytes", 0),
        "transport_ctrl_bytes": ctrl,
        "transport_data_bytes": data,
    }


@dataclasses.dataclass(frozen=True)
class ServeMetrics(Mapping):
    """Headline serving metrics shared by every engine tier.

    * throughput/latency over finished requests (``summarize``):
      ``finished``, ``total_tokens``, ``ttft_mean``/``p50``/``p99``,
      ``accept_rate``;
    * lifecycle: ``retired``;
    * KV residency (the leak-check pair): ``pages_in_use``,
      ``total_pages``;
    * transport traffic (zero for colocated tiers): ``transport_*``
      message/byte counters with a control-vs-data-plane byte split.

    Everything tier-specific (step counters, ingest stats, nested role
    metrics, transport stats, …) lives in ``extra`` and is reachable
    through the same ``Mapping`` interface — ``metrics()["steps"]``
    works whether the key is typed or extra.
    """

    finished: int = 0
    total_tokens: int = 0
    ttft_mean: float = 0.0
    ttft_p50: float = 0.0
    ttft_p99: float = 0.0
    accept_rate: float = 0.0
    retired: int = 0
    pages_in_use: int = 0
    total_pages: int = 0
    transport_sent_msgs: int = 0
    transport_recvd_msgs: int = 0
    transport_sent_bytes: int = 0
    transport_recvd_bytes: int = 0
    transport_ctrl_bytes: int = 0
    transport_data_bytes: int = 0
    extra: Dict[str, Any] = dataclasses.field(default_factory=dict)

    @classmethod
    def from_flat(cls, data: Dict[str, Any]) -> "ServeMetrics":
        """Build from a flat metrics dict: typed keys are lifted into
        fields, the remainder lands in ``extra`` (insertion order
        preserved for ``as_dict`` round-trips)."""
        fields = {k: data[k] for k in _TYPED_FIELDS if k in data}
        extra = {k: v for k, v in data.items() if k not in _TYPED_FIELDS}
        return cls(extra=extra, **fields)

    # ------------------------------------------------------------ mapping
    def _canonical(self, key: str) -> str:
        """Resolve a legacy alias to its canonical key (warning once per
        call site); unknown keys pass through untouched."""
        canon = LEGACY_ALIASES.get(key)
        if canon is None and key.startswith("pool_"):
            tail = key[len("pool_"):]
            if tail in _TYPED_FIELDS or tail in self.extra:
                canon = tail
        if canon is not None:
            warnings.warn(
                f"metrics key {key!r} is deprecated; use {canon!r}",
                DeprecationWarning, stacklevel=3)
            return canon
        return key

    def __getitem__(self, key: str) -> Any:
        key = self._canonical(key)
        if key in _TYPED_FIELDS:
            return getattr(self, key)
        return self.extra[key]

    def __iter__(self) -> Iterator[str]:
        yield from _TYPED_FIELDS
        yield from self.extra

    def __len__(self) -> int:
        return len(_TYPED_FIELDS) + len(self.extra)

    def as_dict(self) -> Dict[str, Any]:
        """The legacy plain-dict shape (canonical keys only)."""
        out = {k: getattr(self, k) for k in _TYPED_FIELDS}
        out.update(self.extra)
        return out
