"""Typed serving metrics — one shape for every engine tier.

``ServeEngine``, ``DisaggServer`` and the multi-replica ``Router`` all
report the same headline metrics, but the key names had drifted (the
prefill role prefixed its pool counters ``pool_*``; the facade nested
what the engine flattened). ``ServeMetrics`` is the unification: a typed
dataclass carrying the headline fields every tier shares, plus an
``extra`` mapping for tier-specific counters, exposed as a read-only
``Mapping`` so every existing ``metrics()["key"]`` consumer keeps
working unchanged.

Back-compat: ``as_dict()`` returns the old plain-dict shape, and legacy
key aliases (``pool_pages_in_use`` → ``pages_in_use``, …) still resolve
through ``__getitem__``/``get`` — with a ``DeprecationWarning`` so the
drifted spellings can eventually be dropped.
"""
from __future__ import annotations

import dataclasses
import warnings
from collections.abc import Mapping
from typing import Any, Dict, Iterator

# drifted spelling -> canonical key. The ``pool_*`` family (the prefill
# role used to prefix every PagePool counter) is handled structurally in
# ``_canonical`` so new pool counters don't need enumeration here.
LEGACY_ALIASES: Dict[str, str] = {
    "pool_pages_in_use": "pages_in_use",
    "pool_total_pages": "total_pages",
    "pool_page_size": "page_size",
}

# dataclass fields every tier reports (``extra`` carries the rest)
_TYPED_FIELDS = ("finished", "total_tokens", "ttft_mean", "ttft_p50",
                 "ttft_p99", "accept_rate", "retired", "pages_in_use",
                 "total_pages")


@dataclasses.dataclass(frozen=True)
class ServeMetrics(Mapping):
    """Headline serving metrics shared by every engine tier.

    * throughput/latency over finished requests (``summarize``):
      ``finished``, ``total_tokens``, ``ttft_mean``/``p50``/``p99``,
      ``accept_rate``;
    * lifecycle: ``retired``;
    * KV residency (the leak-check pair): ``pages_in_use``,
      ``total_pages``.

    Everything tier-specific (step counters, ingest stats, nested role
    metrics, transport stats, …) lives in ``extra`` and is reachable
    through the same ``Mapping`` interface — ``metrics()["steps"]``
    works whether the key is typed or extra.
    """

    finished: int = 0
    total_tokens: int = 0
    ttft_mean: float = 0.0
    ttft_p50: float = 0.0
    ttft_p99: float = 0.0
    accept_rate: float = 0.0
    retired: int = 0
    pages_in_use: int = 0
    total_pages: int = 0
    extra: Dict[str, Any] = dataclasses.field(default_factory=dict)

    @classmethod
    def from_flat(cls, data: Dict[str, Any]) -> "ServeMetrics":
        """Build from a flat metrics dict: typed keys are lifted into
        fields, the remainder lands in ``extra`` (insertion order
        preserved for ``as_dict`` round-trips)."""
        fields = {k: data[k] for k in _TYPED_FIELDS if k in data}
        extra = {k: v for k, v in data.items() if k not in _TYPED_FIELDS}
        return cls(extra=extra, **fields)

    # ------------------------------------------------------------ mapping
    def _canonical(self, key: str) -> str:
        """Resolve a legacy alias to its canonical key (warning once per
        call site); unknown keys pass through untouched."""
        canon = LEGACY_ALIASES.get(key)
        if canon is None and key.startswith("pool_"):
            tail = key[len("pool_"):]
            if tail in _TYPED_FIELDS or tail in self.extra:
                canon = tail
        if canon is not None:
            warnings.warn(
                f"metrics key {key!r} is deprecated; use {canon!r}",
                DeprecationWarning, stacklevel=3)
            return canon
        return key

    def __getitem__(self, key: str) -> Any:
        key = self._canonical(key)
        if key in _TYPED_FIELDS:
            return getattr(self, key)
        return self.extra[key]

    def __iter__(self) -> Iterator[str]:
        yield from _TYPED_FIELDS
        yield from self.extra

    def __len__(self) -> int:
        return len(_TYPED_FIELDS) + len(self.extra)

    def as_dict(self) -> Dict[str, Any]:
        """The legacy plain-dict shape (canonical keys only)."""
        out = {k: getattr(self, k) for k in _TYPED_FIELDS}
        out.update(self.extra)
        return out
