"""Admission batching on a ``poll_only + enqueue_complete`` CR.

Clients may submit requests from any thread at any rate. Each submission
is an already-complete push op registered on a CR configured exactly like
the paper's burst-tolerant activation handling (§3.5 info keys, §5.3.1
usage):

* ``enqueue_complete`` — registration never takes the immediate-completion
  fast path, so every submission flows through the continuation machinery
  uniformly (no flag handling on the submit path);
* ``poll_only``        — admission callbacks run *only* inside
  ``cr.test()``, which only the decode loop calls. A burst of submissions
  therefore queues on the CR without ever preempting in-flight decode
  dispatch, and the loop admits on its own step boundaries.

``admit(n)`` is the decode loop's entry point: one ``cr.test()`` drains
the queued admission callbacks (cheap appends), then up to ``n`` requests
are handed out in **QoS order**: strictly by ``config.priority`` (higher
first), arrival order within a priority class. Requests whose
``config.deadline_s`` already passed while queued are *refused* — expired
with ``DeadlineExceeded`` instead of wasting prefill compute — and
capacity-deferred requests requeue at the head of their priority class.
"""
from __future__ import annotations

import heapq
import itertools
import threading
import time
from typing import Callable, List, Optional

from repro.core.completable import Completable
from repro.core.engine import Engine
from repro.core.status import Status
from repro.serve.request import Request, RequestState


class _SubmitOp(Completable):
    """Push op representing 'a request arrived'; complete at construction."""

    @property
    def supports_push(self) -> bool:
        return True


class Batcher:
    """Thread-safe request intake feeding a single decode loop.

    ``on_drop``: optional callback invoked (loop thread, from ``admit``)
    for every queued request refused without being handed out — cancelled
    while queued, or expired past its deadline. Role engines that attach
    resources *before* admission (the disaggregated decode role queues
    requests whose KV pages already landed) use it to release them; the
    plain colocated intake queues nothing resource-bearing and leaves it
    unset."""

    def __init__(self, engine: Engine,
                 on_drop: Optional[Callable[[Request], None]] = None) -> None:
        self.engine = engine
        self._on_drop = on_drop
        # CR-level defaults (new-style keys; every admission wants both):
        # individual registrations could override via flags=, but intake
        # is deliberately uniform
        self.cr = engine.continue_init(poll_only=True, enqueue_complete=True)
        # priority heap: (-priority, seq, Request). seq is a monotone
        # arrival counter, so equal-priority requests pop in arrival
        # order; requeued requests get a *decreasing* seq and land at the
        # head of their priority class. Only mutated by admission
        # callbacks / admit / requeue, i.e. on the decode-loop thread.
        self._pending: List[tuple] = []
        self._arrival_seq = itertools.count()
        self._head_seq = itertools.count(-1, -1)
        # one mutex makes the closed-check and the CR registration atomic
        # against close(): without it a submission racing close() could pass
        # the check, then register on the CR of a closed batcher and sit
        # there forever (the loop stops admitting once drained).
        self._intake_lock = threading.Lock()
        self._closed = False
        self.stats = {"submitted": 0, "admitted": 0, "dropped_cancelled": 0,
                      "refused_closed": 0, "submitted_speculative": 0,
                      "expired_queued": 0}

    # ---------------------------------------------------------- client side
    def submit(self, request: Request) -> Request:
        """Enqueue a request (any thread). Returns the request for chaining."""
        with self._intake_lock:
            if self._closed:
                self.stats["refused_closed"] += 1
                raise RuntimeError("batcher intake is closed")
            self.stats["submitted"] += 1
            # per-request speculate=K knob (None rides the engine default,
            # which this intake-side counter cannot see)
            if request.speculate:
                self.stats["submitted_speculative"] += 1
            op = _SubmitOp()
            op._complete(Status(payload=request))
            # poll_only routes the ready continuation to the CR's private
            # queue; nothing executes on this (client) thread, so holding
            # the lock across registration is cheap.
            self.engine.continue_when(op, self._on_submit, request,
                                      cr=self.cr)
        return request

    def close(self) -> None:
        """Stop accepting new submissions (already-queued ones still admit)."""
        with self._intake_lock:
            self._closed = True

    @property
    def closed(self) -> bool:
        with self._intake_lock:
            return self._closed

    # ----------------------------------------------------------- loop side
    def _on_submit(self, statuses, request: Request) -> None:
        heapq.heappush(self._pending,
                       (-request.priority, next(self._arrival_seq), request))

    def admit(self, max_n: int) -> List[Request]:
        """Drain queued submissions and hand out up to ``max_n`` requests
        in priority order, refusing past-deadline work.

        Must be called from the decode loop only (single-tester CR rule).
        """
        self.cr.test()
        now = time.monotonic()
        out: List[Request] = []
        while self._pending and len(out) < max_n:
            _, _, req = heapq.heappop(self._pending)
            if req.req_state is RequestState.CANCELLED:
                self.stats["dropped_cancelled"] += 1
                if self._on_drop is not None:
                    self._on_drop(req)
                continue
            if req.past_deadline(now):
                # refuse: the deadline passed while the request queued —
                # expire it here instead of spending prefill on it
                req.expire()
                self.stats["expired_queued"] += 1
                if self._on_drop is not None:
                    self._on_drop(req)
                continue
            req.on_admitted()
            out.append(req)
        self.stats["admitted"] += len(out)
        return out

    def requeue(self, request: Request) -> None:
        """Return an admitted-but-unplaceable request to the head of its
        priority class (loop thread only — the paged engine defers
        admission when the page pool can't cover the request's worst-case
        footprint)."""
        request.on_requeued()
        heapq.heappush(self._pending,
                       (-request.priority, next(self._head_seq), request))
        self.stats["admitted"] -= 1

    @property
    def queued(self) -> int:
        """Submissions already transferred to the pending heap (does not
        count ones still sitting on the CR until the next admit())."""
        return len(self._pending)

    @property
    def drained(self) -> bool:
        """True when intake is closed and nothing is waiting for admission."""
        return (self.closed and not self._pending
                and self.cr.active_count == 0)
