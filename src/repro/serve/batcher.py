"""Admission batching on a ``poll_only + enqueue_complete`` CR.

Clients may submit requests from any thread at any rate. Each submission
is an already-complete push op registered on a CR configured exactly like
the paper's burst-tolerant activation handling (§3.5 info keys, §5.3.1
usage):

* ``enqueue_complete`` — registration never takes the immediate-completion
  fast path, so every submission flows through the continuation machinery
  uniformly (no flag handling on the submit path);
* ``poll_only``        — admission callbacks run *only* inside
  ``cr.test()``, which only the decode loop calls. A burst of submissions
  therefore queues on the CR without ever preempting in-flight decode
  dispatch, and the loop admits on its own step boundaries.

``admit(n)`` is the decode loop's entry point: one ``cr.test()`` drains
the queued admission callbacks (cheap appends), then up to ``n`` requests
are handed out in **QoS order**: strictly by ``config.priority`` (higher
first), arrival order within a priority class. Requests whose
``config.deadline_s`` already passed while queued are *refused* — expired
with ``DeadlineExceeded`` instead of wasting prefill compute — and
capacity-deferred requests requeue at the head of their priority class.

The queue discipline itself is pluggable (``_push``/``_push_head``/
``_pop`` hooks): ``FairBatcher`` keeps the strict priority classes but
runs weighted deficit round robin across tenants *within* each class —
the multi-replica router's admission scheduler.
"""
from __future__ import annotations

import heapq
import itertools
import threading
import time
from collections import deque
from typing import Callable, Deque, Dict, List, Optional

from repro.core.completable import Completable
from repro.core.engine import Engine
from repro.core.status import Status
from repro.serve.request import Request, RequestState


class _SubmitOp(Completable):
    """Push op representing 'a request arrived'; complete at construction."""

    @property
    def supports_push(self) -> bool:
        return True


class Batcher:
    """Thread-safe request intake feeding a single decode loop.

    ``on_drop``: optional callback invoked (loop thread, from ``admit``)
    for every queued request refused without being handed out — cancelled
    while queued, or expired past its deadline. Role engines that attach
    resources *before* admission (the disaggregated decode role queues
    requests whose KV pages already landed) use it to release them; the
    plain colocated intake queues nothing resource-bearing and leaves it
    unset."""

    def __init__(self, engine: Engine,
                 on_drop: Optional[Callable[[Request], None]] = None) -> None:
        self.engine = engine
        self._on_drop = on_drop
        # CR-level defaults (new-style keys; every admission wants both):
        # individual registrations could override via flags=, but intake
        # is deliberately uniform
        self.cr = engine.continue_init(poll_only=True, enqueue_complete=True)
        # priority heap: (-priority, seq, Request). seq is a monotone
        # arrival counter, so equal-priority requests pop in arrival
        # order; requeued requests get a *decreasing* seq and land at the
        # head of their priority class. Only mutated by admission
        # callbacks / admit / requeue, i.e. on the decode-loop thread.
        self._pending: List[tuple] = []
        self._arrival_seq = itertools.count()
        self._head_seq = itertools.count(-1, -1)
        # one mutex makes the closed-check and the CR registration atomic
        # against close(): without it a submission racing close() could pass
        # the check, then register on the CR of a closed batcher and sit
        # there forever (the loop stops admitting once drained).
        self._intake_lock = threading.Lock()
        self._closed = False
        self.stats = {"submitted": 0, "admitted": 0, "dropped_cancelled": 0,
                      "refused_closed": 0, "submitted_speculative": 0,
                      "expired_queued": 0}

    # ---------------------------------------------------------- client side
    def submit(self, request: Request) -> Request:
        """Enqueue a request (any thread). Returns the request for chaining."""
        with self._intake_lock:
            if self._closed:
                self.stats["refused_closed"] += 1
                raise RuntimeError("batcher intake is closed")
            self.stats["submitted"] += 1
            # per-request speculate=K knob (None rides the engine default,
            # which this intake-side counter cannot see)
            if request.speculate:
                self.stats["submitted_speculative"] += 1
            op = _SubmitOp()
            op._complete(Status(payload=request))
            # poll_only routes the ready continuation to the CR's private
            # queue; nothing executes on this (client) thread, so holding
            # the lock across registration is cheap.
            self.engine.continue_when(op, self._on_submit, request,
                                      cr=self.cr)
        return request

    def close(self) -> None:
        """Stop accepting new submissions (already-queued ones still admit)."""
        with self._intake_lock:
            self._closed = True

    @property
    def closed(self) -> bool:
        with self._intake_lock:
            return self._closed

    # ----------------------------------------------------------- loop side
    # The queue discipline lives behind three overridable hooks (_push /
    # _push_head / _pop) so subclasses can change ORDERING without
    # touching the intake CR, the drop/refusal policy, or the drain
    # contract. The base discipline: strict priority, FIFO within class.
    def _push(self, request: Request) -> None:
        heapq.heappush(self._pending,
                       (-request.priority, next(self._arrival_seq), request))

    def _push_head(self, request: Request) -> None:
        heapq.heappush(self._pending,
                       (-request.priority, next(self._head_seq), request))

    def _pop(self) -> Optional[Request]:
        if not self._pending:
            return None
        return heapq.heappop(self._pending)[2]

    def _queue_len(self) -> int:
        return len(self._pending)

    def _on_submit(self, statuses, request: Request) -> None:
        self._push(request)

    def admit(self, max_n: int) -> List[Request]:
        """Drain queued submissions and hand out up to ``max_n`` requests
        in QoS order, refusing past-deadline work.

        Must be called from the decode loop only (single-tester CR rule).
        """
        self.cr.test()
        now = time.monotonic()
        out: List[Request] = []
        while len(out) < max_n:
            req = self._pop()
            if req is None:
                break
            if req.req_state is RequestState.CANCELLED:
                self.stats["dropped_cancelled"] += 1
                if self._on_drop is not None:
                    self._on_drop(req)
                continue
            if req.past_deadline(now):
                # refuse: the deadline passed while the request queued —
                # expire it here instead of spending prefill on it
                req.expire()
                self.stats["expired_queued"] += 1
                if self._on_drop is not None:
                    self._on_drop(req)
                continue
            req.on_admitted()
            out.append(req)
        self.stats["admitted"] += len(out)
        return out

    def requeue(self, request: Request) -> None:
        """Return an admitted-but-unplaceable request to the head of its
        priority class (loop thread only — the paged engine defers
        admission when the page pool can't cover the request's worst-case
        footprint, and the router re-queues a dead replica's in-flight
        work)."""
        request.on_requeued()
        self._push_head(request)
        self.stats["admitted"] -= 1

    @property
    def queued(self) -> int:
        """Submissions already transferred to the pending queue (does not
        count ones still sitting on the CR until the next admit())."""
        return self._queue_len()

    @property
    def drained(self) -> bool:
        """True when intake is closed and nothing is waiting for admission."""
        return (self.closed and self._queue_len() == 0
                and self.cr.active_count == 0)


class _TenantClass:
    """One priority class inside ``FairBatcher``: a head lane for
    requeued work plus per-tenant FIFO queues under deficit round-robin."""

    __slots__ = ("head", "queues", "rotation", "deficit", "count")

    def __init__(self) -> None:
        self.head: Deque[Request] = deque()
        self.queues: Dict[str, Deque[Request]] = {}
        self.rotation: Deque[str] = deque()    # tenants with queued work
        self.deficit: Dict[str, float] = {}
        self.count = 0


class FairBatcher(Batcher):
    """Weighted per-tenant fairness under the strict priority classes.

    Ordering: strict ``config.priority`` classes first (identical to the
    base ``Batcher``), then — *within* a class — weighted deficit round
    robin (DRR) across tenants, with a request's cost its ``max_tokens``
    budget. Each rotation visit grants a tenant ``quantum * weight``
    token-credits; a tenant whose front request costs more saves its
    deficit for the next visit, so over time admitted token-budget
    converges to the weight ratios while cheap-request tenants still
    can't be starved by expensive-request ones.

    ``requeue`` bypasses fairness entirely: a request returned at the
    head of its class (capacity deferral, replica-death failover) already
    charged its tenant's deficit when first admitted — it pops before any
    DRR lane next time.

    Weights default to 1.0 per tenant (``weights=`` overrides per name;
    must be > 0). Same single-consumer rule as ``Batcher``: queue state
    is only touched on the loop thread.
    """

    def __init__(self, engine: Engine, *,
                 weights: Optional[Dict[str, float]] = None,
                 quantum: float = 32.0,
                 on_drop: Optional[Callable[[Request], None]] = None) -> None:
        super().__init__(engine, on_drop=on_drop)
        self.weights: Dict[str, float] = dict(weights or {})
        for tenant, w in self.weights.items():
            if not float(w) > 0.0:
                raise ValueError(
                    f"tenant weight must be > 0, got {tenant!r}: {w}")
        self.quantum = float(quantum)
        if self.quantum <= 0:
            raise ValueError(f"quantum must be > 0, got {quantum}")
        self._classes: Dict[int, _TenantClass] = {}
        self._total = 0
        self.tenant_stats: Dict[str, Dict[str, int]] = {}

    def weight(self, tenant: str) -> float:
        return float(self.weights.get(tenant, 1.0))

    def _tenant_stat(self, tenant: str) -> Dict[str, int]:
        s = self.tenant_stats.get(tenant)
        if s is None:
            s = self.tenant_stats[tenant] = {
                "submitted": 0, "admitted": 0, "admitted_tokens": 0}
        return s

    # ------------------------------------------------------- queue hooks
    def _push(self, request: Request) -> None:
        cls = self._classes.setdefault(request.priority, _TenantClass())
        tenant = request.tenant
        q = cls.queues.get(tenant)
        if q is None:
            q = cls.queues[tenant] = deque()
            cls.deficit.setdefault(tenant, 0.0)
        if not q and tenant not in cls.rotation:
            cls.rotation.append(tenant)
        q.append(request)
        cls.count += 1
        self._total += 1
        self._tenant_stat(tenant)["submitted"] += 1

    def _push_head(self, request: Request) -> None:
        cls = self._classes.setdefault(request.priority, _TenantClass())
        cls.head.appendleft(request)
        cls.count += 1
        self._total += 1

    def _pop(self) -> Optional[Request]:
        if self._total == 0:
            return None
        for prio in sorted(self._classes, reverse=True):
            cls = self._classes[prio]
            if cls.count == 0:
                continue
            req = self._pop_class(cls)
            if req is not None:
                cls.count -= 1
                self._total -= 1
                return req
        return None

    def _pop_class(self, cls: _TenantClass) -> Optional[Request]:
        if cls.head:
            return cls.head.popleft()
        # DRR: visit tenants in rotation order; each visit adds
        # quantum*weight credit, and a tenant spends credit equal to the
        # popped request's token budget. Terminates: every full rotation
        # strictly grows the richest tenant's deficit past any fixed cost.
        while cls.rotation:
            tenant = cls.rotation[0]
            q = cls.queues.get(tenant)
            if not q:
                cls.rotation.popleft()
                continue
            cost = float(q[0].max_new_tokens)
            if cls.deficit[tenant] >= cost:
                cls.deficit[tenant] -= cost
                req = q.popleft()
                if not q:
                    cls.rotation.popleft()
                    # an emptied lane forfeits leftover credit — otherwise
                    # an idle tenant banks unbounded credit and bursts
                    cls.deficit[tenant] = 0.0
                stat = self._tenant_stat(tenant)
                stat["admitted"] += 1
                stat["admitted_tokens"] += int(cost)
                return req
            cls.deficit[tenant] += self.quantum * self.weight(tenant)
            cls.rotation.rotate(-1)
        return None

    def _queue_len(self) -> int:
        return self._total
