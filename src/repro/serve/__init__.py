"""Serving subsystem: continuation-driven continuous batching.

Layers:

* ``serve.steps``   — jittable prefill/decode step factories (with fused
  per-step token surfacing) and the synchronous ``greedy_generate``
  baseline (application-space completion handling — the pattern the
  paper argues against).
* ``serve.config``  — ``GenerationConfig``: the structured, validated bag
  of per-request knobs (budget, speculation, stop sequences, deadline,
  priority, stream buffering) resolved once at admission.
* ``serve.request`` — request lifecycle; each ``Request`` is a
  ``Completable`` so callers attach continuations to completions, and
  per-token *delivery* runs in the engine's step-completion
  continuations (stop matching, stream publication).
* ``serve.batcher`` — thread-safe admission on a ``poll_only +
  enqueue_complete`` CR; bursts queue without preempting the decode
  loop; priority-ordered pops, past-deadline refusal.
* ``serve.drafter`` — pluggable ``Drafter`` protocol for self-speculative
  decoding (default: n-gram prompt lookup); drafts are verified by one
  multi-token paged decode step, so emitted tokens always match greedy.
* ``serve.kv_cache`` — paged KV block pool: free-list page allocation,
  per-request page tables, and content-hashed prefix reuse (shared pages
  are mapped read-only; the mutable tail page is always private).
* ``serve.engine``  — the continuous-batching decode loop where each
  step's ``jax.Array`` outputs are ``ArrayOp``s whose continuations
  deliver tokens, re-enqueue or retire sequences (budget, stop sequence,
  or deadline), and overlap prefill with in-flight decode. Paged by
  default where the model family supports it.
* ``serve.api``     — the streaming session front-end:
  ``ServeClient`` / ``Session`` / ``TokenStream`` (sync + asyncio
  per-token iteration driven by the same continuations; no polling
  thread).
* ``serve.disagg``  — disaggregated prefill/decode: role-based engines
  (``PrefillWorker`` / ``DecodeWorker``) connected only by the
  continuation transport, KV pages shipped per-block as chunked prefill
  produces them, with the ``DisaggServer`` router exposing the same
  serving surface (so token streams run over it unchanged).
* ``serve.protocol`` — ``EngineLike``, the runtime-checkable structural
  protocol every serving tier satisfies (``ServeEngine`` /
  ``DisaggServer`` / ``Router``); ``ServeClient`` binds to any of them.
* ``serve.metrics`` — ``ServeMetrics``, the typed read-only metrics
  mapping every tier's ``metrics()`` returns (legacy flat-dict keys keep
  working through deprecated aliases).
* ``serve.router``  — the multi-replica front door: prefix-affinity
  routing over gossiped ``PagePool`` digests, weighted per-tenant
  fairness (``FairBatcher`` DRR + ``QuotaExceeded`` admission control),
  and heartbeat-driven failover that requeues a dead replica's in-flight
  requests with token-identical greedy replay.
"""
from repro.serve.api import ServeClient, Session, TokenStream
from repro.serve.batcher import Batcher, FairBatcher
from repro.serve.config import (DeadlineExceeded, GenerationConfig,
                                QuotaExceeded)
from repro.serve.disagg import (DecodeWorker, DisaggServer, KVBlockMsg,
                                PrefillWorker, serve_requests_disagg)
from repro.serve.drafter import Drafter, NgramDrafter, RepeatDrafter
from repro.serve.engine import ServeEngine, serve_requests
from repro.serve.kv_cache import (PagePool, paged_supported, pages_for,
                                  prefix_keys)
from repro.serve.metrics import ServeMetrics
from repro.serve.protocol import EngineLike
from repro.serve.request import Request, RequestState, summarize
from repro.serve.router import ReplicaWorker, Router
from repro.serve.steps import (greedy_generate, make_batched_decode_step,
                               make_decode_step, make_paged_decode_step,
                               make_paged_suffix_step,
                               make_paged_verify_step, make_prefill_scatter,
                               make_prefill_step)

__all__ = [
    "Batcher", "ServeEngine", "serve_requests", "Request", "RequestState",
    "summarize", "greedy_generate", "make_decode_step", "make_prefill_step",
    "make_batched_decode_step", "PagePool", "paged_supported", "pages_for",
    "make_paged_decode_step", "make_paged_suffix_step",
    "make_paged_verify_step", "make_prefill_scatter", "Drafter",
    "NgramDrafter", "RepeatDrafter", "GenerationConfig", "DeadlineExceeded",
    "ServeClient", "Session", "TokenStream", "DisaggServer", "PrefillWorker",
    "DecodeWorker", "KVBlockMsg", "serve_requests_disagg",
    "EngineLike", "ServeMetrics", "FairBatcher", "QuotaExceeded",
    "prefix_keys", "Router", "ReplicaWorker",
]
