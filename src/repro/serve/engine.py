"""Continuation-driven continuous-batching decode engine.

The serving analogue of the paper's completion-notification claim: instead
of an application-space synchronous loop (``steps.greedy_generate`` — run a
static batch to the longest member, block, repeat), the decode loop keeps a
fixed set of *slots*, each holding one in-flight sequence with its own KV
cache and position:

* **decode** — one vmapped decode step advances every occupied slot by one
  token (per-slot positions, donated cache). The step's next-token
  ``jax.Array`` is wrapped in an ``ArrayOp`` whose continuation does the
  bookkeeping when the device work *actually* finishes: records
  first-token latency, retires sequences that reached their token budget
  (freeing their slots), and releases the in-flight window so the loop can
  dispatch further ahead. The Python loop never blocks on device work.
* **admission** — new requests queue on the ``Batcher``'s
  ``poll_only + enqueue_complete`` CR (paper §3.5) and are admitted into
  free slots at step boundaries; their prefill dispatches while previously
  issued decode steps are still in flight on device, so prefill of new
  requests overlaps in-flight decode.
* **retirement** — a finished ``Request`` is itself a ``Completable``:
  its continuation fires for whoever attached one, and ``request.wait()``
  unblocks the submitting client.
* **per-token delivery** — the same step-completion continuations
  *deliver* each newly accepted token to the request on the host
  (``Request.deliver``): attached ``TokenStream``s (``serve.api``) wake
  per token with no polling thread, stop sequences match as tokens land,
  and deadline-expired slots are retired by the very continuation that
  releases their pages. QoS: admission pops strictly by
  ``GenerationConfig.priority`` (the ``Batcher`` heap) and steps carrying
  priority work jump the scheduler's ready queue via the
  per-registration ``priority`` flag.
* **speculation** (``speculate=K``, paged mode) — each iteration becomes
  a draft/verify pair: a host-side ``Drafter`` (n-gram prompt lookup by
  default, pluggable) guesses K tokens per slot, and ONE multi-token
  verify step scores all K+1 positions through the paged
  ``decode_attention``, accepting the longest matching prefix. The
  accept bookkeeping — per-slot position advance, token pushes,
  retirement of slots that finish mid-accepted-run — is itself a
  continuation on the verify step's output array, so the loop still
  never blocks on device work; slots simply become re-steppable when
  their verify completes. Token streams are identical to non-speculative
  greedy decode (the verify step emits only what the model itself
  argmaxes); speculation changes the schedule, never the tokens.

**Memory** comes in two flavours:

* *paged* (default where supported, see ``serve.kv_cache``) — slots index
  into a shared ``PagePool`` through per-request page tables; a request
  holds ``ceil((prompt + max_new) / page_size)`` pages instead of a full
  ``max_cache_len`` lane, so at equal pool memory the engine sustains a
  larger effective batch. Prompts sharing a page-aligned prefix with a
  resident request map those pages read-only and skip re-prefilling them;
  pages return to the pool in the retirement continuation (the paper's
  callback-driven lifecycle owns deallocation too). Paged steps default
  to the **fused** Pallas paged-attention kernel
  (``kernels.paged_attention``): one kernel walks the page tables on
  device — gather, flash-style attend, accept-masked KV write — so
  decode/verify/suffix never materialize a contiguous per-slot view and
  need no host-built write tables (``fused=False`` keeps the unfused
  gather/scatter steps as the A/B baseline). Page tables live device-
  resident between steps, refreshed only for slots whose placement
  changed.
* *dense* (``paged=False``, and automatically for SSM/hybrid/audio/SWA
  configs) — the original per-slot stacked cache, each slot padded to
  ``max_cache_len``.

Continuous batching beats static batching whenever output lengths vary or
arrivals straggle: finished slots are refilled immediately instead of
padding along until the longest member of a static batch completes.
"""
from __future__ import annotations

import threading
import time
from typing import Any, List, Optional, Sequence, Set, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ArrayOp, ContinueFlags, Engine, Promise, Scheduler
from repro.models import lm
from repro.obs import events as _obs_events
from repro.obs import tracer as _obs
from repro.models.common import AUDIO, ModelConfig
from repro.serve.batcher import Batcher
from repro.serve.drafter import Drafter, NgramDrafter
from repro.serve.kv_cache import PagePool, paged_supported, pages_for
from repro.serve.metrics import ServeMetrics
from repro.serve.request import Request, RequestState, summarize
from repro.serve.steps import (make_batched_decode_step,
                               make_fused_paged_decode_step,
                               make_fused_paged_suffix_step,
                               make_fused_paged_verify_step,
                               make_paged_decode_step, make_paged_suffix_step,
                               make_paged_verify_step, make_prefill_scatter,
                               make_prefill_step)

# every step/prefill/verify registration: never take the immediate-
# completion fast path, so bookkeeping always runs through the
# continuation machinery even when the device raced ahead
_STEP_FLAGS = ContinueFlags(enqueue_complete=True)
# steps carrying priority>0 requests additionally jump the scheduler's
# ready queue (per-registration priority flag); cached per level, bounded
# — priorities are arbitrary caller ints, so an unbounded cache would be
# a process-lifetime leak under priority-per-request workloads
_PRIO_FLAGS: dict = {}
_PRIO_FLAGS_MAX = 64


def _step_flags(priority: int) -> ContinueFlags:
    if priority <= 0:
        return _STEP_FLAGS
    flags = _PRIO_FLAGS.get(priority)
    if flags is None:
        flags = ContinueFlags(enqueue_complete=True, priority=priority)
        if len(_PRIO_FLAGS) < _PRIO_FLAGS_MAX:
            _PRIO_FLAGS[priority] = flags
    return flags


class ServeEngine:
    """Continuous-batching engine over ``max_batch`` decode slots.

    Single-consumer: exactly one thread drives ``step()``/``run()`` (the
    decode loop); any thread may ``submit()``. Slot state is touched only
    by the loop thread — continuations registered here run on it because
    the CRs use the default ``thread=application`` policy and the loop is
    the only thread that calls into the engine.

    Paged-mode knobs: ``page_size`` tokens per KV page, ``max_seq_len``
    (prompt + generation bound per request, default ``max_cache_len``),
    ``total_pages`` in the pool (default ``max_batch * ceil(max_seq_len /
    page_size)`` — shrink it, or raise ``max_batch``, to oversubscribe).

    Speculative knobs (paged only): ``speculate=K`` compiles a verify
    step scoring K drafts + 1 real token per slot per iteration;
    ``drafter`` plugs any ``serve.drafter.Drafter`` (default: n-gram
    prompt lookup). Requests opt out (``speculate=0``) or cap their own
    K per step; accepted runs advance a slot several tokens per step
    while staying token-identical to non-speculative greedy decode.
    """

    def __init__(self, cfg: ModelConfig, params: Any, *,
                 max_batch: int = 4,
                 max_cache_len: int = 256,
                 max_inflight: int = 2,
                 engine: Optional[Engine] = None,
                 scheduler: Union[str, Scheduler] = "fifo",
                 paged: Optional[bool] = None,
                 page_size: int = 16,
                 total_pages: Optional[int] = None,
                 max_seq_len: Optional[int] = None,
                 speculate: int = 0,
                 drafter: Optional[Drafter] = None,
                 fused: Optional[bool] = None) -> None:
        if cfg.family == AUDIO:
            raise NotImplementedError(
                "ServeEngine drives token-in/token-out LM decode; audio "
                "enc-dec serving still goes through serve.steps directly")
        if paged is None:
            paged = paged_supported(cfg)
        elif paged and not paged_supported(cfg):
            raise ValueError(
                f"paged KV cache unsupported for {cfg.name!r} "
                "(needs dense/MoE family, scan_layers, no sliding window)")
        if speculate and not paged:
            raise ValueError(
                "speculative decoding runs through the paged verify step; "
                "speculate > 0 requires paged=True")
        if fused and not paged:
            raise ValueError("fused paged-attention steps require paged=True")
        self.cfg = cfg
        self.params = params
        self.max_batch = int(max_batch)
        self.max_cache_len = int(max_cache_len)
        self.max_inflight = max(1, int(max_inflight))
        self.paged = bool(paged)
        # fused (default in paged mode): the whole batch runs through ONE
        # lm_paged_decode call — the paged-attention kernel walks page
        # tables on device (gather + attend + accept-masked write), so
        # paged decode needs no _gather_pages view, no write tables, and
        # no per-slot vmap. fused=False keeps the original unfused
        # gather/scatter steps (the A/B baseline the kernel benchmark
        # measures against).
        self.fused = bool(fused) if fused is not None else self.paged
        self.speculate = max(0, int(speculate))
        self.drafter = drafter if drafter is not None else NgramDrafter()
        self._own_engine = engine is None
        self.engine = engine if engine is not None else \
            Engine(scheduler=scheduler)
        self.batcher = Batcher(self.engine)
        # decode-step completions ride a plain CR; the enqueue_complete
        # knob (even an already-materialized step flows through the
        # continuation path) attaches per registration via _STEP_FLAGS —
        # no dedicated CR per flag combination needed anymore
        self.cr_steps = self.engine.continue_init()

        S = self.max_batch
        self.pool: Optional[PagePool] = None
        if self.paged:
            self.page_size = int(page_size)
            self.max_seq_len = int(max_seq_len or max_cache_len)
            self.max_pages = pages_for(self.max_seq_len, self.page_size)
            # padded gather width: every per-slot view is _table_pages
            # pages — max_pages a request may hold, plus scratch slack so
            # a verify step starting on the last real page can write its
            # whole K+1 window without dynamic-slice clamping (the slack
            # is table-padded to the null page, so the overflow lands in
            # the scratch page, never a real one)
            self._spec_pad = pages_for(self.speculate, self.page_size) \
                if self.speculate else 0
            self._table_pages = self.max_pages + self._spec_pad
            self._padded_len = self._table_pages * self.page_size
            n_pool = int(total_pages) if total_pages is not None \
                else S * self.max_pages
            self.pool = PagePool(cfg, n_pool, self.page_size)
            self._tables = np.full((S, self._table_pages),
                                   self.pool.null_page, np.int32)
            # device-resident mirror of _tables, refreshed incrementally:
            # only rows touched since the last step re-upload (placement /
            # eviction), instead of the full (S, table_pages) host → device
            # transfer every dispatch
            self._tables_dev: Optional[jax.Array] = None
            self._tables_dirty: Set[int] = set()
            self._prefill_fn = jax.jit(
                make_prefill_step(cfg, self._padded_len))
            if self.fused:
                self._decode_fn = jax.jit(
                    make_fused_paged_decode_step(cfg, self.page_size),
                    donate_argnums=(1,))
                self._suffix_fn = jax.jit(
                    make_fused_paged_suffix_step(cfg, self.page_size),
                    donate_argnums=(1,))
            else:
                self._decode_fn = jax.jit(
                    make_paged_decode_step(cfg, self.page_size,
                                           return_tokens=True),
                    donate_argnums=(1,))
                self._suffix_fn = jax.jit(
                    make_paged_suffix_step(cfg, self.page_size),
                    donate_argnums=(1,))
            self._scatter_fn = jax.jit(
                make_prefill_scatter(cfg, self.page_size),
                donate_argnums=(0,))
            if self.speculate:
                vf = make_fused_paged_verify_step(cfg, self.page_size,
                                                  self.speculate) \
                    if self.fused else \
                    make_paged_verify_step(cfg, self.page_size,
                                           self.speculate)
                self._verify_fn = jax.jit(vf, donate_argnums=(1,))
                self._verify_pages = 1 + pages_for(self.speculate,
                                                   self.page_size)
        else:
            self._prefill_fn = jax.jit(
                make_prefill_step(cfg, self.max_cache_len))
            self._decode_fn = jax.jit(make_batched_decode_step(cfg),
                                      donate_argnums=(1,))

        # -- slot state (loop thread only) --
        self._slots: List[Optional[Request]] = [None] * S
        self._draining: Set[int] = set()      # token budget met, step in flight
        self._verifying: Set[int] = set()     # verify step in flight
        self._pos = np.zeros(S, np.int32)     # next write position per slot
        self._cache: Any = None               # dense mode: stacked caches
        self._tokens: Any = None              # next input tokens (S, 1, 1)
        # speculative: per-slot host context (prompt + emitted tokens),
        # appended by the prefill/verify continuations as device steps
        # actually complete — what the drafter matches against
        self._ctx: List[Optional[List[int]]] = [None] * S
        self._inflight = 0                    # dispatched, not-yet-complete steps
        self._stalled_at: Optional[int] = None  # pages_in_use at last deferral
        self._retired: List[Request] = []
        self._lock = threading.Lock()         # guards _retired for readers
        self.stats = {"steps": 0, "prefills": 0, "retired": 0,
                      "slot_steps": 0, "padded_steps": 0, "cancelled": 0,
                      "suffix_steps": 0, "suffix_tokens": 0, "deferred": 0,
                      "max_active": 0, "verify_steps": 0, "spec_tokens": 0,
                      "draft_proposed": 0, "draft_accepted": 0,
                      "stopped": 0, "expired": 0}

    # ------------------------------------------------------------- clients
    def submit(self, request: Request) -> Request:
        """Thread-safe request intake (delegates to the Batcher CR)."""
        if self.paged:
            plen = int(np.asarray(request.prompt).reshape(-1).shape[0])
            total = plen + request.max_new_tokens
            if total > self.max_seq_len:
                raise ValueError(
                    f"request needs {total} tokens > max_seq_len="
                    f"{self.max_seq_len}")
            if pages_for(total, self.page_size) > self.pool.total_pages:
                raise ValueError(
                    f"request needs more pages than the pool holds "
                    f"({self.pool.total_pages})")
        tr = _obs.TRACE
        if tr is not None and tr.want(request.req_id):
            tr.evt(_obs_events.REQ_SUBMIT, request.req_id, "engine")
        return self.batcher.submit(request)

    def submit_async(self, request: Request) -> Promise:
        """Submit and get an awaitable ``Promise`` for the request.

        The promise resolves with the generated token list at retirement
        (a ``Request`` is a ``Completable``; its completion payload is the
        tokens) and rejects with ``PromiseCancelled`` if the request is
        cancelled. ``promise.cancel()`` cancels the request. Awaitable from
        asyncio (loop-safe wakeups) or blockable via ``promise.result()``
        — but never from the decode-loop thread itself.
        """
        # submit first: a rejected submit (seq-len/page validation, closed
        # intake) must not leave a never-settling registration on the
        # promise CR. Wrap-after-submit is safe — the resolution
        # registration uses enqueue_complete, so a request that races to
        # retirement still resolves through the continuation path.
        self.submit(request)
        return self.engine.wrap(request)

    def close_intake(self) -> None:
        self.batcher.close()

    @property
    def retired(self) -> List[Request]:
        with self._lock:
            return list(self._retired)

    # ---------------------------------------------------------- slot state
    def _ensure_state(self) -> None:
        if self._tokens is None:
            self._tokens = jnp.zeros((self.max_batch, 1, 1), jnp.int32)
        if self.paged:
            self.pool.ensure_arrays()
        elif self._cache is None:
            base = lm.init_cache(self.cfg, 1, self.max_cache_len)
            self._cache = jax.tree_util.tree_map(
                lambda x: jnp.stack([x] * self.max_batch), base)

    def _free_slots(self) -> List[int]:
        return [i for i, r in enumerate(self._slots) if r is None]

    @property
    def active(self) -> int:
        return sum(1 for r in self._slots if r is not None)

    # ------------------------------------------------------------ admission
    def _admit(self) -> int:
        # after a capacity deferral, don't re-pop and re-hash the queue
        # every loop spin — admission can only succeed once a retirement
        # or cancellation has returned pages to the pool
        if self._stalled_at is not None:
            if self.pool.pages_in_use >= self._stalled_at:
                return 0
            self._stalled_at = None
        free = self._free_slots()
        reqs = self.batcher.admit(len(free))
        admitted = 0
        for i, req in enumerate(reqs):
            if not self._place(req, free):
                # page pool can't cover the request's worst case yet:
                # return it (and everything behind it, preserving arrival
                # order) to the queue head; stats count stall events, not
                # retries
                self.stats["deferred"] += 1
                self._stalled_at = self.pool.pages_in_use
                for r in reversed(reqs[i:]):
                    self.batcher.requeue(r)
                break
            admitted += 1
        return admitted

    def _place(self, req: Request, free: List[int]) -> bool:
        """Prefill ``req`` and seat it in a slot. False = defer (paged
        capacity); True = placed (or answered outright by prefill)."""
        prompt = jnp.asarray(req.prompt, jnp.int32)[None, :]
        plen = prompt.shape[1]
        tr = _obs.TRACE
        t0 = None
        if tr is not None and tr.want(req.req_id):
            # the admission span runs arrival -> placement: the queue
            # delay the SLO report attributes to intake, not compute
            t0 = tr.now()
            tr.evt(_obs_events.REQ_ADMIT, req.req_id, "engine",
                   ts=req.arrival_time, dur=t0 - req.arrival_time)
        if req.max_new_tokens == 1:
            # single-token request: prefill answers it outright; it never
            # occupies a decode slot (nor, in paged mode, any pages)
            logits, _ = self._prefill_fn(self.params, {"tokens": prompt})
            first = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
            req.push_device_token(first[0])
            self.stats["prefills"] += 1
            self.engine.continue_when(ArrayOp(first), self._on_prefill_done,
                                      (req, True, None, first, t0),
                                      cr=self.cr_steps,
                                      flags=_step_flags(req.priority))
            return True

        self._ensure_state()
        if self.paged:
            placed = self._prefill_paged(req, prompt)
            if placed is None:
                return False
            first = placed
        else:
            logits, cache1 = self._prefill_fn(self.params, {"tokens": prompt})
            first = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)

        slot = free.pop(0)
        if not self.paged:
            self._cache = jax.tree_util.tree_map(
                lambda sc, pc: sc.at[slot].set(pc), self._cache, cache1)
        req.push_device_token(first[0])
        self.stats["prefills"] += 1
        ctx = None
        if self.speculate:
            # host context for the drafter: the prompt now; the first
            # token when its array completes (prefill continuation), and
            # every accepted run as verify continuations fire
            ctx = [int(t) for t in
                   np.asarray(req.prompt, np.int32).reshape(-1)]
        self._seat_slot(slot, req, first[:, None], plen, ctx=ctx)
        self.engine.continue_when(ArrayOp(first), self._on_prefill_done,
                                  (req, False, slot, first, t0),
                                  cr=self.cr_steps,
                                  flags=_step_flags(req.priority))
        return True

    def _seat_slot(self, slot: int, req: Request, token0: Any, plen: int,
                   *, ctx: Optional[List[int]] = None) -> None:
        """Seat an already-prefilled request into decode slot ``slot`` —
        the role-neutral half of placement, shared by the colocated
        prefill path (``_place``) and remote KV ingestion
        (``serve.disagg.DecodeWorker``). ``token0`` is the request's next
        input token: a device ``(1, 1)`` array from a local prefill, or a
        host int delivered by a remote prefill role. ``req.page_ids``
        must already hold the request's pages (paged mode)."""
        tr = _obs.TRACE
        if tr is not None and tr.want(req.req_id):
            tr.evt(_obs_events.REQ_SEAT, req.req_id, "engine", meta=slot)
        if self.paged:
            self._tables[slot, :] = self.pool.null_page
            self._tables[slot, :len(req.page_ids)] = req.page_ids
            self._tables_dirty.add(slot)
        self._tokens = self._tokens.at[slot].set(token0)
        self._pos[slot] = plen
        self._slots[slot] = req
        self._ctx[slot] = ctx

    def _prefill_paged(self, req: Request,
                       prompt: jax.Array) -> Optional[jax.Array]:
        """Allocate pages, reuse any cached prefix, fill the prompt KV.

        Returns the first-token array (1,), or None when the pool can't
        cover the worst-case footprint (defer — nothing was allocated)."""
        pool, ps = self.pool, self.page_size
        plen = prompt.shape[1]
        n_pages = pages_for(plen + req.max_new_tokens, ps)
        shared = pool.match_prefix(req.prompt)
        owned = pool.alloc(n_pages - len(shared))
        if owned is None:
            return None
        for p in shared:
            pool.retain(p)
        table = shared + owned
        req.page_ids = table
        req.shared_prefix_tokens = len(shared) * ps
        tr = _obs.TRACE
        if tr is not None and tr.want(req.req_id):
            tr.evt(_obs_events.REQ_PAGES_ALLOC, req.req_id, "engine",
                   meta=len(table))

        if shared:
            # prefix hit: shared pages already hold positions [0, m*ps);
            # one chunked suffix-prefill call runs the remaining prompt
            # tokens against them — the shared prefix is never recomputed
            # and writes land in owned pages only (scatter table maps
            # shared entries to the null page)
            pool.stats["prefix_hits"] += 1
            pool.stats["prefix_tokens_reused"] += len(shared) * ps
            start = len(shared) * ps
            tail = plen - start
            scat = np.full(self._table_pages, pool.null_page, np.int32)
            scat[len(shared):len(table)] = table[len(shared):]
            # pad the tail to a page multiple so at most max_pages suffix
            # shapes ever compile; pad rows are causally invisible to the
            # real rows, and the garbage they write at positions >= plen
            # is overwritten by the decode step for that position before
            # anything attends to it
            padded = pages_for(tail, ps) * ps
            suffix = prompt[:, start:]
            if padded != tail:
                suffix = jnp.pad(suffix, ((0, 0), (0, padded - tail)))
            if self.fused:
                # the fused kernel writes rows [0, tail) through the
                # gather table itself — the prefix is page-aligned, so
                # every written entry is request-owned; shared pages and
                # padding rows are untouched (n_valid masks the pad)
                logits, pool.arrays = self._suffix_fn(
                    self.params, pool.arrays, suffix,
                    jnp.asarray([start], jnp.int32),
                    self._padded_table(table)[None],
                    jnp.asarray([tail], jnp.int32))
            else:
                logits, pool.arrays = self._suffix_fn(
                    self.params, pool.arrays, suffix, jnp.int32(start),
                    self._padded_table(table), jnp.asarray(scat))
            self.stats["suffix_steps"] += 1
            self.stats["suffix_tokens"] += tail
            first = jnp.argmax(logits[:, tail - 1], axis=-1).astype(jnp.int32)
        else:
            # cold: dense prefill over the whole prompt, then blit the
            # prompt pages into the pool in one scatter
            logits, cache1 = self._prefill_fn(self.params, {"tokens": prompt})
            first = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
            scatter_table = np.full(self._table_pages, pool.null_page,
                                    np.int32)
            n_prompt_pages = pages_for(plen, ps)
            scatter_table[:n_prompt_pages] = table[:n_prompt_pages]
            pool.arrays = self._scatter_fn(pool.arrays, cache1,
                                           jnp.asarray(scatter_table))
        pool.register_prefix(req.prompt, table)
        return first

    def _padded_table(self, table: Sequence[int]) -> jax.Array:
        out = np.full(self._table_pages, self.pool.null_page, np.int32)
        out[:len(table)] = table
        return jnp.asarray(out)

    def _device_tables(self) -> jax.Array:
        """Device copy of the page tables, updated incrementally.

        Placement and eviction mark their slot dirty; each dispatch then
        uploads only the dirty rows into the resident array instead of
        re-transferring all (S, table_pages) entries. The row-set scatter
        compiles once per distinct dirty-row COUNT — bounded by
        ``max_batch + 1`` shapes over the engine's lifetime. Steady-state
        decode (no placements) re-uses the resident array with zero
        transfer."""
        if self._tables_dev is None:
            self._tables_dev = jnp.asarray(self._tables)
            self._tables_dirty.clear()
        elif self._tables_dirty:
            rows = sorted(self._tables_dirty)
            self._tables_dev = self._tables_dev.at[
                jnp.asarray(rows, jnp.int32)].set(
                jnp.asarray(self._tables[rows]))
            self._tables_dirty.clear()
        return self._tables_dev

    def _on_prefill_done(self, statuses, meta) -> None:
        req, retire_now, slot, first, t0 = meta
        if t0 is not None:
            tr = _obs.TRACE
            if tr is not None:
                tr.evt(_obs_events.REQ_PREFILL, req.req_id, "engine",
                       ts=t0, dur=tr.now() - t0)
        req.on_first_token()
        # deliver the first token (array complete by continuation time, so
        # int() never blocks): streams see it here — before retirement —
        # and stop-sequence matching starts with it
        finished = req.deliver([int(first[0])])
        if retire_now:
            # the budget is complete: the output the engine already paid
            # for is returned even if the deadline just lapsed
            self._retire(req, stopped=finished == "stop")
            return
        # speculative context append. Guard against the slot having been
        # evicted (cancel) and possibly reseated before this fires.
        if (slot is not None and self._ctx[slot] is not None
                and self._slots[slot] is req):
            self._ctx[slot].append(int(first[0]))
        if req.req_state in (RequestState.PREFILLING, RequestState.DECODING):
            if finished == "stop":
                self._finish_slot(slot, req, "stop")
            elif req.past_deadline():
                self._finish_slot(slot, req, "expired")

    # --------------------------------------------------------------- decode
    def _sweep_dead(self, live: List[Tuple[int, Request]]) -> None:
        """Drop cancellations and already-missed deadlines before paying
        for a step (shared by the plain-decode and speculative-verify
        dispatch paths). Deadline expiry is normally noticed by the
        step-completion continuation; this dispatch-side sweep only saves
        the step for work that is already doomed."""
        now = time.monotonic()
        for i, r in list(live):
            if r.req_state is RequestState.CANCELLED:
                self._evict_slot(i, r)
                self.stats["cancelled"] += 1
                live.remove((i, r))
            elif r.past_deadline(now):
                self._evict_slot(i, r)
                self._expire(r)
                live.remove((i, r))

    def _dispatch_step(self) -> bool:
        if self.speculate:
            return self._dispatch_verify()
        live = [(i, r) for i, r in enumerate(self._slots)
                if r is not None and i not in self._draining]
        self._sweep_dead(live)
        if not live:
            return False
        if self.paged and self.fused:
            # n_valid: 1 = write this slot's token, 0 = idle/draining slot
            # (the kernel then writes nothing and outputs zeros for it —
            # strictly tighter than the unfused path, which runs idle
            # lanes too and parks their garbage writes on the null page)
            nv = np.zeros(self.max_batch, np.int32)
            nv[[i for i, _ in live]] = 1
            nxt, self.pool.arrays = self._decode_fn(
                self.params, self.pool.arrays, self._tokens,
                jnp.asarray(self._pos), self._device_tables(),
                jnp.asarray(nv))
        elif self.paged:
            nxt, self.pool.arrays = self._decode_fn(
                self.params, self.pool.arrays, self._tokens,
                jnp.asarray(self._pos), self._device_tables())
        else:
            nxt, self._cache = self._decode_fn(
                self.params, self._cache, self._tokens,
                jnp.asarray(self._pos))
        # the jitted step surfaces per-slot next tokens directly: (S, 1)
        self._tokens = nxt[..., None]                       # (S, 1, 1)
        stepped: List[Tuple[int, Request, bool]] = []
        prio = 0
        for i, r in live:
            r.push_device_token(nxt[i, 0])
            self._pos[i] += 1
            done = r.remaining == 0
            if done:
                self._draining.add(i)
            stepped.append((i, r, done))
            prio = max(prio, r.priority)
        self._inflight += 1
        self.stats["steps"] += 1
        self.stats["slot_steps"] += len(live)
        self.stats["padded_steps"] += self.max_batch - len(live)
        self.stats["max_active"] = max(self.stats["max_active"], len(live))
        tr = _obs.TRACE
        t0 = tr.now() if tr is not None else None
        self.engine.continue_when(ArrayOp(nxt), self._on_step_done,
                                  (stepped, nxt, t0), cr=self.cr_steps,
                                  flags=_step_flags(prio))
        return True

    def _on_step_done(self, statuses, meta) -> None:
        """Per-token bookkeeping when the step's device work is actually
        complete: deliver each slot's token (streams wake here), then
        retire slots that finished — by budget, by a stop-sequence match,
        or by deadline expiry — releasing their pages in this same
        continuation."""
        stepped, nxt, t0 = meta
        self._inflight -= 1
        arr = np.asarray(nxt)
        now = time.monotonic()
        tr = _obs.TRACE
        if tr is not None and t0 is not None:
            # one span per sampled request riding this step: dispatch ->
            # device-complete, the timeline's per-token compute block
            for slot, req, _ in stepped:
                if tr.want(req.req_id):
                    tr.evt(_obs_events.REQ_STEP, req.req_id, "engine",
                           ts=t0, dur=now - t0, meta=slot)
        for slot, req, done in stepped:
            if done:
                self._draining.discard(slot)
            finished = req.deliver([int(arr[slot, 0])])
            state = req.req_state
            if state is RequestState.FINISHED or \
                    state is RequestState.EXPIRED:
                # an earlier continuation (stop/deadline) already finished
                # this request and freed the slot; the delivery above was
                # dropped there too
                continue
            if state is RequestState.CANCELLED:
                # non-draining slots are swept at the next dispatch; a
                # draining slot sees no further dispatch, so free it here
                if done and self._slots[slot] is req:
                    self._evict_slot(slot, req)
                    self.stats["cancelled"] += 1
                continue
            if finished == "stop":
                self._finish_slot(slot, req, "stop")
            elif done:
                # a completed budget outranks a just-lapsed deadline:
                # the full output is in hand, return it
                self._finish_slot(slot, req, "retire")
            elif req.past_deadline(now):
                self._finish_slot(slot, req, "expired")

    # ---------------------------------------------------------- speculative
    def _slot_drafts(self, slot: int, req: Request) -> List[int]:
        """Draft tokens for one slot: the per-request knob caps the
        engine's compiled K, the token budget caps the window (never
        propose past ``remaining - 1`` — the verify step always emits at
        least one real token), and the drafter may return fewer still."""
        k = self.speculate if req.speculate is None \
            else min(req.speculate, self.speculate)
        k = min(k, req.remaining - 1)
        if k <= 0 or self._ctx[slot] is None:
            return []
        return list(self.drafter.draft(self._ctx[slot], k))[:k]

    def _dispatch_verify(self) -> bool:
        """One speculative verify step for every steppable slot.

        Slots whose previous verify continuation has not fired yet are
        excluded (their position/token state is only updated when the
        device step completes); freshly admitted slots join immediately.
        Slots with no usable drafts run with k=0 — the verify step then
        degenerates to plain greedy decode for them (one emitted token),
        so mixed speculative / non-speculative batches share one step.
        """
        live = [(i, r) for i, r in enumerate(self._slots)
                if r is not None and i not in self._verifying]
        self._sweep_dead(live)
        if not live:
            return False
        S, K = self.max_batch, self.speculate
        drafts = np.zeros((S, K), np.int32)
        n_drafts = np.zeros(S, np.int32)
        for i, r in live:
            d = self._slot_drafts(i, r)
            n_drafts[i] = len(d)
            drafts[i, :len(d)] = d
        tokens = jnp.concatenate(
            [self._tokens, jnp.asarray(drafts)[:, None, :]], axis=2)
        if self.fused:
            # no host-built write tables at all: the kernel accept-masks
            # the window to n_valid = 1 + live drafts (0 for idle /
            # still-verifying slots) and routes overflow into the scratch
            # page through the gather table's null padding
            nv = np.zeros(S, np.int32)
            for i, _ in live:
                nv[i] = 1 + n_drafts[i]
            emitted, accepts, self.pool.arrays = self._verify_fn(
                self.params, self.pool.arrays, tokens,
                jnp.asarray(self._pos), self._device_tables(),
                jnp.asarray(nv))
        else:
            # write tables: rows for idle / still-verifying slots stay all
            # null, so their (garbage) lanes scatter into the scratch page
            wtables = np.full((S, self._verify_pages), self.pool.null_page,
                              np.int32)
            for i, r in live:
                wtables[i] = self.pool.write_table(r.page_ids,
                                                   int(self._pos[i]),
                                                   self._verify_pages)
            emitted, accepts, self.pool.arrays = self._verify_fn(
                self.params, self.pool.arrays, tokens,
                jnp.asarray(self._pos), self._device_tables(),
                jnp.asarray(wtables), jnp.asarray(n_drafts))
        self._verifying.update(i for i, _ in live)
        self._inflight += 1
        self.stats["steps"] += 1
        self.stats["verify_steps"] += 1
        self.stats["slot_steps"] += len(live)
        self.stats["padded_steps"] += self.max_batch - len(live)
        self.stats["draft_proposed"] += int(n_drafts.sum())
        self.stats["max_active"] = max(self.stats["max_active"], len(live))
        tr = _obs.TRACE
        t0 = tr.now() if tr is not None else None
        self.engine.continue_when(ArrayOp(emitted), self._on_verify_done,
                                  (live, emitted, accepts, n_drafts, t0),
                                  cr=self.cr_steps,
                                  flags=_step_flags(
                                      max(r.priority for _, r in live)))
        return True

    def _on_verify_done(self, statuses, meta) -> None:
        """Accept bookkeeping — runs when the verify step's arrays are
        actually complete, so the host reads below never block. Mixed
        accept lengths advance each slot independently; a slot whose
        accepted run reaches its token budget retires right here,
        mid-verify, through the same continuation."""
        live, emitted, accepts, n_drafts, t0 = meta
        self._inflight -= 1
        emitted = np.asarray(emitted)
        accepts = np.asarray(accepts)
        now = time.monotonic()
        tr = _obs.TRACE
        if tr is not None and t0 is not None:
            for i, req in live:
                if tr.want(req.req_id):
                    tr.evt(_obs_events.REQ_STEP, req.req_id, "engine",
                           ts=t0, dur=now - t0, meta=i)
        upd_slots: List[int] = []
        upd_tokens: List[int] = []
        for i, req in live:
            state = req.req_state
            # stale entry: an earlier continuation (prefill stop/deadline)
            # already finished this request and freed the slot — which may
            # since have been reseated (possibly with its own verify in
            # flight). Touch NOTHING keyed by the slot index then.
            stale = self._slots[i] is not req
            if not stale:
                self._verifying.discard(i)
            if state is RequestState.FINISHED or \
                    state is RequestState.EXPIRED:
                continue
            if state is RequestState.CANCELLED:
                # cancel mid-verify: the whole accepted run is dropped —
                # deliver() would refuse it anyway (cancel() returned
                # while this step was in flight), so don't even push
                if not stale:
                    self._evict_slot(i, req)
                    self.stats["cancelled"] += 1
                continue
            a = int(accepts[i])
            n_emit = min(a + 1, req.remaining)   # a <= remaining-1 by cap
            toks = [int(t) for t in emitted[i, :n_emit]]
            for t in toks:
                req.push_device_token(t)
            req.draft_tokens_proposed += int(n_drafts[i])
            req.draft_tokens_accepted += a
            self.stats["draft_accepted"] += a
            self.stats["spec_tokens"] += n_emit
            # the whole accepted run delivers in one call: streams see a
            # burst, stop matching scans it token by token
            finished = req.deliver(toks)
            if self._ctx[i] is not None:
                self._ctx[i].extend(toks)
            self._pos[i] += n_emit
            if finished == "stop":
                self._finish_slot(i, req, "stop")
            elif req.remaining == 0:
                # completed budget outranks a just-lapsed deadline
                self._finish_slot(i, req, "retire")
            elif req.past_deadline(now):
                self._finish_slot(i, req, "expired")
            else:
                upd_slots.append(i)
                upd_tokens.append(toks[-1])
        if upd_slots:
            # fixed-shape masked update (a variable-length index scatter
            # would recompile per distinct count of advancing slots)
            mask = np.zeros(self.max_batch, bool)
            vals = np.zeros(self.max_batch, np.int32)
            mask[upd_slots] = True
            vals[upd_slots] = upd_tokens
            self._tokens = jnp.where(
                jnp.asarray(mask)[:, None, None],
                jnp.asarray(vals)[:, None, None], self._tokens)

    def _finish_slot(self, slot: Optional[int], req: Request,
                     kind: str) -> None:
        """Terminal transition from a step-completion continuation: free
        the slot — releasing the request's pages in this same continuation
        — and finish the request (``kind``: "retire" for budget, "stop"
        for a stop-sequence match, "expired" for a missed deadline). A
        later step already in flight for this slot may still write the
        released pages: the same stale-write window the cancel path
        tolerates (device dispatch order plus causal masking keep the
        garbage invisible before it is overwritten)."""
        if slot is not None and self._slots[slot] is req:
            self._draining.discard(slot)
            self._verifying.discard(slot)
            self._evict_slot(slot, req)
        else:
            # slot already freed (or reseated) by an earlier path — make
            # sure the pages still can't leak (release is idempotent)
            self._release_pages(req)
        if kind == "expired":
            self._expire(req)
        else:
            self._retire(req, stopped=kind == "stop")

    def _evict_slot(self, slot: int, req: Request) -> None:
        """Free a slot and return the request's pages to the pool (every
        exit path — retirement, cancellation mid-decode or mid-drain —
        funnels through here, so pages can never leak)."""
        self._slots[slot] = None
        self._pos[slot] = 0
        self._ctx[slot] = None
        if self.paged:
            self._tables[slot, :] = self.pool.null_page
            self._tables_dirty.add(slot)
        self._release_pages(req)

    def _release_pages(self, req: Request) -> None:
        if self.paged and req.page_ids:
            tr = _obs.TRACE
            if tr is not None and tr.want(req.req_id):
                tr.evt(_obs_events.REQ_PAGES_RELEASE, req.req_id, "engine",
                       meta=len(req.page_ids))
            self.pool.release(req.page_ids)
            req.page_ids = []

    def _retire(self, req: Request, stopped: bool = False) -> None:
        if not req.retire():
            # lost the race to a concurrent cancel() (an idempotent
            # re-retire of an already-finished request counts nothing)
            if req.req_state is RequestState.CANCELLED:
                self.stats["cancelled"] += 1
            return
        if stopped:
            self.stats["stopped"] += 1
        with self._lock:
            self._retired.append(req)
        self.stats["retired"] += 1

    def _expire(self, req: Request) -> None:
        """Deadline-expired: fail the request (partial tokens kept)."""
        if req.expire():
            self.stats["expired"] += 1
        elif req.req_state is RequestState.CANCELLED:
            self.stats["cancelled"] += 1

    # ----------------------------------------------------------------- loop
    def step(self) -> bool:
        """One loop iteration: admit, dispatch (windowed), progress.

        Returns True if any work was started or completed.
        """
        admitted = self._admit()
        dispatched = False
        if self._inflight < self.max_inflight:
            dispatched = self._dispatch_step()
        before = self.stats["retired"]
        self.engine.tick()   # discover step completions, run continuations
        return bool(admitted) or dispatched or \
            self.stats["retired"] != before

    @property
    def idle(self) -> bool:
        """Nothing queued, occupied, or in flight — including prefill/step
        continuations still registered on the step CR (a single-token
        request's whole life is one prefill continuation)."""
        return (not self._pending_intake() and self.active == 0
                and self._inflight == 0
                and self.cr_steps.active_count == 0)

    def _pending_intake(self) -> bool:
        return bool(self.batcher.queued or self.batcher.cr.active_count)

    def run(self, timeout: Optional[float] = None,
            idle_sleep: float = 5e-5, until=None) -> List[Request]:
        """Drive the loop until intake is closed and everything retired
        (or until the ``until()`` predicate flips true, when given —
        benchmarks use it to serve a fixed workload on a warm engine)."""
        deadline = None if timeout is None else time.monotonic() + timeout
        done = until if until is not None else \
            (lambda: self.batcher.closed and self.idle)
        while not done():
            if deadline is not None and time.monotonic() > deadline:
                raise TimeoutError(
                    f"serve loop timed out: active={self.active} "
                    f"inflight={self._inflight} queued={self.batcher.queued}")
            if not self.step():
                time.sleep(idle_sleep)
        return self.retired

    def _metrics_flat(self) -> dict:
        """Flat metrics dict — subclasses extend this before it is
        wrapped into the typed ``ServeMetrics`` by ``metrics()``."""
        out = summarize(self.retired)
        out.update(self.stats)
        out["paged"] = self.paged
        out["fused"] = self.fused
        out["speculate"] = self.speculate
        if self.stats["draft_proposed"]:
            # engine-wide accept rate (includes cancelled requests;
            # summarize() reports the finished-request rate)
            out["accept_rate_engine"] = (self.stats["draft_accepted"]
                                         / self.stats["draft_proposed"])
        if self.paged:
            out.update(self.pool.metrics())
        return out

    def metrics(self) -> ServeMetrics:
        return ServeMetrics.from_flat(self._metrics_flat())

    def shutdown(self) -> None:
        self.batcher.close()
        if self._own_engine:
            self.engine.shutdown()


def serve_requests(cfg: ModelConfig, params: Any,
                   requests: Sequence[Request], *,
                   max_batch: int = 4, max_cache_len: int = 256,
                   timeout: float = 300.0,
                   **kwargs: Any) -> List[Request]:
    """Convenience: serve a fixed request list to completion, in order."""
    eng = ServeEngine(cfg, params, max_batch=max_batch,
                      max_cache_len=max_cache_len, **kwargs)
    try:
        for r in requests:
            eng.submit(r)
        eng.close_intake()
        eng.run(timeout=timeout)
    finally:
        eng.shutdown()
    return list(requests)
