"""Continuation-driven continuous-batching decode engine.

The serving analogue of the paper's completion-notification claim: instead
of an application-space synchronous loop (``steps.greedy_generate`` — run a
static batch to the longest member, block, repeat), the decode loop keeps a
fixed set of *slots*, each holding one in-flight sequence with its own KV
cache and position:

* **decode** — one vmapped decode step advances every occupied slot by one
  token (per-slot positions, donated cache). The step's next-token
  ``jax.Array`` is wrapped in an ``ArrayOp`` whose continuation does the
  bookkeeping when the device work *actually* finishes: records
  first-token latency, retires sequences that reached their token budget
  (freeing their slots), and releases the in-flight window so the loop can
  dispatch further ahead. The Python loop never blocks on device work.
* **admission** — new requests queue on the ``Batcher``'s
  ``poll_only + enqueue_complete`` CR (paper §3.5) and are admitted into
  free slots at step boundaries; their prefill dispatches while previously
  issued decode steps are still in flight on device, so prefill of new
  requests overlaps in-flight decode.
* **retirement** — a finished ``Request`` is itself a ``Completable``:
  its continuation fires for whoever attached one, and ``request.wait()``
  unblocks the submitting client.

**Memory** comes in two flavours:

* *paged* (default where supported, see ``serve.kv_cache``) — slots index
  into a shared ``PagePool`` through per-request page tables; a request
  holds ``ceil((prompt + max_new) / page_size)`` pages instead of a full
  ``max_cache_len`` lane, so at equal pool memory the engine sustains a
  larger effective batch. Prompts sharing a page-aligned prefix with a
  resident request map those pages read-only and skip re-prefilling them;
  pages return to the pool in the retirement continuation (the paper's
  callback-driven lifecycle owns deallocation too).
* *dense* (``paged=False``, and automatically for SSM/hybrid/audio/SWA
  configs) — the original per-slot stacked cache, each slot padded to
  ``max_cache_len``.

Continuous batching beats static batching whenever output lengths vary or
arrivals straggle: finished slots are refilled immediately instead of
padding along until the longest member of a static batch completes.
"""
from __future__ import annotations

import threading
import time
from typing import Any, List, Optional, Sequence, Set, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ArrayOp, Engine, Scheduler
from repro.models import lm
from repro.models.common import AUDIO, ModelConfig
from repro.serve.batcher import Batcher
from repro.serve.kv_cache import PagePool, paged_supported, pages_for
from repro.serve.request import Request, RequestState, summarize
from repro.serve.steps import (make_decode_step, make_paged_decode_step,
                               make_paged_suffix_step, make_prefill_scatter,
                               make_prefill_step)


class ServeEngine:
    """Continuous-batching engine over ``max_batch`` decode slots.

    Single-consumer: exactly one thread drives ``step()``/``run()`` (the
    decode loop); any thread may ``submit()``. Slot state is touched only
    by the loop thread — continuations registered here run on it because
    the CRs use the default ``thread=application`` policy and the loop is
    the only thread that calls into the engine.

    Paged-mode knobs: ``page_size`` tokens per KV page, ``max_seq_len``
    (prompt + generation bound per request, default ``max_cache_len``),
    ``total_pages`` in the pool (default ``max_batch * ceil(max_seq_len /
    page_size)`` — shrink it, or raise ``max_batch``, to oversubscribe).
    """

    def __init__(self, cfg: ModelConfig, params: Any, *,
                 max_batch: int = 4,
                 max_cache_len: int = 256,
                 max_inflight: int = 2,
                 engine: Optional[Engine] = None,
                 scheduler: Union[str, Scheduler] = "fifo",
                 paged: Optional[bool] = None,
                 page_size: int = 16,
                 total_pages: Optional[int] = None,
                 max_seq_len: Optional[int] = None) -> None:
        if cfg.family == AUDIO:
            raise NotImplementedError(
                "ServeEngine drives token-in/token-out LM decode; audio "
                "enc-dec serving still goes through serve.steps directly")
        if paged is None:
            paged = paged_supported(cfg)
        elif paged and not paged_supported(cfg):
            raise ValueError(
                f"paged KV cache unsupported for {cfg.name!r} "
                "(needs dense/MoE family, scan_layers, no sliding window)")
        self.cfg = cfg
        self.params = params
        self.max_batch = int(max_batch)
        self.max_cache_len = int(max_cache_len)
        self.max_inflight = max(1, int(max_inflight))
        self.paged = bool(paged)
        self._own_engine = engine is None
        self.engine = engine if engine is not None else \
            Engine(scheduler=scheduler)
        self.batcher = Batcher(self.engine)
        # decode-step completions: enqueue_complete so even an
        # already-materialized step flows through the continuation path
        self.cr_steps = self.engine.continue_init(
            {"mpi_continue_enqueue_complete": True})

        S = self.max_batch
        self.pool: Optional[PagePool] = None
        if self.paged:
            self.page_size = int(page_size)
            self.max_seq_len = int(max_seq_len or max_cache_len)
            self.max_pages = pages_for(self.max_seq_len, self.page_size)
            # padded gather width: every per-slot view is max_pages pages
            self._padded_len = self.max_pages * self.page_size
            n_pool = int(total_pages) if total_pages is not None \
                else S * self.max_pages
            self.pool = PagePool(cfg, n_pool, self.page_size)
            self._tables = np.full((S, self.max_pages), self.pool.null_page,
                                   np.int32)
            self._prefill_fn = jax.jit(
                make_prefill_step(cfg, self._padded_len))
            self._decode_fn = jax.jit(
                make_paged_decode_step(cfg, self.page_size),
                donate_argnums=(1,))
            self._suffix_fn = jax.jit(
                make_paged_suffix_step(cfg, self.page_size),
                donate_argnums=(1,))
            self._scatter_fn = jax.jit(
                make_prefill_scatter(cfg, self.page_size),
                donate_argnums=(0,))
        else:
            self._prefill_fn = jax.jit(
                make_prefill_step(cfg, self.max_cache_len))
            decode_one = make_decode_step(cfg)

            def _batched(params, caches, tokens, positions):
                return jax.vmap(decode_one,
                                in_axes=(None, 0, 0, 0))(params, caches,
                                                         tokens, positions)

            self._decode_fn = jax.jit(_batched, donate_argnums=(1,))

        # -- slot state (loop thread only) --
        self._slots: List[Optional[Request]] = [None] * S
        self._draining: Set[int] = set()      # token budget met, step in flight
        self._pos = np.zeros(S, np.int32)     # next write position per slot
        self._cache: Any = None               # dense mode: stacked caches
        self._tokens: Any = None              # next input tokens (S, 1, 1)
        self._inflight = 0                    # dispatched, not-yet-complete steps
        self._stalled_at: Optional[int] = None  # pages_in_use at last deferral
        self._retired: List[Request] = []
        self._lock = threading.Lock()         # guards _retired for readers
        self.stats = {"steps": 0, "prefills": 0, "retired": 0,
                      "slot_steps": 0, "padded_steps": 0, "cancelled": 0,
                      "suffix_steps": 0, "suffix_tokens": 0, "deferred": 0,
                      "max_active": 0}

    # ------------------------------------------------------------- clients
    def submit(self, request: Request) -> Request:
        """Thread-safe request intake (delegates to the Batcher CR)."""
        if self.paged:
            plen = int(np.asarray(request.prompt).reshape(-1).shape[0])
            total = plen + request.max_new_tokens
            if total > self.max_seq_len:
                raise ValueError(
                    f"request needs {total} tokens > max_seq_len="
                    f"{self.max_seq_len}")
            if pages_for(total, self.page_size) > self.pool.total_pages:
                raise ValueError(
                    f"request needs more pages than the pool holds "
                    f"({self.pool.total_pages})")
        return self.batcher.submit(request)

    def close_intake(self) -> None:
        self.batcher.close()

    @property
    def retired(self) -> List[Request]:
        with self._lock:
            return list(self._retired)

    # ---------------------------------------------------------- slot state
    def _ensure_state(self) -> None:
        if self._tokens is None:
            self._tokens = jnp.zeros((self.max_batch, 1, 1), jnp.int32)
        if self.paged:
            self.pool.ensure_arrays()
        elif self._cache is None:
            base = lm.init_cache(self.cfg, 1, self.max_cache_len)
            self._cache = jax.tree_util.tree_map(
                lambda x: jnp.stack([x] * self.max_batch), base)

    def _free_slots(self) -> List[int]:
        return [i for i, r in enumerate(self._slots) if r is None]

    @property
    def active(self) -> int:
        return sum(1 for r in self._slots if r is not None)

    # ------------------------------------------------------------ admission
    def _admit(self) -> int:
        # after a capacity deferral, don't re-pop and re-hash the queue
        # every loop spin — admission can only succeed once a retirement
        # or cancellation has returned pages to the pool
        if self._stalled_at is not None:
            if self.pool.pages_in_use >= self._stalled_at:
                return 0
            self._stalled_at = None
        free = self._free_slots()
        reqs = self.batcher.admit(len(free))
        admitted = 0
        for i, req in enumerate(reqs):
            if not self._place(req, free):
                # page pool can't cover the request's worst case yet:
                # return it (and everything behind it, preserving arrival
                # order) to the queue head; stats count stall events, not
                # retries
                self.stats["deferred"] += 1
                self._stalled_at = self.pool.pages_in_use
                for r in reversed(reqs[i:]):
                    self.batcher.requeue(r)
                break
            admitted += 1
        return admitted

    def _place(self, req: Request, free: List[int]) -> bool:
        """Prefill ``req`` and seat it in a slot. False = defer (paged
        capacity); True = placed (or answered outright by prefill)."""
        prompt = jnp.asarray(req.prompt, jnp.int32)[None, :]
        plen = prompt.shape[1]
        if req.max_new_tokens == 1:
            # single-token request: prefill answers it outright; it never
            # occupies a decode slot (nor, in paged mode, any pages)
            logits, _ = self._prefill_fn(self.params, {"tokens": prompt})
            first = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
            req.push_device_token(first[0])
            self.stats["prefills"] += 1
            self.engine.continue_when(ArrayOp(first), self._on_prefill_done,
                                      (req, True), cr=self.cr_steps)
            return True

        self._ensure_state()
        if self.paged:
            placed = self._prefill_paged(req, prompt)
            if placed is None:
                return False
            first = placed
        else:
            logits, cache1 = self._prefill_fn(self.params, {"tokens": prompt})
            first = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)

        slot = free.pop(0)
        if not self.paged:
            self._cache = jax.tree_util.tree_map(
                lambda sc, pc: sc.at[slot].set(pc), self._cache, cache1)
        else:
            self._tables[slot, :] = self.pool.null_page
            self._tables[slot, :len(req.page_ids)] = req.page_ids
        req.push_device_token(first[0])
        self.stats["prefills"] += 1
        self._tokens = self._tokens.at[slot].set(first[:, None])
        self._pos[slot] = plen
        self._slots[slot] = req
        self.engine.continue_when(ArrayOp(first), self._on_prefill_done,
                                  (req, False), cr=self.cr_steps)
        return True

    def _prefill_paged(self, req: Request,
                       prompt: jax.Array) -> Optional[jax.Array]:
        """Allocate pages, reuse any cached prefix, fill the prompt KV.

        Returns the first-token array (1,), or None when the pool can't
        cover the worst-case footprint (defer — nothing was allocated)."""
        pool, ps = self.pool, self.page_size
        plen = prompt.shape[1]
        n_pages = pages_for(plen + req.max_new_tokens, ps)
        shared = pool.match_prefix(req.prompt)
        owned = pool.alloc(n_pages - len(shared))
        if owned is None:
            return None
        for p in shared:
            pool.retain(p)
        table = shared + owned
        req.page_ids = table
        req.shared_prefix_tokens = len(shared) * ps

        if shared:
            # prefix hit: shared pages already hold positions [0, m*ps);
            # one chunked suffix-prefill call runs the remaining prompt
            # tokens against them — the shared prefix is never recomputed
            # and writes land in owned pages only (scatter table maps
            # shared entries to the null page)
            pool.stats["prefix_hits"] += 1
            pool.stats["prefix_tokens_reused"] += len(shared) * ps
            start = len(shared) * ps
            tail = plen - start
            scat = np.full(self.max_pages, pool.null_page, np.int32)
            scat[len(shared):len(table)] = table[len(shared):]
            # pad the tail to a page multiple so at most max_pages suffix
            # shapes ever compile; pad rows are causally invisible to the
            # real rows, and the garbage they write at positions >= plen
            # is overwritten by the decode step for that position before
            # anything attends to it
            padded = pages_for(tail, ps) * ps
            suffix = prompt[:, start:]
            if padded != tail:
                suffix = jnp.pad(suffix, ((0, 0), (0, padded - tail)))
            logits, pool.arrays = self._suffix_fn(
                self.params, pool.arrays, suffix, jnp.int32(start),
                self._padded_table(table), jnp.asarray(scat))
            self.stats["suffix_steps"] += 1
            self.stats["suffix_tokens"] += tail
            first = jnp.argmax(logits[:, tail - 1], axis=-1).astype(jnp.int32)
        else:
            # cold: dense prefill over the whole prompt, then blit the
            # prompt pages into the pool in one scatter
            logits, cache1 = self._prefill_fn(self.params, {"tokens": prompt})
            first = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
            scatter_table = np.full(self.max_pages, pool.null_page, np.int32)
            n_prompt_pages = pages_for(plen, ps)
            scatter_table[:n_prompt_pages] = table[:n_prompt_pages]
            pool.arrays = self._scatter_fn(pool.arrays, cache1,
                                           jnp.asarray(scatter_table))
        pool.register_prefix(req.prompt, table)
        return first

    def _padded_table(self, table: Sequence[int]) -> jax.Array:
        out = np.full(self.max_pages, self.pool.null_page, np.int32)
        out[:len(table)] = table
        return jnp.asarray(out)

    def _on_prefill_done(self, statuses, meta: Tuple[Request, bool]) -> None:
        req, retire_now = meta
        req.on_first_token()
        if retire_now:
            self._retire(req)

    # --------------------------------------------------------------- decode
    def _dispatch_step(self) -> bool:
        live = [(i, r) for i, r in enumerate(self._slots)
                if r is not None and i not in self._draining]
        # drop cancellations before paying for a step
        for i, r in list(live):
            if r.req_state is RequestState.CANCELLED:
                self._evict_slot(i, r)
                self.stats["cancelled"] += 1
                live.remove((i, r))
        if not live:
            return False
        if self.paged:
            logits, self.pool.arrays = self._decode_fn(
                self.params, self.pool.arrays, self._tokens,
                jnp.asarray(self._pos), jnp.asarray(self._tables))
        else:
            logits, self._cache = self._decode_fn(
                self.params, self._cache, self._tokens,
                jnp.asarray(self._pos))
        # per-slot logits are (1, 1, V); stacked (S, 1, 1, V)
        nxt = jnp.argmax(logits[:, :, -1, :], axis=-1).astype(jnp.int32)
        self._tokens = nxt[..., None]                       # (S, 1, 1)
        finishing: List[Tuple[int, Request]] = []
        for i, r in live:
            r.push_device_token(nxt[i, 0])
            self._pos[i] += 1
            if r.remaining == 0:
                self._draining.add(i)
                finishing.append((i, r))
        self._inflight += 1
        self.stats["steps"] += 1
        self.stats["slot_steps"] += len(live)
        self.stats["padded_steps"] += self.max_batch - len(live)
        self.stats["max_active"] = max(self.stats["max_active"], len(live))
        self.engine.continue_when(ArrayOp(nxt), self._on_step_done,
                                  finishing, cr=self.cr_steps)
        return True

    def _on_step_done(self, statuses,
                      finishing: List[Tuple[int, Request]]) -> None:
        self._inflight -= 1
        for slot, req in finishing:
            self._draining.discard(slot)
            self._evict_slot(slot, req)
            self._retire(req)

    def _evict_slot(self, slot: int, req: Request) -> None:
        """Free a slot and return the request's pages to the pool (every
        exit path — retirement, cancellation mid-decode or mid-drain —
        funnels through here, so pages can never leak)."""
        self._slots[slot] = None
        self._pos[slot] = 0
        if self.paged:
            self._tables[slot, :] = self.pool.null_page
        self._release_pages(req)

    def _release_pages(self, req: Request) -> None:
        if self.paged and req.page_ids:
            self.pool.release(req.page_ids)
            req.page_ids = []

    def _retire(self, req: Request) -> None:
        if not req.retire():      # lost the race to a concurrent cancel()
            self.stats["cancelled"] += 1
            return
        with self._lock:
            self._retired.append(req)
        self.stats["retired"] += 1

    # ----------------------------------------------------------------- loop
    def step(self) -> bool:
        """One loop iteration: admit, dispatch (windowed), progress.

        Returns True if any work was started or completed.
        """
        admitted = self._admit()
        dispatched = False
        if self._inflight < self.max_inflight:
            dispatched = self._dispatch_step()
        before = self.stats["retired"]
        self.engine.tick()   # discover step completions, run continuations
        return bool(admitted) or dispatched or \
            self.stats["retired"] != before

    @property
    def idle(self) -> bool:
        """Nothing queued, occupied, or in flight — including prefill/step
        continuations still registered on the step CR (a single-token
        request's whole life is one prefill continuation)."""
        return (not self._pending_intake() and self.active == 0
                and self._inflight == 0
                and self.cr_steps.active_count == 0)

    def _pending_intake(self) -> bool:
        return bool(self.batcher.queued or self.batcher.cr.active_count)

    def run(self, timeout: Optional[float] = None,
            idle_sleep: float = 5e-5, until=None) -> List[Request]:
        """Drive the loop until intake is closed and everything retired
        (or until the ``until()`` predicate flips true, when given —
        benchmarks use it to serve a fixed workload on a warm engine)."""
        deadline = None if timeout is None else time.monotonic() + timeout
        done = until if until is not None else \
            (lambda: self.batcher.closed and self.idle)
        while not done():
            if deadline is not None and time.monotonic() > deadline:
                raise TimeoutError(
                    f"serve loop timed out: active={self.active} "
                    f"inflight={self._inflight} queued={self.batcher.queued}")
            if not self.step():
                time.sleep(idle_sleep)
        return self.retired

    def metrics(self) -> dict:
        out = summarize(self.retired)
        out.update(self.stats)
        out["paged"] = self.paged
        if self.paged:
            out.update(self.pool.metrics())
        return out

    def shutdown(self) -> None:
        self.batcher.close()
        if self._own_engine:
            self.engine.shutdown()


def serve_requests(cfg: ModelConfig, params: Any,
                   requests: Sequence[Request], *,
                   max_batch: int = 4, max_cache_len: int = 256,
                   timeout: float = 300.0,
                   **kwargs: Any) -> List[Request]:
    """Convenience: serve a fixed request list to completion, in order."""
    eng = ServeEngine(cfg, params, max_batch=max_batch,
                      max_cache_len=max_cache_len, **kwargs)
    try:
        for r in requests:
            eng.submit(r)
        eng.close_intake()
        eng.run(timeout=timeout)
    finally:
        eng.shutdown()
    return list(requests)
