"""Continuation-driven continuous-batching decode engine.

The serving analogue of the paper's completion-notification claim: instead
of an application-space synchronous loop (``steps.greedy_generate`` — run a
static batch to the longest member, block, repeat), the decode loop keeps a
fixed set of *slots*, each holding one in-flight sequence with its own KV
cache and position:

* **decode** — one vmapped decode step advances every occupied slot by one
  token (per-slot positions, donated stacked cache). The step's next-token
  ``jax.Array`` is wrapped in an ``ArrayOp`` whose continuation does the
  bookkeeping when the device work *actually* finishes: records
  first-token latency, retires sequences that reached their token budget
  (freeing their slots), and releases the in-flight window so the loop can
  dispatch further ahead. The Python loop never blocks on device work.
* **admission** — new requests queue on the ``Batcher``'s
  ``poll_only + enqueue_complete`` CR (paper §3.5) and are admitted into
  free slots at step boundaries; their prefill dispatches while previously
  issued decode steps are still in flight on device, so prefill of new
  requests overlaps in-flight decode.
* **retirement** — a finished ``Request`` is itself a ``Completable``:
  its continuation fires for whoever attached one, and ``request.wait()``
  unblocks the submitting client.

Continuous batching beats static batching whenever output lengths vary or
arrivals straggle: finished slots are refilled immediately instead of
padding along until the longest member of a static batch completes.
"""
from __future__ import annotations

import threading
import time
from typing import Any, List, Optional, Sequence, Set, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ArrayOp, Engine, Scheduler
from repro.models import lm
from repro.models.common import AUDIO, ModelConfig
from repro.serve.batcher import Batcher
from repro.serve.request import Request, RequestState, summarize
from repro.serve.steps import make_decode_step, make_prefill_step


class ServeEngine:
    """Continuous-batching engine over ``max_batch`` decode slots.

    Single-consumer: exactly one thread drives ``step()``/``run()`` (the
    decode loop); any thread may ``submit()``. Slot state is touched only
    by the loop thread — continuations registered here run on it because
    the CRs use the default ``thread=application`` policy and the loop is
    the only thread that calls into the engine.
    """

    def __init__(self, cfg: ModelConfig, params: Any, *,
                 max_batch: int = 4,
                 max_cache_len: int = 256,
                 max_inflight: int = 2,
                 engine: Optional[Engine] = None,
                 scheduler: Union[str, Scheduler] = "fifo") -> None:
        if cfg.family == AUDIO:
            raise NotImplementedError(
                "ServeEngine drives token-in/token-out LM decode; audio "
                "enc-dec serving still goes through serve.steps directly")
        self.cfg = cfg
        self.params = params
        self.max_batch = int(max_batch)
        self.max_cache_len = int(max_cache_len)
        self.max_inflight = max(1, int(max_inflight))
        self._own_engine = engine is None
        self.engine = engine if engine is not None else \
            Engine(scheduler=scheduler)
        self.batcher = Batcher(self.engine)
        # decode-step completions: enqueue_complete so even an
        # already-materialized step flows through the continuation path
        self.cr_steps = self.engine.continue_init(
            {"mpi_continue_enqueue_complete": True})

        self._prefill_fn = jax.jit(make_prefill_step(cfg, self.max_cache_len))
        decode_one = make_decode_step(cfg)

        def _batched(params, caches, tokens, positions):
            return jax.vmap(decode_one,
                            in_axes=(None, 0, 0, 0))(params, caches, tokens,
                                                     positions)

        self._decode_fn = jax.jit(_batched, donate_argnums=(1,))

        # -- slot state (loop thread only) --
        S = self.max_batch
        self._slots: List[Optional[Request]] = [None] * S
        self._draining: Set[int] = set()      # token budget met, step in flight
        self._pos = np.zeros(S, np.int32)     # next write position per slot
        self._cache: Any = None               # stacked per-slot caches (S, ...)
        self._tokens: Any = None              # next input tokens (S, 1, 1)
        self._inflight = 0                    # dispatched, not-yet-complete steps
        self._retired: List[Request] = []
        self._lock = threading.Lock()         # guards _retired for readers
        self.stats = {"steps": 0, "prefills": 0, "retired": 0,
                      "slot_steps": 0, "padded_steps": 0, "cancelled": 0}

    # ------------------------------------------------------------- clients
    def submit(self, request: Request) -> Request:
        """Thread-safe request intake (delegates to the Batcher CR)."""
        return self.batcher.submit(request)

    def close_intake(self) -> None:
        self.batcher.close()

    @property
    def retired(self) -> List[Request]:
        with self._lock:
            return list(self._retired)

    # ---------------------------------------------------------- slot state
    def _ensure_state(self) -> None:
        if self._cache is not None:
            return
        base = lm.init_cache(self.cfg, 1, self.max_cache_len)
        self._cache = jax.tree_util.tree_map(
            lambda x: jnp.stack([x] * self.max_batch), base)
        self._tokens = jnp.zeros((self.max_batch, 1, 1), jnp.int32)

    def _free_slots(self) -> List[int]:
        return [i for i, r in enumerate(self._slots) if r is None]

    @property
    def active(self) -> int:
        return sum(1 for r in self._slots if r is not None)

    # ------------------------------------------------------------ admission
    def _admit(self) -> int:
        free = self._free_slots()
        reqs = self.batcher.admit(len(free))
        for req in reqs:
            slot = free.pop(0)
            prompt = jnp.asarray(req.prompt, jnp.int32)[None, :]
            logits, cache1 = self._prefill_fn(self.params, {"tokens": prompt})
            first = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)  # (1,)
            req.push_device_token(first[0])
            self.stats["prefills"] += 1
            if req.remaining == 0:
                # single-token request: prefill answers it outright; it
                # never occupies a decode slot
                free.insert(0, slot)
                self.engine.continue_when(ArrayOp(first),
                                          self._on_prefill_done,
                                          (req, True), cr=self.cr_steps)
                continue
            self._ensure_state()
            self._cache = jax.tree_util.tree_map(
                lambda sc, pc: sc.at[slot].set(pc), self._cache, cache1)
            self._tokens = self._tokens.at[slot].set(first[:, None])
            self._pos[slot] = prompt.shape[1]
            self._slots[slot] = req
            self.engine.continue_when(ArrayOp(first), self._on_prefill_done,
                                      (req, False), cr=self.cr_steps)
        return len(reqs)

    def _on_prefill_done(self, statuses, meta: Tuple[Request, bool]) -> None:
        req, retire_now = meta
        req.on_first_token()
        if retire_now:
            self._retire(req)

    # --------------------------------------------------------------- decode
    def _dispatch_step(self) -> bool:
        live = [(i, r) for i, r in enumerate(self._slots)
                if r is not None and i not in self._draining]
        # drop cancellations before paying for a step
        for i, r in list(live):
            if r.req_state is RequestState.CANCELLED:
                self._slots[i] = None
                self.stats["cancelled"] += 1
                live.remove((i, r))
        if not live:
            return False
        logits, self._cache = self._decode_fn(
            self.params, self._cache, self._tokens, jnp.asarray(self._pos))
        # per-slot logits are (1, 1, V); stacked (S, 1, 1, V)
        nxt = jnp.argmax(logits[:, :, -1, :], axis=-1).astype(jnp.int32)
        self._tokens = nxt[..., None]                       # (S, 1, 1)
        finishing: List[Tuple[int, Request]] = []
        for i, r in live:
            r.push_device_token(nxt[i, 0])
            self._pos[i] += 1
            if r.remaining == 0:
                self._draining.add(i)
                finishing.append((i, r))
        self._inflight += 1
        self.stats["steps"] += 1
        self.stats["slot_steps"] += len(live)
        self.stats["padded_steps"] += self.max_batch - len(live)
        self.engine.continue_when(ArrayOp(nxt), self._on_step_done,
                                  finishing, cr=self.cr_steps)
        return True

    def _on_step_done(self, statuses,
                      finishing: List[Tuple[int, Request]]) -> None:
        self._inflight -= 1
        for slot, req in finishing:
            self._slots[slot] = None
            self._draining.discard(slot)
            self._retire(req)

    def _retire(self, req: Request) -> None:
        if req.req_state is RequestState.CANCELLED:
            return
        req.retire()
        with self._lock:
            self._retired.append(req)
        self.stats["retired"] += 1

    # ----------------------------------------------------------------- loop
    def step(self) -> bool:
        """One loop iteration: admit, dispatch (windowed), progress.

        Returns True if any work was started or completed.
        """
        admitted = self._admit()
        dispatched = False
        if self._inflight < self.max_inflight:
            dispatched = self._dispatch_step()
        before = self.stats["retired"]
        self.engine.tick()   # discover step completions, run continuations
        return bool(admitted) or dispatched or \
            self.stats["retired"] != before

    @property
    def idle(self) -> bool:
        """Nothing queued, occupied, or in flight — including prefill/step
        continuations still registered on the step CR (a single-token
        request's whole life is one prefill continuation)."""
        return (not self._pending_intake() and self.active == 0
                and self._inflight == 0
                and self.cr_steps.active_count == 0)

    def _pending_intake(self) -> bool:
        return bool(self.batcher.queued or self.batcher.cr.active_count)

    def run(self, timeout: Optional[float] = None,
            idle_sleep: float = 5e-5, until=None) -> List[Request]:
        """Drive the loop until intake is closed and everything retired
        (or until the ``until()`` predicate flips true, when given —
        benchmarks use it to serve a fixed workload on a warm engine)."""
        deadline = None if timeout is None else time.monotonic() + timeout
        done = until if until is not None else \
            (lambda: self.batcher.closed and self.idle)
        while not done():
            if deadline is not None and time.monotonic() > deadline:
                raise TimeoutError(
                    f"serve loop timed out: active={self.active} "
                    f"inflight={self._inflight} queued={self.batcher.queued}")
            if not self.step():
                time.sleep(idle_sleep)
        return self.retired

    def metrics(self) -> dict:
        out = summarize(self.retired)
        out.update(self.stats)
        return out

    def shutdown(self) -> None:
        self.batcher.close()
        if self._own_engine:
            self.engine.shutdown()


def serve_requests(cfg: ModelConfig, params: Any,
                   requests: Sequence[Request], *,
                   max_batch: int = 4, max_cache_len: int = 256,
                   timeout: float = 300.0,
                   **kwargs: Any) -> List[Request]:
    """Convenience: serve a fixed request list to completion, in order."""
    eng = ServeEngine(cfg, params, max_batch=max_batch,
                      max_cache_len=max_cache_len, **kwargs)
    try:
        for r in requests:
            eng.submit(r)
        eng.close_intake()
        eng.run(timeout=timeout)
    finally:
        eng.shutdown()
    return list(requests)
