"""Structured generation configuration for the serving front-end.

Before this layer, request knobs were loose arguments scattered across
``Request`` (``max_new_tokens``, ``speculate``) and whatever each caller
bolted on. ``GenerationConfig`` is the one structured, validated bag of
knobs a request carries; validation happens **once**, at construction
(i.e. at admission time for the public API — a malformed config never
reaches the decode loop).

Fields:

* ``max_tokens``     — generation budget (>= 1). The only required knob.
* ``speculate``      — speculative-decoding cap for this request: ``None``
  rides the engine default K, ``0`` disables speculation, ``k`` caps the
  drafts per verify step (further capped by the engine's compiled K).
* ``stop``           — stop sequences as token-id sequences. Generation
  finishes as soon as the emitted tokens *end with* any stop sequence;
  the stop sequence itself is excluded from the output. Checked on the
  host in the step-completion continuation, so streamed and
  retirement-time token lists are identical by construction.
* ``temperature``    — ``0.0`` = greedy argmax (the only decode mode this
  engine implements; the verify step's token-identity guarantee is
  defined against greedy). Non-zero values are rejected at validation —
  the field exists so admission, not the decode loop, owns the check.
* ``deadline_s``     — QoS deadline in seconds, measured from request
  arrival. Queued requests past their deadline are refused at admission;
  in-slot requests are retired (state ``EXPIRED``, pages released) by the
  step-completion continuation that notices the expiry.
* ``priority``       — QoS priority (higher = sooner, default 0).
  Admission pops strictly by priority (arrival order within a class) and
  the engine tags step continuations carrying priority>0 work with the
  scheduler's per-registration ``priority`` flag (front-of-ready-queue).
* ``stream_buffer``  — per-stream pending-token watermark: a consumer
  further than this many tokens behind the decode loop marks the stream
  ``lagging`` (delivery degrades to catch-up bursts; the loop itself
  never blocks and no token is ever dropped).
* ``tenant``         — the accounting principal the request bills to.
  Single-engine tiers ignore it; the multi-replica ``Router`` keys its
  weighted-fairness scheduler and per-tenant quotas on it. Must be a
  non-empty string (default ``"default"``).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Sequence, Tuple


class DeadlineExceeded(Exception):
    """A request's QoS deadline passed before it finished.

    Carries the partially generated tokens (``.tokens``) when the request
    had already produced some.
    """

    def __init__(self, message: str, tokens: Optional[list] = None) -> None:
        super().__init__(message)
        self.tokens = tokens if tokens is not None else []


class QuotaExceeded(Exception):
    """A tenant's outstanding-work quota refused this request at admission.

    Carries the refusing ``tenant`` and a ``retry_after_s`` hint — the
    router's running estimate of how long until that tenant's oldest
    outstanding request retires and frees quota.
    """

    def __init__(self, message: str, *, tenant: str = "default",
                 retry_after_s: float = 0.0) -> None:
        super().__init__(message)
        self.tenant = tenant
        self.retry_after_s = float(retry_after_s)


def _normalize_stop(stop: Any) -> Tuple[Tuple[int, ...], ...]:
    """Coerce stop sequences to a tuple of non-empty int tuples."""
    if stop is None:
        return ()
    if not isinstance(stop, (list, tuple)):
        raise ValueError("stop must be a sequence of token-id sequences")
    out = []
    for seq in stop:
        if not isinstance(seq, (list, tuple)):
            raise ValueError(
                f"each stop entry must be a token-id sequence, got {seq!r}")
        if len(seq) == 0:
            raise ValueError("empty stop sequence")
        try:
            out.append(tuple(int(t) for t in seq))
        except (TypeError, ValueError):
            raise ValueError(
                f"stop sequences must contain ints, got {seq!r}") from None
    return tuple(out)


@dataclasses.dataclass(frozen=True)
class GenerationConfig:
    """Validated per-request generation knobs (see module docstring)."""

    max_tokens: int = 16
    speculate: Optional[int] = None
    stop: Sequence[Sequence[int]] = ()
    temperature: float = 0.0
    deadline_s: Optional[float] = None
    priority: int = 0
    stream_buffer: int = 64
    tenant: str = "default"

    def __post_init__(self) -> None:
        object.__setattr__(self, "max_tokens", int(self.max_tokens))
        if self.max_tokens < 1:
            raise ValueError(
                f"max_tokens must be >= 1, got {self.max_tokens}")
        if self.speculate is not None:
            object.__setattr__(self, "speculate", int(self.speculate))
            if self.speculate < 0:
                raise ValueError("speculate must be >= 0")
        object.__setattr__(self, "stop", _normalize_stop(self.stop))
        if float(self.temperature) != 0.0:
            raise ValueError(
                f"temperature={self.temperature}: only greedy (0.0) decode "
                "is implemented — the engine's token-identity guarantees "
                "are defined against greedy argmax")
        if self.deadline_s is not None and float(self.deadline_s) <= 0:
            raise ValueError(
                f"deadline_s must be > 0 (seconds from arrival), "
                f"got {self.deadline_s}")
        object.__setattr__(self, "priority", int(self.priority))
        object.__setattr__(self, "stream_buffer", int(self.stream_buffer))
        if self.stream_buffer < 1:
            raise ValueError(
                f"stream_buffer must be >= 1, got {self.stream_buffer}")
        if not isinstance(self.tenant, str) or not self.tenant:
            raise ValueError(
                f"tenant must be a non-empty string, got {self.tenant!r}")

    def merged(self, **overrides: Any) -> "GenerationConfig":
        """A copy with ``overrides`` applied (re-validated)."""
        return dataclasses.replace(self, **overrides)
