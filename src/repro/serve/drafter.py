"""Draft-token proposers for self-speculative decoding.

Speculative decoding splits each decode iteration into *draft* (cheap,
host-side guesses for the next K tokens) and *verify* (one batched
multi-token step through the real model that scores all K+1 positions at
once). Any guess is *correct* — the verify step only emits tokens the
model itself would have produced greedily — so a drafter trades nothing
but wasted compute for its misses. The engine consumes drafters through
the ``Drafter`` protocol, so a small-model drafter can slot in later
without touching the engine; the default is prompt-lookup/n-gram
drafting (Saxena-style), which needs no extra model at all.

Drafting runs on the decode-loop thread against the slot's *host*
context (prompt + accepted tokens, appended by the verify continuation
when the device step actually finishes — the same completion-driven
bookkeeping the rest of the engine uses).
"""
from __future__ import annotations

from typing import List, Protocol, Sequence, runtime_checkable

import numpy as np


@runtime_checkable
class Drafter(Protocol):
    """Proposes up to ``k`` draft tokens given the decoded-so-far context.

    ``context`` is the request's prompt followed by every token emitted
    so far (host ints, in order). Implementations may return fewer than
    ``k`` tokens (including none) when they have no confident guess —
    the engine pads the verify batch and masks the missing positions.
    """

    def draft(self, context: Sequence[int], k: int) -> List[int]:
        ...


class NgramDrafter:
    """Prompt-lookup drafting: match the context's trailing n-gram
    against its own history and propose the tokens that followed the
    most recent previous occurrence.

    Tries ``max_ngram`` down to ``min_ngram`` (longer matches are more
    specific, so they win); within one n, the *most recent* prior
    occurrence wins, which makes cyclic generations — the repetition
    regime speculative decoding targets — accept near-K runs.
    """

    def __init__(self, max_ngram: int = 3, min_ngram: int = 1) -> None:
        if not 1 <= min_ngram <= max_ngram:
            raise ValueError("need 1 <= min_ngram <= max_ngram")
        self.max_ngram = int(max_ngram)
        self.min_ngram = int(min_ngram)

    def draft(self, context: Sequence[int], k: int) -> List[int]:
        ctx = np.asarray(context, np.int64).reshape(-1)
        n_ctx = ctx.shape[0]
        if k <= 0 or n_ctx < self.min_ngram + 1:
            return []
        # longest n-gram wins; within one n, the most recent hit with a
        # FULL k-token continuation wins (the verify window is statically
        # k wide, so shorter proposals waste free lanes — a truncated
        # match near the end of context, e.g. a constant run, only beats
        # falling through to a shorter n that can fill the window)
        best: List[int] = []
        for n in range(min(self.max_ngram, n_ctx - 1), self.min_ngram - 1, -1):
            pat = ctx[n_ctx - n:]
            wins = np.lib.stride_tricks.sliding_window_view(ctx, n)
            # exclude the trailing window (the pattern matching itself)
            hits = np.nonzero((wins[:-1] == pat).all(axis=1))[0]
            for h in hits[::-1]:
                cont = ctx[int(h) + n:int(h) + n + k]
                if cont.size == k:
                    return [int(t) for t in cont]
                if cont.size > len(best):
                    best = [int(t) for t in cont]
        return best


class RepeatDrafter:
    """Degenerate drafter: propose the last token k times. Exists mainly
    to exercise the protocol (stutter-heavy outputs accept on it)."""

    def draft(self, context: Sequence[int], k: int) -> List[int]:
        if k <= 0 or not len(context):
            return []
        return [int(context[-1])] * k
