"""Serving step factories: prefill / decode / long-context decode.

``decode_32k`` and ``long_500k`` lower ``serve_step`` — one new token
against a KV cache (or SSM state) of the shape's sequence length — NOT a
training step (assignment note). Caches are donated by the drivers so the
update is in-place on device.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models import encdec, lm
from repro.models.common import AUDIO, ModelConfig


def make_prefill_step(cfg: ModelConfig, max_cache_len: int) -> Callable:
    """(params, batch) → (next-token logits, primed cache/state)."""
    if cfg.family == AUDIO:
        def prefill(params, batch):
            # whisper prefill_32k = encoder forward over 32k frames +
            # decoder state init (cross-KV precompute)
            state = encdec.init_decode_state(params, batch["audio_embed"],
                                             cfg, max_cache_len)
            bos = jnp.zeros((batch["audio_embed"].shape[0], 1), jnp.int32)
            logits, state = encdec.encdec_decode_step(
                params, bos, cfg, state, jnp.zeros((), jnp.int32))
            return logits, state
        return prefill

    def prefill(params, batch):
        B = batch["tokens"].shape[0]
        cache = lm.init_cache(cfg, B, max_cache_len)
        return lm.lm_prefill(params, batch, cfg, cache)
    return prefill


def make_decode_step(cfg: ModelConfig) -> Callable:
    """(params, cache, token, pos) → (logits, new cache). One token."""
    if cfg.family == AUDIO:
        def decode(params, cache, token, pos):
            return encdec.encdec_decode_step(params, token, cfg, cache, pos)
        return decode

    def decode(params, cache, token, pos):
        return lm.lm_decode_step(params, token, cfg, cache, pos)
    return decode


def greedy_generate(cfg: ModelConfig, params, prompt: jax.Array,
                    n_tokens: int, max_cache_len: int) -> jax.Array:
    """Greedy decoding loop (exercised by examples/serve_batch)."""
    prefill = jax.jit(make_prefill_step(cfg, max_cache_len))
    decode = jax.jit(make_decode_step(cfg), donate_argnums=(1,))
    logits, cache = prefill(params, {"tokens": prompt})
    pos = prompt.shape[1]
    out = [jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)]
    for i in range(n_tokens - 1):
        logits, cache = decode(params, cache, out[-1][:, None],
                               jnp.int32(pos + i))
        out.append(jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32))
    return jnp.stack(out, axis=1)
