"""Serving step factories: prefill / decode / paged variants.

``decode_32k`` and ``long_500k`` lower ``serve_step`` — one new token
against a KV cache (or SSM state) of the shape's sequence length — NOT a
training step (assignment note). Caches are donated by the drivers so the
update is in-place on device.

The ``make_paged_*`` factories are the jitted half of the paged KV cache
(``serve.kv_cache``): inside the step, a slot's page table gathers its
pages into a contiguous per-slot view, the ordinary ``lm_decode_step``
runs against it, and only the one page containing the written position
scatters back to the pool — shared prefix pages are read, never written.
Shapes are static and bounded: tables are null-page padded to
``max_pages`` (decode/scatter compile once) and suffix tails are padded
to a page multiple by the engine (at most ``max_pages`` suffix shapes).
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models import encdec, lm
from repro.models.common import AUDIO, ModelConfig
from repro.serve.kv_cache import pages_for


def make_prefill_step(cfg: ModelConfig, max_cache_len: int) -> Callable:
    """(params, batch) → (next-token logits, primed cache/state)."""
    if cfg.family == AUDIO:
        def prefill(params, batch):
            # whisper prefill_32k = encoder forward over 32k frames +
            # decoder state init (cross-KV precompute)
            state = encdec.init_decode_state(params, batch["audio_embed"],
                                             cfg, max_cache_len)
            bos = jnp.zeros((batch["audio_embed"].shape[0], 1), jnp.int32)
            logits, state = encdec.encdec_decode_step(
                params, bos, cfg, state, jnp.zeros((), jnp.int32))
            return logits, state
        return prefill

    def prefill(params, batch):
        B = batch["tokens"].shape[0]
        cache = lm.init_cache(cfg, B, max_cache_len)
        return lm.lm_prefill(params, batch, cfg, cache)
    return prefill


def make_decode_step(cfg: ModelConfig) -> Callable:
    """(params, cache, token, pos) → (logits, new cache). One token."""
    if cfg.family == AUDIO:
        def decode(params, cache, token, pos):
            return encdec.encdec_decode_step(params, token, cfg, cache, pos)
        return decode

    def decode(params, cache, token, pos):
        return lm.lm_decode_step(params, token, cfg, cache, pos)
    return decode


def make_batched_decode_step(cfg: ModelConfig) -> Callable:
    """Dense-mode slot decode with per-step token surfacing.

    ``(params, caches(S,...), tokens(S,1,1), positions(S,)) →
    (next_tokens(S,1), new caches)`` — one vmapped decode step over every
    slot with the greedy argmax fused *inside* the jitted step, so the
    engine's step-completion continuation receives the accepted tokens
    directly (a vocab-times smaller transfer than logits, and one fewer
    dispatch on the per-token critical path the streaming API rides).
    """
    decode_one = make_decode_step(cfg)

    def step(params, caches, tokens, positions):
        logits, new_caches = jax.vmap(
            decode_one, in_axes=(None, 0, 0, 0))(params, caches, tokens,
                                                 positions)
        nxt = jnp.argmax(logits[:, :, -1, :], axis=-1).astype(jnp.int32)
        return nxt, new_caches
    return step


# ------------------------------------------------------------- paged steps

def _gather_pages(pool: Dict[str, jax.Array], table: jax.Array,
                  page_size: int) -> Dict[str, jax.Array]:
    """Page table -> contiguous per-slot cache view.

    pool leaf: (L, total_pages+1, page_size, KV, hd); table: (max_pages,)
    -> (L, 1, max_pages*page_size, KV, hd), i.e. a batch-1 stacked cache
    exactly as ``lm_decode_step`` expects. Null-padded table tails gather
    scratch-page garbage, which the decode mask (idx <= pos) zeroes out.
    """
    def one(p):
        g = p[:, table]                       # (L, max_pages, ps, KV, hd)
        L, n_pages = g.shape[0], g.shape[1]
        return g.reshape((L, n_pages * page_size) + g.shape[3:])[:, None]
    return jax.tree_util.tree_map(one, pool)


def _written_page(new_cache: Dict[str, jax.Array], pos: jax.Array,
                  page_size: int) -> Dict[str, jax.Array]:
    """Slice the page containing ``pos`` out of the contiguous view."""
    pi = (pos // page_size).astype(jnp.int32)
    return jax.tree_util.tree_map(
        lambda c: jax.lax.dynamic_slice_in_dim(
            c[:, 0], pi * page_size, page_size, axis=1), new_cache)


def make_paged_decode_step(cfg: ModelConfig, page_size: int, *,
                           return_tokens: bool = False) -> Callable:
    """(params, pool, tokens(S,1,1), positions(S,), tables(S,max_pages))
    → (logits(S,1,1,V), new pool). One token for every slot.

    ``return_tokens=True`` surfaces the greedy next tokens instead:
    → (next_tokens(S,1), new pool), with the argmax fused into the step
    (same per-step token surfacing as ``make_batched_decode_step`` — the
    serving engine's continuations deliver tokens straight from it)."""
    decode_one = make_decode_step(cfg)

    def step(params, pool, tokens, positions, tables):
        def one(token, pos, table):
            cache = _gather_pages(pool, table, page_size)
            logits, new_cache = decode_one(params, cache, token, pos)
            pi = (pos // page_size).astype(jnp.int32)
            return logits, _written_page(new_cache, pos, page_size), table[pi]

        logits, pages, targets = jax.vmap(one)(tokens, positions, tables)
        # each live slot owns its write page, so targets collide only on
        # the null page (idle slots) — scatter order there is irrelevant
        new_pool = jax.tree_util.tree_map(
            lambda p, pg: p.at[:, targets].set(jnp.swapaxes(pg, 0, 1)),
            pool, pages)
        if return_tokens:
            nxt = jnp.argmax(logits[:, :, -1, :], axis=-1).astype(jnp.int32)
            return nxt, new_pool
        return logits, new_pool
    return step


def make_paged_verify_step(cfg: ModelConfig, page_size: int,
                           n_draft: int) -> Callable:
    """Speculative verify: score ``1 + n_draft`` tokens per slot in ONE
    multi-token paged decode and compute each slot's accept length on
    device.

    ``(params, pool, tokens(S,1,1+K), positions(S,), tables(S,T),
    write_tables(S,W), n_drafts(S,))`` → ``(emitted(S,1+K), accepts(S,),
    new pool)`` where K = ``n_draft`` and W = ``1 + ceil(K/page_size)``
    (the most pages a K+1-token write window can span).

    Per slot: token 0 is the slot's real next-input token, tokens 1..K
    are drafter guesses. The ordinary ``lm_decode_step`` runs all K+1
    positions against the gathered page view (causal mask per query
    row), ``emitted[j] = argmax(logits[j])`` is the token the model
    *actually* produces at position ``pos+j+1``, and the accept length
    is the longest prefix where the guesses reproduce it:
    ``accepts = max a such that tokens[1..a] == emitted[0..a-1]``
    (masked to the slot's live draft count ``n_drafts``). Everything in
    ``emitted[:accepts+1]`` is exactly the greedy-decode token stream —
    speculation changes the schedule, never the tokens.

    Rollback is split between the write tables and the engine's position
    bookkeeping: the KV writes for all K+1 positions land in the slices
    ``write_tables`` maps — the engine maps only request-owned pages in
    the write window and points everything else (rejected tails past the
    token budget, idle slots) at the scratch page — and positions past
    the accepted run are overwritten by the next verify step before the
    advancing causal mask can expose them, so no stale entry is ever
    attended and no page leaks.
    """
    decode_one = make_decode_step(cfg)
    n_wpages = 1 + pages_for(n_draft, page_size)

    def step(params, pool, tokens, positions, tables, write_tables,
             n_drafts):
        def one(token, pos, table, k):
            cache = _gather_pages(pool, table, page_size)
            logits, new_cache = decode_one(params, cache, token, pos)
            emitted = jnp.argmax(logits[0], axis=-1).astype(jnp.int32)
            ok = ((token[0, 1:] == emitted[:-1])
                  & (jnp.arange(n_draft, dtype=jnp.int32) < k))
            accept = jnp.sum(jnp.cumprod(ok.astype(jnp.int32)))
            pi = (pos // page_size).astype(jnp.int32)
            pages = jax.tree_util.tree_map(
                lambda c: jax.lax.dynamic_slice_in_dim(
                    c[:, 0], pi * page_size, n_wpages * page_size, axis=1
                ).reshape((c.shape[0], n_wpages, page_size) + c.shape[3:]),
                new_cache)
            return emitted, accept.astype(jnp.int32), pages

        emitted, accepts, pages = jax.vmap(one)(tokens, positions, tables,
                                                n_drafts)

        # write pages are request-owned and disjoint across slots, so the
        # flattened scatter collides only on the scratch page (idle slots,
        # out-of-footprint tails) where order is irrelevant
        def scat(p, pg):                      # pg: (S, L, W, ps, KV, hd)
            pg = jnp.moveaxis(pg, 0, 1)       # (L, S, W, ps, KV, hd)
            pg = pg.reshape((pg.shape[0], -1) + pg.shape[3:])
            return p.at[:, write_tables.reshape(-1)].set(pg)

        return emitted, accepts, jax.tree_util.tree_map(scat, pool, pages)
    return step


def make_paged_suffix_step(cfg: ModelConfig, page_size: int) -> Callable:
    """Chunked suffix prefill for a prefix-cache hit: run the whole prompt
    tail (positions ``pos .. pos+S-1``) against the shared pages in ONE
    call — (params, pool, tokens(1,S), pos, gather_table, scatter_table)
    → (logits(1,S,V), new pool). ``gather_table`` is the request's full
    page table; ``scatter_table`` maps only request-OWNED entries (shared
    prefix pages and padding point at the null page), so shared pages are
    read but never written. Unwritten owned pages scatter their gathered
    content back — an identity write."""
    decode_one = make_decode_step(cfg)

    def step(params, pool, tokens, pos, gather_table, scatter_table):
        cache = _gather_pages(pool, gather_table, page_size)
        logits, new_cache = decode_one(params, cache, tokens, pos)

        def one(p, c):
            L = c.shape[0]
            pages = c[:, 0].reshape((L, -1, page_size) + c.shape[3:])
            return p.at[:, scatter_table].set(pages)
        return logits, jax.tree_util.tree_map(one, pool, new_cache)
    return step


# ------------------------------------------------------- fused paged steps
#
# The make_fused_* factories run the whole batch through ONE
# ``lm.lm_paged_decode`` call: the Pallas kernel (or its jnp reference
# under impl="xla") walks each slot's page table on device — gather,
# flash-style attend, accept-masked KV write — so there is no
# ``_gather_pages`` materialization, no ``_written_page`` slice, no
# host-built write tables, and no per-slot vmap. ``n_valid`` carries the
# write mask: 0 = idle slot (nothing written, zero output), 1 = decode,
# ``1 + K`` = verify window, tail length = suffix prefill. Overflow rows
# land in the scratch page by the table-padding contract, which is
# exactly ``PagePool.write_table``'s rollback behaviour.

def make_fused_paged_decode_step(cfg: ModelConfig, page_size: int) -> Callable:
    """(params, pool, tokens(S,1,1), positions(S,), tables(S,T),
    n_valid(S,)) → (next_tokens(S,1), new pool). One fused kernel pass
    for every slot; greedy argmax fused into the step like
    ``make_paged_decode_step(return_tokens=True)``."""
    def step(params, pool, tokens, positions, tables, n_valid):
        logits, new_pool = lm.lm_paged_decode(
            params, tokens[:, 0, :], cfg, pool, positions, tables, n_valid,
            page_size=page_size)
        nxt = jnp.argmax(logits[:, -1:, :], axis=-1).astype(jnp.int32)
        return nxt, new_pool
    return step


def make_fused_paged_verify_step(cfg: ModelConfig, page_size: int,
                                 n_draft: int) -> Callable:
    """Speculative verify on the fused kernel: ``(params, pool,
    tokens(S,1,1+K), positions(S,), tables(S,T), n_valid(S,))`` →
    ``(emitted(S,1+K), accepts(S,), new pool)``.

    Same accept semantics as ``make_paged_verify_step`` but with no
    ``write_tables`` operand at all: ``n_valid = 1 + k_live`` for live
    slots (0 idle) accept-masks the KV writes inside the kernel, and the
    gather table doubles as the write map (out-of-footprint rows fall in
    the scratch page). Rejected-but-written rows sit beyond the advancing
    causal horizon until the next verify window overwrites them."""
    K = n_draft

    def step(params, pool, tokens, positions, tables, n_valid):
        tok = tokens[:, 0, :]                                # (S, 1+K)
        logits, new_pool = lm.lm_paged_decode(
            params, tok, cfg, pool, positions, tables, n_valid,
            page_size=page_size)
        emitted = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        k = jnp.maximum(n_valid - 1, 0)                      # live drafts
        ok = ((tok[:, 1:] == emitted[:, :-1])
              & (jnp.arange(K, dtype=jnp.int32)[None, :] < k[:, None]))
        accepts = jnp.sum(jnp.cumprod(ok.astype(jnp.int32), axis=1), axis=1)
        return emitted, accepts.astype(jnp.int32), new_pool
    return step


def make_fused_paged_suffix_step(cfg: ModelConfig, page_size: int) -> Callable:
    """Chunked suffix prefill on the fused kernel: (params, pool,
    tokens(1,Sw), positions(1,), tables(1,T), n_valid(1,)) →
    (logits(1,Sw,V), new pool). ``Sw`` is the page-padded tail;
    ``n_valid`` its real length — padded rows are never written and the
    clamped causal horizon keeps them off unwritten positions, while
    shared prefix pages (positions < pos) are read, never written."""
    def step(params, pool, tokens, positions, tables, n_valid):
        return lm.lm_paged_decode(
            params, tokens, cfg, pool, positions, tables, n_valid,
            page_size=page_size)
    return step


def make_prefill_scatter(cfg: ModelConfig, page_size: int) -> Callable:
    """Blit a dense prefill cache into the pool: (pool, dense_cache,
    table(max_pages,)) → new pool. ``dense_cache`` leaves are
    (L, 1, max_pages*page_size, KV, hd); entry ``i`` of the table is the
    page receiving tokens [i*ps, (i+1)*ps) — null past the prompt."""
    def scatter(pool, dense_cache, table):
        def one(p, c):
            L = c.shape[0]
            pages = c[:, 0].reshape(
                (L, -1, page_size) + c.shape[3:])   # (L, max_pages, ps, ..)
            return p.at[:, table].set(pages)
        return jax.tree_util.tree_map(one, pool, dense_cache)
    return scatter


def greedy_generate(cfg: ModelConfig, params, prompt: jax.Array,
                    n_tokens: int, max_cache_len: int) -> jax.Array:
    """Greedy decoding loop (exercised by examples/serve_batch)."""
    prefill = jax.jit(make_prefill_step(cfg, max_cache_len))
    decode = jax.jit(make_decode_step(cfg), donate_argnums=(1,))
    logits, cache = prefill(params, {"tokens": prompt})
    pos = prompt.shape[1]
    out = [jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)]
    for i in range(n_tokens - 1):
        logits, cache = decode(params, cache, out[-1][:, None],
                               jnp.int32(pos + i))
        out.append(jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32))
    return jnp.stack(out, axis=1)
