"""Serving request lifecycle — each request is itself a ``Completable``.

A ``Request`` moves through::

    QUEUED ──admit──▶ PREFILLING ──first token──▶ DECODING ──retire──▶ FINISHED
       │                                                          ▲
       └────────────────────────cancel────────────────────────────┘

Because a ``Request`` is a ``Completable``, callers interact with it
exactly like any other operation in this runtime: attach a continuation
(``engine.continue_when(request, on_done, cr=cr)``), group several into a
``continue_all``, or block with ``request.wait()``. Completion status
carries the generated token ids as payload.

Timing fields feed the serving metrics (benchmarks and tests): arrival,
admission, first-token (TTFT), and finish timestamps.
"""
from __future__ import annotations

import enum
import itertools
import threading
import time
from typing import Any, List, Optional, Sequence

from repro.core.completable import Completable
from repro.core.status import OpState, Status

_req_ids = itertools.count()


class RequestState(enum.Enum):
    QUEUED = "queued"            # submitted, not yet admitted to a slot
    PREFILLING = "prefilling"    # prompt being processed
    DECODING = "decoding"        # in a decode slot, generating
    FINISHED = "finished"        # all tokens generated (op COMPLETE)
    CANCELLED = "cancelled"      # cancelled before finishing


class Request(Completable):
    """One generation request: prompt in, ``max_new_tokens`` greedy tokens out.

    ``prompt`` is a 1-D int sequence (list/np/jnp). Generated token ids
    accumulate in ``tokens`` (host ints, materialized at retirement).
    """

    def __init__(self, prompt: Any, max_new_tokens: int,
                 *, speculate: Optional[int] = None,
                 arrival_time: Optional[float] = None) -> None:
        super().__init__()
        self.req_id = next(_req_ids)
        self.prompt = prompt
        self.max_new_tokens = int(max_new_tokens)
        if self.max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")
        # speculative decoding knob: None → engine default K; 0 disables
        # speculation for this request; k caps the drafts per verify step
        # (the engine further caps at its own compiled K and the budget)
        if speculate is not None and int(speculate) < 0:
            raise ValueError("speculate must be >= 0")
        self.speculate = None if speculate is None else int(speculate)
        self.draft_tokens_proposed = 0
        self.draft_tokens_accepted = 0
        self.req_state = RequestState.QUEUED
        self.tokens: List[int] = []
        # paged serving: KV pages held (engine-owned; emptied at eviction)
        # and how many prompt tokens were satisfied from the prefix cache
        self.page_ids: List[int] = []
        self.shared_prefix_tokens = 0
        # device-side per-step token refs; drained into .tokens at retirement
        self._device_tokens: List[Any] = []
        self._finished_evt = threading.Event()
        # -- timing (monotonic seconds) --
        self.arrival_time = (time.monotonic() if arrival_time is None
                             else arrival_time)
        self.admit_time: Optional[float] = None
        self.first_token_time: Optional[float] = None
        self.finish_time: Optional[float] = None

    # ------------------------------------------------------------- lifecycle
    def on_admitted(self) -> None:
        # guard like on_first_token: a cancel() racing admission must not
        # be resurrected into the decode pipeline
        if self.req_state is RequestState.QUEUED:
            self.req_state = RequestState.PREFILLING
        self.admit_time = time.monotonic()

    def on_requeued(self) -> None:
        """Undo admission (capacity-deferred: back to the queue head).
        A concurrent cancel() must not be resurrected — only an
        in-flight admission is downgraded (the batcher drops CANCELLED
        requests at the next admit)."""
        if self.req_state is RequestState.PREFILLING:
            self.req_state = RequestState.QUEUED
        self.admit_time = None

    def on_first_token(self) -> None:
        if self.first_token_time is None:
            self.first_token_time = time.monotonic()
        # the continuation may fire after a concurrent cancel(); a terminal
        # state must never be downgraded back to DECODING
        if self.req_state is RequestState.PREFILLING:
            self.req_state = RequestState.DECODING

    def push_device_token(self, token: Any) -> None:
        """Record one generated token (may still be an in-flight device
        scalar; materialized lazily at retirement)."""
        self._device_tokens.append(token)

    @property
    def generated(self) -> int:
        return len(self._device_tokens)

    @property
    def remaining(self) -> int:
        return self.max_new_tokens - self.generated

    def retire(self) -> bool:
        """Finish the request: materialize tokens, publish completion.
        Returns False (no-op) if a concurrent cancel() won the race."""
        if self.req_state is RequestState.CANCELLED:
            return False
        self.tokens = [int(t) for t in self._device_tokens]
        self._device_tokens = []
        self.req_state = RequestState.FINISHED
        self.finish_time = time.monotonic()
        self._finished_evt.set()
        self._complete(Status(payload=self.tokens, count=len(self.tokens)))
        return True

    def cancel(self) -> bool:
        """Cancel a not-yet-finished request (best effort: queued requests
        are dropped by the batcher; in-flight slots retire at the next
        step boundary)."""
        if self.req_state is RequestState.FINISHED:
            return False
        fired = self._complete(Status(cancelled=True), OpState.CANCELLED)
        if fired:
            self.req_state = RequestState.CANCELLED
            self.finish_time = time.monotonic()
            self._finished_evt.set()
        return fired

    # --------------------------------------------------------- completable
    @property
    def supports_push(self) -> bool:
        return True    # retire()/cancel() publish completion

    def _poll(self) -> bool:
        return self._finished_evt.is_set()

    def wait(self, timeout: Optional[float] = None) -> bool:
        """Block the *caller* until finished (the engine loop never does)."""
        return self._finished_evt.wait(timeout)

    # -------------------------------------------------------------- metrics
    @property
    def ttft(self) -> Optional[float]:
        """Time to first token, from arrival (seconds)."""
        if self.first_token_time is None:
            return None
        return self.first_token_time - self.arrival_time

    @property
    def accept_rate(self) -> Optional[float]:
        """Fraction of proposed draft tokens the verify step accepted
        (None when the request never ran speculatively)."""
        if self.draft_tokens_proposed == 0:
            return None
        return self.draft_tokens_accepted / self.draft_tokens_proposed

    @property
    def latency(self) -> Optional[float]:
        if self.finish_time is None:
            return None
        return self.finish_time - self.arrival_time

    def __repr__(self) -> str:
        return (f"Request(id={self.req_id}, state={self.req_state.value}, "
                f"generated={self.generated}/{self.max_new_tokens})")


def summarize(requests: Sequence[Request]) -> dict:
    """Aggregate serving metrics over finished requests."""
    done = [r for r in requests if r.req_state is RequestState.FINISHED]
    ttfts = sorted(r.ttft for r in done if r.ttft is not None)
    total_tokens = sum(len(r.tokens) for r in done)
    proposed = sum(r.draft_tokens_proposed for r in done)
    accepted = sum(r.draft_tokens_accepted for r in done)
    out = {
        "finished": len(done),
        "total_tokens": total_tokens,
        "ttft_mean": sum(ttfts) / len(ttfts) if ttfts else 0.0,
        "ttft_p50": _percentile(ttfts, 0.50),
        "ttft_p99": _percentile(ttfts, 0.99),
        "draft_tokens_proposed": proposed,
        "draft_tokens_accepted": accepted,
        "accept_rate": accepted / proposed if proposed else 0.0,
    }
    return out


def _percentile(sorted_vals: Sequence[float], q: float) -> float:
    if not sorted_vals:
        return 0.0
    idx = min(len(sorted_vals) - 1, int(round(q * (len(sorted_vals) - 1))))
    return sorted_vals[idx]
