"""Serving request lifecycle — each request is itself a ``Completable``.

A ``Request`` moves through::

    QUEUED ──admit──▶ PREFILLING ──first token──▶ DECODING ──retire──▶ FINISHED
       │                                              │           ▲
       │                                              ├─deadline─▶ EXPIRED
       └────────────────────────cancel────────────────┴──▶ CANCELLED

Because a ``Request`` is a ``Completable``, callers interact with it
exactly like any other operation in this runtime: attach a continuation
(``engine.continue_when(request, on_done, cr=cr)``), group several into a
``continue_all``, or block with ``request.wait()``. Completion status
carries the generated token ids as payload.

Knobs are a structured ``GenerationConfig`` (``serve.config``), validated
once at construction. The legacy loose kwargs (``max_new_tokens=``,
``speculate=``) still work as deprecated shims; ``Request(prompt, n)``
with an int stays as the canonical shorthand for
``GenerationConfig(max_tokens=n)``.

**Token delivery.** The engine pushes budget-tracking device scalars at
dispatch (``push_device_token``) and *delivers* host ints from the
step-completion continuations (``deliver``) — where the paper's
callback-driven lifecycle guarantees the arrays are materialized, so
``int()`` never blocks. Delivery owns stop-sequence matching (with
holdback: a token that could still extend into a stop match is withheld
until it can't, so streamed and retirement-time token lists are identical
and the excluded stop sequence is never observable) and feeds the
attached ``TokenStream``, if any. ``cancel()`` closes the stream under
the same lock delivery takes: once ``cancel()`` returns, no further token
can be delivered — even one produced by a step already in flight.

Timing fields feed the serving metrics (benchmarks and tests): arrival,
admission, first-token (TTFT), and finish timestamps.
"""
from __future__ import annotations

import enum
import itertools
import threading
import time
import warnings
from typing import Any, List, Optional, Sequence, Union

from repro.core.completable import Completable
from repro.core.status import OpState, Status
from repro.obs import events as _obs_events
from repro.obs import tracer as _obs
from repro.serve.config import DeadlineExceeded, GenerationConfig

_req_ids = itertools.count()
_UNSET = object()


class RequestState(enum.Enum):
    QUEUED = "queued"            # submitted, not yet admitted to a slot
    PREFILLING = "prefilling"    # prompt being processed
    DECODING = "decoding"        # in a decode slot, generating
    FINISHED = "finished"        # all tokens generated (op COMPLETE)
    CANCELLED = "cancelled"      # cancelled before finishing
    EXPIRED = "expired"          # QoS deadline passed before finishing


_TERMINAL = (RequestState.FINISHED, RequestState.CANCELLED,
             RequestState.EXPIRED)


class Request(Completable):
    """One generation request: prompt in, ``config.max_tokens`` greedy
    tokens out (fewer if a stop sequence or the deadline hits first).

    ``prompt`` is a 1-D int sequence (list/np/jnp). ``config`` is a
    ``GenerationConfig`` or an int shorthand for ``max_tokens``. Generated
    token ids accumulate in ``tokens`` (host ints, final at retirement).
    """

    def __init__(self, prompt: Any,
                 config: Union[None, int, GenerationConfig] = None,
                 *, max_new_tokens: Optional[int] = None,
                 speculate: Any = _UNSET,
                 arrival_time: Optional[float] = None) -> None:
        super().__init__()
        if max_new_tokens is not None:
            if config is not None:
                raise ValueError(
                    "pass either config/max_tokens or the deprecated "
                    "max_new_tokens kwarg, not both")
            warnings.warn(
                "Request(max_new_tokens=...) is deprecated; pass "
                "Request(prompt, n) or GenerationConfig(max_tokens=n)",
                DeprecationWarning, stacklevel=2)
            config = int(max_new_tokens)
        if config is None:
            raise ValueError("Request needs a GenerationConfig (or an int "
                             "max_tokens shorthand)")
        if isinstance(config, GenerationConfig):
            cfg = config
        else:
            cfg = GenerationConfig(max_tokens=int(config))
        if speculate is not _UNSET:
            warnings.warn(
                "Request(speculate=...) is deprecated; set "
                "GenerationConfig(speculate=...)",
                DeprecationWarning, stacklevel=2)
            cfg = cfg.merged(
                speculate=None if speculate is None else int(speculate))
        self.config = cfg
        self.req_id = next(_req_ids)
        self.prompt = prompt
        self.draft_tokens_proposed = 0
        self.draft_tokens_accepted = 0
        self.req_state = RequestState.QUEUED
        self.tokens: List[int] = []
        # paged serving: KV pages held (engine-owned; emptied at eviction)
        # and how many prompt tokens were satisfied from the prefix cache
        self.page_ids: List[int] = []
        self.shared_prefix_tokens = 0
        # device-side per-step token refs: budget bookkeeping at dispatch;
        # only materialized at retirement if delivery never ran (legacy
        # direct-push path — the engine always delivers)
        self._device_tokens: List[Any] = []
        # host-side delivery (step-completion continuations): committed
        # tokens, stop-match holdback tail, and the attached stream.
        # RLock: cancel()/retire() fire completion hooks while holding it,
        # and a hook may drain a step continuation that re-enters deliver.
        self._deliver_lock = threading.RLock()
        self._out: List[int] = []
        self._hold: List[int] = []
        # per-token delivery instants (monotonic), 1:1 with committed
        # tokens: stamped where delivery publishes to the stream, so the
        # bench runner reads inter-token latencies without per-consumer
        # timing threads. Tokens committed by one step share a stamp.
        self.token_times: List[float] = []
        self._delivered_any = False
        self._stop_hit = False
        self._stream: Optional[Any] = None    # serve.api.TokenStream
        self._finished_evt = threading.Event()
        # -- timing (monotonic seconds) --
        self.arrival_time = (time.monotonic() if arrival_time is None
                             else arrival_time)
        self.admit_time: Optional[float] = None
        self.first_token_time: Optional[float] = None
        self.finish_time: Optional[float] = None

    # ------------------------------------------------------------ config view
    @property
    def max_new_tokens(self) -> int:
        return self.config.max_tokens

    @property
    def speculate(self) -> Optional[int]:
        return self.config.speculate

    @property
    def priority(self) -> int:
        return self.config.priority

    @property
    def tenant(self) -> str:
        return self.config.tenant

    @property
    def deadline_time(self) -> Optional[float]:
        """Absolute monotonic deadline (``None`` = no deadline). Derived
        from ``arrival_time`` at read time so load generators that stamp
        arrival late keep a consistent deadline."""
        if self.config.deadline_s is None:
            return None
        return self.arrival_time + self.config.deadline_s

    def past_deadline(self, now: Optional[float] = None) -> bool:
        dt = self.deadline_time
        if dt is None:
            return False
        return (time.monotonic() if now is None else now) >= dt

    # ------------------------------------------------------------- lifecycle
    def on_admitted(self) -> None:
        # guard like on_first_token: a cancel() racing admission must not
        # be resurrected into the decode pipeline
        if self.req_state is RequestState.QUEUED:
            self.req_state = RequestState.PREFILLING
        self.admit_time = time.monotonic()

    def on_requeued(self) -> None:
        """Undo admission (capacity-deferred: back to the queue head).
        A concurrent cancel() must not be resurrected — only an
        in-flight admission is downgraded (the batcher drops CANCELLED
        requests at the next admit)."""
        if self.req_state is RequestState.PREFILLING:
            self.req_state = RequestState.QUEUED
        self.admit_time = None

    def on_first_token(self) -> None:
        if self.first_token_time is None:
            self.first_token_time = time.monotonic()
        # the continuation may fire after a concurrent cancel(); a terminal
        # state must never be downgraded back to DECODING
        if self.req_state is RequestState.PREFILLING:
            self.req_state = RequestState.DECODING

    def push_device_token(self, token: Any) -> None:
        """Record one generated token at dispatch (may still be an
        in-flight device scalar; budget bookkeeping only)."""
        self._device_tokens.append(token)

    @property
    def is_terminal(self) -> bool:
        """FINISHED, CANCELLED or EXPIRED — nothing further can happen."""
        return self.req_state in _TERMINAL

    @property
    def generated(self) -> int:
        return len(self._device_tokens)

    @property
    def remaining(self) -> int:
        return self.config.max_tokens - self.generated

    @property
    def delivered(self) -> int:
        """Tokens committed to the output so far (stream-visible; excludes
        stop-sequence holdback). The failover replay offset: a restarted
        request re-generates exactly this many tokens before new ones."""
        with self._deliver_lock:
            return len(self._out)

    # --------------------------------------------------------------- delivery
    def attach_stream(self, stream: Any) -> None:
        """Attach the single ``TokenStream`` receiving per-token delivery.

        Tokens committed before attachment are replayed; a terminal
        request closes the stream immediately with the matching reason.
        """
        with self._deliver_lock:
            if self._stream is not None:
                raise RuntimeError("request already has a stream attached")
            self._stream = stream
            if self._out:
                stream._publish(list(self._out))
            terminal = self.req_state if self.req_state in _TERMINAL \
                else None
        # close outside the lock — _close settles the stream's done
        # promise, which runs user .then() handlers (see retire())
        if terminal is not None:
            stream._close(terminal.value)

    def deliver(self, toks: Sequence[int]) -> Optional[str]:
        """Deliver host tokens from a step-completion continuation.

        Returns ``"stop"`` when a stop sequence completed generation with
        this batch, else ``None``. Tokens arriving after a terminal state
        (or after a stop already hit) are dropped — ``cancel()`` holds the
        same lock, so nothing is delivered after it returns.
        """
        with self._deliver_lock:
            if self.req_state in _TERMINAL or self._stop_hit:
                return None
            self._delivered_any = True
            stops = self.config.stop
            if not stops:
                committed = [int(t) for t in toks]
                self._out.extend(committed)
            else:
                committed = []
                for t in toks:
                    hit = self._hold_token(int(t), committed)
                    if hit:
                        self._stop_hit = True
                        break
            if committed:
                self.token_times.extend(
                    [time.monotonic()] * len(committed))
                tr = _obs.TRACE
                if tr is not None and tr.want(self.req_id):
                    tr.evt(_obs_events.REQ_DELIVER, self.req_id, "serve",
                           meta=len(committed))
                if self._stream is not None:
                    self._stream._publish(committed)
            return "stop" if self._stop_hit else None

    def _hold_token(self, t: int, committed: List[int]) -> bool:
        """Stop-sequence matching with holdback (see module docstring).

        Appends ``t`` to the holdback tail; commits any prefix of the tail
        that can no longer participate in a stop match (into ``_out`` and
        ``committed``). Returns True when the tail completed a stop
        sequence — the matched tokens are discarded (stop sequences are
        excluded from output)."""
        hold = self._hold
        hold.append(t)
        for seq in self.config.stop:
            n = len(seq)
            if len(hold) >= n and tuple(hold[-n:]) == seq:
                front = hold[:-n]      # can no longer match: commit
                self._out.extend(front)
                committed.extend(front)
                self._hold = []
                return True
        # longest suffix of the tail that is a proper prefix of some stop
        # sequence must stay held; everything before it is committed
        keep = 0
        for seq in self.config.stop:
            for k in range(min(len(hold), len(seq) - 1), keep, -1):
                if tuple(hold[-k:]) == seq[:k]:
                    keep = k
                    break
        cut = len(hold) - keep
        if cut:
            front = hold[:cut]
            self._out.extend(front)
            committed.extend(front)
            self._hold = hold[cut:]
        return False

    def rewind_holdback(self) -> int:
        """Failover support: drop the uncommitted stop-matching tail and
        return the committed-token count (the replay offset). A request
        restarted from its prompt regenerates the held-back tokens, which
        then re-enter ``deliver``'s stop matching from a clean state —
        replayed delivery stays identical to the uninterrupted run."""
        with self._deliver_lock:
            self._hold = []
            return len(self._out)

    def _flush_hold(self) -> None:
        """Commit the holdback tail (no stop match can complete anymore)."""
        if self._hold:
            front, self._hold = self._hold, []
            self._out.extend(front)
            self.token_times.extend([time.monotonic()] * len(front))
            if self._stream is not None:
                self._stream._publish(front)

    # ------------------------------------------------------------- completion
    def _trace_finish(self, reason: str) -> None:
        tr = _obs.TRACE
        if tr is not None and tr.want(self.req_id):
            tr.evt(_obs_events.REQ_FINISH, self.req_id, "serve", meta=reason)

    def retire(self) -> bool:
        """Finish the request: finalize tokens, publish completion.
        Returns False (no-op) if the request already reached a terminal
        state (concurrent cancel, expiry, or an earlier stop-retirement).
        """
        with self._deliver_lock:
            if self.req_state in _TERMINAL:
                return False
            if self._delivered_any:
                self._flush_hold()
                self.tokens = list(self._out)
            else:
                # legacy direct-push path (tests drive it): materialize
                self.tokens = [int(t) for t in self._device_tokens]
            self._device_tokens = []
            self.req_state = RequestState.FINISHED
            self.finish_time = time.monotonic()
            self._finished_evt.set()
            stream = self._stream
        # stream close and completion hooks (promise resolutions, user
        # .then() handlers, attached continuations — which may
        # inline-drain unrelated ready continuations) run OUTSIDE the
        # delivery lock: the terminal-state flip above already guarantees
        # delivery atomicity, and holding the lock across code that can
        # touch *other* requests could order locks ABBA
        self._trace_finish("finished")
        if stream is not None:
            stream._close("finished")
        self._complete(Status(payload=self.tokens, count=len(self.tokens)))
        return True

    def cancel(self) -> bool:
        """Cancel a not-yet-finished request (best effort: queued requests
        are dropped by the batcher; in-flight slots are swept at the next
        step boundary). Atomic against delivery: once ``cancel()``
        returns, no token — including one produced by the very step being
        cancelled under — is delivered to the stream or the token list.
        """
        with self._deliver_lock:
            if self.req_state in _TERMINAL:
                return False
            # the state flip is the atomic cutoff: any deliver() serialized
            # after this lock release drops its tokens
            self.req_state = RequestState.CANCELLED
            self.finish_time = time.monotonic()
            self._finished_evt.set()
            stream = self._stream
        # stream close + hooks outside the lock (see retire()); the state
        # check above makes this thread the only one reaching them, and
        # both still run before cancel() returns
        self._trace_finish("cancelled")
        if stream is not None:
            stream._close("cancelled")
        self._complete(Status(cancelled=True), OpState.CANCELLED)
        return True

    def expire(self) -> bool:
        """Deadline passed: fail the request with ``DeadlineExceeded``.

        Called by the batcher (queued past-deadline refusal) and by the
        engine's step-completion continuations (in-slot expiry, in the
        same continuation that releases the request's pages). Partial
        tokens stay readable on ``.tokens`` and ride the exception.
        """
        with self._deliver_lock:
            if self.req_state in _TERMINAL:
                return False
            self._flush_hold()
            self.tokens = list(self._out)
            err = DeadlineExceeded(
                f"request {self.req_id} missed its deadline "
                f"({self.config.deadline_s}s from arrival) with "
                f"{len(self.tokens)}/{self.config.max_tokens} tokens",
                tokens=self.tokens)
            self.req_state = RequestState.EXPIRED
            self.finish_time = time.monotonic()
            self._finished_evt.set()
            stream = self._stream
        # stream close + hooks outside the lock (see retire())
        self._trace_finish("expired")
        if stream is not None:
            stream._close("expired", err)
        self._complete(Status(error=err, payload=self.tokens),
                       OpState.FAILED)
        return True

    # --------------------------------------------------------- completable
    @property
    def supports_push(self) -> bool:
        return True    # retire()/cancel()/expire() publish completion

    def _poll(self) -> bool:
        return self._finished_evt.is_set()

    def wait(self, timeout: Optional[float] = None) -> bool:
        """Block the *caller* until finished (the engine loop never does)."""
        return self._finished_evt.wait(timeout)

    # -------------------------------------------------------------- metrics
    @property
    def ttft(self) -> Optional[float]:
        """Time to first token, from arrival (seconds)."""
        if self.first_token_time is None:
            return None
        return self.first_token_time - self.arrival_time

    @property
    def accept_rate(self) -> Optional[float]:
        """Fraction of proposed draft tokens the verify step accepted
        (None when the request never ran speculatively)."""
        if self.draft_tokens_proposed == 0:
            return None
        return self.draft_tokens_accepted / self.draft_tokens_proposed

    @property
    def latency(self) -> Optional[float]:
        if self.finish_time is None:
            return None
        return self.finish_time - self.arrival_time

    def __repr__(self) -> str:
        return (f"Request(id={self.req_id}, state={self.req_state.value}, "
                f"generated={self.generated}/{self.config.max_tokens})")


def summarize(requests: Sequence[Request]) -> dict:
    """Aggregate serving metrics over finished requests."""
    done = [r for r in requests if r.req_state is RequestState.FINISHED]
    ttfts = sorted(r.ttft for r in done if r.ttft is not None)
    total_tokens = sum(len(r.tokens) for r in done)
    proposed = sum(r.draft_tokens_proposed for r in done)
    accepted = sum(r.draft_tokens_accepted for r in done)
    out = {
        "finished": len(done),
        "total_tokens": total_tokens,
        "ttft_mean": sum(ttfts) / len(ttfts) if ttfts else 0.0,
        "ttft_p50": _percentile(ttfts, 0.50),
        "ttft_p99": _percentile(ttfts, 0.99),
        "draft_tokens_proposed": proposed,
        "draft_tokens_accepted": accepted,
        "accept_rate": accepted / proposed if proposed else 0.0,
    }
    return out


def _percentile(sorted_vals: Sequence[float], q: float) -> float:
    if not sorted_vals:
        return 0.0
    idx = min(len(sorted_vals) - 1, int(round(q * (len(sorted_vals) - 1))))
    return sorted_vals[idx]
