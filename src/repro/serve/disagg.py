"""Disaggregated prefill/decode serving over the continuation transport.

The paper's claim — completion callbacks let a runtime overlap
communication with computation instead of blocking at phase boundaries —
applied to the serving stack's biggest phase boundary: prefill vs
decode. Instead of one colocated ``ServeEngine`` doing both, two *roles*
run against one continuation engine and talk **only** through
``core.transport`` ops (never shared references), so a mesh/multi-host
backend can replace the in-process transport without touching either
role:

* ``PrefillWorker`` (rank 0) — admits routed requests into its own
  staging ``PagePool``, runs *chunked* prefill (one fused paged-suffix
  step per ``chunk_pages`` window), and ships each finished KV page to
  the decode role the moment the page's export slices complete — a
  continuation on the export ``ArrayOp`` issues the ``Transport.isend``,
  so shipping overlaps the remaining prefill chunks per-block, with no
  barrier at end-of-prompt. The worker's staging pages are released by a
  ``when_all`` continuation over the block sends (delivery-complete =
  safe to recycle), which is the "prefill pages released after ship"
  half of the leak contract.
* ``DecodeWorker`` (rank 1) — a ``ServeEngine`` whose admission path is
  remote ingestion instead of local prefill: a standing control receive
  accepts per-request headers (allocate the full decode footprint,
  post one block receive per shipped page), each block receive's
  delivery continuation installs the page into the decode ``PagePool``
  (``import_page``), and once the last block lands *and* the prefill
  role has delivered the first token, the request queues for a decode
  slot through a priority ``Batcher`` and is seated via the shared
  ``ServeEngine._seat_slot``. Decode pages release at retirement through
  the unchanged slot machinery — the other half of the leak contract.
* ``DisaggServer`` — the router/facade: one intake ``Batcher`` admits in
  QoS order and hands each request to the prefill role (control-plane
  only: both roles hold the same ``Request`` object for delivery and
  lifecycle, but **KV state** crosses the boundary exclusively as typed
  transport messages). The facade exposes the ``ServeEngine`` surface
  (``submit`` / ``step`` / ``run`` / ``metrics`` / ``idle`` /
  ``shutdown``), so ``serve.api.ServeClient`` token streams run over it
  unchanged.

Wire protocol (all messages typed; ``_payload_nbytes`` accounts block
payloads at their real size, so ``Transport.stats()`` shows shipping
bandwidth per tag):

* ``CTRL_TAG``: ``PrefillHeader`` (request announced; decode allocates
  its footprint and posts block receives) → ``PrefillDone`` (first
  token; seat when all blocks installed) *or* ``PrefillAbort`` (request
  ended at the prefill role — cancel/deadline/stop/budget-of-one;
  decode cancels outstanding block receives and releases pages —
  ``RecvOp.cancel``'s atomic complete-or-cancel keeps the teardown
  race-free).
* ``block_tag(req_id)``: one ``KVBlockMsg`` per prompt page, in page
  order (transport non-overtaking per tag), each installed by its own
  delivery continuation.

Token identity: the decode role runs the very same fused paged steps as
the colocated engine, and chunked prefill appends the same KV the
colocated suffix path would — so disaggregated token streams are
identical to colocated ones on the same traffic (asserted in
``tests/serve/test_disagg.py``, including speculative and
prefix-cache-hit traffic). The prefill role deliberately keeps no prefix
cache of its own (staging pages are recycled right after shipping);
cross-request prefix reuse on the prefill side is future work riding the
router's affinity hooks.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from dataclasses import dataclass
from typing import Any, Deque, Dict, List, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (ArrayOp, ContinueFlags, Engine, OpState, Scheduler,
                        Transport, when_all)
from repro.models.common import ModelConfig
from repro.obs import events as _obs_events
from repro.obs import tracer as _obs
from repro.serve.batcher import Batcher
from repro.serve.drafter import Drafter
from repro.serve.engine import ServeEngine, _step_flags
from repro.serve.kv_cache import paged_supported, pages_for, PagePool
from repro.serve.metrics import ServeMetrics, transport_fields
from repro.serve.request import Request, RequestState, summarize
from repro.serve.steps import make_fused_paged_suffix_step

PREFILL_RANK = 0
DECODE_RANK = 1

# control-plane channel (headers / done / abort); data-plane channels are
# per-request so per-tag transport stats separate KV bandwidth from
# control chatter
CTRL_TAG = 7001
_BLOCK_TAG_BASE = 1 << 16

_FLAGS = ContinueFlags(enqueue_complete=True)


def block_tag(req_id: int) -> int:
    """Per-request KV-block channel tag."""
    return _BLOCK_TAG_BASE + req_id


# handoff-lifecycle ``_log`` kinds -> trace-event kinds. ``seat`` is
# omitted: ``ServeEngine._seat_slot`` (shared with the colocated path)
# already emits ``req.seat``.
_LOG_EVENTS = {
    "ship": _obs_events.REQ_KV_SHIP,
    "install": _obs_events.REQ_KV_IMPORT,
    "header": "req.kv.announce",
    "prefill_done": "req.prefill.done",
    "landed": "req.kv.landed",
    "abort": "req.abort",
    "prefill_released": _obs_events.REQ_PAGES_RELEASE,
}


def _trace_log(kind: str, req_id: int, rest: tuple, src: str) -> None:
    """Mirror a handoff-lifecycle record into the tracer (same sampling
    as every other ``req.*`` event, so timelines stay complete)."""
    tr = _obs.TRACE
    if tr is None:
        return
    ek = _LOG_EVENTS.get(kind)
    if ek is not None and tr.want(req_id):
        tr.evt(ek, req_id, src, meta=rest[0] if rest else None)


# --------------------------------------------------------------- messages
@dataclass(frozen=True)
class PrefillHeader:
    """Announces a request to the decode role: allocate the footprint
    for ``plen + max_new`` tokens and post ``n_ship`` block receives."""
    req_id: int
    plen: int
    max_new: int
    n_ship: int


@dataclass(frozen=True)
class PrefillDone:
    """Prefill finished; ``first_token`` was already delivered to the
    request at the prefill role (TTFT does not wait for seating)."""
    req_id: int
    first_token: int


@dataclass(frozen=True)
class PrefillAbort:
    """The request ended at the prefill role (cancel, deadline, stop
    sequence or single-token budget). ``shipped`` blocks were (or are
    being) sent; the decode role drains/cancels accordingly."""
    req_id: int
    shipped: int


@dataclass(frozen=True)
class KVBlockMsg:
    """One shipped KV page: ``k``/``v`` device arrays of shape
    ``(n_layers, page_size, kv_heads, head_dim)``. ``nbytes`` lets the
    transport account the payload at its real wire size (eager vs
    rendezvous, per-tag byte counters)."""
    req_id: int
    index: int
    k: Any
    v: Any

    @property
    def nbytes(self) -> int:
        return int(self.k.nbytes) + int(self.v.nbytes)


# ---------------------------------------------------------- prefill role
class _PrefillJob:
    """Host bookkeeping for one request moving through chunked prefill."""

    __slots__ = ("req", "prompt", "plen", "n_ship", "ship", "table", "pos",
                 "exported", "shipped", "exports_pending", "sends",
                 "chunk_inflight", "first_arr", "done", "aborted",
                 "released")

    def __init__(self, req: Request, prompt: np.ndarray, n_ship: int,
                 table: List[int], ship: bool) -> None:
        self.req = req
        self.prompt = prompt
        self.plen = int(prompt.shape[0])
        self.n_ship = n_ship
        self.ship = ship                  # False: budget of 1, nothing ships
        self.table = table
        self.pos = 0                      # prompt tokens prefilled so far
        self.exported = 0                 # pages whose export is dispatched
        self.shipped = 0                  # block sends issued
        self.exports_pending = 0
        self.sends: List[Any] = []
        self.chunk_inflight = False
        self.first_arr: Optional[jax.Array] = None
        self.done = False                 # all chunks computed
        self.aborted = False
        self.released = False


class PrefillWorker:
    """The prefill role: chunked prompt prefill + per-block KV shipping.

    Owns a small staging ``PagePool`` sized for in-flight prompts only;
    pages recycle as soon as a request's block sends complete, so the
    staging pool never grows with decode residency. Driven by the same
    loop thread as the decode role (single-consumer, like
    ``ServeEngine``); all callbacks here are continuations running on
    that thread.
    """

    def __init__(self, cfg: ModelConfig, params: Any, *, engine: Engine,
                 transport: Transport, rank: int, peer: int,
                 page_size: int, total_pages: int, max_prompt_len: int,
                 chunk_pages: int = 1, max_jobs: int = 2,
                 events: Optional[List[tuple]] = None) -> None:
        if not paged_supported(cfg):
            raise ValueError("disaggregated prefill requires a "
                             "paged-cache-capable model config")
        self.cfg = cfg
        self.params = params
        self.engine = engine
        self.transport = transport
        self.rank, self.peer = rank, peer
        self.page_size = int(page_size)
        self.max_jobs = max(1, int(max_jobs))
        self.pool = PagePool(cfg, total_pages, page_size)
        self._table_pages = pages_for(max_prompt_len, page_size)
        self._window = max(1, int(chunk_pages)) * self.page_size
        self._suffix_fn = jax.jit(
            make_fused_paged_suffix_step(cfg, self.page_size),
            donate_argnums=(1,))
        self.cr = engine.continue_init()
        self._jobs: Dict[int, _PrefillJob] = {}
        self._queue: Deque[Request] = deque()   # routed, waiting for pages
        self._events = events
        self._retired: List[Request] = []
        self._lock = threading.Lock()
        self.bytes_shipped = 0
        self.stats = {"jobs": 0, "chunks": 0, "blocks_shipped": 0,
                      "blocks_dropped": 0, "retired": 0, "stopped": 0,
                      "cancelled": 0, "expired": 0, "aborted": 0,
                      "deferred": 0}

    # ------------------------------------------------------------- intake
    @property
    def capacity(self) -> int:
        """How many more requests the router should hand over now."""
        return max(0, self.max_jobs - len(self._jobs) - len(self._queue))

    def start(self, req: Request) -> None:
        """Accept a routed request (may wait for staging pages)."""
        self._queue.append(req)

    def _activate(self) -> int:
        started = 0
        while self._queue and len(self._jobs) < self.max_jobs:
            req = self._queue[0]
            if req.req_state is RequestState.CANCELLED:
                self._queue.popleft()
                self.stats["cancelled"] += 1
                self._unannounce(req)
                continue
            if req.past_deadline():
                self._queue.popleft()
                if req.expire():
                    self.stats["expired"] += 1
                self._unannounce(req)
                continue
            prompt = np.asarray(req.prompt, np.int32).reshape(-1)
            n_ship = pages_for(prompt.shape[0], self.page_size)
            table = self.pool.alloc(n_ship)
            if table is None:
                self.stats["deferred"] += 1
                break
            self._queue.popleft()
            tr = _obs.TRACE
            if tr is not None and tr.want(req.req_id):
                tr.evt(_obs_events.REQ_PAGES_ALLOC, req.req_id, "prefill",
                       meta=len(table))
            ship = req.max_new_tokens > 1
            job = _PrefillJob(req, prompt, n_ship, table, ship)
            self._jobs[req.req_id] = job
            self.stats["jobs"] += 1
            if ship:
                # announce before any chunk runs: the decode role posts
                # its block receives ahead of the first send
                self.transport.isend(self.rank, self.peer, CTRL_TAG,
                                     PrefillHeader(req.req_id, job.plen,
                                                   req.max_new_tokens,
                                                   n_ship))
                self._log("header", req.req_id)
            started += 1
        return started

    def _unannounce(self, req: Request) -> None:
        """A routed request died before prefill even started: the decode
        role may be expecting it — a zero-shipped abort clears that."""
        if req.max_new_tokens > 1:
            self.transport.isend(self.rank, self.peer, CTRL_TAG,
                                 PrefillAbort(req.req_id, 0))

    # --------------------------------------------------------------- loop
    def step(self) -> bool:
        """Dispatch the next chunk of every job with no chunk in flight."""
        progressed = bool(self._activate())
        for job in list(self._jobs.values()):
            if job.chunk_inflight or job.done or job.aborted:
                continue
            req = job.req
            if req.req_state is RequestState.CANCELLED:
                self.stats["cancelled"] += 1
                self._abort(job)
                continue
            if req.past_deadline():
                if req.expire():
                    self.stats["expired"] += 1
                self._abort(job)
                continue
            self._dispatch_chunk(job)
            progressed = True
        return progressed

    def _padded_table(self, table: List[int]) -> jax.Array:
        out = np.full(self._table_pages, self.pool.null_page, np.int32)
        out[:len(table)] = table
        return jnp.asarray(out)

    def _dispatch_chunk(self, job: _PrefillJob) -> None:
        self.pool.ensure_arrays()
        W = self._window
        start = job.pos
        end = min(start + W, job.plen)
        tail = end - start
        tok = np.zeros((1, W), np.int32)
        tok[0, :tail] = job.prompt[start:end]
        logits, self.pool.arrays = self._suffix_fn(
            self.params, self.pool.arrays, jnp.asarray(tok),
            jnp.asarray([start], jnp.int32),
            self._padded_table(job.table)[None],
            jnp.asarray([tail], jnp.int32))
        job.chunk_inflight = True
        self.stats["chunks"] += 1
        last = end == job.plen
        if last:
            job.first_arr = jnp.argmax(logits[:, tail - 1],
                                       axis=-1).astype(jnp.int32)
            op = ArrayOp(job.first_arr)
        else:
            op = ArrayOp(logits)
        tr = _obs.TRACE
        t0 = (tr.now() if tr is not None and tr.want(job.req.req_id)
              else None)
        self.engine.continue_when(op, self._on_chunk, (job, end, t0),
                                  cr=self.cr,
                                  flags=_step_flags(job.req.priority))

    def _on_chunk(self, statuses, meta) -> None:
        job, end, t0 = meta
        if t0 is not None:
            tr = _obs.TRACE
            if tr is not None:
                # one span per prefill chunk: dispatch -> device-complete,
                # interleaving with the per-block ship instants
                tr.evt(_obs_events.REQ_PREFILL, job.req.req_id, "prefill",
                       ts=t0, dur=tr.now() - t0, meta=end)
        job.chunk_inflight = False
        job.pos = end
        req = job.req
        if job.aborted:
            return
        if req.req_state is RequestState.CANCELLED:
            self.stats["cancelled"] += 1
            self._abort(job)
            return
        if req.past_deadline() and end < job.plen:
            # mid-prompt expiry: nothing delivered yet, fail cheaply (a
            # finished prompt falls through — the paid-for first token is
            # still returned, mirroring the colocated engine)
            if req.expire():
                self.stats["expired"] += 1
            self._abort(job)
            return
        done = end == job.plen
        # export every page this chunk completed (the partial tail page
        # counts once the whole prompt is in); each export's completion
        # continuation ships the block — communication overlaps the
        # remaining chunks per-block
        if job.ship:
            n_complete = job.n_ship if done else end // self.page_size
            for idx in range(job.exported, n_complete):
                kv = self.pool.export_page(job.table[idx])
                job.exports_pending += 1
                self.engine.continue_when(ArrayOp(kv), self._on_export,
                                          (job, idx, kv), cr=self.cr,
                                          flags=_FLAGS)
            job.exported = n_complete
        if not done:
            return
        job.done = True
        self._log("prefill_done", req.req_id)
        first = int(np.asarray(job.first_arr)[0])
        req.push_device_token(first)
        req.on_first_token()
        finished = req.deliver([first])
        if finished == "stop":
            self._retire(req, stopped=True)
            self._abort(job)
        elif req.remaining == 0:
            # budget of one: answered entirely at the prefill role — the
            # decode role was never involved (no header was sent)
            self._retire(req)
            self._abort(job, notify=job.ship)
        elif req.past_deadline():
            if req.expire():
                self.stats["expired"] += 1
            self._abort(job)
        else:
            self.transport.isend(self.rank, self.peer, CTRL_TAG,
                                 PrefillDone(req.req_id, first))
            self._maybe_finalize(job)

    def _on_export(self, statuses, meta) -> None:
        job, idx, kv = meta
        job.exports_pending -= 1
        if job.aborted:
            self.stats["blocks_dropped"] += 1
            self._maybe_finalize(job)
            return
        msg = KVBlockMsg(job.req.req_id, idx, kv["k"], kv["v"])
        op = self.transport.isend(self.rank, self.peer,
                                  block_tag(job.req.req_id), msg)
        job.sends.append(op)
        job.shipped += 1
        self.bytes_shipped += msg.nbytes
        self.stats["blocks_shipped"] += 1
        self._log("ship", job.req.req_id, idx)
        self._maybe_finalize(job)

    # ----------------------------------------------------------- teardown
    def _abort(self, job: _PrefillJob, notify: bool = True) -> None:
        """Stop shipping for a job (terminal at this role). ``notify``
        tells the decode role to tear its landing down — skipped only
        when no header was ever sent."""
        if job.aborted:
            return
        job.aborted = True
        self.stats["aborted"] += 1
        if notify and job.ship:
            self.transport.isend(self.rank, self.peer, CTRL_TAG,
                                 PrefillAbort(job.req.req_id, job.shipped))
            self._log("abort", job.req.req_id)
        self._maybe_finalize(job)

    def _maybe_finalize(self, job: _PrefillJob) -> None:
        """Once every dispatched export has either shipped or been
        dropped, release the staging pages when ALL block sends complete
        (delivery done — ``when_all([])`` is vacuous for unshipped
        jobs)."""
        if job.released or job.exports_pending:
            return
        if not (job.done or job.aborted):
            return
        job.released = True
        self.engine.continue_when(when_all(job.sends),
                                  self._on_ships_complete, job,
                                  cr=self.cr, flags=_FLAGS)

    def _on_ships_complete(self, statuses, job: _PrefillJob) -> None:
        self.pool.release(job.table)
        job.table = []
        self._jobs.pop(job.req.req_id, None)
        self._log("prefill_released", job.req.req_id)

    def _retire(self, req: Request, stopped: bool = False) -> None:
        if not req.retire():
            if req.req_state is RequestState.CANCELLED:
                self.stats["cancelled"] += 1
            return
        if stopped:
            self.stats["stopped"] += 1
        with self._lock:
            self._retired.append(req)
        self.stats["retired"] += 1

    # ------------------------------------------------------------- surface
    @property
    def retired(self) -> List[Request]:
        """Requests that finished entirely at the prefill role."""
        with self._lock:
            return list(self._retired)

    @property
    def idle(self) -> bool:
        return (not self._jobs and not self._queue
                and self.cr.active_count == 0)

    def metrics(self) -> "ServeMetrics":
        # canonical flat keys (the pool_* prefix this role used to apply
        # survives only as deprecated aliases on ServeMetrics)
        out = summarize(self.retired)
        out.update(self.stats)
        out["bytes_shipped"] = self.bytes_shipped
        out.update(self.pool.metrics())
        return ServeMetrics.from_flat(out)

    def _log(self, kind: str, req_id: int, *rest: Any) -> None:
        if self._events is not None:
            self._events.append((kind, req_id) + rest)
        _trace_log(kind, req_id, rest, "prefill")


# ----------------------------------------------------------- decode role
class _Landing:
    """One request's blocks-in-flight state on the decode side."""

    __slots__ = ("req", "plen", "n_ship", "first", "installed", "resolved",
                 "recvs", "active", "aborted", "queued")

    def __init__(self, req: Request, plen: int, n_ship: int) -> None:
        self.req = req
        self.plen = plen
        self.n_ship = n_ship
        self.first: Optional[int] = None   # set by PrefillDone
        self.installed = 0                 # blocks written into the pool
        self.resolved = 0                  # block receives completed/cancelled
        self.recvs: List[Any] = []
        self.active = False                # footprint allocated, recvs posted
        self.aborted = False
        self.queued = False                # handed to the seat batcher


class DecodeWorker(ServeEngine):
    """A ``ServeEngine`` whose admission path is remote KV ingestion.

    Local prefill never runs here: requests arrive as a ``PrefillHeader``
    on the control channel, their KV pages land via per-block delivery
    continuations (``PagePool.import_page``), and seating goes through
    ``_seat_slot`` — the same slot/step/retirement machinery as the
    colocated engine, so decode behavior (and tokens) are identical.

    The seat queue is a second ``Batcher``: landed requests admit into
    free slots in QoS order with past-deadline refusal, and its
    ``on_drop`` hook releases the already-landed pages of requests
    cancelled or expired while waiting — role-aware admission with the
    same component the router uses at intake.
    """

    def __init__(self, cfg: ModelConfig, params: Any, *,
                 transport: Transport, rank: int, peer: int,
                 events: Optional[List[tuple]] = None,
                 **engine_kwargs: Any) -> None:
        engine_kwargs.setdefault("paged", True)
        super().__init__(cfg, params, **engine_kwargs)
        if not self.paged:
            raise ValueError("DecodeWorker requires paged mode")
        self.transport = transport
        self.rank, self.peer = rank, peer
        self._events = events
        # standing control receive rides its own CR so its permanent
        # registration never blocks idle detection; block receives ride
        # cr_ingest and drain to zero with their landings
        self.cr_ctrl = self.engine.continue_init()
        self.cr_ingest = self.engine.continue_init()
        self._expected: Dict[int, Request] = {}
        self._landings: Dict[int, _Landing] = {}
        self._pending_landings: Deque[_Landing] = deque()
        self.seat_batcher = Batcher(self.engine, on_drop=self._drop_landed)
        self.ingest_stats = {"headers": 0, "blocks_installed": 0,
                             "blocks_discarded": 0, "blocks_drained": 0,
                             "remote_seated": 0, "aborts": 0,
                             "landings_deferred": 0}
        self._ctrl_op: Optional[Any] = None
        self._post_ctrl_recv()

    # ------------------------------------------------------------- intake
    def submit(self, request: Request) -> Request:
        raise RuntimeError(
            "the decode role receives work via transport ingestion; "
            "submit through the DisaggServer router")

    def expect(self, req: Request) -> None:
        """Control-plane registration: the router names the ``Request``
        object a forthcoming header refers to (the transport itself only
        ever carries ids and KV blocks)."""
        self._expected[req.req_id] = req

    # ----------------------------------------------------- control channel
    def _post_ctrl_recv(self) -> None:
        op = self.transport.irecv(self.rank, source=self.peer, tag=CTRL_TAG)
        self._ctrl_op = op
        self.engine.continue_when(op, self._on_ctrl, op, cr=self.cr_ctrl,
                                  flags=_FLAGS)

    def _on_ctrl(self, statuses, op) -> None:
        if op.state is OpState.CANCELLED:
            return                      # shutdown: don't re-arm
        msg = op.status.payload
        self._post_ctrl_recv()          # re-arm before processing
        if isinstance(msg, PrefillHeader):
            self._on_header(msg)
        elif isinstance(msg, PrefillDone):
            landing = self._landings.get(msg.req_id)
            if landing is not None:
                landing.first = int(msg.first_token)
                self._advance_landing(landing)
        elif isinstance(msg, PrefillAbort):
            self._on_abort(msg)

    def _on_header(self, msg: PrefillHeader) -> None:
        req = self._expected.pop(msg.req_id, None)
        if req is None:                 # router never announced it
            raise RuntimeError(f"header for unknown request {msg.req_id}")
        self.ingest_stats["headers"] += 1
        landing = _Landing(req, msg.plen, msg.n_ship)
        self._landings[msg.req_id] = landing
        if not self._try_activate(landing):
            self.ingest_stats["landings_deferred"] += 1
            self._pending_landings.append(landing)

    def _try_activate(self, landing: _Landing) -> bool:
        """Allocate the request's full decode footprint and post its
        block receives. False = pool can't cover it yet (backpressure:
        rendezvous block sends simply wait unmatched)."""
        req = landing.req
        n_pages = pages_for(landing.plen + req.max_new_tokens,
                            self.page_size)
        table = self.pool.alloc(n_pages)
        if table is None:
            return False
        req.page_ids = table
        tr = _obs.TRACE
        if tr is not None and tr.want(req.req_id):
            tr.evt(_obs_events.REQ_PAGES_ALLOC, req.req_id, "decode",
                   meta=len(table))
        landing.active = True
        self._ensure_state()
        for _ in range(landing.n_ship):
            rop = self.transport.irecv(self.rank, source=self.peer,
                                       tag=block_tag(req.req_id))
            landing.recvs.append(rop)
            self.engine.continue_when(rop, self._on_block, (landing, rop),
                                      cr=self.cr_ingest, flags=_FLAGS)
        return True

    # ------------------------------------------------------ block landing
    def _on_block(self, statuses, meta) -> None:
        landing, rop = meta
        landing.resolved += 1
        if rop.state is not OpState.CANCELLED:
            msg = rop.status.payload
            req = landing.req
            if landing.aborted or req.is_terminal:
                self.ingest_stats["blocks_discarded"] += 1
            else:
                self.pool.import_page(req.page_ids[msg.index],
                                      {"k": msg.k, "v": msg.v})
                landing.installed += 1
                self.ingest_stats["blocks_installed"] += 1
                self._log("install", msg.req_id, msg.index)
        self._advance_landing(landing)

    def _advance_landing(self, landing: _Landing) -> None:
        req = landing.req
        if landing.queued:
            return                      # seat queue / slot machinery owns it
        if landing.aborted or req.is_terminal:
            # teardown completes once every posted receive resolved
            # (matched-and-discarded or cancelled)
            if landing.resolved == len(landing.recvs):
                self._release_pages(req)
                self._landings.pop(req.req_id, None)
            return
        if landing.first is not None and landing.installed == landing.n_ship:
            landing.queued = True
            # full prompt pages join the decode-side prefix index, so
            # future colocated-style affinity/reuse can find them
            self.pool.register_prefix(req.prompt, req.page_ids)
            self.seat_batcher.submit(req)
            self._log("landed", req.req_id)

    def _on_abort(self, msg: PrefillAbort) -> None:
        self.ingest_stats["aborts"] += 1
        # the request may have died before its header was ever sent
        self._expected.pop(msg.req_id, None)
        landing = self._landings.get(msg.req_id)
        if landing is None:
            return
        if landing.queued:
            return                      # done+abort never both arrive
        landing.aborted = True
        if not landing.active:
            # never allocated: just drain the blocks already in flight so
            # their (rendezvous) sends complete and nothing lingers in
            # the unexpected queue
            try:
                self._pending_landings.remove(landing)
            except ValueError:
                pass
            self._landings.pop(msg.req_id, None)
            for _ in range(msg.shipped):
                rop = self.transport.irecv(self.rank, source=self.peer,
                                           tag=block_tag(msg.req_id))
                self.engine.continue_when(rop, self._on_drain, rop,
                                          cr=self.cr_ingest, flags=_FLAGS)
            return
        # cancel still-posted receives; ones concurrently matching resolve
        # through _on_block (RecvOp.cancel is atomic complete-or-cancel)
        for rop in landing.recvs:
            if rop.state is OpState.PENDING:
                rop.cancel()
        self._advance_landing(landing)

    def _on_drain(self, statuses, rop) -> None:
        self.ingest_stats["blocks_drained"] += 1

    def _drop_landed(self, req: Request) -> None:
        """Seat-batcher ``on_drop``: a landed request was refused
        (cancelled or past-deadline while queued for a slot) — its pages
        are already allocated and must release here."""
        self._landings.pop(req.req_id, None)
        self._release_pages(req)

    # ------------------------------------------------------------ seating
    def _admit(self) -> int:
        # deferred landings first: pages freed by retirements may now
        # cover them (FIFO — the prefill role already ordered admission)
        while self._pending_landings:
            landing = self._pending_landings[0]
            if landing.req.is_terminal:
                self._pending_landings.popleft()
                self._landings.pop(landing.req.req_id, None)
                continue
            if not self._try_activate(landing):
                break
            self._pending_landings.popleft()
        free = self._free_slots()
        if not free:
            return 0
        admitted = 0
        for req in self.seat_batcher.admit(len(free)):
            landing = self._landings.pop(req.req_id, None)
            if landing is None:
                continue
            self._ensure_state()
            ctx = None
            if self.speculate:
                ctx = [int(t) for t in
                       np.asarray(req.prompt, np.int32).reshape(-1)]
                ctx.append(landing.first)
            self._seat_slot(free.pop(0), req, jnp.int32(landing.first),
                            landing.plen, ctx=ctx)
            self.ingest_stats["remote_seated"] += 1
            self._log("seat", req.req_id)
            admitted += 1
        return admitted

    # ------------------------------------------------------------- surface
    @property
    def idle(self) -> bool:
        return (super().idle
                and not self._landings and not self._pending_landings
                and self.seat_batcher.queued == 0
                and self.seat_batcher.cr.active_count == 0
                and self.cr_ingest.active_count == 0)

    def shutdown_ingest(self) -> None:
        """Cancel the standing control receive (facade shutdown)."""
        if self._ctrl_op is not None:
            self._ctrl_op.cancel()

    def _metrics_flat(self) -> dict:
        out = super()._metrics_flat()
        out.update(self.ingest_stats)
        return out

    def _log(self, kind: str, req_id: int, *rest: Any) -> None:
        if self._events is not None:
            self._events.append((kind, req_id) + rest)
        _trace_log(kind, req_id, rest, "decode")


# --------------------------------------------------------------- facade
class DisaggServer:
    """Router + facade over a prefill role and a decode role connected by
    an in-process ``Transport`` (2 ranks, one shared continuation
    engine, one driver thread).

    Exposes the ``ServeEngine`` surface — ``submit`` / ``step`` /
    ``run`` / ``close_intake`` / ``idle`` / ``metrics`` / ``retired`` /
    ``shutdown`` plus a ``batcher`` attribute — so ``ServeClient`` and
    the token-stream API work over it unchanged. ``events`` records the
    handoff lifecycle (``header``/``ship``/``install``/``prefill_done``/
    ``landed``/``seat``/``abort``/``prefill_released``) in driver-thread
    order; tests assert per-block pipelining on it.

    Construction knobs beyond ``ServeEngine``'s: ``chunk_pages`` (prompt
    pages per prefill chunk — smaller chunks ship earlier), ``
    prefill_pages`` (staging pool size, default twice one max request),
    ``prefill_jobs`` (concurrent prompts at the prefill role), and the
    transport's ``latency_s`` / ``eager_threshold`` for experiments.
    """

    def __init__(self, cfg: ModelConfig, params: Any, *,
                 max_batch: int = 4,
                 max_cache_len: int = 256,
                 max_inflight: int = 2,
                 engine: Optional[Engine] = None,
                 scheduler: Union[str, Scheduler] = "fifo",
                 page_size: int = 16,
                 total_pages: Optional[int] = None,
                 max_seq_len: Optional[int] = None,
                 speculate: int = 0,
                 drafter: Optional[Drafter] = None,
                 fused: Optional[bool] = None,
                 chunk_pages: int = 1,
                 prefill_pages: Optional[int] = None,
                 prefill_jobs: int = 2,
                 latency_s: float = 0.0,
                 eager_threshold: int = 4096) -> None:
        if not paged_supported(cfg):
            raise ValueError("disaggregated serving requires a "
                             "paged-cache-capable model config")
        self._own_engine = engine is None
        self.engine = engine if engine is not None else \
            Engine(scheduler=scheduler)
        self.transport = Transport(2, engine=self.engine,
                                   latency_s=latency_s,
                                   eager_threshold=eager_threshold)
        self.events: List[tuple] = []
        self.decode = DecodeWorker(
            cfg, params, transport=self.transport, rank=DECODE_RANK,
            peer=PREFILL_RANK, events=self.events, engine=self.engine,
            max_batch=max_batch, max_cache_len=max_cache_len,
            max_inflight=max_inflight, paged=True, page_size=page_size,
            total_pages=total_pages, max_seq_len=max_seq_len,
            speculate=speculate, drafter=drafter, fused=fused)
        if prefill_pages is None:
            prefill_pages = 2 * pages_for(self.decode.max_seq_len,
                                          page_size)
        self.prefill = PrefillWorker(
            cfg, params, engine=self.engine, transport=self.transport,
            rank=PREFILL_RANK, peer=DECODE_RANK, page_size=page_size,
            total_pages=prefill_pages,
            max_prompt_len=self.decode.max_seq_len,
            chunk_pages=chunk_pages, max_jobs=prefill_jobs,
            events=self.events)
        self.batcher = Batcher(self.engine)      # router intake

    # ------------------------------------------------------------- clients
    def submit(self, request: Request) -> Request:
        plen = int(np.asarray(request.prompt).reshape(-1).shape[0])
        total = plen + request.max_new_tokens
        if total > self.decode.max_seq_len:
            raise ValueError(f"request needs {total} tokens > max_seq_len="
                             f"{self.decode.max_seq_len}")
        if pages_for(total, self.decode.page_size) \
                > self.decode.pool.total_pages:
            raise ValueError("request needs more pages than the decode "
                             f"pool holds ({self.decode.pool.total_pages})")
        if pages_for(plen, self.prefill.page_size) \
                > self.prefill.pool.total_pages:
            raise ValueError("prompt needs more pages than the prefill "
                             f"pool holds ({self.prefill.pool.total_pages})")
        tr = _obs.TRACE
        if tr is not None and tr.want(request.req_id):
            tr.evt(_obs_events.REQ_SUBMIT, request.req_id, "serve")
        return self.batcher.submit(request)

    def close_intake(self) -> None:
        self.batcher.close()

    @property
    def retired(self) -> List[Request]:
        return self.decode.retired + self.prefill.retired

    # ----------------------------------------------------------------- loop
    def _route(self) -> int:
        """Admit intake in QoS order and hand requests to the prefill
        role; the decode role is told to expect each one first (the
        header may race ahead on the control channel otherwise)."""
        reqs = self.batcher.admit(self.prefill.capacity)
        tr = _obs.TRACE
        for req in reqs:
            if tr is not None and tr.want(req.req_id):
                tr.evt(_obs_events.REQ_ADMIT, req.req_id, "serve",
                       ts=req.arrival_time,
                       dur=tr.now() - req.arrival_time)
            if req.max_new_tokens > 1:
                self.decode.expect(req)
            self.prefill.start(req)
        return len(reqs)

    def step(self) -> bool:
        routed = self._route()
        prefilled = self.prefill.step()
        decoded = self.decode.step()     # also ticks the shared engine
        return bool(routed) or prefilled or decoded

    @property
    def idle(self) -> bool:
        return (not self._pending_intake() and self.prefill.idle
                and self.decode.idle)

    def _pending_intake(self) -> bool:
        return bool(self.batcher.queued or self.batcher.cr.active_count)

    def run(self, timeout: Optional[float] = None,
            idle_sleep: float = 5e-5, until=None) -> List[Request]:
        deadline = None if timeout is None else time.monotonic() + timeout
        done = until if until is not None else \
            (lambda: self.batcher.closed and self.idle)
        while not done():
            if deadline is not None and time.monotonic() > deadline:
                raise TimeoutError(
                    "disagg serve loop timed out: "
                    f"queued={self.batcher.queued} "
                    f"prefill_jobs={len(self.prefill._jobs)} "
                    f"landings={len(self.decode._landings)} "
                    f"active={self.decode.active}")
            if not self.step():
                time.sleep(idle_sleep)
        return self.retired

    # -------------------------------------------------------------- metrics
    def metrics(self) -> "ServeMetrics":
        out = summarize(self.retired)
        out["disaggregated"] = True
        out["retired"] = (self.decode.stats["retired"]
                          + self.prefill.stats["retired"])
        # headline residency = the decode pool (long-lived KV); per-role
        # detail stays nested
        out["pages_in_use"] = self.decode.pool.pages_in_use
        out["total_pages"] = self.decode.pool.total_pages
        out["decode"] = self.decode.metrics()
        out["prefill"] = self.prefill.metrics()
        st = self.transport.stats()
        out["transport"] = st
        out.update(transport_fields(st))
        shipped = self.prefill.stats["blocks_shipped"]
        jobs = self.prefill.stats["jobs"]
        out["blocks_shipped"] = shipped
        out["bytes_shipped"] = self.prefill.bytes_shipped
        out["bytes_shipped_per_request"] = \
            self.prefill.bytes_shipped / jobs if jobs else 0.0
        return ServeMetrics.from_flat(out)

    def shutdown(self) -> None:
        self.batcher.close()
        self.decode.shutdown_ingest()
        self.decode.shutdown()           # closes its (unused) intake
        self.transport.shutdown()
        if self._own_engine:
            self.engine.shutdown()


def serve_requests_disagg(cfg: ModelConfig, params: Any,
                          requests: List[Request], *,
                          timeout: float = 300.0,
                          **kwargs: Any) -> List[Request]:
    """Convenience: serve a fixed request list through a disaggregated
    server to completion (mirror of ``serve.engine.serve_requests``)."""
    srv = DisaggServer(cfg, params, **kwargs)
    try:
        for r in requests:
            srv.submit(r)
        srv.close_intake()
        srv.run(timeout=timeout)
    finally:
        srv.shutdown()
    return list(requests)
