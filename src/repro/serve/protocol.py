"""The formal engine protocol every serving tier satisfies.

Three tiers grew the same surface organically — the colocated
``ServeEngine``, the disaggregated ``DisaggServer`` facade, and the
multi-replica ``Router`` — and ``ServeClient`` binds to whichever one it
is handed. ``EngineLike`` makes that contract explicit: anything with
``submit / step / run / metrics / shutdown`` (plus the ``idle`` /
``batcher`` / ``retired`` attributes the client's drain logic reads) IS
a serving engine, checkable at runtime via ``isinstance`` thanks to
``typing.runtime_checkable``.

The protocol is deliberately structural, not nominal: the tiers share no
base class (``DisaggServer`` and ``Router`` are facades composing
engines over a transport, not engine subclasses), and a mesh-backed
implementation living outside this repo should satisfy it without
importing anything but this module.
"""
from __future__ import annotations

from typing import (Any, List, Mapping, Optional, Protocol,
                    runtime_checkable)

from repro.serve.request import Request


@runtime_checkable
class EngineLike(Protocol):
    """Structural contract for a serving engine tier.

    Single-consumer loop semantics: exactly one thread drives
    ``step()``/``run()``; any thread may ``submit()``. ``metrics()``
    returns a read-only mapping (``serve.metrics.ServeMetrics`` for the
    in-repo tiers).
    """

    # one intake queue: the client's drain logic reads .closed/.drained
    batcher: Any

    def submit(self, request: Request) -> Request:
        """Thread-safe intake; returns the (validated) request."""
        ...

    def close_intake(self) -> None:
        """Refuse further submissions (the client's drain handshake)."""
        ...

    def step(self) -> bool:
        """One loop iteration; True if any work started or completed."""
        ...

    def run(self, timeout: Optional[float] = None,
            idle_sleep: float = 5e-5, until=None) -> List[Request]:
        """Drive the loop until drained (or ``until()`` flips true)."""
        ...

    def metrics(self) -> Mapping[str, Any]:
        """Snapshot of serving metrics (see ``serve.metrics``)."""
        ...

    def shutdown(self) -> None:
        """Release resources; idempotent."""
        ...

    @property
    def idle(self) -> bool:
        """Nothing queued, occupied, or in flight."""
        ...

    @property
    def retired(self) -> List[Request]:
        """Requests that finished (any terminal success path)."""
        ...
