"""Streaming session API — the serving front-end users actually call.

``ServeEngine`` speaks the runtime's native dialect (submit a ``Request``,
get tokens at retirement). This module is the *application-facing*
surface on top of it, built the way the paper says APM front-ends should
be: loosely coupled to the completion-notification engine, with all
concurrency surfaced through continuations rather than polling threads.

::

    client = ServeClient(cfg, params, max_batch=8)
    session = client.session(max_tokens=32, priority=1)

    stream = session.generate(prompt)            # -> TokenStream
    for tok in stream:                           # sync: per-token
        ...
    # or, from async code:
    async for tok in session.generate(prompt):   # asyncio: per-token
        ...
    text = await session.generate(prompt).text() # or just the final text

Delivery path (no polling thread anywhere): each decode-step completion
continuation delivers the newly accepted tokens to the ``Request``
(``Request.deliver``), which publishes them into the attached
``TokenStream``. The stream wakes sync consumers through a condition
variable and async consumers through a ``core.promise.Signal`` — a
re-armable chain of one-shot promises whose loop-safe settle
(``call_soon_threadsafe`` from the decode loop) is the same wakeup
machinery every promise uses. The decode loop never blocks on a
consumer: a consumer that falls more than ``config.stream_buffer``
tokens behind just marks the stream ``lagging`` (per-token wakeup
degrades to catch-up bursts; no token is ever dropped, and the final
token list is identical to retirement-time delivery).

``cancel()`` is atomic against delivery: tokens produced by a step still
in flight when ``cancel()`` returns are never delivered.
"""
from __future__ import annotations

import threading
import time
from typing import Any, Callable, Iterator, List, Optional, Union

from repro.core import Promise, PromiseCancelled, Signal
from repro.obs import events as _obs_events
from repro.obs import tracer as _obs
from repro.serve.config import DeadlineExceeded, GenerationConfig
from repro.serve.engine import ServeEngine
from repro.serve.metrics import ServeMetrics
from repro.serve.protocol import EngineLike
from repro.serve.request import Request, RequestState


def _default_detokenize(tokens: List[int]) -> str:
    """This repro is token-in/token-out (no tokenizer ships with it);
    the canonical rendering is space-joined token ids. Pass
    ``detokenize=`` to ``ServeClient`` to plug a real one."""
    return " ".join(str(t) for t in tokens)


class TokenStream:
    """Per-token view of one generation — sync iterator *and* async
    iterator, fed by the decode engine's step-completion continuations.

    Single-consumer. Iteration yields token ids as they are accepted and
    ends when the request finishes (budget or stop sequence), is
    cancelled, or misses its deadline — inspect ``reason`` afterwards, or
    use ``tokens()`` / ``text()``, which reject on cancel/expiry.
    """

    def __init__(self, request: Request,
                 detokenize: Optional[Callable[[List[int]], str]] = None
                 ) -> None:
        self.request = request
        self._detokenize = detokenize or _default_detokenize
        self._watermark = request.config.stream_buffer
        self._cond = threading.Condition()
        self._toks: List[int] = []        # everything ever published
        self._yielded = 0                 # consumed by this stream's iterator
        self._reason: Optional[str] = None
        self._lagging = False
        self._signal = Signal()           # async wakeup (multi-shot settle)
        self._done = Promise.deferred()   # settles at close
        self.first_token_time: Optional[float] = None
        request.attach_stream(self)

    # ---------------------------------------------------- engine-facing side
    # Called under the request's delivery lock, from the step-completion
    # continuation (or cancel()/retire()/expire() on their caller's
    # thread). Must never block: state update + wakeup only.
    def _publish(self, toks: List[int]) -> None:
        with self._cond:
            if self._reason is not None:
                return
            if self.first_token_time is None:
                self.first_token_time = time.monotonic()
            self._toks.extend(toks)
            if len(self._toks) - self._yielded > self._watermark:
                # consumer is further behind than the configured buffer:
                # it observes catch-up bursts from here on (sticky flag)
                self._lagging = True
            self._cond.notify_all()
        self._signal.set()

    def _close(self, reason: str,
               error: Optional[BaseException] = None) -> None:
        with self._cond:
            if self._reason is not None:
                return
            self._reason = reason
            self._cond.notify_all()
        self._signal.set()
        if reason == "finished":
            self._done._fulfill(list(self.request.tokens))
        elif reason == "expired":
            err = error or self.request.status.error or DeadlineExceeded(
                "request expired", tokens=list(self.request.tokens))
            self._done._reject(err)
        else:
            self._done._reject(PromiseCancelled())

    # -------------------------------------------------------- consumer side
    @property
    def lagging(self) -> bool:
        """True once the consumer fell behind the decode loop by more
        than ``config.stream_buffer`` tokens (sticky)."""
        return self._lagging

    @property
    def received(self) -> int:
        """Total tokens delivered to this stream so far."""
        with self._cond:
            return len(self._toks)

    @property
    def pending(self) -> int:
        """Tokens delivered but not yet consumed by this iterator."""
        with self._cond:
            return len(self._toks) - self._yielded

    @property
    def done(self) -> bool:
        return self._reason is not None

    @property
    def reason(self) -> Optional[str]:
        """``None`` while streaming; "finished", "cancelled" or "expired"
        once closed."""
        return self._reason

    def cancel(self) -> bool:
        """Cancel the underlying request. When this returns, no further
        token will be delivered — including tokens of a decode step
        already in flight."""
        return self.request.cancel()

    def tokens(self) -> Promise:
        """Awaitable/blockable promise for the *complete* token list
        (identical to retirement delivery). Rejects ``PromiseCancelled``
        on cancel and ``DeadlineExceeded`` on expiry."""
        return self._done.then(lambda toks: list(toks))

    def text(self) -> Promise:
        """``await stream.text()`` — the finished generation through the
        client's detokenizer. Same rejection contract as ``tokens()``."""
        return self._done.then(self._detokenize)

    def result(self, timeout: Optional[float] = None) -> List[int]:
        """Blocking ``tokens()`` for sync callers."""
        return self.tokens().result(timeout)

    # ------------------------------------------------------------- sync iter
    def __iter__(self) -> Iterator[int]:
        return self

    def __next__(self) -> int:
        with self._cond:
            while True:
                if self._yielded < len(self._toks):
                    tok = self._toks[self._yielded]
                    self._yielded += 1
                    return tok
                if self._reason is not None:
                    raise StopIteration
                self._cond.wait()

    # ------------------------------------------------------------ async iter
    def __aiter__(self) -> "TokenStream":
        return self

    async def __anext__(self) -> int:
        while True:
            # arm FIRST, then check, then await: a publish racing between
            # the check and the await settles the armed promise, so the
            # consumer cannot sleep through it (Signal contract)
            wakeup = self._signal.wait()
            with self._cond:
                if self._yielded < len(self._toks):
                    tok = self._toks[self._yielded]
                    self._yielded += 1
                    return tok
                if self._reason is not None:
                    raise StopAsyncIteration
            await wakeup


class Session:
    """A configuration scope over a ``ServeClient``: defaults for every
    ``generate()`` call (overridable per call), plus bulk cancellation."""

    def __init__(self, client: "ServeClient",
                 defaults: GenerationConfig) -> None:
        self.client = client
        self.defaults = defaults
        self._streams: List[TokenStream] = []
        self._lock = threading.Lock()

    def generate(self, prompt: Any,
                 config: Optional[GenerationConfig] = None,
                 **overrides: Any) -> TokenStream:
        """Submit one generation, return its ``TokenStream``.

        ``config`` replaces the session defaults wholesale; ``overrides``
        are individual ``GenerationConfig`` fields layered on top of
        whichever base applies — all validated here, at admission.
        """
        base = config if config is not None else self.defaults
        cfg = base.merged(**overrides) if overrides else base
        request = Request(prompt, cfg)
        tr = _obs.TRACE
        if tr is not None and tr.want(request.req_id):
            # client-side edge of the timeline: everything between this
            # instant and the tier's own req.submit is client overhead
            tr.evt(_obs_events.REQ_SUBMIT, request.req_id, "client")
        stream = TokenStream(request, detokenize=self.client.detokenize)
        self.client.submit(request)
        with self._lock:
            # lazily prune closed streams so a long-lived session doesn't
            # pin every past generation's token list
            self._streams = [s for s in self._streams if not s.done]
            self._streams.append(stream)
        return stream

    @property
    def streams(self) -> List[TokenStream]:
        """Streams not yet pruned (every open one, plus recently closed
        ones generate() hasn't swept yet)."""
        with self._lock:
            return list(self._streams)

    def cancel_all(self) -> int:
        """Best-effort cancel of every stream this session opened;
        returns how many actually transitioned to cancelled."""
        return sum(1 for s in self.streams if s.cancel())


class ServeClient:
    """Process-local serving client: owns an ``EngineLike`` tier and the
    one thread driving its serve loop, so callers (sync or async, any
    thread) only ever touch sessions and streams.

    Build it over a model (``ServeClient(cfg, params, max_batch=8, ...)``
    — engine kwargs pass through to ``ServeEngine``) or wrap ANY tier
    satisfying ``serve.protocol.EngineLike``
    (``ServeClient(engine=serve_engine_or_disagg_or_router)``): the
    client speaks only the protocol surface (``submit``/``step``/
    ``metrics``/``shutdown`` plus the ``batcher``/``idle`` drain
    contract), so one client binds to the colocated engine, the
    disaggregated server, or the multi-replica router interchangeably.
    The serve loop starts lazily with the first submission; ``close()``
    drains and joins it. Usable as a context manager.
    """

    def __init__(self, cfg: Any = None, params: Any = None, *,
                 engine: Optional[EngineLike] = None,
                 detokenize: Optional[Callable[[List[int]], str]] = None,
                 defaults: Optional[GenerationConfig] = None,
                 idle_sleep: float = 5e-5,
                 **engine_kwargs: Any) -> None:
        if engine is None:
            if cfg is None or params is None:
                raise ValueError(
                    "ServeClient needs (cfg, params) or engine=")
            engine = ServeEngine(cfg, params, **engine_kwargs)
        elif engine_kwargs:
            raise ValueError("engine= and engine kwargs are exclusive")
        elif not isinstance(engine, EngineLike):
            raise TypeError(
                f"engine= must satisfy serve.protocol.EngineLike, got "
                f"{type(engine).__name__}")
        self.serve: EngineLike = engine
        self.detokenize = detokenize or _default_detokenize
        self.defaults = defaults or GenerationConfig()
        self._idle_sleep = idle_sleep
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self._thread_lock = threading.Lock()
        self._closed = False
        self._loop_error: Optional[BaseException] = None
        # live requests, so a dying loop can cancel them (closing their
        # streams) instead of stranding consumers; pruned on submit
        self._live: List[Request] = []
        self._live_lock = threading.Lock()

    # -------------------------------------------------------------- sessions
    def session(self, config: Optional[GenerationConfig] = None,
                **defaults: Any) -> Session:
        """A new ``Session``; ``defaults`` are ``GenerationConfig`` fields
        layered over the client defaults (or over ``config``)."""
        base = config if config is not None else self.defaults
        return Session(self, base.merged(**defaults) if defaults else base)

    def generate(self, prompt: Any,
                 config: Optional[GenerationConfig] = None,
                 **overrides: Any) -> TokenStream:
        """One-off generation on an anonymous session."""
        return self.session().generate(prompt, config, **overrides)

    # ------------------------------------------------------------ loop/drive
    def submit(self, request: Request) -> Request:
        """Submit a raw ``Request`` (streams usually go via sessions)."""
        self._ensure_loop()
        self.serve.submit(request)   # may raise: track only accepted work
        with self._live_lock:
            self._live = [r for r in self._live if not r.is_terminal]
            self._live.append(request)
        if self._loop_error is not None:
            request.cancel()         # loop died while we were tracking
        return request

    def _ensure_loop(self) -> None:
        if self._loop_error is not None:
            # a crashed loop fails the client: silently restarting would
            # mask the error (and auto-cancel work against it). close()
            # re-raises; a fresh client is the recovery path.
            raise RuntimeError(
                "serve loop crashed; client is failed — close() it"
            ) from self._loop_error
        if self._thread is not None and self._thread.is_alive():
            return
        with self._thread_lock:
            if self._closed:
                raise RuntimeError("client is closed")
            if self._thread is None or not self._thread.is_alive():
                self._stop.clear()
                self._thread = threading.Thread(
                    target=self._loop, name="serve-client-loop", daemon=True)
                self._thread.start()

    def _loop(self) -> None:
        # the single decode-loop thread (ServeEngine is single-consumer):
        # admits, dispatches, and runs the completion continuations that
        # feed every TokenStream
        try:
            while not self._stop.is_set():
                if not self.serve.step():
                    time.sleep(self._idle_sleep)
        except BaseException as exc:
            # a dead loop must not strand anyone: consumers blocked on
            # streams of in-flight requests would otherwise wait forever,
            # and close() would hang on a drain that can no longer
            # happen. Cancel every live request (closing its stream and
            # rejecting its promises) and re-raise the error from
            # close() on the caller's thread. An error raised AFTER
            # close() signalled stop is teardown noise (the engine may be
            # shutting down under a step that overran the drain window):
            # abandoned requests are still cancelled, but the client is
            # not marked failed.
            if not self._stop.is_set():
                self._loop_error = exc
            with self._live_lock:
                live, self._live = self._live, []
            for req in live:
                req.cancel()

    def metrics(self) -> ServeMetrics:
        return self.serve.metrics()

    def close(self, timeout: Optional[float] = 60.0) -> None:
        """Close intake, drain in-flight work, stop the loop thread and
        shut the engine down."""
        with self._thread_lock:
            if self._closed:
                return
            self._closed = True
        self.serve.close_intake()
        thread = self._thread
        if thread is not None and thread.is_alive():
            deadline = None if timeout is None \
                else time.monotonic() + timeout
            while not (self.serve.batcher.drained and self.serve.idle):
                if not thread.is_alive():
                    break                       # loop died: don't hang
                if deadline is not None and time.monotonic() > deadline:
                    break
                time.sleep(self._idle_sleep)
        self._stop.set()
        if thread is not None:
            thread.join(timeout=5.0)
        self.serve.shutdown()
        if self._loop_error is not None:
            raise self._loop_error

    def __enter__(self) -> "ServeClient":
        return self

    def __exit__(self, *exc: Any) -> None:
        self.close()
