"""Multi-replica front door: prefix-affinity routing, weighted tenant
fairness, and heartbeat-driven failover — all reactions in continuations.

One decode loop is not a fleet. The ``Router`` fronts N serving replicas
— each a full ``EngineLike`` tier (``ServeEngine`` or ``DisaggServer``)
— and speaks to them ONLY through the typed ``core.transport`` control
plane plus the continuation machinery, the same discipline
``serve.disagg`` established for the prefill/decode boundary: the
``Request`` object is shared in-process for token delivery, but
everything the router *decides* on (routing, liveness, residency) rides
transport messages, so a multi-host backend can replace the in-process
transport without touching the policy.

Three policies, one loop:

* **Prefix-affinity routing** — prompts are content-hashed with the very
  chained page digests ``PagePool`` indexes resident pages under
  (``kv_cache.prefix_keys``), and each replica *gossips* its resident
  digest set on a control tag whenever it changes. A request routes to
  the replica holding the longest leading run of its prompt's page keys
  — where its KV pages already live, so the replica's prefix cache turns
  the prompt into a suffix-prefill — falling back to the least-loaded
  replica when there is no hit or the affine replica is saturated.
  Dispatches insert the routed prompt's digests optimistically, so a
  burst of same-prefix traffic lands together without waiting a gossip
  round-trip.
* **Weighted per-tenant fairness** — intake is a ``FairBatcher``: strict
  ``priority`` classes, weighted deficit round robin across
  ``config.tenant`` lanes within each class. On top sits per-tenant
  admission control: more than ``quota`` outstanding requests refuses
  the submit with ``QuotaExceeded`` carrying a retry-after hint (the
  router's EWMA of request latency).
* **Failure-driven requeue** — every replica ``beat()``s a
  ``HeartbeatSender`` from its step loop; the router runs the
  ``HeartbeatMonitor`` whose missed-deadline sweep (a continuation
  chained on a ``TimerOp`` promise — no poller thread) declares a silent
  replica dead *inside the sweep continuation*: its pending transport
  receives are cancelled (``Transport.cancel_posted`` — cancelled
  statuses flow to their continuations, paper Listing 4), its in-flight
  requests requeue at the **head** of their priority class, and the
  affinity map shrinks ``runtime.elastic``-style to the surviving
  replicas.

**Failover replay.** The router never hands a client's ``Request`` to a
replica. Each dispatch creates a *shadow* request (same prompt, same
config, same arrival time) whose attached stream is a ``_ReplayAdapter``
forwarding committed tokens into the original ``Request.deliver``. On
replica death the shadow is simply cancelled (the original unaffected)
and a fresh shadow restarts from the prompt on a surviving replica,
skipping the first ``original.delivered`` regenerated tokens — greedy
decode is deterministic, so the replayed stream is token-identical to an
uninterrupted run, and the client's stream observes at most a latency
blip. Zero requests are lost.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass
from typing import (Any, Callable, Dict, FrozenSet, List, Optional, Set,
                    Union)

import numpy as np

from repro.core import ContinueFlags, Engine, OpState, Scheduler, Transport
from repro.runtime.heartbeat import HeartbeatMonitor, HeartbeatSender
from repro.serve.batcher import FairBatcher
from repro.serve.config import GenerationConfig, QuotaExceeded
from repro.serve.disagg import DisaggServer
from repro.serve.engine import ServeEngine
from repro.obs import events as _obs_events
from repro.obs import tracer as _obs
from repro.serve.kv_cache import pages_for, prefix_keys
from repro.serve.metrics import ServeMetrics, transport_fields
from repro.serve.protocol import EngineLike
from repro.serve.request import Request, RequestState, summarize

ROUTER_RANK = 0

# control-plane channels on the router's transport (replica ranks are
# 1..N; heartbeats ride runtime.heartbeat.HEARTBEAT_TAG)
ROUTE_TAG = 8001
GOSSIP_TAG = 8002

_FLAGS = ContinueFlags(enqueue_complete=True)


# --------------------------------------------------------------- messages
@dataclass(frozen=True)
class RouteMsg:
    """Hand one expected request to a replica (ids only on the wire;
    the ``Request`` object was registered via ``ReplicaWorker.expect``)."""
    req_id: int


@dataclass(frozen=True)
class PrefixDigestMsg:
    """A replica's resident-prefix gossip: the digest set its ``PagePool``
    currently indexes (sent only when it changed)."""
    rank: int
    digests: FrozenSet[bytes]


# ---------------------------------------------------------- failover glue
class _ReplayAdapter:
    """The shadow request's stream: forwards committed tokens into the
    original, skipping the first ``skip`` regenerated ones (already
    delivered before the previous replica died). Greedy determinism
    makes the skipped prefix byte-identical, so the original's stream
    sees each token exactly once."""

    __slots__ = ("original", "_skip")

    def __init__(self, original: Request, skip: int) -> None:
        self.original = original
        self._skip = skip

    def _publish(self, toks: List[int]) -> None:
        if self._skip:
            n = min(self._skip, len(toks))
            self._skip -= n
            toks = toks[n:]
        if toks:
            self.original.on_first_token()
            self.original.deliver(toks)

    def _close(self, reason: str,
               error: Optional[BaseException] = None) -> None:
        if reason == "finished":
            self.original.retire()
        elif reason == "expired":
            self.original.expire()
        # "cancelled" is router-initiated (failover re-shadow, or the
        # original was cancelled first): never propagated to the original

    # stream-protocol stubs (Request.attach_stream only uses the above)
    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return f"_ReplayAdapter(req={self.original.req_id}, " \
               f"skip={self._skip})"


class _Tracked:
    """Router-side bookkeeping for one client request."""

    __slots__ = ("original", "shadow", "rank", "replays", "seq")

    def __init__(self, original: Request, seq: int) -> None:
        self.original = original
        self.shadow: Optional[Request] = None
        self.rank: Optional[int] = None
        self.replays = 0
        self.seq = seq


# ------------------------------------------------------------ the replica
def _tier_core(tier: EngineLike) -> Any:
    """The object holding paged-serving limits/pool for a tier."""
    return tier.decode if isinstance(tier, DisaggServer) else tier


class ReplicaWorker:
    """One replica behind the router: an ``EngineLike`` tier plus the
    replica half of the control plane — a standing routed-work receive
    (re-armed by its own continuation), heartbeat beats from the step
    loop, and resident-prefix gossip whenever the digest set changes.

    Driven by the router's single driver thread. ``kill()`` simulates
    replica death: the worker stops stepping and beating; the monitor's
    sweep notices the silence.
    """

    def __init__(self, tier: EngineLike, *, engine: Engine,
                 transport: Transport, rank: int,
                 heartbeat_interval_s: float = 0.005) -> None:
        self.tier = tier
        self.engine = engine
        self.transport = transport
        self.rank = rank
        self.alive = True
        self.sender = HeartbeatSender(transport, rank, ROUTER_RANK,
                                      interval_s=heartbeat_interval_s)
        self.cr = engine.continue_init()
        self._expected: Dict[int, Request] = {}
        self._last_digests: Optional[FrozenSet[bytes]] = None
        self._route_op: Optional[Any] = None
        self._post_route_recv()

    # ---------------------------------------------------------- limits
    @property
    def core(self) -> Any:
        return _tier_core(self.tier)

    @property
    def pool(self):
        return getattr(self.core, "pool", None)

    # ----------------------------------------------------- control plane
    def expect(self, req: Request) -> None:
        """Register the Request a forthcoming ``RouteMsg`` names (the
        wire carries ids only — same registry idiom as ``disagg``)."""
        self._expected[req.req_id] = req

    def _post_route_recv(self) -> None:
        op = self.transport.irecv(self.rank, source=ROUTER_RANK,
                                  tag=ROUTE_TAG)
        self._route_op = op
        self.engine.continue_when(op, self._on_route, op, cr=self.cr,
                                  flags=_FLAGS)

    def _on_route(self, statuses, op) -> None:
        if op.state is OpState.CANCELLED:
            return                       # death/shutdown: don't re-arm
        msg: RouteMsg = op.status.payload
        self._post_route_recv()          # re-arm before processing
        req = self._expected.pop(msg.req_id, None)
        if req is None:
            raise RuntimeError(f"routed unknown request {msg.req_id}")
        if req.req_state is RequestState.CANCELLED:
            return                       # died between dispatch and here
        self.tier.submit(req)

    def _gossip(self) -> None:
        pool = self.pool
        if pool is None:
            return
        digests = pool.prefix_digests()
        if digests != self._last_digests:
            self._last_digests = digests
            self.transport.isend(self.rank, ROUTER_RANK, GOSSIP_TAG,
                                 PrefixDigestMsg(self.rank, digests))

    # --------------------------------------------------------------- loop
    def step(self) -> bool:
        if not self.alive:
            return False
        self.sender.beat()
        progressed = self.tier.step()
        self._gossip()
        return progressed

    def kill(self) -> None:
        """Simulate replica death: stop stepping and beating (the
        monitor's sweep will flag the silence)."""
        self.alive = False

    def quiesce(self, max_steps: int = 500) -> None:
        """Reclaim a dead replica's lease: with every in-flight shadow
        already cancelled by the router, drive the tier's own sweep
        machinery until its pages drain — the in-process analogue of the
        elastic controller tearing down a failed rank's resources."""
        pool = self.pool
        for _ in range(max_steps):
            if (pool is None or pool.pages_in_use == 0) and self.tier.idle:
                break
            self.tier.step()

    def shutdown(self) -> None:
        if self._route_op is not None and \
                self._route_op.state is OpState.PENDING:
            self._route_op.cancel()
        self.tier.shutdown()


# -------------------------------------------------------------- the router
class Router:
    """The multi-replica front door (see module docstring).

    Satisfies ``serve.protocol.EngineLike``: ``ServeClient`` binds to a
    ``Router`` exactly as it binds to a single engine. Single-consumer
    like every tier: one thread drives ``step()``/``run()``; any thread
    may ``submit()``.

    Construction: either pass ``replicas=[tier, ...]`` (pre-built
    ``EngineLike`` tiers sharing ``engine=``), or ``(cfg, params)`` with
    ``n_replicas`` and engine kwargs to build homogeneous ``ServeEngine``
    replicas. Policy knobs:

    * ``weights`` / ``quantum`` — tenant fairness (``FairBatcher``).
    * ``quota`` — max outstanding requests per tenant (int for all, or
      ``{tenant: n}``; ``None`` = unlimited). Refusal raises
      ``QuotaExceeded`` with ``retry_after_s`` from the latency EWMA.
    * ``saturation`` — per-replica in-flight cap before affinity falls
      back to least-loaded (default ``2 * max_batch``).
    * ``heartbeat_timeout_s`` / ``sweep_interval_s`` — failure detector.
    """

    def __init__(self, cfg: Any = None, params: Any = None, *,
                 replicas: Optional[List[EngineLike]] = None,
                 n_replicas: int = 2,
                 engine: Optional[Engine] = None,
                 scheduler: Union[str, Scheduler] = "fifo",
                 weights: Optional[Dict[str, float]] = None,
                 quantum: float = 32.0,
                 quota: Union[None, int, Dict[str, int]] = None,
                 saturation: Optional[int] = None,
                 heartbeat_interval_s: float = 0.005,
                 heartbeat_timeout_s: float = 0.25,
                 sweep_interval_s: float = 0.02,
                 **engine_kwargs: Any) -> None:
        self._own_engine = engine is None
        self.engine = engine if engine is not None else \
            Engine(scheduler=scheduler)
        if replicas is None:
            if cfg is None or params is None:
                raise ValueError("Router needs (cfg, params) or replicas=")
            replicas = [ServeEngine(cfg, params, engine=self.engine,
                                    **engine_kwargs)
                        for _ in range(int(n_replicas))]
        elif engine_kwargs:
            raise ValueError("replicas= and engine kwargs are exclusive")
        if not replicas:
            raise ValueError("Router needs at least one replica")
        for i, tier in enumerate(replicas):
            if not isinstance(tier, EngineLike):
                raise TypeError(f"replica {i} does not satisfy EngineLike: "
                                f"{type(tier).__name__}")
        self.transport = Transport(len(replicas) + 1, engine=self.engine)
        self.workers = [
            ReplicaWorker(tier, engine=self.engine, transport=self.transport,
                          rank=i + 1,
                          heartbeat_interval_s=heartbeat_interval_s)
            for i, tier in enumerate(replicas)]
        self.batcher = FairBatcher(self.engine, weights=weights,
                                   quantum=quantum,
                                   on_drop=self._on_intake_drop)
        # per-request lifecycle continuations: poll_only routes them to
        # step()'s cr.test() (driver thread), enqueue_complete lets a
        # request that raced to terminal still flow through them
        self.cr_track = self.engine.continue_init(poll_only=True,
                                                  enqueue_complete=True)
        if saturation is None:
            saturation = 2 * max(int(getattr(w.core, "max_batch", 1))
                                 for w in self.workers)
        self.saturation = max(1, int(saturation))
        self._quota = quota
        # tenant outstanding counts are read on submit() (client threads)
        # and written by tracking continuations (driver thread)
        self._quota_lock = threading.Lock()
        self._outstanding: Dict[str, int] = {}
        self._ewma_latency: Optional[float] = None
        self._tracked: Dict[int, _Tracked] = {}       # original.req_id ->
        self._track_seq = 0
        self._rank_inflight: Dict[int, int] = {w.rank: 0
                                               for w in self.workers}
        self._digests: Dict[int, Set[bytes]] = {w.rank: set()
                                                for w in self.workers}
        self._retired: List[Request] = []
        self._retired_lock = threading.Lock()
        self.stats = {"routed": 0, "affinity_hits": 0, "affinity_misses": 0,
                      "quota_refused": 0, "failovers": 0, "requeued": 0,
                      "retired": 0, "cancelled": 0, "expired": 0}
        # failure detector: replicas beat on the router transport; the
        # sweep is a TimerOp promise chain driven by progress() — every
        # failure reaction below runs inside that sweep continuation
        self.monitor = HeartbeatMonitor(
            self.transport, self.engine, ROUTER_RANK,
            watched=[w.rank for w in self.workers],
            timeout_s=heartbeat_timeout_s,
            sweep_interval_s=sweep_interval_s,
            on_failure=self._on_replica_dead,
            # the router's loop jit-compiles replica steps inline: a
            # stalled sweep must not read compile time as silence
            stall_guard_s=heartbeat_timeout_s)
        self._gossip_ops: Dict[int, Any] = {}
        for w in self.workers:
            self._post_gossip_recv(w.rank)

    # ------------------------------------------------------------- helpers
    @property
    def live_workers(self) -> List[ReplicaWorker]:
        return [w for w in self.workers if w.alive]

    def _worker(self, rank: int) -> ReplicaWorker:
        return self.workers[rank - 1]

    # ------------------------------------------------------------- clients
    def submit(self, request: Request) -> Request:
        """Thread-safe intake: validate against replica limits, enforce
        the tenant quota, then queue on the fairness scheduler."""
        self._validate(request)
        tenant = request.tenant
        limit = self._tenant_quota(tenant)
        with self._quota_lock:
            held = self._outstanding.get(tenant, 0)
            if limit is not None and held >= limit:
                self.stats["quota_refused"] += 1
                retry = self._ewma_latency if self._ewma_latency else 0.05
                raise QuotaExceeded(
                    f"tenant {tenant!r} has {held} outstanding requests "
                    f"(quota {limit}); retry in ~{retry:.3f}s",
                    tenant=tenant, retry_after_s=retry)
            self._outstanding[tenant] = held + 1
        tr = _obs.TRACE
        if tr is not None and tr.want(request.req_id):
            tr.evt(_obs_events.REQ_SUBMIT, request.req_id, "router")
        tracked = _Tracked(request, self._track_seq)
        self._track_seq += 1
        self._tracked[request.req_id] = tracked
        # the original's terminal transition — retire via replay, user
        # cancel, expiry — funnels through ONE tracking continuation
        self.engine.continue_when(request, self._on_original_done, tracked,
                                  cr=self.cr_track)
        self.batcher.submit(request)
        return request

    def _tenant_quota(self, tenant: str) -> Optional[int]:
        if self._quota is None:
            return None
        if isinstance(self._quota, dict):
            return self._quota.get(tenant)
        return int(self._quota)

    def _validate(self, request: Request) -> None:
        core = self.workers[0].core
        if getattr(core, "paged", False):
            plen = int(np.asarray(request.prompt).reshape(-1).shape[0])
            total = plen + request.max_new_tokens
            if total > core.max_seq_len:
                raise ValueError(f"request needs {total} tokens > "
                                 f"max_seq_len={core.max_seq_len}")
            if pages_for(total, core.page_size) > core.pool.total_pages:
                raise ValueError(
                    "request needs more pages than a replica pool holds "
                    f"({core.pool.total_pages})")

    def close_intake(self) -> None:
        self.batcher.close()

    @property
    def retired(self) -> List[Request]:
        with self._retired_lock:
            return list(self._retired)

    # --------------------------------------------------- lifecycle tracking
    def _on_intake_drop(self, req: Request) -> None:
        """FairBatcher refused a queued request (cancelled while queued,
        or past-deadline). The tracking continuation on the request does
        the accounting; nothing to release here (no pages at intake)."""

    def _on_original_done(self, statuses, tracked: _Tracked) -> None:
        req = tracked.original
        self._tracked.pop(req.req_id, None)
        with self._quota_lock:
            held = self._outstanding.get(req.tenant, 0)
            if held:
                self._outstanding[req.tenant] = held - 1
        state = req.req_state
        if state is RequestState.FINISHED:
            lat = (req.finish_time or time.monotonic()) - req.arrival_time
            self._ewma_latency = lat if self._ewma_latency is None else \
                0.8 * self._ewma_latency + 0.2 * lat
            with self._retired_lock:
                self._retired.append(req)
            self.stats["retired"] += 1
        elif state is RequestState.CANCELLED:
            self.stats["cancelled"] += 1
        else:
            self.stats["expired"] += 1
        # a client cancel/expiry while a shadow is still decoding: reap it
        shadow = tracked.shadow
        if shadow is not None and not shadow.is_terminal \
                and state is not RequestState.FINISHED:
            shadow.cancel()

    def _on_shadow_done(self, statuses, meta) -> None:
        rank, shadow = meta
        self._rank_inflight[rank] -= 1

    # ------------------------------------------------------------- routing
    def _prompt_keys(self, prompt: Any) -> List[bytes]:
        core = self.workers[0].core
        if not getattr(core, "paged", False):
            return []
        ps = core.page_size
        toks = np.asarray(prompt, np.int32).reshape(-1)
        # cap one token short of the prompt, mirroring match_prefix: a
        # "hit" here must mean actual page reuse at the replica
        return prefix_keys(toks, ps, (len(toks) - 1) // ps)

    def _choose_replica(self, req: Request) -> Optional[ReplicaWorker]:
        """Affinity first (longest leading digest run, not saturated),
        else least-loaded live replica with headroom."""
        live = [w for w in self.live_workers
                if self._rank_inflight[w.rank] < self.saturation]
        if not live:
            return None
        keys = self._prompt_keys(req.prompt)
        best, best_score = None, 0
        for w in live:
            digests = self._digests[w.rank]
            score = 0
            for k in keys:
                if k not in digests:
                    break
                score += 1
            if score > best_score or (
                    best is not None and score == best_score and score > 0
                    and self._rank_inflight[w.rank]
                    < self._rank_inflight[best.rank]):
                best, best_score = w, score
        if best is not None:
            self.stats["affinity_hits"] += 1
            return best
        self.stats["affinity_misses"] += 1
        return min(live, key=lambda w: (self._rank_inflight[w.rank], w.rank))

    def _dispatch(self) -> int:
        capacity = sum(max(0, self.saturation - self._rank_inflight[w.rank])
                       for w in self.live_workers)
        if capacity == 0:
            return 0
        routed = 0
        for req in self.batcher.admit(capacity):
            tracked = self._tracked.get(req.req_id)
            if tracked is None:
                # submitted around the router (protocol allows it): track
                # now so failover still covers it — quota was never held
                tracked = _Tracked(req, self._track_seq)
                self._track_seq += 1
                self._tracked[req.req_id] = tracked
                self.engine.continue_when(req, self._on_original_done,
                                          tracked, cr=self.cr_track)
            worker = self._choose_replica(req)
            if worker is None:
                self.batcher.requeue(req)
                break
            self._send_to(worker, tracked)
            routed += 1
        return routed

    def _send_to(self, worker: ReplicaWorker, tracked: _Tracked) -> None:
        """Create the engine-side shadow and hand it to ``worker`` over
        the route channel; the original never leaves the router."""
        orig = tracked.original
        skip = orig.rewind_holdback()
        shadow = Request(orig.prompt, orig.config,
                         arrival_time=orig.arrival_time)
        shadow.attach_stream(_ReplayAdapter(orig, skip))
        tr = _obs.TRACE
        if tr is not None and tr.want(shadow.req_id):
            # the link event lets the exporter collapse the shadow's
            # whole replica-side timeline onto the original's track
            # (transitively, across repeated failover re-shadows)
            tr.evt(_obs_events.REQ_LINK, shadow.req_id, "router",
                   meta=orig.req_id)
        tracked.shadow = shadow
        tracked.rank = worker.rank
        self._rank_inflight[worker.rank] += 1
        self.engine.continue_when(shadow, self._on_shadow_done,
                                  (worker.rank, shadow), cr=self.cr_track)
        worker.expect(shadow)
        self.transport.isend(ROUTER_RANK, worker.rank, ROUTE_TAG,
                             RouteMsg(shadow.req_id))
        # optimistic digest insert: same-prefix traffic right behind this
        # request routes to the same replica without a gossip round-trip
        self._digests[worker.rank].update(self._prompt_keys(orig.prompt))
        self.stats["routed"] += 1

    # -------------------------------------------------------------- gossip
    def _post_gossip_recv(self, rank: int) -> None:
        op = self.transport.irecv(ROUTER_RANK, source=rank, tag=GOSSIP_TAG)
        self._gossip_ops[rank] = op
        self.engine.continue_when(op, self._on_gossip, (rank, op),
                                  cr=self._worker(rank).cr, flags=_FLAGS)

    def _on_gossip(self, statuses, meta) -> None:
        rank, op = meta
        if op.state is OpState.CANCELLED:
            return                       # replica dead: don't re-arm
        msg: PrefixDigestMsg = op.status.payload
        self._post_gossip_recv(rank)
        if self._rank_inflight[rank] == 0:
            # authoritative replace (picks up evictions) only when no
            # optimistic in-flight entries could be clobbered
            self._digests[rank] = set(msg.digests)
        else:
            self._digests[rank].update(msg.digests)

    # ------------------------------------------------------------ failover
    def _on_replica_dead(self, rank: int) -> None:
        """Runs inside the monitor's sweep continuation. Tear the dead
        replica out of the fleet and requeue its in-flight work."""
        worker = self._worker(rank)
        worker.kill()                    # idempotent when already killed
        self.monitor.unwatch(rank)
        self.stats["failovers"] += 1
        # cancel the control plane: the replica's pending receives (the
        # standing route recv) and the router's receives from it (gossip).
        # Their continuations observe CANCELLED and do not re-arm.
        self.transport.cancel_posted(rank)
        self.transport.cancel_posted(ROUTER_RANK, source=rank,
                                     tag=GOSSIP_TAG)
        self._digests[rank].clear()      # elastic shrink of the affinity map
        # requeue this replica's in-flight requests at the head of their
        # priority class. Reverse tracked order: _push_head prepends, so
        # iterating newest-first restores oldest-first at the head.
        stranded = sorted((t for t in self._tracked.values()
                           if t.rank == rank),
                          key=lambda t: t.seq, reverse=True)
        tr = _obs.TRACE
        for t in stranded:
            shadow, t.shadow, t.rank = t.shadow, None, None
            t.replays += 1
            if shadow is not None and not shadow.is_terminal:
                shadow.cancel()          # adapter ignores router cancels
            if not t.original.is_terminal:
                if tr is not None and tr.want(t.original.req_id):
                    tr.evt(_obs_events.REQ_REPLAY, t.original.req_id,
                           "router", meta=rank)
                self.batcher.requeue(t.original)
                self.stats["requeued"] += 1
        # reclaim the dead tier's resources (pages of cancelled shadows)
        worker.quiesce()

    def kill_replica(self, rank: int) -> None:
        """Test/chaos hook: silence a replica NOW (stops its stepping and
        beats); detection and failover still flow through the heartbeat
        sweep, exactly as a real silent death would."""
        self._worker(rank).kill()

    # ----------------------------------------------------------------- loop
    def step(self) -> bool:
        routed = self._dispatch()
        progressed = bool(routed)
        for w in self.workers:
            progressed = w.step() or progressed
        self.cr_track.test()             # lifecycle continuations
        self.engine.tick()
        self.monitor.progress()          # drives the sweep promise chain
        return progressed

    @property
    def idle(self) -> bool:
        return (not self._pending_intake() and not self._tracked
                and self.cr_track.active_count == 0
                and all(w.tier.idle for w in self.live_workers))

    def _pending_intake(self) -> bool:
        return bool(self.batcher.queued or self.batcher.cr.active_count)

    def run(self, timeout: Optional[float] = None,
            idle_sleep: float = 5e-5, until=None) -> List[Request]:
        deadline = None if timeout is None else time.monotonic() + timeout
        done = until if until is not None else \
            (lambda: self.batcher.closed and self.idle)
        while not done():
            if deadline is not None and time.monotonic() > deadline:
                raise TimeoutError(
                    f"router loop timed out: queued={self.batcher.queued} "
                    f"tracked={len(self._tracked)} "
                    f"live={[w.rank for w in self.live_workers]}")
            if not self.step():
                time.sleep(idle_sleep)
        return self.retired

    # -------------------------------------------------------------- metrics
    def metrics(self) -> ServeMetrics:
        out = summarize(self.retired)
        out.update(self.stats)
        routed = self.stats["routed"]
        out["affinity_hit_rate"] = \
            self.stats["affinity_hits"] / routed if routed else 0.0
        out["replicas"] = len(self.workers)
        out["replicas_live"] = len(self.live_workers)
        out["pages_in_use"] = sum(w.pool.pages_in_use
                                  for w in self.workers
                                  if w.pool is not None)
        out["total_pages"] = sum(w.pool.total_pages
                                 for w in self.workers if w.pool is not None)
        out["rank_inflight"] = dict(self._rank_inflight)
        out["per_tenant"] = {t: dict(s) for t, s
                             in self.batcher.tenant_stats.items()}
        out["per_replica"] = {w.rank: w.tier.metrics()
                              for w in self.workers}
        st = self.transport.stats()
        out["transport"] = st
        out.update(transport_fields(st))
        return ServeMetrics.from_flat(out)

    def shutdown(self) -> None:
        self.batcher.close()
        self.monitor.stop()
        self.transport.cancel_posted(ROUTER_RANK)  # heartbeat + gossip recvs
        for w in self.workers:
            w.shutdown()
        self.transport.shutdown()
        if self._own_engine:
            self.engine.shutdown()
