"""Completion status objects — the analogue of ``MPI_Status``.

The paper requires that status objects are *set before the continuation is
invoked* (or before ``MPIX_Continue[all]`` returns on immediate completion)
and that callbacks can detect cancellation (``MPI_Test_cancelled``,
paper Listing 4). We model that contract here.
"""
from __future__ import annotations

import dataclasses
import enum
import threading
from typing import Any, Optional

# Sentinel mirroring MPI_STATUS_IGNORE: caller does not want a status.
STATUS_IGNORE = None


class OpState(enum.Enum):
    """Lifecycle of a completable operation."""

    PENDING = "pending"
    COMPLETE = "complete"
    CANCELLED = "cancelled"
    FAILED = "failed"


@dataclasses.dataclass
class Status:
    """Completion record handed to a continuation callback.

    Mirrors ``MPI_Status``: identifies the source/tag of a message-like
    operation, whether the op was cancelled, an error (if any) and an
    op-specific payload (e.g. the received message, the ready jax.Array,
    the written checkpoint path).
    """

    source: Optional[int] = None
    tag: Optional[int] = None
    cancelled: bool = False
    error: Optional[BaseException] = None
    payload: Any = None
    #: number of payload bytes, where meaningful (message ops)
    count: int = 0

    def test_cancelled(self) -> bool:
        """``MPI_Test_cancelled`` analogue (paper Listing 4)."""
        return self.cancelled

    def raise_for_error(self) -> None:
        if self.error is not None:
            raise self.error


class OneShotLatch:
    """A tiny single-transition latch used by ops to publish completion.

    Thread-safe; ``fire`` is idempotent and returns True only for the first
    caller, so completion hooks run exactly once no matter how many threads
    race on the transition (multiple application threads may be inside the
    engine concurrently — paper §3).
    """

    __slots__ = ("_fired", "_lock")

    def __init__(self) -> None:
        self._fired = False
        self._lock = threading.Lock()

    @property
    def fired(self) -> bool:
        return self._fired

    def fire(self) -> bool:
        with self._lock:
            if self._fired:
                return False
            self._fired = True
            return True
